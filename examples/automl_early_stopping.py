"""End-to-end driver: LKGP-driven early stopping over a pool of REAL
LM training runs (the paper's AutoML use case, complete loop).

8 hyper-parameter configurations (learning rate x weight decay) of the
reduced RWKV-6 arch train on the synthetic token pipeline; after every
2 "epochs" the FreezeThawScheduler folds the new observations into its
LKGP state (``extend`` + warm-started ``refit``) and stops runs predicted
to end badly, reallocating budget.

    PYTHONPATH=src python examples/automl_early_stopping.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.autotune import AutotuneConfig, FreezeThawScheduler
from repro.configs import get_smoke_config
from repro.core import LKGPConfig
from repro.data import TokenPipeline
from repro.models import build_model
from repro.train.optimizers import OptConfig
from repro.train.trainer import make_train_step
from repro.launch.mesh import make_debug_mesh

STEPS_PER_EPOCH = 8
BATCH, SEQ = 8, 32


class Run:
    """One training run = one hyper-parameter configuration."""

    def __init__(self, idx, lr, wd, mesh):
        self.cfg = get_smoke_config("rwkv6_1b6")
        self.model = build_model(self.cfg)
        opt = OptConfig(name="adamw", peak_lr=lr, weight_decay=wd,
                        warmup_steps=4, decay_steps=200)
        self.setup = make_train_step(self.model, mesh, opt_cfg=opt)
        self.state = jax.jit(self.setup.init_state,
                             out_shardings=self.setup.state_shardings)(
                                 jax.random.key(idx))
        self.pipe = TokenPipeline(self.cfg.vocab_size, BATCH, SEQ, seed=0)
        self.step = 0
        self.eval_tokens, self.eval_labels = self.pipe.batch_at(10_000)

    def train_one_epoch(self) -> float:
        for _ in range(STEPS_PER_EPOCH):
            tokens, labels = self.pipe.batch_at(self.step)
            self.state, m = self.setup.step_fn(
                self.state, {"tokens": jnp.asarray(tokens),
                             "labels": jnp.asarray(labels)})
            self.step += 1
        # validation "accuracy" proxy: exp(-eval loss)
        loss = self.model.loss(self.state.params,
                               {"tokens": jnp.asarray(self.eval_tokens),
                                "labels": jnp.asarray(self.eval_labels)})
        return float(np.exp(-float(loss)))


def main():
    mesh = make_debug_mesh(data=1, model=1)
    lrs = [1e-5, 3e-3, 1e-3, 3e-4, 1e-2, 3e-2, 3e-5, 1e-4]
    wds = [0.0, 0.1, 0.0, 0.1, 0.0, 0.1, 0.1, 0.0]
    X = np.array([[np.log10(lr), wd] for lr, wd in zip(lrs, wds)])
    print("pool: 8 configs of reduced rwkv6_1b6, "
          f"{STEPS_PER_EPOCH} steps/epoch, batch {BATCH}x{SEQ}")
    with mesh:
        runs = [Run(i, lr, wd, mesh) for i, (lr, wd) in
                enumerate(zip(lrs, wds))]
        sched = FreezeThawScheduler(
            X, [r.train_one_epoch for r in runs],
            AutotuneConfig(max_epochs=10, refit_every=2,
                           min_epochs_before_stop=4, ucb_beta=1.5,
                           gp=LKGPConfig(lbfgs_iters=25)))
        full_budget = len(runs) * 10
        summary = sched.run(total_epoch_budget=full_budget)

    print("\nstop events:")
    for ev in summary["stop_events"]:
        print(f"  after epoch {ev['epoch']}: stopped {ev['stopped']} "
              f"({ev['active']} remain)")
    print(f"epochs spent: {summary['epochs_spent']} / {full_budget} "
          f"(saved {1 - summary['epochs_spent']/full_budget:.0%})")
    print(f"survivors: {summary['survivors']}")
    print(f"best observed accuracy-proxy: {summary['observed_best']:.4f}")

    # the scheduler must have kept at least one of the best-LR configs
    best_cfg = int(np.argmax([max(sched.Y[i]) for i in range(len(runs))]))
    assert best_cfg in summary["survivors"], \
        f"scheduler stopped the best config {best_cfg}"
    assert summary["epochs_spent"] < full_budget, "no budget was saved"
    print("\nOK: best config survived; budget saved by early stopping.")


if __name__ == "__main__":
    main()
