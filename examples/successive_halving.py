"""30-second Successive Halving demo: LKGP-ranked vs rank-based promotion.

A pool of synthetic learning curves (crossing regime: high-asymptote
configs are slow starters) with a few configs pre-trained to completion
("history"). Both promotion modes follow the identical rung schedule — the
comparison is at exactly equal epoch budget; the LKGP mode transfers from
the completed history curves through the config kernel, the rank-based
baseline can only look at each run's current metric.

    PYTHONPATH=src python examples/successive_halving.py
"""
import time

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.autotune import SHConfig, SuccessiveHalvingScheduler
from repro.core import LKGPConfig
from repro.data import noisy_step_fns, sample_task

N, M, N_HIST = 16, 12, 4
OBS_NOISE, SPIKE_PROB = 0.02, 0.03


def main():
    t_start = time.time()
    task = sample_task(seed=502, n=N, m=M, d=5, noise=0.005,
                       spike_prob=0.0, diverge_prob=0.0, crossing=True)
    rng = np.random.default_rng(0)
    hist = rng.choice(N, N_HIST, replace=False)
    fresh = np.setdiff1d(np.arange(N), hist).tolist()
    true_final = task.Y_full[:, -1]
    best = float(true_final[fresh].max())
    print(f"pool: {N} configs x {M} epochs, {N_HIST} pre-completed "
          f"(history), racing {len(fresh)}")

    results = {}
    for promo in ("lkgp", "rank"):
        cfg = SHConfig(max_epochs=M, min_epochs=2, eta=3, promotion=promo,
                       ucb_beta=0.0, refit_lbfgs_iters=8,
                       gp=LKGPConfig(lbfgs_iters=20, posterior_samples=64,
                                     slq_probes=8, slq_iters=15))
        sched = SuccessiveHalvingScheduler(
            task.X, noisy_step_fns(task, 7, OBS_NOISE, SPIKE_PROB),
            cfg, seed=0)
        for i in hist:
            sched.pool.advance_to(i, M, charge=False)
        summary = sched.run(subset=fresh)
        sel = summary["selected"]
        regret = best - float(true_final[sel])
        results[promo] = (regret, summary["epochs_spent"])
        print(f"\nSH-{promo}: selected config {sel} "
              f"(true final {true_final[sel]:.3f}, regret {regret:.3f}) "
              f"in {summary['epochs_spent']} epochs")
        for rung in summary["rungs"]:
            print(f"  rung {rung['rung']} @ {rung['target_epochs']} epochs: "
                  f"{len(rung['active'])} active"
                  + (f" -> promoted {rung['promoted']}"
                     if "promoted" in rung else ""))

    (r_gp, e_gp), (r_rk, e_rk) = results["lkgp"], results["rank"]
    assert e_gp == e_rk, "promotion modes must spend identical budgets"
    print(f"\nequal budget: {e_gp} epochs each")
    print(f"regret: lkgp {r_gp:.3f} vs rank {r_rk:.3f}"
          + ("  (LKGP promotion wins)" if r_gp < r_rk else ""))
    print(f"total wall time: {time.time() - t_start:.1f}s")


if __name__ == "__main__":
    main()
