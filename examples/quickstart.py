"""Quickstart: fit a Latent Kronecker GP to partial learning curves and
predict their continuations.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.core import LKGPConfig, fit, posterior
from repro.data import sample_task


def main():
    # 16 hyper-parameter configs, 20 epochs, curves observed partially
    task = sample_task(seed=7, n=16, m=20, d=7)
    print(f"task: X {task.X.shape}, curves {task.Y.shape}, "
          f"{int(task.mask.sum())}/{task.mask.size} values observed")

    state = fit(task.X, task.t, task.Y, task.mask, LKGPConfig(lbfgs_iters=50))
    res = state.fit_result
    print(f"fit: {res.n_iters} L-BFGS iters, {res.n_evals} evals, "
          f"objective {res.fun:.4f} (backend: {state.backend_used})")
    print(f"learned noise sigma^2 = "
          f"{float(np.exp(state.params.raw_noise)):.2e}")

    mean, var = posterior(state).final()
    truth = task.Y_full[:, -1]
    err = np.abs(np.asarray(mean) - truth)
    z = err / np.sqrt(np.asarray(var))
    print("\nconfig | observed | predicted final | true final | |z|")
    for i in range(len(truth)):
        n_obs = int(task.mask[i].sum())
        print(f"  {i:3d}  | {n_obs:2d}/20 ep | {float(mean[i]):.4f}        "
              f"| {truth[i]:.4f}    | {z[i]:.2f}")
    rmse = float(np.sqrt(np.mean(err ** 2)))
    cover = float(np.mean(z < 2.0))
    print(f"\nRMSE(final) = {rmse:.4f};  |z|<2 coverage = {cover:.0%}")
    assert rmse < 0.1, "quickstart regression: rmse too high"


if __name__ == "__main__":
    main()
