"""Transformer learning-curve baseline vs the LKGP, in ~60 seconds.

Pre-trains a tiny amortized curve-prediction transformer on streams of
synthetic tasks, then scores it head-to-head against the LKGP on held-out
tasks at three observation cutoffs — the paper's "our GP model can match
the performance of a Transformer" experiment at demo scale.

    PYTHONPATH=src python examples/transformer_baseline.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.baselines import (CurveTransformerConfig, PretrainConfig,
                             head_to_head, pretrain)
from repro.core import LKGPConfig
from repro.data import sample_suite


def main():
    model_cfg = CurveTransformerConfig(d_model=32, num_layers=2,
                                       num_heads=2, d_ff=64)
    pre_cfg = PretrainConfig(steps=150, tasks_per_step=4, n=10, m=9,
                             log_every=50)
    print(f"pre-training ({pre_cfg.steps} steps on streamed synthetic "
          f"tasks, curriculum over observed-prefix fraction)...")
    params, info = pretrain(model_cfg, pre_cfg)
    print(f"pretrain nll {info['first_loss']} -> {info['final_loss']} "
          f"in {info['train_s']}s\n")

    tasks = sample_suite(777, 2, n=10, m=9, d=7, crossing=True)
    rows = head_to_head(params, model_cfg, tasks, cutoffs=(0.2, 0.4, 0.7),
                        gp_cfg=LKGPConfig(lbfgs_iters=30), seed=0)

    print("model       | cutoff | NLL     | MAE    | rank corr | fit+pred s")
    for model in ("lkgp", "transformer"):
        for cut in (0.2, 0.4, 0.7):
            sel = [r for r in rows
                   if r["model"] == model and r["cutoff"] == cut]
            nll = np.mean([r["nll"] for r in sel])
            mae = np.mean([r["mae"] for r in sel])
            rho = np.mean([r["rank_corr"] for r in sel])
            sec = np.mean([r["fit_s"] + r["predict_s"] for r in sel])
            print(f"{model:11s} |  {cut:.1f}   | {nll:7.3f} | {mae:.4f} | "
                  f"{rho:9.3f} | {sec:.2f}")

    lk = np.mean([r["mae"] for r in rows if r["model"] == "lkgp"])
    tf = np.mean([r["mae"] for r in rows if r["model"] == "transformer"])
    print(f"\nmean MAE: lkgp {lk:.4f} vs transformer {tf:.4f} "
          f"(amortized over the exact task prior)")
    assert np.isfinite(lk) and np.isfinite(tf)
    return rows


if __name__ == "__main__":
    main()
