"""Fig. 1 reproduction: posterior samples over learning-curve continuations.

Fits the LKGP to 16 partially observed curves and draws Matheron posterior
samples; prints an ASCII panel per curve showing observed prefix, the
posterior band, and the ground-truth continuation — the qualitative claims
of Fig. 1: confident prediction near convergence, widening uncertainty for
short prefixes, sane behaviour on noisy/spiky curves.
"""
import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.core import LKGPConfig, fit, posterior
from repro.data import sample_task


def ascii_panel(t, y_obs, mask, samples, y_true, width=64, height=12):
    lo = min(float(np.min(samples)), float(np.min(y_true))) - 0.02
    hi = max(float(np.max(samples)), float(np.max(y_true))) + 0.02
    grid = [[" "] * width for _ in range(height)]
    m = len(t)
    q05, q95 = np.quantile(samples, [0.05, 0.95], axis=0)

    def put(x, y, ch):
        col = int(x / (m - 1) * (width - 1))
        row = height - 1 - int((y - lo) / (hi - lo) * (height - 1))
        row = min(max(row, 0), height - 1)
        grid[row][col] = ch

    for j in range(m):
        for q in np.linspace(q05[j], q95[j], 6):
            put(j, q, ".")
    for j in range(m):
        if mask[j] > 0:
            put(j, y_obs[j], "#")           # observed
        else:
            put(j, y_true[j], "o")          # ground-truth continuation
    return "\n".join("".join(r) for r in grid)


def main():
    task = sample_task(seed=3, n=16, m=20, d=7,
                       observed_fraction=(0.15, 0.85))
    state = fit(task.X, task.t, task.Y, task.mask,
                LKGPConfig(lbfgs_iters=50, posterior_samples=128))
    samples = np.asarray(posterior(state).samples(jax.random.PRNGKey(0)))

    inside = []
    show = [int(np.argmax(task.mask.sum(1))), int(np.argmin(task.mask.sum(1)))]
    for i in range(task.Y.shape[0]):
        s = samples[:, i, :]
        q02, q98 = np.quantile(s, [0.02, 0.98], axis=0)
        unobs = task.mask[i] == 0
        if unobs.any():
            frac = np.mean((task.Y_full[i, unobs] >= q02[unobs])
                           & (task.Y_full[i, unobs] <= q98[unobs]))
            inside.append(frac)
        if i in show:
            kind = "long prefix" if i == show[0] else "short prefix"
            print(f"\ncurve {i} ({kind}, {int(task.mask[i].sum())}/20 epochs "
                  f"observed)  [#=observed o=truth .=posterior band]")
            print(ascii_panel(task.t, task.Y_full[i], task.mask[i], s,
                              task.Y_full[i]))
    cov = float(np.mean(inside))
    print(f"\nground-truth continuations inside 2-98% posterior band: "
          f"{cov:.0%} (Fig 1 claim: spread covers the truth)")
    assert cov > 0.75, cov


if __name__ == "__main__":
    main()
