"""Real-dataset workflow: load an LCBench-format artifact, fit, replay.

Demonstrates the pluggable dataset subsystem end to end on the committed
mini fixture (non-uniform log-spaced budget grid + early-stop masks):

1. resolve a :class:`repro.data.CurveSource` from a spec string;
2. fit the LKGP on one task's observed cells — the artifact's log-spaced
   fidelity grid flows into the K2 Gram as-is;
3. predict final-budget values and score them against the recorded curves;
4. replay the task through a Successive Halving race
   (``RunPool.replay``-style step functions, LKGP-ranked promotion).

    PYTHONPATH=src python examples/lcbench_dataset.py [spec]
"""
import sys

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.autotune import SHConfig, SuccessiveHalvingScheduler
from repro.core import LKGPConfig, fit, posterior
from repro.data import get_source, replay_step_fns

SPEC = (sys.argv[1] if len(sys.argv) > 1
        else "lcbench:tests/fixtures/lcbench_mini.npz")


def main():
    src = get_source(SPEC)
    tasks = src.tasks()
    task = tasks[0]
    n, m = task.Y_full.shape
    t = np.asarray(task.t)
    print(f"dataset {src.dataset_id}: {len(tasks)} tasks; task 0 has "
          f"{n} configs over {m} budgets t=[{t[0]:g}..{t[-1]:g}] "
          f"({int(task.mask.sum())} observed cells)")

    # -- curve prediction on the artifact's own (non-uniform) grid --------
    state = fit(task.X, task.t, task.Y, task.mask,
                LKGPConfig(lbfgs_iters=30))
    mean, var = posterior(state).final()
    err = np.abs(np.asarray(mean) - task.Y_full[:, -1])
    print(f"final-budget prediction: mae {err.mean():.4f}, "
          f"mean std {np.sqrt(np.asarray(var)).mean():.4f}")

    # -- replay the recorded curves through a scheduler race --------------
    sched = SuccessiveHalvingScheduler(
        task.X, replay_step_fns(task, seed=0),
        SHConfig(max_epochs=m, min_epochs=1, eta=3, promotion="lkgp",
                 ucb_beta=0.0, refit_lbfgs_iters=8,
                 gp=LKGPConfig(lbfgs_iters=15)),
        seed=0, t=task.t)
    summary = sched.run()
    best = int(np.argmax(task.Y_full[:, -1]))
    sel = summary["selected"]
    print(f"SH replay: selected config {sel} "
          f"(true best {best}) after {summary['epochs_spent']} budget "
          f"steps; regret "
          f"{task.Y_full[best, -1] - task.Y_full[sel, -1]:.4f}")


if __name__ == "__main__":
    main()
