"""Multi-tenant streaming prediction service demo.

    PYTHONPATH=src python examples/serving_demo.py

Walks the full session lifecycle against synthetic tenants:

1. coalesced cold fits (one vmapped L-BFGS across tenants),
2. per-request vs coalesced predictions (bitwise identical),
3. streaming observes (``extend`` + periodic warm ``refit``) invalidating
   the warm posterior cache,
4. LRU eviction under a small capacity,
5. the Future-based async surface (``submit_predict`` / ``flush``).
"""
import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.core import LKGPConfig
from repro.data import sample_task
from repro.serving import PredictionService, ServiceConfig


def reveal_one_epoch(mask: np.ndarray) -> np.ndarray:
    """Grow every curve's observed prefix by one epoch."""
    mask = mask.copy()
    for i in range(mask.shape[0]):
        k = int(mask[i].sum())
        if k < mask.shape[1]:
            mask[i, k] = 1.0
    return mask


def main():
    tenants = [f"team-{c}" for c in "abcdef"]
    tasks = {name: sample_task(seed=i, n=8, m=10, d=4)
             for i, name in enumerate(tenants)}
    svc = PredictionService(ServiceConfig(
        gp=LKGPConfig(lbfgs_iters=12, backend="dense"),
        capacity=len(tenants), refit_every=2, refit_lbfgs_iters=4))

    # 1. Coalesced cold fits: same-shape new tasks share one fit_batch.
    infos = svc.observe_batch([
        dict(tenant=name, task="sweep", X=task.X, t=task.t,
             Y=task.Y, mask=task.mask)
        for name, task in tasks.items()])
    print(f"cold fits: {[i['action'] for i in infos]}")

    # 2. Per-request and coalesced predictions agree bitwise.
    singles = {name: svc.predict(name, "sweep") for name in tenants}
    coalesced = svc.predict_many([(name, "sweep") for name in tenants])
    assert all(np.array_equal(singles[p.tenant].mean, p.mean)
               and np.array_equal(singles[p.tenant].var, p.var)
               for p in coalesced)
    print(f"coalesced (batch={coalesced[0].batch_size}) == per-request: "
          "bitwise")

    # Warm repeat: same state object -> state-keyed posterior cache hit.
    again = svc.predict(tenants[0], "sweep")
    assert np.array_equal(again.mean, singles[tenants[0]].mean)

    # 3. Stream observations; the new state invalidates cached solves.
    masks = {name: np.asarray(task.mask).copy()
             for name, task in tasks.items()}
    for rnd in range(3):
        for name, task in tasks.items():
            masks[name] = reveal_one_epoch(masks[name])
            Y = np.where(masks[name] > 0, np.asarray(task.Y_full), 0.0)
            info = svc.observe(name, "sweep", Y, masks[name])
        preds = svc.predict_many([(name, "sweep") for name in tenants])
        best = max(float(np.max(p.mean)) for p in preds)
        print(f"round {rnd}: last action={info['action']:<12s} "
              f"gen={info['generation']} best-final={best:.4f}")

    # 4. LRU eviction: a small store drops the least-recently-used session.
    small = PredictionService(ServiceConfig(
        gp=LKGPConfig(lbfgs_iters=5, backend="dense"), capacity=2))
    for i, name in enumerate(tenants[:3]):
        task = tasks[name]
        small.observe(name, "sweep", task.Y, task.mask, X=task.X, t=task.t)
    stats = small.store.stats()
    assert stats["size"] == 2 and stats["evictions"] == 1
    print(f"eviction under capacity=2: {stats}")

    # 5. Async surface: queued futures resolve in one coalesced flush.
    futures = [svc.submit_predict(name, "sweep") for name in tenants]
    resolved = svc.flush()
    results = [f.result() for f in futures]
    assert resolved == len(tenants)
    assert all(r.batch_size == len(tenants) for r in results)
    print(f"async flush: {resolved} futures in one batch of "
          f"{results[0].batch_size}")

    metrics = svc.metrics()
    print(f"metrics: predicts={metrics['counters']['predicts']} "
          f"observes={metrics['counters']['observes']} "
          f"refits={metrics['counters']['refits']} "
          f"p50={metrics['predict_latency']['p50_ms']:.2f} ms")
    print("serving demo OK")


if __name__ == "__main__":
    main()
