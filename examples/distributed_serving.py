"""Serve a small model with batched requests on a multi-device mesh.

Demonstrates the serving path end-to-end: sharded params + KV cache,
prefill, then batched greedy decode. Run with host-device emulation:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/distributed_serving.py
"""
import os
import sys

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                               + os.environ.get("XLA_FLAGS", ""))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.distributed.sharding import TP_RULES
from repro.models import build_model
from repro.train.trainer import make_serve_steps
from repro.launch.mesh import make_debug_mesh


def main(arch="recurrentgemma_2b", batch=8, prompt_len=16, gen_len=24):
    n = len(jax.devices())
    mesh = make_debug_mesh(data=max(1, n // 2), model=min(2, n))
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    serve = make_serve_steps(model, mesh, rules=TP_RULES,
                             max_len=prompt_len + gen_len)
    with mesh:
        params = jax.jit(model.init,
                         out_shardings=serve["param_shardings"])(
                             jax.random.key(0))
        prompts = jax.random.randint(jax.random.PRNGKey(1),
                                     (batch, prompt_len), 0, cfg.vocab_size)
        logits, cache = jax.jit(serve["prefill"])(params,
                                                  {"tokens": prompts})
        step = jax.jit(serve["decode_step"], donate_argnums=(1,))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out = [tok]
        for _ in range(gen_len - 1):
            logits, cache = step(params, cache, tok)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            out.append(tok)
    gen = np.asarray(jnp.concatenate(out, axis=1))
    print(f"arch={arch} mesh={dict(mesh.shape)} served batch={batch}")
    print(f"prompt_len={prompt_len} generated={gen.shape[1]} tokens/request")
    for i in range(min(3, batch)):
        print(f"  request {i}: {gen[i, :12].tolist()} ...")
    assert gen.shape == (batch, gen_len)
    assert np.all(gen >= 0) and np.all(gen < cfg.vocab_size)
    print("OK: batched serving on the mesh.")


if __name__ == "__main__":
    main(*(sys.argv[1:2] or []))
