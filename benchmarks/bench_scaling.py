"""Scaling benchmarks: solver crossover (CG/PCG/SGD) + Fig. 3 reproduction.

Two modes share this module:

**Solver scaling (default CLI mode).** The unified solver stack
(``repro.core.solvers``) is raced on the iterative backend's latent-
Kronecker operator at n in {4096 .. 32768} (``--quick``: {256, 512}) with a
fixed operator-sweep budget per solver, emitting a CG/PCG/SGD crossover
table to ``BENCH_scaling.json``. This is the arXiv 2506.06895 regime
check: at small n CG's superlinear convergence wins; as n (and the
spectrum's spread) grows, fixed-budget SGD with Polyak averaging keeps
completing where CG's per-sweep advantage shrinks. Everything is explicit
float32 (the CI gate runs under JAX_ENABLE_X64=1): K1 at n=32768 is a
4 GiB dense f32 Gram, built in-place to keep one resident copy.

Acceptance (gated by ``check_regression.py --scaling``):

* ``sgd_completes_max_n`` — the SGD solver finishes the largest n without
  breakdown and with a finite residual (the headline "n=32k completes on
  the iterative backend with SGD");
* ``f32_posterior_mean_parity`` — posterior mean K1 (mask*alpha) K2 from
  the SGD alpha matches the CG alpha to rel-err <= 1e-4 at the smallest n;
* ``crossover_table_present`` — every (n, solver) cell was measured.

Wall times INCLUDE jit trace+compile (one compile per (n, solver) shape —
noted in ``meta``); they are machine-relative and never compared against a
committed baseline.

**Fig. 3 reproduction (``--fig3``; library entry :func:`main`).** Paper
protocol (App. C): random data, n = m, d = 10, no missing values; time and
peak-RSS of LKGP (iterative) vs naive Cholesky. Sizes are scaled down to a
single CPU core while keeping the asymptotic separation visible (naive
O(n^3 m^3) vs LKGP O(n^2 m + n m^2) per solve).
"""
from __future__ import annotations

import argparse
import gc
import json
import threading
import time

import jax
import numpy as np

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import psutil

from repro.core import LKGPConfig, fit, get_engine, posterior, resolve_solver


class PeakRSS:
    def __init__(self):
        self.proc = psutil.Process()
        self.peak = 0
        self._stop = False

    def __enter__(self):
        gc.collect()
        self.base = self.proc.memory_info().rss
        self.peak = self.base
        self._thread = threading.Thread(target=self._watch, daemon=True)
        self._thread.start()
        return self

    def _watch(self):
        while not self._stop:
            self.peak = max(self.peak, self.proc.memory_info().rss)
            time.sleep(0.005)

    def __exit__(self, *a):
        self._stop = True
        self._thread.join()

    @property
    def delta_mb(self):
        return (self.peak - self.base) / 2**20


# ==========================================================================
# Solver-scaling mode (CG / PCG / SGD crossover on the iterative backend)
# ==========================================================================
_SOLVER_M = 8          # progression-grid length (small: n is the story)
_SOLVER_D = 2
_NOISE = 1.0           # sigma^2; keeps kappa(A) ~ lambda_max(K1 (x) K2)
_LS = 0.05             # short RBF lengthscale: lambda_max(K1) ~ n*2*pi*ls^2


def _rbf_gram_inplace(X: np.ndarray, ls: float, jitter: float) -> np.ndarray:
    """Dense f32 RBF Gram, built with ONE resident (n, n) buffer.

    At n=32768 the Gram is 4 GiB; the naive ``exp(-d2 / .)`` broadcast
    holds three such buffers at peak. Everything here mutates the X@X.T
    product in place instead.
    """
    G = X @ X.T                                    # (n, n) f32
    sq = np.einsum("ij,ij->i", X, X)
    G *= np.float32(-2.0)
    G += sq[:, None]
    G += sq[None, :]
    np.maximum(G, np.float32(0.0), out=G)
    G *= np.float32(-1.0 / (2.0 * ls * ls))
    np.exp(G, out=G)
    G[np.diag_indices_from(G)] += np.float32(jitter)
    return G


def _solver_problem(n: int, m: int = _SOLVER_M, seed: int = 0,
                    smooth_y: bool = False):
    """f32 latent-Kronecker solve problem with a staircase mask.

    ``smooth_y`` draws Y from K1's range (Y = K1 @ Z, normalised) instead
    of white noise — the RHS then lives in the top eigenspace, which is
    what posterior RHS look like and what the parity check needs (white-
    noise RHS put most energy where lambda ~ 0 and the posterior mean is
    ~zero, making rel-err meaningless).
    """
    rng = np.random.default_rng(seed)
    X = rng.uniform(0, 1, (n, _SOLVER_D)).astype(np.float32)
    K1 = _rbf_gram_inplace(X, _LS, 1e-3)

    t = np.linspace(0.05, 1.0, m, dtype=np.float32)
    K2 = np.exp(-np.abs(t[:, None] - t[None, :]) / np.float32(0.5))
    K2 = K2.astype(np.float32)
    K2[np.diag_indices_from(K2)] += np.float32(1e-4)

    # Staircase mask: curve i observed for 2 .. m epochs, cycling.
    lengths = 2 + (np.arange(n) % (m - 1))
    mask = (np.arange(m)[None, :] < lengths[:, None]).astype(np.float32)

    Z = rng.normal(0, 1, (n, m)).astype(np.float32)
    if smooth_y:
        Y = K1 @ Z
        Y = (Y / max(float(np.abs(Y).max()), 1e-30)).astype(np.float32)
    else:
        Y = Z
    return K1, K2, mask, Y


def _solver_config(name: str, tol: float, budget: int) -> LKGPConfig:
    kw = dict(solver=name, cg_tol=tol, cg_max_iters=budget, sgd_iters=budget)
    if name == "pcg":
        kw["precond_rank"] = 15
    return LKGPConfig(**kw)


def _run_solver_cell(A, b, name: str, tol: float, budget: int) -> dict:
    cfg = _solver_config(name, tol, budget)
    t0 = time.time()
    res = resolve_solver(cfg, A).solve(A, b, cfg)
    jax.block_until_ready(res.x)
    wall = time.time() - t0
    rel = float(jnp.max(res.rel_residual))
    return {
        "solver": name,
        "wall_s": round(wall, 3),
        "iters": int(res.iters),
        "rel_residual": rel,
        "matvecs": int(res.matvecs) if res.matvecs is not None else None,
        "breakdown": bool(jnp.any(res.breakdown))
        if res.breakdown is not None else False,
        "completed": bool(np.isfinite(rel)),
    }


def _parity_check(n: int, tol_cg: float = 1e-6, tol_sgd: float = 2e-6,
                  max_iters: int = 3000) -> dict:
    """f32 posterior-mean parity: SGD alpha vs CG alpha at the smallest n.

    Both solvers run to tight tolerances on a smooth (in-range) RHS; the
    posterior mean on the training grid is K1 @ (mask * alpha) @ K2. The
    K (K + s^2 I)^{-1} composition damps exactly the directions the
    solvers converge slowest on, so mean rel-err tracks the residuals.
    """
    K1, K2, mask, Y = _solver_problem(n, smooth_y=True)
    engine = get_engine("iterative")
    K1j, K2j, mj = jnp.asarray(K1), jnp.asarray(K2), jnp.asarray(mask)
    A = engine.operator_from_grams(K1j, K2j, mj, _NOISE)
    b = mj * jnp.asarray(Y)

    cfg_cg = LKGPConfig(solver="cg", cg_tol=tol_cg, cg_max_iters=max_iters)
    cfg_sgd = LKGPConfig(solver="sgd", cg_tol=tol_sgd, sgd_iters=max_iters)
    res_cg = resolve_solver(cfg_cg, A).solve(A, b, cfg_cg)
    res_sgd = resolve_solver(cfg_sgd, A).solve(A, b, cfg_sgd)

    def mean_grid(alpha):
        return jnp.einsum("ij,jm,mk->ik", K1j, mj * alpha, K2j)

    m_cg = mean_grid(res_cg.x)
    m_sgd = mean_grid(res_sgd.x)
    rel_err = float(jnp.linalg.norm(m_sgd - m_cg) /
                    jnp.maximum(jnp.linalg.norm(m_cg), 1e-30))
    return {
        "n": n,
        "cg_iters": int(res_cg.iters),
        "cg_rel_residual": float(jnp.max(res_cg.rel_residual)),
        "sgd_iters": int(res_sgd.iters),
        "sgd_rel_residual": float(jnp.max(res_sgd.rel_residual)),
        "posterior_mean_rel_err": rel_err,
    }


SOLVER_NAMES = ("cg", "pcg", "sgd")


def solver_scaling(sizes=(4096, 8192, 16384, 32768), budget: int = 50,
                   tol: float = 1e-5, quick: bool = False,
                   out_path: str | None = "BENCH_scaling.json") -> dict:
    """Race the registered solvers at each n; emit the crossover payload."""
    print(f"# bench_scaling (solver crossover): n in {list(sizes)}, "
          f"budget {budget} sweeps, f32, iterative backend")
    print("n,solver,wall_s,iters,rel_residual,matvecs,breakdown")
    engine = get_engine("iterative")
    results = []
    for n in sizes:
        K1, K2, mask, Y = _solver_problem(n)
        K1j = jnp.asarray(K1)
        del K1                       # keep ONE resident 4 GiB copy at 32k
        K2j, mj = jnp.asarray(K2), jnp.asarray(mask)
        A = engine.operator_from_grams(K1j, K2j, mj, _NOISE)
        b = mj * jnp.asarray(Y)
        for name in SOLVER_NAMES:
            row = {"n": n, **_run_solver_cell(A, b, name, tol, budget)}
            results.append(row)
            print(f"{n},{name},{row['wall_s']},{row['iters']},"
                  f"{row['rel_residual']:.2e},{row['matvecs']},"
                  f"{row['breakdown']}")
        del A, b, K1j
        gc.collect()

    parity = _parity_check(sizes[0],
                           max_iters=600 if quick else 3000)
    print(f"# parity n={parity['n']}: mean rel-err "
          f"{parity['posterior_mean_rel_err']:.2e} "
          f"(cg res {parity['cg_rel_residual']:.1e}, "
          f"sgd res {parity['sgd_rel_residual']:.1e})")

    # Crossover summary: per n the fastest solver among those that hit tol
    # (falling back to best-residual when the budget bound them all), and
    # the smallest n where SGD's wall time beats CG's.
    per_n_fastest = {}
    for n in sizes:
        rows = [r for r in results if r["n"] == n and r["completed"]]
        hit = [r for r in rows if r["rel_residual"] <= tol]
        pick = (min(hit, key=lambda r: r["wall_s"]) if hit
                else min(rows, key=lambda r: r["rel_residual"]))
        per_n_fastest[str(n)] = pick["solver"]
    sgd_cross = None
    for n in sizes:
        by = {r["solver"]: r for r in results if r["n"] == n}
        if ("sgd" in by and "cg" in by and by["sgd"]["completed"]
                and by["sgd"]["wall_s"] < by["cg"]["wall_s"]):
            sgd_cross = n
            break
    print(f"# crossover: per-n fastest {per_n_fastest}, "
          f"sgd-beats-cg at n={sgd_cross}")

    max_n = max(sizes)
    sgd_max = next((r for r in results
                    if r["n"] == max_n and r["solver"] == "sgd"), None)
    acceptance = {
        "sgd_completes_max_n": bool(sgd_max and sgd_max["completed"]
                                    and not sgd_max["breakdown"]),
        "f32_posterior_mean_parity":
            parity["posterior_mean_rel_err"] <= 1e-4,
        "crossover_table_present": all(
            any(r["n"] == n and r["solver"] == s for r in results)
            for n in sizes for s in SOLVER_NAMES),
    }
    payload = {
        "meta": {
            "dataset": "synthetic",
            "mode": "solver_scaling",
            "dtype": "float32",
            "m": _SOLVER_M, "d": _SOLVER_D,
            "noise": _NOISE, "lengthscale": _LS,
            "budget_iters": budget, "tol": tol, "quick": quick,
            "notes": "wall_s includes jit trace+compile (one compile per "
                     "(n, solver) shape); sgd additionally spends 8 power-"
                     "iteration sweeps on the auto learning rate",
        },
        "results": results,
        "crossover": {"per_n_fastest": per_n_fastest,
                      "sgd_beats_cg_at_n": sgd_cross},
        "parity": parity,
        "acceptance": acceptance,
    }
    for claim, ok in acceptance.items():
        print(f"# acceptance {claim}: {ok}")
    if out_path:
        with open(out_path, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"# wrote {out_path}")
    return payload


# ==========================================================================
# Fig. 3 reproduction (legacy mode; benchmarks.run imports `main`)
# ==========================================================================
def _task(n, m, d=10, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.uniform(0, 1, (n, d))
    Y = rng.normal(0, 1, (n, m))
    t = np.linspace(0.01, 1.0, m)  # unit interval, linear spacing (App. C)
    mask = np.ones((n, m))
    return X, t, Y, mask


def run_one(backend: str, n: int, m: int, n_test: int = 64,
            lbfgs_iters: int = 5):
    X, t, Y, mask = _task(n, m)
    cfg = LKGPConfig(backend=backend, lbfgs_iters=lbfgs_iters,
                     posterior_samples=8, cg_tol=0.01, slq_probes=8,
                     slq_iters=15, seed=0)
    with PeakRSS() as mem_fit:
        t0 = time.time()
        state = fit(X, t + 1.0, Y, mask, cfg)
        fit_s = time.time() - t0
    Xs = np.random.default_rng(1).uniform(0, 1, (n_test, X.shape[1]))
    with PeakRSS() as mem_pred:
        t0 = time.time()
        s = posterior(state, Xs=Xs).samples(jax.random.PRNGKey(0), 8)
        jax.block_until_ready(s)
        pred_s = time.time() - t0
    return fit_s, pred_s, mem_fit.delta_mb, mem_pred.delta_mb


def main(sizes=(16, 32, 64), cholesky_max: int = 32, out=print):
    out("# bench_scaling (Fig 3): train/predict time and memory vs n=m")
    out("backend,n=m,fit_s,predict_s,fit_peak_mb,predict_peak_mb")
    rows = []
    for n in sizes:
        for backend in ("iterative", "dense"):
            if backend == "dense" and n > cholesky_max:
                out(f"dense,{n},SKIPPED (O(n^3 m^3) infeasible),,,")
                continue
            f, p, mf, mp = run_one(backend, n, n)
            rows.append((backend, n, f, p, mf, mp))
            out(f"{backend},{n},{f:.2f},{p:.2f},{mf:.0f},{mp:.0f}")
    # derived claim: iterative scales better than dense Cholesky
    it = {r[1]: r[2] for r in rows if r[0] == "iterative"}
    ch = {r[1]: r[2] for r in rows if r[0] == "dense"}
    shared = sorted(set(it) & set(ch))
    if len(shared) >= 2:
        lo, hi = shared[0], shared[-1]
        growth_it = it[hi] / max(it[lo], 1e-9)
        growth_ch = ch[hi] / max(ch[lo], 1e-9)
        out(f"# growth {lo}->{hi}: iterative x{growth_it:.1f}, "
            f"dense x{growth_ch:.1f} (paper: LKGP scales far better)")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small sizes / short budget (CI smoke)")
    ap.add_argument("--out", default="BENCH_scaling.json",
                    help="solver-crossover payload path")
    ap.add_argument("--fig3", action="store_true",
                    help="run the legacy Fig. 3 time/memory mode instead")
    args = ap.parse_args()
    if args.fig3:
        main(sizes=(16, 32) if args.quick else (16, 32, 64))
    else:
        if args.quick:
            solver_scaling(sizes=(256, 512), budget=15, quick=True,
                           out_path=args.out)
        else:
            solver_scaling(out_path=args.out)
