"""Fig. 3 reproduction: time & memory of LKGP (iterative) vs naive Cholesky.

Paper protocol (App. C): random data, n = m in {16, 32, ...}, d = 10, no
missing values; training = optimizing noise + kernel params; prediction =
sampling full curves for 512 (here: scaled-down) test configs. The paper ran
on a V100; this container is a single CPU core, so sizes are scaled to keep
the benchmark < ~2 min while still exhibiting the asymptotic separation
(naive O(n^3 m^3) vs LKGP O(n^2 m + n m^2) per solve).

Memory is the peak RSS delta sampled by a watcher thread (includes interpreter
overheads — same caveat as the paper's "measurements include constant
overheads such as memory reserved by CUDA drivers").
"""
from __future__ import annotations

import gc
import threading
import time

import jax
import numpy as np

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import psutil

from repro.core import LKGPConfig, fit, posterior


class PeakRSS:
    def __init__(self):
        self.proc = psutil.Process()
        self.peak = 0
        self._stop = False

    def __enter__(self):
        gc.collect()
        self.base = self.proc.memory_info().rss
        self.peak = self.base
        self._thread = threading.Thread(target=self._watch, daemon=True)
        self._thread.start()
        return self

    def _watch(self):
        while not self._stop:
            self.peak = max(self.peak, self.proc.memory_info().rss)
            time.sleep(0.005)

    def __exit__(self, *a):
        self._stop = True
        self._thread.join()

    @property
    def delta_mb(self):
        return (self.peak - self.base) / 2**20


def _task(n, m, d=10, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.uniform(0, 1, (n, d))
    Y = rng.normal(0, 1, (n, m))
    t = np.linspace(0.01, 1.0, m)  # unit interval, linear spacing (App. C)
    mask = np.ones((n, m))
    return X, t, Y, mask


def run_one(backend: str, n: int, m: int, n_test: int = 64,
            lbfgs_iters: int = 5):
    X, t, Y, mask = _task(n, m)
    cfg = LKGPConfig(backend=backend, lbfgs_iters=lbfgs_iters,
                     posterior_samples=8, cg_tol=0.01, slq_probes=8,
                     slq_iters=15, seed=0)
    with PeakRSS() as mem_fit:
        t0 = time.time()
        state = fit(X, t + 1.0, Y, mask, cfg)
        fit_s = time.time() - t0
    Xs = np.random.default_rng(1).uniform(0, 1, (n_test, X.shape[1]))
    with PeakRSS() as mem_pred:
        t0 = time.time()
        s = posterior(state, Xs=Xs).samples(jax.random.PRNGKey(0), 8)
        jax.block_until_ready(s)
        pred_s = time.time() - t0
    return fit_s, pred_s, mem_fit.delta_mb, mem_pred.delta_mb


def main(sizes=(16, 32, 64), cholesky_max: int = 32, out=print):
    out("# bench_scaling (Fig 3): train/predict time and memory vs n=m")
    out("backend,n=m,fit_s,predict_s,fit_peak_mb,predict_peak_mb")
    rows = []
    for n in sizes:
        for backend in ("iterative", "dense"):
            if backend == "dense" and n > cholesky_max:
                out(f"dense,{n},SKIPPED (O(n^3 m^3) infeasible),,,")
                continue
            f, p, mf, mp = run_one(backend, n, n)
            rows.append((backend, n, f, p, mf, mp))
            out(f"{backend},{n},{f:.2f},{p:.2f},{mf:.0f},{mp:.0f}")
    # derived claim: iterative scales better than dense Cholesky
    it = {r[1]: r[2] for r in rows if r[0] == "iterative"}
    ch = {r[1]: r[2] for r in rows if r[0] == "dense"}
    shared = sorted(set(it) & set(ch))
    if len(shared) >= 2:
        lo, hi = shared[0], shared[-1]
        growth_it = it[hi] / max(it[lo], 1e-9)
        growth_ch = ch[hi] / max(ch[lo], 1e-9)
        out(f"# growth {lo}->{hi}: iterative x{growth_it:.1f}, "
            f"dense x{growth_ch:.1f} (paper: LKGP scales far better)")
    return rows


if __name__ == "__main__":
    main()
