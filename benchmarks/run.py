"""Benchmark harness: one module per paper table/figure + roofline summary.

    PYTHONPATH=src python -m benchmarks.run [--quick]

Prints ``name,us_per_call,derived`` CSV lines per benchmark (harness
contract) and the per-benchmark tables used in EXPERIMENTS.md.
"""
import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller sizes / fewer seeds")
    ap.add_argument("--only", default="",
                    help="comma list: scaling,prediction,mvm,automl,roofline")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    t_all = time.time()

    if only is None or "mvm" in only:
        from . import bench_mvm
        t0 = time.time()
        rows = bench_mvm.main(sizes=(32, 64, 128) if args.quick
                              else (32, 64, 128, 256))
        print(f"bench_mvm,{(time.time()-t0)*1e6:.0f},"
              f"structured_mvm_n256_us={rows[-1][1]:.0f}")

    if only is None or "scaling" in only:
        from . import bench_scaling
        t0 = time.time()
        rows = bench_scaling.main(sizes=(16, 32) if args.quick
                                  else (16, 32, 64))
        it_time = [r[2] for r in rows if r[0] == "iterative"][-1]
        print(f"bench_scaling,{(time.time()-t0)*1e6:.0f},"
              f"iterative_fit_s_at_max={it_time:.2f}")

    if only is None or "prediction" in only:
        from . import bench_prediction
        t0 = time.time()
        res = bench_prediction.main(
            n_seeds=2 if args.quick else 5,
            budgets=(60, 120) if args.quick else (60, 120, 240))
        budget = 120
        print(f"bench_prediction,{(time.time()-t0)*1e6:.0f},"
              f"lkgp_mse_b{budget}={res[('LKGP', budget)][0]:.5f}")

    if only is None or "automl" in only:
        from . import bench_automl
        t0 = time.time()
        payload = bench_automl.main(
            quick=args.quick,
            out_path="BENCH_automl.quick.json" if args.quick
            else "BENCH_automl.json")
        acc = payload["acceptance"]
        print(f"bench_automl,{(time.time()-t0)*1e6:.0f},"
              f"sh_lkgp_beats_rank={acc['sh_lkgp_beats_rank']},"
              f"precond_reduces_cg_iters={acc['precond_reduces_cg_iters']}")

    if (only is None and not args.quick) or (only and "ablation" in only):
        from .bench_prediction import ablate_t_kernel
        t0 = time.time()
        res = ablate_t_kernel()
        best = min(res, key=lambda k: res[k][0])
        print(f"bench_ablation,{(time.time()-t0)*1e6:.0f},"
              f"best_t_kernel={best}")

    if only is None or "roofline" in only:
        # summarise dry-run artifacts if present (no compile here)
        import glob
        import json
        import os
        d = "artifacts/dryrun_opt" if os.path.isdir("artifacts/dryrun_opt") \
            else "artifacts/dryrun"
        arts = sorted(glob.glob(f"{d}/*.json"))
        if arts:
            from repro.launch.roofline import summarize_artifacts
            t0 = time.time()
            table = summarize_artifacts(arts)
            worst = min(table, key=lambda r: r["roofline_fraction"])
            best = max(table, key=lambda r: r["roofline_fraction"])
            print(f"bench_roofline,{(time.time()-t0)*1e6:.0f},"
                  f"cells={len(table)},best_fraction="
                  f"{best['roofline_fraction']:.3f}"
                  f"({best['arch']}/{best['shape']}),worst_fraction="
                  f"{worst['roofline_fraction']:.3f}")
        else:
            print("bench_roofline,0,no_artifacts (run repro.launch.dryrun)")

    print(f"# total benchmark wall time: {time.time()-t_all:.1f}s",
          file=sys.stderr)


if __name__ == "__main__":
    main()
