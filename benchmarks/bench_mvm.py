"""MVM microbenchmark (§2): engine operators vs dense joint MVM.

Times the latent-Kronecker operator of each registered iterative-family
engine (built via ``engine.operator_from_grams``, the same construction the
solvers use) against the dense joint matvec: the structured MVM is
O(n^2 m + n m^2) with O(nm) memory; the dense one is O(n^2 m^2) with
O(n^2 m^2) memory. The Pallas engine runs in interpret mode off-TPU, purely
as a correctness path (interpret timings are not meaningful for TPU perf —
see EXPERIMENTS.md §Roofline for the kernel's compiled analysis).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import get_engine, gram_matrices, init_params, kron_dense


def _time(fn, *args, reps=5):
    fn(*args)  # warmup/compile
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6  # us


def main(sizes=(32, 64, 128, 256), pallas_max_n: int = 64, out=print):
    out("# bench_mvm: engine operator MVM vs dense joint (f32, CPU wall time)")
    out("n=m,iterative_us,pallas_us,dense_us,speedup_vs_dense")
    rows = []
    for n in sizes:
        m = n
        key = jax.random.PRNGKey(0)
        X = jax.random.uniform(key, (n, 10), jnp.float32)
        t = jnp.linspace(0, 1, m)
        params = init_params(10, jnp.float32)
        K1, K2 = gram_matrices(params, X, t)
        mask = jnp.ones((n, m), jnp.float32)
        v = jax.random.normal(key, (n, m), jnp.float32)
        noise = jnp.float32(0.1)

        def op_time(backend):
            A = get_engine(backend).operator_from_grams(K1, K2, mask, noise)
            return _time(jax.jit(A), v)

        us_iter = op_time("iterative")
        # interpret-mode Pallas is slow on CPU; cap its sweep off-TPU
        run_pallas = jax.default_backend() == "tpu" or n <= pallas_max_n
        us_pal = op_time("pallas") if run_pallas else None
        pal_s = f"{us_pal:.0f}" if us_pal is not None else "skipped"

        if n <= 128:
            Kd = kron_dense(K1, K2)
            f_dense = jax.jit(
                lambda Kd, u: (Kd @ u.reshape(-1)).reshape(u.shape)
                + 0.1 * u)
            us_dense = _time(f_dense, Kd, v)
            out(f"{n},{us_iter:.0f},{pal_s},{us_dense:.0f},"
                f"{us_dense/us_iter:.1f}x")
        else:
            out(f"{n},{us_iter:.0f},{pal_s},OOM-skipped,")
        rows.append((n, us_iter))
    return rows


if __name__ == "__main__":
    main()
