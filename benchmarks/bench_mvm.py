"""MVM kernel + solver-consolidation benchmark -> BENCH_mvm.json.

Two claims from the fused-MVM PR are measured and gated in CI
(``check_regression.py --mvm``):

1. **Kernel**: the single-pass fused Pallas kernel
   (:func:`repro.kernels.lk_mvm_fused`) vs the committed two-stage kernel
   (:func:`repro.kernels.lk_mvm_two_stage`) at stacked-solve shapes — the
   leading B is the RHS stack size of the consolidated block solve
   ``K^{-1}[y | probes | Matheron residuals]``. Reported per shape:
   wall-clock, XLA ``cost_analysis`` bytes-accessed / flops, and exact
   parity against the jnp oracle (atol 1e-5, f32). Acceptance: bytes
   accessed drops >= 1.5x and parity holds at every shape. The bf16
   (inputs)/f32 (accumulate) mode is reported as information.
2. **Solve consolidation**: total operator applications for one
   MLL/posterior-shaped evaluation — mean solve + SLQ log-det probes +
   Matheron residual solves — separately (three block solves plus a
   dedicated Lanczos sweep) vs consolidated (ONE stacked block solve whose
   probe columns also yield the log-det via their CG-Lanczos
   tridiagonals). Both operator *sweeps* (batched A applications: what you
   launch) and *column MVMs* (active columns x sweeps: what you compute,
   with converged columns frozen) are recorded. Acceptance: the stacked
   path performs strictly fewer sweeps.

Off-TPU everything runs the Pallas interpreter (correct, slow): wall
times are informational there; bytes-accessed and operator counts are the
gated quantities. ``--quick`` restricts to the two smallest shapes for CI.
"""
from __future__ import annotations

import argparse
import functools
import json
import time

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.core import (cg_solve, cg_solve_tridiag, gram_matrices,
                        init_params, lk_operator, mll_cholesky,
                        prior_residual_draws, rademacher_probes, slq_logdet,
                        slq_logdet_from_tridiag, tridiag_from_cg)
from repro.kernels import (autotune_blocks, lk_mvm_fused, lk_mvm_ref,
                           lk_mvm_two_stage)

KERNEL_SIZES = [          # (B, n, m): B = stacked-RHS count
    (4, 128, 64),
    (8, 128, 128),
    (4, 256, 64),
    (2, 256, 128),
]
QUICK_KERNEL_SIZES = KERNEL_SIZES[:2]
PARITY_ATOL = 1e-5


def _mvm_problem(B, n, m, seed=0):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    A = jax.random.normal(k1, (n, n), jnp.float32)
    K1 = A @ A.T / n + 0.5 * jnp.eye(n, dtype=jnp.float32)
    C = jax.random.normal(k2, (m, m), jnp.float32)
    K2 = C @ C.T / m + 0.5 * jnp.eye(m, dtype=jnp.float32)
    lens = jax.random.randint(k3, (n,), m // 2, m + 1)
    mask = (jnp.arange(m)[None, :] < lens[:, None]).astype(jnp.float32)
    u = jax.random.normal(k4, (B, n, m), jnp.float32) * mask
    return K1, K2, mask, u


def _cost(fn, *args):
    """(bytes_accessed, flops) from the compiled computation."""
    comp = jax.jit(fn).lower(*args).compile()
    ca = comp.cost_analysis()
    if isinstance(ca, list):    # older jax returns a per-computation list
        ca = ca[0] if ca else {}
    return float(ca.get("bytes accessed", float("nan"))), \
        float(ca.get("flops", float("nan")))


def _wall_us(fn, *args, reps=3):
    jax.block_until_ready(fn(*args))   # warmup/compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def bench_kernel(sizes, out=print):
    on_tpu = jax.default_backend() == "tpu"
    rows = []
    out("# kernel: fused single-pass vs committed two-stage (noise term incl.)")
    out("B,n,m,blocks,fused_us,two_stage_us,fused_MB,two_stage_MB,"
        "bytes_ratio,err_f32,err_bf16")
    for B, n, m in sizes:
        K1, K2, mask, u = _mvm_problem(B, n, m)
        noise = 0.37
        bn, bm = autotune_blocks(n, m, B, timed=True if on_tpu else None)
        fused = functools.partial(lk_mvm_fused, block_n=bn, block_m=bm)
        fused_bf16 = functools.partial(lk_mvm_fused, block_n=bn, block_m=bm,
                                       precision="bf16")
        two = lk_mvm_two_stage     # committed defaults (block 128)

        ref = np.asarray(lk_mvm_ref(K1, K2, mask, u, noise))
        err = float(np.max(np.abs(np.asarray(
            fused(K1, K2, mask, u, noise)) - ref)))
        err_bf16 = float(np.max(np.abs(np.asarray(
            fused_bf16(K1, K2, mask, u, noise)) - ref)))

        fb, ff = _cost(fused, K1, K2, mask, u, noise)
        tb, tf = _cost(two, K1, K2, mask, u, noise)
        bb16, _ = _cost(fused_bf16, K1, K2, mask, u, noise)
        fus = _wall_us(fused, K1, K2, mask, u, noise)
        tus = _wall_us(two, K1, K2, mask, u, noise)
        bus16 = _wall_us(fused_bf16, K1, K2, mask, u, noise)

        ratio = tb / fb if fb > 0 else float("nan")
        out(f"{B},{n},{m},({bn},{bm}),{fus:.0f},{tus:.0f},"
            f"{fb/1e6:.2f},{tb/1e6:.2f},{ratio:.2f}x,{err:.1e},{err_bf16:.1e}")
        rows.append(dict(
            B=B, n=n, m=m, block_n=bn, block_m=bm,
            fused_us=fus, two_stage_us=tus, bf16_us=bus16,
            fused_bytes=fb, two_stage_bytes=tb, bf16_bytes=bb16,
            fused_flops=ff, two_stage_flops=tf,
            bytes_ratio=ratio, max_abs_err_f32=err,
            max_abs_err_bf16=err_bf16))
    return rows


def bench_solve_consolidation(n=32, m=24, d=4, n_probes=8, n_samples=8,
                              tol=0.01, slq_iters=20, out=print):
    """Operator applications per MLL/posterior evaluation, separate vs stacked.

    A *sweep* is one batched application of the latent-Kronecker operator
    to however many columns ride in it (one kernel launch); per CG solve
    that is ``iters + 2`` (initial residual + final true-residual check).
    The dedicated reorthogonalised Lanczos of the separate path adds one
    sweep per SLQ iteration. *Column MVMs* count columns actually worked
    on (frozen columns excluded).
    """
    key = jax.random.PRNGKey(1)
    kx, ky, kp, ks = jax.random.split(key, 4)
    X = jax.random.uniform(kx, (n, d), jnp.float64)
    t = jnp.linspace(0.05, 1.0, m).astype(jnp.float64)
    params = init_params(d, jnp.float64)
    K1, K2 = gram_matrices(params, X, t)
    noise = jnp.float64(0.05)
    lens = jax.random.randint(kp, (n,), m // 3, m + 1)
    mask = (jnp.arange(m)[None, :] < lens[:, None]).astype(jnp.float64)
    Y = jax.random.normal(ky, (n, m), jnp.float64) * mask
    A = lk_operator(K1, K2, mask, noise)
    N_obs = jnp.sum(mask)

    probes = rademacher_probes(jax.random.PRNGKey(2), n_probes, mask,
                               jnp.float64)
    F, eps = prior_residual_draws(jax.random.PRNGKey(3), K1, K2, n, noise,
                                  n_samples, jitter=1e-6)
    resid = mask * (F[:, :n, :] + eps)

    # --- separate path: three block solves + a dedicated Lanczos sweep ---
    r_mean = cg_solve(A, Y, tol=tol)
    r_probe = cg_solve(A, probes, tol=tol)
    r_samp = cg_solve(A, resid, tol=tol)
    logdet_lanczos = float(slq_logdet(A, probes, slq_iters, N_obs))
    sep_sweeps = int(r_mean.iters) + 2 + int(r_probe.iters) + 2 \
        + int(r_samp.iters) + 2 + slq_iters
    sep_colmv = int(r_mean.matvecs) + int(r_probe.matvecs) \
        + int(r_samp.matvecs) + slq_iters * n_probes

    # --- consolidated path: ONE stacked solve, log-det from its probes ---
    rhs = jnp.concatenate([Y[None], probes, resid], axis=0)
    res, tri = cg_solve_tridiag(A, rhs, max_rank=slq_iters, tol=tol)
    pr = slice(1, 1 + n_probes)
    diag, off = tridiag_from_cg(tri.alphas[pr], tri.betas[pr], tri.steps[pr])
    logdet_cg = float(slq_logdet_from_tridiag(diag, off, N_obs))
    stk_sweeps = int(res.iters) + 2
    stk_colmv = int(res.matvecs)

    sep_x = jnp.concatenate([r_mean.x[None], r_probe.x, r_samp.x], axis=0)
    sol_diff = float(jnp.max(jnp.abs(res.x - sep_x)))
    logdet_exact = None
    if n * m <= 4096:   # exact logdet via the dense construction
        from repro.core import kron_dense
        mv = mask.reshape(-1)
        Kd = kron_dense(K1, K2) * (mv[:, None] * mv[None, :])
        Kd = Kd + jnp.diag(noise * mv + (1.0 - mv))
        sign, logdet_exact = np.linalg.slogdet(np.asarray(Kd))
        logdet_exact = float(logdet_exact)

    out(f"# solve consolidation (n={n} m={m} rhs=1+{n_probes}+{n_samples}, "
        f"tol={tol})")
    out(f"separate: {sep_sweeps} sweeps / {sep_colmv} column-MVMs "
        f"(mean {int(r_mean.iters)}, probes {int(r_probe.iters)}, "
        f"samples {int(r_samp.iters)} iters + {slq_iters} Lanczos)")
    out(f"stacked : {stk_sweeps} sweeps / {stk_colmv} column-MVMs "
        f"(max-column {int(res.iters)} iters, log-det fused)")
    out(f"logdet  : exact {logdet_exact} lanczos {logdet_lanczos:.4f} "
        f"cg-fused {logdet_cg:.4f}; stacked-vs-separate x diff {sol_diff:.2e}")
    return dict(
        n=n, m=m, d=d, n_probes=n_probes, n_samples=n_samples, tol=tol,
        slq_iters=slq_iters,
        separate=dict(sweeps=sep_sweeps, column_matvecs=sep_colmv,
                      mean_iters=int(r_mean.iters),
                      probe_iters=int(r_probe.iters),
                      sample_iters=int(r_samp.iters),
                      lanczos_sweeps=slq_iters),
        stacked=dict(sweeps=stk_sweeps, column_matvecs=stk_colmv,
                     iters=int(res.iters)),
        logdet=dict(exact=logdet_exact, lanczos=logdet_lanczos,
                    cg_fused=logdet_cg),
        solution_max_diff=sol_diff)


def main(quick=False, out_path="BENCH_mvm.json", out=print):
    sizes = QUICK_KERNEL_SIZES if quick else KERNEL_SIZES
    kernel_rows = bench_kernel(sizes, out=out)
    solve = bench_solve_consolidation(out=out)

    min_ratio = min(r["bytes_ratio"] for r in kernel_rows)
    acceptance = {
        "fused_parity_atol_1e-5_f32": bool(
            all(r["max_abs_err_f32"] <= PARITY_ATOL for r in kernel_rows)),
        "fused_bytes_reduction_ge_1.5x": bool(min_ratio >= 1.5),
        "stacked_fewer_operator_sweeps": bool(
            solve["stacked"]["sweeps"] < solve["separate"]["sweeps"]),
        "stacked_fewer_column_matvecs": bool(
            solve["stacked"]["column_matvecs"]
            < solve["separate"]["column_matvecs"]),
    }
    payload = dict(
        meta=dict(backend=jax.default_backend(), quick=bool(quick),
                  parity_atol=PARITY_ATOL),
        kernel=kernel_rows, solve=solve, acceptance=acceptance)
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    out(f"# wrote {out_path}; acceptance: {acceptance}")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="two smallest kernel shapes only (CI smoke)")
    ap.add_argument("--out", default="BENCH_mvm.json")
    args = ap.parse_args()
    main(quick=args.quick, out_path=args.out)
