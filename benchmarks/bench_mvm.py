"""MVM microbenchmark (§2): latent-Kronecker MVM vs dense joint MVM.

Demonstrates the core complexity claim on CPU wall-time: the structured MVM
is O(n^2 m + n m^2) with O(nm) memory; the dense joint matvec is O(n^2 m^2)
with O(n^2 m^2) memory. Also times the Pallas kernel in interpret mode purely
as a correctness path (interpret timings are not meaningful for TPU perf —
see EXPERIMENTS.md §Roofline for the kernel's compiled analysis).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gram_matrices, init_params, kron_dense, lk_mvm


def _time(fn, *args, reps=5):
    fn(*args)  # warmup/compile
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6  # us


def main(sizes=(32, 64, 128, 256), out=print):
    out("# bench_mvm: structured vs dense joint MVM (f32, CPU wall time)")
    out("n=m,structured_us,dense_us,speedup")
    rows = []
    for n in sizes:
        m = n
        key = jax.random.PRNGKey(0)
        X = jax.random.uniform(key, (n, 10), jnp.float32)
        t = jnp.linspace(0, 1, m)
        params = init_params(10, jnp.float32)
        K1, K2 = gram_matrices(params, X, t)
        mask = jnp.ones((n, m), jnp.float32)
        v = jax.random.normal(key, (n, m), jnp.float32)

        f_struct = jax.jit(lambda a, b, mk, u: lk_mvm(a, b, mk, u, 0.1))
        us_struct = _time(f_struct, K1, K2, mask, v)

        if n <= 128:
            Kd = kron_dense(K1, K2)
            f_dense = jax.jit(
                lambda Kd, u: (Kd @ u.reshape(-1)).reshape(u.shape)
                + 0.1 * u)
            us_dense = _time(f_dense, Kd, v)
            out(f"{n},{us_struct:.0f},{us_dense:.0f},"
                f"{us_dense/us_struct:.1f}x")
        else:
            out(f"{n},{us_struct:.0f},OOM-skipped,")
        rows.append((n, us_struct))
    return rows


if __name__ == "__main__":
    main()
