"""Reliability benchmark: availability under faults, escalation latency,
crash recovery.

Runs the fault-injection harness (:mod:`repro.testing.faults`) against the
serving stack and writes ``BENCH_reliability.json`` with the acceptance
booleans the CI gate (``check_regression.py --reliability``) enforces:

* **availability** — a chaos workload (one tenant streaming NaN-poisoned
  payloads every round, a mid-schedule crash + restore from checkpoint)
  must not cost healthy tenants a single request: their availability is
  1.0 and their final predictions are bitwise identical to a fault-free
  control service that saw the same healthy traffic;
* **escalation latency** — a guarded solve through a forced solver
  breakdown (armed flaky solver -> instant fake failure -> first ladder
  rung recovers via CG) must keep p99 latency within 5x of a clean
  guarded CG solve on the same system. The fake failure costs no operator
  sweeps, so the ratio measures guard/dispatch overhead plus one retry —
  the regime the escalate policy is designed for;
* **recovery** — restoring a crashed service from its checkpoint must
  bring back every session warm: same generation, predictions bitwise
  equal to the moment before the crash, no refits. Recovery wall time is
  reported as information.

    PYTHONPATH=src python benchmarks/bench_reliability.py [--quick] [--out F]
"""
from __future__ import annotations

import argparse
import json
import platform
import tempfile
import time

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402

from repro.core import LKGPConfig, get_engine, gram_matrices  # noqa: E402
from repro.core import init_params  # noqa: E402
from repro.core.solvers.guarded import guarded_solve  # noqa: E402
from repro.data import sample_task  # noqa: E402
from repro.serving import PredictionService, ServiceConfig  # noqa: E402
from repro.serving.metrics import percentile  # noqa: E402
from repro.testing import arm_flaky_solver, crash_and_restore  # noqa: E402
from repro.testing import poison_nan  # noqa: E402


def _summ(samples_s: list[float]) -> dict:
    xs = sorted(samples_s)
    return {"count": len(xs),
            "p50_ms": round(percentile(xs, 0.50) * 1e3, 4),
            "p99_ms": round(percentile(xs, 0.99) * 1e3, 4),
            "mean_ms": round(sum(xs) / len(xs) * 1e3, 4)}


def _grow(Y: np.ndarray, mask: np.ndarray, value: float) -> tuple:
    """One more observed epoch per row — a healthy extend payload."""
    Y, mask = np.array(Y), np.array(mask)
    for row in range(mask.shape[0]):
        k = int(mask[row].sum())
        if k < mask.shape[1]:
            mask[row, k] = 1.0
            Y[row, k] = value
    return Y, mask


def bench_availability(tenants: int, rounds: int, n: int, m: int,
                       lbfgs: int, workdir: str, out=print) -> dict:
    """Chaos workload vs fault-free control; healthy tenants must not notice.

    tenant-0 streams a NaN-poisoned payload every round (quarantined on the
    chaos service, withheld on the control service so both see identical
    *healthy* traffic); halfway through, the chaos service crashes right
    after a checkpoint and is restored. Availability counts every healthy-
    tenant observe AND predict that completes un-quarantined.
    """
    gp = LKGPConfig(lbfgs_iters=lbfgs, backend="dense")
    make_cfg = lambda d: ServiceConfig(   # noqa: E731
        gp=gp, refit_every=0, checkpoint_dir=d, checkpoint_every=0)
    control = PredictionService(make_cfg(f"{workdir}/control"))
    chaos = PredictionService(make_cfg(f"{workdir}/chaos"))

    tasks = [sample_task(seed=i, n=n, m=m, d=4) for i in range(tenants)]
    for svc in (control, chaos):
        for i, tk in enumerate(tasks):
            svc.observe(f"tenant-{i}", "run", Y=tk.Y, mask=tk.mask,
                        X=tk.X, t=tk.t)

    healthy = list(range(1, tenants))
    grids = {i: (np.asarray(tasks[i].Y), np.asarray(tasks[i].mask))
             for i in healthy}
    served = attempted = quarantines = 0
    crash_round = rounds // 2
    for rnd in range(rounds):
        bad = poison_nan(tasks[0].Y, tasks[0].mask)
        res = chaos.observe("tenant-0", "run", *bad)
        quarantines += int(res["action"] == "quarantined")
        for i in healthy:
            grids[i] = _grow(*grids[i], value=0.1 * (rnd + 1))
            for svc in (control, chaos):
                r = svc.observe(f"tenant-{i}", "run",
                                Y=grids[i][0], mask=grids[i][1])
                if svc is chaos:
                    attempted += 1
                    served += int(r["action"] != "quarantined")
            p = chaos.predict(f"tenant-{i}", "run")
            attempted += 1
            served += int(p.mean is not None)
        if rnd == crash_round:
            chaos.checkpoint()
            chaos, restored = crash_and_restore(chaos)
            assert restored == tenants

    bitwise = True
    for i in healthy:
        want = control.predict(f"tenant-{i}", "run")
        got = chaos.predict(f"tenant-{i}", "run")
        bitwise = bitwise and bool(
            np.array_equal(want.mean, got.mean)
            and np.array_equal(want.var, got.var))
    availability = served / max(attempted, 1)
    row = {"tenants": tenants, "rounds": rounds, "n": n, "m": m,
           "healthy_requests": attempted, "healthy_served": served,
           "availability": availability,
           "quarantines": quarantines,
           "expected_quarantines": rounds,
           "healthy_bitwise_equal_to_control": bitwise}
    out(f"availability tenants={tenants} rounds={rounds}: "
        f"{served}/{attempted} healthy requests served "
        f"({availability:.3f}), {quarantines} quarantines, "
        f"bitwise={bitwise}")
    return row


def bench_escalation_latency(n: int, m: int, solves: int, out=print) -> dict:
    """Clean guarded CG solves vs flaky-armed escalated solves, p99 ratio."""
    key = jax.random.PRNGKey(0)
    kx, ky = jax.random.split(key)
    X = jax.random.uniform(kx, (n, 3), jax.numpy.float64)
    t = jax.numpy.linspace(0.05, 1.0, m).astype(jax.numpy.float64)
    K1, K2 = gram_matrices(init_params(3, jax.numpy.float64), X, t)
    mask = jax.numpy.ones((n, m), jax.numpy.float64)
    Y = jax.random.normal(ky, (n, m), jax.numpy.float64)
    noise = jax.numpy.float64(0.05)
    A = get_engine("iterative").operator_from_grams(K1, K2, mask, noise)

    clean_cfg = LKGPConfig(solver="cg")
    flaky_cfg = LKGPConfig(solver="flaky")

    # Warmup: compile the CG solve once for both paths.
    jax.block_until_ready(guarded_solve(A, Y, clean_cfg).x)

    clean, escalated = [], []
    for _ in range(solves):
        t0 = time.perf_counter()
        jax.block_until_ready(guarded_solve(A, Y, clean_cfg).x)
        clean.append(time.perf_counter() - t0)
    for _ in range(solves):
        arm_flaky_solver(1)
        t0 = time.perf_counter()
        res = guarded_solve(A, Y, flaky_cfg)
        jax.block_until_ready(res.x)
        escalated.append(time.perf_counter() - t0)
        assert res.trace[-1].ok and len(res.trace) == 2

    clean_s, escalated_s = _summ(clean), _summ(escalated)
    ratio = escalated_s["p99_ms"] / max(clean_s["p99_ms"], 1e-9)
    row = {"n": n, "m": m, "solves": solves,
           "clean": clean_s, "escalated": escalated_s,
           "p99_ratio": round(ratio, 2)}
    out(f"escalation latency n={n} m={m} solves={solves}: clean p99 "
        f"{clean_s['p99_ms']:.2f}ms escalated p99 "
        f"{escalated_s['p99_ms']:.2f}ms -> {ratio:.2f}x")
    return row


def bench_recovery(tenants: int, n: int, m: int, lbfgs: int,
                   workdir: str, out=print) -> dict:
    """Checkpoint -> crash -> restore; every session must come back warm."""
    gp = LKGPConfig(lbfgs_iters=lbfgs, backend="dense")
    svc = PredictionService(ServiceConfig(
        gp=gp, refit_every=0, checkpoint_dir=f"{workdir}/recovery"))
    before = {}
    for i in range(tenants):
        tk = sample_task(seed=100 + i, n=n, m=m, d=4)
        svc.observe(f"tenant-{i}", "run", Y=tk.Y, mask=tk.mask,
                    X=tk.X, t=tk.t)
        before[i] = svc.predict(f"tenant-{i}", "run")
    svc.checkpoint()

    t0 = time.perf_counter()
    svc2, restored = crash_and_restore(svc)
    recovery_s = time.perf_counter() - t0
    warm = restored == tenants
    t0 = time.perf_counter()
    for i in range(tenants):
        got = svc2.predict(f"tenant-{i}", "run")
        warm = warm and bool(
            np.array_equal(before[i].mean, got.mean)
            and np.array_equal(before[i].var, got.var)
            and got.generation == before[i].generation)
    first_predict_s = time.perf_counter() - t0
    row = {"tenants": tenants, "n": n, "m": m,
           "sessions_restored": restored,
           "all_sessions_warm": warm,
           "refits_after_restore": svc2.counters["refits"].value,
           "restore_ms": round(recovery_s * 1e3, 2),
           "first_predictions_ms": round(first_predict_s * 1e3, 2)}
    out(f"recovery tenants={tenants}: restored {restored} sessions in "
        f"{row['restore_ms']}ms, warm={warm}, first predictions "
        f"{row['first_predictions_ms']}ms")
    return row


def main(argv=None, out=print):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke sizes (fewer tenants/rounds/solves)")
    ap.add_argument("--out", default="BENCH_reliability.json")
    args = ap.parse_args(argv)

    if args.quick:
        tenants, rounds, solves, n, m, lbfgs = 4, 3, 30, 8, 10, 5
    else:
        tenants, rounds, solves, n, m, lbfgs = 6, 6, 100, 16, 12, 10

    out("# bench_reliability: availability, escalation latency, recovery")
    with tempfile.TemporaryDirectory() as workdir:
        availability = bench_availability(tenants, rounds, n, m, lbfgs,
                                          workdir, out=out)
        latency = bench_escalation_latency(n, m, solves, out=out)
        recovery = bench_recovery(tenants, n, m, lbfgs, workdir, out=out)

    acceptance = {
        "healthy_tenant_availability_is_1":
            availability["availability"] == 1.0,
        "every_bad_payload_quarantined":
            availability["quarantines"]
            == availability["expected_quarantines"],
        "healthy_tenants_bitwise_unchanged_under_faults":
            bool(availability["healthy_bitwise_equal_to_control"]),
        "escalated_p99_within_5x_clean": latency["p99_ratio"] <= 5.0,
        "restore_recovers_all_sessions_warm":
            bool(recovery["all_sessions_warm"]),
    }
    payload = {
        "meta": {
            "jax_backend": jax.default_backend(),
            "jax_version": jax.__version__,
            "platform": platform.platform(),
            "quick": args.quick,
            "config": {"tenants": tenants, "rounds": rounds,
                       "solves": solves, "n": n, "m": m,
                       "lbfgs_iters": lbfgs},
        },
        "availability": availability,
        "latency": latency,
        "recovery": recovery,
        "acceptance": acceptance,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    out(f"# wrote {args.out}")
    for claim, value in acceptance.items():
        out(f"acceptance {claim}: {value}")
    return payload


if __name__ == "__main__":
    main()
