"""Backend benchmark: MLL evaluation and posterior-mean wall time per engine.

Times one jitted MLL value+grad evaluation and one posterior-mean solve for
each backend over a grid of (n, m) problem sizes, and writes
``BENCH_backends.json`` so later PRs have a perf trajectory to compare
against.

Notes on interpretation:
  * ``dense`` is O(n^3 m^3) — it drops out of the sweep past
    ``dense_max_nm`` observed cells.
  * ``pallas`` off-TPU runs the kernel in *interpret mode*, which is a
    correctness path, not a perf path; its CPU timings are reported for
    trajectory only and capped at ``pallas_max_n`` rows. On TPU the same
    backend compiles to the fused kernel.

    PYTHONPATH=src python benchmarks/bench_backends.py
"""
from __future__ import annotations

import json
import platform
import time

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.core import (LKGPConfig, get_engine, gram_matrices, init_params,
                        make_mll, rademacher_probes)
from repro.data import sample_task


def _time(fn, *args, reps=3):
    out = fn(*args)  # warmup / compile
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e3  # ms


def _bench_one(backend: str, n: int, m: int, cfg: LKGPConfig, seed: int = 0):
    task = sample_task(seed, n=n, m=m, d=7)
    X = jnp.asarray(task.X)
    t = jnp.asarray(task.t, X.dtype)
    Y = jnp.asarray(task.Y, X.dtype)
    mask = jnp.asarray(task.mask, X.dtype)
    d = X.shape[1]
    params = init_params(d, X.dtype)
    engine = get_engine(backend)
    mll = make_mll(cfg, engine)
    probes = (None if engine.exact else
              rademacher_probes(jax.random.PRNGKey(0), cfg.slq_probes, mask,
                                X.dtype))

    vg = jax.jit(jax.value_and_grad(
        lambda p: mll(p, X, t, Y, mask, probes)))
    mll_ms = _time(lambda: vg(params))

    K1, K2 = gram_matrices(params, X, t, cfg.t_kernel, cfg.jitter)
    noise = jnp.exp(params.raw_noise)

    @jax.jit
    def posterior_mean():
        A = engine.operator_from_grams(K1, K2, mask, noise)
        alpha = engine.solve(A, Y * mask, cfg)
        return jnp.einsum("aj,jm,mk->ak", K1, alpha, K2)

    mean_ms = _time(posterior_mean)
    return {"backend": backend, "n": n, "m": m,
            "n_obs": int(np.sum(task.mask)),
            "mll_eval_ms": round(mll_ms, 3),
            "posterior_mean_ms": round(mean_ms, 3)}


def main(sizes=((16, 12), (32, 20), (64, 32), (128, 50)),
         backends=("dense", "iterative", "pallas"),
         dense_max_nm: int = 64 * 32, pallas_max_n: int = 32,
         out_path: str = "BENCH_backends.json", out=print):
    cfg = LKGPConfig(cg_tol=1e-4, cg_max_iters=2000, slq_probes=8,
                     slq_iters=15)
    out("# bench_backends: MLL eval + posterior-mean wall time per engine")
    out("backend,n,m,mll_eval_ms,posterior_mean_ms")
    results = []
    for n, m in sizes:
        for backend in backends:
            if backend == "dense" and n * m > dense_max_nm:
                out(f"dense,{n},{m},skipped(n*m>{dense_max_nm}),")
                continue
            if backend == "pallas" and n > pallas_max_n \
                    and jax.default_backend() != "tpu":
                out(f"pallas,{n},{m},skipped(interpret-mode cap),")
                continue
            row = _bench_one(backend, n, m, cfg)
            results.append(row)
            out(f"{backend},{n},{m},{row['mll_eval_ms']},"
                f"{row['posterior_mean_ms']}")
    payload = {
        "meta": {
            "jax_backend": jax.default_backend(),
            "jax_version": jax.__version__,
            "platform": platform.platform(),
            "config": {"cg_tol": cfg.cg_tol, "slq_probes": cfg.slq_probes,
                       "slq_iters": cfg.slq_iters},
        },
        "results": results,
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    out(f"# wrote {out_path} ({len(results)} rows)")
    return results


if __name__ == "__main__":
    main()
