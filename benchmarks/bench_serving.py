"""Serving benchmark: warm-cache latency, coalescing throughput, solve cache.

Measures the three headline claims of the multi-tenant prediction service
and writes ``BENCH_serving.json`` with acceptance booleans the CI gate
(``check_regression.py --serving``) enforces:

* **warm vs cold latency** — p50 of a per-request prediction on an
  *unchanged* session (state-keyed posterior cache hit: the resident
  solve products are re-read) must be >= 3x lower than the same request
  stream with the cache bypassed (every request re-runs the vmapped
  posterior solve);
* **coalescing throughput** — at 8 concurrent tenants streaming
  observations, ``predict_many`` (one vmapped B=8 posterior call per
  round) must sustain >= 2x the request throughput of per-request
  ``predict`` loops (8 separate B=1 calls). Both paths run the same
  compiled function, so this is pure dispatch/stacking amortisation —
  results stay bitwise identical. The gated claim is measured in the
  regime the service targets (many tenants, small per-task pools) where
  per-request overhead dominates; a larger per-task size is reported as
  information to show the trend toward compute-bound parity;
* **solve cache** — deterministic: a second ``posterior(state)`` on an
  unchanged state returns the SAME object, leaves ``solve_count`` and the
  process-wide engine ``solve_tally`` untouched, and still exposes the
  identical resident ``solve_info`` diagnostics (iterative backend, so
  the CG block-solve diagnostics are non-None).

    PYTHONPATH=src python benchmarks/bench_serving.py [--quick] [--out F]
"""
from __future__ import annotations

import argparse
import json
import platform
import time

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.core import LKGPConfig, fit, posterior
from repro.core import engines as engines_mod
from repro.core.posterior import posterior_batch
from repro.data import sample_task
from repro.serving import PredictionService, ServiceConfig, SessionKey
from repro.serving.metrics import percentile

TENANTS = 8   # the acceptance claims are stated at 8 concurrent tenants


def _summ(samples_s: list[float]) -> dict:
    xs = sorted(samples_s)
    return {"count": len(xs),
            "p50_ms": round(percentile(xs, 0.50) * 1e3, 4),
            "p99_ms": round(percentile(xs, 0.99) * 1e3, 4),
            "mean_ms": round(sum(xs) / len(xs) * 1e3, 4)}


def _make_service(n: int, m: int, lbfgs_iters: int,
                  refit_every: int = 4) -> tuple[PredictionService, dict]:
    svc = PredictionService(ServiceConfig(
        gp=LKGPConfig(lbfgs_iters=lbfgs_iters, backend="dense"),
        capacity=TENANTS, refit_every=refit_every, refit_lbfgs_iters=3))
    tasks = {f"tenant-{i}": sample_task(seed=i, n=n, m=m, d=4)
             for i in range(TENANTS)}
    svc.observe_batch([
        dict(tenant=name, task="run", X=tk.X, t=tk.t, Y=tk.Y, mask=tk.mask)
        for name, tk in tasks.items()])
    return svc, tasks


def _reveal_one_epoch(mask: np.ndarray) -> np.ndarray:
    mask = mask.copy()
    for i in range(mask.shape[0]):
        k = int(mask[i].sum())
        if k < mask.shape[1]:
            mask[i, k] = 1.0
    return mask


def bench_latency(n: int, m: int, requests: int, lbfgs_iters: int,
                  out=print) -> dict:
    """p50/p99 of warm (cache-hit) vs cold (cache-bypassed) predictions."""
    svc, _ = _make_service(n, m, lbfgs_iters)
    names = [f"tenant-{i}" for i in range(TENANTS)]

    def predict_cold(name: str) -> None:
        session = svc.store.get(SessionKey(name, "run"))
        bp = posterior_batch(session.stacked(), cache=False)
        mean, var = bp.final()
        np.asarray(mean), np.asarray(var)

    # Warmup: compile the B=1 path and populate every session's caches.
    for name in names:
        predict_cold(name)
        svc.predict(name, "run")

    stream = [names[i % TENANTS] for i in range(requests)]
    cold, warm = [], []
    for name in stream:
        t0 = time.perf_counter()
        predict_cold(name)
        cold.append(time.perf_counter() - t0)
    for name in stream:
        t0 = time.perf_counter()
        svc.predict(name, "run")
        warm.append(time.perf_counter() - t0)

    cold_s, warm_s = _summ(cold), _summ(warm)
    speedup = cold_s["p50_ms"] / max(warm_s["p50_ms"], 1e-9)
    out(f"latency n={n} m={m} requests={requests}: cold p50 "
        f"{cold_s['p50_ms']:.3f}ms warm p50 {warm_s['p50_ms']:.3f}ms "
        f"-> {speedup:.1f}x")
    return {"tenants": TENANTS, "n": n, "m": m, "requests": requests,
            "cold": cold_s, "warm": warm_s,
            "warm_speedup_p50": round(speedup, 2)}


def bench_throughput(n: int, m: int, rounds: int, lbfgs_iters: int,
                     out=print) -> dict:
    """Requests/s of coalesced predict_many vs per-request predict loops.

    Each measured round first streams one more observed epoch into every
    tenant (``extend`` swaps the state, so the following predictions do
    real solve work — no mode ever rides the other's warm cache), then
    serves one prediction per tenant through the mode under test.
    """
    svc, tasks = _make_service(n, m, lbfgs_iters, refit_every=0)
    names = list(tasks)
    keys = [(name, "run") for name in names]
    masks = {name: np.asarray(tk.mask).copy() for name, tk in tasks.items()}

    def observe_round() -> None:
        for name, tk in tasks.items():
            masks[name] = _reveal_one_epoch(masks[name])
            Y = np.where(masks[name] > 0, np.asarray(tk.Y_full), 0.0)
            svc.observe(name, "run", Y, masks[name])

    # Warmup round: compile both the B=1 and B=TENANTS posterior paths.
    observe_round()
    for name in names:
        svc.predict(name, "run")
    svc.predict_many(keys)

    per_request = coalesced = 0.0
    for _ in range(rounds):
        observe_round()
        t0 = time.perf_counter()
        for name in names:
            svc.predict(name, "run")
        per_request += time.perf_counter() - t0

        observe_round()
        t0 = time.perf_counter()
        svc.predict_many(keys)
        coalesced += time.perf_counter() - t0

    total = rounds * TENANTS
    rps_single = total / max(per_request, 1e-9)
    rps_coalesced = total / max(coalesced, 1e-9)
    speedup = rps_coalesced / max(rps_single, 1e-9)
    out(f"throughput n={n} m={m} rounds={rounds}: per-request "
        f"{rps_single:.0f} req/s coalesced {rps_coalesced:.0f} req/s "
        f"-> {speedup:.1f}x")
    return {"tenants": TENANTS, "n": n, "m": m, "rounds": rounds,
            "per_request_rps": round(rps_single, 1),
            "coalesced_rps": round(rps_coalesced, 1),
            "coalesced_speedup": round(speedup, 2)}


def bench_solve_cache(n: int, m: int, lbfgs_iters: int, out=print) -> dict:
    """Deterministic check: a repeated posterior read re-runs no solves.

    Uses the iterative backend so ``solve_info`` carries the CG block
    solver's diagnostics — the acceptance criterion is that the second
    ``posterior(state)`` returns the same resident object: same
    ``solve_count``, same ``solve_info`` identity, and the process-wide
    engine solve tally does not move.
    """
    tk = sample_task(seed=0, n=n, m=m, d=4)
    cfg = LKGPConfig(lbfgs_iters=lbfgs_iters, backend="iterative",
                     cg_tol=1e-6, cg_max_iters=500)
    state = fit(tk.X, tk.t, tk.Y, tk.mask, cfg)

    p1 = posterior(state)
    mean1, var1 = p1.final()           # one stacked multi-RHS solve
    jax.block_until_ready(mean1)
    count1, info1 = p1.solve_count, p1.solve_info
    tally1 = engines_mod.solve_tally()

    p2 = posterior(state)
    mean2, var2 = p2.final()
    _ = p2.mean
    jax.block_until_ready(mean2)
    count2, info2 = p2.solve_count, p2.solve_info
    tally2 = engines_mod.solve_tally()

    row = {
        "backend": "iterative", "n": n, "m": m,
        "posterior_identity": p2 is p1,
        "solve_count_first": count1,
        "solve_count_second": count2,
        "tally_delta": tally2 - tally1,
        "solve_info_resident": info2 is info1 and info1 is not None,
        "results_identical": bool(np.array_equal(np.asarray(mean1),
                                                 np.asarray(mean2))
                                  and np.array_equal(np.asarray(var1),
                                                     np.asarray(var2))),
    }
    ok = (row["posterior_identity"] and count2 == count1
          and row["tally_delta"] == 0 and row["solve_info_resident"]
          and row["results_identical"])
    out(f"solve-cache n={n} m={m}: identity={row['posterior_identity']} "
        f"solves {count1}->{count2} tally_delta={row['tally_delta']} "
        f"info_resident={row['solve_info_resident']} -> "
        f"{'ok' if ok else 'FAIL'}")
    row["zero_extra_sweeps"] = ok
    return row


def main(argv=None, out=print):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke sizes (fewer requests/rounds)")
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args(argv)

    if args.quick:
        n, m, requests, rounds, lbfgs = 8, 10, 48, 3, 5
    else:
        n, m, requests, rounds, lbfgs = 16, 12, 200, 6, 12

    out("# bench_serving: warm latency, coalescing throughput, solve cache")
    latency = bench_latency(n, m, requests, lbfgs, out=out)
    # The gated throughput claim lives in the dispatch-bound regime the
    # coalescer targets (small per-task pools, 8 tenants); larger pools
    # are compute-bound and reported as information only.
    throughput = bench_throughput(8, 10, rounds, lbfgs, out=out)
    throughput_large = (None if args.quick
                        else bench_throughput(n, m, rounds, lbfgs, out=out))
    solve_cache = bench_solve_cache(n, m, lbfgs, out=out)

    acceptance = {
        "warm_p50_at_least_3x_faster_than_cold":
            latency["warm_speedup_p50"] >= 3.0,
        "coalesced_at_least_2x_throughput_at_8_tenants":
            throughput["coalesced_speedup"] >= 2.0,
        "solve_cache_zero_extra_sweeps":
            bool(solve_cache["zero_extra_sweeps"]),
    }
    payload = {
        "meta": {
            "jax_backend": jax.default_backend(),
            "jax_version": jax.__version__,
            "platform": platform.platform(),
            "quick": args.quick,
            "config": {"tenants": TENANTS, "n": n, "m": m,
                       "requests": requests, "rounds": rounds,
                       "lbfgs_iters": lbfgs},
        },
        "latency": latency,
        "throughput": throughput,
        "throughput_large": throughput_large,
        "solve_cache": solve_cache,
        "acceptance": acceptance,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    out(f"# wrote {args.out}")
    for claim, value in acceptance.items():
        out(f"acceptance {claim}: {value}")
    return payload


if __name__ == "__main__":
    main()
