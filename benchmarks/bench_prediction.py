"""Fig. 4 reproduction (offline proxy): predict final validation accuracy
from partially observed learning curves; score MSE and log-likelihood.

The LCBench tasks + published ifBO seeds are not available offline, so tasks
are drawn from the synthetic LCBench-like prior in repro.data.curves (same
parametric families as the DPL/ifBO priors). Baselines implemented per the
paper's comparison set:

  * LKGP           — the paper's model (ours).
  * LKGP (no HPs)  — FT-PFN(no HPs) analogue: no correlation across curves
                     (K1 = I via per-curve independent GPs on t).
  * DPL            — power-law ensemble: y = a - b * t^-c, 5 least-squares
                     fits from random inits per curve (Kadra et al. 2023).
  * last-value     — predict the last observed value (strong naive baseline).

Protocol follows Rakotoarison et al. (2024) §5.1 in structure: for each seed
a budget of observed points is spread over the curves; the target is each
curve's value at the final epoch; metrics averaged over curves and seeds.
"""
from __future__ import annotations

import math
import time

import jax
import numpy as np

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
from scipy.optimize import least_squares

from repro.core import LKGPConfig, fit, posterior
from repro.data import benchmark_cutoffs, sample_task


# --------------------------------------------------------------------------
# baselines
# --------------------------------------------------------------------------
def lkgp_predict(task, seed):
    state = fit(task.X, task.t, task.Y, task.mask,
                LKGPConfig(lbfgs_iters=40, seed=seed))
    mean, var = posterior(state).final(jax.random.PRNGKey(seed))
    return np.asarray(mean), np.asarray(var)


def nohp_predict(task, seed):
    """Independent Matern-1/2 GP per curve (no cross-config correlation)."""
    n, m = task.Y.shape
    means, vars_ = np.zeros(n), np.zeros(n)
    t = np.log(task.t)
    t = (t - t[0]) / max(t[-1] - t[0], 1e-9)
    y_obs_all = task.Y[task.mask > 0]
    mu = y_obs_all.max()
    sd = max(y_obs_all.std(), 1e-6)
    for i in range(n):
        idx = np.where(task.mask[i] > 0)[0]
        if len(idx) == 0:
            means[i], vars_[i] = mu, sd**2
            continue
        yi = (task.Y[i, idx] - mu) / sd
        ls, os_, noise = 0.3, 1.0, 1e-3
        K = os_ * np.exp(-np.abs(t[idx][:, None] - t[idx][None, :]) / ls)
        K += noise * np.eye(len(idx))
        ks = os_ * np.exp(-np.abs(t[-1] - t[idx]) / ls)
        sol = np.linalg.solve(K, yi)
        means[i] = (ks @ sol) * sd + mu
        vars_[i] = max(os_ - ks @ np.linalg.solve(K, ks), 1e-6) * sd**2 \
            + noise * sd**2
    return means, vars_


def dpl_predict(task, seed):
    """Power-law ensemble y = a - b * t^-c per curve."""
    rng = np.random.default_rng(seed)
    n, m = task.Y.shape
    means, vars_ = np.zeros(n), np.zeros(n)
    tf = task.t[-1]
    for i in range(n):
        idx = np.where(task.mask[i] > 0)[0]
        if len(idx) < 2:
            obs = task.Y[i, idx]
            means[i] = obs[-1] if len(idx) else 0.5
            vars_[i] = 0.1
            continue
        tt, yy = task.t[idx], task.Y[i, idx]
        preds = []
        for _ in range(5):
            p0 = [yy.max() + rng.uniform(0, 0.2), rng.uniform(0.1, 1.0),
                  rng.uniform(0.1, 2.0)]
            try:
                res = least_squares(
                    lambda p: p[0] - p[1] * np.power(tt, -p[2]) - yy, p0,
                    bounds=([0, 0, 0.01], [2, 5, 5]), max_nfev=200)
                preds.append(res.x[0] - res.x[1] * tf ** -res.x[2])
            except Exception:
                pass
        preds = np.asarray(preds) if preds else np.asarray([yy[-1]])
        means[i] = float(np.mean(preds))
        vars_[i] = float(np.var(preds) + 1e-4)
    return means, vars_


def lastvalue_predict(task, seed):
    n, m = task.Y.shape
    means = np.zeros(n)
    for i in range(n):
        idx = np.where(task.mask[i] > 0)[0]
        means[i] = task.Y[i, idx[-1]] if len(idx) else 0.5
    resid = 0.05
    return means, np.full(n, resid**2)


METHODS = {
    "LKGP": lkgp_predict,
    "LKGP-noHP": nohp_predict,
    "DPL": dpl_predict,
    "last-value": lastvalue_predict,
}


def _score(mean, var, truth):
    mse = float(np.mean((mean - truth) ** 2))
    var = np.maximum(var, 1e-8)
    llh = float(np.mean(-0.5 * np.log(2 * np.pi * var)
                        - 0.5 * (truth - mean) ** 2 / var))
    return mse, llh


def main(n_seeds: int = 5, n: int = 24, m: int = 20,
         budgets=(60, 120, 240), out=print):
    out("# bench_prediction (Fig 4): final-value MSE / LLH vs #observed")
    out("method,budget,mse,llh,seconds")
    results = {}
    for budget in budgets:
        agg = {k: [[], [], 0.0] for k in METHODS}
        for seed in range(n_seeds):
            task_full = sample_task(seed + 1000, n=n, m=m)
            lens = benchmark_cutoffs(budget, n, m, seed)
            mask = (np.arange(m)[None, :] < lens[:, None]).astype(np.float64)
            task = task_full._replace(mask=mask, Y=task_full.Y_full * mask)
            truth = task_full.Y_full[:, -1]
            for name, fn in METHODS.items():
                t0 = time.time()
                mean, var = fn(task, seed)
                dt = time.time() - t0
                mse, llh = _score(mean, var, truth)
                agg[name][0].append(mse)
                agg[name][1].append(llh)
                agg[name][2] += dt
        for name, (mses, llhs, secs) in agg.items():
            out(f"{name},{budget},{np.mean(mses):.5f},{np.mean(llhs):.3f},"
                f"{secs:.1f}")
            results[(name, budget)] = (float(np.mean(mses)),
                                       float(np.mean(llhs)))
    # paper's claim: LKGP matches/beats baselines on MSE
    for budget in budgets:
        lk = results[("LKGP", budget)][0]
        others = [results[(k, budget)][0] for k in METHODS if k != "LKGP"]
        out(f"# budget {budget}: LKGP mse={lk:.5f} vs best-other="
            f"{min(others):.5f}")
    return results


if __name__ == "__main__":
    main()


def ablate_t_kernel(n_seeds: int = 3, n: int = 24, m: int = 20,
                    budget: int = 120, out=print):
    """Beyond-paper ablation (paper §4 'future work: specialized kernels'):
    Matern-1/2 (paper) vs Matern-3/2 / 5/2 / RBF-like smoothness over t."""
    out("# ablation: progression kernel k2 (budget=%d)" % budget)
    out("t_kernel,mse,llh")
    results = {}
    for kern in ("matern12", "matern32", "matern52"):
        mses, llhs = [], []
        for seed in range(n_seeds):
            task_full = sample_task(seed + 2000, n=n, m=m)
            lens = benchmark_cutoffs(budget, n, m, seed)
            mask = (np.arange(m)[None, :] < lens[:, None]).astype(np.float64)
            task = task_full._replace(mask=mask, Y=task_full.Y_full * mask)
            state = fit(task.X, task.t, task.Y, task.mask,
                        LKGPConfig(t_kernel=kern, lbfgs_iters=40, seed=seed))
            mean, var = posterior(state).final(jax.random.PRNGKey(seed))
            mse, llh = _score(np.asarray(mean), np.asarray(var),
                              task_full.Y_full[:, -1])
            mses.append(mse)
            llhs.append(llh)
        results[kern] = (float(np.mean(mses)), float(np.mean(llhs)))
        out(f"{kern},{results[kern][0]:.5f},{results[kern][1]:.3f}")
    return results
