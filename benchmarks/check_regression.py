"""Benchmark-regression gate: compare smoke runs against a committed baseline.

    python benchmarks/check_regression.py \
        --baseline BENCH_baseline.json \
        --backends BENCH_backends.ci.json \
        --automl BENCH_automl.ci.json \
        --curvepred BENCH_curve_pred.ci.json \
        --factor 2.0

Fails (exit 1) when

* any backend's ``mll_eval_ms`` / ``posterior_mean_ms`` at a matching
  (backend, n, m) cell regresses more than ``--factor`` against the
  committed ``BENCH_baseline.json``, or
* any acceptance claim measured by ``bench_automl`` is false — the two
  headline scheduler claims (LKGP-ranked SH beats rank-based at equal
  budget; ``precond_rank > 0`` reduces CG iterations) plus, when the run
  carries the ``--amortized`` suite, the amortized-hyper-parameter claims
  (amortized+polish cuts mean refit wall-clock >= 3x at equal-or-better
  regret within tolerance; the amortized init's MLL stays within
  tolerance of a converged fit and beats the default init), or
* any acceptance claim measured by ``bench_curve_pred`` is false (the LKGP
  stays within the paper's "matches a Transformer" tolerance on NLL / MAE /
  final-value rank correlation, on identical held-out suites), or
* any acceptance claim measured by ``bench_mvm`` is false: the fused
  single-pass kernel must keep exact f32 parity with the jnp oracle AND
  reduce cost_analysis bytes-accessed by >= 1.5x vs the committed
  two-stage kernel, and the consolidated stacked solve must perform
  strictly fewer operator sweeps (and column MVMs) per MLL/posterior
  evaluation than the separate-solve path, or
* any acceptance claim measured by the solver-crossover mode of
  ``bench_scaling`` is false: the SGD solver must complete the largest n
  without breakdown, the SGD-vs-CG f32 posterior mean must agree to
  rel-err <= 1e-4, and every (n, solver) crossover cell must be present.
  Wall times include compile and are machine-relative, so like ``--mvm``
  the section gates on its acceptance booleans only, or
* any acceptance claim measured by ``bench_reliability`` is false: under
  the injected-fault schedule every healthy tenant keeps availability 1.0
  with predictions bitwise equal to a fault-free control run, every bad
  payload is quarantined, a forced-breakdown escalated solve keeps p99
  within 5x of a clean guarded solve, and checkpoint restore brings every
  session back warm. All deterministic or machine-relative, so the
  section gates on its acceptance booleans only, or
* any acceptance claim measured by ``bench_serving`` is false: the
  state-keyed posterior cache must make warm per-request latency >= 3x
  lower than cache-bypassed requests, coalesced prediction must sustain
  >= 2x per-request throughput at 8 concurrent tenants, and a repeated
  ``posterior()`` on an unchanged state must perform zero additional
  operator sweeps (verified via ``solve_info`` / solve-count identity).

Like ``--mvm``, the serving section is machine-relative (speedup ratios
and deterministic cache checks), so it gates without a committed-baseline
comparison.

The committed baseline was measured on a different machine than the CI
runner, so raw wall times are not comparable. Timings are therefore
normalised by a per-run machine-speed reference — the dense backend's
``mll_eval_ms`` at the first shared cell — before the factor check: a
uniformly slower runner cancels out, while one backend regressing
relative to the others does not. The reference cell itself is reported as
information only.

Wall-clock deltas of the AutoML schedulers are likewise informational —
scheduler timing includes many small L-BFGS refits and is too noisy on
shared CI runners for a hard gate.

Every benchmark payload carries a dataset id (``meta.dataset``, defaulting
to ``"synthetic"`` for payloads predating the tag); rows measured on one
dataset never gate against a baseline measured on another. A run on a real
artifact (``--dataset lcbench:...``) therefore reports its acceptance
booleans and metrics as information against the committed synthetic
baseline instead of failing the gate — commit a matching-dataset baseline
to make them binding. ``--backends`` / ``--automl`` may be omitted to skip
those sections (e.g. the dataset-only CI leg).
"""
from __future__ import annotations

import argparse
import json
import sys


def _backend_cells(payload):
    return {(r["backend"], r["n"], r["m"]): r for r in payload["results"]}


def _dataset(payload) -> str:
    """Dataset id a payload was measured on (pre-tag payloads: synthetic)."""
    return (payload or {}).get("meta", {}).get("dataset", "synthetic")


def _speed_reference(cells):
    """Machine-speed proxy: dense mll_eval_ms at the smallest shared cell."""
    dense = sorted(k for k in cells if k[0] == "dense")
    if not dense:     # dense skipped (huge smoke size) — first cell instead
        dense = sorted(cells)
    key = dense[0]
    return key, cells[key]["mll_eval_ms"]


def _check_acceptance(name: str, payload: dict, base_payload: dict,
                      failures: list) -> bool:
    """Gate a payload's acceptance booleans iff datasets match the baseline.

    Returns True when the datasets match (metric deltas vs the baseline
    are meaningful); on a mismatch the claims are reported as information
    so a real-dataset run never fails a synthetic-baseline gate.
    """
    ds, base_ds = _dataset(payload), _dataset(base_payload)
    gate = ds == base_ds
    if not gate:
        print(f"info      {name}: dataset {ds!r} does not match baseline "
              f"{base_ds!r}; acceptance reported as info, not gated")
    for claim, value in payload["acceptance"].items():
        if value:
            print(f"ok        {name} [{ds}] acceptance: {claim}")
        elif gate:
            failures.append(f"CLAIM FAILED {name} [{ds}] acceptance: {claim}")
        else:
            print(f"info      {name} [{ds}] acceptance: {claim} = False "
                  "(not gated: dataset differs from baseline)")
    return gate


def check(baseline: dict, backends: dict | None, automl: dict | None,
          factor: float, curvepred: dict | None = None,
          mvm: dict | None = None, serving: dict | None = None,
          scaling: dict | None = None,
          reliability: dict | None = None) -> list[str]:
    failures = []

    if backends is not None:
        base_cells = _backend_cells(baseline["backends"])
        cur_cells = _backend_cells(backends)
        ref_key, base_ref = _speed_reference(base_cells)
        if ref_key not in cur_cells:
            return [f"backends: reference cell {ref_key} missing from "
                    "current run"]
        cur_ref = cur_cells[ref_key]["mll_eval_ms"]
        speed = cur_ref / base_ref if base_ref > 0 else 1.0
        print(f"info      machine-speed reference {ref_key}: current "
              f"{cur_ref:.2f}ms / baseline {base_ref:.2f}ms = {speed:.2f}x")

        for key, base_row in base_cells.items():
            cur_row = cur_cells.get(key)
            if cur_row is None:
                failures.append(f"backends: cell {key} missing from "
                                "current run")
                continue
            for metric in ("mll_eval_ms", "posterior_mean_ms"):
                if (key, metric) == (ref_key, "mll_eval_ms"):
                    continue                   # the reference itself
                base_v, cur_v = base_row[metric], cur_row[metric]
                ratio = (cur_v / (base_v * speed)) if base_v > 0 \
                    else float("inf")
                line = (f"backends {key} {metric}: {cur_v:.2f}ms vs "
                        f"baseline {base_v:.2f}ms (normalised {ratio:.2f}x)")
                if ratio > factor:
                    failures.append("REGRESSION " + line)
                else:
                    print("ok        " + line)

    if automl is not None:
        gate = _check_acceptance("automl", automl, baseline.get("automl"),
                                 failures)
        base_sched = baseline.get("automl", {}).get("mean_regret", {})
        for sched, regret in automl.get("mean_regret", {}).items():
            base_r = base_sched.get(sched) if gate else None
            print(f"info      automl [{_dataset(automl)}] {sched}: "
                  f"mean regret {regret}"
                  + (f" (baseline {base_r})" if base_r is not None else ""))
        am = automl.get("amortized", {}).get("summary")
        if am:
            base_am = (baseline.get("automl", {}).get("amortized", {})
                       .get("summary", {}) if gate else {})
            base_sp = base_am.get("refit_speedup")
            print(f"info      automl [{_dataset(automl)}] amortized: "
                  f"refit speedup {am['refit_speedup']}x "
                  f"(mll gap {am['mean_mll_gap']['amortized']})"
                  + (f" (baseline speedup {base_sp}x)"
                     if base_sp is not None else ""))
            for strat, ms in am.get("mean_refit_ms", {}).items():
                print(f"info      automl [{_dataset(automl)}] amortized "
                      f"{strat}: refit {ms} ms, "
                      f"solve {am['mean_solve_ms'].get(strat)} ms, "
                      f"regret {am['mean_regret'].get(strat)}")

    if curvepred is not None:
        gate = _check_acceptance("curve_pred", curvepred,
                                 baseline.get("curve_pred"), failures)
        # Prediction-quality deltas vs the committed baseline summary are
        # informational: the smoke transformer is tiny and briefly trained,
        # so its absolute metrics move with runner/python version — the
        # gate is the tolerance-band acceptance above, not these numbers.
        base_sum = (baseline.get("curve_pred", {}).get("summary", {})
                    if gate else {})
        for model, s in curvepred.get("summary", {}).items():
            base_s = base_sum.get(model, {})
            print(f"info      curve_pred [{_dataset(curvepred)}] {model}: "
                  f"nll {s['nll']} mae {s['mae']} rank {s['rank_corr']}"
                  + (f" (baseline nll {base_s.get('nll')} "
                     f"mae {base_s.get('mae')})" if base_s else ""))

    if mvm is not None:
        for claim, value in mvm["acceptance"].items():
            if value:
                print(f"ok        mvm acceptance: {claim}")
            else:
                failures.append(f"CLAIM FAILED mvm acceptance: {claim}")
        for row in mvm.get("kernel", []):
            print(f"info      mvm kernel B={row['B']} n={row['n']} "
                  f"m={row['m']}: bytes ratio {row['bytes_ratio']:.2f}x "
                  f"(fused {row['fused_bytes']/1e6:.2f}MB vs two-stage "
                  f"{row['two_stage_bytes']/1e6:.2f}MB), "
                  f"f32 err {row['max_abs_err_f32']:.1e}")
        s = mvm.get("solve")
        if s:
            print(f"info      mvm solve: stacked {s['stacked']['sweeps']} "
                  f"sweeps / {s['stacked']['column_matvecs']} col-MVMs vs "
                  f"separate {s['separate']['sweeps']} / "
                  f"{s['separate']['column_matvecs']}")

    if serving is not None:
        for claim, value in serving["acceptance"].items():
            if value:
                print(f"ok        serving acceptance: {claim}")
            else:
                failures.append(f"CLAIM FAILED serving acceptance: {claim}")
        lat = serving.get("latency", {})
        if lat:
            print(f"info      serving latency (n={lat['n']} m={lat['m']}): "
                  f"cold p50 {lat['cold']['p50_ms']}ms vs warm "
                  f"{lat['warm']['p50_ms']}ms "
                  f"({lat['warm_speedup_p50']}x)")
        for name in ("throughput", "throughput_large"):
            tp = serving.get(name)
            if tp:
                print(f"info      serving {name} (n={tp['n']} m={tp['m']}): "
                      f"per-request {tp['per_request_rps']} req/s vs "
                      f"coalesced {tp['coalesced_rps']} req/s "
                      f"({tp['coalesced_speedup']}x)")
        sc = serving.get("solve_cache", {})
        if sc:
            print(f"info      serving solve-cache [{sc['backend']}]: "
                  f"solves {sc['solve_count_first']}->"
                  f"{sc['solve_count_second']} tally_delta="
                  f"{sc['tally_delta']} info_resident="
                  f"{sc['solve_info_resident']}")

    if reliability is not None:
        for claim, value in reliability["acceptance"].items():
            if value:
                print(f"ok        reliability acceptance: {claim}")
            else:
                failures.append(f"CLAIM FAILED reliability acceptance: "
                                f"{claim}")
        av = reliability.get("availability", {})
        if av:
            print(f"info      reliability availability: "
                  f"{av['healthy_served']}/{av['healthy_requests']} healthy "
                  f"requests served ({av['availability']:.3f}), "
                  f"{av['quarantines']} quarantines, bitwise="
                  f"{av['healthy_bitwise_equal_to_control']}")
        lat = reliability.get("latency", {})
        if lat:
            print(f"info      reliability escalation (n={lat['n']} "
                  f"m={lat['m']}): clean p99 {lat['clean']['p99_ms']}ms vs "
                  f"escalated p99 {lat['escalated']['p99_ms']}ms "
                  f"({lat['p99_ratio']}x)")
        rec = reliability.get("recovery", {})
        if rec:
            print(f"info      reliability recovery: "
                  f"{rec['sessions_restored']} sessions in "
                  f"{rec['restore_ms']}ms, warm={rec['all_sessions_warm']}")

    if scaling is not None:
        for claim, value in scaling["acceptance"].items():
            if value:
                print(f"ok        scaling acceptance: {claim}")
            else:
                failures.append(f"CLAIM FAILED scaling acceptance: {claim}")
        for row in scaling.get("results", []):
            print(f"info      scaling n={row['n']} {row['solver']}: "
                  f"{row['wall_s']}s, {row['iters']} iters, "
                  f"rel {row['rel_residual']:.1e}"
                  + (" BREAKDOWN" if row.get("breakdown") else ""))
        cx = scaling.get("crossover", {})
        if cx:
            print(f"info      scaling crossover: per-n fastest "
                  f"{cx.get('per_n_fastest')}, sgd beats cg at "
                  f"n={cx.get('sgd_beats_cg_at_n')}")
        par = scaling.get("parity", {})
        if par:
            print(f"info      scaling parity n={par.get('n')}: posterior "
                  f"mean rel-err {par.get('posterior_mean_rel_err'):.2e}")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_baseline.json")
    ap.add_argument("--backends", default=None,
                    help="BENCH_backends json to gate (omit to skip)")
    ap.add_argument("--automl", default=None,
                    help="BENCH_automl json to gate (omit to skip)")
    ap.add_argument("--curvepred", default=None,
                    help="BENCH_curve_pred json to gate (omit to skip)")
    ap.add_argument("--mvm", default=None,
                    help="BENCH_mvm json to gate (omit to skip)")
    ap.add_argument("--serving", default=None,
                    help="BENCH_serving json to gate (omit to skip)")
    ap.add_argument("--scaling", default=None,
                    help="BENCH_scaling json to gate (omit to skip)")
    ap.add_argument("--reliability", default=None,
                    help="BENCH_reliability json to gate (omit to skip)")
    ap.add_argument("--factor", type=float, default=2.0)
    args = ap.parse_args(argv)

    def load(path):
        if not path:
            return None
        with open(path) as f:
            return json.load(f)

    with open(args.baseline) as f:
        baseline = json.load(f)
    backends = load(args.backends)
    automl = load(args.automl)
    curvepred = load(args.curvepred)
    mvm = load(args.mvm)
    serving = load(args.serving)
    scaling = load(args.scaling)
    reliability = load(args.reliability)
    if all(p is None for p in (backends, automl, curvepred, mvm, serving,
                               scaling, reliability)):
        print("benchmark gate FAILED: no sections given — pass at least "
              "one of --backends/--automl/--curvepred/--mvm/--serving/"
              "--scaling/--reliability")
        return 1

    failures = check(baseline, backends, automl, args.factor, curvepred,
                     mvm, serving, scaling, reliability)
    if failures:
        print("\n".join(["", "benchmark gate FAILED:"] + failures))
        return 1
    print("benchmark gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
