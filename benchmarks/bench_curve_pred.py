"""Learning-curve prediction head-to-head: LKGP vs amortized transformer.

Reproduces the paper's headline experimental claim — "our GP model can
match the performance of a Transformer on a learning curve prediction
task" (PAPER.md §5) — on the offline synthetic LCBench-like prior:

1. pre-train the curve transformer (:mod:`repro.baselines`) on a stream of
   synthetic tasks covering every regime (noise / spikes / divergence /
   crossing families, curriculum over observed-prefix fraction);
2. score the LKGP (``fit`` -> ``Posterior.mean`` / ``.variance``) and the
   transformer on *identical* held-out suites at three observation-cutoff
   fractions: continuation NLL, MAE, Spearman rank correlation of
   final-epoch values, and fit/predict wall-clock;
3. write ``BENCH_curve_pred.json`` with per-row results, per-model summary
   means, and the acceptance booleans CI gates on (the LKGP must stay
   within a fixed tolerance of the transformer; tolerances are absolute —
   accuracy units for MAE, nats for NLL — because the transformer is
   amortized over the exact task prior and sets a strong reference).

With ``--dataset lcbench:<path>`` the held-out suites come from an
LCBench/ifBO-format artifact instead of the synthetic prior (the
transformer still pre-trains on the prior, at the artifact's shapes and
budget grid — the realistic transfer setting); every row and the payload
meta carry the dataset id so the regression gate never compares synthetic
and real rows.

    PYTHONPATH=src python benchmarks/bench_curve_pred.py [--quick]
        [--dataset lcbench:tests/fixtures/lcbench_mini.npz]
"""
from __future__ import annotations

import argparse
import json
import platform
import time

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.baselines import (CurveTransformerConfig, PretrainConfig,
                             head_to_head, pretrain)
from repro.core import LKGPConfig
from repro.data import get_source, sample_suite

# Paper-tolerance margins for "the GP matches the Transformer" (absolute:
# accuracy units for MAE, nats per cell for NLL, Spearman units for rank).
MAE_TOL = 0.08
NLL_TOL = 1.5
RANK_TOL = 0.35


def _suites(quick: bool):
    base = dict(d=7, noise=0.01, spike_prob=0.03)
    if quick:
        return [
            dict(name="smoke-mixed", seed=901, num_tasks=2, n=10,
                 diverge_prob=0.03, crossing=False, **base),
            dict(name="smoke-crossing", seed=902, num_tasks=2, n=10,
                 diverge_prob=0.0, crossing=True, **base),
        ]
    return [
        dict(name="mixed", seed=901, num_tasks=5, n=16,
             diverge_prob=0.03, crossing=False, **base),
        dict(name="crossing", seed=902, num_tasks=5, n=16,
             diverge_prob=0.0, crossing=True, **base),
        dict(name="noisy-divergent", seed=903, num_tasks=5, n=16,
             diverge_prob=0.08, crossing=False, **dict(base, noise=0.03)),
    ]


def _summarise(rows):
    out = {}
    for model in ("lkgp", "transformer"):
        sel = [r for r in rows if r["model"] == model]
        out[model] = {k: round(float(np.mean([r[k] for r in sel])), 5)
                      for k in ("nll", "mae", "rank_corr", "fit_s",
                                "predict_s")}
    return out


def main(quick: bool = False, steps: int | None = None, seed: int = 0,
         out_path: str = "BENCH_curve_pred.json", out=print,
         dataset: str | None = None):
    t_all = time.time()
    if dataset:
        src = get_source(dataset)
        dataset_id = src.dataset_id
        ds_tasks = src.tasks(2 if quick else None)
        d = ds_tasks[0].X.shape[1]
        grid = max((np.asarray(tk.t, np.float64) for tk in ds_tasks),
                   key=len)
        m = grid.shape[0]
        pre_t = tuple(float(v) for v in grid)
        has_full = getattr(src, "has_full", [True] * len(ds_tasks))
        out(f"# dataset {dataset_id}: {len(ds_tasks)} tasks, d={d}, "
            f"grid m={m} t=[{grid[0]:g}..{grid[-1]:g}]")
    else:
        dataset_id = "synthetic"
        d, m, pre_t = 7, 9 if quick else 12, None
    model_cfg = (CurveTransformerConfig(d_in=d, d_model=32, num_layers=2,
                                        num_heads=2, d_ff=64)
                 if quick else CurveTransformerConfig(d_in=d))
    pre_cfg = PretrainConfig(
        steps=steps or (250 if quick else 2000),
        tasks_per_step=4 if quick else 6,
        n=10 if quick else 16, m=m, d=d, t=pre_t, seed=seed,
        log_every=100 if quick else 200)
    out(f"# pre-training curve transformer ({pre_cfg.steps} steps, "
        f"m={pre_cfg.m})")
    params, pre_info = pretrain(model_cfg, pre_cfg, out=out)
    out(f"# pretrain: nll {pre_info['first_loss']} -> "
        f"{pre_info['final_loss']} in {pre_info['train_s']}s")

    gp_cfg = LKGPConfig(lbfgs_iters=40, seed=seed)
    cutoffs = (0.2, 0.4, 0.7)
    rows = []
    if dataset:
        # Censored tasks (no post-cutoff ground truth) restrict scoring to
        # their artifact mask; fully-recorded tasks score everywhere.
        valid_masks = (None if all(has_full)
                       else [np.ones_like(tk.mask) if hf else tk.mask
                             for tk, hf in zip(ds_tasks, has_full)])
        out(f"# suite {dataset_id}: {len(ds_tasks)} tasks, cutoffs {cutoffs}")
        rows += head_to_head(params, model_cfg, ds_tasks, cutoffs=cutoffs,
                             gp_cfg=gp_cfg, seed=seed, suite=dataset_id,
                             valid_masks=valid_masks)
    else:
        for suite in _suites(quick):
            tasks = sample_suite(suite["seed"], suite["num_tasks"],
                                 n=suite["n"], m=m, d=suite["d"],
                                 noise=suite["noise"],
                                 spike_prob=suite["spike_prob"],
                                 diverge_prob=suite["diverge_prob"],
                                 crossing=suite["crossing"])
            out(f"# suite {suite['name']}: {suite['num_tasks']} tasks, "
                f"n={suite['n']} m={m}, cutoffs {cutoffs}")
            rows += head_to_head(params, model_cfg, tasks, cutoffs=cutoffs,
                                 gp_cfg=gp_cfg, seed=seed,
                                 suite=suite["name"])
    for r in rows:
        r["dataset"] = dataset_id

    summary = _summarise(rows)
    out("model,nll,mae,rank_corr,fit_s,predict_s")
    for name, s in summary.items():
        out(f"{name},{s['nll']},{s['mae']},{s['rank_corr']},{s['fit_s']},"
            f"{s['predict_s']}")

    lk, tf = summary["lkgp"], summary["transformer"]
    acceptance = {
        "all_cutoffs_scored": all(
            any(r["cutoff"] == c and r["model"] == mdl for r in rows)
            for c in cutoffs for mdl in ("lkgp", "transformer")),
        "lkgp_matches_transformer_mae": lk["mae"] <= tf["mae"] + MAE_TOL,
        "lkgp_matches_transformer_nll": lk["nll"] <= tf["nll"] + NLL_TOL,
        "lkgp_matches_transformer_rank": (lk["rank_corr"]
                                          >= tf["rank_corr"] - RANK_TOL),
        "transformer_pretrain_converged": (pre_info["final_loss"]
                                           < pre_info["first_loss"]),
    }
    for k, v in acceptance.items():
        out(f"# acceptance {k}: {v}")

    payload = {
        "meta": {
            "jax_backend": jax.default_backend(),
            "jax_version": jax.__version__,
            "platform": platform.platform(),
            "quick": quick,
            "seed": seed,
            "dataset": dataset_id,
            "cutoffs": list(cutoffs),
            "tolerances": {"mae": MAE_TOL, "nll": NLL_TOL, "rank": RANK_TOL},
            "gp": {"lbfgs_iters": gp_cfg.lbfgs_iters},
            "transformer": {"d_model": model_cfg.d_model,
                            "num_layers": model_cfg.num_layers,
                            "pretrain": pre_info},
        },
        "results": rows,
        "summary": summary,
        "acceptance": acceptance,
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    out(f"# wrote {out_path} ({time.time() - t_all:.1f}s total)")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smoke sizes for CI (tiny model, short pretrain)")
    ap.add_argument("--steps", type=int, default=None,
                    help="override pre-training steps")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_curve_pred.json")
    ap.add_argument("--dataset", default=None,
                    help="curve source spec, e.g. "
                         "lcbench:tests/fixtures/lcbench_mini.npz "
                         "(default: the synthetic prior suites)")
    args = ap.parse_args()
    main(quick=args.quick, steps=args.steps, seed=args.seed,
         out_path=args.out, dataset=args.dataset)
