"""AutoML scheduler benchmark: regret vs budget, wall-clock, PCG, batching.

Four sections, all written to ``BENCH_automl.json``:

* ``schedulers`` — every scheduler (SH with LKGP-ranked promotion, SH with
  the classic rank-based baseline, Hyperband, freeze-thaw) raced on a grid
  of synthetic task suites from :mod:`repro.data.curves` (varying n, m,
  observation-noise regime, divergent-curve fraction). Each pool contains a
  few configs pre-trained to completion ("history" from earlier
  experiments): the LKGP transfers from those completed curves through the
  config kernel, the rank baseline cannot — that asymmetry is the paper
  follow-up's (arXiv:2508.14818) central claim. SH-lkgp and SH-rank follow
  the identical rung schedule, so their regrets compare at exactly equal
  epoch budget.
* ``precond`` — CG vs pivoted-Cholesky-preconditioned CG
  (``LKGPConfig.precond_rank``) on the posterior solve: iterations, wall
  time, and solution agreement per problem size.
* ``batched`` — the vmapped ``fit_batch`` + ``posterior_batch`` path (one
  compiled call for a whole task suite) against the per-task loop.
* ``acceptance`` — the two headline claims as booleans so CI can gate on
  them: SH-lkgp beats SH-rank at equal budget, and ``precond_rank > 0``
  reduces CG iterations on at least one size.
* ``amortized`` (``--amortized``) — the amortized-hyper-parameter suite:
  per-task MLL gap of the :mod:`repro.amortize` one-shot init vs a
  converged L-BFGS fit, a per-round refit wall-clock breakdown (MLL-opt
  time vs posterior-solve time) across full-LBFGS / amortized-oneshot /
  amortized+polish, and an SH-lkgp regret race of the three strategies.
  Adds gated acceptance booleans: amortized+polish cuts mean refit
  wall-clock >= 3x at equal-or-better regret (within tolerance), the
  amortized init's MLL is within tolerance of the converged optimum, and
  it beats the prior-mean default init.

With ``--dataset lcbench:<path>`` the scheduler races replay the tasks of
an LCBench/ifBO-format artifact instead of sampling the synthetic prior:
each pool steps through the artifact's recorded curves
(:func:`repro.data.curves.replay_step_fns`) on the artifact's (possibly
non-uniform) budget grid, which the LKGP consumes as its progression axis.
Rows and payload meta carry the dataset id so the regression gate never
compares synthetic and real rows; the precond/batched solver sections stay
on the synthetic prior (they measure the solver, not the data).

    PYTHONPATH=src python benchmarks/bench_automl.py [--quick]
        [--dataset lcbench:tests/fixtures/lcbench_mini.npz]
"""
from __future__ import annotations

import argparse
import json
import platform
import time
from dataclasses import replace

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.autotune import (AutotuneConfig, FreezeThawScheduler,
                            HyperbandScheduler, SHConfig,
                            SuccessiveHalvingScheduler)
from repro.core import (LKGPConfig, cg_solve, fit, fit_batch, get_engine,
                        gram_matrices, init_params, pcg_solve,
                        pivoted_cholesky_grid, posterior, posterior_batch,
                        woodbury_preconditioner)
from repro.data import (get_source, noisy_step_fns, replay_step_fns,
                        sample_suite, sample_task, stack_suite)


# --------------------------------------------------------------------------
# scheduler section
# --------------------------------------------------------------------------
def _regret_trajectory(rungs, true_final, best, sign=1.0):
    """Anytime regret: incumbent (best-scored active) after each rung.

    ``sign`` is +1 for maximized metrics, -1 for minimized ones (scores
    are always score-space, larger = better; regret stays >= 0 either
    way).
    """
    out = []
    for rung in rungs:
        act = rung["active"]
        inc = act[int(np.argmax(rung["scores"]))]
        out.append([int(rung["epochs_spent"]),
                    round(float(sign * (best - true_final[inc])), 5)])
    return out


def run_suite(suite: dict, seeds, gp: LKGPConfig, out=print):
    """Race every scheduler on one suite.

    Synthetic suites sample a fresh task per seed; dataset suites carry a
    loaded ``task`` (``replay=True``) whose recorded curves are replayed —
    the seed then varies the history selection and scheduler tie-breaks,
    not the curves. Either way the task's progression grid ``t`` (uniform
    epochs or real budget fidelities) is handed to the model.
    """
    rows = []
    replay = bool(suite.get("replay"))
    for seed in seeds:
        if replay:
            task = suite["task"]
        else:
            task = sample_task(seed=suite["task_seed"] + seed,
                               n=suite["n"], m=suite["m"],
                               d=suite["d"], noise=0.005,
                               diverge_prob=suite["diverge_prob"],
                               spike_prob=0.0, crossing=True)
        n, m = task.Y_full.shape

        def step_fns():
            if replay:
                return replay_step_fns(task, 7000 + seed,
                                       suite["obs_noise"],
                                       suite["spike_prob"],
                                       censored=suite.get("censored"))
            return noisy_step_fns(task, 7000 + seed, suite["obs_noise"],
                                  suite["spike_prob"])

        rng = np.random.default_rng(seed)
        hist = rng.choice(n, suite["n_hist"], replace=False)
        fresh = np.setdiff1d(np.arange(n), hist).tolist()
        maximize = bool(suite.get("maximize", True))
        sign = 1.0 if maximize else -1.0
        true_final = task.Y_full[:, -1]
        best = float(true_final[fresh].max() if maximize
                     else true_final[fresh].min())

        def race(name, make_sched, select_key="selected"):
            sched, run_kwargs = make_sched()
            if hasattr(sched, "pool"):          # history: free completed curves
                for i in hist:
                    sched.pool.advance_to(i, m, charge=False)
            t0 = time.time()
            summary = sched.run(**run_kwargs)
            wall = time.time() - t0
            if select_key == "survivors":       # freeze-thaw keeps a set
                surv = [i for i in summary["survivors"] if i in fresh]
                pred = summary.get("predicted_final")
                if surv and pred is not None:
                    pick = [sign * pred[i] for i in surv]   # raw -> score
                    sel = surv[int(np.argmax(pick))]
                else:
                    sel = surv[0] if surv else fresh[0]
            else:
                sel = summary["selected"]
            row = {
                "suite": suite["name"], "scheduler": name, "seed": seed,
                "n": n, "m": m, "n_hist": suite["n_hist"],
                "obs_noise": suite["obs_noise"],
                "diverge_prob": suite["diverge_prob"],
                "maximize": maximize,
                "epochs_spent": int(summary["epochs_spent"]),
                "regret": round(float(sign * (best - true_final[sel])), 5),
                "wall_s": round(wall, 3),
            }
            if "rungs" in summary:
                row["regret_vs_budget"] = _regret_trajectory(
                    summary["rungs"], true_final, best, sign)
            rows.append(row)
            out(f"{suite['name']},{name},{seed},{row['epochs_spent']},"
                f"{row['regret']},{row['wall_s']}")

        sh_cfg = dict(max_epochs=m, min_epochs=suite["min_epochs"],
                      eta=3, gp=gp, ucb_beta=0.0, refit_lbfgs_iters=8,
                      maximize=maximize)

        def sh(promotion):
            def make():
                sched = SuccessiveHalvingScheduler(
                    task.X, step_fns(),
                    SHConfig(promotion=promotion, **sh_cfg), seed=seed,
                    t=task.t)
                return sched, {"subset": fresh}
            return make

        race("sh-lkgp", sh("lkgp"))
        race("sh-rank", sh("rank"))

        def hb():
            sched = HyperbandScheduler(
                task.X, step_fns(),
                SHConfig(promotion="lkgp", **sh_cfg), seed=seed,
                candidates=fresh, t=task.t)
            return sched, {}

        race("hyperband-lkgp", hb)

        def ft():
            sched = FreezeThawScheduler(
                task.X, step_fns(),
                AutotuneConfig(max_epochs=m, refit_every=max(2, m // 4),
                               min_epochs_before_stop=suite["min_epochs"],
                               ucb_beta=1.0, gp=gp, refit_lbfgs_iters=8,
                               maximize=maximize),
                seed=seed, t=task.t)
            return sched, {}

        race("freeze-thaw", ft, select_key="survivors")
    return rows


# --------------------------------------------------------------------------
# preconditioner section
# --------------------------------------------------------------------------
def _timed(fn, reps=3):
    """Median wall ms over ``reps`` calls after one warm-up (compile) call."""
    out = fn()
    jax.block_until_ready(out)
    times = []
    for _ in range(reps):
        t0 = time.time()
        out = fn()
        jax.block_until_ready(out)
        times.append((time.time() - t0) * 1e3)
    return out, float(np.median(times))


def bench_precond(sizes, ranks=(20, 50), tol=1e-6, out=print):
    rows = []
    for n, m in sizes:
        task = sample_task(seed=1, n=n, m=m, d=7)
        X = jnp.asarray(task.X)
        params = init_params(X.shape[1], X.dtype)
        K1, K2 = gram_matrices(params, X, jnp.asarray(task.t, X.dtype))
        mask = jnp.asarray(task.mask, X.dtype)
        noise = jnp.exp(params.raw_noise)
        engine = get_engine("iterative")
        A = engine.operator_from_grams(K1, K2, mask, noise)
        b = jnp.asarray(task.Y * task.mask, X.dtype)

        base = cg_solve(A, b, tol=tol, max_iters=10_000)
        _, base_ms = _timed(
            jax.jit(lambda: cg_solve(A, b, tol=tol, max_iters=10_000).x))
        row = {"n": n, "m": m, "n_obs": int(np.sum(task.mask)),
               "cg_iters": int(base.iters), "cg_ms": round(base_ms, 2)}

        def A_flat(u):
            return A(u.reshape(*u.shape[:-1], n, m)).reshape(u.shape)

        for rank in ranks:
            L = pivoted_cholesky_grid(K1, K2, mask, rank)
            M_inv = woodbury_preconditioner(L, noise)
            res = pcg_solve(A_flat, b.reshape(-1), M_inv, tol=tol,
                            max_iters=10_000)
            # steady-state solve cost, factor included (it is rebuilt per
            # refit but shared across the solves inside one)
            _, pcg_ms = _timed(jax.jit(
                lambda: pcg_solve(
                    A_flat, b.reshape(-1),
                    woodbury_preconditioner(
                        pivoted_cholesky_grid(K1, K2, mask, rank), noise),
                    tol=tol, max_iters=10_000).x))
            err = float(jnp.max(jnp.abs(res.x.reshape(n, m) - base.x)))
            row[f"pcg_r{rank}_iters"] = int(res.iters)
            row[f"pcg_r{rank}_ms"] = round(pcg_ms, 2)
            row[f"pcg_r{rank}_max_err"] = err
        rows.append(row)
        out(f"precond,{n}x{m},cg_iters={row['cg_iters']},cg_ms={row['cg_ms']},"
            + ",".join(f"r{r}_iters={row[f'pcg_r{r}_iters']},"
                       f"r{r}_ms={row[f'pcg_r{r}_ms']}" for r in ranks))
    return rows


# --------------------------------------------------------------------------
# batched-task section
# --------------------------------------------------------------------------
def bench_batched(num_tasks, n, m, d=5, out=print):
    tasks = sample_suite(seed=11, num_tasks=num_tasks, n=n, m=m, d=d)
    X, t, Y, mask, Y_full = stack_suite(tasks)
    cfg = LKGPConfig(lbfgs_iters=15, mll_method="cholesky")

    t0 = time.time()
    state = fit_batch(X, t, Y, mask, cfg)
    mean_b, var_b = posterior_batch(state).final()
    jax.block_until_ready(mean_b)
    batch_s = time.time() - t0

    t0 = time.time()
    means_loop = []
    for tk in tasks:
        st = fit(tk.X, tk.t, tk.Y, tk.mask, cfg)
        mu, _ = posterior(st).final()
        means_loop.append(np.asarray(mu))
    loop_s = time.time() - t0

    rmse_b = float(np.sqrt(np.mean((np.asarray(mean_b) - Y_full[:, :, -1]) ** 2)))
    rmse_l = float(np.sqrt(np.mean((np.stack(means_loop) - Y_full[:, :, -1]) ** 2)))
    row = {"num_tasks": num_tasks, "n": n, "m": m,
           "batch_s": round(batch_s, 3), "loop_s": round(loop_s, 3),
           "speedup": round(loop_s / batch_s, 2),
           "final_rmse_batched": round(rmse_b, 5),
           "final_rmse_loop": round(rmse_l, 5)}
    out(f"batched,B={num_tasks},n={n},m={m},batch_s={row['batch_s']},"
        f"loop_s={row['loop_s']},speedup={row['speedup']}x")
    return row


# --------------------------------------------------------------------------
# amortized-hyper-parameter section (--amortized)
# --------------------------------------------------------------------------
def _amortized_strategies(gp: LKGPConfig):
    """The three fit strategies the suite races (shared base config).

    ``full-lbfgs`` refits with the host L-BFGS at its full default budget
    (``gp.lbfgs_iters`` per round); the amortized arms replace it with the
    fixed-budget device polish, so the race measures the actual swap a
    scheduler makes when it opts into ``hyper_init="amortized"``.
    """
    return [
        ("full-lbfgs", gp),
        ("amortized-oneshot",
         replace(gp, hyper_init="amortized", polish_steps=0)),
        ("amortized-polish",
         replace(gp, hyper_init="amortized", polish_steps=2)),
    ]


def bench_amortized_mll(seeds, n, m, d, out=print):
    """Per-task MLL-objective gap of each init vs a converged L-BFGS fit.

    ``gap_*`` is the per-observation penalised negative MLL above the
    converged optimum (lower = closer); ``gap_default`` is the prior-mean
    init the amortizer must beat for the warm start to be worth anything.
    """
    rows = []
    for seed in seeds:
        task = sample_task(seed=900 + seed, n=n, m=m, d=d, noise=0.005,
                           crossing=True)
        args = (task.X, task.t, task.Y, task.mask)
        conv = fit(*args, LKGPConfig(lbfgs_iters=60)).fit_result.fun
        one = fit(*args, LKGPConfig(hyper_init="amortized",
                                    polish_steps=0)).fit_result.fun
        dflt = fit(*args, LKGPConfig(polish_steps=0)).fit_result.fun
        pol = fit(*args, LKGPConfig(hyper_init="amortized",
                                    polish_steps=2)).fit_result.fun
        rows.append({
            "seed": seed, "n": n, "m": m,
            "fun_converged": round(float(conv), 5),
            "gap_amortized": round(float(one - conv), 5),
            "gap_default": round(float(dflt - conv), 5),
            "gap_polished": round(float(pol - conv), 5),
        })
        out(f"amortized-mll,seed={seed},conv={rows[-1]['fun_converged']},"
            f"gap_amortized={rows[-1]['gap_amortized']},"
            f"gap_default={rows[-1]['gap_default']},"
            f"gap_polished={rows[-1]['gap_polished']}")
    return rows


def bench_amortized_refit(strategies, seeds, n, m, d, out=print):
    """Per-round refit wall-clock breakdown for each fit strategy.

    Replays the predictor loop a scheduler runs — reveal one epoch column,
    ``extend`` + ``refit`` (MLL optimisation), then read the final-epoch
    posterior (solve) — and times the two phases separately. The first
    round (cold fit + compile) is reported as ``cold_s`` and excluded
    from the per-round means.
    """
    from repro.autotune import CurvePredictor

    rows = []
    for name, gp in strategies:
        refit_s, solve_s, cold = [], [], None
        for seed in seeds:
            task = sample_task(seed=900 + seed, n=n, m=m, d=d, noise=0.005,
                               crossing=True)
            # full default refit budget (gp.lbfgs_iters); the polish
            # strategies ignore it — gp.polish_steps >= 0 takes over
            pred = CurvePredictor(task.X, gp=gp, t=task.t,
                                  refit_lbfgs_iters=None)
            Y = task.Y_full
            for k in range(2, m + 1):
                maskk = np.zeros((n, m))
                maskk[:, :k] = 1.0
                t0 = time.perf_counter()
                pred.update(Y, maskk)
                t1 = time.perf_counter()
                pred.predict_final()
                jax.block_until_ready(0)
                t2 = time.perf_counter()
                if k == 2:
                    cold = t1 - t0 if cold is None else cold
                else:
                    refit_s.append(t1 - t0)
                    solve_s.append(t2 - t1)
        row = {
            "strategy": name, "n": n, "m": m,
            "rounds": len(refit_s),
            "cold_s": round(float(cold), 4),
            "mean_refit_ms": round(float(np.mean(refit_s)) * 1e3, 3),
            "p90_refit_ms": round(float(np.quantile(refit_s, 0.9)) * 1e3, 3),
            "mean_solve_ms": round(float(np.mean(solve_s)) * 1e3, 3),
        }
        rows.append(row)
        out(f"amortized-refit,{name},mean_refit_ms={row['mean_refit_ms']},"
            f"mean_solve_ms={row['mean_solve_ms']},cold_s={row['cold_s']}")
    return rows


def bench_amortized_regret(strategies, suite, seeds, out=print):
    """SH-lkgp regret + wall-clock raced across the three fit strategies.

    Identical task, history, rung schedule, and observation stream per
    seed — only the hyper-parameter optimisation strategy differs, so
    regret deltas measure init/polish quality and wall-clock deltas the
    refit cost.
    """
    rows = []
    for seed in seeds:
        task = sample_task(seed=suite["task_seed"] + seed, n=suite["n"],
                           m=suite["m"], d=suite["d"], noise=0.005,
                           diverge_prob=suite["diverge_prob"],
                           spike_prob=0.0, crossing=True)
        n, m = task.Y_full.shape
        rng = np.random.default_rng(seed)
        hist = rng.choice(n, suite["n_hist"], replace=False)
        fresh = np.setdiff1d(np.arange(n), hist).tolist()
        true_final = task.Y_full[:, -1]
        best = float(true_final[fresh].max())
        for name, gp in strategies:
            sched = SuccessiveHalvingScheduler(
                task.X,
                noisy_step_fns(task, 7000 + seed, suite["obs_noise"],
                               suite["spike_prob"]),
                SHConfig(promotion="lkgp", max_epochs=m,
                         min_epochs=suite["min_epochs"], eta=3, gp=gp,
                         ucb_beta=0.0, refit_lbfgs_iters=None), seed=seed,
                t=task.t)
            for i in hist:
                sched.pool.advance_to(i, m, charge=False)
            t0 = time.time()
            summary = sched.run(subset=fresh)
            wall = time.time() - t0
            sel = summary["selected"]
            rows.append({
                "strategy": name, "seed": seed,
                "epochs_spent": int(summary["epochs_spent"]),
                "regret": round(float(best - true_final[sel]), 5),
                "wall_s": round(wall, 3),
            })
            out(f"amortized-regret,{name},{seed},"
                f"{rows[-1]['epochs_spent']},{rows[-1]['regret']},"
                f"{rows[-1]['wall_s']}")
    return rows


def bench_amortized(quick: bool, seeds, gp: LKGPConfig, suite: dict,
                    out=print):
    """The full amortized suite + its gated acceptance booleans."""
    strategies = _amortized_strategies(gp)
    n, m, d = suite["n"], suite["m"], suite["d"]
    mll_rows = bench_amortized_mll(seeds, n=n, m=m, d=d, out=out)
    refit_rows = bench_amortized_refit(strategies, seeds, n=n, m=m, d=d,
                                       out=out)
    regret_rows = bench_amortized_regret(strategies, suite, seeds, out=out)

    refit_ms = {r["strategy"]: r["mean_refit_ms"] for r in refit_rows}
    solve_ms = {r["strategy"]: r["mean_solve_ms"] for r in refit_rows}
    speedup = refit_ms["full-lbfgs"] / max(refit_ms["amortized-polish"], 1e-9)

    def mean_regret(name):
        rs = [r["regret"] for r in regret_rows if r["strategy"] == name]
        return round(float(np.mean(rs)), 5)

    regret = {name: mean_regret(name) for name, _ in strategies}
    gap_amortized = float(np.mean([r["gap_amortized"] for r in mll_rows]))
    gap_default = float(np.mean([r["gap_default"] for r in mll_rows]))

    # Tolerances: regret is in [0, 1] metric units (0.02 is far below the
    # seed-to-seed spread); the MLL gap is per-observation penalised NLL
    # units, where the default init sits ~0.2+ above the optimum.
    regret_tol = 0.02
    mll_tol = 0.15
    acceptance = {
        "amortized_polish_refit_speedup_3x": bool(speedup >= 3.0),
        "amortized_polish_regret_ok": bool(
            regret["amortized-polish"]
            <= regret["full-lbfgs"] + regret_tol),
        "amortized_mll_within_tol": bool(gap_amortized <= mll_tol),
        "amortized_beats_default_init": bool(gap_amortized < gap_default),
    }
    summary = {
        "refit_speedup": round(float(speedup), 2),
        "mean_refit_ms": refit_ms,
        "mean_solve_ms": solve_ms,
        "mean_regret": regret,
        "mean_mll_gap": {"amortized": round(gap_amortized, 5),
                         "default": round(gap_default, 5)},
        "regret_tol": regret_tol, "mll_tol": mll_tol,
    }
    out(f"# amortized summary: {summary}")
    return {"mll_gap": mll_rows, "refit_race": refit_rows,
            "regret_race": regret_rows, "summary": summary}, acceptance


# --------------------------------------------------------------------------
# main
# --------------------------------------------------------------------------
def dataset_suites(src, quick: bool, out=print):
    """One replay suite per artifact task (first task only when quick).

    Censored tasks (no post-cutoff ground truth: the loader fell back to
    ``Y_full = masked Y``) are skipped — regret against zero-padded finals
    would be meaningless. The artifact's metric convention rides along so
    minimized metrics race with inverted promotion and regret math.
    """
    names = getattr(src, "names", None)
    has_full = getattr(src, "has_full", None)
    maximize = bool(getattr(src, "maximize", True))
    suites = []
    for i, task in enumerate(src.tasks()):
        name = names[i] if names and i < len(names) else f"task{i}"
        if has_full is not None and i < len(has_full) and not has_full[i]:
            out(f"# skipping censored task {src.dataset_id}/{name}: no "
                "ground-truth finals to measure regret against")
            continue
        n, m = task.Y_full.shape
        suites.append(dict(
            name=f"{src.dataset_id}/{name}", task=task, replay=True,
            censored=False, maximize=maximize,
            n=n, m=m, n_hist=max(2, n // 8),
            min_epochs=1 if quick else min(2, m),
            obs_noise=0.0, spike_prob=0.0, diverge_prob=0.0))
    if not suites:
        raise SystemExit(f"--dataset {src.dataset_id}: every task is "
                         "censored; no ground truth to race against")
    # Truncate AFTER the censored filter so a censored-first artifact
    # still yields the first raceable task in quick mode.
    return suites[:1] if quick else suites


def suites_grid(quick: bool):
    base = dict(d=5, obs_noise=0.02, spike_prob=0.03, diverge_prob=0.0,
                min_epochs=3, task_seed=500)
    if quick:
        return [
            dict(base, name="smoke-crossing", n=12, m=9, n_hist=3,
                 min_epochs=1),
        ]
    return [
        dict(base, name="small-crossing", n=16, m=12, n_hist=4, min_epochs=2),
        dict(base, name="mid-crossing", n=24, m=20, n_hist=6),
        dict(base, name="mid-divergent", n=24, m=20, n_hist=6,
             diverge_prob=0.1),
        dict(base, name="mid-noisy", n=24, m=20, n_hist=6, obs_noise=0.05,
             spike_prob=0.06),
    ]


def main(quick: bool = False, seeds=None, out_path: str = "BENCH_automl.json",
         out=print, dataset: str | None = None, amortized: bool = False):
    gp = LKGPConfig(lbfgs_iters=20, posterior_samples=64, slq_probes=8,
                    slq_iters=15)
    if seeds is None:
        seeds = range(2) if quick else range(4)
    seeds = list(seeds)

    if dataset:
        src = get_source(dataset)
        dataset_id = src.dataset_id
        suites = dataset_suites(src, quick, out=out)
        out(f"# bench_automl on {dataset_id}: {len(suites)} replayed tasks")
    else:
        dataset_id = "synthetic"
        suites = suites_grid(quick)
    out("# bench_automl: scheduler regret/budget, PCG, batched harness")
    out("suite,scheduler,seed,epochs_spent,regret,wall_s")
    sched_rows = []
    for suite in suites:
        sched_rows += run_suite(suite, seeds, gp, out=out)
    for r in sched_rows:
        r["dataset"] = dataset_id

    precond_rows = bench_precond(
        sizes=((24, 16),) if quick else ((32, 24), (64, 32)),
        ranks=(10,) if quick else (20, 50), out=out)

    batched_row = bench_batched(num_tasks=4 if quick else 8,
                                n=6 if quick else 8,
                                m=8 if quick else 10, out=out)

    amortized_section, amortized_acceptance = None, {}
    if amortized:
        # The suite needs the synthetic prior (the packaged amortizer is
        # trained on it) at the d=5 grid the quick suite already uses.
        am_suite = suites_grid(True)[0] if (dataset or not quick) \
            else suites[0]
        amortized_section, amortized_acceptance = bench_amortized(
            quick, seeds, gp, am_suite, out=out)

    # headline aggregates + acceptance
    def agg(name):
        rs = [r["regret"] for r in sched_rows if r["scheduler"] == name]
        return round(float(np.mean(rs)), 5) if rs else None

    budgets_equal = all(
        a["epochs_spent"] == b["epochs_spent"]
        for a in sched_rows if a["scheduler"] == "sh-lkgp"
        for b in sched_rows if b["scheduler"] == "sh-rank"
        and (b["suite"], b["seed"]) == (a["suite"], a["seed"]))
    mean_regret = {s: agg(s) for s in
                   ("sh-lkgp", "sh-rank", "hyperband-lkgp", "freeze-thaw")}
    precond_ok = any(
        row[k] < row["cg_iters"]
        for row in precond_rows for k in row if k.endswith("_iters")
        and k != "cg_iters")
    acceptance = {
        "sh_budgets_equal": bool(budgets_equal),
        "sh_lkgp_beats_rank": bool(budgets_equal
                                   and mean_regret["sh-lkgp"] is not None
                                   and mean_regret["sh-lkgp"]
                                   < mean_regret["sh-rank"]),
        "precond_reduces_cg_iters": bool(precond_ok),
        **amortized_acceptance,
    }
    out(f"# mean regret: {mean_regret}")
    out(f"# acceptance: {acceptance}")

    payload = {
        "meta": {
            "jax_backend": jax.default_backend(),
            "jax_version": jax.__version__,
            "platform": platform.platform(),
            "quick": quick, "seeds": seeds,
            "dataset": dataset_id,
            "gp": {"lbfgs_iters": gp.lbfgs_iters,
                   "posterior_samples": gp.posterior_samples},
        },
        "schedulers": sched_rows,
        "mean_regret": mean_regret,
        "precond": precond_rows,
        "batched": batched_row,
        "acceptance": acceptance,
    }
    if amortized_section is not None:
        payload["amortized"] = amortized_section
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    out(f"# wrote {out_path}")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smoke sizes for the CI gate")
    ap.add_argument("--out", default="BENCH_automl.json")
    ap.add_argument("--dataset", default=None,
                    help="curve source spec, e.g. "
                         "lcbench:tests/fixtures/lcbench_mini.npz "
                         "(default: the synthetic prior grid)")
    ap.add_argument("--amortized", action="store_true",
                    help="also run the amortized-hyper-parameter suite: "
                         "MLL-gap vs converged L-BFGS, per-round refit "
                         "wall-clock breakdown, and the regret race of "
                         "full-LBFGS vs amortized-oneshot vs "
                         "amortized+polish (adds gated acceptance "
                         "booleans)")
    args = ap.parse_args()
    main(quick=args.quick, out_path=args.out, dataset=args.dataset,
         amortized=args.amortized)
