"""whisper-tiny [audio]: enc-dec, conv frontend stubbed (frame embeddings).

4L d_model=384 6H (kv=6 -> MHA) d_ff=1536 vocab=51865 [arXiv:2212.04356].
"4L" = 4 encoder + 4 decoder blocks (whisper-tiny). No RoPE: sinusoidal
encoder positions, learned decoder positions. GELU MLP with biases.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper_tiny", family="audio",
    num_layers=4, d_model=384, num_heads=6, num_kv_heads=6, head_dim=64,
    d_ff=1536, vocab_size=51865,
    enc_layers=4, enc_frames=1500,
    mlp_act="gelu", mlp_bias=True, use_rope=False,
)

SMOKE = ModelConfig(
    arch_id="whisper_tiny", family="audio",
    num_layers=2, d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
    d_ff=128, vocab_size=509,
    enc_layers=2, enc_frames=24,
    mlp_act="gelu", mlp_bias=True, use_rope=False,
    dtype_act="float32", dtype_param="float32", remat=False,
)
