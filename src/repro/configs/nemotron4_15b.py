"""nemotron-4-15b [dense]: GQA, squared-ReLU MLP (no gating).

32L d_model=6144 48H (GQA kv=8) d_ff=24576 vocab=256000 [arXiv:2402.16819].
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="nemotron4_15b", family="dense",
    num_layers=32, d_model=6144, num_heads=48, num_kv_heads=8, head_dim=128,
    d_ff=24576, vocab_size=256_000,
    mlp_act="relu2",
)

SMOKE = ModelConfig(
    arch_id="nemotron4_15b", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=256, vocab_size=271,
    mlp_act="relu2",
    dtype_act="float32", dtype_param="float32", remat=False,
)
