"""stablelm-12b [dense]: 40L d_model=5120 32H (GQA kv=8) d_ff=13824
vocab=100352 [hf:stabilityai/stablelm-2-12b family]. SwiGLU, RoPE."""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="stablelm_12b", family="dense",
    num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8, head_dim=160,
    d_ff=13824, vocab_size=100_352,
)

SMOKE = ModelConfig(
    arch_id="stablelm_12b", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=269,
    dtype_act="float32", dtype_param="float32", remat=False,
)
