"""qwen2-72b [dense]: GQA with QKV bias.

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064 [arXiv:2407.10671].
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2_72b", family="dense",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=29568, vocab_size=152_064,
    qkv_bias=True, rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    arch_id="qwen2_72b", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=281,
    qkv_bias=True,
    dtype_act="float32", dtype_param="float32", remat=False,
)
