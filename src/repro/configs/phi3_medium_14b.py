"""phi3-medium-14b [dense]: RoPE SwiGLU GQA.

40L d_model=5120 40H (GQA kv=10) d_ff=17920 vocab=100352 [arXiv:2404.14219].
Note: 40 query heads do not divide the 16-way tensor axis of the production
mesh; projections shard on the fused (heads*head_dim)=5120 dim instead (see
DESIGN.md / EXPERIMENTS.md Perf notes).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="phi3_medium_14b", family="dense",
    num_layers=40, d_model=5120, num_heads=40, num_kv_heads=10, head_dim=128,
    d_ff=17920, vocab_size=100_352,
)

SMOKE = ModelConfig(
    arch_id="phi3_medium_14b", family="dense",
    num_layers=2, d_model=60, num_heads=6, num_kv_heads=3, head_dim=10,
    d_ff=112, vocab_size=277,
    dtype_act="float32", dtype_param="float32", remat=False,
)
