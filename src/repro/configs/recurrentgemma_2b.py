"""recurrentgemma-2b [hybrid]: RG-LRU + local attention, pattern 1 attn : 2 rec.

26L d_model=2560 10H MQA (kv=1) d_ff=7680 vocab=256000 [arXiv:2402.19427; hf].
Griffin details: lru_width=2560, window=2048, GeGLU MLP, embeddings scaled by
sqrt(d_model), final logit soft-cap 30.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="recurrentgemma_2b", family="hybrid",
    num_layers=26, d_model=2560, num_heads=10, num_kv_heads=1, head_dim=256,
    d_ff=7680, vocab_size=256_000,
    rnn_width=2560, conv_width=4, window=2048,
    block_pattern=("rec", "rec", "attn"),
    mlp_act="geglu", scale_embed=True, final_logit_cap=30.0,
)

SMOKE = ModelConfig(
    arch_id="recurrentgemma_2b", family="hybrid",
    num_layers=5, d_model=64, num_heads=2, num_kv_heads=1, head_dim=32,
    d_ff=128, vocab_size=251,
    rnn_width=64, conv_width=4, window=8,
    block_pattern=("rec", "rec", "attn"),
    mlp_act="geglu", scale_embed=True, final_logit_cap=30.0,
    dtype_act="float32", dtype_param="float32", remat=False,
)
