"""rwkv6-1.6b "Finch" [ssm]: attention-free, data-dependent decay.

24L d_model=2048 d_ff=7168 vocab=65536, head_size=64 (32 wkv heads)
[arXiv:2404.05892].
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="rwkv6_1b6", family="ssm",
    num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32, head_dim=64,
    d_ff=7168, vocab_size=65_536,
    rwkv_head_size=64, use_rope=False,
    rwkv_chunk=16,  # chunk-parallel wkv (§Perf; exact, MXU-friendly)
)

SMOKE = ModelConfig(
    arch_id="rwkv6_1b6", family="ssm",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=293,
    rwkv_head_size=16, use_rope=False,
    dtype_act="float32", dtype_param="float32", remat=False,
)
