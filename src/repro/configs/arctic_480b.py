"""arctic-480b [moe]: 128 experts top-2 plus a parallel dense residual MLP.

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128e top-2
[hf:Snowflake/snowflake-arctic-base]. The published dense-MoE-hybrid places a
dense MLP residual in parallel with the MoE FFN; both use d_ff=4864 here.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="arctic_480b", family="moe",
    num_layers=35, d_model=7168, num_heads=56, num_kv_heads=8, head_dim=128,
    d_ff=4864, vocab_size=32_000,
    moe=True, num_experts=128, moe_top_k=2, moe_d_ff=4864,
    moe_dense_residual=True, capacity_factor=1.25,
)

SMOKE = ModelConfig(
    arch_id="arctic_480b", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=96, vocab_size=257,
    moe=True, num_experts=8, moe_top_k=2, moe_d_ff=96,
    moe_dense_residual=True, capacity_factor=1.25, num_moe_groups=1,
    dtype_act="float32", dtype_param="float32", remat=False,
)
