"""qwen3-moe-235b-a22b [moe]: 128 experts top-8, QK-norm, GQA kv=4.

94L d_model=4096 64H (GQA kv=4) d_ff=1536 (per-expert) vocab=151936
[hf:Qwen/Qwen3-30B-A3B family scaled]. Qwen3 uses head_dim=128 (decoupled
from d_model/num_heads) and per-head RMS QK-norm; top-k probabilities are
renormalised.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen3_moe_235b", family="moe",
    num_layers=94, d_model=4096, num_heads=64, num_kv_heads=4, head_dim=128,
    d_ff=1536, vocab_size=151_936,
    qk_norm=True, rope_theta=1_000_000.0,
    moe=True, num_experts=128, moe_top_k=8, moe_d_ff=1536,
    moe_renormalize=True, capacity_factor=1.25,
)

SMOKE = ModelConfig(
    arch_id="qwen3_moe_235b", family="moe",
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=96, vocab_size=263,
    qk_norm=True,
    moe=True, num_experts=8, moe_top_k=4, moe_d_ff=96,
    moe_renormalize=True, capacity_factor=1.25, num_moe_groups=1,
    dtype_act="float32", dtype_param="float32", remat=False,
)
