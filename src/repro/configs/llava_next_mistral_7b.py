"""llava-next-mistral-7b [vlm]: Mistral-7B backbone + anyres patch prefix.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000
[hf:llava-hf/llava-v1.6-mistral-7b-hf]. The vision tower is a STUB per the
assignment: input_specs() provides 2880 precomputed patch embeddings (anyres
tiling: 4 tiles + base image, 576 patches each) consumed as a prefix.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="llava_next_mistral_7b", family="vlm",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=32_000,
    rope_theta=1_000_000.0, num_patch_tokens=2880,
)

SMOKE = ModelConfig(
    arch_id="llava_next_mistral_7b", family="vlm",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=283,
    num_patch_tokens=12,
    dtype_act="float32", dtype_param="float32", remat=False,
)
