"""Published architecture configs + reduced smoke variants."""
from .base import (ARCH_IDS, SHAPES, ModelConfig, ShapeSpec, get_config,
                   get_smoke_config, shape_applicable)

__all__ = ["ARCH_IDS", "SHAPES", "ModelConfig", "ShapeSpec", "get_config",
           "get_smoke_config", "shape_applicable"]
