"""Architecture config dataclass, input-shape sets, and the registry.

Every assigned architecture gets a module in this package defining CONFIG
(the exact published shape) and SMOKE (a reduced same-family variant for CPU
tests). ``get_config(arch_id)`` / ``get_smoke_config(arch_id)`` look them up;
``SHAPES`` defines the four assigned input-shape cells for LM-family archs.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass
from typing import Any

import jax.numpy as jnp

__all__ = ["ModelConfig", "ShapeSpec", "SHAPES", "ARCH_IDS", "get_config",
           "get_smoke_config", "shape_applicable"]


@dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                    # dense | moe | encdec | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads

    # MLP / attention variants
    mlp_act: str = "swiglu"        # swiglu | geglu | gelu | relu2
    mlp_bias: bool = False
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    use_rope: bool = True
    scale_embed: bool = False
    window: int | None = None      # uniform local-attention window
    layer_windows: tuple | None = None  # per-layer window pattern (cycled)
    final_logit_cap: float | None = None
    norm_eps: float = 1e-6

    # MoE
    moe: bool = False
    num_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    moe_dense_residual: bool = False
    moe_renormalize: bool = True
    capacity_factor: float = 1.25
    num_moe_groups: int = 16       # = data-parallel shard count on the prod mesh

    # encoder-decoder (whisper)
    enc_layers: int = 0
    enc_frames: int = 1500
    enc_pos: str = "sinusoidal"

    # hybrid recurrent (recurrentgemma) / ssm (rwkv6)
    rnn_width: int = 0             # RG-LRU lru width
    conv_width: int = 4
    block_pattern: tuple = ()      # e.g. ("rec", "rec", "attn")
    rwkv_head_size: int = 64
    rwkv_chunk: int = 0            # 0 = sequential scan; >0 = chunk-parallel

    # VLM
    num_patch_tokens: int = 0

    # numerics / execution
    dtype_act: Any = jnp.bfloat16
    dtype_param: Any = jnp.bfloat16
    remat: bool = True
    q_chunk: int = 512
    kv_chunk: int = 1024
    loss_chunk: int = 512
    scan_layers: bool = True

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def param_count(self) -> int:
        """Analytic parameter count (embeddings + layers), used for roofline."""
        from ..models.registry import count_params
        return count_params(self)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS = [
    "whisper_tiny",
    "recurrentgemma_2b",
    "arctic_480b",
    "qwen3_moe_235b",
    "stablelm_12b",
    "nemotron4_15b",
    "phi3_medium_14b",
    "qwen2_72b",
    "llava_next_mistral_7b",
    "rwkv6_1b6",
]

# Sub-quadratic archs that can serve a 500k-token context (SSM / hybrid with
# bounded attention state). Pure full-attention archs skip long_500k — see
# DESIGN.md §Arch-applicability.
_LONG_CONTEXT_OK = {"rwkv6_1b6", "recurrentgemma_2b"}


def shape_applicable(arch_id: str, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return arch_id in _LONG_CONTEXT_OK
    return True


def _module(arch_id: str):
    return importlib.import_module(f"repro.configs.{arch_id}")


def get_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).SMOKE
