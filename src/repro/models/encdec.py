"""Whisper-style encoder-decoder transformer (whisper-tiny backbone).

The audio conv frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings (B, frames, D) — the output of the
two-conv mel frontend. Encoder: bidirectional MHA + GELU MLP, sinusoidal
positions, pre-LN. Decoder: causal self-attention + cross-attention over the
encoder output, learned positions, tied embedding head.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import attention, chunked_ce_loss, decode_attention, layer_norm, mlp, mlp_params

__all__ = ["encdec_param_table", "encdec_loss", "encdec_prefill",
           "encdec_decode_step", "init_encdec_cache", "EncDecCache"]


class EncDecCache(NamedTuple):
    k: jnp.ndarray        # (L, B, T, H, Dh) decoder self-attn K
    v: jnp.ndarray
    xk: jnp.ndarray       # (L, B, F, H, Dh) cross-attn K (static)
    xv: jnp.ndarray
    length: jnp.ndarray


def _mha_table(cfg, prefix, kv_bias=True):
    D, H, Dh = cfg.d_model, cfg.num_heads, cfg.head_dim
    t = {
        f"{prefix}wq": ((D, H * Dh), ("embed", "heads_fused"), D),
        f"{prefix}bq": ((H * Dh,), ("heads_fused",), None),
        f"{prefix}wk": ((D, H * Dh), ("embed", "heads_fused"), D),
        f"{prefix}wv": ((D, H * Dh), ("embed", "heads_fused"), D),
        f"{prefix}bv": ((H * Dh,), ("heads_fused",), None),
        f"{prefix}wo": ((H * Dh, D), ("heads_fused", "embed"), H * Dh),
        f"{prefix}bo": ((D,), ("embed",), None),
    }
    return t


def _ln_table(cfg, name):
    return {f"{name}": ((cfg.d_model,), ("embed",), None),
            f"{name}_b": ((cfg.d_model,), ("embed",), None)}


def encdec_layer_table(cfg, cross: bool):
    t = {}
    t.update(_ln_table(cfg, "ln1"))
    t.update(_mha_table(cfg, "attn/"))
    if cross:
        t.update(_ln_table(cfg, "lnx"))
        t.update(_mha_table(cfg, "xattn/"))
    t.update(_ln_table(cfg, "ln2"))
    for k, v in mlp_params("gelu", cfg.d_model, cfg.d_ff, bias=True).items():
        t[f"mlp/{k}"] = v
    return t


def encdec_param_table(cfg):
    table = {
        "embed": ((cfg.vocab_size, cfg.d_model), ("vocab", "embed"), None),
        "dec_pos": ((cfg.max_dec_len if hasattr(cfg, "max_dec_len") else 32768,
                     cfg.d_model), (None, "embed"), None),
        "enc_ln": ((cfg.d_model,), ("embed",), None),
        "enc_ln_b": ((cfg.d_model,), ("embed",), None),
        "dec_ln": ((cfg.d_model,), ("embed",), None),
        "dec_ln_b": ((cfg.d_model,), ("embed",), None),
    }
    for k, v in encdec_layer_table(cfg, cross=False).items():
        shape, logical, fan = v
        table[f"enc_layers/{k}"] = ((cfg.enc_layers, *shape),
                                    ("layers", *logical), fan)
    for k, v in encdec_layer_table(cfg, cross=True).items():
        shape, logical, fan = v
        table[f"dec_layers/{k}"] = ((cfg.num_layers, *shape),
                                    ("layers", *logical), fan)
    return table


def _sinusoid(length, d, dtype):
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10_000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], -1).astype(dtype)


def _mha(x, kv_src, p, cfg, causal):
    B, S, _ = x.shape
    H, Dh = cfg.num_heads, cfg.head_dim
    q = (jnp.einsum("bsd,dh->bsh", x, p["wq"]) + p["bq"]).reshape(B, S, H, Dh)
    k = jnp.einsum("bsd,dh->bsh", kv_src, p["wk"]).reshape(B, -1, H, Dh)
    v = (jnp.einsum("bsd,dh->bsh", kv_src, p["wv"]) + p["bv"]).reshape(B, -1, H, Dh)
    a = attention(q, k, v, causal=causal, q_chunk=cfg.q_chunk,
                  kv_chunk=cfg.kv_chunk)
    return jnp.einsum("bsh,hd->bsd", a.reshape(B, S, -1), p["wo"]) + p["bo"]


def _enc_layer(x, lp, cfg):
    h = layer_norm(x, 1.0 + lp["ln1"], lp["ln1_b"])
    x = x + _mha(h, h, lp["attn"], cfg, causal=False)
    h = layer_norm(x, 1.0 + lp["ln2"], lp["ln2_b"])
    return x + mlp(h, lp["mlp"], "gelu")


def _dec_layer(x, enc, lp, cfg):
    h = layer_norm(x, 1.0 + lp["ln1"], lp["ln1_b"])
    x = x + _mha(h, h, lp["attn"], cfg, causal=True)
    h = layer_norm(x, 1.0 + lp["lnx"], lp["lnx_b"])
    x = x + _mha(h, enc, lp["xattn"], cfg, causal=False)
    h = layer_norm(x, 1.0 + lp["ln2"], lp["ln2_b"])
    return x + mlp(h, lp["mlp"], "gelu")


def encode(params, frames, cfg, constrain=lambda t, n: t):
    """frames: (B, F, D) precomputed frontend embeddings."""
    x = frames.astype(cfg.dtype_act) + _sinusoid(frames.shape[1], cfg.d_model,
                                                 cfg.dtype_act)[None]
    x = constrain(x, (("batch",), None, "embed"))

    def body(h, lp):
        return _enc_layer(h, lp, cfg), None

    scan_body = jax.checkpoint(body, prevent_cse=False) if cfg.remat else body
    x, _ = jax.lax.scan(scan_body, x, params["enc_layers"])
    return layer_norm(x, 1.0 + params["enc_ln"], params["enc_ln_b"])


def decode_train(params, enc, tokens, cfg, constrain=lambda t, n: t):
    x = params["embed"].astype(cfg.dtype_act)[tokens]
    x = x + params["dec_pos"][: x.shape[1]].astype(x.dtype)[None]
    x = constrain(x, (("batch",), None, "embed"))

    def body(h, lp):
        return _dec_layer(h, enc, lp, cfg), None

    scan_body = jax.checkpoint(body, prevent_cse=False) if cfg.remat else body
    x, _ = jax.lax.scan(scan_body, x, params["dec_layers"])
    return layer_norm(x, 1.0 + params["dec_ln"], params["dec_ln_b"])


def encdec_loss(params, batch, cfg, constrain=lambda t, n: t):
    enc = encode(params, batch["frames"], cfg, constrain)
    x = decode_train(params, enc, batch["tokens"], cfg, constrain)
    return chunked_ce_loss(x, params["embed"].astype(cfg.dtype_act),
                           batch["labels"], chunk=cfg.loss_chunk)


def init_encdec_cache(cfg, batch, max_len, dtype):
    L, H, Dh, F = cfg.num_layers, cfg.num_heads, cfg.head_dim, cfg.enc_frames
    return EncDecCache(
        k=jnp.zeros((L, batch, max_len, H, Dh), dtype),
        v=jnp.zeros((L, batch, max_len, H, Dh), dtype),
        xk=jnp.zeros((L, batch, F, H, Dh), dtype),
        xv=jnp.zeros((L, batch, F, H, Dh), dtype),
        length=jnp.int32(0),
    )


def encdec_prefill(params, batch, cfg, max_len, constrain=lambda t, n: t):
    """Encoder pass + decoder prompt pass; returns (last logits, cache)."""
    enc = encode(params, batch["frames"], cfg, constrain)
    tokens = batch["tokens"]
    B, S = tokens.shape
    H, Dh = cfg.num_heads, cfg.head_dim
    x = params["embed"].astype(cfg.dtype_act)[tokens]
    x = x + params["dec_pos"][:S].astype(x.dtype)[None]

    def body(h, lp):
        hn = layer_norm(h, 1.0 + lp["ln1"], lp["ln1_b"])
        k = jnp.einsum("bsd,dh->bsh", hn, lp["attn"]["wk"]).reshape(B, S, H, Dh)
        v = (jnp.einsum("bsd,dh->bsh", hn, lp["attn"]["wv"])
             + lp["attn"]["bv"]).reshape(B, S, H, Dh)
        xk = jnp.einsum("bsd,dh->bsh", enc, lp["xattn"]["wk"]).reshape(
            B, -1, H, Dh)
        xv = (jnp.einsum("bsd,dh->bsh", enc, lp["xattn"]["wv"])
              + lp["xattn"]["bv"]).reshape(B, -1, H, Dh)
        h = _dec_layer(h, enc, lp, cfg)
        return h, (k, v, xk, xv)

    scan_body = jax.checkpoint(body, prevent_cse=False) if cfg.remat else body
    x, (ks, vs, xks, xvs) = jax.lax.scan(scan_body, x, params["dec_layers"])
    x = layer_norm(x, 1.0 + params["dec_ln"], params["dec_ln_b"])
    logits = jnp.einsum("bd,vd->bv", x[:, -1], params["embed"].astype(x.dtype))

    cache = init_encdec_cache(cfg, B, max_len, cfg.dtype_act)
    cache = EncDecCache(
        k=jax.lax.dynamic_update_slice(cache.k, ks.astype(cache.k.dtype),
                                       (0, 0, 0, 0, 0)),
        v=jax.lax.dynamic_update_slice(cache.v, vs.astype(cache.v.dtype),
                                       (0, 0, 0, 0, 0)),
        xk=xks.astype(cache.xk.dtype), xv=xvs.astype(cache.xv.dtype),
        length=jnp.int32(S),
    )
    return logits, cache


def encdec_decode_step(params, cache: EncDecCache, tokens, cfg,
                       constrain=lambda t, n: t):
    B = tokens.shape[0]
    H, Dh = cfg.num_heads, cfg.head_dim
    pos = cache.length
    x = params["embed"].astype(cfg.dtype_act)[tokens]
    x = x + jax.lax.dynamic_slice_in_dim(
        params["dec_pos"], pos, 1, 0).astype(x.dtype)[None]

    def body(h, inp):
        lp, ck, cv, xk, xv = inp
        hn = layer_norm(h, 1.0 + lp["ln1"], lp["ln1_b"])
        q = (jnp.einsum("bsd,dh->bsh", hn, lp["attn"]["wq"])
             + lp["attn"]["bq"]).reshape(B, 1, H, Dh)
        k = jnp.einsum("bsd,dh->bsh", hn, lp["attn"]["wk"]).reshape(B, 1, H, Dh)
        v = (jnp.einsum("bsd,dh->bsh", hn, lp["attn"]["wv"])
             + lp["attn"]["bv"]).reshape(B, 1, H, Dh)
        z = jnp.zeros((), pos.dtype)
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                          (z, pos, z, z))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                          (z, pos, z, z))
        a = decode_attention(q, ck, cv, pos + 1)
        h = h + (jnp.einsum("bsh,hd->bsd", a.reshape(B, 1, -1),
                            lp["attn"]["wo"]) + lp["attn"]["bo"])
        # cross attention against the static encoder cache
        hn = layer_norm(h, 1.0 + lp["lnx"], lp["lnx_b"])
        q = (jnp.einsum("bsd,dh->bsh", hn, lp["xattn"]["wq"])
             + lp["xattn"]["bq"]).reshape(B, 1, H, Dh)
        a = decode_attention(q, xk, xv, xk.shape[1])
        h = h + (jnp.einsum("bsh,hd->bsd", a.reshape(B, 1, -1),
                            lp["xattn"]["wo"]) + lp["xattn"]["bo"])
        hn = layer_norm(h, 1.0 + lp["ln2"], lp["ln2_b"])
        h = h + mlp(hn, lp["mlp"], "gelu")
        return h, (ck, cv)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["dec_layers"], cache.k, cache.v, cache.xk, cache.xv))
    x = layer_norm(x, 1.0 + params["dec_ln"], params["dec_ln_b"])
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
    new_cache = EncDecCache(k=ks, v=vs, xk=cache.xk, xv=cache.xv,
                            length=cache.length + 1)
    return logits[:, 0], new_cache
