"""Model zoo registry: build any assigned architecture behind one interface.

``build_model(cfg)`` returns a ``Model`` with functional endpoints:
    init(key)                      -> params
    loss(params, batch)            -> scalar        (train shapes)
    prefill(params, batch)         -> (logits, cache)
    decode_step(params, cache, tk) -> (logits, cache)
    init_cache(batch, max_len)     -> cache pytree
plus the parameter table / logical-axis tree used by the sharding rules.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from . import encdec, griffin, rwkv, transformer

__all__ = ["Model", "build_model", "count_params", "active_params",
           "make_input_specs"]


class Model(NamedTuple):
    cfg: Any
    param_table: dict
    logical: dict
    init: Callable
    loss: Callable
    prefill: Callable
    decode_step: Callable
    init_cache: Callable


def _wrap(fn, cfg):
    return functools.partial(fn, cfg=cfg)


def build_model(cfg) -> Model:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        table = transformer.decoder_param_table(cfg)
        return Model(
            cfg=cfg, param_table=table,
            logical=transformer.table_logical(table),
            init=lambda key, dtype=cfg.dtype_param: transformer.build_params(
                key, table, dtype),
            loss=lambda p, b, constrain=_ident: transformer.decoder_loss(
                p, b, cfg, constrain),
            prefill=lambda p, b, max_len, constrain=_ident:
                transformer.decoder_prefill(p, b, cfg, max_len, constrain),
            decode_step=lambda p, c, t, constrain=_ident:
                transformer.decoder_decode_step(p, c, t, cfg, constrain),
            init_cache=lambda batch, max_len, dtype=cfg.dtype_act:
                transformer.init_decoder_cache(cfg, batch, max_len, dtype),
        )
    if fam in ("encdec", "audio"):
        table = encdec.encdec_param_table(cfg)
        return Model(
            cfg=cfg, param_table=table,
            logical=transformer.table_logical(table),
            init=lambda key, dtype=cfg.dtype_param: transformer.build_params(
                key, table, dtype),
            loss=lambda p, b, constrain=_ident: encdec.encdec_loss(
                p, b, cfg, constrain),
            prefill=lambda p, b, max_len, constrain=_ident:
                encdec.encdec_prefill(p, b, cfg, max_len, constrain),
            decode_step=lambda p, c, t, constrain=_ident:
                encdec.encdec_decode_step(p, c, t, cfg, constrain),
            init_cache=lambda batch, max_len, dtype=cfg.dtype_act:
                encdec.init_encdec_cache(cfg, batch, max_len, dtype),
        )
    if fam == "hybrid":
        table = griffin.griffin_param_table(cfg)
        return Model(
            cfg=cfg, param_table=table,
            logical=transformer.table_logical(table),
            init=lambda key, dtype=cfg.dtype_param: transformer.build_params(
                key, table, dtype),
            loss=lambda p, b, constrain=_ident: griffin.griffin_loss(
                p, b, cfg, constrain),
            prefill=lambda p, b, max_len=None, constrain=_ident:
                griffin.griffin_prefill(p, b, cfg, constrain),
            decode_step=lambda p, c, t, constrain=_ident:
                griffin.griffin_decode_step(p, c, t, cfg, constrain),
            init_cache=lambda batch, max_len=None, dtype=cfg.dtype_act:
                griffin.init_griffin_cache(cfg, batch, dtype),
        )
    if fam == "ssm":
        table = rwkv.rwkv_param_table(cfg)
        return Model(
            cfg=cfg, param_table=table,
            logical=transformer.table_logical(table),
            init=lambda key, dtype=cfg.dtype_param: transformer.build_params(
                key, table, dtype),
            loss=lambda p, b, constrain=_ident: rwkv.rwkv_loss(
                p, b, cfg, constrain),
            prefill=lambda p, b, max_len=None, constrain=_ident:
                rwkv.rwkv_prefill(p, b, cfg, constrain),
            decode_step=lambda p, c, t, constrain=_ident:
                rwkv.rwkv_decode_step(p, c, t, cfg, constrain),
            init_cache=lambda batch, max_len=None, dtype=cfg.dtype_act:
                rwkv.init_rwkv_cache(cfg, batch, dtype),
        )
    raise ValueError(f"unknown family: {fam}")


def _ident(t, names):
    return t


def count_params(cfg) -> int:
    """Total parameter count from the table (exact)."""
    table = build_model(cfg).param_table
    return int(sum(math.prod(shape) for shape, _, _ in table.values()))


def active_params(cfg) -> int:
    """Active-per-token parameters (MoE: top_k of num_experts)."""
    total = count_params(cfg)
    if not cfg.moe:
        return total
    table = build_model(cfg).param_table
    expert = sum(math.prod(shape) for name, (shape, _, _) in table.items()
                 if "/moe/w" in name)
    return int(total - expert + expert * cfg.moe_top_k / cfg.num_experts)


def make_input_specs(cfg, shape, dtype_tokens=jnp.int32):
    """ShapeDtypeStructs for a batch of the given ShapeSpec (no allocation).

    Modality frontends are stubs: whisper gets precomputed frame embeddings,
    llava gets precomputed patch embeddings (anyres tiling), per assignment.
    """
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if cfg.family in ("encdec", "audio"):
        specs = {"frames": sds((B, cfg.enc_frames, cfg.d_model), cfg.dtype_act)}
        if shape.kind == "train":
            specs["tokens"] = sds((B, S), dtype_tokens)
            specs["labels"] = sds((B, S), dtype_tokens)
        elif shape.kind == "prefill":
            specs["tokens"] = sds((B, S), dtype_tokens)
        else:  # decode: one new token; cache handled by the caller
            specs = {"tokens": sds((B, 1), dtype_tokens)}
        return specs
    if cfg.family == "vlm" and shape.kind != "decode":
        P = cfg.num_patch_tokens
        text = S - P
        specs = {"prefix_embeds": sds((B, P, cfg.d_model), cfg.dtype_act),
                 "tokens": sds((B, text), dtype_tokens)}
        if shape.kind == "train":
            specs["labels"] = sds((B, text), dtype_tokens)
        return specs
    if shape.kind == "train":
        return {"tokens": sds((B, S), dtype_tokens),
                "labels": sds((B, S), dtype_tokens)}
    if shape.kind == "prefill":
        return {"tokens": sds((B, S), dtype_tokens)}
    return {"tokens": sds((B, 1), dtype_tokens)}
