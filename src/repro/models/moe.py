"""Mixture-of-Experts FFN with capacity-based sort-free dispatch.

Design (TPU-native adaptation; see DESIGN.md):
  * tokens are organised into G groups (G = data-parallel shard count) so all
    dispatch bookkeeping (rank-within-expert via cumsum) is local to a group
    — no cross-shard prefix sums;
  * expert buffers are (G, E, C, D) with C = ceil(Tg * top_k * cf / E): the
    gather/scatter dispatch costs zero matmul FLOPs, unlike one-hot dispatch
    einsums whose (tokens, E, C) one-hot tensors are infeasible at top-8 /
    128 experts;
  * experts shard over the 'model' mesh axis, groups over 'data'; the combine
    is a scatter-add followed by the usual TP psum (inserted by SPMD).

Dropping: tokens beyond an expert's capacity C are dropped (standard
capacity-factor semantics). Decode-sized batches clamp C to the group size,
which makes dispatch provably dropless there.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from ..compat import shard_map

__all__ = ["moe_param_table", "moe_ffn", "moe_ffn_sharded", "moe_capacity"]


def moe_capacity(tokens_per_group: int, num_experts: int, top_k: int,
                 capacity_factor: float) -> int:
    c = math.ceil(tokens_per_group * top_k * capacity_factor / num_experts)
    c = max(c, min(8, tokens_per_group))
    return min(c, tokens_per_group)


def moe_param_table(cfg) -> dict[str, tuple]:
    """name -> (shape, logical_axes, fan_in). Gated (swiglu) experts."""
    E, D, F = cfg.num_experts, cfg.d_model, cfg.moe_d_ff
    return {
        "router": ((D, E), ("embed", "experts_router"), D),
        "wi_0": ((E, D, F), ("experts", "embed", "mlp"), D),
        "wi_1": ((E, D, F), ("experts", "embed", "mlp"), D),
        "wo": ((E, F, D), ("experts", "mlp", "embed"), F),
    }


def moe_ffn(x: jnp.ndarray, params: dict[str, Any], cfg, num_groups: int,
            constrain=lambda t, names: t) -> jnp.ndarray:
    """x: (B, S, D) -> (B, S, D).

    ``constrain(tensor, logical_axes)`` applies a mesh sharding constraint
    (identity in single-device tests).
    """
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.moe_top_k
    T = B * S
    G = max(1, min(num_groups, T))
    while T % G:
        G -= 1
    Tg = T // G
    C = moe_capacity(Tg, E, K, cfg.capacity_factor)
    xg = x.reshape(G, Tg, D)
    xg = constrain(xg, ("moe_groups", None, "embed"))

    # --- routing -----------------------------------------------------------
    logits = jnp.einsum("gtd,de->gte", xg, params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)            # (G, Tg, K)
    if getattr(cfg, "moe_renormalize", True):
        top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    # --- rank of each (token, k) within its expert --------------------------
    # flat (G, Tg*K) assignment order is token-major: earlier tokens win slots.
    flat_e = top_e.reshape(G, Tg * K)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.float32)      # (G, Tg*K, E)
    onehot = constrain(onehot, ("moe_groups", None, None))
    pos_in_e = jnp.cumsum(onehot, axis=1) - onehot             # rank, 0-based
    slot = jnp.sum(pos_in_e * onehot, axis=-1).astype(jnp.int32)  # (G, Tg*K)
    slot = slot.reshape(G, Tg, K)
    keep = (slot < C)
    weight = top_p * keep.astype(top_p.dtype)                  # dropped -> 0

    # --- dispatch: scatter tokens into (G, E, C, D) buffers -----------------
    buf = jnp.zeros((G, E, C, D), x.dtype)
    gidx = jnp.arange(G)[:, None]
    for j in range(K):
        src = jnp.where(keep[:, :, j, None], xg, 0).astype(x.dtype)
        buf = buf.at[gidx, top_e[:, :, j], jnp.minimum(slot[:, :, j], C - 1)].add(
            src, mode="drop")
    buf = constrain(buf, ("moe_groups", "experts", None, "embed"))

    # --- expert computation (gated SwiGLU) ----------------------------------
    g = jnp.einsum("gecd,edf->gecf", buf, params["wi_0"])
    u = jnp.einsum("gecd,edf->gecf", buf, params["wi_1"])
    h = (jax.nn.silu(g.astype(jnp.float32)) * u.astype(jnp.float32)).astype(x.dtype)
    out_buf = jnp.einsum("gecf,efd->gecd", h, params["wo"])
    out_buf = constrain(out_buf, ("moe_groups", "experts", None, "embed"))

    # --- combine: gather each token's k slots, weight, and sum --------------
    out = jnp.zeros((G, Tg, D), jnp.float32)
    for j in range(K):
        gathered = out_buf[gidx, top_e[:, :, j],
                           jnp.minimum(slot[:, :, j], C - 1)]
        out = out + weight[:, :, j, None] * gathered.astype(jnp.float32)
    out = constrain(out.astype(x.dtype), ("moe_groups", None, "embed"))
    return out.reshape(B, S, D)


# --------------------------------------------------------------------------
# Expert-parallel shard_map path (§Perf hillclimb 1)
# --------------------------------------------------------------------------
def _local_moe(x_loc, router, wi0, wi1, wo, cfg, e_lo_size, axis="model"):
    """Per-shard body: all local tokens x this shard's experts, psum combine.

    x_loc: (B_loc, S, D) — this data-shard's tokens (replicated over the
    model axis). wi0/wi1/wo: (E_loc, ...) — this model-shard's experts.
    Every rank routes against the FULL router (E logits), keeps only the
    assignments that land in its local expert range, computes them at
    capacity C, and the final psum over the model axis sums partial outputs
    (dropped tokens and foreign-expert assignments contribute zeros).
    """
    B, S, D = x_loc.shape
    E, K = cfg.num_experts, cfg.moe_top_k
    e_rank = jax.lax.axis_index(axis)
    e_lo = e_rank * e_lo_size
    T = B * S
    C = moe_capacity(T, E, K, cfg.capacity_factor)
    xf = x_loc.reshape(T, D)

    logits = jnp.einsum("td,de->te", xf, router).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)                 # (T, K)
    if getattr(cfg, "moe_renormalize", True):
        top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    # Global slot ranks (shared across shards so capacity drops agree),
    # then restrict to local experts.
    flat_e = top_e.reshape(T * K)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.float32)  # (T*K, E)
    pos = jnp.cumsum(onehot, axis=0) - onehot
    slot = jnp.sum(pos * onehot, axis=-1).astype(jnp.int32).reshape(T, K)
    local = (top_e >= e_lo) & (top_e < e_lo + e_lo_size)
    keep = (slot < C) & local
    weight = (top_p * keep.astype(top_p.dtype)).astype(jnp.float32)
    e_idx = jnp.clip(top_e - e_lo, 0, e_lo_size - 1)
    s_idx = jnp.minimum(slot, C - 1)

    buf = jnp.zeros((e_lo_size, C, D), x_loc.dtype)
    for j in range(K):
        src = jnp.where(keep[:, j, None], xf, 0).astype(x_loc.dtype)
        buf = buf.at[e_idx[:, j], s_idx[:, j]].add(src, mode="drop")

    g = jnp.einsum("ecd,edf->ecf", buf, wi0)
    u = jnp.einsum("ecd,edf->ecf", buf, wi1)
    h = (jax.nn.silu(g.astype(jnp.float32)) * u.astype(jnp.float32)).astype(
        x_loc.dtype)
    out_buf = jnp.einsum("ecf,efd->ecd", h, wo)

    out = jnp.zeros((T, D), jnp.float32)
    for j in range(K):
        gathered = out_buf[e_idx[:, j], s_idx[:, j]]
        out = out + weight[:, j, None] * gathered.astype(jnp.float32)
    out = jax.lax.psum(out.astype(x_loc.dtype), axis)
    return out.reshape(B, S, D)


def moe_ffn_sharded(x, params, cfg, mesh) -> jnp.ndarray:
    """Expert-parallel MoE: tokens over data axes, experts over 'model'.

    vs the einsum path: per-device buffers are (E/tp, C_loc, D) (never the
    full expert grid), the dispatch bookkeeping is shard-local, and the only
    collective is one activation-sized psum over 'model' per layer — the
    same wire cost as a dense TP MLP.
    """
    tp = mesh.shape.get("model", 1)
    if cfg.num_experts % tp:
        raise ValueError("experts must divide the model axis")
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dp_ok = dp if x.shape[0] % math.prod(mesh.shape[a] for a in dp) == 0 \
        else ()
    xspec = P(dp_ok if dp_ok else None, None, None)

    fn = shard_map(
        lambda xl, r, a, b, c: _local_moe(xl, r, a, b, c, cfg,
                                          cfg.num_experts // tp),
        mesh=mesh,
        in_specs=(xspec, P(None, None), P("model", None, None),
                  P("model", None, None), P("model", None, None)),
        out_specs=xspec,
        check_vma=False,
    )
    return fn(x, params["router"], params["wi_0"], params["wi_1"],
              params["wo"])


def _local_moe_tokens_gathered(x_loc, router, wi0, wi1, wo, cfg, e_lo_size,
                               dp_axes, tp_axis="model"):
    """Decode-path body: all-gather the (tiny) token batch over the data
    axes and keep expert weights fully resident, sharded over BOTH mesh axes
    (E over 'model', F over 'data').

    Valid because every shard then holds ALL tokens: the partial expert
    outputs (partial over the F contraction AND over local experts) psum
    over both axes into the full combine; each shard slices its tokens back.
    Comm per layer = token bytes (KBs at decode) instead of weight bytes.
    """
    B, S, D = x_loc.shape
    E, K = cfg.num_experts, cfg.moe_top_k
    x_all = x_loc
    for ax in dp_axes:
        x_all = jax.lax.all_gather(x_all, ax, axis=0, tiled=True)
    T = x_all.shape[0] * S
    xf = x_all.reshape(T, D)
    e_rank = jax.lax.axis_index(tp_axis)
    e_lo = e_rank * e_lo_size
    C = moe_capacity(T, E, K, cfg.capacity_factor)

    logits = jnp.einsum("td,de->te", xf, router).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)
    if getattr(cfg, "moe_renormalize", True):
        top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)
    flat_e = top_e.reshape(T * K)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.float32)
    pos = jnp.cumsum(onehot, axis=0) - onehot
    slot = jnp.sum(pos * onehot, axis=-1).astype(jnp.int32).reshape(T, K)
    local = (top_e >= e_lo) & (top_e < e_lo + e_lo_size)
    keep = (slot < C) & local
    weight = (top_p * keep.astype(top_p.dtype)).astype(jnp.float32)
    e_idx = jnp.clip(top_e - e_lo, 0, e_lo_size - 1)
    s_idx = jnp.minimum(slot, C - 1)

    buf = jnp.zeros((e_lo_size, C, D), x_loc.dtype)
    for j in range(K):
        src = jnp.where(keep[:, j, None], xf, 0).astype(x_loc.dtype)
        buf = buf.at[e_idx[:, j], s_idx[:, j]].add(src, mode="drop")

    g = jnp.einsum("ecd,edf->ecf", buf, wi0)   # F already local slice
    u = jnp.einsum("ecd,edf->ecf", buf, wi1)
    h = (jax.nn.silu(g.astype(jnp.float32)) * u.astype(jnp.float32)).astype(
        x_loc.dtype)
    out_buf = jnp.einsum("ecf,efd->ecd", h, wo)  # partial over F

    out = jnp.zeros((T, D), jnp.float32)
    for j in range(K):
        gathered = out_buf[e_idx[:, j], s_idx[:, j]]
        out = out + weight[:, j, None] * gathered.astype(jnp.float32)
    for ax in (tp_axis, *dp_axes):
        out = jax.lax.psum(out, ax)
    out = out.astype(x_loc.dtype).reshape(x_all.shape)
    # slice this shard's tokens back out (last gather = outermost blocks)
    idx = jnp.int32(0)
    for ax in reversed(dp_axes):
        idx = idx * jax.lax.psum(1, ax) + jax.lax.axis_index(ax)
    return jax.lax.dynamic_slice_in_dim(out, idx * B, B, axis=0)


def moe_ffn_sharded_decode(x, params, cfg, mesh) -> jnp.ndarray:
    """Serve-time MoE for small token counts (decode): resident weights."""
    tp = mesh.shape.get("model", 1)
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape
               and x.shape[0] % mesh.shape[a] == 0)
    xspec = P(dp if dp else None, None, None)
    fn = shard_map(
        lambda xl, r, a, b, c: _local_moe_tokens_gathered(
            xl, r, a, b, c, cfg, cfg.num_experts // tp, dp),
        mesh=mesh,
        in_specs=(xspec, P(None, None), P("model", None, "data"),
                  P("model", None, "data"), P("model", "data", None)),
        out_specs=xspec,
        check_vma=False,
    )
    return fn(x, params["router"], params["wi_0"], params["wi_1"],
              params["wo"])
