"""RWKV-6 "Finch" (attention-free, data-dependent decay), pure JAX.

Time-mix (per head, head_size N = 64, H = D / N heads):
    S_t = diag(w_t) S_{t-1} + k_t v_t^T                (state: (H, N, N))
    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
with data-dependent decay w_t = exp(-exp(w_base + lora_w(x))) and
token-shift "ddlerp" mixing (low-rank adapters) for r/k/v/w/g, following
arXiv:2404.05892. Channel-mix uses squared-ReLU.

The training path uses a sequential lax.scan over time (exact; O(1) HLO).
A chunkwise-parallel variant is the documented perf hillclimb for the
compute-bound cells (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import chunked_ce_loss, layer_norm

__all__ = ["rwkv_param_table", "rwkv_loss", "rwkv_prefill",
           "rwkv_decode_step", "init_rwkv_cache", "RWKVCache"]

_MIX_KEYS = ("r", "k", "v", "w", "g")
_LORA = 32          # ddlerp low-rank dim
_LORA_W = 64        # decay lora dim


class RWKVCache(NamedTuple):
    state: jnp.ndarray    # (L, B, H, N, N) wkv state (fp32)
    x_tm: jnp.ndarray     # (L, B, D) last input of time-mix
    x_cm: jnp.ndarray     # (L, B, D) last input of channel-mix
    length: jnp.ndarray


def rwkv_layer_table(cfg):
    D, F = cfg.d_model, cfg.d_ff
    t = {
        "ln1": ((D,), ("embed",), None),
        "ln1_b": ((D,), ("embed",), None),
        "ln2": ((D,), ("embed",), None),
        "ln2_b": ((D,), ("embed",), None),
        # ddlerp mixing
        "tm/mu_x": ((D,), ("embed",), None),
        "tm/mu": ((5, D), (None, "embed"), None),
        "tm/lora_a": ((D, 5 * _LORA), ("embed", None), D),
        "tm/lora_b": ((5, _LORA, D), (None, None, "embed"), _LORA),
        # projections
        "tm/wr": ((D, D), ("embed", "heads_fused"), D),
        "tm/wk": ((D, D), ("embed", "heads_fused"), D),
        "tm/wv": ((D, D), ("embed", "heads_fused"), D),
        "tm/wg": ((D, D), ("embed", "heads_fused"), D),
        "tm/wo": ((D, D), ("heads_fused", "embed"), D),
        # decay + bonus
        "tm/w_base": ((D,), ("embed",), None),
        "tm/w_lora_a": ((D, _LORA_W), ("embed", None), D),
        "tm/w_lora_b": ((_LORA_W, D), (None, "embed"), _LORA_W),
        "tm/u": ((D,), ("embed",), None),
        # group-norm on heads after wkv
        "tm/gn": ((D,), ("embed",), None),
        "tm/gn_b": ((D,), ("embed",), None),
        # channel mix
        "cm/mu_k": ((D,), ("embed",), None),
        "cm/mu_r": ((D,), ("embed",), None),
        "cm/wk": ((D, F), ("embed", "mlp"), D),
        "cm/wv": ((F, D), ("mlp", "embed"), F),
        "cm/wr": ((D, D), ("embed", "embed_out"), D),
    }
    return t


def rwkv_param_table(cfg):
    table = {
        "embed": ((cfg.vocab_size, cfg.d_model), ("vocab", "embed"), None),
        "ln0": ((cfg.d_model,), ("embed",), None),
        "ln0_b": ((cfg.d_model,), ("embed",), None),
        "final_norm": ((cfg.d_model,), ("embed",), None),
        "final_norm_b": ((cfg.d_model,), ("embed",), None),
        "head": ((cfg.d_model, cfg.vocab_size), ("embed", "vocab"), cfg.d_model),
    }
    for k, v in rwkv_layer_table(cfg).items():
        shape, logical, fan = v
        table[f"layers/{k}"] = ((cfg.num_layers, *shape),
                                ("layers", *logical), fan)
    return table


# --------------------------------------------------------------------------
# time-mix
# --------------------------------------------------------------------------
def _ddlerp(x, x_prev, p):
    """Data-dependent lerp producing the 5 mixed inputs (r, k, v, w, g)."""
    xx = x_prev - x
    base = x + xx * p["mu_x"].astype(x.dtype)
    lora = jnp.tanh(jnp.einsum("bsd,dk->bsk", base, p["lora_a"]))
    lora = lora.reshape(*lora.shape[:-1], 5, _LORA)
    adj = jnp.einsum("bsik,ikd->bsid", lora, p["lora_b"])
    mix = p["mu"].astype(x.dtype)[None, None] + adj        # (B, S, 5, D)
    return [x + xx * mix[:, :, i, :] for i in range(5)]


def _decay(xw, p):
    lora = jnp.tanh(jnp.einsum("bsd,dk->bsk", xw, p["w_lora_a"]))
    ww = p["w_base"].astype(jnp.float32) + \
        jnp.einsum("bsk,kd->bsd", lora, p["w_lora_b"]).astype(jnp.float32)
    return jnp.exp(-jnp.exp(ww))  # (B, S, D) in (0, 1)


def _wkv_scan(r, k, v, w, u, H, N, state0=None):
    """Sequential wkv recurrence. r/k/v/w: (B, S, D); returns (B, S, D)."""
    B, S, D = r.shape
    rh = r.reshape(B, S, H, N).astype(jnp.float32)
    kh = k.reshape(B, S, H, N).astype(jnp.float32)
    vh = v.reshape(B, S, H, N).astype(jnp.float32)
    wh = w.reshape(B, S, H, N)
    uh = u.reshape(H, N).astype(jnp.float32)
    if state0 is None:
        state0 = jnp.zeros((B, H, N, N), jnp.float32)

    def step(S_, inp):
        rt, kt, vt, wt = inp  # (B, H, N) each
        kv = kt[..., :, None] * vt[..., None, :]          # (B, H, N, N)
        y = jnp.einsum("bhn,bhnm->bhm", rt, S_ + uh[None, :, :, None] * kv)
        S_new = wt[..., :, None] * S_ + kv
        return S_new, y

    xs = (jnp.moveaxis(rh, 1, 0), jnp.moveaxis(kh, 1, 0),
          jnp.moveaxis(vh, 1, 0), jnp.moveaxis(wh, 1, 0))
    state, ys = jax.lax.scan(step, state0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, D)
    return y, state


def _wkv_chunked(r, k, v, w, u, H, N, chunk, state0=None):
    """Chunk-parallel wkv (§Perf: the MXU-friendly form of the recurrence).

    Exact algebra: with per-step decay products A_t = prod_{u<=t} w_u
    (per channel), unrolling the recurrence inside a chunk of length c gives

        y_t = (r_t * A_{t-1})^T S_0                         [inter]
            + sum_{s<t} (sum_n r_t[n] k_s[n] e^{la_{t-1,n} - la_{s,n}}) v_s
            + (r_t * u)^T k_t v_t                           [bonus diag]
        S_c = diag(A_c) S_0 + sum_s diag(A_c / A_s) k_s v_s^T

    The pairwise decay exponents la_{t-1} - la_s are <= 0 for s <= t-1, so
    the (c, c, N) exp tensor is numerically stable (no 1/A blow-up), unlike
    the factored r~ = r*A / k^ = k/A form. Chunks turn 4096 sequential
    (B,H,N)x(B,H,N,M) outer-product steps into c^2-dense einsums.
    """
    B, S, D = r.shape
    c = chunk
    nc = S // c
    sh = (B, nc, c, H, N)
    rh = r.reshape(sh).astype(jnp.float32)
    kh = k.reshape(sh).astype(jnp.float32)
    vh = v.reshape(sh).astype(jnp.float32)
    # 1e-30: smallest clamp safely in f32 NORMAL range (1e-38 is subnormal
    # and flushed to zero on XLA CPU/TPU, which would put -inf into la)
    la = jnp.cumsum(jnp.log(jnp.maximum(
        w.reshape(sh).astype(jnp.float32), 1e-30)), axis=2)
    uh = u.reshape(H, N).astype(jnp.float32)
    if state0 is None:
        state0 = jnp.zeros((B, H, N, N), jnp.float32)

    # intra-chunk pairwise decay scores (strictly lower triangular)
    la_prev = jnp.concatenate([jnp.zeros_like(la[:, :, :1]), la[:, :, :-1]],
                              axis=2)                       # la_{t-1}
    pair = jnp.exp(jnp.clip(la_prev[:, :, :, None] - la[:, :, None, :, :],
                            -80.0, 0.0))                    # (B,nc,t,s,H,N)
    tri = jnp.tril(jnp.ones((c, c), bool), k=-1)
    scores = jnp.einsum("bgthn,bgshn,bgtshn->bghts", rh, kh, pair)
    scores = jnp.where(tri[None, None, None], scores, 0.0)
    diag = jnp.einsum("bgthn,hn,bgthn->bgth", rh, uh, kh)
    y_intra = jnp.einsum("bghts,bgshm->bgthm", scores, vh) \
        + diag[..., None] * vh

    # inter-chunk: scan over chunk states
    A_end = jnp.exp(la[:, :, -1])                           # (B,nc,H,N)
    kd = kh * jnp.exp(la[:, :, -1:, :, :] - la)             # k_s * A_c/A_s

    def chunk_step(S_, inp):
        r_t, la_p, kd_g, v_g, a_end = inp
        y_int = jnp.einsum("bthn,bhnm->bthm", r_t * jnp.exp(la_p), S_)
        S_new = a_end[:, :, :, None] * S_ + jnp.einsum(
            "bshn,bshm->bhnm", kd_g, v_g)
        return S_new, y_int

    xs = (jnp.moveaxis(rh, 1, 0), jnp.moveaxis(la_prev, 1, 0),
          jnp.moveaxis(kd, 1, 0), jnp.moveaxis(vh, 1, 0),
          jnp.moveaxis(A_end, 1, 0))
    state, y_inter = jax.lax.scan(chunk_step, state0, xs)
    y = y_intra + jnp.moveaxis(y_inter, 0, 1)
    return y.reshape(B, S, D), state


def _time_mix(x, x_prev, p, cfg, state0=None):
    H = cfg.d_model // cfg.rwkv_head_size
    N = cfg.rwkv_head_size
    xr, xk, xv, xw, xg = _ddlerp(x, x_prev, p)
    r = jnp.einsum("bsd,dh->bsh", xr, p["wr"])
    k = jnp.einsum("bsd,dh->bsh", xk, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", xv, p["wv"])
    g = jax.nn.silu(jnp.einsum("bsd,dh->bsh", xg, p["wg"]).astype(jnp.float32))
    w = _decay(xw, p)
    S = r.shape[1]
    chunk = getattr(cfg, "rwkv_chunk", 0)
    if chunk and S > chunk and S % chunk == 0:
        y, state = _wkv_chunked(r, k, v, w, p["u"], H, N, chunk, state0)
    else:
        y, state = _wkv_scan(r, k, v, w, p["u"], H, N, state0)
    # per-head group norm
    B, S, D = y.shape
    yh = y.reshape(B, S, H, N)
    mu = jnp.mean(yh, axis=-1, keepdims=True)
    var = jnp.var(yh, axis=-1, keepdims=True)
    yh = (yh - mu) * jax.lax.rsqrt(var + 64e-5)
    y = yh.reshape(B, S, D) * p["gn"].astype(jnp.float32) \
        + p["gn_b"].astype(jnp.float32)
    out = jnp.einsum("bsh,hd->bsd", (y * g).astype(x.dtype), p["wo"])
    return out, state


def _channel_mix(x, x_prev, p):
    xx = x_prev - x
    xk = x + xx * p["mu_k"].astype(x.dtype)
    xr = x + xx * p["mu_r"].astype(x.dtype)
    k = jnp.einsum("bsd,df->bsf", xk, p["wk"])
    k32 = jnp.maximum(k.astype(jnp.float32), 0.0)
    kv = jnp.einsum("bsf,fd->bsd", (k32 * k32).astype(x.dtype), p["wv"])
    r = jax.nn.sigmoid(
        jnp.einsum("bsd,de->bse", xr, p["wr"]).astype(jnp.float32))
    return (r * kv.astype(jnp.float32)).astype(x.dtype)


def _shift(x, last=None):
    """Token shift: x_prev[t] = x[t-1]; first uses ``last`` (or zeros)."""
    first = jnp.zeros_like(x[:, :1]) if last is None else last[:, None, :]
    return jnp.concatenate([first, x[:, :-1]], axis=1)


# --------------------------------------------------------------------------
# forward / loss / serving
# --------------------------------------------------------------------------
def rwkv_forward(params, tokens, cfg, constrain=lambda t, n: t):
    x = params["embed"].astype(cfg.dtype_act)[tokens]
    x = layer_norm(x, 1.0 + params["ln0"], params["ln0_b"])
    x = constrain(x, (("batch",), None, "embed"))

    def body(h, lp):
        hn = layer_norm(h, 1.0 + lp["ln1"], lp["ln1_b"])
        out, _ = _time_mix(hn, _shift(hn), lp["tm"], cfg)
        h = h + constrain(out, (("batch",), None, "embed"))
        hn = layer_norm(h, 1.0 + lp["ln2"], lp["ln2_b"])
        h = h + constrain(_channel_mix(hn, _shift(hn), lp["cm"]),
                          (("batch",), None, "embed"))
        return h, None

    scan_body = jax.checkpoint(body, prevent_cse=False) if cfg.remat else body
    x, _ = jax.lax.scan(scan_body, x, params["layers"])
    return layer_norm(x, 1.0 + params["final_norm"], params["final_norm_b"])


def rwkv_loss(params, batch, cfg, constrain=lambda t, n: t):
    x = rwkv_forward(params, batch["tokens"], cfg, constrain)
    return chunked_ce_loss(x, params["head"].T.astype(cfg.dtype_act),
                           batch["labels"], chunk=cfg.loss_chunk)


def init_rwkv_cache(cfg, batch, dtype):
    H = cfg.d_model // cfg.rwkv_head_size
    N = cfg.rwkv_head_size
    L, D = cfg.num_layers, cfg.d_model
    return RWKVCache(
        state=jnp.zeros((L, batch, H, N, N), jnp.float32),
        x_tm=jnp.zeros((L, batch, D), dtype),
        x_cm=jnp.zeros((L, batch, D), dtype),
        length=jnp.int32(0),
    )


def rwkv_decode_step(params, cache: RWKVCache, tokens, cfg,
                     constrain=lambda t, n: t):
    x = params["embed"].astype(cfg.dtype_act)[tokens]  # (B, 1, D)
    x = layer_norm(x, 1.0 + params["ln0"], params["ln0_b"])

    def body(h, inp):
        lp, st, xtm, xcm = inp
        hn = layer_norm(h, 1.0 + lp["ln1"], lp["ln1_b"])
        out, st_new = _time_mix(hn, xtm[:, None, :], lp["tm"], cfg, state0=st)
        xtm_new = hn[:, -1, :]
        h = h + out
        hn = layer_norm(h, 1.0 + lp["ln2"], lp["ln2_b"])
        h = h + _channel_mix(hn, xcm[:, None, :], lp["cm"])
        xcm_new = hn[:, -1, :]
        return h, (st_new, xtm_new, xcm_new)

    x, (states, xtms, xcms) = jax.lax.scan(
        body, x, (params["layers"], cache.state, cache.x_tm, cache.x_cm))
    x = layer_norm(x, 1.0 + params["final_norm"], params["final_norm_b"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["head"].astype(x.dtype))
    new_cache = RWKVCache(state=states, x_tm=xtms, x_cm=xcms,
                          length=cache.length + 1)
    return logits[:, 0], new_cache


def rwkv_prefill(params, batch, cfg, constrain=lambda t, n: t):
    """Prompt pass returning (last logits, cache with final states)."""
    tokens = batch["tokens"]
    x = params["embed"].astype(cfg.dtype_act)[tokens]
    x = layer_norm(x, 1.0 + params["ln0"], params["ln0_b"])

    def body(h, lp):
        hn = layer_norm(h, 1.0 + lp["ln1"], lp["ln1_b"])
        out, st = _time_mix(hn, _shift(hn), lp["tm"], cfg)
        xtm = hn[:, -1, :]
        h = h + out
        hn = layer_norm(h, 1.0 + lp["ln2"], lp["ln2_b"])
        h = h + _channel_mix(hn, _shift(hn), lp["cm"])
        xcm = hn[:, -1, :]
        return h, (st, xtm, xcm)

    scan_body = jax.checkpoint(body, prevent_cse=False) if cfg.remat else body
    x, (states, xtms, xcms) = jax.lax.scan(scan_body, x, params["layers"])
    x = layer_norm(x, 1.0 + params["final_norm"], params["final_norm_b"])
    logits = jnp.einsum("bd,dv->bv", x[:, -1], params["head"].astype(x.dtype))
    cache = RWKVCache(state=states, x_tm=xtms, x_cm=xcms,
                      length=jnp.int32(tokens.shape[1]))
    return logits, cache
