"""Decoder-only transformer LM (dense / MoE / VLM-prefix), scan-over-layers.

One implementation covers stablelm-12b, nemotron-4-15b, phi3-medium-14b,
qwen2-72b, llava-next-mistral-7b (patch-embedding prefix), arctic-480b and
qwen3-moe-235b (MoE FFN, optional parallel dense residual, optional QK-norm).

Parameters are stored stacked over layers (leading L dim) and the stack is
traversed with jax.lax.scan (O(1) HLO size in depth — required to keep the
94-layer MoE dry-run compile tractable), with optional per-layer remat.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from ..distributed.sharding import get_active_mesh
from .layers import (Cache, apply_rope, attention, chunked_ce_loss,
                     decode_attention, mlp, mlp_params, rms_norm, rope)
from .moe import moe_ffn, moe_ffn_sharded, moe_param_table

__all__ = ["decoder_param_table", "build_params", "table_logical",
           "decoder_forward", "decoder_loss", "decoder_prefill",
           "decoder_decode_step", "init_decoder_cache"]


# --------------------------------------------------------------------------
# parameter tables:  path -> (shape, logical_axes, fan_in or None)
# --------------------------------------------------------------------------
def _attn_table(cfg):
    D, Hq, Hkv, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    t = {
        "ln1": ((D,), ("embed",), None),
        "wq": ((D, Hq * Dh), ("embed", "heads_fused"), D),
        "wk": ((D, Hkv * Dh), ("embed", "kv_fused"), D),
        "wv": ((D, Hkv * Dh), ("embed", "kv_fused"), D),
        "wo": ((Hq * Dh, D), ("heads_fused", "embed"), Hq * Dh),
    }
    if cfg.qkv_bias:
        t["bq"] = ((Hq * Dh,), ("heads_fused",), None)
        t["bk"] = ((Hkv * Dh,), ("kv_fused",), None)
        t["bv"] = ((Hkv * Dh,), ("kv_fused",), None)
    if cfg.qk_norm:
        t["q_norm"] = ((Dh,), (None,), None)
        t["k_norm"] = ((Dh,), (None,), None)
    return t


def decoder_layer_table(cfg):
    t = dict(_attn_table(cfg))
    t["ln2"] = ((cfg.d_model,), ("embed",), None)
    if cfg.moe:
        for k, v in moe_param_table(cfg).items():
            t[f"moe/{k}"] = v
        if cfg.moe_dense_residual:
            for k, v in mlp_params(cfg.mlp_act, cfg.d_model, cfg.d_ff).items():
                t[f"residual_mlp/{k}"] = v
    else:
        for k, v in mlp_params(cfg.mlp_act, cfg.d_model, cfg.d_ff,
                               bias=cfg.mlp_bias).items():
            t[f"mlp/{k}"] = v
    return t


def decoder_param_table(cfg):
    table = {
        "embed": ((cfg.vocab_size, cfg.d_model), ("vocab", "embed"), None),
        "final_norm": ((cfg.d_model,), ("embed",), None),
    }
    for k, v in decoder_layer_table(cfg).items():
        shape, logical, fan = v
        table[f"layers/{k}"] = ((cfg.num_layers, *shape),
                                ("layers", *logical), fan)
    return table


def build_params(key, table, dtype):
    """Materialise a parameter pytree from a table (fan-in scaled init)."""
    names = sorted(table)
    keys = jax.random.split(key, len(names))
    params: dict[str, Any] = {}
    for name, k in zip(names, keys):
        shape, _, fan = table[name]
        if name.endswith(("ln1", "ln2", "final_norm", "q_norm", "k_norm")) \
                or "/b" in name or name.startswith("b"):
            arr = jnp.zeros(shape, dtype)
        elif fan is None:
            arr = (0.02 * jax.random.normal(k, shape, jnp.float32)).astype(dtype)
        else:
            std = fan ** -0.5
            arr = (std * jax.random.normal(k, shape, jnp.float32)).astype(dtype)
        _assign(params, name, arr)
    return params


def table_logical(table):
    out: dict[str, Any] = {}
    for name, (_, logical, _) in table.items():
        _assign(out, name, logical)
    return out


def _assign(tree, path, value):
    parts = path.split("/")
    for p in parts[:-1]:
        tree = tree.setdefault(p, {})
    tree[parts[-1]] = value


# --------------------------------------------------------------------------
# blocks
# --------------------------------------------------------------------------
def _project_qkv(x, p, cfg):
    B, S, _ = x.shape
    Hq, Hkv, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    q = q.reshape(B, S, Hq, Dh)
    k = k.reshape(B, S, Hkv, Dh)
    v = v.reshape(B, S, Hkv, Dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _ffn(x, p, cfg, constrain):
    if cfg.moe:
        from .moe import moe_ffn_sharded_decode

        mesh = get_active_mesh()
        if (mesh is not None and mesh.shape.get("model", 1) > 1
                and cfg.num_experts % mesh.shape["model"] == 0):
            if x.shape[0] * x.shape[1] <= 4096:
                # decode-sized batches: resident weights, gathered tokens
                out = moe_ffn_sharded_decode(x, p["moe"], cfg, mesh)
            else:
                # expert-parallel shard_map path (§Perf hillclimb 1)
                out = moe_ffn_sharded(x, p["moe"], cfg, mesh)
        else:
            out = moe_ffn(x, p["moe"], cfg, cfg.num_moe_groups, constrain)
        if cfg.moe_dense_residual:
            out = out + mlp(x, p["residual_mlp"], cfg.mlp_act)
        return out
    return mlp(x, p["mlp"], cfg.mlp_act)


def _attn_out(a, p):
    B, S = a.shape[:2]
    return jnp.einsum("bsh,hd->bsd", a.reshape(B, S, -1), p["wo"])


def _decoder_layer(x, p, cfg, cos, sin, constrain, layer_window):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = _project_qkv(h, p, cfg)
    if cfg.use_rope:
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    q = constrain(q, (("batch",), None, "heads", None))
    a = attention(q, k, v, causal=True, window=layer_window,
                  q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
    x = x + constrain(_attn_out(a, p), (("batch",), "seq", "embed"))
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + constrain(_ffn(h, p, cfg, constrain),
                      (("batch",), "seq", "embed"))
    return x


# --------------------------------------------------------------------------
# forward / loss / serve
# --------------------------------------------------------------------------
def decoder_forward(params, tokens, cfg, *, prefix_embeds=None,
                    constrain=lambda t, names: t):
    """tokens: (B, S_text) int32; prefix_embeds: (B, P, D) or None.

    Returns final hidden states (B, P + S_text, D).
    """
    x = params["embed"].astype(cfg.dtype_act)[tokens]
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    x = x * (cfg.d_model ** 0.5 if cfg.scale_embed else 1.0)
    x = constrain(x, (("batch",), "seq", "embed"))
    S = x.shape[1]
    cos, sin = rope(jnp.arange(S), cfg.head_dim, cfg.rope_theta, jnp.float32)

    windows = cfg.layer_windows  # tuple of len pattern or None
    def body(carry, lp):
        h, li = carry
        if windows is None:
            w = cfg.window
            h = _decoder_layer(h, lp, cfg, cos, sin, constrain, w)
        else:
            # static alternation pattern folded into scan via switch
            idx = li % len(windows)
            branches = [functools.partial(
                _decoder_layer, cfg=cfg, cos=cos, sin=sin,
                constrain=constrain, layer_window=w) for w in windows]
            h = jax.lax.switch(idx, branches, h, lp)
        return (h, li + 1), None

    scan_body = body
    if cfg.remat:
        scan_body = jax.checkpoint(body, prevent_cse=False)
    (x, _), _ = jax.lax.scan(scan_body, (x, jnp.int32(0)), params["layers"])
    return rms_norm(x, params["final_norm"], cfg.norm_eps)


def decoder_loss(params, batch, cfg, constrain=lambda t, names: t):
    x = decoder_forward(params, batch["tokens"], cfg,
                        prefix_embeds=batch.get("prefix_embeds"),
                        constrain=constrain)
    P = 0 if batch.get("prefix_embeds") is None else batch["prefix_embeds"].shape[1]
    x_text = x[:, P:, :]
    return chunked_ce_loss(x_text, params["embed"].astype(cfg.dtype_act),
                           batch["labels"], chunk=cfg.loss_chunk,
                           logit_cap=cfg.final_logit_cap)


def init_decoder_cache(cfg, batch, max_len, dtype):
    L, Hkv, Dh = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
    return Cache(
        k=jnp.zeros((L, batch, max_len, Hkv, Dh), dtype),
        v=jnp.zeros((L, batch, max_len, Hkv, Dh), dtype),
        length=jnp.int32(0),
    )


def _decode_layer(x, lp, cache_k, cache_v, length, cfg, cos, sin, constrain,
                  layer_window):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    q, k, v = _project_qkv(h, lp, cfg)
    if cfg.use_rope:
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    z = jnp.zeros((), length.dtype) if hasattr(length, "dtype") \
        else jnp.int32(0)
    ck = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype),
                                      (z, length, z, z))
    cv = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype),
                                      (z, length, z, z))
    a = decode_attention(q, ck, cv, length + 1, window=layer_window)
    x = x + _attn_out(a, lp)
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    x = x + _ffn(h, lp, cfg, constrain)
    return x, ck, cv


def decoder_decode_step(params, cache: Cache, tokens, cfg,
                        constrain=lambda t, names: t):
    """One greedy decode step. tokens: (B, 1) -> (logits (B, V), new cache)."""
    x = params["embed"].astype(cfg.dtype_act)[tokens]
    x = x * (cfg.d_model ** 0.5 if cfg.scale_embed else 1.0)
    pos = cache.length
    cos, sin = rope(jnp.arange(1) + pos, cfg.head_dim, cfg.rope_theta)
    windows = cfg.layer_windows

    def body(carry, inp):
        h, li = carry
        lp, ck, cv = inp
        if windows is None:
            h, ck, cv = _decode_layer(h, lp, ck, cv, pos, cfg, cos, sin,
                                      constrain, cfg.window)
        else:
            idx = li % len(windows)
            branches = [functools.partial(
                _decode_layer, cfg=cfg, cos=cos, sin=sin, constrain=constrain,
                layer_window=w) for w in windows]
            h, ck, cv = jax.lax.switch(idx, branches, h, lp, ck, cv, pos)
        return (h, li + 1), (ck, cv)

    (x, _), (new_k, new_v) = jax.lax.scan(
        body, (x, jnp.int32(0)), (params["layers"], cache.k, cache.v))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
    logits = constrain(logits, (("batch",), None, "vocab"))
    if cfg.final_logit_cap is not None:
        logits = cfg.final_logit_cap * jnp.tanh(logits / cfg.final_logit_cap)
    return logits[:, 0], Cache(k=new_k, v=new_v, length=cache.length + 1)


def decoder_prefill(params, batch, cfg, max_len,
                    constrain=lambda t, names: t):
    """Process a full prompt, return (last-token logits, populated cache).

    One scan over layers produces both the final hidden state and the K/V
    pairs that seed the decode cache.
    """
    tokens = batch["tokens"]
    x0 = params["embed"].astype(cfg.dtype_act)[tokens]
    if batch.get("prefix_embeds") is not None:
        x0 = jnp.concatenate([batch["prefix_embeds"].astype(x0.dtype), x0], 1)
    x0 = x0 * (cfg.d_model ** 0.5 if cfg.scale_embed else 1.0)
    x0 = constrain(x0, (("batch",), "seq", "embed"))
    B, S = x0.shape[:2]
    cos, sin = rope(jnp.arange(S), cfg.head_dim, cfg.rope_theta)
    windows = cfg.layer_windows

    def layer_with_kv(h, lp, w):
        hn = rms_norm(h, lp["ln1"], cfg.norm_eps)
        q, k, v = _project_qkv(hn, lp, cfg)
        if cfg.use_rope:
            q = apply_rope(q, cos, sin)
            k = apply_rope(k, cos, sin)
        a = attention(q, k, v, causal=True, window=w,
                      q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
        h = h + constrain(_attn_out(a, lp), (("batch",), "seq", "embed"))
        hn = rms_norm(h, lp["ln2"], cfg.norm_eps)
        h = h + constrain(_ffn(hn, lp, cfg, constrain),
                          (("batch",), "seq", "embed"))
        return h, k, v

    def body(carry, lp):
        h, li = carry
        if windows is None:
            h, k, v = layer_with_kv(h, lp, cfg.window)
        else:
            branches = [functools.partial(layer_with_kv, w=w) for w in windows]
            h, k, v = jax.lax.switch(li % len(windows), branches, h, lp)
        return (h, li + 1), (k, v)

    scan_body = jax.checkpoint(body, prevent_cse=False) if cfg.remat else body
    (x, _), (ks, vs) = jax.lax.scan(scan_body, (x0, jnp.int32(0)),
                                    params["layers"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,vd->bv", x[:, -1, :],
                        params["embed"].astype(x.dtype))
    if cfg.final_logit_cap is not None:
        logits = cfg.final_logit_cap * jnp.tanh(logits / cfg.final_logit_cap)

    cache = init_decoder_cache(cfg, B, max_len, cfg.dtype_act)
    cache = Cache(
        k=jax.lax.dynamic_update_slice(
            cache.k, ks.astype(cache.k.dtype), (0, 0, 0, 0, 0)),
        v=jax.lax.dynamic_update_slice(
            cache.v, vs.astype(cache.v.dtype), (0, 0, 0, 0, 0)),
        length=jnp.int32(S),
    )
    return logits, cache
