"""Griffin-style hybrid (RecurrentGemma-2B): RG-LRU recurrent blocks + local
sliding-window MQA, pattern (rec, rec, attn) cycled over layers.

Recurrent block (Griffin, De et al. 2024):
    y  = GeLU(W_y x)                       (B, S, R)
    z  = W_x x -> causal depthwise conv(4) -> RG-LRU -> h
    out = W_o (y * h)
RG-LRU:
    r_t = sigmoid(W_a z_t + b_a);  i_t = sigmoid(W_i z_t + b_i)
    log a_t = -c * r_t * softplus(lam)          (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * z_t)
computed with jax.lax.associative_scan over time for train/prefill and a
single fused step for decode. The attention layers cache only ``window``
K/V entries (rotating buffer), which is what makes the 500k-token decode
shape feasible for this arch.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import (apply_rope, attention, chunked_ce_loss, mlp, mlp_params,
                     rms_norm, rope)

__all__ = ["griffin_param_table", "griffin_loss", "griffin_prefill",
           "griffin_decode_step", "init_griffin_cache", "GriffinCache"]

_LRU_C = 8.0


class GriffinCache(NamedTuple):
    h: jnp.ndarray        # (L, B, R)   RG-LRU hidden state
    conv: jnp.ndarray     # (L, B, W_conv-1, R) conv tail
    k: jnp.ndarray        # (L, B, W, Hkv, Dh) rotating window K
    v: jnp.ndarray        # (L, B, W, Hkv, Dh)
    pos: jnp.ndarray      # (L, B, W) absolute positions in the buffer
    length: jnp.ndarray   # scalar int32


def griffin_layer_table(cfg):
    D, R = cfg.d_model, cfg.rnn_width
    Hq, Hkv, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    t = {
        # recurrent branch (present in every layer; attn layers ignore)
        "rec/ln": ((D,), ("embed",), None),
        "rec/wy": ((D, R), ("embed", "rnn"), D),
        "rec/wx": ((D, R), ("embed", "rnn"), D),
        "rec/conv_w": ((cfg.conv_width, R), (None, "rnn"), None),
        "rec/conv_b": ((R,), ("rnn",), None),
        "rec/wa": ((R, R), ("rnn", "rnn_in"), R),
        "rec/ba": ((R,), ("rnn",), None),
        "rec/wi": ((R, R), ("rnn", "rnn_in"), R),
        "rec/bi": ((R,), ("rnn",), None),
        "rec/lam": ((R,), ("rnn",), None),
        "rec/wo": ((R, D), ("rnn", "embed"), R),
        # local attention branch
        "attn/ln": ((D,), ("embed",), None),
        "attn/wq": ((D, Hq * Dh), ("embed", "heads_fused"), D),
        "attn/wk": ((D, Hkv * Dh), ("embed", "kv_fused"), D),
        "attn/wv": ((D, Hkv * Dh), ("embed", "kv_fused"), D),
        "attn/wo": ((Hq * Dh, D), ("heads_fused", "embed"), Hq * Dh),
        # shared MLP
        "mlp_ln": ((D,), ("embed",), None),
    }
    for k, v in mlp_params(cfg.mlp_act, cfg.d_model, cfg.d_ff).items():
        t[f"mlp/{k}"] = v
    return t


def griffin_param_table(cfg):
    table = {
        "embed": ((cfg.vocab_size, cfg.d_model), ("vocab", "embed"), None),
        "final_norm": ((cfg.d_model,), ("embed",), None),
    }
    for k, v in griffin_layer_table(cfg).items():
        shape, logical, fan = v
        table[f"layers/{k}"] = ((cfg.num_layers, *shape),
                                ("layers", *logical), fan)
    return table


# --------------------------------------------------------------------------
# RG-LRU
# --------------------------------------------------------------------------
def _rglru_gates(z, p):
    r = jax.nn.sigmoid(
        (jnp.einsum("bsr,rq->bsq", z, p["wa"]) + p["ba"]).astype(jnp.float32))
    i = jax.nn.sigmoid(
        (jnp.einsum("bsr,rq->bsq", z, p["wi"]) + p["bi"]).astype(jnp.float32))
    log_a = -_LRU_C * r * jax.nn.softplus(p["lam"].astype(jnp.float32))
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * z.astype(jnp.float32))
    return a, gated


def _rglru_scan(z, p):
    """z: (B, S, R) -> h: (B, S, R) via associative scan over time."""
    a, b = _rglru_gates(z, p)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    a_c, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(z.dtype)


def _causal_conv(z, w, b, tail=None):
    """Depthwise causal conv along time. z: (B, S, R); w: (K, R)."""
    K = w.shape[0]
    if tail is None:
        tail = jnp.zeros((z.shape[0], K - 1, z.shape[2]), z.dtype)
    zp = jnp.concatenate([tail, z], axis=1)
    out = sum(zp[:, i:i + z.shape[1], :] * w[i][None, None, :]
              for i in range(K))
    return (out + b[None, None, :]).astype(z.dtype), zp[:, -(K - 1):, :]


def _rec_block(x, p, cfg, h0=None, conv_tail=None):
    """Returns (out, h_last, new_conv_tail)."""
    xn = rms_norm(x, p["ln"], cfg.norm_eps)
    y = jax.nn.gelu(jnp.einsum("bsd,dr->bsr", xn, p["wy"]).astype(jnp.float32),
                    approximate=True).astype(x.dtype)
    z = jnp.einsum("bsd,dr->bsr", xn, p["wx"])
    z, new_tail = _causal_conv(z, p["conv_w"], p["conv_b"], conv_tail)
    if h0 is None:
        h = _rglru_scan(z, p)
    else:  # single decode step: S == 1
        a, b = _rglru_gates(z, p)
        h = (a * h0[:, None, :] + b).astype(x.dtype)
    out = jnp.einsum("bsr,rd->bsd", (y * h).astype(x.dtype), p["wo"])
    return out, h[:, -1, :].astype(jnp.float32), new_tail


def _attn_block(x, p, cfg, cos, sin):
    xn = rms_norm(x, p["ln"], cfg.norm_eps)
    B, S, _ = xn.shape
    Hq, Hkv, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dh->bsh", xn, p["wq"]).reshape(B, S, Hq, Dh)
    k = jnp.einsum("bsd,dh->bsh", xn, p["wk"]).reshape(B, S, Hkv, Dh)
    v = jnp.einsum("bsd,dh->bsh", xn, p["wv"]).reshape(B, S, Hkv, Dh)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    a = attention(q, k, v, causal=True, window=cfg.window,
                  q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
    out = jnp.einsum("bsh,hd->bsd", a.reshape(B, S, -1), p["wo"])
    return out, k, v


def _is_attn(cfg, li):
    pat = cfg.block_pattern
    return pat[li % len(pat)] == "attn"


# --------------------------------------------------------------------------
# forward / loss
# --------------------------------------------------------------------------
def griffin_forward(params, tokens, cfg, constrain=lambda t, n: t):
    x = params["embed"].astype(cfg.dtype_act)[tokens]
    x = x * math.sqrt(cfg.d_model)
    x = constrain(x, (("batch",), None, "embed"))
    S = x.shape[1]
    cos, sin = rope(jnp.arange(S), cfg.head_dim, cfg.rope_theta)
    pat = len(cfg.block_pattern)

    def rec_branch(h, lp):
        out, _, _ = _rec_block(h, lp["rec"], cfg)
        return h + constrain(out, (("batch",), None, "embed"))

    def attn_branch(h, lp):
        out, _, _ = _attn_block(h, lp["attn"], cfg, cos, sin)
        return h + constrain(out, (("batch",), None, "embed"))

    def body(carry, lp):
        h, li = carry
        branches = [attn_branch if b == "attn" else rec_branch
                    for b in cfg.block_pattern]
        h = jax.lax.switch(li % pat, branches, h, lp)
        hn = rms_norm(h, lp["mlp_ln"], cfg.norm_eps)
        h = h + constrain(mlp(hn, lp["mlp"], cfg.mlp_act),
                          (("batch",), None, "embed"))
        return (h, li + 1), None

    scan_body = jax.checkpoint(body, prevent_cse=False) if cfg.remat else body
    (x, _), _ = jax.lax.scan(scan_body, (x, jnp.int32(0)), params["layers"])
    return rms_norm(x, params["final_norm"], cfg.norm_eps)


def griffin_loss(params, batch, cfg, constrain=lambda t, n: t):
    x = griffin_forward(params, batch["tokens"], cfg, constrain)
    return chunked_ce_loss(x, params["embed"].astype(cfg.dtype_act),
                           batch["labels"], chunk=cfg.loss_chunk,
                           logit_cap=cfg.final_logit_cap)


# --------------------------------------------------------------------------
# serving
# --------------------------------------------------------------------------
def init_griffin_cache(cfg, batch, dtype):
    L, R, W = cfg.num_layers, cfg.rnn_width, cfg.window
    Hkv, Dh = cfg.num_kv_heads, cfg.head_dim
    return GriffinCache(
        h=jnp.zeros((L, batch, R), jnp.float32),
        conv=jnp.zeros((L, batch, cfg.conv_width - 1, R), dtype),
        k=jnp.zeros((L, batch, W, Hkv, Dh), dtype),
        v=jnp.zeros((L, batch, W, Hkv, Dh), dtype),
        pos=jnp.full((L, batch, W), -10**9, jnp.int32),
        length=jnp.int32(0),
    )


def _windowed_decode_attention(q, kbuf, vbuf, posbuf, cur_pos, window):
    """q: (B,1,Hq,Dh); kbuf/vbuf: (B,W,Hkv,Dh); posbuf: (B,W)."""
    B, W, Hkv, Dh = kbuf.shape
    Hq = q.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, 1, Hkv, G, Dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kbuf).astype(jnp.float32)
    s = s / math.sqrt(Dh)
    valid = (posbuf <= cur_pos) & (posbuf > cur_pos - window)
    s = jnp.where(valid[:, None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(vbuf.dtype), vbuf)
    return out.reshape(B, 1, Hq, Dh)


def griffin_decode_step(params, cache: GriffinCache, tokens, cfg,
                        constrain=lambda t, n: t):
    x = params["embed"].astype(cfg.dtype_act)[tokens] * math.sqrt(cfg.d_model)
    pos = cache.length
    cos, sin = rope(jnp.arange(1) + pos, cfg.head_dim, cfg.rope_theta)
    slot = pos % cfg.window
    pat = len(cfg.block_pattern)

    def rec_branch(h, lp, st):
        h0, tail, k, v, pb = st
        out, h_new, tail_new = _rec_block(h, lp["rec"], cfg, h0=h0,
                                          conv_tail=tail)
        return h + out, (h_new, tail_new, k, v, pb)

    def attn_branch(h, lp, st):
        h0, tail, kbuf, vbuf, pb = st
        xn = rms_norm(h, lp["attn"]["ln"], cfg.norm_eps)
        B = xn.shape[0]
        Hq, Hkv, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        q = jnp.einsum("bsd,dh->bsh", xn, lp["attn"]["wq"]).reshape(B, 1, Hq, Dh)
        k = jnp.einsum("bsd,dh->bsh", xn, lp["attn"]["wk"]).reshape(B, 1, Hkv, Dh)
        v = jnp.einsum("bsd,dh->bsh", xn, lp["attn"]["wv"]).reshape(B, 1, Hkv, Dh)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        z = jnp.zeros((), slot.dtype)
        kbuf = jax.lax.dynamic_update_slice(kbuf, k.astype(kbuf.dtype),
                                            (z, slot, z, z))
        vbuf = jax.lax.dynamic_update_slice(vbuf, v.astype(vbuf.dtype),
                                            (z, slot, z, z))
        pb = jax.lax.dynamic_update_slice(
            pb, jnp.full((B, 1), pos, jnp.int32), (z, slot))
        a = _windowed_decode_attention(q, kbuf, vbuf, pb, pos, cfg.window)
        out = jnp.einsum("bsh,hd->bsd", a.reshape(B, 1, -1), lp["attn"]["wo"])
        return h + out, (h0, tail, kbuf, vbuf, pb)

    def body(carry, inp):
        h, li = carry
        lp, st = inp[0], inp[1:]
        branches = [attn_branch if b == "attn" else rec_branch
                    for b in cfg.block_pattern]
        h, st = jax.lax.switch(li % pat, branches, h, lp, st)
        hn = rms_norm(h, lp["mlp_ln"], cfg.norm_eps)
        h = h + mlp(hn, lp["mlp"], cfg.mlp_act)
        return (h, li + 1), st

    (x, _), (hs, tails, ks, vs, pbs) = jax.lax.scan(
        body, (x, jnp.int32(0)),
        (params["layers"], cache.h, cache.conv, cache.k, cache.v, cache.pos))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
    if cfg.final_logit_cap is not None:
        logits = cfg.final_logit_cap * jnp.tanh(logits / cfg.final_logit_cap)
    new_cache = GriffinCache(h=hs, conv=tails, k=ks, v=vs, pos=pbs,
                             length=cache.length + 1)
    return logits[:, 0], new_cache


def griffin_prefill(params, batch, cfg, constrain=lambda t, n: t):
    """Prompt pass returning (last logits, cache) — full state version."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = params["embed"].astype(cfg.dtype_act)[tokens] * math.sqrt(cfg.d_model)
    cos, sin = rope(jnp.arange(S), cfg.head_dim, cfg.rope_theta)
    W = cfg.window
    pat = len(cfg.block_pattern)
    cache0 = init_griffin_cache(cfg, B, cfg.dtype_act)

    def rec_branch(h, lp):
        out, h_last, tail = _rec_block(h, lp["rec"], cfg)
        zeros_k = jnp.zeros((B, W, cfg.num_kv_heads, cfg.head_dim), h.dtype)
        pb = jnp.full((B, W), -10**9, jnp.int32)
        return h + out, (h_last, tail, zeros_k, zeros_k, pb)

    def attn_branch(h, lp):
        out, k, v = _attn_block(h, lp["attn"], cfg, cos, sin)
        # keep the last W positions in rotating-slot order (slot = pos % W)
        last = jnp.arange(W)
        src_pos = S - W + ((last - S % W) % W) if S >= W else last
        take = jnp.clip(src_pos, 0, S - 1)
        kw = k[:, take, :, :]
        vw = v[:, take, :, :]
        pb = jnp.where(src_pos >= 0, src_pos, -10**9)[None, :].repeat(B, 0) \
            if S >= W else jnp.where(last < S, last, -10**9)[None, :].repeat(B, 0)
        h_last = jnp.zeros((B, cfg.rnn_width), jnp.float32)
        tail = jnp.zeros((B, cfg.conv_width - 1, cfg.rnn_width), h.dtype)
        return h + out, (h_last, tail, kw.astype(h.dtype), vw.astype(h.dtype),
                         pb.astype(jnp.int32))

    def body(carry, lp):
        h, li = carry
        branches = [attn_branch if b == "attn" else rec_branch
                    for b in cfg.block_pattern]
        h, st = jax.lax.switch(li % pat, branches, h, lp)
        hn = rms_norm(h, lp["mlp_ln"], cfg.norm_eps)
        h = h + mlp(hn, lp["mlp"], cfg.mlp_act)
        return (h, li + 1), st

    scan_body = jax.checkpoint(body, prevent_cse=False) if cfg.remat else body
    (x, _), (hs, tails, ks, vs, pbs) = jax.lax.scan(
        scan_body, (x, jnp.int32(0)), params["layers"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,vd->bv", x[:, -1], params["embed"].astype(x.dtype))
    if cfg.final_logit_cap is not None:
        logits = cfg.final_logit_cap * jnp.tanh(logits / cfg.final_logit_cap)
    cache = GriffinCache(h=hs, conv=tails, k=ks, v=vs, pos=pbs,
                         length=jnp.int32(S))
    return logits, cache
