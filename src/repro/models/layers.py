"""Shared neural-net layers (pure JAX, functional, dtype-explicit).

Conventions:
  * activations: (batch, seq, d_model), dtype = cfg activation dtype (bf16).
  * attention weights are computed in fp32 (softmax stability), outputs cast
    back to the activation dtype.
  * long sequences use chunked (flash-style) attention: nested scans over
    query/key blocks with an online softmax, wrapped in jax.checkpoint so the
    backward pass recomputes scores instead of saving (Sq, Sk) tensors.
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["rms_norm", "layer_norm", "rope", "apply_rope", "mlp", "mlp_params",
           "attention", "decode_attention", "chunked_ce_loss", "Cache"]


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------
def rms_norm(x, scale, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, scale, bias, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------
# rotary position embeddings
# --------------------------------------------------------------------------
def rope(positions, head_dim, theta=10_000.0, dtype=jnp.float32):
    """positions: (..., S) -> cos, sin of shape (..., S, head_dim/2)."""
    freqs = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    angles = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(angles).astype(dtype), jnp.sin(angles).astype(dtype)


def apply_rope(x, cos, sin):
    """x: (B, S, H, Dh); cos/sin: (B, S, Dh/2) or (S, Dh/2)."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    if cos.ndim == 2:
        cos, sin = cos[None, :, None, :], sin[None, :, None, :]
    else:
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# MLP
# --------------------------------------------------------------------------
def mlp(x, params, act: str):
    """act in {swiglu, geglu, gelu, relu2}. Gated acts use wi_0 (gate) & wi_1."""
    if act in ("swiglu", "geglu"):
        g = jnp.einsum("bsd,df->bsf", x, params["wi_0"])
        u = jnp.einsum("bsd,df->bsf", x, params["wi_1"])
        g = jax.nn.silu(g.astype(jnp.float32)) if act == "swiglu" else \
            jax.nn.gelu(g.astype(jnp.float32), approximate=True)
        h = (g * u.astype(jnp.float32)).astype(x.dtype)
    else:
        h = jnp.einsum("bsd,df->bsf", x, params["wi_0"])
        if act == "gelu":
            h = jax.nn.gelu(h.astype(jnp.float32), approximate=True).astype(x.dtype)
        elif act == "relu2":  # squared ReLU (Nemotron-4)
            h32 = jnp.maximum(h.astype(jnp.float32), 0.0)
            h = (h32 * h32).astype(x.dtype)
        else:
            raise ValueError(act)
        if "bi_0" in params:
            h = h + params["bi_0"].astype(h.dtype)
    out = jnp.einsum("bsf,fd->bsd", h, params["wo"])
    if "bo" in params:
        out = out + params["bo"].astype(out.dtype)
    return out


def mlp_params(act: str, d_model: int, d_ff: int, bias: bool = False):
    """(name -> (shape, logical_axes, fan_in)) table entries for an MLP."""
    table = {}
    if act in ("swiglu", "geglu"):
        table["wi_0"] = ((d_model, d_ff), ("embed", "mlp"), d_model)
        table["wi_1"] = ((d_model, d_ff), ("embed", "mlp"), d_model)
    else:
        table["wi_0"] = ((d_model, d_ff), ("embed", "mlp"), d_model)
        if bias:
            table["bi_0"] = ((d_ff,), ("mlp",), None)
    table["wo"] = ((d_ff, d_model), ("mlp", "embed"), d_ff)
    if bias:
        table["bo"] = ((d_model,), ("embed",), None)
    return table


# --------------------------------------------------------------------------
# attention (training / prefill)
# --------------------------------------------------------------------------
def _plain_attention(q, k, v, causal, window, q_offset):
    """q: (B, Sq, Hq, Dh), k/v: (B, Sk, Hkv, Dh). Full score matrix."""
    B, Sq, Hq, Dh = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, Dh)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32)
    scores = scores / math.sqrt(Dh)
    qpos = jnp.arange(Sq)[:, None] + q_offset
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)
    return out.reshape(B, Sq, Hq, Dh)


def _chunked_attention(q, k, v, causal, window, q_chunk, kv_chunk):
    """Flash-style two-level scan with online softmax; O(cq*ck) score memory."""
    B, Sq, Hq, Dh = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    nq, nk = Sq // q_chunk, Sk // kv_chunk
    qs = q.reshape(B, nq, q_chunk, Hkv, G, Dh)
    ks = k.reshape(B, nk, kv_chunk, Hkv, Dh)
    vs = v.reshape(B, nk, kv_chunk, Hkv, Dh)
    scale = 1.0 / math.sqrt(Dh)

    def q_block(qi, qb):
        # qb: (B, cq, Hkv, G, Dh)
        m0 = jnp.full((B, Hkv, G, q_chunk), -1e30, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, q_chunk, Dh), jnp.float32)

        @functools.partial(jax.checkpoint, prevent_cse=False)
        def kv_step(carry, inp):
            m, l, acc = carry
            ki, kb, vb = inp
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb).astype(jnp.float32) * scale
            qpos = qi * q_chunk + jnp.arange(q_chunk)[:, None]
            kpos = ki * kv_chunk + jnp.arange(kv_chunk)[None, :]
            msk = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                msk &= kpos <= qpos
            if window is not None:
                msk &= kpos > qpos - window
            s = jnp.where(msk[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            corr = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, vb.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        idx = jnp.arange(nk)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (idx, jnp.moveaxis(ks, 1, 0), jnp.moveaxis(vs, 1, 0)))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return jnp.einsum("bhgqd->bqhgd", out)  # (B, cq, Hkv, G, Dh)

    outs = jax.lax.map(lambda i: q_block(i, qs[:, i]), jnp.arange(nq))
    out = jnp.einsum("nbqhgd->bnqhgd", outs).reshape(B, Sq, Hq, Dh)
    return out.astype(q.dtype)


def attention(q, k, v, *, causal=True, window=None, q_offset=0,
              q_chunk=512, kv_chunk=1024):
    """Dispatch between plain and chunked attention on sequence length."""
    Sq, Sk = q.shape[1], k.shape[1]
    if Sq <= max(q_chunk, 1024) or Sq % q_chunk or Sk % kv_chunk:
        return _plain_attention(q, k, v, causal, window, q_offset)
    return _chunked_attention(q, k, v, causal, window, q_chunk, kv_chunk)


def decode_attention(q, k_cache, v_cache, cache_len, window=None):
    """Single-token attention against a (possibly windowed) KV cache.

    q: (B, 1, Hq, Dh); k/v_cache: (B, T, Hkv, Dh); cache_len: scalar count of
    valid entries (new token already written at cache_len - 1).
    """
    B, T, Hkv, Dh = k_cache.shape
    Hq = q.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, 1, Hkv, G, Dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_cache).astype(jnp.float32)
    s = s / math.sqrt(Dh)
    kpos = jnp.arange(T)
    valid = kpos < cache_len
    if window is not None:
        valid &= kpos >= cache_len - window
    s = jnp.where(valid[None, None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v_cache.dtype), v_cache)
    return out.reshape(B, 1, Hq, Dh)


# --------------------------------------------------------------------------
# loss
# --------------------------------------------------------------------------
def chunked_ce_loss(x, embed, labels, *, chunk=512, logit_cap=None):
    """Cross-entropy with the logits computed per sequence chunk.

    Avoids materialising the full (B, S, vocab) fp32 logits tensor (vocab up
    to 256k here). x: (B, S, D); embed: (V, D) tied output head; labels
    (B, S) with -1 = ignore.
    """
    B, S, D = x.shape
    chunk = min(chunk, S)
    nchunk = S // chunk if S % chunk == 0 else 1
    if S % chunk != 0:
        chunk = S
    xs = x.reshape(B, nchunk, chunk, D).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, nchunk, chunk).transpose(1, 0, 2)

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def body(carry, inp):
        xc, lc = inp
        logits = jnp.einsum("bsd,vd->bsv", xc, embed).astype(jnp.float32)
        if logit_cap is not None:
            logits = logit_cap * jnp.tanh(logits / logit_cap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lc, 0)[..., None], axis=-1)[..., 0]
        valid = (lc >= 0).astype(jnp.float32)
        nll = (lse - gold) * valid
        return (carry[0] + jnp.sum(nll), carry[1] + jnp.sum(valid)), None

    (total, count), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)),
                                     (xs, ls))
    return total / jnp.maximum(count, 1.0)


class Cache(NamedTuple):
    """Decode-time KV cache for one attention stack (stacked over layers)."""
    k: jnp.ndarray        # (L, B, T, Hkv, Dh)
    v: jnp.ndarray        # (L, B, T, Hkv, Dh)
    length: jnp.ndarray   # scalar int32: number of valid positions
