"""The 10 assigned LM architectures, pure JAX with scan-over-layers."""
from .registry import Model, active_params, build_model, count_params, make_input_specs

__all__ = ["Model", "active_params", "build_model", "count_params",
           "make_input_specs"]
