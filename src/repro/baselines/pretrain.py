"""Amortized pre-training of the curve transformer on synthetic task streams.

Every step samples a fresh batch of tasks from the LCBench-like prior
(:func:`repro.data.curves.sample_suite`) with randomized regimes — noise
level, spike probability, divergent-curve fraction, and the ``crossing``
(anti-correlated rate/asymptote) family — flattens them into curves, and
takes one optimizer step on the weighted Gaussian NLL. The observed-prefix
fraction follows a curriculum: early steps see mostly-complete curves (easy
interpolation), the floor then anneals down so late training is dominated
by the hard short-prefix extrapolation regime the evaluation actually
scores.

The step itself is the shared SPMD trainer
(:func:`repro.train.trainer.make_train_step` on a debug mesh), so the
baseline inherits microbatching, donation, and the AdamW/Adafactor
implementations in :mod:`repro.train.optimizers` for free.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..data.curves import sample_suite, stack_suite
from ..distributed.sharding import TP_RULES
from ..train.optimizers import OptConfig
from ..train.trainer import make_train_step
from .curve_transformer import (CurveTransformerConfig, build_curve_model,
                                normalize_t)

__all__ = ["PretrainConfig", "sample_stream_batch", "pretrain"]


@dataclass(frozen=True)
class PretrainConfig:
    steps: int = 1500
    tasks_per_step: int = 6
    n: int = 12                # configs per task
    m: int = 12                # epochs per task (fixed per pretrain run)
    d: int = 7
    # Optional explicit progression grid (tuple for dataclass hashability;
    # positive, strictly increasing, len == m). Set from a real dataset's
    # budget grid so the amortized model trains on the fidelities it will
    # be evaluated at; None keeps epochs 1..m.
    t: tuple | None = None
    seed: int = 0
    # Curriculum: the lower bound of the observed-prefix fraction anneals
    # from floor_start to floor_end over the first curriculum_frac of steps.
    prefix_floor_start: float = 0.5
    prefix_floor_end: float = 0.05
    prefix_cap: float = 0.95
    curriculum_frac: float = 0.6
    peak_lr: float = 3e-3
    log_every: int = 200


def _prefix_floor(cfg: PretrainConfig, step: int) -> float:
    prog = min(1.0, step / max(1.0, cfg.curriculum_frac * cfg.steps))
    return (cfg.prefix_floor_start
            + (cfg.prefix_floor_end - cfg.prefix_floor_start) * prog)


def sample_stream_batch(cfg: PretrainConfig, step: int) -> dict:
    """One training batch of flattened curves, all regimes randomized."""
    rng = np.random.default_rng(cfg.seed * 1_000_003 + step)
    floor = _prefix_floor(cfg, step)
    tasks = sample_suite(
        int(rng.integers(0, 2**31 - 1)), cfg.tasks_per_step,
        n=cfg.n, m=cfg.m, d=cfg.d,
        t=None if cfg.t is None else np.asarray(cfg.t, np.float64),
        observed_fraction=(floor, cfg.prefix_cap),
        noise=float(rng.uniform(0.003, 0.03)),
        spike_prob=float(rng.uniform(0.0, 0.08)),
        diverge_prob=float(rng.uniform(0.0, 0.08)),
        crossing=bool(rng.random() < 0.5))
    X, t, Y, mask, Y_full = stack_suite(tasks)
    B = cfg.tasks_per_step * cfg.n
    return {
        "hp": X.reshape(B, cfg.d).astype(np.float32),
        "y": Y.reshape(B, cfg.m).astype(np.float32),
        "mask": mask.reshape(B, cfg.m).astype(np.float32),
        "target": Y_full.reshape(B, cfg.m).astype(np.float32),
        "t_norm": np.asarray(normalize_t(t), np.float32),
    }


def pretrain(model_cfg: CurveTransformerConfig,
             cfg: PretrainConfig | None = None,
             opt_cfg: OptConfig | None = None, mesh=None, out=print):
    """Pre-train the curve transformer; returns (params, info dict)."""
    from ..launch.mesh import make_debug_mesh

    cfg = cfg or PretrainConfig()
    model = build_curve_model(model_cfg)
    if mesh is None:
        n_dev = len(jax.devices())
        mesh = make_debug_mesh(data=n_dev, model=1)
    opt = opt_cfg or OptConfig(peak_lr=cfg.peak_lr,
                               warmup_steps=max(5, cfg.steps // 20),
                               decay_steps=cfg.steps)
    setup = make_train_step(model, mesh, opt_cfg=opt, rules=TP_RULES)

    t0 = time.time()
    losses = []
    with mesh:
        state = jax.jit(setup.init_state,
                        out_shardings=setup.state_shardings)(
                            jax.random.key(cfg.seed))
        for step in range(cfg.steps):
            batch = {k: jnp.asarray(v)
                     for k, v in sample_stream_batch(cfg, step).items()}
            state, metrics = setup.step_fn(state, batch)
            # Keep the device scalar: float() here would block on the
            # accelerator every step and kill async dispatch (RA103).
            losses.append(metrics["loss"])
            if cfg.log_every and (step + 1) % cfg.log_every == 0:
                out(f"pretrain step {step + 1:5d}  nll "
                    f"{np.mean(losses[-cfg.log_every:]):.4f}  "
                    f"prefix_floor {_prefix_floor(cfg, step):.2f}")
        params = jax.device_get(state.params)
    info = {
        "steps": cfg.steps,
        "train_s": round(time.time() - t0, 3),
        "first_loss": round(float(np.mean(losses[:20])), 5),
        "final_loss": round(float(np.mean(losses[-20:])), 5),
    }
    return params, info
