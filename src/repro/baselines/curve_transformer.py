"""Curve-prediction transformer: amortized learning-curve continuation.

The model is the paper's Transformer competitor (an FT-PFN-style amortized
predictor, cf. Rakotoarison et al. 2024): each curve is a sequence of epoch
tokens carrying ``(observed value, missing-value mask, progression
encoding)``, a conditioning token embeds the curve's hyper-parameter
vector, a bidirectional transformer encoder attends over the ``m + 1``
tokens, and a heteroscedastic head decodes a Gaussian ``N(mu_j, sigma_j^2)``
for every epoch ``j`` — observed or not. Trained on streams of synthetic
tasks (see :mod:`repro.baselines.pretrain`), one forward pass amortizes the
whole fit-and-predict loop the LKGP runs per task.

Built from the shared neural-net blocks in :mod:`repro.models.layers`
(``rms_norm`` / ``attention`` / ``mlp``) with parameters materialised by the
same table machinery the model zoo uses (:func:`repro.models.transformer
.build_params`), so the baseline plugs straight into
:func:`repro.train.trainer.make_train_step`.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models.layers import attention, mlp, mlp_params, rms_norm
from ..models.transformer import build_params, table_logical

__all__ = ["CurveTransformerConfig", "CurveModel", "param_table",
           "layer_table", "transformer_stack", "build_curve_model",
           "encode_features", "forward", "gaussian_nll", "curve_loss",
           "normalize_t", "predict_task"]


@dataclass(frozen=True)
class CurveTransformerConfig:
    """Shape + loss configuration for the curve transformer."""
    d_in: int = 7              # hyper-parameter dimension
    d_model: int = 64
    num_layers: int = 3
    num_heads: int = 4
    d_ff: int = 128
    mlp_act: str = "swiglu"
    norm_eps: float = 1e-6
    min_sigma: float = 1e-3    # floor on the predicted std
    fourier_feats: int = 6     # continuous progression encoding (any m works)
    obs_loss_weight: float = 0.1  # NLL weight on observed (vs continued) cells
    dtype: Any = jnp.float32

    @property
    def head_dim(self) -> int:
        return self.d_model // self.num_heads

    @property
    def num_features(self) -> int:
        # (masked value, mask flag, t_norm) + sin/cos Fourier features of t.
        return 3 + 2 * self.fourier_feats


class CurveModel(NamedTuple):
    """Functional endpoints; duck-types the zoo ``Model`` for the trainer."""
    cfg: CurveTransformerConfig
    param_table: dict
    logical: dict
    init: Callable
    loss: Callable
    predict: Callable


# --------------------------------------------------------------------------
# parameter table (same (shape, logical_axes, fan_in) format as the zoo)
# --------------------------------------------------------------------------
def layer_table(cfg: CurveTransformerConfig):
    """Parameter table for ONE encoder block (pre-norm attention + MLP).

    Exported so other amortized models (e.g. the hyper-parameter encoder
    in :mod:`repro.amortize`) can stack the same blocks under their own
    top-level names.
    """
    D, H, Dh = cfg.d_model, cfg.num_heads, cfg.head_dim
    t = {
        "ln1": ((D,), ("embed",), None),
        "wq": ((D, H * Dh), ("embed", "heads_fused"), D),
        "wk": ((D, H * Dh), ("embed", "heads_fused"), D),
        "wv": ((D, H * Dh), ("embed", "heads_fused"), D),
        "wo": ((H * Dh, D), ("heads_fused", "embed"), H * Dh),
        "ln2": ((D,), ("embed",), None),
    }
    for k, v in mlp_params(cfg.mlp_act, D, cfg.d_ff).items():
        t[f"mlp/{k}"] = v
    return t


def param_table(cfg: CurveTransformerConfig):
    D = cfg.d_model
    table = {
        "in_proj/w": ((cfg.num_features, D), (None, "embed"), cfg.num_features),
        "in_proj/b": ((D,), ("embed",), None),
        "hp_embed/w0": ((cfg.d_in, D), (None, "embed"), cfg.d_in),
        "hp_embed/b0": ((D,), ("embed",), None),
        "hp_embed/w1": ((D, D), ("embed", None), D),
        "final_norm": ((D,), ("embed",), None),
        "head/w": ((D, 2), ("embed", None), D),
        "head/b": ((2,), (None,), None),
    }
    for k, (shape, logical, fan) in layer_table(cfg).items():
        table[f"layers/{k}"] = ((cfg.num_layers, *shape),
                                ("layers", *logical), fan)
    return table


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------
def normalize_t(t) -> jnp.ndarray:
    """Log-scale progressions to [0, 1] (matches ``TTransform``).

    Host-side numpy on purpose: callers pass concrete epoch grids, and a
    ``jnp.float64`` request would warn/truncate whenever x64 is off.
    """
    lt = np.log(np.asarray(t, np.float64))
    span = max(float(lt[-1] - lt[0]), 1e-9)
    return jnp.asarray((lt - lt[0]) / span, jnp.float32)


def encode_features(y, mask, t_norm, cfg: CurveTransformerConfig):
    """Per-epoch token features: masked value, mask flag, progression enc."""
    B, m = y.shape
    ym = (y * mask).astype(cfg.dtype)
    freqs = (2.0 ** jnp.arange(cfg.fourier_feats, dtype=jnp.float32)) * math.pi
    ang = t_norm.astype(jnp.float32)[:, None] * freqs[None, :]
    tf = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)    # (m, 2F)
    tf = jnp.broadcast_to(tf[None], (B, m, 2 * cfg.fourier_feats))
    tcol = jnp.broadcast_to(t_norm.astype(cfg.dtype)[None, :, None], (B, m, 1))
    return jnp.concatenate([ym[..., None], mask.astype(cfg.dtype)[..., None],
                            tcol, tf.astype(cfg.dtype)], axis=-1)


def transformer_stack(x, layers, cfg: CurveTransformerConfig):
    """Scan the bidirectional pre-norm encoder blocks over ``x``.

    ``x`` is (B, S, d_model); ``layers`` the stacked (num_layers, ...)
    block parameters (the ``layers/*`` entries of :func:`param_table`, or
    any other stack built from :func:`layer_table`).
    """
    B, S, _ = x.shape
    H, Dh = cfg.num_heads, cfg.head_dim

    def body(h, lp):
        hn = rms_norm(h, lp["ln1"], cfg.norm_eps)
        q = (hn @ lp["wq"]).reshape(B, S, H, Dh)
        k = (hn @ lp["wk"]).reshape(B, S, H, Dh)
        v = (hn @ lp["wv"]).reshape(B, S, H, Dh)
        a = attention(q, k, v, causal=False)              # bidirectional
        h = h + a.reshape(B, S, H * Dh) @ lp["wo"]
        hn = rms_norm(h, lp["ln2"], cfg.norm_eps)
        h = h + mlp(hn, lp["mlp"], cfg.mlp_act)
        return h, None

    x, _ = jax.lax.scan(body, x, layers)
    return x


def forward(params, hp, y, mask, t_norm, cfg: CurveTransformerConfig):
    """hp: (B, d_in); y, mask: (B, m); t_norm: (m,) -> (mu, sigma), (B, m).

    Values at ``mask == 0`` cells never enter the computation (the feature
    encoder zeroes them), so predictions depend only on the observed prefix.
    """
    x = encode_features(y, mask, t_norm, cfg)
    x = x @ params["in_proj"]["w"] + params["in_proj"]["b"]
    h0 = jax.nn.gelu(hp.astype(cfg.dtype) @ params["hp_embed"]["w0"]
                     + params["hp_embed"]["b0"])
    h0 = h0 @ params["hp_embed"]["w1"]
    x = jnp.concatenate([h0[:, None, :], x], axis=1)      # (B, m + 1, D)
    x = transformer_stack(x, params["layers"], cfg)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    out = x[:, 1:, :] @ params["head"]["w"] + params["head"]["b"]  # (B, m, 2)
    mu = out[..., 0]
    sigma = cfg.min_sigma + jax.nn.softplus(out[..., 1])
    return mu, sigma


# --------------------------------------------------------------------------
# loss
# --------------------------------------------------------------------------
def gaussian_nll(mu, sigma, target):
    """Per-cell negative log-likelihood of a heteroscedastic Gaussian."""
    var = sigma * sigma
    return 0.5 * (jnp.log(2.0 * math.pi * var)
                  + (target - mu) ** 2 / var)


def curve_loss(params, batch, cfg: CurveTransformerConfig,
               constrain=lambda t, names: t):
    """Weighted NLL: full weight on continuation cells, ``obs_loss_weight``
    on the (noisy) observed prefix. Batch keys: hp, y, mask, t_norm, target.
    """
    mu, sigma = forward(params, batch["hp"], batch["y"], batch["mask"],
                        batch["t_norm"], cfg)
    nll = gaussian_nll(mu, sigma, batch["target"].astype(mu.dtype))
    mask = batch["mask"].astype(mu.dtype)
    w = mask * cfg.obs_loss_weight + (1.0 - mask)
    return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)


# --------------------------------------------------------------------------
# model + convenience prediction
# --------------------------------------------------------------------------
def build_curve_model(cfg: CurveTransformerConfig) -> CurveModel:
    table = param_table(cfg)
    return CurveModel(
        cfg=cfg, param_table=table, logical=table_logical(table),
        init=lambda key, dtype=cfg.dtype: build_params(key, table, dtype),
        loss=lambda p, b, constrain=None: curve_loss(p, b, cfg),
        predict=lambda p, hp, y, mask, t_norm: forward(p, hp, y, mask,
                                                       t_norm, cfg),
    )


def predict_task(params, cfg: CurveTransformerConfig, X, t, Y, mask):
    """One amortized forward pass over a task; returns np (mean, var), (n, m)."""
    mu, sigma = jax.jit(forward, static_argnums=5)(
        params, jnp.asarray(X), jnp.asarray(Y), jnp.asarray(mask),
        normalize_t(jnp.asarray(t)), cfg)
    return np.asarray(mu, np.float64), np.asarray(sigma, np.float64) ** 2
