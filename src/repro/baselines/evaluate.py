"""Head-to-head evaluation: LKGP vs the amortized transformer baseline.

Both models see *identical* held-out tasks and identical observation masks
(an observed-prefix cutoff at a given fraction of the epochs, with one
fully-observed anchor curve per task — the freeze-thaw setting), and are
scored on the cells the mask hides:

* ``nll``       — mean Gaussian negative log-likelihood on unobserved cells;
* ``mae``       — mean absolute error of the predicted mean on those cells;
* ``rank_corr`` — Spearman correlation of predicted vs true final-epoch
                  values across configs (the quantity AutoML promotion
                  decisions rank on);
* ``fit_s`` / ``predict_s`` — wall-clock. The transformer's ``fit_s`` is 0
  by construction (amortized); its pre-training cost is reported once by
  the benchmark, not per task.
"""
from __future__ import annotations

import time

import numpy as np

from ..core import LKGPConfig, fit, posterior
from ..data.curves import CurveTask
from .curve_transformer import (CurveTransformerConfig, gaussian_nll,
                                predict_task)

__all__ = ["cutoff_masks", "eval_lkgp", "eval_transformer",
           "score_predictions", "head_to_head"]


def cutoff_masks(task: CurveTask, cutoffs, seed: int) -> dict:
    """Per-cutoff observation masks: each curve observed up to
    ``round(frac * m)`` epochs; one (seed-deterministic) anchor curve stays
    fully observed. Identical masks are fed to every model under test."""
    n, m = task.Y.shape
    anchor = int(np.random.default_rng(seed).integers(0, n))
    out = {}
    for frac in cutoffs:
        # Host-side mask construction over Python floats, no device value.
        lens = np.full(n, max(1, int(round(frac * m))),  # lint: disable=RA103
                       np.int64)
        lens[anchor] = m
        out[frac] = (np.arange(m)[None, :] < lens[:, None]).astype(np.float64)
    return out


def _rank_with_ties(x: np.ndarray) -> np.ndarray:
    """Average-tie ranks (1-based), matching scipy.stats.rankdata."""
    order = np.argsort(x, kind="stable")
    ranks = np.empty(len(x), np.float64)
    i = 0
    while i < len(x):
        j = i
        while j + 1 < len(x) and x[order[j + 1]] == x[order[i]]:
            j += 1
        ranks[order[i:j + 1]] = 0.5 * (i + j) + 1.0
        i = j + 1
    return ranks


def _spearman(a, b) -> float:
    """Spearman rank correlation via Pearson on average-tie ranks.

    Matches ``scipy.stats.spearmanr(a, b).statistic`` (which this repo
    must not depend on — lint rule RA106); constant input gives nan, as
    scipy's does under its ConstantInputWarning.
    """
    ra, rb = _rank_with_ties(np.asarray(a, np.float64)), \
        _rank_with_ties(np.asarray(b, np.float64))
    ra -= ra.mean()
    rb -= rb.mean()
    denom = np.sqrt((ra * ra).sum() * (rb * rb).sum())
    if denom == 0.0:
        return float("nan")
    return float((ra * rb).sum() / denom)


def score_predictions(mean, var, task: CurveTask, mask, valid=None) -> dict:
    """NLL / MAE on unobserved cells + final-value rank correlation.

    ``valid`` (optional (n, m) 0/1 array) restricts scoring to cells where
    ``task.Y_full`` is real ground truth — for censored dataset artifacts
    (no post-cutoff values) pass the artifact's early-stop mask so padding
    zeros are never scored against. The rank correlation likewise only
    ranks configs whose *final* cell is valid. With no scorable hidden
    cell at all, NLL/MAE come back NaN (callers should skip such rows —
    ``head_to_head`` does).
    """
    truth = task.Y_full
    unobs = np.asarray(mask) == 0
    if valid is not None:
        unobs = unobs & (np.asarray(valid) > 0)
    var = np.maximum(np.asarray(var, np.float64), 1e-8)
    resid = np.asarray(mean, np.float64) - truth
    nll_cells = np.asarray(gaussian_nll(np.asarray(mean, np.float64),
                                        np.sqrt(var), truth))
    final_ok = (np.ones(truth.shape[0], bool) if valid is None
                else np.asarray(valid)[:, -1] > 0)
    rho = (_spearman(np.asarray(mean)[final_ok, -1], truth[final_ok, -1])
           if int(final_ok.sum()) >= 2 else float("nan"))
    if not np.isfinite(rho):     # constant predictions -> undefined rank
        rho = 0.0
    any_cell = bool(np.any(unobs))
    return {
        "nll": float(np.mean(nll_cells[unobs])) if any_cell else float("nan"),
        "mae": (float(np.mean(np.abs(resid[unobs]))) if any_cell
                else float("nan")),
        "rank_corr": float(rho),
    }


def eval_lkgp(task: CurveTask, mask, gp_cfg: LKGPConfig | None = None,
              seed: int = 0) -> dict:
    """Fit the LKGP on the masked task; predict mean/var over the grid."""
    gp_cfg = gp_cfg or LKGPConfig(lbfgs_iters=40, seed=seed)
    Y_obs = task.Y_full * mask
    t0 = time.time()
    state = fit(task.X, task.t, Y_obs, mask, gp_cfg)
    fit_s = time.time() - t0
    t0 = time.time()
    post = posterior(state)
    mean = np.asarray(post.mean)
    var = np.asarray(post.variance)      # Matheron MC + observation noise
    predict_s = time.time() - t0
    return {"mean": mean, "var": var, "fit_s": fit_s, "predict_s": predict_s}


def eval_transformer(params, model_cfg: CurveTransformerConfig,
                     task: CurveTask, mask) -> dict:
    """One amortized forward pass (no per-task fitting)."""
    t0 = time.time()
    mean, var = predict_task(params, model_cfg, task.X, task.t,
                             task.Y_full * mask, mask)
    predict_s = time.time() - t0
    return {"mean": mean, "var": var, "fit_s": 0.0, "predict_s": predict_s}


def head_to_head(params, model_cfg: CurveTransformerConfig, tasks,
                 cutoffs=(0.2, 0.4, 0.7), gp_cfg: LKGPConfig | None = None,
                 seed: int = 0, suite: str = "heldout",
                 valid_masks=None) -> list[dict]:
    """Score both models on identical (task, cutoff) cells; one row each.

    ``valid_masks`` (optional, one (n, m) array per task) marks the cells
    whose ``Y_full`` is genuine ground truth — used for censored dataset
    artifacts. Cutoff masks are intersected with it (models never observe
    unobservable cells) and scoring is restricted to it.
    """
    rows = []
    if tasks:
        # Untimed warm-up: the first jitted fit/forward otherwise charges
        # one-time XLA compilation to the first row's wall-clock columns
        # (measured ~300x the steady-state transformer predict time).
        warm = cutoff_masks(tasks[0], cutoffs[:1], seed=seed * 10_007)
        warm_mask = warm[cutoffs[0]]
        eval_transformer(params, model_cfg, tasks[0], warm_mask)
        eval_lkgp(tasks[0], warm_mask, gp_cfg, seed=seed)
    for ti, task in enumerate(tasks):
        masks = cutoff_masks(task, cutoffs, seed=seed * 10_007 + ti)
        # Eval harness: valid_masks arrive as host numpy artifacts.
        valid = (None if valid_masks is None
                 else np.asarray(valid_masks[ti]))  # lint: disable=RA103
        for frac, mask in masks.items():
            if valid is not None:
                mask = mask * valid
                if not np.any((mask == 0) & (valid > 0)):
                    continue   # nothing scorable: every valid cell observed
            preds = {
                "lkgp": eval_lkgp(task, mask, gp_cfg, seed=seed),
                "transformer": eval_transformer(params, model_cfg, task,
                                                mask),
            }
            for name, p in preds.items():
                row = {"suite": suite, "task": ti,
                       "cutoff": float(frac),  # lint: disable=RA103
                       "model": name,
                       "fit_s": round(p["fit_s"], 4),
                       "predict_s": round(p["predict_s"], 4)}
                row.update({k: round(v, 5) for k, v in
                            score_predictions(p["mean"], p["var"], task,
                                              mask, valid=valid).items()})
                rows.append(row)
    return rows
