"""Amortized learning-curve baselines (the paper's Transformer competitor).

The paper's headline experimental claim is that the LKGP "can match the
performance of a Transformer on a learning curve prediction task"; this
package provides that Transformer and the head-to-head harness:

* :mod:`~repro.baselines.curve_transformer` — a curve-prediction
  transformer that encodes (hyper-parameter vector, observed curve prefix
  with explicit missing-value mask) and decodes the full curve as a
  heteroscedastic Gaussian per step, built from the shared
  :mod:`repro.models.layers` blocks;
* :mod:`~repro.baselines.pretrain` — amortized pre-training on streams of
  synthetic tasks from :func:`repro.data.curves.sample_suite` (all noise /
  spike / divergence / crossing regimes) with a curriculum over the
  observed-prefix fraction, driven through
  :func:`repro.train.trainer.make_train_step`;
* :mod:`~repro.baselines.evaluate` — scores the LKGP and the transformer
  on identical held-out suites (NLL, MAE, final-value rank correlation at
  several observation cutoffs, plus fit/predict wall-clock).
"""
from .curve_transformer import (CurveModel, CurveTransformerConfig,
                                build_curve_model, curve_loss, forward,
                                gaussian_nll, layer_table, normalize_t,
                                param_table, predict_task, transformer_stack)
from .evaluate import (cutoff_masks, eval_lkgp, eval_transformer,
                       head_to_head, score_predictions)
from .pretrain import PretrainConfig, pretrain, sample_stream_batch

__all__ = [
    "CurveModel", "CurveTransformerConfig", "build_curve_model",
    "curve_loss", "forward", "gaussian_nll", "layer_table", "normalize_t",
    "param_table", "predict_task", "transformer_stack",
    "PretrainConfig", "pretrain", "sample_stream_batch",
    "cutoff_masks", "eval_lkgp", "eval_transformer", "head_to_head",
    "score_predictions",
]
