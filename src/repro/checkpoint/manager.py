"""Fault-tolerant checkpointing: atomic, keep-K, async, mesh-independent.

Checkpoints are written as one ``.npz`` of host-gathered arrays keyed by
pytree path plus a JSON manifest, into a temp dir that is atomically renamed
(a crash mid-write can never corrupt the latest checkpoint). Restore rebuilds
the pytree and ``jax.device_put``s it with the *target* shardings — which may
belong to a different mesh/device count than the writer's (elastic restart).

An optional background thread makes saves asynchronous; ``wait()`` joins it
(the trainer calls wait() before the next save or at exit).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np

__all__ = ["CheckpointManager"]

_SEP = "//"


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out[key] = leaf
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # -- write --------------------------------------------------------------
    def save(self, step: int, state: Any, extra: dict | None = None):
        flat, _ = _flatten(state)
        # Gather to host np arrays (single-host: device_get; multi-host
        # deployments would use fully_replicated views or per-host shards).
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
        self.wait()
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, host, extra or {}), daemon=True)
            self._thread.start()
        else:
            self._write(step, host, extra or {})

    def _write(self, step: int, host: dict, extra: dict):
        tmp = os.path.join(self.directory, f".tmp_step_{step}_{os.getpid()}")
        final = os.path.join(self.directory, f"step_{step:010d}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "state.npz"),
                 **{k: v for k, v in host.items()})
        manifest = {"step": step, "time": time.time(),
                    "keys": sorted(host.keys()), "extra": extra}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"),
                          ignore_errors=True)

    # -- read ---------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_"):
                try:
                    # Parsing directory names — host strings, no sync.
                    out.append(int(name.split("_")[1]))  # lint: disable=RA103
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, target: Any, step: int | None = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of ``target``.

        ``shardings``: optional pytree of NamedShardings (same structure) for
        elastic restore onto a different mesh; defaults to replicated host
        arrays that jit re-shards on first use.
        """
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = os.path.join(self.directory, f"step_{step:010d}")
        with np.load(os.path.join(path, "state.npz")) as data:
            host = {k: data[k] for k in data.files}
        flat, treedef = _flatten(target)
        missing = set(flat) - set(host)
        if missing:
            raise KeyError(f"checkpoint missing keys: {sorted(missing)[:5]}")
        sh_flat = None
        if shardings is not None:
            sh_flat, _ = _flatten(shardings)
        leaves = {}
        for k, tgt in flat.items():
            arr = host[k]
            if hasattr(tgt, "dtype"):
                arr = arr.astype(tgt.dtype)
            if sh_flat is not None:
                leaves[k] = jax.device_put(arr, sh_flat[k])
            else:
                leaves[k] = jax.numpy.asarray(arr)
        ordered = [leaves[k] for k in flat.keys()]
        return jax.tree_util.tree_unflatten(treedef, ordered)
