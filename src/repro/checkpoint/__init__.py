"""Fault-tolerant checkpoint manager."""
from .manager import CheckpointManager
