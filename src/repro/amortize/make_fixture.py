"""Regenerate the packaged pretrained mini-amortizer fixture.

Run from the repo root::

    PYTHONPATH=src python -m repro.amortize.make_fixture [--steps N] [--out P]

Trains the default d=5 mini-amortizer (the configuration the benchmarks
and the serving layer resolve via ``get_amortizer(5)``) and writes it to
``src/repro/amortize/fixtures/amortizer_d5.npz``. Deterministic given
the seed, but retraining on a different BLAS/hardware stack can shift
weights in the last ulp — commit the regenerated file together with any
encoder change so the fixture always matches the architecture.
"""
from __future__ import annotations

import argparse

from .encoder import FIXTURE_DIR, AmortizerConfig
from .train import AmortizeTrainConfig, train_amortizer


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", type=str, default=None)
    args = ap.parse_args(argv)

    acfg = AmortizerConfig()       # d=5 mini config — keep in sync with docs
    tcfg = AmortizeTrainConfig(steps=args.steps, seed=args.seed)
    am, info = train_amortizer(acfg, tcfg)
    out = args.out or (FIXTURE_DIR / f"amortizer_d{acfg.d}.npz")
    am.save(out)
    print(f"saved {out}  ({info})")


if __name__ == "__main__":
    main()
