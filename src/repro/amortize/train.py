"""Self-supervised amortizer training on synthetic task streams.

The loss needs NO ground-truth hyper-parameters: for every sampled task
the encoder predicts LKGP parameters and is scored by the SAME
per-observation negative penalised marginal likelihood ``fit`` optimises
— ``-(MLL + log prior) / n_obs`` through the exact Cholesky MLL. Driving
the MLL down is exactly what makes the prediction a good warm start, so
the training signal and the downstream use are the same quantity.

Every step draws a fresh batch of tasks from the LCBench-like prior
(:func:`repro.data.curves.sample_suite`) with randomized regimes (noise,
spikes, divergence, crossing, observed-prefix fraction), applies the
per-task data transforms ``fit`` would apply, and takes one optimizer
step through the shared SPMD trainer
(:func:`repro.train.trainer.make_train_step` on a debug mesh) — the same
harness the curve-transformer baseline pretrains with.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.engines import mll_cholesky
from ..core.state import LKGPConfig, _unflatten_params, log_prior
from ..core.transforms import TTransform, XTransform, YTransform
from ..data.curves import sample_suite, stack_suite
from ..distributed.sharding import TP_RULES
from ..models.transformer import table_logical
from ..train.optimizers import OptConfig
from ..train.trainer import make_train_step
from .encoder import (Amortizer, AmortizerConfig, forward, init_amortizer,
                      param_table)

__all__ = ["AmortizeTrainConfig", "AmortizerModel", "build_amortizer_model",
           "sample_amortize_batch", "train_amortizer"]


@dataclass(frozen=True)
class AmortizeTrainConfig:
    steps: int = 400
    tasks_per_step: int = 8
    n: int = 8                 # configs per task
    m: int = 9                 # epochs per task
    seed: int = 0
    peak_lr: float = 1e-3
    prefix_lo: float = 0.15    # observed-fraction window (uniform per curve)
    prefix_hi: float = 0.9
    log_every: int = 50


class AmortizerModel(NamedTuple):
    """Duck-types the zoo ``Model`` for ``make_train_step``."""
    cfg: AmortizerConfig
    param_table: dict
    logical: dict
    init: Callable
    loss: Callable
    predict: Callable


def build_amortizer_model(acfg: AmortizerConfig,
                          gp_cfg: LKGPConfig | None = None) -> AmortizerModel:
    """The trainable model; ``gp_cfg`` fixes the MLL's kernel + jitter so
    training optimises the same objective surface ``fit`` will polish on.
    """
    gp = gp_cfg or LKGPConfig()
    table = param_table(acfg)

    def one_task(params, Xn, tn, Yn, mask):
        flat = forward(params, Xn, tn, Yn, mask, acfg)
        p = _unflatten_params(flat, acfg.d)
        n_obs = jnp.maximum(jnp.sum(mask), 1.0)
        mll = mll_cholesky(p, Xn, tn, Yn, mask, gp.t_kernel, gp.jitter)
        return -(mll + log_prior(p, acfg.d)) / n_obs

    def loss(params, batch, constrain=None):
        per_task = jax.vmap(
            lambda Xn, tn, Yn, mask: one_task(params, Xn, tn, Yn, mask))(
                batch["Xn"], batch["tn"], batch["Yn"], batch["mask"])
        return jnp.mean(per_task)

    return AmortizerModel(
        cfg=acfg, param_table=table, logical=table_logical(table),
        init=lambda key, dtype=acfg.dtype: init_amortizer(key, acfg),
        loss=loss,
        predict=lambda p, Xn, tn, Yn, mask: forward(p, Xn, tn, Yn, mask,
                                                    acfg))


def sample_amortize_batch(acfg: AmortizerConfig, cfg: AmortizeTrainConfig,
                          step: int) -> dict:
    """One batch of TRANSFORMED tasks, all regimes randomized.

    Transforms are fitted per task exactly as ``fit`` does, so the
    encoder trains on the distribution it will be queried on.
    """
    rng = np.random.default_rng(cfg.seed * 1_000_003 + step)
    tasks = sample_suite(
        int(rng.integers(0, 2**31 - 1)), cfg.tasks_per_step,
        n=cfg.n, m=cfg.m, d=acfg.d,
        observed_fraction=(cfg.prefix_lo, cfg.prefix_hi),
        noise=float(rng.uniform(0.003, 0.03)),
        spike_prob=float(rng.uniform(0.0, 0.08)),
        diverge_prob=float(rng.uniform(0.0, 0.08)),
        crossing=bool(rng.random() < 0.5))
    X, t, Y, mask, _ = stack_suite(tasks)
    B = cfg.tasks_per_step
    dt = np.float32
    Xn = np.empty((B, cfg.n, acfg.d), dt)
    Yn = np.empty((B, cfg.n, cfg.m), dt)
    tn = np.empty((B, cfg.m), dt)
    for b in range(B):
        Xb = jnp.asarray(X[b])
        tb = jnp.asarray(t, Xb.dtype)
        Yb = jnp.asarray(Y[b], Xb.dtype)
        mb = jnp.asarray(mask[b], Xb.dtype)
        Yb = jnp.where(mb > 0, Yb, jnp.zeros_like(Yb))
        # Host data pipeline: the per-task syncs ARE the product here (the
        # batch is staged to numpy before the device step), not a leak of
        # device values into Python control flow.
        Xn[b] = np.asarray(XTransform.fit(Xb)(Xb), dt)   # lint: disable=RA103
        tn[b] = np.asarray(TTransform.fit(tb)(tb), dt)   # lint: disable=RA103
        Yn[b] = np.asarray(YTransform.fit(Yb, mb)(Yb), dt)  # lint: disable=RA103
    return {"Xn": Xn, "tn": tn, "Yn": Yn,
            "mask": mask.astype(dt)}


def train_amortizer(acfg: AmortizerConfig | None = None,
                    cfg: AmortizeTrainConfig | None = None,
                    gp_cfg: LKGPConfig | None = None,
                    opt_cfg: OptConfig | None = None, mesh=None,
                    out: Any = print):
    """Train an amortizer from scratch; returns ``(Amortizer, info)``."""
    from ..launch.mesh import make_debug_mesh

    acfg = acfg or AmortizerConfig()
    cfg = cfg or AmortizeTrainConfig()
    model = build_amortizer_model(acfg, gp_cfg)
    if mesh is None:
        mesh = make_debug_mesh(data=len(jax.devices()), model=1)
    opt = opt_cfg or OptConfig(peak_lr=cfg.peak_lr,
                               warmup_steps=max(5, cfg.steps // 20),
                               decay_steps=cfg.steps)
    setup = make_train_step(model, mesh, opt_cfg=opt, rules=TP_RULES)

    t0 = time.time()
    losses = []
    with mesh:
        state = jax.jit(setup.init_state,
                        out_shardings=setup.state_shardings)(
                            jax.random.key(cfg.seed))
        for step in range(cfg.steps):
            batch = {k: jnp.asarray(v)
                     for k, v in sample_amortize_batch(acfg, cfg,
                                                       step).items()}
            state, metrics = setup.step_fn(state, batch)
            # Keep the device scalar: float() here would block on the
            # accelerator every step and kill async dispatch (RA103).
            losses.append(metrics["loss"])
            if cfg.log_every and (step + 1) % cfg.log_every == 0:
                out(f"amortize step {step + 1:5d}  obj "
                    f"{np.mean(losses[-cfg.log_every:]):.4f}")
        params = jax.device_get(state.params)
    info = {
        "steps": cfg.steps,
        "train_s": round(time.time() - t0, 3),
        "first_loss": round(float(np.mean(losses[:20])), 5),
        "final_loss": round(float(np.mean(losses[-20:])), 5),
    }
    return Amortizer(acfg, jax.tree_util.tree_map(jnp.asarray, params)), info
