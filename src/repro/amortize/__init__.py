"""Amortized hyper-parameter initialisation for the LKGP.

A set encoder (:mod:`~repro.amortize.encoder`, built from the curve
transformer's shared blocks) maps a masked task straight to the LKGP's
unconstrained parameter vector; ``fit(init="amortized")`` starts there
and needs only a fixed-budget device polish (:mod:`repro.core.polish`)
instead of a full host L-BFGS. Training
(:mod:`~repro.amortize.train`) is self-supervised on synthetic task
streams with the fit objective itself as the loss — no ground-truth
hyper-parameters anywhere. A pretrained mini-amortizer ships as a
packaged fixture (``fixtures/amortizer_d5.npz``; regenerate with
``python -m repro.amortize.make_fixture``) and is what
``LKGPConfig(hyper_init="amortized")`` resolves to by default.
"""
from .encoder import (FIXTURE_DIR, Amortizer, AmortizerConfig,
                      clear_amortizer_registry, forward, get_amortizer,
                      init_amortizer, param_table, register_amortizer)
from .train import (AmortizeTrainConfig, AmortizerModel,
                    build_amortizer_model, sample_amortize_batch,
                    train_amortizer)

__all__ = [
    "Amortizer", "AmortizerConfig", "FIXTURE_DIR", "forward",
    "get_amortizer", "register_amortizer", "clear_amortizer_registry",
    "init_amortizer", "param_table",
    "AmortizeTrainConfig", "AmortizerModel", "build_amortizer_model",
    "sample_amortize_batch", "train_amortizer",
]
