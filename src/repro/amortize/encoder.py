"""Hyper-parameter amortizer: a set encoder from curves to LKGP params.

The encoder maps a whole masked task — hyper-parameter vectors ``X``
(n, d), progression grid ``t`` (m,), observed curves ``Y`` / ``mask``
(n, m) — directly to the LKGP's unconstrained parameter vector
(d ARD log-lengthscales, t log-lengthscale, log-outputscale, log-noise),
so a fit can start from a data-dependent point instead of the prior mean
and finish with a handful of polish steps (:mod:`repro.core.polish`)
rather than a full host L-BFGS.

Architecture — deliberately the curve transformer re-used twice:

1. **curve stage**: each curve becomes ``m`` epoch tokens
   (:func:`repro.baselines.curve_transformer.encode_features`) plus a
   conditioning token embedding its hyper-parameter vector, run through
   the shared bidirectional encoder blocks
   (:func:`~repro.baselines.curve_transformer.transformer_stack`); the
   conditioning token's output summarises the curve;
2. **set stage**: the ``n`` curve summaries attend to each other through
   a second (smaller) stack of the same blocks — cross-curve structure
   like crossing/divergence is what determines good lengthscales — and
   are mean-pooled;
3. **head**: a gelu MLP decodes a bounded *delta* around the prior-mean
   init: ``base + delta_scale * tanh(delta / delta_scale)``. The last
   head weight is zero-initialised, so an untrained amortizer predicts
   exactly :func:`repro.core.state.init_params` — training can only
   improve on the default init, never start worse.

The encoder consumes the *transformed* view of the data (the same
``Xn / tn / Yn / mask`` the MLL objective sees), which is what
``fit(init="amortized")`` passes it — no second normalisation scheme.

Batch invariance: :meth:`Amortizer.init_batch` dispatches the ONE
compiled single-task forward once per task rather than vmapping, so the
amortized init used by a coalesced ``fit_batch`` is bitwise identical to
the one a single-task ``fit`` computes (same policy, same reason, as the
polish in :mod:`repro.core.state`).
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass
from pathlib import Path
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..baselines.curve_transformer import (CurveTransformerConfig,
                                           encode_features, layer_table,
                                           transformer_stack)
from ..baselines.curve_transformer import param_table as curve_param_table
from ..core.state import (LKGPParams, _flatten_params, _unflatten_params,
                          init_params)
from ..models.layers import rms_norm
from ..models.transformer import build_params

__all__ = ["AmortizerConfig", "Amortizer", "param_table", "init_amortizer",
           "forward", "get_amortizer", "register_amortizer",
           "clear_amortizer_registry", "FIXTURE_DIR"]

FIXTURE_DIR = Path(__file__).resolve().parent / "fixtures"


@dataclass(frozen=True)
class AmortizerConfig:
    """Shape configuration; ``d`` is the hyper-parameter dimension."""
    d: int = 5
    d_model: int = 32
    curve_layers: int = 2      # per-curve encoder depth
    set_layers: int = 1        # cross-curve encoder depth
    num_heads: int = 4
    d_ff: int = 64
    mlp_act: str = "swiglu"
    norm_eps: float = 1e-6
    fourier_feats: int = 4
    delta_scale: float = 3.0   # bound on |predicted - default| per coordinate
    dtype: Any = jnp.float32

    @property
    def n_out(self) -> int:
        """Flat unconstrained LKGP parameter count (see ``LKGPParams``)."""
        return self.d + 3

    def curve_cfg(self) -> CurveTransformerConfig:
        """The curve-transformer view of this config (shared blocks)."""
        return CurveTransformerConfig(
            d_in=self.d, d_model=self.d_model, num_layers=self.curve_layers,
            num_heads=self.num_heads, d_ff=self.d_ff, mlp_act=self.mlp_act,
            norm_eps=self.norm_eps, fourier_feats=self.fourier_feats,
            dtype=self.dtype)


# --------------------------------------------------------------------------
# parameter table / init
# --------------------------------------------------------------------------
def param_table(cfg: AmortizerConfig):
    """Curve-transformer table minus its Gaussian head, plus set stage + head.

    ``set_final_norm`` ends with ``final_norm`` on purpose: the zoo's
    :func:`repro.models.transformer.build_params` zero-initialises norm
    scales by name suffix.
    """
    ccfg = cfg.curve_cfg()
    D = cfg.d_model
    table = {k: v for k, v in curve_param_table(ccfg).items()
             if not k.startswith("head/")}
    for k, (shape, logical, fan) in layer_table(ccfg).items():
        table[f"set_layers/{k}"] = ((cfg.set_layers, *shape),
                                    ("layers", *logical), fan)
    table["set_final_norm"] = ((D,), ("embed",), None)
    table["head/w0"] = ((D, D), ("embed", None), D)
    table["head/b0"] = ((D,), (None,), None)
    table["head/w1"] = ((D, cfg.n_out), ("embed", None), D)
    return table


def init_amortizer(key, cfg: AmortizerConfig):
    """Fresh parameters; the last head weight is zeroed so the untrained
    encoder predicts exactly the prior-mean default init (identity start).
    """
    p = build_params(key, param_table(cfg), cfg.dtype)
    p["head"]["w1"] = jnp.zeros_like(p["head"]["w1"])
    return p


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------
def forward(params, Xn, tn, Yn, mask, cfg: AmortizerConfig):
    """One task -> flat unconstrained LKGP parameter vector (d + 3,).

    ``Xn`` (n, d), ``tn`` (m,), ``Yn`` / ``mask`` (n, m) are the
    TRANSFORMED training data (unit-cube configs, [0, 1] progressions,
    normalised curves) — exactly what the MLL objective consumes.
    """
    ccfg = cfg.curve_cfg()
    dt = ccfg.dtype
    x = encode_features(Yn.astype(dt), mask.astype(dt), tn.astype(dt), ccfg)
    x = x @ params["in_proj"]["w"] + params["in_proj"]["b"]
    h0 = jax.nn.gelu(Xn.astype(dt) @ params["hp_embed"]["w0"]
                     + params["hp_embed"]["b0"])
    h0 = h0 @ params["hp_embed"]["w1"]
    x = jnp.concatenate([h0[:, None, :], x], axis=1)       # (n, m + 1, D)
    x = transformer_stack(x, params["layers"], ccfg)
    e = rms_norm(x, params["final_norm"], ccfg.norm_eps)[:, 0, :]  # (n, D)
    s = transformer_stack(e[None], params["set_layers"], ccfg)[0]
    s = rms_norm(s, params["set_final_norm"], ccfg.norm_eps)
    pooled = jnp.mean(s, axis=0)
    h = jax.nn.gelu(pooled @ params["head"]["w0"] + params["head"]["b0"])
    delta = h @ params["head"]["w1"]
    base = _flatten_params(init_params(cfg.d, delta.dtype))
    scale = jnp.asarray(cfg.delta_scale, delta.dtype)
    return base + scale * jnp.tanh(delta / scale)


# --------------------------------------------------------------------------
# the user-facing artifact
# --------------------------------------------------------------------------
class Amortizer:
    """A (pre)trained amortizer bound to one compiled forward program."""

    def __init__(self, cfg: AmortizerConfig, params):
        self.cfg = cfg
        self.params = params
        self._fwd = jax.jit(
            lambda p, Xn, tn, Yn, mask: forward(p, Xn, tn, Yn, mask, cfg))

    def init_flat(self, Xn, tn, Yn, mask) -> jnp.ndarray:
        """Predicted flat unconstrained parameter vector for one task."""
        return self._fwd(self.params, jnp.asarray(Xn), jnp.asarray(tn),
                         jnp.asarray(Yn), jnp.asarray(mask))

    def init_for(self, Xn, tn, Yn, mask) -> LKGPParams:
        """Predicted :class:`LKGPParams` for one (transformed) task."""
        return _unflatten_params(self.init_flat(Xn, tn, Yn, mask), self.cfg.d)

    def init_batch(self, Xn, tn, Yn, mask) -> LKGPParams:
        """Per-task predictions for a (B, ...) stack, leading axis B.

        Dispatches the single-task program once per task (NOT vmap) so
        every row is bitwise identical to :meth:`init_for` on that task —
        the invariant ``fit_batch`` relies on (see module docstring).
        """
        B = Xn.shape[0]
        flats = jnp.stack([self.init_flat(Xn[i], tn[i], Yn[i], mask[i])
                           for i in range(B)])
        return jax.vmap(lambda f: _unflatten_params(f, self.cfg.d))(flats)

    # ---- persistence -----------------------------------------------------
    def save(self, path) -> None:
        """Write a self-describing ``.npz`` (config json + flat param paths)."""
        flat = _flatten_tree(self.params)
        cfg = asdict(self.cfg)
        cfg["dtype"] = jnp.dtype(cfg["dtype"]).name
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        np.savez(path, __cfg__=np.asarray(json.dumps(cfg)),
                 **{k: np.asarray(v) for k, v in flat.items()})

    @classmethod
    def load(cls, path) -> "Amortizer":
        with np.load(path) as z:
            cfg_d = json.loads(str(z["__cfg__"]))
            cfg_d["dtype"] = jnp.dtype(cfg_d["dtype"])
            cfg = AmortizerConfig(**cfg_d)
            params = _nest_tree({k: jnp.asarray(z[k], cfg.dtype)
                                 for k in z.files if k != "__cfg__"})
        return cls(cfg, params)


def _flatten_tree(tree, prefix: str = ""):
    out = {}
    for k in sorted(tree):
        v = tree[k]
        if isinstance(v, dict):
            out.update(_flatten_tree(v, f"{prefix}{k}/"))
        else:
            out[f"{prefix}{k}"] = v
    return out


def _nest_tree(flat):
    out: dict = {}
    for path, v in flat.items():
        node = out
        *parents, leaf = path.split("/")
        for p in parents:
            node = node.setdefault(p, {})
        node[leaf] = v
    return out


# --------------------------------------------------------------------------
# registry: fit(init="amortized") resolves through here
# --------------------------------------------------------------------------
_REGISTRY: dict[int, Amortizer] = {}


def register_amortizer(am: Amortizer) -> Amortizer:
    """Make ``am`` the process-wide amortizer for its ``d``; returns it."""
    _REGISTRY[am.cfg.d] = am
    return am


def clear_amortizer_registry() -> None:
    _REGISTRY.clear()


def get_amortizer(d: int) -> Amortizer:
    """The registered amortizer for ``d``, lazily falling back to the
    packaged pretrained fixture (``fixtures/amortizer_d{d}.npz``)."""
    am = _REGISTRY.get(d)
    if am is None:
        path = FIXTURE_DIR / f"amortizer_d{d}.npz"
        if not path.exists():
            raise ValueError(
                f"no amortizer registered for d={d} and no packaged fixture "
                f"at {path}; train one with repro.amortize.train_amortizer "
                "and register_amortizer(...), or pass amortizer= explicitly")
        am = register_amortizer(Amortizer.load(path))
    return am
