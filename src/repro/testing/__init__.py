"""Test-support utilities shipped with the library.

:mod:`repro.testing.faults` — composable fault injectors (NaN payloads,
near-singular operators, forced solver breakdown, eviction, crash/restore)
driving the chaos suite (``tests/test_reliability.py``) and the
reliability benchmark (``benchmarks/bench_reliability.py``).
"""
from .faults import (FaultSchedule, FlakySolver, NegatedOperator,
                     arm_flaky_solver, crash_and_restore, evict_session,
                     near_singular_problem, poison_nan)

__all__ = [
    "NegatedOperator", "FlakySolver", "arm_flaky_solver", "poison_nan",
    "near_singular_problem", "evict_session", "crash_and_restore",
    "FaultSchedule",
]
