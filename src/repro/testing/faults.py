"""Composable fault injectors for the reliability chaos suite.

Each injector produces exactly ONE kind of failure the reliability layer
claims to survive, deterministically, so the chaos tests can assert not
just "nothing crashed" but precisely which detection point fired:

* :class:`NegatedOperator` — wraps an SPD operator as ``u -> -A(u)``:
  every CG/PCG iteration sees ``p^T A p < 0`` and flags per-column
  ``breakdown`` (detection: solver diagnostics -> guarded-solve ladder).
* :class:`FlakySolver` (registry name ``"flaky"``) — an armed solver that
  returns an instant fake breakdown for the next N calls, then delegates
  to plain CG. Escalation succeeds on the first ladder rung at roughly
  the cost of one clean CG solve, which is what the latency-inflation
  benchmark measures (detection: guarded-solve health check).
* :func:`poison_nan` — plants NaNs at newly-observed cells of an
  ``extend`` payload (detection: ``check_observed_finite`` at the
  streaming boundary -> service quarantine).
* :func:`near_singular_problem` — duplicated rows + tiny noise make the
  gram factors near-singular (detection: escalation ladder's jitter
  retries).
* :func:`evict_session` — forces an LRU-style eviction mid-workload.
* :func:`crash_and_restore` — simulated process crash: a FRESH service
  over the same checkpoint directory, rebuilt via ``restore()``.
* :class:`FaultSchedule` — maps workload rounds to injector thunks so a
  whole chaos scenario is one declarative object.
"""
from __future__ import annotations

import threading
from typing import Any, Callable

import jax.numpy as jnp
import numpy as np

from ..core.solvers import (CGResult, StackedSolveResult, get_solver,
                            register_solver)

__all__ = [
    "NegatedOperator", "FlakySolver", "arm_flaky_solver", "poison_nan",
    "near_singular_problem", "evict_session", "crash_and_restore",
    "FaultSchedule",
]


class NegatedOperator:
    """``u -> -A(u)``: a maximally indefinite wrapper around an SPD operator.

    Attribute access (mask, Kronecker factors, preconditioner) delegates to
    the base operator, so solver routing and the guarded dense fallback see
    the INTENDED model matrix — exactly the situation the fallback exists
    for: a broken operator realisation over healthy factors.
    """

    def __init__(self, base: Callable) -> None:
        self._base = base

    def __call__(self, u: jnp.ndarray) -> jnp.ndarray:
        return -self._base(u)

    def __getattr__(self, name: str) -> Any:
        return getattr(self._base, name)


def _fake_breakdown(b: jnp.ndarray) -> CGResult:
    """Instant all-columns-broke result (no operator applications at all)."""
    sys_shape = b.shape[:-2]
    return CGResult(
        x=jnp.zeros_like(b), iters=jnp.int32(0),
        rel_residual=jnp.ones(sys_shape, b.dtype),
        breakdown=jnp.ones(sys_shape, bool),
        col_iters=jnp.zeros(sys_shape, jnp.int32), matvecs=jnp.int32(0))


@register_solver("flaky")
class FlakySolver:
    """Armed fault: fake breakdown for the next N solves, then plain CG.

    The fake failure costs zero operator sweeps, so an escalated solve
    through this fault pays ~one clean CG solve plus ladder bookkeeping —
    the escalated-vs-clean p99 comparison in ``bench_reliability``
    measures guard overhead, not an artificially slow fault.
    """

    def __init__(self) -> None:
        self._armed = 0
        self._lock = threading.Lock()

    def arm(self, n: int) -> None:
        with self._lock:
            self._armed = int(n)

    def _trip(self) -> bool:
        with self._lock:
            if self._armed > 0:
                self._armed -= 1
                return True
            return False

    def solve(self, A: Callable, b: jnp.ndarray, config: Any,
              x0: jnp.ndarray | None = None) -> CGResult:
        if self._trip():
            return _fake_breakdown(b)
        return get_solver("cg").solve(A, b, config, x0=x0)

    def solve_stacked(self, A: Callable, rhs: jnp.ndarray, config: Any, *,
                      probe_cols: int = 0, subspace_dim: Any = None,
                      x0: jnp.ndarray | None = None) -> StackedSolveResult:
        if self._trip():
            res = _fake_breakdown(rhs)
            return StackedSolveResult(x=res.x, logdet=None, result=res)
        return get_solver("cg").solve_stacked(
            A, rhs, config, probe_cols=probe_cols,
            subspace_dim=subspace_dim, x0=x0)


def arm_flaky_solver(n: int) -> "FlakySolver":
    """Arm the registered ``"flaky"`` solver singleton for the next N solves."""
    solver = get_solver("flaky")
    solver.arm(n)
    return solver


def poison_nan(Y, mask, cells: int = 1):
    """Extend-payload poisoner: mark ``cells`` new cells observed, value NaN.

    Grows each poisoned row's mask by one cell (stays a superset of the
    input mask, so only the finiteness guard can be the detector) and puts
    ``nan`` there. Returns (Y_poisoned, mask_poisoned) as numpy arrays.
    """
    Y = np.array(Y, copy=True)
    mask = np.array(mask, copy=True)
    planted = 0
    seen_per_row = mask.sum(axis=1).astype(np.int64)
    for row in range(mask.shape[0]):
        if planted >= cells:
            break
        seen = seen_per_row[row]
        if seen < mask.shape[1]:
            mask[row, seen] = 1.0
            Y[row, seen] = np.nan
            planted += 1
    if planted == 0:
        raise ValueError("mask is already full; nowhere to plant a NaN")
    return Y, mask


def near_singular_problem(n: int = 8, m: int = 6, d: int = 3,
                          noise: float = 1e-10, seed: int = 0):
    """An ill-conditioned LKGP system: duplicated configs + ~zero noise.

    Every config row is (near-)duplicated, so ``K1`` has (near-)repeated
    columns and the masked system's condition number blows up; the tiny
    noise removes the diagonal regularisation that normally hides it.
    Returns ``(K1, K2, mask, Y, noise)`` in the same layout the solver
    tests use.
    """
    import jax

    from ..core.state import gram_matrices, init_params

    key = jax.random.PRNGKey(seed)
    kx, ky = jax.random.split(key)
    half = jax.random.uniform(kx, ((n + 1) // 2, d), jnp.float64)
    X = jnp.concatenate([half, half + 1e-9], axis=0)[:n]
    t = jnp.linspace(0.05, 1.0, m).astype(jnp.float64)
    K1, K2 = gram_matrices(init_params(d, jnp.float64), X, t, jitter=0.0)
    mask = jnp.ones((n, m), jnp.float64)
    Y = jax.random.normal(ky, (n, m), jnp.float64)
    return K1, K2, mask, Y, jnp.float64(noise)


def evict_session(service, tenant: str, task: str) -> bool:
    """Mid-workload eviction: drop a session from the store (LRU-style)."""
    from ..serving.store import SessionKey

    return service.store.drop(SessionKey(tenant, task))


def crash_and_restore(service, step: int | None = None):
    """Simulated crash: fresh service over the same checkpoint directory.

    The old service object is abandoned exactly as a killed process would
    abandon its memory; the replacement rebuilds warm sessions via
    ``restore()``. Returns ``(new_service, sessions_restored)``.
    """
    from ..serving.service import PredictionService

    if service.checkpointer is None:
        raise RuntimeError("service has no checkpoint_dir; nothing to "
                           "restore a crash from")
    replacement = PredictionService(service.config)
    restored = replacement.restore(step)
    return replacement, restored


class FaultSchedule:
    """Declarative round -> injectors mapping for chaos scenarios.

    ``add(round, fn)`` registers an injector thunk; ``fire(round, **ctx)``
    runs every injector registered for that round (in registration order)
    and returns their results. Injectors receive the context kwargs the
    driver passes (e.g. ``service=...``).
    """

    def __init__(self) -> None:
        self._by_round: dict[int, list[Callable]] = {}

    def add(self, round_idx: int, injector: Callable) -> "FaultSchedule":
        self._by_round.setdefault(int(round_idx), []).append(injector)
        return self

    def rounds(self) -> list[int]:
        return sorted(self._by_round)

    def fire(self, round_idx: int, **ctx: Any) -> list:
        return [fn(**ctx) for fn in self._by_round.get(int(round_idx), [])]
