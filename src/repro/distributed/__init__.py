"""Mesh sharding rules, collectives, distributed LKGP."""
from .sharding import (ACT_RULES, FSDP_RULES, TP_RULES, batch_shardings,
                       dp_axes, logical_to_pspec, make_constrain,
                       param_shardings, rules_for)
