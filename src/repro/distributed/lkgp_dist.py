"""Distributed latent-Kronecker MVM and CG via shard_map.

TPU-native distribution of the paper's primitive (DESIGN.md §3): rows of the
latent grid (hyper-parameter configs) shard over the 'data' mesh axis; K2
(m x m) is replicated. One MVM is then

    T_loc = (mask_loc * U_loc) @ K2          local    O(n/p * m^2)
    S_loc = K1[rows_loc, :] @ all_gather(T)  1 gather O(n^2/p * m)
    out   = mask_loc * S_loc + noise * U_loc

i.e. a single all-gather of the (n, m) intermediate per CG iteration —
communication O(nm) vs compute O(n^2 m / p + n m^2 / p).

K1 itself is built distributed: each shard evaluates its row block
k1(X_loc, X_full) after one all-gather of X (n x d, tiny). Memory per device
is O(n^2/p + m^2), so a 100k-config sweep fits a pod.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from ..compat import shard_map

from ..core.gp_kernels import KERNELS_1D, rbf_ard

__all__ = ["dist_lk_operator", "dist_lk_mvm_fused", "dist_cg_solve",
           "dist_mll_value"]


def _row_sharded(mesh, *trailing):
    return P("data", *trailing)


def dist_lk_operator(mesh: Mesh, K1_rows, K2, mask, noise):
    """Returns a jit-ready distributed operator u -> A(u).

    K1_rows: (n, n) sharded P('data', None) — row block per device.
    mask, u: (n, m) sharded P('data', None). K2: (m, m) replicated.
    """

    def body(k1r, k2, msk, u):
        t_loc = (msk * u) @ k2                       # (n/p, m)
        t_full = jax.lax.all_gather(t_loc, "data", axis=0, tiled=True)
        s_loc = k1r @ t_full                          # (n/p, m)
        return msk * s_loc + noise * (msk * u)

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P("data", None), P(None, None), P("data", None),
                  P("data", None)),
        out_specs=P("data", None),
        check_vma=False,
    )
    return functools.partial(fn, K1_rows, K2, mask)


def dist_lk_mvm_fused(mesh: Mesh, K1_rows, K2, mask, noise, *,
                      block_n: int = 128, block_m: int = 128,
                      precision: str = "f32",
                      interpret: bool | None = None):
    """Distributed operator u -> A(u) running the FUSED Pallas kernel per shard.

    Same sharding contract as :func:`dist_lk_operator` (K1_rows / mask / u
    row-sharded P('data', None), K2 replicated), but each shard's row-block
    MVM is one :func:`repro.kernels.lk_mvm.lk_mvm_fused_rows` pallas_call
    instead of the two-stage einsum reference: the (n/p, m) stage-R
    intermediate lives only in VMEM. Communication is unchanged — one
    all-gather of the pre-masked (n, m) input per MVM; the gathered operand
    feeds the kernel's global k sweep while the local mask/u rows feed its
    epilogue.

    The kernel accumulates in f32 (or bf16-compute with ``precision=
    "bf16"``), so callers wanting f64-exact semantics (e.g. x64 parity
    tests) should use :func:`dist_lk_operator`. Block sizes should come
    from :func:`repro.analysis.vmem.best_fitting_blocks` evaluated at the
    PER-SHARD shape (n/p, m) — :class:`repro.core.engines.DistributedEngine`
    does exactly that.
    """
    from ..kernels.lk_mvm import lk_mvm_fused_rows

    def body(k1r, k2, msk, u):
        um_loc = msk * u                              # (n/p, m)
        um_full = jax.lax.all_gather(um_loc, "data", axis=0, tiled=True)
        return lk_mvm_fused_rows(k1r, k2, msk, u, um_full, noise,
                                 block_n=block_n, block_m=block_m,
                                 precision=precision, interpret=interpret)

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P("data", None), P(None, None), P("data", None),
                  P("data", None)),
        out_specs=P("data", None),
        check_vma=False,
    )
    return functools.partial(fn, K1_rows, K2, mask)


def dist_cg_solve(A, b, tol=0.01, max_iters=10_000, x0=None):
    """CG on distributed grid vectors (the reductions are global jnp.sums,
    which XLA lowers to psums over the sharded rows). ``x0`` warm-starts
    the solve (scheduler refits re-solve against a nearby operator)."""
    b_norm = jnp.sqrt(jnp.sum(b * b))
    safe = jnp.where(b_norm == 0, 1.0, b_norm)
    if x0 is None:
        x0 = jnp.zeros_like(b)
    r0 = b - A(x0)

    def cond(state):
        _, _, _, rs, it = state
        return jnp.logical_and(jnp.sqrt(rs) / safe > tol, it < max_iters)

    def step(state):
        x, r, p, rs, it = state
        Ap = A(p)
        alpha = rs / jnp.maximum(jnp.sum(p * Ap), 1e-30)
        x = x + alpha * p
        r = r - alpha * Ap
        rs_new = jnp.sum(r * r)
        p = r + (rs_new / jnp.maximum(rs, 1e-30)) * p
        return (x, r, p, rs_new, it + 1)

    x, _, _, rs, it = jax.lax.while_loop(
        cond, step, (x0, r0, r0, jnp.sum(r0 * r0), jnp.int32(0)))
    return x, it, jnp.sqrt(rs) / safe


def dist_mll_value(mesh: Mesh, params_ls, params_tls, params_os, params_noise,
                   X, t, Y, mask, t_kernel="matern12", jitter=1e-6,
                   cg_tol=0.01, cg_max_iters=10_000):
    """Distributed MLL quadratic term (row-sharded X / Y / mask).

    Builds K1's row block per device (all-gather of X), runs distributed CG,
    and returns -0.5 y^T alpha (the log-det term uses SLQ with the same
    distributed operator; see core.slq). Used by the dry-run 'lkgp' cell and
    the scaling benchmark's distributed mode.
    """

    def build_k1_rows(x_loc, x_same):
        x_full = jax.lax.all_gather(x_same, "data", axis=0, tiled=True)
        return rbf_ard(x_loc, x_full, params_ls)

    k1_rows = shard_map(
        build_k1_rows, mesh=mesh,
        in_specs=(P("data", None), P("data", None)),
        out_specs=P("data", None), check_vma=False)(X, X)
    # jitter on the diagonal of the row block
    n = X.shape[0]
    diag = jitter * jnp.eye(n, dtype=X.dtype)
    k1_rows = k1_rows + diag

    K2 = KERNELS_1D[t_kernel](t, t, params_tls, params_os)
    K2 = K2 + jitter * jnp.eye(t.shape[0], dtype=t.dtype)

    A = dist_lk_operator(mesh, k1_rows, K2, mask, params_noise)
    alpha, iters, rel = dist_cg_solve(A, Y * mask, tol=cg_tol,
                                      max_iters=cg_max_iters)
    quad = -0.5 * jnp.sum((Y * mask) * alpha)
    return quad, iters, rel
