"""Logical-axis sharding rules (MaxText-style) for the production meshes.

Parameters and activations carry *logical* axis names (see the per-model
param tables); rules map logical names to mesh axes. The resolver drops any
mesh axis that does not evenly divide the dimension (NamedSharding requires
even tiling) and never uses a mesh axis twice within one spec — so e.g.
phi3's 40 heads fall back to fused-dim sharding and batch=1 decode shapes
fall back to replication, by construction rather than by special case.
"""
from __future__ import annotations

from typing import Any, Mapping

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["TP_RULES", "FSDP_RULES", "ZERO_RULES", "SERVE_RULES", "ACT_RULES",
           "rules_for", "logical_to_pspec", "make_constrain",
           "param_shardings", "batch_shardings", "dp_axes",
           "set_active_mesh", "get_active_mesh"]

# Mesh context for shard_map-based layers (the MoE expert-parallel path).
# Set by the trainer / serve / dry-run builders; None in single-device tests,
# which then use the pure-einsum reference implementation.
_ACTIVE_MESH: list = [None]


def set_active_mesh(mesh):
    _ACTIVE_MESH[0] = mesh


def get_active_mesh():
    return _ACTIVE_MESH[0]

# -- parameter rules --------------------------------------------------------
TP_RULES: dict[str, Any] = {
    "vocab": "model",
    "heads_fused": "model",
    "kv_fused": "model",
    "heads": "model",
    "mlp": "model",
    "experts": "model",
    "rnn": "model",
    "embed": None,
    "embed_out": None,
    "rnn_in": None,
    "moe_groups": "data",
    "layers": None,
    "batch": None,          # parameters have no batch axis
}

# FSDP additionally shards the d_model ("embed") dim of weights over 'data'
# (ZeRO-3 style: optimizer state and parameters fully sharded; XLA inserts
# all-gathers at use sites). Used for the >=10B archs.
FSDP_RULES = dict(TP_RULES, embed="data", rnn_in="data", embed_out="data")

# Pure ZeRO-DP (§Perf hillclimb 3): no tensor parallelism — both mesh axes
# are data-parallel for activations; weights/optimizer state shard 256-way on
# their widest dim and are all-gathered per layer. Wins when per-layer
# weight bytes < per-layer activation all-reduce bytes (dense <=72B here).
ZERO_RULES = dict(
    TP_RULES,
    heads_fused=None, kv_fused=None, heads=None, mlp=None,
    experts=None, rnn=None,
    # every weight shards 256-way on its d_model ("embed") dim; the vocab dim
    # of the embedding table takes whatever axis remains so the table is also
    # fully sharded (iter-3: avoids replicating multi-GiB tables at lookup).
    vocab=("data", "model"),
    embed=("data", "model"), rnn_in=("data", "model"),
    embed_out=("data", "model"),
)
ZERO_ACT_RULES = {
    "batch": ("pod", "data", "model"),
    "seq": None,
    "heads": None, "vocab": None, "mlp": None, "embed": None,
    "experts": None, "moe_groups": None, "rnn": None,
}

# Serving (§Perf hillclimb 2): weights stay RESIDENT (no FSDP gathers per
# token) — TP over 'model', and the MoE/MLP inner dim additionally over
# 'data' so the 480B-class experts fit (psums of decode activations are
# tiny). Optimizer state does not exist at serve time.
SERVE_RULES = dict(TP_RULES, mlp=("model", "data"))

# Decode-specific layout (§Perf hillclimb 2, iter 4): 2D tensor parallelism
# over BOTH axes — weights shard 256-way on (d_model x d_ff) so a 72B dense
# model costs ~0.6 GiB/chip resident, and every per-token collective is a
# psum of (B, 1, .) activations (KBs). Wrong for prefill (token-heavy), right
# for decode (weight-heavy).
SERVE_DECODE_RULES = dict(
    TP_RULES,
    embed="model", mlp="data", heads_fused=None, kv_fused=None, heads=None,
    vocab="data", experts="model", rnn="data", rnn_in="model",
    embed_out="data",
)

# -- activation rules -------------------------------------------------------
ACT_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "seq": None,
    "heads": "model",
    "vocab": "model",
    "mlp": "model",
    "embed": None,
    "experts": "model",
    "moe_groups": "data",
    "rnn": "model",
}

# Sequence parallelism for the MoE trains (§Perf hillclimb 1, iteration 2):
# layer-boundary activations (the remat'd scan carries) shard their sequence
# dim over 'model', cutting saved-activation HBM 16x for one AG/RS pair per
# layer. Used with the expert-parallel shard_map MoE.
SP_ACT_RULES = dict(ACT_RULES, seq="model")


def rules_for(cfg, param_count: int | None = None) -> dict[str, Any]:
    """Pick parameter rules by model scale (FSDP for the big archs)."""
    from ..models.registry import count_params

    n = param_count if param_count is not None else count_params(cfg)
    return FSDP_RULES if n >= 1e10 else TP_RULES


def _resolve(name, rules):
    axes = rules.get(name, None) if name is not None else None
    if axes is None:
        return ()
    if isinstance(axes, str):
        return (axes,)
    return tuple(axes)


def logical_to_pspec(logical, rules: Mapping[str, Any], mesh: Mesh,
                     shape) -> P:
    """Map a logical-axis tuple to a PartitionSpec valid for ``shape``."""
    used: set[str] = set()
    out = []
    for dim, name in zip(shape, logical):
        names = name if isinstance(name, tuple) else (name,)
        axes = []
        for n in names:
            axes.extend(_resolve(n, rules))
        # drop axes not in the mesh, already used, or not dividing the dim
        kept = []
        prod = 1
        for a in axes:
            if a not in mesh.shape or a in used:
                continue
            if dim % (prod * mesh.shape[a]) != 0:
                continue
            kept.append(a)
            prod *= mesh.shape[a]
        for a in kept:
            used.add(a)
        if not kept:
            out.append(None)
        elif len(kept) == 1:
            out.append(kept[0])
        else:
            out.append(tuple(kept))
    return P(*out)


def make_constrain(mesh: Mesh, act_rules: Mapping[str, Any] | None = None):
    """Activation-constraint callback passed into the model functions."""
    act_rules = act_rules or ACT_RULES

    def constrain(t, logical):
        spec = logical_to_pspec(logical, act_rules, mesh, t.shape)
        return jax.lax.with_sharding_constraint(t, NamedSharding(mesh, spec))

    return constrain


def param_shardings(logical_tree, mesh: Mesh, rules, shape_tree):
    """NamedSharding pytree for parameters (same structure as params)."""
    return jax.tree_util.tree_map(
        lambda logical, sds: NamedSharding(
            mesh, logical_to_pspec(logical, rules, mesh, sds.shape)),
        logical_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, tuple, type(None))) for e in x),
    )


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def batch_shardings(specs: dict, mesh: Mesh):
    """Shard every batch input over the data-parallel axes (dim 0)."""
    dp = dp_axes(mesh)

    def one(sds):
        prod = 1
        kept = []
        for a in dp:
            if sds.shape[0] % (prod * mesh.shape[a]) == 0:
                kept.append(a)
                prod *= mesh.shape[a]
        spec = P(tuple(kept) if kept else None,
                 *([None] * (len(sds.shape) - 1)))
        return NamedSharding(mesh, spec)

    return {k: one(v) for k, v in specs.items()}
