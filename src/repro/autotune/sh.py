"""Successive Halving and Hyperband with LKGP-ranked promotion.

Successive Halving (Jamieson & Talwalkar, 2016) runs a pool of configs in
rungs: every config reaches ``min_epochs * eta^k`` epochs at rung k, then
only the top ``1/eta`` fraction is promoted. The classic promotion rule
ranks configs by their *current* observed metric — which systematically
kills slow starters. Following Lin et al. 2025 (arXiv:2508.14818), the
LKGP mode instead ranks by the model's predicted *final-epoch* metric
(UCB or quantile of the predictive distribution from
:class:`~repro.autotune.predictor.CurvePredictor`), so curves that cross
later are promoted on their extrapolated value.

:class:`HyperbandScheduler` (Li et al., 2018) hedges over the
aggressiveness of early stopping by running several Successive Halving
brackets with different initial resources against one shared
:class:`~repro.autotune.predictor.RunPool` and one shared model state —
epochs already spent on a config in an earlier bracket are never
re-charged, and every bracket's observations sharpen the same LKGP.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

from ..core import LKGPConfig
from .predictor import CurvePredictor, RunPool

__all__ = ["SHConfig", "SuccessiveHalvingScheduler", "HyperbandScheduler"]


@dataclass
class SHConfig:
    """Successive Halving / Hyperband policy + model configuration."""
    max_epochs: int = 27            # R: full-fidelity resource per config
    min_epochs: int = 1             # r: resource of the first rung
    eta: int = 3                    # promotion fraction 1/eta per rung
    promotion: str = "lkgp"         # "lkgp" (predicted final) | "rank" (observed)
    rule: str = "ucb"               # lkgp scoring: "ucb" | "quantile"
    ucb_beta: float = 1.0
    quantile: float = 0.75
    maximize: bool = True
    gp: LKGPConfig = field(default_factory=lambda: LKGPConfig(lbfgs_iters=30))
    # Host L-BFGS budget for warm refits; ignored when gp.polish_steps >= 0
    # (fixed-budget device polish, init chosen by gp.hyper_init).
    refit_lbfgs_iters: int | None = 10
    # Explicit repro.amortize.Amortizer; passing one opts every fit/refit
    # into amortized inits with it (None defers to gp.hyper_init).
    amortizer: object | None = None


class SuccessiveHalvingScheduler:
    """One Successive Halving race over a pool of runs.

    ``step_fns[i]() -> float`` advances config i one epoch. With
    ``cfg.promotion == "lkgp"`` every rung folds the pool's curves into the
    shared :class:`CurvePredictor` (extend + warm refit) and promotes by
    predicted final value; ``"rank"`` is the classic observed-metric
    baseline and never touches the model.
    """

    def __init__(self, X, step_fns, cfg: SHConfig | None = None, seed: int = 0,
                 pool: RunPool | None = None,
                 predictor: CurvePredictor | None = None, t=None):
        self.X = np.asarray(X, np.float64)
        self.cfg = cfg or SHConfig()
        self.seed = seed
        self.pool = pool if pool is not None else RunPool(
            step_fns, self.cfg.max_epochs)
        if predictor is None and self.cfg.promotion == "lkgp":
            # ``t`` carries a real dataset's (possibly non-uniform) budget
            # grid into the model; rung resources stay epoch *indices*.
            predictor = CurvePredictor(
                self.X, self.cfg.max_epochs, gp=self.cfg.gp,
                maximize=self.cfg.maximize,
                refit_lbfgs_iters=self.cfg.refit_lbfgs_iters, seed=seed,
                t=t, amortizer=self.cfg.amortizer)
        self.predictor = predictor
        self.history: list[dict] = []

    # -- scoring -----------------------------------------------------------
    def _scores(self, active: list[int]) -> np.ndarray:
        """Score-space promotion scores for the active subset."""
        cfg = self.cfg
        sign = 1.0 if cfg.maximize else -1.0
        if cfg.promotion == "rank":
            vals = np.array([sign * self.pool.observed_last(i)
                             for i in active])
            # never-run configs (NaN under an exhausted budget) rank worst —
            # argmax/argsort would otherwise propagate the NaN as a max
            return np.where(np.isnan(vals), -np.inf, vals)
        if cfg.promotion != "lkgp":
            raise ValueError(f"unknown promotion mode {cfg.promotion!r}; "
                             "expected 'lkgp' or 'rank'")
        self.predictor.update(self.pool.Y, self.pool.mask)
        scores = self.predictor.scores(rule=cfg.rule, ucb_beta=cfg.ucb_beta,
                                       quantile=cfg.quantile)
        return scores[np.asarray(active)]

    # -- core loop ---------------------------------------------------------
    def run(self, subset: list[int] | None = None,
            min_epochs: int | None = None) -> dict:
        """Race ``subset`` (default: the whole pool) through the rungs.

        ``min_epochs`` overrides the first-rung resource (used by Hyperband
        brackets). Returns a summary dict; ``selected`` is the surviving
        config with the best score.
        """
        cfg = self.cfg
        active = list(range(self.pool.n)) if subset is None else list(subset)
        r = int(min_epochs if min_epochs is not None else cfg.min_epochs)
        # clamp to [1, max_epochs]: r > R would make the rung count
        # non-positive; r == R degenerates to one full-fidelity rung
        r = max(1, min(r, cfg.max_epochs))
        num_rungs = int(math.floor(
            math.log(cfg.max_epochs / r) / math.log(cfg.eta))) + 1

        scores = None
        for k in range(num_rungs):
            target = (cfg.max_epochs if k == num_rungs - 1
                      else min(cfg.max_epochs, r * cfg.eta ** k))
            for i in active:
                self.pool.advance_to(i, target)
            scores = self._scores(active)
            rung = {"rung": k, "target_epochs": int(target),
                    "active": list(active),
                    "scores": [float(s) for s in scores],
                    "epochs_spent": int(self.pool.spent)}
            if k < num_rungs - 1 and len(active) > 1:
                keep = max(1, int(math.ceil(len(active) / cfg.eta)))
                order = np.argsort(-scores, kind="stable")[:keep]
                active = [active[j] for j in sorted(order)]
                scores = scores[np.sort(order)]
                rung["promoted"] = list(active)
            self.history.append(rung)
            if self.pool.exhausted():
                break

        best = int(active[int(np.argmax(scores))])
        summary = {
            "epochs_spent": int(self.pool.spent),
            "selected": best,
            "survivors": list(active),
            "rungs": self.history,
            "observed_best": self.pool.observed_best(cfg.maximize),
        }
        if self.predictor is not None and self.predictor.state is not None:
            mean, _ = self.predictor.predict_final()
            summary["predicted_final"] = self.predictor.to_raw(mean).tolist()
        return summary


class HyperbandScheduler:
    """Hyperband: Successive Halving brackets over one shared pool + model.

    Bracket s starts ``n_s = ceil((s_max+1)/(s+1) * eta^s)`` configs at
    resource ``R * eta^-s``; s runs from most-aggressive (s_max) down to
    plain full-resource evaluation (0). Configs are drawn without
    replacement per bracket from the finite pool, favouring the
    least-trained so brackets spread coverage. The shared
    :class:`RunPool` never re-charges epochs a config already ran, and in
    ``"lkgp"`` mode every bracket re-uses (and further sharpens) the same
    warm-started model state.
    """

    def __init__(self, X, step_fns, cfg: SHConfig | None = None,
                 seed: int = 0, candidates: list[int] | None = None,
                 t=None):
        self.X = np.asarray(X, np.float64)
        self.cfg = cfg or SHConfig()
        self.seed = seed
        # brackets sample (and may select) only from `candidates`; other
        # pool rows — e.g. completed curves from previous experiments —
        # still inform the shared model through the config kernel.
        self.candidates = (list(range(len(step_fns)))
                           if candidates is None else list(candidates))
        self.pool = RunPool(step_fns, self.cfg.max_epochs)
        self.predictor = None
        if self.cfg.promotion == "lkgp":
            self.predictor = CurvePredictor(
                self.X, self.cfg.max_epochs, gp=self.cfg.gp,
                maximize=self.cfg.maximize,
                refit_lbfgs_iters=self.cfg.refit_lbfgs_iters, seed=seed,
                t=t, amortizer=self.cfg.amortizer)
        self.brackets: list[dict] = []

    def run(self) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(self.seed)
        s_max = int(math.floor(math.log(cfg.max_epochs) / math.log(cfg.eta)))
        candidates: list[tuple[int, float]] = []   # (config, score)

        cand = np.asarray(self.candidates)
        for s in range(s_max, -1, -1):
            n_s = int(math.ceil((s_max + 1) / (s + 1) * cfg.eta ** s))
            n_s = min(n_s, len(cand))
            # least-trained first; random tie-break inside equal counts
            jitter = rng.random(len(cand))
            order = np.lexsort((jitter, self.pool.epochs_done[cand]))
            subset = sorted(int(i) for i in cand[order[:n_s]])
            r_s = max(1, int(round(cfg.max_epochs * cfg.eta ** (-s))))

            sh = SuccessiveHalvingScheduler(
                self.X, self.pool.step_fns, cfg, seed=self.seed + s,
                pool=self.pool, predictor=self.predictor)
            summary = sh.run(subset=subset, min_epochs=r_s)
            last = summary["rungs"][-1]
            sel = summary["selected"]
            sel_score = last["scores"][last["active"].index(sel)]
            candidates.append((sel, float(sel_score)))
            self.brackets.append({"bracket": s, "n_configs": n_s,
                                  "min_epochs": r_s, **summary})

        best = max(candidates, key=lambda cs: cs[1])[0]
        return {
            "epochs_spent": int(self.pool.spent),
            "selected": int(best),
            "bracket_selections": candidates,
            "brackets": self.brackets,
            "observed_best": self.pool.observed_best(cfg.maximize),
        }
