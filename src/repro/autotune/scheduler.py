"""LKGP-driven early-stopping scheduler (the paper's AutoML application).

Freeze-thaw-style loop over a pool of training runs:
  1. every ``refit_every`` epochs, fit an LKGP to all partial curves;
  2. predict each run's final-epoch metric (Matheron posterior over the
     full grid);
  3. stop runs whose predicted final value is below the best observed /
     predicted value with high confidence (UCB rule), reallocating their
     remaining budget to survivors.

This is the system-level answer to stragglers and wasted fleet compute: bad
hyper-parameter configurations are detected from partial learning curves and
preempted. Works with any trainer exposing (advance one epoch -> metric).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import jax
import numpy as np

from ..core import LKGP, LKGPConfig

__all__ = ["AutotuneConfig", "FreezeThawScheduler"]


@dataclass
class AutotuneConfig:
    max_epochs: int = 20
    refit_every: int = 2
    min_epochs_before_stop: int = 3
    ucb_beta: float = 1.0          # stop if pred + beta*std < best estimate
    maximize: bool = True
    gp: LKGPConfig = field(default_factory=lambda: LKGPConfig(lbfgs_iters=30))


class FreezeThawScheduler:
    """Drives n runs; ``step_fns[i]() -> float`` advances run i one epoch."""

    def __init__(self, X: np.ndarray, step_fns: list[Callable[[], float]],
                 cfg: AutotuneConfig | None = None, seed: int = 0):
        self.X = np.asarray(X, np.float64)
        self.step_fns = step_fns
        self.cfg = cfg or AutotuneConfig()
        n, m = len(step_fns), self.cfg.max_epochs
        self.Y = np.zeros((n, m))
        self.mask = np.zeros((n, m))
        self.active = np.ones(n, bool)
        self.seed = seed
        self.history: list[dict] = []
        self.model: LKGP | None = None

    # -- core loop -----------------------------------------------------------
    def run(self, total_epoch_budget: int | None = None) -> dict:
        cfg = self.cfg
        n, m = self.Y.shape
        budget = total_epoch_budget if total_epoch_budget is not None else n * m
        epoch = 0
        spent = 0
        while spent < budget and self.active.any() and epoch < m:
            for i in range(n):
                if not self.active[i] or spent >= budget:
                    continue
                val = float(self.step_fns[i]())
                self.Y[i, epoch] = val
                self.mask[i, epoch] = 1.0
                spent += 1
            if (epoch + 1) % cfg.refit_every == 0 \
                    and epoch + 1 >= cfg.min_epochs_before_stop \
                    and epoch + 1 < m:
                self._refit_and_stop(epoch + 1)
            epoch += 1
        return self.summary(spent)

    def _refit_and_stop(self, epochs_done: int):
        cfg = self.cfg
        t = np.arange(1.0, self.Y.shape[1] + 1.0)
        sign = 1.0 if cfg.maximize else -1.0
        model = LKGP(cfg.gp)
        model.fit(self.X, t, sign * self.Y, self.mask)
        self.model = model
        mean, var = model.predict_final(
            key=jax.random.PRNGKey(self.seed + epochs_done))
        mean = np.asarray(mean)
        std = np.sqrt(np.maximum(np.asarray(var), 0.0))
        best = float(np.max(mean[self.active]))
        stopped = []
        for i in range(len(mean)):
            if self.active[i] and mean[i] + cfg.ucb_beta * std[i] < best:
                self.active[i] = False
                stopped.append(i)
        self.history.append({
            "epoch": epochs_done, "stopped": stopped,
            "active": int(self.active.sum()),
            "pred_best": best,
        })

    def summary(self, spent: int) -> dict:
        t = np.arange(1.0, self.Y.shape[1] + 1.0)
        obs_best = float(np.max(self.Y[self.mask > 0])) if self.mask.any() else None
        # final prediction pass for reporting
        pred_mean = None
        if self.model is not None:
            mean, _ = self.model.predict_final(
                key=jax.random.PRNGKey(self.seed + 999))
            pred_mean = np.asarray(mean).tolist()
        return {
            "epochs_spent": spent,
            "observed_best": obs_best,
            "survivors": np.where(self.active)[0].tolist(),
            "stop_events": self.history,
            "predicted_final": pred_mean,
        }
