"""LKGP-driven early-stopping scheduler (the paper's AutoML application).

Freeze-thaw-style loop over a pool of training runs:
  1. every ``refit_every`` epochs, fold the new partial-curve observations
     into the shared :class:`~repro.autotune.predictor.CurvePredictor`
     (``extend`` + warm-started ``refit`` — no model is rebuilt);
  2. predict each run's final-epoch metric via ``Posterior.final`` (exact
     mean from the cached CG solve + Matheron variance);
  3. stop runs whose predicted final value is below the best observed /
     predicted value with high confidence (UCB rule), reallocating their
     remaining budget to survivors.

This is the system-level answer to stragglers and wasted fleet compute: bad
hyper-parameter configurations are detected from partial learning curves and
preempted. Works with any trainer exposing (advance one epoch -> metric).
Unlike :class:`~repro.autotune.sh.SuccessiveHalvingScheduler` it never
*commits* to a kill schedule — every run survives until the model is
confident it will lose.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import jax
import numpy as np

from ..core import LKGPConfig, LKGPState
from .predictor import CurvePredictor, RunPool

__all__ = ["AutotuneConfig", "FreezeThawScheduler"]


@dataclass
class AutotuneConfig:
    max_epochs: int = 20
    refit_every: int = 2
    min_epochs_before_stop: int = 3
    ucb_beta: float = 1.0          # stop if pred + beta*std < best estimate
    maximize: bool = True
    gp: LKGPConfig = field(default_factory=lambda: LKGPConfig(lbfgs_iters=30))
    # L-BFGS budget for warm-started refits; None -> gp.lbfgs_iters. Set
    # gp.polish_steps >= 0 (with gp.hyper_init="amortized"|"default") to
    # replace the host L-BFGS with the fixed-budget device polish on every
    # per-round refit instead.
    refit_lbfgs_iters: int | None = None
    # Explicit repro.amortize.Amortizer; passing one opts every fit/refit
    # into amortized inits with it (None defers to gp.hyper_init).
    amortizer: object | None = None


class FreezeThawScheduler:
    """Drives n runs; ``step_fns[i]() -> float`` advances run i one epoch."""

    def __init__(self, X: np.ndarray, step_fns: list[Callable[[], float]],
                 cfg: AutotuneConfig | None = None, seed: int = 0, t=None):
        self.X = np.asarray(X, np.float64)
        self.step_fns = step_fns
        self.cfg = cfg or AutotuneConfig()
        n, m = len(step_fns), self.cfg.max_epochs
        self.pool = RunPool(step_fns, m)
        self.active = np.ones(n, bool)
        self.seed = seed
        self.history: list[dict] = []
        # ``t`` carries a real dataset's (possibly non-uniform) budget grid
        # into the model; scheduling still counts epoch indices.
        self.predictor = CurvePredictor(
            self.X, m, gp=self.cfg.gp, maximize=self.cfg.maximize,
            refit_lbfgs_iters=self.cfg.refit_lbfgs_iters, seed=seed, t=t,
            amortizer=self.cfg.amortizer)

    @property
    def state(self) -> LKGPState | None:
        """The predictor's fitted model state (None before the first refit)."""
        return self.predictor.state

    @property
    def Y(self) -> np.ndarray:
        return self.pool.Y

    @property
    def mask(self) -> np.ndarray:
        return self.pool.mask

    # -- core loop -----------------------------------------------------------
    def run(self, total_epoch_budget: int | None = None) -> dict:
        cfg = self.cfg
        n, m = self.pool.n, self.pool.max_epochs
        self.pool.budget = (total_epoch_budget
                            if total_epoch_budget is not None else n * m)
        epoch = 0
        while not self.pool.exhausted() and self.active.any() and epoch < m:
            for i in range(n):
                if self.active[i]:
                    # no-op for configs already past this epoch (preloaded
                    # history curves ride along for free)
                    self.pool.advance_to(i, epoch + 1)
            if (epoch + 1) % cfg.refit_every == 0 \
                    and epoch + 1 >= cfg.min_epochs_before_stop \
                    and epoch + 1 < m:
                self._refit_and_stop(epoch + 1)
            epoch += 1
        return self.summary(self.pool.spent)

    def _refit_and_stop(self, epochs_done: int):
        cfg = self.cfg
        self.predictor.update(self.Y, self.mask)
        mean, std = self.predictor.predict_final(
            key=jax.random.PRNGKey(self.seed + epochs_done))
        best = float(np.max(mean[self.active]))
        stopped = []
        for i in range(len(mean)):
            if self.active[i] and mean[i] + cfg.ucb_beta * std[i] < best:
                self.active[i] = False
                stopped.append(i)
        self.history.append({
            "epoch": epochs_done, "stopped": stopped,
            "active": int(self.active.sum()),
            "pred_best": best,
        })

    def summary(self, spent: int) -> dict:
        obs_best = self.pool.observed_best(self.cfg.maximize)
        # final prediction pass for reporting (back in raw metric units)
        pred_mean = None
        if self.predictor.state is not None:
            mean, _ = self.predictor.predict_final(
                key=jax.random.PRNGKey(self.seed + 999))
            pred_mean = self.predictor.to_raw(mean).tolist()
        return {
            "epochs_spent": spent,
            "observed_best": obs_best,
            "survivors": np.where(self.active)[0].tolist(),
            "stop_events": self.history,
            "predicted_final": pred_mean,
        }
