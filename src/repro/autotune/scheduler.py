"""LKGP-driven early-stopping scheduler (the paper's AutoML application).

Freeze-thaw-style loop over a pool of training runs:
  1. every ``refit_every`` epochs, fold the new partial-curve observations
     into the model state with ``extend`` (incremental conditioning) and
     re-optimise hyper-parameters with ``refit``, warm-started from the
     previous fit — no model is rebuilt from scratch;
  2. predict each run's final-epoch metric via ``Posterior.final`` (exact
     mean from the cached CG solve + Matheron variance);
  3. stop runs whose predicted final value is below the best observed /
     predicted value with high confidence (UCB rule), reallocating their
     remaining budget to survivors.

This is the system-level answer to stragglers and wasted fleet compute: bad
hyper-parameter configurations are detected from partial learning curves and
preempted. Works with any trainer exposing (advance one epoch -> metric).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import jax
import numpy as np

from ..core import LKGPConfig, LKGPState, extend, fit, posterior, refit

__all__ = ["AutotuneConfig", "FreezeThawScheduler"]


@dataclass
class AutotuneConfig:
    max_epochs: int = 20
    refit_every: int = 2
    min_epochs_before_stop: int = 3
    ucb_beta: float = 1.0          # stop if pred + beta*std < best estimate
    maximize: bool = True
    gp: LKGPConfig = field(default_factory=lambda: LKGPConfig(lbfgs_iters=30))
    # L-BFGS budget for warm-started refits; None -> gp.lbfgs_iters.
    refit_lbfgs_iters: int | None = None


class FreezeThawScheduler:
    """Drives n runs; ``step_fns[i]() -> float`` advances run i one epoch."""

    def __init__(self, X: np.ndarray, step_fns: list[Callable[[], float]],
                 cfg: AutotuneConfig | None = None, seed: int = 0):
        self.X = np.asarray(X, np.float64)
        self.step_fns = step_fns
        self.cfg = cfg or AutotuneConfig()
        n, m = len(step_fns), self.cfg.max_epochs
        self.Y = np.zeros((n, m))
        self.mask = np.zeros((n, m))
        self.active = np.ones(n, bool)
        self.seed = seed
        self.history: list[dict] = []
        self.state: LKGPState | None = None

    # -- core loop -----------------------------------------------------------
    def run(self, total_epoch_budget: int | None = None) -> dict:
        cfg = self.cfg
        n, m = self.Y.shape
        budget = total_epoch_budget if total_epoch_budget is not None else n * m
        epoch = 0
        spent = 0
        while spent < budget and self.active.any() and epoch < m:
            for i in range(n):
                if not self.active[i] or spent >= budget:
                    continue
                val = float(self.step_fns[i]())
                self.Y[i, epoch] = val
                self.mask[i, epoch] = 1.0
                spent += 1
            if (epoch + 1) % cfg.refit_every == 0 \
                    and epoch + 1 >= cfg.min_epochs_before_stop \
                    and epoch + 1 < m:
                self._refit_and_stop(epoch + 1)
            epoch += 1
        return self.summary(spent)

    def _sign(self) -> float:
        return 1.0 if self.cfg.maximize else -1.0

    def _refit_and_stop(self, epochs_done: int):
        cfg = self.cfg
        t = np.arange(1.0, self.Y.shape[1] + 1.0)
        sign = self._sign()
        if self.state is None:
            # Cold start: first fit of the pool's partial curves.
            self.state = fit(self.X, t, sign * self.Y, self.mask, cfg.gp)
        else:
            # Incremental conditioning + warm-started hyper-parameters.
            self.state = extend(self.state, sign * self.Y, self.mask)
            self.state = refit(self.state,
                               lbfgs_iters=cfg.refit_lbfgs_iters)
        mean, var = posterior(self.state).final(
            key=jax.random.PRNGKey(self.seed + epochs_done))
        mean = np.asarray(mean)
        std = np.sqrt(np.maximum(np.asarray(var), 0.0))
        best = float(np.max(mean[self.active]))
        stopped = []
        for i in range(len(mean)):
            if self.active[i] and mean[i] + cfg.ucb_beta * std[i] < best:
                self.active[i] = False
                stopped.append(i)
        self.history.append({
            "epoch": epochs_done, "stopped": stopped,
            "active": int(self.active.sum()),
            "pred_best": best,
        })

    def summary(self, spent: int) -> dict:
        best_fn = np.max if self.cfg.maximize else np.min
        obs_best = float(best_fn(self.Y[self.mask > 0])) if self.mask.any() else None
        # final prediction pass for reporting (back in raw metric units:
        # the GP is fit on sign * Y, so undo the sign here)
        pred_mean = None
        if self.state is not None:
            mean, _ = posterior(self.state).final(
                key=jax.random.PRNGKey(self.seed + 999))
            pred_mean = (self._sign() * np.asarray(mean)).tolist()
        return {
            "epochs_spent": spent,
            "observed_best": obs_best,
            "survivors": np.where(self.active)[0].tolist(),
            "stop_events": self.history,
            "predicted_final": pred_mean,
        }
