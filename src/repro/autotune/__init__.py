"""AutoML scheduler subsystem driven by LKGP learning-curve prediction.

Layered as predictor -> schedulers:

* :mod:`~repro.autotune.predictor` — the shared :class:`CurvePredictor`
  (extend → warm refit → ``Posterior.final``) and the :class:`RunPool`
  execution harness;
* :mod:`~repro.autotune.scheduler` — :class:`FreezeThawScheduler`
  (confidence-based early stopping, no fixed kill schedule);
* :mod:`~repro.autotune.sh` — :class:`SuccessiveHalvingScheduler` and
  :class:`HyperbandScheduler` (rung-based promotion, LKGP-ranked or
  classic rank-based).
"""
from .predictor import CurvePredictor, RunPool
from .scheduler import AutotuneConfig, FreezeThawScheduler
from .sh import HyperbandScheduler, SHConfig, SuccessiveHalvingScheduler

__all__ = [
    "CurvePredictor", "RunPool",
    "AutotuneConfig", "FreezeThawScheduler",
    "SHConfig", "SuccessiveHalvingScheduler", "HyperbandScheduler",
]
