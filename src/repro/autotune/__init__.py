"""LKGP-driven early-stopping (freeze-thaw) scheduler."""
from .scheduler import AutotuneConfig, FreezeThawScheduler
