"""Shared LKGP curve-prediction layer for every AutoML scheduler.

All schedulers (freeze-thaw, Successive Halving, Hyperband) need the same
model loop over a pool of partially observed learning curves:

  1. fold new observations into the state — cold :func:`~repro.core.fit`
     on first contact, :func:`~repro.core.extend` afterwards (incremental
     conditioning, hyper-parameters carried over as a warm start);
  2. re-optimise hyper-parameters with a warm-started, budget-capped
     :func:`~repro.core.refit`;
  3. read each config's predicted final-epoch metric from
     ``Posterior.final`` (exact mean from the cached CG solve + Matheron
     variance).

:class:`CurvePredictor` owns that loop so scheduler classes only contain
promotion/stopping policy. Predictions live in *score space* (metrics are
multiplied by ±1 so that larger is always better); ``to_raw`` undoes the
sign for reporting.

:class:`RunPool` is the matching execution-side helper: it drives the
user-supplied ``step_fns`` (one "advance one epoch -> metric" callable per
config), records curves/masks, and enforces a total epoch budget.
"""
from __future__ import annotations

from typing import Callable

import jax
import numpy as np

from ..core import LKGPConfig, LKGPState, extend, fit, posterior, refit

__all__ = ["CurvePredictor", "RunPool"]


def _norm_ppf(q: float) -> float:
    """Standard-normal quantile."""
    from scipy.stats import norm

    return float(norm.ppf(q))


class CurvePredictor:
    """LKGP over a fixed pool of configs: extend → warm refit → final mean/std.

    Parameters
    ----------
    X : (n, d) hyper-parameter configurations (the whole pool).
    max_epochs : grid length m; progressions are epochs ``1..m``.
    gp : model/inference config for the cold fit (``precond_rank`` et al.
        flow straight through to the engines).
    maximize : if False the metric is negated internally so score space is
        always "larger is better".
    refit_lbfgs_iters : L-BFGS budget for warm-started refits
        (None -> ``gp.lbfgs_iters``).
    """

    def __init__(self, X, max_epochs: int, gp: LKGPConfig | None = None,
                 maximize: bool = True, refit_lbfgs_iters: int | None = None,
                 seed: int = 0):
        self.X = np.asarray(X, np.float64)
        self.t = np.arange(1.0, max_epochs + 1.0)
        self.gp = gp if gp is not None else LKGPConfig(lbfgs_iters=30)
        self.sign = 1.0 if maximize else -1.0
        self.refit_lbfgs_iters = refit_lbfgs_iters
        self.seed = seed
        self.state: LKGPState | None = None
        self.n_refits = 0
        self._final_cache: tuple | None = None   # (n_refits, mean, std)

    def update(self, Y, mask) -> None:
        """Fold the pool's current (n, m) curves in and re-optimise.

        ``mask`` must grow monotonically between calls (``extend`` enforces
        it) — schedulers only ever add observations.
        """
        Y = self.sign * np.asarray(Y, np.float64)
        mask = np.asarray(mask, np.float64)
        if self.state is None:
            self.state = fit(self.X, self.t, Y, mask, self.gp)
        else:
            self.state = extend(self.state, Y, mask)
            self.state = refit(self.state,
                               lbfgs_iters=self.refit_lbfgs_iters)
        self.n_refits += 1

    def predict_final(self, key=None):
        """(mean, std) of each config's final-epoch metric in score space.

        Default-key calls are cached per refit, so a scheduler reading the
        same prediction twice (rung scoring, then the run summary) pays for
        one posterior pass.
        """
        if self.state is None:
            raise RuntimeError("predict_final before any update()")
        default_key = key is None
        if default_key:
            if self._final_cache is not None \
                    and self._final_cache[0] == self.n_refits:
                return self._final_cache[1], self._final_cache[2]
            key = jax.random.PRNGKey(self.seed + self.n_refits)
        mean, var = posterior(self.state).final(key=key)
        mean = np.asarray(mean)
        std = np.sqrt(np.maximum(np.asarray(var), 0.0))
        if default_key:
            self._final_cache = (self.n_refits, mean, std)
        return mean, std

    def scores(self, rule: str = "ucb", ucb_beta: float = 1.0,
               quantile: float = 0.75, key=None) -> np.ndarray:
        """Per-config promotion scores (score space, larger = better).

        ``"ucb"``: mean + beta * std — optimistic, keeps configs whose
        upside is still plausible. ``"quantile"``: the q-quantile of the
        predictive final-value distribution (q < 0.5 is conservative,
        q > 0.5 optimistic).
        """
        mean, std = self.predict_final(key=key)
        if rule == "ucb":
            return mean + ucb_beta * std
        if rule == "quantile":
            return mean + _norm_ppf(quantile) * std
        raise ValueError(f"unknown promotion rule {rule!r}; "
                         "expected 'ucb' or 'quantile'")

    def to_raw(self, scores: np.ndarray) -> np.ndarray:
        """Map score-space values back to raw metric units."""
        return self.sign * np.asarray(scores)


class RunPool:
    """Execution state over a pool of runs: curves, masks, epoch accounting.

    ``step_fns[i]() -> float`` advances run i by one epoch and returns the
    metric. The pool never re-runs an epoch: ``advance_to`` is a no-op for
    configs already at (or past) the target, which lets Hyperband brackets
    share one pool without double-charging epochs.
    """

    def __init__(self, step_fns: list[Callable[[], float]], max_epochs: int,
                 budget: int | None = None):
        n = len(step_fns)
        self.step_fns = step_fns
        self.max_epochs = max_epochs
        self.Y = np.zeros((n, max_epochs))
        self.mask = np.zeros((n, max_epochs))
        self.epochs_done = np.zeros(n, np.int64)
        self.spent = 0
        self.budget = budget

    @property
    def n(self) -> int:
        return len(self.step_fns)

    def exhausted(self) -> bool:
        return self.budget is not None and self.spent >= self.budget

    def advance_to(self, i: int, target_epochs: int,
                   charge: bool = True) -> None:
        """Run config i until it has ``target_epochs`` epochs (budget-capped).

        ``charge=False`` records the epochs without counting them against
        ``spent`` — used to preload completed curves from *previous*
        experiments ("history"), which every scheduler gets for free.
        """
        target = min(int(target_epochs), self.max_epochs)
        while self.epochs_done[i] < target \
                and not (charge and self.exhausted()):
            e = int(self.epochs_done[i])
            self.Y[i, e] = float(self.step_fns[i]())
            self.mask[i, e] = 1.0
            self.epochs_done[i] += 1
            if charge:
                self.spent += 1

    def observed_last(self, i: int) -> float:
        """Most recent observed metric of config i (nan if never run)."""
        e = int(self.epochs_done[i])
        return float(self.Y[i, e - 1]) if e > 0 else float("nan")

    def observed_best(self, maximize: bool = True):
        if not self.mask.any():
            return None
        vals = self.Y[self.mask > 0]
        return float(np.max(vals) if maximize else np.min(vals))
