"""Shared LKGP curve-prediction layer for every AutoML scheduler.

All schedulers (freeze-thaw, Successive Halving, Hyperband) need the same
model loop over a pool of partially observed learning curves:

  1. fold new observations into the state — cold :func:`~repro.core.fit`
     on first contact, :func:`~repro.core.extend` afterwards (incremental
     conditioning, hyper-parameters carried over as a warm start);
  2. re-optimise hyper-parameters with a warm-started, budget-capped
     :func:`~repro.core.refit`;
  3. read each config's predicted final-epoch metric from
     ``Posterior.final`` (exact mean from the cached CG solve + Matheron
     variance).

:class:`CurvePredictor` owns that loop so scheduler classes only contain
promotion/stopping policy. Predictions live in *score space* — the raw
metric mapped through an invertible
:class:`~repro.data.transforms.AffineTransform` (default: a ±1 sign flip
from ``maximize``) so that larger is always better; ``to_raw`` inverts the
transform for reporting.

:class:`RunPool` is the matching execution-side helper: it drives the
user-supplied ``step_fns`` (one "advance one epoch -> metric" callable per
config), records curves/masks, and enforces a total epoch budget.
:meth:`RunPool.replay` builds the pool straight from a loaded dataset
task, stepping through its recorded curves.
"""
from __future__ import annotations

import math
from typing import Callable

import numpy as np
from jax.scipy.special import erfinv

from ..core import LKGPConfig, LKGPState, extend, fit, posterior, refit
from ..data.curves import CurveTask, replay_step_fns
from ..data.transforms import AffineTransform

__all__ = ["CurvePredictor", "RunPool"]


def _norm_ppf(q: float) -> float:
    """Standard-normal quantile via erfinv (no scipy dependency)."""
    if not 0.0 < q < 1.0:
        raise ValueError(f"quantile must be in (0, 1), got {q}")
    return float(math.sqrt(2.0) * erfinv(2.0 * q - 1.0))


class CurvePredictor:
    """LKGP over a fixed pool of configs: extend → warm refit → final mean/std.

    Parameters
    ----------
    X : (n, d) hyper-parameter configurations (the whole pool).
    max_epochs : grid length m; progressions default to epochs ``1..m``.
    gp : model/inference config for the cold fit (``precond_rank`` et al.
        flow straight through to the engines).
    maximize : if False the metric is negated internally so score space is
        always "larger is better" (ignored when ``metric_tf`` is given).
    refit_lbfgs_iters : L-BFGS budget for warm-started refits
        (None -> ``gp.lbfgs_iters``). Only the host-L-BFGS path reads it:
        with ``gp.polish_steps >= 0`` every fit/refit instead runs the
        fixed-budget device polish from the init ``gp.hyper_init``
        selects (``"default"`` or ``"amortized"``; refits warm-start from
        the current optimum unless ``hyper_init="amortized"``, which
        re-amortizes on each round's extended data).
    amortizer : explicit :class:`repro.amortize.Amortizer` forwarded to
        ``fit``/``refit``; passing one opts every fit and refit into
        amortized inits with this encoder. None leaves the choice to
        ``gp.hyper_init`` (whose ``"amortized"`` resolves the
        registered/packaged encoder lazily).
    t : explicit progression grid (length ``max_epochs``; positive,
        strictly increasing) — e.g. a real dataset's log-spaced budget
        fidelities. The GP's progression kernel sees these values; the
        scheduler's epoch indices keep addressing positions ``0..m-1``.
    metric_tf : invertible transform raw metric -> score space (an
        :class:`~repro.data.transforms.AffineTransform`-like object with
        ``__call__`` / ``inverse``). Default: the ±1 sign flip derived
        from ``maximize``.
    """

    def __init__(self, X, max_epochs: int | None = None,
                 gp: LKGPConfig | None = None,
                 maximize: bool = True, refit_lbfgs_iters: int | None = None,
                 seed: int = 0, t=None, metric_tf=None, amortizer=None):
        self.X = np.asarray(X, np.float64)
        if t is not None:
            self.t = np.asarray(t, np.float64)
            if self.t.ndim != 1 or np.any(np.diff(self.t) <= 0) \
                    or self.t[0] <= 0:
                raise ValueError("t must be a positive strictly-increasing "
                                 f"1-D grid, got {self.t}")
            if max_epochs is not None and max_epochs != self.t.shape[0]:
                raise ValueError(f"max_epochs={max_epochs} disagrees with "
                                 f"len(t)={self.t.shape[0]}")
        elif max_epochs is not None:
            self.t = np.arange(1.0, max_epochs + 1.0)
        else:
            raise ValueError("give max_epochs or an explicit t grid")
        self.gp = gp if gp is not None else LKGPConfig(lbfgs_iters=30)
        self.metric_tf = (metric_tf if metric_tf is not None
                          else AffineTransform.sign(maximize))
        self.refit_lbfgs_iters = refit_lbfgs_iters
        self.amortizer = amortizer
        self.seed = seed
        self.state: LKGPState | None = None
        self.n_refits = 0
        self._final_cache: tuple | None = None   # (n_refits, mean, std)

    @property
    def max_epochs(self) -> int:
        return self.t.shape[0]

    def update(self, Y, mask) -> None:
        """Fold the pool's current (n, m) curves in and re-optimise.

        ``mask`` must grow monotonically between calls (``extend`` enforces
        it) — schedulers only ever add observations.
        """
        Y = np.asarray(self.metric_tf(np.asarray(Y, np.float64)), np.float64)
        mask = np.asarray(mask, np.float64)
        if self.state is None:
            self.state = fit(self.X, self.t, Y, mask, self.gp,
                             amortizer=self.amortizer)
        else:
            self.state = extend(self.state, Y, mask)
            self.state = refit(self.state,
                               lbfgs_iters=self.refit_lbfgs_iters,
                               amortizer=self.amortizer)
        self.n_refits += 1

    def predict_final(self, key=None):
        """(mean, std) of each config's final-epoch metric in score space.

        Default-key calls go through the state-keyed posterior cache
        (``posterior(state)`` returns the state's shared lazy posterior and
        ``final()`` reads its cached default-sample stream), so a scheduler
        reading the same prediction twice — rung scoring, then the run
        summary — performs zero additional operator sweeps. The numpy
        conversion is additionally cached per refit. ``extend``/``refit``
        in :meth:`update` produce fresh state objects, which is what
        invalidates both layers.
        """
        if self.state is None:
            raise RuntimeError("predict_final before any update()")
        default_key = key is None
        if default_key:
            if self._final_cache is not None \
                    and self._final_cache[0] == self.n_refits:
                return self._final_cache[1], self._final_cache[2]
        mean, var = posterior(self.state).final(key=key)
        mean = np.asarray(mean)
        std = np.sqrt(np.maximum(np.asarray(var), 0.0))
        if default_key:
            self._final_cache = (self.n_refits, mean, std)
        return mean, std

    def scores(self, rule: str = "ucb", ucb_beta: float = 1.0,
               quantile: float = 0.75, key=None) -> np.ndarray:
        """Per-config promotion scores (score space, larger = better).

        ``"ucb"``: mean + beta * std — optimistic, keeps configs whose
        upside is still plausible. ``"quantile"``: the q-quantile of the
        predictive final-value distribution (q < 0.5 is conservative,
        q > 0.5 optimistic).
        """
        mean, std = self.predict_final(key=key)
        if rule == "ucb":
            return mean + ucb_beta * std
        if rule == "quantile":
            return mean + _norm_ppf(quantile) * std
        raise ValueError(f"unknown promotion rule {rule!r}; "
                         "expected 'ucb' or 'quantile'")

    def to_raw(self, scores: np.ndarray) -> np.ndarray:
        """Map score-space values back to raw metric units."""
        return np.asarray(self.metric_tf.inverse(np.asarray(scores)))


class RunPool:
    """Execution state over a pool of runs: curves, masks, epoch accounting.

    ``step_fns[i]() -> float`` advances run i by one epoch and returns the
    metric. The pool never re-runs an epoch: ``advance_to`` is a no-op for
    configs already at (or past) the target, which lets Hyperband brackets
    share one pool without double-charging epochs.
    """

    def __init__(self, step_fns: list[Callable[[], float]], max_epochs: int,
                 budget: int | None = None):
        n = len(step_fns)
        self.step_fns = step_fns
        self.max_epochs = max_epochs
        self.Y = np.zeros((n, max_epochs))
        self.mask = np.zeros((n, max_epochs))
        self.epochs_done = np.zeros(n, np.int64)
        self.spent = 0
        self.budget = budget

    @classmethod
    def replay(cls, task: CurveTask, budget: int | None = None,
               seed: int = 0, obs_noise: float = 0.0,
               spike_prob: float = 0.0,
               censored: bool | None = None) -> "RunPool":
        """Replay mode: a pool stepping through a loaded task's real curves.

        The step callables come from
        :func:`repro.data.curves.replay_step_fns` — exact replay of the
        task's recorded ``Y_full`` by default (censored configs hold their
        last observed value), with an optional observation-noise model on
        top. ``max_epochs`` is the task's grid length. Pass ``censored``
        (e.g. ``not artifact.has_full[i]``) to override the zero-tail
        heuristic with the artifact's authoritative flag.
        """
        return cls(replay_step_fns(task, seed=seed, obs_noise=obs_noise,
                                   spike_prob=spike_prob,
                                   censored=censored),
                   max_epochs=np.asarray(task.t).shape[0], budget=budget)

    @property
    def n(self) -> int:
        return len(self.step_fns)

    def exhausted(self) -> bool:
        return self.budget is not None and self.spent >= self.budget

    def advance_to(self, i: int, target_epochs: int,
                   charge: bool = True) -> None:
        """Run config i until it has ``target_epochs`` epochs (budget-capped).

        ``charge=False`` records the epochs without counting them against
        ``spent`` — used to preload completed curves from *previous*
        experiments ("history"), which every scheduler gets for free.
        """
        target = min(int(target_epochs), self.max_epochs)
        while self.epochs_done[i] < target \
                and not (charge and self.exhausted()):
            # Harness boundary: step_fns are caller-supplied Python
            # callables and the pool state is plain numpy — host-side by
            # construction, not a device sync.
            e = int(self.epochs_done[i])              # lint: disable=RA103
            self.Y[i, e] = float(self.step_fns[i]())  # lint: disable=RA103
            self.mask[i, e] = 1.0
            self.epochs_done[i] += 1
            if charge:
                self.spent += 1

    def observed_last(self, i: int) -> float:
        """Most recent observed metric of config i (nan if never run)."""
        e = int(self.epochs_done[i])
        return float(self.Y[i, e - 1]) if e > 0 else float("nan")

    def observed_best(self, maximize: bool = True):
        if not self.mask.any():
            return None
        vals = self.Y[self.mask > 0]
        return float(np.max(vals) if maximize else np.min(vals))
