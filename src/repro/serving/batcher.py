"""Cross-tenant request coalescing.

Independent tenants' prediction requests can share ONE vmapped posterior
evaluation when their states are stackable: identical ``LKGPConfig`` and
identical data shapes (progression *values* may differ per task — the grid
is a data leaf, not metadata). :func:`coalesce_sessions` partitions a
request list into maximal stackable groups while preserving within-group
request order.

:class:`CoalescingBatcher` is the async surface over the same idea:
``submit`` enqueues a request and returns a ``Future``; ``flush`` drains
the queue, groups it, hands each group to the executor callback (the
service's batched-posterior evaluation), and resolves the futures. A
group whose execution raises fails only that group's futures.
"""
from __future__ import annotations

import threading
from concurrent.futures import Future
from typing import Any, Callable, Hashable, Sequence, TypeVar

from .store import Session

__all__ = ["stack_signature", "coalesce_sessions", "CoalescingBatcher"]

T = TypeVar("T")


def stack_signature(session: Session) -> Hashable:
    """Hashable compatibility key: sessions with equal keys can be stacked.

    ``LKGPConfig`` is frozen (hash by value) and is the pytree *metadata*
    of the state, so equal configs + equal leaf shapes is exactly the
    precondition of :func:`repro.core.state.stack_states`.
    """
    st = session.state
    return (st.config, st.X.shape, st.t.shape, st.Y.shape)


def coalesce_sessions(
        sessions: Sequence[Session]) -> list[list[int]]:
    """Partition request indices into stackable groups (order-preserving)."""
    groups: dict[Hashable, list[int]] = {}
    for i, session in enumerate(sessions):
        groups.setdefault(stack_signature(session), []).append(i)
    return list(groups.values())


class CoalescingBatcher:
    """Queue of pending requests resolved in coalesced batches.

    ``execute`` receives a same-signature list of sessions and must return
    one result per session, in order.
    """

    def __init__(self, execute: Callable[[list[Session]], list[Any]]) -> None:
        self._execute = execute
        self._lock = threading.Lock()
        self._pending: list[tuple[Session, Future]] = []

    def submit(self, session: Session) -> "Future[Any]":
        """Enqueue a prediction request; resolved at the next ``flush``."""
        future: "Future[Any]" = Future()
        with self._lock:
            self._pending.append((session, future))
        return future

    def pending(self) -> int:
        with self._lock:
            return len(self._pending)

    def flush(self) -> int:
        """Drain the queue; returns the number of requests resolved."""
        with self._lock:
            batch = self._pending
            self._pending = []
        if not batch:
            return 0
        sessions = [session for session, _ in batch]
        for indices in coalesce_sessions(sessions):
            group = [sessions[i] for i in indices]
            try:
                results = self._execute(group)
            except Exception as exc:  # noqa: BLE001 - fail only this group
                for i in indices:
                    batch[i][1].set_exception(exc)
                continue
            if len(results) != len(indices):
                err = RuntimeError(
                    f"executor returned {len(results)} results for "
                    f"{len(indices)} requests")
                for i in indices:
                    batch[i][1].set_exception(err)
                continue
            for i, result in zip(indices, results):
                batch[i][1].set_result(result)
        return len(batch)
