"""Per-tenant session store: warm ``LKGPState``s behind an LRU cap.

A :class:`Session` owns one task's fitted state plus everything derived
from it: a monotonically increasing ``generation`` (bumped on every state
swap) and the lazily built single-task *stacked* view the prediction path
evaluates through. Swapping the state via :meth:`Session.swap_state`
clears the stacked view, and because the posterior solve cache lives on
the state object itself (:mod:`repro.core.posterior`), dropping the old
state is what invalidates its cached solves — a warm posterior can never
serve pre-``extend`` results.

The :class:`SessionStore` is an ``OrderedDict``-based LRU: ``get`` marks
recency, inserting past ``capacity`` evicts the least-recently-used
session (state, stacked view, and attached posterior cache all go with
it). All store operations are guarded by one lock; per-session mutation is
guarded by the session's own re-entrant lock so tenants stream
observations concurrently without serialising on the store.
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Iterator, NamedTuple

from ..core.state import LKGPState, stack_states

__all__ = ["SessionKey", "Session", "SessionStore"]


class SessionKey(NamedTuple):
    """Identity of one streamed learning-curve task."""
    tenant: str
    task: str


@dataclass
class Session:
    """One tenant/task's warm state and its derived prediction view."""

    key: SessionKey
    state: LKGPState
    generation: int = 0
    observes: int = 0
    created_at: float = field(default_factory=time.monotonic)
    lock: threading.RLock = field(default_factory=threading.RLock)
    _stacked: LKGPState | None = field(default=None, repr=False)

    def swap_state(self, state: LKGPState) -> None:
        """Install a new state (post ``extend``/``refit``) atomically.

        Bumps ``generation`` and drops the stacked prediction view; the
        old state object — and with it every posterior solve cached on it —
        becomes unreachable from the session.
        """
        with self.lock:
            self.state = state
            self.generation += 1
            self._stacked = None

    def stacked(self) -> LKGPState:
        """Batch-of-one view of the state, cached until the next swap.

        Predictions always evaluate through the batched (vmapped) posterior
        so that a request served alone and the same request served inside a
        coalesced batch run the identical compiled function — bitwise-equal
        results. Caching the view keeps repeated predictions hitting the
        SAME stacked state object, i.e. the state-keyed posterior cache.
        """
        with self.lock:
            if self._stacked is None:
                self._stacked = stack_states([self.state])
            return self._stacked


class SessionStore:
    """LRU map of :class:`SessionKey` to :class:`Session`."""

    def __init__(self, capacity: int = 64) -> None:
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._sessions: OrderedDict[SessionKey, Session] = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key: SessionKey) -> Session | None:
        with self._lock:
            session = self._sessions.get(key)
            if session is None:
                self.misses += 1
                return None
            self._sessions.move_to_end(key)
            self.hits += 1
            return session

    def put(self, key: SessionKey, state: LKGPState) -> Session:
        """Install a fresh session (cold fit), evicting LRU past capacity."""
        session = Session(key=key, state=state)
        with self._lock:
            self._sessions[key] = session
            self._sessions.move_to_end(key)
            while len(self._sessions) > self.capacity:
                self._sessions.popitem(last=False)
                self.evictions += 1
            return session

    def drop(self, key: SessionKey) -> bool:
        with self._lock:
            return self._sessions.pop(key, None) is not None

    def __len__(self) -> int:
        with self._lock:
            return len(self._sessions)

    def __contains__(self, key: SessionKey) -> bool:
        with self._lock:
            return key in self._sessions

    def keys(self) -> list[SessionKey]:
        """Keys, least- to most-recently-used."""
        with self._lock:
            return list(self._sessions)

    def sessions(self) -> Iterator[Session]:
        with self._lock:
            return iter(list(self._sessions.values()))

    def stats(self) -> dict:
        with self._lock:
            return {
                "size": len(self._sessions),
                "capacity": self.capacity,
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
            }
