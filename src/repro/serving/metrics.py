"""Lightweight, dependency-free service metrics.

A :class:`LatencyRecorder` keeps a bounded window of samples and reports
percentiles over it; :class:`Counter` is a thread-safe monotonic counter;
:class:`EventLog` is a bounded structured log of notable service events
(quarantined observations, escalated solves, checkpoint/restore activity).
All expose ``snapshot()`` dicts that the service aggregates into one
metrics payload — the same shape ``benchmarks/bench_serving.py`` writes to
``BENCH_serving.json``.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Any

__all__ = ["LatencyRecorder", "Counter", "EventLog", "percentile"]


def percentile(sorted_values: list[float], q: float) -> float:
    """Linear-interpolated percentile of an already-sorted list."""
    if not sorted_values:
        return 0.0
    if len(sorted_values) == 1:
        return sorted_values[0]
    pos = (q / 100.0) * (len(sorted_values) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(sorted_values) - 1)
    frac = pos - lo
    return sorted_values[lo] * (1.0 - frac) + sorted_values[hi] * frac


class Counter:
    """Thread-safe monotonic counter."""

    def __init__(self) -> None:
        self._value = 0
        self._lock = threading.Lock()

    def inc(self, by: int = 1) -> None:
        with self._lock:
            self._value += by

    @property
    def value(self) -> int:
        return self._value


class EventLog:
    """Bounded, thread-safe structured event log.

    The reliability layer records one entry per notable event — a
    quarantined observation, an escalated solve, a checkpoint written, a
    restore — as a plain dict (``kind`` + free-form fields + monotonic
    ``seq`` + wall-clock ``time``). Bounded so a misbehaving tenant cannot
    grow service memory without limit; ``count(kind)`` stays exact over the
    process lifetime even after old entries roll off the window.
    """

    def __init__(self, window: int = 4096) -> None:
        self._events: deque[dict] = deque(maxlen=window)
        self._counts: dict[str, int] = {}
        self._seq = 0
        self._lock = threading.Lock()

    def record(self, kind: str, **fields: Any) -> dict:
        with self._lock:
            event = {"kind": kind, "seq": self._seq, "time": time.time(),
                     **fields}
            self._seq += 1
            self._events.append(event)
            self._counts[kind] = self._counts.get(kind, 0) + 1
        return event

    def count(self, kind: str) -> int:
        """Total events of ``kind`` recorded (not bounded by the window)."""
        with self._lock:
            return self._counts.get(kind, 0)

    def snapshot(self) -> dict:
        """Per-kind totals plus the most recent window of events."""
        with self._lock:
            return {"counts": dict(self._counts),
                    "recent": [dict(e) for e in self._events]}


class LatencyRecorder:
    """Bounded sliding window of latencies (seconds) with percentiles."""

    def __init__(self, window: int = 8192) -> None:
        self._samples: deque[float] = deque(maxlen=window)
        self._lock = threading.Lock()
        self._count = 0

    def record(self, seconds: float) -> None:
        with self._lock:
            self._samples.append(seconds)
            self._count += 1

    def snapshot(self) -> dict:
        """count plus p50/p99/mean in milliseconds over the window."""
        with self._lock:
            values = sorted(self._samples)
            count = self._count
        if not values:
            return {"count": 0, "p50_ms": 0.0, "p99_ms": 0.0, "mean_ms": 0.0}
        return {
            "count": count,
            "p50_ms": 1e3 * percentile(values, 50.0),
            "p99_ms": 1e3 * percentile(values, 99.0),
            "mean_ms": 1e3 * sum(values) / len(values),
        }
