"""Lightweight, dependency-free service metrics.

A :class:`LatencyRecorder` keeps a bounded window of samples and reports
percentiles over it; :class:`Counter` is a thread-safe monotonic counter.
Both expose ``snapshot()`` dicts that the service aggregates into one
metrics payload — the same shape ``benchmarks/bench_serving.py`` writes to
``BENCH_serving.json``.
"""
from __future__ import annotations

import threading
from collections import deque

__all__ = ["LatencyRecorder", "Counter", "percentile"]


def percentile(sorted_values: list[float], q: float) -> float:
    """Linear-interpolated percentile of an already-sorted list."""
    if not sorted_values:
        return 0.0
    if len(sorted_values) == 1:
        return sorted_values[0]
    pos = (q / 100.0) * (len(sorted_values) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(sorted_values) - 1)
    frac = pos - lo
    return sorted_values[lo] * (1.0 - frac) + sorted_values[hi] * frac


class Counter:
    """Thread-safe monotonic counter."""

    def __init__(self) -> None:
        self._value = 0
        self._lock = threading.Lock()

    def inc(self, by: int = 1) -> None:
        with self._lock:
            self._value += by

    @property
    def value(self) -> int:
        return self._value


class LatencyRecorder:
    """Bounded sliding window of latencies (seconds) with percentiles."""

    def __init__(self, window: int = 8192) -> None:
        self._samples: deque[float] = deque(maxlen=window)
        self._lock = threading.Lock()
        self._count = 0

    def record(self, seconds: float) -> None:
        with self._lock:
            self._samples.append(seconds)
            self._count += 1

    def snapshot(self) -> dict:
        """count plus p50/p99/mean in milliseconds over the window."""
        with self._lock:
            values = sorted(self._samples)
            count = self._count
        if not values:
            return {"count": 0, "p50_ms": 0.0, "p99_ms": 0.0, "mean_ms": 0.0}
        return {
            "count": count,
            "p50_ms": 1e3 * percentile(values, 50.0),
            "p99_ms": 1e3 * percentile(values, 99.0),
            "mean_ms": 1e3 * sum(values) / len(values),
        }
