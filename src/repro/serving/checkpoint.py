"""Session durability: checkpoint/restore of the serving session store.

Built on :class:`repro.checkpoint.manager.CheckpointManager` (atomic
npz+manifest directories, keep-K GC): one checkpoint snapshots every
resident tenant ``LKGPState`` (as a LIST pytree — list indices keep the
flattened keys unique and order-stable) plus a JSON-serialisable manifest
describing each session (tenant/task/generation/observes, array shapes,
dtype, and the full ``LKGPConfig``) and the monotonic observation log.

Restore is template-based: the manifest carries enough metadata to build a
correctly-shaped/dtyped template ``LKGPState`` per session, so
``PredictionService.restore()`` can rebuild warm sessions into an EMPTY
store after a crash — no live pytree needed. The observation log survives
alongside, so the service can tell which observations landed after the
snapshot (clients replay from ``next_seq``).
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
from collections import deque
from typing import Any

import jax.numpy as jnp

from ..checkpoint.manager import CheckpointManager
from ..core.state import LKGPConfig, LKGPState, init_params
from ..core.transforms import TTransform, XTransform, YTransform

__all__ = ["ObservationLog", "ServiceCheckpointer", "state_template"]


class ObservationLog:
    """Monotonic, thread-safe log of accepted observations.

    Each accepted ``observe`` appends ``{seq, tenant, task, action}``; the
    sequence number is strictly increasing for the life of the service
    (restores carry it forward), so "which observations post-date this
    checkpoint" is a single integer comparison. Bounded: only the newest
    ``window`` entries are retained (and checkpointed), the counter never
    resets.
    """

    def __init__(self, window: int = 8192) -> None:
        self._entries: deque[dict] = deque(maxlen=window)
        self._next_seq = 0
        self._lock = threading.Lock()

    def append(self, tenant: str, task: str, action: str) -> int:
        with self._lock:
            seq = self._next_seq
            self._next_seq += 1
            self._entries.append({"seq": seq, "tenant": tenant,
                                  "task": task, "action": action})
            return seq

    @property
    def next_seq(self) -> int:
        return self._next_seq

    def entries(self) -> list[dict]:
        with self._lock:
            return [dict(e) for e in self._entries]

    def load(self, entries: list[dict], next_seq: int) -> None:
        """Adopt a checkpointed log (restore path)."""
        with self._lock:
            self._entries.clear()
            self._entries.extend(dict(e) for e in entries)
            self._next_seq = max(int(next_seq), self._next_seq)


def state_template(n: int, m: int, d: int, dtype: Any,
                   config: LKGPConfig) -> LKGPState:
    """Correctly-shaped/dtyped placeholder state for checkpoint restore.

    Only shapes, dtypes, and the (metadata) config matter — every array
    leaf is overwritten by the restored values. Transform leaves are
    benign constants (NOT ``.fit`` of placeholder data, which would take
    logs/stds of meaningless values).
    """
    dtype = jnp.dtype(dtype)
    zeros = lambda *s: jnp.zeros(s, dtype)   # noqa: E731
    return LKGPState(
        params=init_params(d, dtype),
        X=zeros(n, d), t=jnp.ones((m,), dtype),
        Y=zeros(n, m), mask=jnp.ones((n, m), dtype),
        x_tf=XTransform(lo=zeros(d), hi=jnp.ones((d,), dtype)),
        t_tf=TTransform(log_t1=zeros(), log_tm=jnp.ones((), dtype)),
        y_tf=YTransform(shift=zeros(), scale=jnp.ones((), dtype)),
        config=config)


class ServiceCheckpointer:
    """Checkpoint/restore of a :class:`~repro.serving.store.SessionStore`.

    Saves are synchronous (``async_save=False``): the service calls this
    from its own observation path and the durability guarantee is "the
    checkpoint exists when ``save`` returns". Atomicity/keep-K come from
    the underlying manager.
    """

    def __init__(self, directory: str, keep: int = 3) -> None:
        self.directory = directory
        self._manager = CheckpointManager(directory, keep=keep,
                                          async_save=False)
        self._step = 0
        self._lock = threading.Lock()

    # -- write ------------------------------------------------------------
    def save(self, sessions: list, obs_log: ObservationLog | None = None
             ) -> int:
        """Snapshot the given sessions (+ observation log); returns step.

        ``sessions`` are :class:`~repro.serving.store.Session` objects;
        each is snapshotted under its own lock so a concurrent ``observe``
        cannot tear a state mid-copy.
        """
        metas, states = [], []
        for s in sessions:
            with s.lock:
                state, gen, obs = s.state, s.generation, s.observes
            metas.append({
                "tenant": s.key.tenant, "task": s.key.task,
                "generation": gen, "observes": obs,
                "n": state.n, "m": state.m, "d": state.d,  # shape dims: ints
                "dtype": str(jnp.asarray(state.Y).dtype),
                "config": dataclasses.asdict(state.config),
            })
            states.append(state)
        extra = {"sessions": metas, "next_seq": 0, "obs_log": []}
        if obs_log is not None:
            extra["obs_log"] = obs_log.entries()
            extra["next_seq"] = obs_log.next_seq
        with self._lock:
            self._step += 1
            step = self._step
        self._manager.save(step, states, extra=extra)
        return step

    # -- read -------------------------------------------------------------
    def latest_step(self) -> int | None:
        return self._manager.latest_step()

    def manifest(self, step: int | None = None) -> dict:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = os.path.join(self.directory, f"step_{step:010d}",
                            "manifest.json")
        with open(path) as f:
            return json.load(f)

    def load(self, step: int | None = None) -> tuple[list[dict],
                                                     list[LKGPState], dict]:
        """Load (session metas, restored states, manifest extra).

        States come back in the same order as the metas; the caller
        reinstalls them into a store (see ``PredictionService.restore``).
        """
        manifest = self.manifest(step)
        extra = manifest["extra"]
        metas = extra["sessions"]
        templates = [
            state_template(meta["n"], meta["m"], meta["d"], meta["dtype"],
                           LKGPConfig(**meta["config"]))
            for meta in metas
        ]
        states: list[LKGPState] = []
        if templates:
            states = self._manager.restore(templates,
                                           step=manifest["step"])
        with self._lock:
            self._step = max(self._step, int(manifest["step"]))
        return metas, states, extra
