"""Multi-tenant streaming prediction service (ROADMAP: serve heavy traffic).

Layered store -> batcher -> service:

* :mod:`~repro.serving.store`   — :class:`SessionStore`, an LRU of warm
  per-tenant/task :class:`~repro.core.state.LKGPState` sessions;
* :mod:`~repro.serving.batcher` — cross-tenant request coalescing into
  stackable groups, plus the Future-based async surface;
* :mod:`~repro.serving.service` — :class:`PredictionService`: cold fit /
  stream ``extend`` / warm ``refit`` lifecycle, per-request and coalesced
  prediction through one vmapped posterior, metrics;
* :mod:`~repro.serving.metrics` — latency percentiles, counters, and the
  structured :class:`EventLog` the reliability layer records into;
* :mod:`~repro.serving.checkpoint` — session durability: periodic
  :class:`ServiceCheckpointer` snapshots of the store + observation log,
  and the template-based restore behind ``PredictionService.restore()``.

Cache semantics in one line: solves are cached on the state object
(:mod:`repro.core.posterior`), sessions cache their stacked prediction
view, and every ``observe`` swaps the state — so invalidation is object
replacement, never bookkeeping.
"""
from .batcher import CoalescingBatcher, coalesce_sessions, stack_signature
from .checkpoint import ObservationLog, ServiceCheckpointer, state_template
from .metrics import Counter, EventLog, LatencyRecorder
from .service import Prediction, PredictionService, ServiceConfig
from .store import Session, SessionKey, SessionStore

__all__ = [
    "PredictionService", "ServiceConfig", "Prediction",
    "SessionStore", "SessionKey", "Session",
    "CoalescingBatcher", "coalesce_sessions", "stack_signature",
    "LatencyRecorder", "Counter", "EventLog",
    "ObservationLog", "ServiceCheckpointer", "state_template",
]
