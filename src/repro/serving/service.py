"""Multi-tenant streaming prediction service over warm LKGP states.

Request lifecycle per tenant/task session:

* **cold fit** — the first ``observe`` fits a fresh :class:`LKGPState`
  (optionally coalesced across tenants via ``fit_batch``);
* **stream extend** — subsequent ``observe`` calls fold newly observed
  epochs in via ``extend`` (transforms refit, hyper-parameters carried as
  a warm start);
* **warm refit** — every ``refit_every``-th observation re-optimises
  hyper-parameters for a few L-BFGS steps from the warm start;
* **predict** — evaluates the exact batched posterior of the session's
  state. Repeated predictions on an unchanged session hit the state-keyed
  posterior cache (zero additional solves); any ``observe`` swaps the
  state object, which *is* the invalidation.

Predictions — served alone or coalesced across tenants through
:class:`~repro.serving.batcher.CoalescingBatcher` — always run through the
same vmapped batched-posterior function, so a request's results are
bitwise identical whichever path served it.

Reliability: invalid payloads (non-finite observed values, out-of-grid
masks — :class:`~repro.core.errors.ObservationError`) and exhausted solver
escalation (:class:`~repro.core.solvers.guarded.GuardedSolveError`) are
**quarantined**, never propagated: the offending observation is rejected,
the session keeps serving from its last good state, and the event lands in
the service :class:`~repro.serving.metrics.EventLog`. With
``checkpoint_dir`` set, the session store is periodically snapshotted
(:mod:`repro.serving.checkpoint`) and :meth:`PredictionService.restore`
rebuilds warm sessions after a crash.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Sequence

import numpy as np

from ..core.errors import ObservationError
from ..core.posterior import posterior_batch
from ..core.solvers.guarded import GuardedSolveError
from ..core.state import LKGPConfig, LKGPState, extend, fit, fit_batch, refit
from .batcher import CoalescingBatcher, coalesce_sessions
from .checkpoint import ObservationLog, ServiceCheckpointer
from .metrics import Counter, EventLog, LatencyRecorder
from .store import Session, SessionKey, SessionStore

__all__ = ["ServiceConfig", "Prediction", "PredictionService"]


@dataclass(frozen=True)
class ServiceConfig:
    """Service policy knobs (the GP itself is configured via ``gp``).

    ``gp.hyper_init`` / ``gp.polish_steps`` select the fit strategy for
    every session: the default host L-BFGS, or an amortized /
    default-init start polished by a fixed budget of device L-BFGS steps
    (one compiled program shared across all tenants — see
    :mod:`repro.amortize` and :mod:`repro.core.polish`).
    """

    gp: LKGPConfig = field(default_factory=LKGPConfig)
    capacity: int = 64            # LRU cap on resident sessions
    refit_every: int = 4          # warm refit every k-th observe (0 = never)
    refit_lbfgs_iters: int = 5    # L-BFGS budget of a warm refit (host path
    #                               only; ignored when gp.polish_steps >= 0)
    coalesce: bool = True         # allow cross-tenant fit coalescing
    checkpoint_dir: str | None = None   # None: durability off
    checkpoint_every: int = 8     # snapshot every k-th accepted observe
    checkpoint_keep: int = 3      # keep-K checkpoint GC


@dataclass(frozen=True)
class Prediction:
    """Final-progression prediction for every config of one task."""

    tenant: str
    task: str
    mean: np.ndarray        # (n,) final-epoch posterior mean, y units
    var: np.ndarray         # (n,) final-epoch predictive variance
    generation: int         # session generation that produced it
    batch_size: int         # how many requests shared the vmapped call


class PredictionService:
    """Thread-safe front door: ``observe`` / ``predict`` / ``flush``."""

    def __init__(self, config: ServiceConfig | None = None) -> None:
        self.config = config or ServiceConfig()
        self.store = SessionStore(capacity=self.config.capacity)
        self.batcher = CoalescingBatcher(self._execute_group)
        self.predict_latency = LatencyRecorder()
        self.observe_latency = LatencyRecorder()
        self.events = EventLog()
        self.obs_log = ObservationLog()
        self.checkpointer: ServiceCheckpointer | None = None
        if self.config.checkpoint_dir is not None:
            self.checkpointer = ServiceCheckpointer(
                self.config.checkpoint_dir, keep=self.config.checkpoint_keep)
        self.counters = {
            "predicts": Counter(),
            "observes": Counter(),
            "cold_fits": Counter(),
            "extends": Counter(),
            "refits": Counter(),
            "coalesced_groups": Counter(),
            "coalesced_requests": Counter(),
            "quarantined": Counter(),
            "checkpoints": Counter(),
            "restores": Counter(),
        }

    # -- observation path --------------------------------------------------
    def observe(self, tenant: str, task: str, Y, mask,
                X=None, t=None) -> dict:
        """Stream observations into a session; creates it on first call.

        First call for a key must carry the task's configs ``X`` (n, d)
        and progression grid ``t`` (m,) alongside the initial observed
        grids ``Y`` / ``mask`` (n, m) — a cold fit. Later calls pass the
        *full updated* ``Y`` / ``mask`` over the same grid (``mask`` a
        superset of what the session has seen) — an ``extend`` plus, every
        ``refit_every``-th time, a warm ``refit``.

        Invalid payloads and exhausted solver escalation are quarantined:
        the call returns ``action="quarantined"`` (with the error message),
        the session — if one exists — keeps serving from its last good
        state, and the event is recorded. Nothing is raised; a misbehaving
        tenant cannot take the service down.
        """
        start = time.perf_counter()
        key = SessionKey(tenant, task)
        session = self.store.get(key)
        try:
            if session is None:
                if X is None or t is None:
                    raise KeyError(
                        f"unknown session {key}: the first observe must "
                        "include X and t for the cold fit")
                state = fit(X, t, Y, mask, self.config.gp)
                session = self.store.put(key, state)
                action = "fit"
                self.counters["cold_fits"].inc()
            else:
                with session.lock:
                    # Build the candidate state FULLY before touching any
                    # session field: an ObservationError / exhausted
                    # escalation below leaves the session exactly as it
                    # was (last good state keeps serving).
                    state = extend(session.state, Y, mask)
                    session.observes += 1
                    action = "extend"
                    self.counters["extends"].inc()
                    every = self.config.refit_every
                    if every > 0 and session.observes % every == 0:
                        state = refit(
                            state, lbfgs_iters=self.config.refit_lbfgs_iters)
                        action = "extend+refit"
                        self.counters["refits"].inc()
                    session.swap_state(state)
        except (ObservationError, GuardedSolveError) as e:
            self.counters["quarantined"].inc()
            self.events.record(
                "quarantine", tenant=tenant, task=task,
                error=type(e).__name__, detail=str(e))
            self.observe_latency.record(time.perf_counter() - start)
            return {"tenant": tenant, "task": task, "action": "quarantined",
                    "error": str(e),
                    "generation": session.generation if session else -1}
        self.counters["observes"].inc()
        self.obs_log.append(tenant, task, action)
        self._maybe_checkpoint()
        self.observe_latency.record(time.perf_counter() - start)
        return {"tenant": tenant, "task": task, "action": action,
                "generation": session.generation}

    def observe_batch(self, requests: Sequence[dict]) -> list[dict]:
        """Coalesced cold fits: one ``fit_batch`` for same-shape new tasks.

        Each request is the kwargs of :meth:`observe` (with ``tenant`` /
        ``task``). Requests for *new* sessions whose shapes match are
        jointly fitted in one ``fit_batch``; everything else falls back to
        per-request :meth:`observe`. With the default host L-BFGS
        (``gp.polish_steps == -1``) the joint fit shares the line search
        across tasks, so hyper-parameters may differ slightly from an
        individual fit; with ``gp.polish_steps >= 0`` every task runs the
        same compiled fixed-budget polish a single-task fit runs and the
        coalesced results are bitwise identical to individual observes
        (matching the posterior parity guarantee of *prediction*
        coalescing).
        """
        out: list[dict | None] = [None] * len(requests)
        cold: dict[tuple, list[int]] = {}
        for i, req in enumerate(requests):
            key = SessionKey(req["tenant"], req["task"])
            is_cold = (self.config.coalesce and key not in self.store
                       and req.get("X") is not None
                       and req.get("t") is not None)
            if is_cold:
                sig = (np.shape(req["X"]), np.shape(req["t"]),
                       np.shape(req["Y"]))
                cold.setdefault(sig, []).append(i)
            else:
                out[i] = self.observe(**req)
        for indices in cold.values():
            if len(indices) == 1:
                i = indices[0]
                out[i] = self.observe(**requests[i])
                continue
            start = time.perf_counter()
            group = [requests[i] for i in indices]
            X = np.stack([np.asarray(r["X"]) for r in group])
            t = np.stack([np.asarray(r["t"]) for r in group])
            Y = np.stack([np.asarray(r["Y"]) for r in group])
            mask = np.stack([np.asarray(r["mask"]) for r in group])
            try:
                batched = fit_batch(X, t, Y, mask, self.config.gp)
            except ObservationError:
                # One poisoned payload must not sink the whole coalesced
                # group: fall back to per-request observes, which fit the
                # healthy ones and quarantine the offender individually.
                for i in indices:
                    out[i] = self.observe(**requests[i])
                continue
            from ..core.state import unstack
            states = unstack(batched)
            self.counters["coalesced_groups"].inc()
            self.counters["coalesced_requests"].inc(len(group))
            for i, state in zip(indices, states):
                req = requests[i]
                key = SessionKey(req["tenant"], req["task"])
                session = self.store.put(key, state)
                self.counters["cold_fits"].inc()
                self.counters["observes"].inc()
                self.obs_log.append(req["tenant"], req["task"], "fit_batch")
                out[i] = {"tenant": req["tenant"], "task": req["task"],
                          "action": "fit_batch",
                          "generation": session.generation}
            self._maybe_checkpoint()
            self.observe_latency.record(time.perf_counter() - start)
        return [r for r in out if r is not None]

    # -- durability --------------------------------------------------------
    def _maybe_checkpoint(self) -> None:
        every = self.config.checkpoint_every
        if (self.checkpointer is not None and every > 0
                and self.counters["observes"].value % every == 0):
            self.checkpoint()

    def checkpoint(self) -> int | None:
        """Snapshot every resident session (+ observation log) durably.

        Returns the checkpoint step, or None when durability is off
        (``checkpoint_dir`` unset). Sessions are snapshotted under their
        own locks; the write is atomic (temp dir + rename).
        """
        if self.checkpointer is None:
            return None
        step = self.checkpointer.save(list(self.store.sessions()),
                                      self.obs_log)
        self.counters["checkpoints"].inc()
        self.events.record("checkpoint", step=step, sessions=len(self.store))
        return step

    def restore(self, step: int | None = None) -> int:
        """Rebuild warm sessions from the latest (or given) checkpoint.

        Reinstalls every checkpointed session into the store with its
        state, ``generation`` and ``observes`` intact — a restored session
        serves predictions immediately, bitwise identical to the moment it
        was snapshotted. Also adopts the checkpointed observation log so
        sequence numbers keep increasing monotonically across the crash.
        Returns the number of sessions restored.
        """
        if self.checkpointer is None:
            raise RuntimeError("durability is off: ServiceConfig."
                               "checkpoint_dir is not set")
        metas, states, extra = self.checkpointer.load(step)
        for meta, state in zip(metas, states):
            key = SessionKey(meta["tenant"], meta["task"])
            session = self.store.put(key, state)
            session.generation = int(meta["generation"])
            session.observes = int(meta["observes"])
        self.obs_log.load(extra.get("obs_log", []),
                          extra.get("next_seq", 0))
        self.counters["restores"].inc()
        self.events.record("restore", sessions=len(metas),
                           next_seq=self.obs_log.next_seq)
        return len(metas)

    # -- prediction path ---------------------------------------------------
    def _session(self, tenant: str, task: str) -> Session:
        session = self.store.get(SessionKey(tenant, task))
        if session is None:
            raise KeyError(f"no session for {(tenant, task)}; observe first")
        return session

    def _finalize(self, session: Session, mean_row: np.ndarray,
                  var_row: np.ndarray, batch_size: int) -> Prediction:
        return Prediction(
            tenant=session.key.tenant, task=session.key.task,
            mean=mean_row, var=var_row,
            generation=session.generation, batch_size=batch_size)

    def _execute_group(self, group: list[Session]) -> list[Prediction]:
        """One vmapped posterior evaluation for a stackable session group."""
        from ..core.state import stack_states
        if len(group) == 1:
            # A group of one reuses the session's cached stacked view so a
            # repeat request hits the state-keyed posterior cache.
            stacked = group[0].stacked()
        else:
            stacked = stack_states([s.state for s in group])
            self.counters["coalesced_groups"].inc()
            self.counters["coalesced_requests"].inc(len(group))
        bp = posterior_batch(stacked)
        # Warm requests re-read host arrays: the numpy conversion of the
        # default final() is cached on the batched posterior, whose own
        # lifetime is the state's — invalidation stays object replacement.
        final_np = getattr(bp, "_final_np", None)
        if final_np is None:
            mean, var = bp.final()
            final_np = (np.asarray(mean), np.asarray(var))
            bp._final_np = final_np
        mean_np, var_np = final_np
        return [self._finalize(s, mean_np[i], var_np[i], len(group))
                for i, s in enumerate(group)]

    def predict(self, tenant: str, task: str) -> Prediction:
        """Final-value prediction for one session (batch of one)."""
        start = time.perf_counter()
        session = self._session(tenant, task)
        result = self._execute_group([session])[0]
        self.counters["predicts"].inc()
        self.predict_latency.record(time.perf_counter() - start)
        return result

    def predict_many(self, keys: Sequence[tuple[str, str]]) -> list[Prediction]:
        """Coalesced predictions: stackable sessions share one vmapped call.

        Results are bitwise identical to per-request :meth:`predict` — both
        paths run the same compiled batched-posterior function, whose
        per-row computation is batch-size invariant by construction.
        """
        start = time.perf_counter()
        sessions = [self._session(tenant, task) for tenant, task in keys]
        out: list[Prediction | None] = [None] * len(sessions)
        for indices in coalesce_sessions(sessions):
            results = self._execute_group([sessions[i] for i in indices])
            for i, result in zip(indices, results):
                out[i] = result
        self.counters["predicts"].inc(len(keys))
        self.predict_latency.record(time.perf_counter() - start)
        return [r for r in out if r is not None]

    def submit_predict(self, tenant: str, task: str):
        """Async surface: enqueue a request, resolved at :meth:`flush`."""
        return self.batcher.submit(self._session(tenant, task))

    def flush(self) -> int:
        """Resolve all queued :meth:`submit_predict` futures, coalesced."""
        return self.batcher.flush()

    # -- introspection -----------------------------------------------------
    def metrics(self) -> dict:
        from ..core.engines import engine_cache_stats
        from ..core.state import compiled_cache_stats

        return {
            "store": self.store.stats(),
            "predict_latency": self.predict_latency.snapshot(),
            "observe_latency": self.observe_latency.snapshot(),
            "counters": {k: c.value for k, c in self.counters.items()},
            "events": self.events.snapshot(),
            # process-wide compiled-program LRU caches the fit/refit path
            # runs on — a hot service should show hits >> misses and zero
            # evictions; evictions here mean recompiles in the latency path.
            "compiled_caches": {**compiled_cache_stats(),
                                "engines": engine_cache_stats()},
        }
