"""Version shims for jax API drift.

``shard_map`` moved from ``jax.experimental.shard_map`` to the ``jax``
top level, and its replication-check kwarg was renamed
``check_rep`` -> ``check_vma`` along the way. Every shard_map call site in
this repo goes through :func:`shard_map` below so the rest of the code can
use the modern spelling on any jax in the supported range.
"""
from __future__ import annotations

import functools
import inspect

try:  # modern jax: top-level export
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
except ImportError:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

# The check_rep -> check_vma rename happened independently of the top-level
# export, so detect the kwarg from the signature rather than the location.
try:
    _CHECK_KW = ("check_vma"
                 if "check_vma" in inspect.signature(_shard_map).parameters
                 else "check_rep")
except (ValueError, TypeError):  # signature unavailable: assume modern name
    _CHECK_KW = "check_vma"

__all__ = ["shard_map"]


@functools.wraps(_shard_map)
def shard_map(f=None, /, *, mesh, in_specs, out_specs, check_vma=True):
    kwargs = {_CHECK_KW: check_vma}
    if f is None:
        return functools.partial(_shard_map, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, **kwargs)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)
