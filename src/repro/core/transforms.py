"""Input / output transformations (paper App. B, verbatim).

* x in R^d  -> unit hypercube via per-dimension min/max of the training data.
* t         -> log t, shifted/scaled so [t_1, t_m] maps to [0, 1]
               (logarithmic spacing of the unit interval).
* Y         -> subtract max(Y_observed), divide by std over observed elements.
               (Subtracting the max centres converged accuracies near 0 and
               makes the zero-mean GP prior a "curves saturate" prior.)
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

__all__ = ["XTransform", "TTransform", "YTransform"]


class XTransform(NamedTuple):
    lo: jnp.ndarray  # (d,)
    hi: jnp.ndarray  # (d,)

    @staticmethod
    def fit(X: jnp.ndarray) -> "XTransform":
        lo = jnp.min(X, axis=0)
        hi = jnp.max(X, axis=0)
        # Constant dimensions map to 0.5 instead of dividing by zero.
        hi = jnp.where(hi == lo, lo + 1.0, hi)
        return XTransform(lo=lo, hi=hi)

    def __call__(self, X: jnp.ndarray) -> jnp.ndarray:
        return (X - self.lo) / (self.hi - self.lo)


class TTransform(NamedTuple):
    log_t1: jnp.ndarray
    log_tm: jnp.ndarray

    @staticmethod
    def fit(t: jnp.ndarray) -> "TTransform":
        lt = jnp.log(t)
        lo, hi = lt[0], lt[-1]
        hi = jnp.where(hi == lo, lo + 1.0, hi)
        return TTransform(log_t1=lo, log_tm=hi)

    def __call__(self, t: jnp.ndarray) -> jnp.ndarray:
        return (jnp.log(t) - self.log_t1) / (self.log_tm - self.log_t1)


class YTransform(NamedTuple):
    shift: jnp.ndarray  # max over observed values
    scale: jnp.ndarray  # std over observed values

    @staticmethod
    def fit(Y: jnp.ndarray, mask: jnp.ndarray) -> "YTransform":
        big_neg = jnp.asarray(-jnp.inf, Y.dtype)
        shift = jnp.max(jnp.where(mask > 0, Y, big_neg))
        cnt = jnp.sum(mask)
        mean = jnp.sum(Y * mask) / cnt
        var = jnp.sum(mask * (Y - mean) ** 2) / cnt
        scale = jnp.sqrt(jnp.maximum(var, 1e-12))
        return YTransform(shift=shift, scale=scale)

    def __call__(self, Y: jnp.ndarray) -> jnp.ndarray:
        return (Y - self.shift) / self.scale

    def inverse(self, Z: jnp.ndarray) -> jnp.ndarray:
        return Z * self.scale + self.shift

    def inverse_var(self, V: jnp.ndarray) -> jnp.ndarray:
        return V * self.scale**2
