"""Lazy posterior over the latent grid, behind one ``PosteriorLike`` API.

A :class:`Posterior` is cheap to construct: nothing is computed until a
property is read. The expensive CG solve of ``alpha = K^{-1} (Y * mask)``
is computed once and cached, then shared between

* the exact posterior mean  ``K1[:, :n] @ alpha @ K2``  and
* Matheron-rule samples: by linearity,
  ``K^{-1}(Y - F - eps) = alpha - K^{-1}(F + eps)``, so each sampling call
  only solves for the (F + eps) part and reuses the cached ``alpha`` — the
  sample mean is exactly consistent with the exact mean.

Solves are consolidated: if samples are requested before ``alpha`` exists,
the posterior stacks ``[Y * mask | Matheron residuals]`` into ONE multi-RHS
block solve, so a full posterior evaluation (``final()``: exact mean +
Matheron variance) costs a single batched operator sweep instead of two.
The block solver's per-column diagnostics (iterations, true residuals,
breakdown flags) from the most recent solve are exposed as
:attr:`Posterior.solve_info`; :attr:`Posterior.solve_count` counts the
engine solves this posterior has performed.

Caching is *state-keyed*: :func:`posterior` attaches the lazy posterior to
the state instance itself, so repeated ``posterior(state)`` calls on an
unchanged state return the SAME object and reuse its resident
``K^{-1}[y | residuals]`` instead of re-running the stacked solve. Because
``extend`` / ``refit`` are functional (they return fresh state objects),
derived states never see a stale cache — invalidation is construction.
Per-call control via ``posterior(state, cache=...)``; the default policy
is ``LKGPConfig.posterior_cache``.

:class:`Posterior` (lazy, engine-backed, Matheron MC variance) and
:class:`BatchedPosterior` (vmapped exact dense, one task per batch row)
both conform to the :class:`PosteriorLike` protocol — ``mean`` /
``variance`` / ``samples(key, n_samples)`` / ``final(key, n_samples)`` /
``solve_info`` — so callers (schedulers, the serving layer) swap them
without isinstance checks.

All solves go through the inference engine resolved from the state's
config (or an explicitly provided engine), so the posterior path uses the
same backend — dense, iterative, pallas, or distributed — as fitting.
"""
from __future__ import annotations

import threading
from functools import cached_property
from typing import Any, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from . import gp_kernels as gk
from .engines import get_engine
from .matheron import kronecker_correction, prior_residual_draws
from .mvm import kron_dense
from .state import LKGPState, resolve_backend

__all__ = ["PosteriorLike", "Posterior", "posterior", "joint_grams",
           "BatchedPosterior", "posterior_batch"]


@runtime_checkable
class PosteriorLike(Protocol):
    """One posterior interface for lazy and batched implementations.

    ``mean`` / ``variance`` cover the full grid (original y units);
    ``samples`` draws posterior functions; ``final`` returns the
    final-progression (mean, var) per config; ``solve_info`` surfaces the
    most recent solver diagnostics (None for exact paths that have none).
    """

    @property
    def mean(self) -> jnp.ndarray: ...

    @property
    def variance(self) -> jnp.ndarray: ...

    @property
    def solve_info(self) -> Any: ...

    def samples(self, key, n_samples: int | None = None) -> jnp.ndarray: ...

    def final(self, key=None, n_samples: int | None = None): ...


def joint_grams(state: LKGPState, Xs=None):
    """K1 over [X_train; X_test] (transformed) and K2 over t (jittered).

    Matches the training-time Gram construction: K2 carries the jitter, the
    joint K1 does not (its train block is only used inside the noisy
    operator; Cholesky call sites add jitter themselves).
    """
    cfg = state.config
    p = state.params
    Xn = state.x_tf(state.X)
    tn = state.t_tf(state.t)
    K2 = gk.KERNELS_1D[cfg.t_kernel](
        tn, tn, jnp.exp(p.raw_t_lengthscale), jnp.exp(p.raw_outputscale))
    K2 = K2 + cfg.jitter * jnp.eye(tn.shape[0], dtype=K2.dtype)
    if Xs is None:
        Xa = Xn
    else:
        Xa = jnp.concatenate([Xn, state.x_tf(jnp.asarray(Xs, Xn.dtype))], 0)
    K1a = gk.rbf_ard(Xa, Xa, jnp.exp(p.raw_x_lengthscale))
    return K1a, K2


class Posterior:
    """Lazy LKGP posterior over the full (train [+ test]) x t grid.

    Rows ``[:n]`` of every product are curve continuations for the training
    configs; if ``Xs`` was given, rows ``[n:]`` are predictions for the new
    configs. All outputs are in original y units.
    """

    def __init__(self, state: LKGPState, Xs=None, engine=None):
        self._state = state
        self._Xs = Xs
        if engine is None:
            # An engine explicitly injected at fit() time (e.g. bound to a
            # specific mesh) is pinned on the state; otherwise resolve from
            # config and observation count.
            engine = getattr(state, "engine", None)
        if engine is None:
            n_obs = int(np.sum(np.asarray(state.mask)))
            engine = get_engine(resolve_backend(state.config, n_obs))
        self._engine = engine
        self._alpha: jnp.ndarray | None = None   # cached K^{-1}(Y*mask)
        self._solve_info: Any = None  # CGResult of most recent engine solve
        self._n_solves = 0            # engine solves performed (sweeps run)

    # -- cached pieces -----------------------------------------------------
    @cached_property
    def _grams(self):
        return joint_grams(self._state, self._Xs)

    @cached_property
    def _operator(self):
        """A = P (K1 (x) K2) P^T + sigma^2 I over the training block."""
        K1a, K2 = self._grams
        n = self._state.n
        noise = jnp.exp(self._state.params.raw_noise)
        return self._engine.operator_from_grams(
            K1a[:n, :n], K2, self._state.mask, noise)

    def _solve(self, rhs):
        """Engine solve capturing the block solver's diagnostics."""
        x = self._engine.solve(self._operator, rhs, self._state.config)
        self._solve_info = getattr(self._operator, "last_result", None)
        self._n_solves += 1
        return x

    @property
    def alpha(self):
        """Cached K^{-1} (Y * mask) in transformed space (grid form)."""
        if self._alpha is None:
            st = self._state
            Ym = st.y_tf(st.Y) * st.mask
            self._alpha = self._solve(Ym)
        return self._alpha

    @property
    def solve_info(self):
        """Diagnostics (:class:`repro.core.solvers.CGResult`) of the most recent
        solve through this posterior — per-column iterations, true
        residuals, and breakdown flags — or None before any solve (or for
        engines that do not report them, e.g. the exact dense solve)."""
        return self._solve_info

    @property
    def solve_count(self) -> int:
        """Number of engine solves (batched operator sweeps) this posterior
        has run. A state-cache hit returns the same posterior object, so a
        repeated evaluation leaves this counter unchanged — the handle the
        serving benchmark uses to verify the solve cache."""
        return self._n_solves

    # -- products ----------------------------------------------------------
    @property
    def mean(self) -> jnp.ndarray:
        """Exact posterior mean over the grid: (n(+n*), m), y units."""
        K1a, K2 = self._grams
        n = self._state.n
        mean_t = jnp.einsum("aj,jm,mk->ak", K1a[:, :n], self.alpha, K2)
        return self._state.y_tf.inverse(mean_t)

    def samples(self, key, n_samples: int | None = None) -> jnp.ndarray:
        """Matheron-rule posterior samples: (s, n(+n*), m), y units.

        If ``alpha`` is not cached yet, ``[Y * mask | residuals]`` are
        stacked into ONE multi-RHS block solve (a single batched operator
        sweep yields the exact mean's alpha AND every sample); afterwards
        samples reuse the cached alpha and only solve the residual part.
        """
        st = self._state
        cfg = st.config
        n_samples = n_samples or cfg.posterior_samples
        K1a, K2 = self._grams
        n = st.n
        noise = jnp.exp(st.params.raw_noise)
        F, eps = prior_residual_draws(key, K1a, K2, n, noise, n_samples,
                                      jitter=cfg.jitter)
        resid = st.mask * (F[:, :n, :] + eps)
        if self._alpha is None:
            Ym = st.y_tf(st.Y) * st.mask
            sol = self._solve(jnp.concatenate([Ym[None], resid], axis=0))
            self._alpha = sol[0]
            u = sol[0][None] - sol[1:]
        else:
            # Linearity: K^{-1}(Y - F - eps) = alpha - K^{-1}(F + eps).
            u = self._alpha[None] - self._solve(resid)
        raw = F + kronecker_correction(K1a, u, K2, n)
        return st.y_tf.inverse(raw)

    @cached_property
    def _default_samples(self):
        cfg = self._state.config
        # fold_in tag 1: the cached default-sample stream. final()'s
        # explicit-key fallback uses tag 2 so the two paths never share
        # randomness (they used to both build PRNGKey(seed + 1)).
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), 1)
        return self.samples(key)

    @property
    def variance(self) -> jnp.ndarray:
        """Predictive variance (Matheron MC estimate + observation noise)."""
        st = self._state
        var_f = jnp.var(self._default_samples, axis=0)
        return var_f + st.y_tf.inverse_var(jnp.exp(st.params.raw_noise))

    def final(self, key=None, n_samples: int | None = None):
        """(mean, var) of the final-progression value per config.

        Mean is exact (cached CG solve); variance is estimated from Matheron
        samples plus observation noise — the Fig. 4 protocol.
        """
        st = self._state
        # Samples first: on a fresh posterior this folds the alpha solve and
        # the Matheron residual solves into ONE stacked operator sweep; the
        # mean below then reads the alpha cached by that same solve.
        if key is None and n_samples is None:
            s = self._default_samples[:, :, -1]   # cached; same default key
        else:
            if key is None:
                # tag 2: distinct from the _default_samples stream (tag 1).
                key = jax.random.fold_in(
                    jax.random.PRNGKey(st.config.seed), 2)
            s = self.samples(key, n_samples)[:, :, -1]
        mean = self.mean[:, -1]
        var_f = jnp.var(s, axis=0)
        var_y = var_f + st.y_tf.inverse_var(jnp.exp(st.params.raw_noise))
        return mean, var_y


# -- state-keyed solve cache -----------------------------------------------
# The cached posterior lives ON the state instance (attached the same way
# fit() attaches its diagnostics), so its lifetime is exactly the state's:
# extend/refit build new objects and therefore start cold, evicting a
# session's state drops its solves with it. The lock only guards the
# get-or-create so concurrent serving threads share one posterior.
_CACHE_ATTR = "_posterior_cache"
_BATCH_CACHE_ATTR = "_posterior_batch_cache"
_CACHE_LOCK = threading.Lock()


def _state_cached(state, attr: str, build):
    with _CACHE_LOCK:
        post = getattr(state, attr, None)
        if post is None:
            post = build()
            object.__setattr__(state, attr, post)
        return post


def posterior(state: LKGPState, Xs=None, engine=None,
              cache: bool | None = None) -> Posterior:
    """Lazy posterior for a fitted state (optionally at new configs Xs).

    ``cache=None`` (default) consults ``state.config.posterior_cache``:
    when on, repeated calls on the same state object return ONE shared
    :class:`Posterior` whose solves are resident — the second call performs
    zero additional operator sweeps. Explicit ``Xs`` / ``engine`` arguments
    always bypass the cache (their results are not state-determined);
    ``cache=False`` forces a fresh posterior; ``cache=True`` demands the
    cached one and raises if the call is not cacheable.
    """
    cacheable = Xs is None and engine is None
    if cache is None:
        cache = cacheable and state.config.posterior_cache
    elif cache and not cacheable:
        raise ValueError("cache=True requires the state-determined "
                         "posterior: no explicit Xs or engine")
    if not cache:
        return Posterior(state, Xs=Xs, engine=engine)
    return _state_cached(state, _CACHE_ATTR, lambda: Posterior(state))


# -- batched exact posterior (one vmapped call over fit_batch states) ------
# The jitted+vmapped product functions are cached per (t_kernel, jitter) at
# module level: a fresh closure per BatchedPosterior would make every
# serving request retrace, turning the coalesced hot path into a compile
# benchmark. Same-shape requests now hit jit's own executable cache.
_BATCHED_FN_CACHE: dict = {}


def _batched_products_fn(t_kernel: str, jitter: float):
    key = ("products", t_kernel, jitter)
    fn = _BATCHED_FN_CACHE.get(key)
    if fn is not None:
        return fn
    k2fn = gk.KERNELS_1D[t_kernel]

    def one(params, X, t, Y, mask, x_tf, t_tf, y_tf):
        Xn, tn, Yn = x_tf(X), t_tf(t), y_tf(Y)
        n, m = mask.shape
        K2 = k2fn(tn, tn, jnp.exp(params.raw_t_lengthscale),
                  jnp.exp(params.raw_outputscale))
        K2 = K2 + jitter * jnp.eye(m, dtype=K2.dtype)
        K1 = gk.rbf_ard(Xn, Xn, jnp.exp(params.raw_x_lengthscale))
        noise = jnp.exp(params.raw_noise)

        mv = mask.reshape(-1)
        Kd = kron_dense(K1, K2) * (mv[:, None] * mv[None, :])
        Kd = Kd + jnp.diag(noise * mv + (1.0 - mv))
        L = jnp.linalg.cholesky(Kd)
        ym = (Yn * mask).reshape(-1)
        # Joint-covariance rows at the final-epoch cells, used both for the
        # exact final variance and (below) stacked with ym into ONE
        # multi-RHS solve.
        Krhs = (K1[:, :, None] * K2[:, -1][None, None, :]) * mask[None]
        Krhs = Krhs.reshape(n, n * m)
        # Bitwise per-request == coalesced (the serving guarantee) bans two
        # constructs whose lowering changes with batch size: single-column
        # triangular solves (XLA vectorizes trsv across the batch) and
        # gemm-based means (per-B tiling). So ym rides along the multi-RHS
        # solve, and the mean contraction is broadcast-multiply + reduce.
        sol = jax.scipy.linalg.cho_solve(
            (L, True), jnp.concatenate([ym[:, None], Krhs.T], axis=1))
        alpha = sol[:, 0] * mv
        S = sol[:, 1:]                                      # (N, n)
        ag = alpha.reshape(n, m)
        tmp = jnp.sum(ag[:, :, None] * K2[None, :, :], axis=1)     # (n, m)
        mean_t = jnp.sum(K1[:, :, None] * tmp[None, :, :], axis=1)

        # Exact latent variance of each config's final-epoch value:
        # var_i = K1[ii] K2[mm] - k_i^T A^{-1} k_i with k_i the masked
        # joint-covariance row at cell (i, m-1).
        quad = jnp.sum(Krhs.T * S, axis=0)
        var_f = jnp.diag(K1) * K2[-1, -1] - quad
        var_f = jnp.maximum(var_f, 0.0)
        return (y_tf.inverse(mean_t),
                y_tf.inverse_var(var_f + noise))

    fn = _BATCHED_FN_CACHE[key] = jax.jit(jax.vmap(one))
    return fn


def _batched_cov_fn(t_kernel: str, jitter: float):
    """Full-grid exact posterior: mean (transformed), per-cell variance in
    y units (incl. noise), and the Cholesky of the latent grid covariance
    (for joint sampling) — per task, vmapped over the batch."""
    key = ("cov", t_kernel, jitter)
    fn = _BATCHED_FN_CACHE.get(key)
    if fn is not None:
        return fn
    k2fn = gk.KERNELS_1D[t_kernel]

    def one(params, X, t, Y, mask, x_tf, t_tf, y_tf):
        Xn, tn, Yn = x_tf(X), t_tf(t), y_tf(Y)
        n, m = mask.shape
        N = n * m
        K2 = k2fn(tn, tn, jnp.exp(params.raw_t_lengthscale),
                  jnp.exp(params.raw_outputscale))
        K2 = K2 + jitter * jnp.eye(m, dtype=K2.dtype)
        K1 = gk.rbf_ard(Xn, Xn, jnp.exp(params.raw_x_lengthscale))
        noise = jnp.exp(params.raw_noise)

        mv = mask.reshape(-1)
        Kfull = kron_dense(K1, K2)
        Kd = Kfull * (mv[:, None] * mv[None, :])
        Kd = Kd + jnp.diag(noise * mv + (1.0 - mv))
        L = jnp.linalg.cholesky(Kd)
        ym = (Yn * mask).reshape(-1)
        # Latent covariance of f on EVERY grid cell given the observed
        # cells: C = K - Kx A^{-1} Kx^T with Kx the cross-covariance whose
        # unobserved columns are zeroed (those rows/cols of A are identity,
        # so they contribute nothing to the solve). ym rides along as one
        # more RHS column and the mean uses reduce-style contractions —
        # batch-size-stable bits, see _batched_products_fn.
        Kx = Kfull * mv[None, :]
        sol = jax.scipy.linalg.cho_solve(
            (L, True), jnp.concatenate([ym[:, None], Kx.T], axis=1))
        alpha = sol[:, 0] * mv
        S = sol[:, 1:]                                       # (N, N)
        ag = alpha.reshape(n, m)
        tmp = jnp.sum(ag[:, :, None] * K2[None, :, :], axis=1)
        mean_t = jnp.sum(K1[:, :, None] * tmp[None, :, :], axis=1)
        C = Kfull - Kx @ S
        var_grid = jnp.maximum(jnp.diag(C), 0.0).reshape(n, m)
        Lc = jnp.linalg.cholesky(
            C + 10.0 * jitter * jnp.eye(N, dtype=C.dtype))
        scale = y_tf.scale
        var_y = y_tf.inverse_var(var_grid + noise)
        return mean_t, var_y, Lc, y_tf.shift, scale

    fn = _BATCHED_FN_CACHE[key] = jax.jit(jax.vmap(one))
    return fn


class BatchedPosterior:
    """Vmapped exact posterior over a batch of tasks from :func:`fit_batch`.

    All B tasks are processed in ONE jitted+vmapped call: exact dense
    posterior mean over each task's grid plus the exact final-progression
    mean/variance (no Matheron MC — the per-task problems this path targets
    are small, so the dense O(N^3) route is both exact and fast). The
    Gram construction matches :func:`joint_grams` (jitter on K2 only), so
    per-task results agree with :class:`Posterior` on the same state slice.

    Conforms to :class:`PosteriorLike`: ``variance`` is the exact per-cell
    predictive variance (B, n, m), ``samples(key, n_samples)`` draws exact
    joint posterior functions (s, B, n, m) from the dense grid covariance,
    and ``final(key, n_samples)`` accepts the same signature as
    :meth:`Posterior.final` — with a key it estimates the final variance
    from samples (behavioural parity with the Matheron protocol), without
    one it returns the exact variance. ``solve_info`` is None: the exact
    vmapped Cholesky path has no iterative diagnostics to report.
    """

    def __init__(self, state: LKGPState):
        if state.X.ndim != 3:
            raise ValueError("BatchedPosterior expects a batched state from "
                             f"fit_batch; got X of shape {state.X.shape}")
        self._state = state

    @property
    def solve_info(self):
        """None — the exact dense path reports no iterative diagnostics."""
        return None

    @cached_property
    def _products(self):
        st = self._state
        fn = _batched_products_fn(st.config.t_kernel, st.config.jitter)
        return fn(st.params, st.X, st.t, st.Y, st.mask,
                  st.x_tf, st.t_tf, st.y_tf)

    @cached_property
    def _cov_products(self):
        st = self._state
        fn = _batched_cov_fn(st.config.t_kernel, st.config.jitter)
        return fn(st.params, st.X, st.t, st.Y, st.mask,
                  st.x_tf, st.t_tf, st.y_tf)

    @cached_property
    def _final_exact(self):
        # Resident default-final: the slice is dispatched once, so a warm
        # serving request re-reads arrays instead of re-running eager ops.
        mean, var = self._products
        return mean[:, :, -1], var

    @property
    def mean(self) -> jnp.ndarray:
        """Exact posterior means, (B, n, m), y units."""
        return self._products[0]

    @property
    def variance(self) -> jnp.ndarray:
        """Exact per-cell predictive variance (+ noise), (B, n, m), y units."""
        return self._cov_products[1]

    def samples(self, key, n_samples: int | None = None) -> jnp.ndarray:
        """Exact joint posterior samples, (s, B, n, m), y units.

        Drawn from the dense latent grid covariance per task (no
        observation noise — same convention as :meth:`Posterior.samples`).
        """
        st = self._state
        n_samples = n_samples or st.config.posterior_samples
        mean_t, _, Lc, shift, scale = self._cov_products
        B, n, m = st.Y.shape
        z = jax.random.normal(key, (B, n_samples, n * m), mean_t.dtype)
        draws = mean_t.reshape(B, 1, n * m) + jnp.einsum(
            "bij,bsj->bsi", Lc, z)
        raw = draws.reshape(B, n_samples, n, m).transpose(1, 0, 2, 3)
        return raw * scale[None, :, None, None] \
            + shift[None, :, None, None]

    def final(self, key=None, n_samples: int | None = None):
        """(mean, var) of the final-progression value, each (B, n).

        Signature-compatible with :meth:`Posterior.final`. The default
        (no key) returns the exact final variance; with an explicit key the
        variance is estimated from ``n_samples`` joint samples plus noise,
        mirroring the Matheron MC protocol of the lazy posterior.
        """
        if key is None and n_samples is None:
            return self._final_exact
        mean, _ = self._products
        if key is None:
            key = jax.random.fold_in(
                jax.random.PRNGKey(self._state.config.seed), 2)
        s = self.samples(key, n_samples)[:, :, :, -1]        # (s, B, n)
        noise = jnp.exp(self._state.params.raw_noise)        # (B,)
        scale = jnp.asarray(self._state.y_tf.scale)          # (B,)
        var_mc = jnp.var(s, axis=0) + (noise * scale**2)[:, None]
        return mean[:, :, -1], var_mc


def posterior_batch(state: LKGPState,
                    cache: bool | None = None) -> BatchedPosterior:
    """Batched exact posterior for a :func:`fit_batch` state.

    Same state-keyed cache semantics as :func:`posterior`: by default the
    batched posterior (and its resident vmapped solve products) is shared
    across calls on the same state object.
    """
    if cache is None:
        cache = state.config.posterior_cache
    if not cache:
        return BatchedPosterior(state)
    return _state_cached(state, _BATCH_CACHE_ATTR,
                         lambda: BatchedPosterior(state))
