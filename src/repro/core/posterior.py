"""Lazy posterior over the latent grid.

A :class:`Posterior` is cheap to construct: nothing is computed until a
property is read. The expensive CG solve of ``alpha = K^{-1} (Y * mask)``
is computed once and cached, then shared between

* the exact posterior mean  ``K1[:, :n] @ alpha @ K2``  and
* Matheron-rule samples: by linearity,
  ``K^{-1}(Y - F - eps) = alpha - K^{-1}(F + eps)``, so each sampling call
  only solves for the (F + eps) part and reuses the cached ``alpha`` — the
  sample mean is exactly consistent with the exact mean.

Solves are consolidated: if samples are requested before ``alpha`` exists,
the posterior stacks ``[Y * mask | Matheron residuals]`` into ONE multi-RHS
block solve, so a full posterior evaluation (``final()``: exact mean +
Matheron variance) costs a single batched operator sweep instead of two.
The block solver's per-column diagnostics (iterations, true residuals,
breakdown flags) from the most recent solve are exposed as
:attr:`Posterior.solve_info`.

All solves go through the inference engine resolved from the state's
config (or an explicitly provided engine), so the posterior path uses the
same backend — dense, iterative, pallas, or distributed — as fitting.
"""
from __future__ import annotations

from functools import cached_property
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import gp_kernels as gk
from .engines import get_engine
from .matheron import kronecker_correction, prior_residual_draws
from .mvm import kron_dense
from .state import LKGPState, resolve_backend

__all__ = ["Posterior", "posterior", "joint_grams", "BatchedPosterior",
           "posterior_batch"]


def joint_grams(state: LKGPState, Xs=None):
    """K1 over [X_train; X_test] (transformed) and K2 over t (jittered).

    Matches the training-time Gram construction: K2 carries the jitter, the
    joint K1 does not (its train block is only used inside the noisy
    operator; Cholesky call sites add jitter themselves).
    """
    cfg = state.config
    p = state.params
    Xn = state.x_tf(state.X)
    tn = state.t_tf(state.t)
    K2 = gk.KERNELS_1D[cfg.t_kernel](
        tn, tn, jnp.exp(p.raw_t_lengthscale), jnp.exp(p.raw_outputscale))
    K2 = K2 + cfg.jitter * jnp.eye(tn.shape[0], dtype=K2.dtype)
    if Xs is None:
        Xa = Xn
    else:
        Xa = jnp.concatenate([Xn, state.x_tf(jnp.asarray(Xs, Xn.dtype))], 0)
    K1a = gk.rbf_ard(Xa, Xa, jnp.exp(p.raw_x_lengthscale))
    return K1a, K2


class Posterior:
    """Lazy LKGP posterior over the full (train [+ test]) x t grid.

    Rows ``[:n]`` of every product are curve continuations for the training
    configs; if ``Xs`` was given, rows ``[n:]`` are predictions for the new
    configs. All outputs are in original y units.
    """

    def __init__(self, state: LKGPState, Xs=None, engine=None):
        self._state = state
        self._Xs = Xs
        if engine is None:
            # An engine explicitly injected at fit() time (e.g. bound to a
            # specific mesh) is pinned on the state; otherwise resolve from
            # config and observation count.
            engine = getattr(state, "engine", None)
        if engine is None:
            n_obs = int(np.sum(np.asarray(state.mask)))
            engine = get_engine(resolve_backend(state.config, n_obs))
        self._engine = engine
        self._alpha: jnp.ndarray | None = None   # cached K^{-1}(Y*mask)
        self._solve_info: Any = None  # CGResult of most recent engine solve

    # -- cached pieces -----------------------------------------------------
    @cached_property
    def _grams(self):
        return joint_grams(self._state, self._Xs)

    @cached_property
    def _operator(self):
        """A = P (K1 (x) K2) P^T + sigma^2 I over the training block."""
        K1a, K2 = self._grams
        n = self._state.n
        noise = jnp.exp(self._state.params.raw_noise)
        return self._engine.operator_from_grams(
            K1a[:n, :n], K2, self._state.mask, noise)

    def _solve(self, rhs):
        """Engine solve capturing the block solver's diagnostics."""
        x = self._engine.solve(self._operator, rhs, self._state.config)
        self._solve_info = getattr(self._operator, "last_result", None)
        return x

    @property
    def alpha(self):
        """Cached K^{-1} (Y * mask) in transformed space (grid form)."""
        if self._alpha is None:
            st = self._state
            Ym = st.y_tf(st.Y) * st.mask
            self._alpha = self._solve(Ym)
        return self._alpha

    @property
    def solve_info(self):
        """Diagnostics (:class:`repro.core.cg.CGResult`) of the most recent
        solve through this posterior — per-column iterations, true
        residuals, and breakdown flags — or None before any solve (or for
        engines that do not report them, e.g. the exact dense solve)."""
        return self._solve_info

    # -- products ----------------------------------------------------------
    @property
    def mean(self) -> jnp.ndarray:
        """Exact posterior mean over the grid: (n(+n*), m), y units."""
        K1a, K2 = self._grams
        n = self._state.n
        mean_t = jnp.einsum("aj,jm,mk->ak", K1a[:, :n], self.alpha, K2)
        return self._state.y_tf.inverse(mean_t)

    def samples(self, key, n_samples: int | None = None) -> jnp.ndarray:
        """Matheron-rule posterior samples: (s, n(+n*), m), y units.

        If ``alpha`` is not cached yet, ``[Y * mask | residuals]`` are
        stacked into ONE multi-RHS block solve (a single batched operator
        sweep yields the exact mean's alpha AND every sample); afterwards
        samples reuse the cached alpha and only solve the residual part.
        """
        st = self._state
        cfg = st.config
        n_samples = n_samples or cfg.posterior_samples
        K1a, K2 = self._grams
        n = st.n
        noise = jnp.exp(st.params.raw_noise)
        F, eps = prior_residual_draws(key, K1a, K2, n, noise, n_samples,
                                      jitter=cfg.jitter)
        resid = st.mask * (F[:, :n, :] + eps)
        if self._alpha is None:
            Ym = st.y_tf(st.Y) * st.mask
            sol = self._solve(jnp.concatenate([Ym[None], resid], axis=0))
            self._alpha = sol[0]
            u = sol[0][None] - sol[1:]
        else:
            # Linearity: K^{-1}(Y - F - eps) = alpha - K^{-1}(F + eps).
            u = self._alpha[None] - self._solve(resid)
        raw = F + kronecker_correction(K1a, u, K2, n)
        return st.y_tf.inverse(raw)

    @cached_property
    def _default_samples(self):
        cfg = self._state.config
        # fold_in tag 1: the cached default-sample stream. final()'s
        # explicit-key fallback uses tag 2 so the two paths never share
        # randomness (they used to both build PRNGKey(seed + 1)).
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), 1)
        return self.samples(key)

    @property
    def variance(self) -> jnp.ndarray:
        """Predictive variance (Matheron MC estimate + observation noise)."""
        st = self._state
        var_f = jnp.var(self._default_samples, axis=0)
        return var_f + st.y_tf.inverse_var(jnp.exp(st.params.raw_noise))

    def final(self, key=None, n_samples: int | None = None):
        """(mean, var) of the final-progression value per config.

        Mean is exact (cached CG solve); variance is estimated from Matheron
        samples plus observation noise — the Fig. 4 protocol.
        """
        st = self._state
        # Samples first: on a fresh posterior this folds the alpha solve and
        # the Matheron residual solves into ONE stacked operator sweep; the
        # mean below then reads the alpha cached by that same solve.
        if key is None and n_samples is None:
            s = self._default_samples[:, :, -1]   # cached; same default key
        else:
            if key is None:
                # tag 2: distinct from the _default_samples stream (tag 1).
                key = jax.random.fold_in(
                    jax.random.PRNGKey(st.config.seed), 2)
            s = self.samples(key, n_samples)[:, :, -1]
        mean = self.mean[:, -1]
        var_f = jnp.var(s, axis=0)
        var_y = var_f + st.y_tf.inverse_var(jnp.exp(st.params.raw_noise))
        return mean, var_y


def posterior(state: LKGPState, Xs=None, engine=None) -> Posterior:
    """Lazy posterior for a fitted state (optionally at new configs Xs)."""
    return Posterior(state, Xs=Xs, engine=engine)


class BatchedPosterior:
    """Vmapped exact posterior over a batch of tasks from :func:`fit_batch`.

    All B tasks are processed in ONE jitted+vmapped call: exact dense
    posterior mean over each task's grid plus the exact final-progression
    mean/variance (no Matheron MC — the per-task problems this path targets
    are small, so the dense O(N^3) route is both exact and fast). The
    Gram construction matches :func:`joint_grams` (jitter on K2 only), so
    per-task results agree with :class:`Posterior` on the same state slice.
    """

    def __init__(self, state: LKGPState):
        if state.X.ndim != 3:
            raise ValueError("BatchedPosterior expects a batched state from "
                             f"fit_batch; got X of shape {state.X.shape}")
        self._state = state

    @cached_property
    def _products(self):
        cfg = self._state.config
        k2fn = gk.KERNELS_1D[cfg.t_kernel]

        def one(params, X, t, Y, mask, x_tf, t_tf, y_tf):
            Xn, tn, Yn = x_tf(X), t_tf(t), y_tf(Y)
            n, m = mask.shape
            K2 = k2fn(tn, tn, jnp.exp(params.raw_t_lengthscale),
                      jnp.exp(params.raw_outputscale))
            K2 = K2 + cfg.jitter * jnp.eye(m, dtype=K2.dtype)
            K1 = gk.rbf_ard(Xn, Xn, jnp.exp(params.raw_x_lengthscale))
            noise = jnp.exp(params.raw_noise)

            mv = mask.reshape(-1)
            Kd = kron_dense(K1, K2) * (mv[:, None] * mv[None, :])
            Kd = Kd + jnp.diag(noise * mv + (1.0 - mv))
            L = jnp.linalg.cholesky(Kd)
            ym = (Yn * mask).reshape(-1)
            alpha = jax.scipy.linalg.cho_solve((L, True), ym) * mv
            mean_t = jnp.einsum("ij,jm,mk->ik", K1, alpha.reshape(n, m), K2)

            # Exact latent variance of each config's final-epoch value:
            # var_i = K1[ii] K2[mm] - k_i^T A^{-1} k_i with k_i the masked
            # joint-covariance row at cell (i, m-1).
            Krhs = (K1[:, :, None] * K2[:, -1][None, None, :]) * mask[None]
            Krhs = Krhs.reshape(n, n * m)
            S = jax.scipy.linalg.cho_solve((L, True), Krhs.T)   # (N, n)
            quad = jnp.sum(Krhs.T * S, axis=0)
            var_f = jnp.diag(K1) * K2[-1, -1] - quad
            var_f = jnp.maximum(var_f, 0.0)
            return (y_tf.inverse(mean_t),
                    y_tf.inverse_var(var_f + noise))

        st = self._state
        fn = jax.jit(jax.vmap(one))
        return fn(st.params, st.X, st.t, st.Y, st.mask,
                  st.x_tf, st.t_tf, st.y_tf)

    @property
    def mean(self) -> jnp.ndarray:
        """Exact posterior means, (B, n, m), y units."""
        return self._products[0]

    def final(self):
        """(mean, var) of the final-progression value, each (B, n)."""
        mean, var = self._products
        return mean[:, :, -1], var


def posterior_batch(state: LKGPState) -> BatchedPosterior:
    """Batched exact posterior for a :func:`fit_batch` state."""
    return BatchedPosterior(state)
