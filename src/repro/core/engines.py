"""Pluggable inference engines behind one front door.

An :class:`InferenceEngine` realises the projected latent Kronecker operator

    A(u) = mask * (K1 @ (mask * u) @ K2) + sigma^2 * (mask * u)

and the three linear-algebra primitives the model needs: the operator
itself, solves against it, and its (observed-subspace) log-determinant.
Four implementations are registered:

* ``dense``       — exact Cholesky of the masked joint matrix, O(N^3);
                    the paper's naive baseline and the small-N fast path.
* ``iterative``   — batched CG + stochastic Lanczos quadrature (the paper's
                    method), O(n^2 m + n m^2) per MVM.
* ``pallas``      — the iterative engine with every MVM routed through the
                    Pallas TPU kernel (:mod:`repro.kernels.ops`); runs in
                    interpret mode off-TPU so it is testable on CPU.
* ``distributed`` — the iterative engine over the shard_map row-sharded
                    operator (:mod:`repro.distributed.lkgp_dist`), reachable
                    from the top-level API via ``LKGPConfig(backend=...)``.

``make_mll(config, engine)`` assembles the marginal likelihood for any
engine: exact engines differentiate through the Cholesky; iterative-family
engines use the custom-VJP quadratic-form gradient trick (Gardner et al.,
2018) with fixed Rademacher probes.
"""
from __future__ import annotations

import math
import threading
from typing import Callable, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from .caching import LRUCache
from .mvm import kron_dense, lk_mvm
from .precond import pivoted_cholesky_grid, woodbury_preconditioner
from .slq import slq_logdet
from .solvers import (CGResult, StackedSolveResult, escalation_tally,
                      guarded_solve, guarded_solve_stacked)
from .state import GPData, LKGPConfig, LKGPParams, gram_matrices

__all__ = [
    "InferenceEngine", "ENGINES", "register_engine", "get_engine",
    "engine_cache_stats", "list_backends", "DenseEngine", "IterativeEngine", "PallasEngine",
    "DistributedEngine", "CustomMVMEngine", "LatentKroneckerOperator",
    "StackedSolveResult", "make_mll", "mll_cholesky", "make_mll_iterative",
    "solve_tally", "escalation_tally",
]

_LOG_2PI = math.log(2.0 * math.pi)

# Process-wide count of engine solve entries. Eager solves (the posterior
# hot path) bump it once per call; solves inside a jitted objective bump it
# once per TRACE, not per execution — so this is a cache-verification aid
# ("did that posterior() call re-solve?"), not a performance counter. The
# serving benchmark asserts it stays flat across a warm posterior() re-read.
# Engines are shared singletons and PredictionService solves from multiple
# tenant threads, so the read-modify-write must be lock-guarded — an
# unguarded `+= 1` can drop counts across an interpreter switch.
_solve_tally = 0
_TALLY_LOCK = threading.Lock()


def solve_tally() -> int:
    """Monotonic count of engine solve entries in this process."""
    return _solve_tally


def _bump_tally(n: int = 1) -> None:
    global _solve_tally
    with _TALLY_LOCK:
        _solve_tally += n


def _bump_escalations(res) -> None:
    """Count extra escalation-ladder attempts as solve entries.

    Guarded eager solves attach their escalation trace (one entry per
    attempt, including the base one); each attempt beyond the first was a
    full extra solve against the operator, so the tally reflects it.
    """
    trace = getattr(res, "trace", None)
    if trace and len(trace) > 1:
        _bump_tally(len(trace) - 1)


@runtime_checkable
class InferenceEngine(Protocol):
    """Linear-algebra backend: operator construction, solves, log-dets."""

    name: str
    exact: bool   # True -> logdet/solve are exact, probes unused

    def operator(self, params: LKGPParams, data: GPData,
                 config: LKGPConfig) -> Callable[[jnp.ndarray], jnp.ndarray]:
        """Build A(u) on grid-form vectors from raw parameters."""
        ...

    def operator_from_grams(self, K1, K2, mask, noise):
        """Build A(u) from precomputed Gram matrices (posterior hot path)."""
        ...

    def solve(self, A, b, config: LKGPConfig, x0=None) -> jnp.ndarray:
        """Solve A x = b; b may carry leading batch dimensions.

        ``x0`` optionally warm-starts iterative solves (scheduler refits).
        """
        ...

    def logdet(self, A, data: GPData, config: LKGPConfig,
               probes: jnp.ndarray | None) -> jnp.ndarray:
        """log det of A restricted to the observed subspace."""
        ...


ENGINES: dict[str, type] = {}


def register_engine(name: str):
    def deco(cls):
        cls.name = name
        ENGINES[name] = cls
        return cls
    return deco


# Bounded + instrumented like the compiled-objective caches it keys (see
# core.state): the cap is far above the four registered engines, so in
# practice nothing is ever evicted — an eviction here would mint a new
# engine identity and silently retrace every cached objective keyed on the
# old one, which is exactly the pathology the hit/miss counters make
# visible.
_ENGINE_SINGLETONS: LRUCache = LRUCache(16)


def engine_cache_stats() -> dict:
    """Hit/miss/eviction counters of the engine singleton map."""
    return _ENGINE_SINGLETONS.stats()


def get_engine(name: str, **kwargs) -> "InferenceEngine":
    """Engine by backend name; kwargs-free lookups return a singleton.

    The singleton matters beyond saving an allocation: the jitted fit
    objective is cached keyed on engine *identity* (see
    ``core.state._cached_fit_vg``), so config-resolved engines must be
    the same object across ``fit``/``refit`` rounds or every refit would
    retrace and recompile. Engines are stateless, so sharing is safe.
    Custom-configured engines (``kwargs`` given) are built fresh.
    """
    try:
        cls = ENGINES[name]
    except KeyError:
        raise ValueError(f"unknown backend {name!r}; "
                         f"available: {sorted(ENGINES)}") from None
    if kwargs:
        return cls(**kwargs)
    engine = _ENGINE_SINGLETONS.get(name)
    if engine is None:
        engine = _ENGINE_SINGLETONS[name] = cls()
    return engine


def list_backends() -> list[str]:
    return sorted(ENGINES)


# --------------------------------------------------------------------------
# dense (exact Cholesky)
# --------------------------------------------------------------------------
class _DenseOperator:
    """Callable A(u) that can also materialise / factorise the dense matrix.

    The dynamic-mask construction zeroes unobserved rows/cols and puts a
    unit diagonal on unobserved cells, so the full-grid Cholesky reproduces
    the observed-block solve and log-det exactly while staying jittable.
    The factorisation is cached per instance (one trace/evaluation).
    """

    def __init__(self, K1, K2, mask, noise):
        self.K1, self.K2, self.mask, self.noise = K1, K2, mask, noise
        self._chol: jnp.ndarray | None = None

    def __call__(self, u):
        return lk_mvm(self.K1, self.K2, self.mask, u, self.noise)

    def chol(self):
        if self._chol is None:
            mv = self.mask.reshape(-1)
            K = kron_dense(self.K1, self.K2) * (mv[:, None] * mv[None, :])
            K = K + jnp.diag(self.noise * mv + (1.0 - mv))
            self._chol = jnp.linalg.cholesky(K)
        return self._chol


@register_engine("dense")
class DenseEngine:
    exact = True

    def operator(self, params, data, config):
        K1, K2 = gram_matrices(params, data.X, data.t, config.t_kernel,
                               config.jitter)
        return self.operator_from_grams(K1, K2, data.mask,
                                        jnp.exp(params.raw_noise))

    def operator_from_grams(self, K1, K2, mask, noise):
        return _DenseOperator(K1, K2, mask, noise)

    def solve(self, A, b, config, x0=None):
        # x0 is accepted for interface uniformity; the exact solve ignores it.
        _bump_tally()
        if not isinstance(A, _DenseOperator):
            # Non-dense operator handed to the dense engine: route through
            # the guarded iterative solve and keep the diagnostics (this
            # path used to drop them entirely).
            res = guarded_solve(A, b, config, x0=x0)
            _bump_escalations(res)
            _stash_diagnostics(A, res)
            return res.x
        L = A.chol()
        N = A.mask.size
        bb = (b * A.mask).reshape(-1, N)          # (batch, N)
        x = jax.scipy.linalg.cho_solve((L, True), bb.T).T
        return (x * A.mask.reshape(-1)).reshape(b.shape)

    def logdet(self, A, data, config, probes=None):
        L = A.chol()
        return 2.0 * jnp.sum(jnp.log(jnp.diag(L)))  # unobserved diag = 1 -> log 0


# --------------------------------------------------------------------------
# iterative (CG + SLQ)
# --------------------------------------------------------------------------
class LatentKroneckerOperator:
    """Callable A(u) that remembers its Kronecker factors.

    The iterative-family engines return this instead of a bare closure so
    that ``solve`` can build the pivoted-Cholesky preconditioner from the
    factors when ``LKGPConfig.precond_rank > 0`` — the factorisation only
    needs K1 / K2 / mask, never the assembled operator.
    """

    def __init__(self, K1, K2, mask, noise, mvm=lk_mvm):
        self.K1, self.K2, self.mask, self.noise = K1, K2, mask, noise
        self._mvm = mvm
        self._precond = None    # (rank, M_inv) cache

    def __call__(self, u):
        return self._mvm(self.K1, self.K2, self.mask, u, noise=self.noise)

    def preconditioner(self, rank: int):
        """Woodbury M^{-1} from the rank-``rank`` pivoted Cholesky, cached.

        The factorisation only depends on (K1, K2, mask, noise), all fixed
        for this operator, so repeated solves (posterior alpha + Matheron
        samples, CG inside one MLL evaluation) share one factor.
        """
        if self._precond is None or self._precond[0] != rank:
            L = pivoted_cholesky_grid(self.K1, self.K2, self.mask, rank)
            self._precond = (rank, woodbury_preconditioner(L, self.noise))
        return self._precond[1]


def _stash_diagnostics(A, res: CGResult) -> None:
    """Best-effort: hang the solve diagnostics on the operator object.

    Operators are created per evaluation (and per trace), so the attribute
    has the same lifetime as the solve it describes; eager callers
    (:class:`repro.core.posterior.Posterior`) read it back as
    ``A.last_result``. Plain-callable operators that reject attributes are
    skipped silently.
    """
    try:
        A.last_result = res
    except AttributeError:
        pass


@register_engine("iterative")
class IterativeEngine:
    exact = False

    def operator(self, params, data, config):
        K1, K2 = gram_matrices(params, data.X, data.t, config.t_kernel,
                               config.jitter)
        return self.operator_from_grams(K1, K2, data.mask,
                                        jnp.exp(params.raw_noise))

    def operator_from_grams(self, K1, K2, mask, noise):
        return LatentKroneckerOperator(K1, K2, mask, noise)

    def solve(self, A, b, config, x0=None):
        return self.solve_result(A, b, config, x0=x0).x

    def solve_result(self, A, b, config, x0=None) -> CGResult:
        """Like :meth:`solve` but returning the full per-column diagnostics
        (iterations, true residuals, breakdown flags, MVM counts).

        The solve strategy comes from the registry (``config.solver``:
        cg / pcg / sgd; "auto" keeps the historic PCG-iff-precond_rank
        routing) — see :mod:`repro.core.solvers`. Eager solves run under
        the ``config.solve_policy`` escalation guard
        (:mod:`repro.core.solvers.guarded`); traced solves pass through
        unguarded.
        """
        _bump_tally()
        res = guarded_solve(A, b, config, x0=x0)
        _bump_escalations(res)
        _stash_diagnostics(A, res)
        return res

    def solve_stacked(self, A, rhs, config, *, probe_cols: int = 0,
                      subspace_dim=None, x0=None) -> StackedSolveResult:
        """ONE batched operator sweep for a whole stack of right-hand sides.

        ``rhs``: (s, n, m) stack (e.g. ``[y | probes | Matheron
        residuals]``); every solver iteration applies the operator to the
        full stack at once, converged columns freeze. When the trailing
        ``probe_cols`` rows are SLQ probes and the CG solver runs, their
        CG-Lanczos tridiagonals are recorded during the SAME solve and
        turned into the log-determinant estimate — no separate Lanczos
        sweep. PCG/SGD solves report ``logdet=None`` and the caller falls
        back to the separate SLQ pass. Eager solves run under the
        ``config.solve_policy`` escalation guard; traced solves pass
        through unguarded.
        """
        _bump_tally()
        st = guarded_solve_stacked(A, rhs, config, probe_cols=probe_cols,
                                   subspace_dim=subspace_dim, x0=x0)
        _bump_escalations(st.result)
        _stash_diagnostics(A, st.result)
        return st

    def logdet(self, A, data, config, probes):
        return slq_logdet(A, probes, config.slq_iters, jnp.sum(data.mask))


class CustomMVMEngine(IterativeEngine):
    """Iterative engine over a user-supplied ``mvm(K1, K2, mask, u, noise=...)``."""

    name = "custom"

    def __init__(self, mvm: Callable):
        self._mvm = mvm

    def operator_from_grams(self, K1, K2, mask, noise):
        return LatentKroneckerOperator(K1, K2, mask, noise, mvm=self._mvm)


# --------------------------------------------------------------------------
# pallas (iterative, MVMs through the TPU kernel)
# --------------------------------------------------------------------------
def _pallas_mvm_raw(K1, K2, mask, u, noise):
    # Import at call time: repro.kernels imports repro.core.gp_kernels, so a
    # module-level import here would be circular. force_pallas=True runs the
    # kernel even off-TPU (interpret mode) so the backend exercises the same
    # code path everywhere.
    from ..kernels import ops
    return ops.lk_mvm_op(K1, K2, mask, u, noise, force_pallas=True)


@jax.custom_vjp
def _pallas_mvm(K1, K2, mask, u, noise):
    """Differentiable wrapper: Pallas forward, analytic jnp cotangents.

    pallas_call has no autodiff rule, but the MVM is bilinear in (K1, K2, u),
    so the VJPs are closed-form; the ``u`` cotangent is A(g) itself (A is
    symmetric) and is routed back through the Pallas kernel.
    """
    return _pallas_mvm_raw(K1, K2, mask, u, noise)


def _pallas_mvm_fwd(K1, K2, mask, u, noise):
    return _pallas_mvm_raw(K1, K2, mask, u, noise), (K1, K2, mask, u, noise)


def _pallas_mvm_bwd(res, g):
    K1, K2, mask, u, noise = res
    n, m = mask.shape
    gm = (g * mask).reshape(-1, n, m)   # flatten leading batch dims
    um = (u * mask).reshape(-1, n, m)
    umK2 = jnp.einsum("bnm,mk->bnk", um, K2)
    dK1 = jnp.einsum("bik,bjk->ij", gm, umK2)
    K1um = jnp.einsum("ij,bjm->bim", K1, um)
    dK2 = jnp.einsum("bij,bik->jk", K1um, gm)
    du = _pallas_mvm_raw(K1, K2, mask, g, noise)          # A(g), A symmetric
    dnoise = jnp.sum(gm * um).astype(jnp.asarray(noise).dtype)
    return dK1, dK2, jnp.zeros_like(mask), du, dnoise


_pallas_mvm.defvjp(_pallas_mvm_fwd, _pallas_mvm_bwd)


def _pallas_mvm_kw(K1, K2, mask, u, noise=0.0):
    # custom_vjp functions only take positional args; adapt to the
    # ``mvm(K1, K2, mask, u, noise=...)`` calling convention.
    return _pallas_mvm(K1, K2, mask, u, noise)


@register_engine("pallas")
class PallasEngine(IterativeEngine):
    def operator_from_grams(self, K1, K2, mask, noise):
        return LatentKroneckerOperator(K1, K2, mask, noise, mvm=_pallas_mvm_kw)


# --------------------------------------------------------------------------
# distributed (shard_map row sharding)
# --------------------------------------------------------------------------
@register_engine("distributed")
class DistributedEngine(IterativeEngine):
    """Row-shards the grid over a mesh 'data' axis (one all-gather per MVM).

    Pass a mesh for multi-device runs (n must divide the 'data' axis size);
    the default is a 1-axis mesh over all local devices. K1 is built
    replicated here; the fully row-sharded K1 build used at pod scale lives
    in :func:`repro.distributed.lkgp_dist.dist_mll_value`.

    ``fused`` routes each shard's row-block MVM through the fused Pallas
    kernel (:func:`repro.kernels.lk_mvm.lk_mvm_fused_rows`) instead of the
    two-stage einsum reference. The kernel accumulates in f32, so
    ``"auto"`` only takes it for f32 operands with a block size that passes
    the per-shard VMEM budget check; f64 operands (e.g. the x64 parity
    tests) keep the exact reference body. ``True`` forces it (raising if no
    block configuration fits VMEM), ``False`` disables it.

    Solves route through the solver registry like every iterative engine
    (``config.solver``); the global reductions CG/SGD perform are plain
    ``jnp.sum`` over the sharded rows, which XLA lowers to psums.
    """

    def __init__(self, mesh=None, fused="auto"):
        if mesh is None:
            import numpy as np
            from jax.sharding import Mesh
            mesh = Mesh(np.array(jax.devices()), ("data",))
        self.mesh = mesh
        self.fused = fused

    def _fused_blocks(self, K1, K2, mask):
        """Per-shard (block_n, block_m) for the fused kernel, or None."""
        if self.fused is False:
            return None
        K1 = jnp.asarray(K1)
        if K1.dtype != jnp.float32:
            if self.fused is True:
                raise ValueError(
                    "DistributedEngine(fused=True) needs float32 operands: "
                    f"the fused Pallas kernel accumulates in f32, got "
                    f"{K1.dtype}")
            return None
        from ..analysis.vmem import best_fitting_blocks
        n_local = max(K1.shape[0] // self.mesh.shape["data"], 1)
        m = jnp.asarray(K2).shape[0]
        blocks = best_fitting_blocks(n_local, m, precision="f32",
                                     out_itemsize=K1.dtype.itemsize)
        if blocks is None and self.fused is True:
            raise ValueError(
                "DistributedEngine(fused=True): no fused block size fits "
                f"the per-shard VMEM budget for n_local={n_local}, m={m}")
        return blocks

    def operator_from_grams(self, K1, K2, mask, noise):
        from ..distributed.lkgp_dist import dist_lk_mvm_fused, dist_lk_operator
        blocks = self._fused_blocks(K1, K2, mask)
        if blocks is not None:
            base = dist_lk_mvm_fused(self.mesh, K1, K2, mask, noise,
                                     block_n=blocks[0], block_m=blocks[1])
        else:
            base = dist_lk_operator(self.mesh, K1, K2, mask, noise)

        def A(u):
            # The shard_map body is rank-2; map leading batch dims (CG rhs
            # stacks, SLQ probes) sequentially over it.
            if u.ndim == 2:
                return base(u)
            flat = u.reshape((-1, *u.shape[-2:]))
            return jax.lax.map(base, flat).reshape(u.shape)

        # Introspection hook: tests and audits assert which body was traced.
        setattr(A, "fused", blocks is not None)
        return A


# --------------------------------------------------------------------------
# marginal likelihood
# --------------------------------------------------------------------------
def mll_cholesky(params: LKGPParams, X, t, Y, mask, t_kernel: str = "matern12",
                 jitter: float = 1e-6) -> jnp.ndarray:
    """Exact MLL of the observed block — the paper's NAIVE baseline.

    O(n^3 m^3) time / O(n^2 m^2) space, via the dynamic-mask construction
    (see :class:`_DenseOperator`). Fully differentiable through the
    Cholesky; also the objective of the ``dense`` engine.
    """
    K1, K2 = gram_matrices(params, X, t, t_kernel, jitter)
    noise = jnp.exp(params.raw_noise)
    mv = mask.reshape(-1)
    y = (Y * mask).reshape(-1)
    K = kron_dense(K1, K2) * (mv[:, None] * mv[None, :])
    K = K + jnp.diag(noise * mv + (1.0 - mv))
    L = jnp.linalg.cholesky(K)
    alpha = jax.scipy.linalg.cho_solve((L, True), y)
    N = jnp.sum(mask)
    logdet = 2.0 * jnp.sum(jnp.log(jnp.diag(L)))  # unobserved diag = 1 -> log 0
    return -0.5 * jnp.dot(y, alpha) - 0.5 * logdet - 0.5 * N * _LOG_2PI


def make_mll(config: LKGPConfig, engine: "InferenceEngine") -> Callable:
    """MLL as ``mll(params, X, t, Y, mask, probes)`` for any engine.

    Exact engines ignore ``probes`` and differentiate through the Cholesky.
    Iterative-family engines share fixed Rademacher probes between the SLQ
    log-det estimate and the stochastic trace gradients; fixing them makes
    the objective deterministic, which the L-BFGS line search requires.
    """
    if engine.exact:
        # Exact engines differentiate straight through their solve/logdet
        # (no probes, no custom VJP). For DenseEngine this is exactly
        # mll_cholesky: one cached Cholesky shared by solve and log-det.
        def mll_exact(params, X, t, Y, mask, probes=None):
            data = GPData(X, t, None, mask)
            A = engine.operator(params, data, config)
            Ym = Y * mask
            alpha = engine.solve(A, Ym, config)
            N = jnp.sum(mask)
            logdet = engine.logdet(A, data, config, probes)
            return (-0.5 * jnp.sum(Ym * alpha) - 0.5 * logdet
                    - 0.5 * N * _LOG_2PI)
        return mll_exact

    def _operator(params, X, t, mask):
        return engine.operator(params, GPData(X, t, None, mask), config)

    @jax.custom_vjp
    def mll(params, X, t, Y, mask, probes):
        value, _ = _fwd(params, X, t, Y, mask, probes)
        return value

    def _fwd(params, X, t, Y, mask, probes):
        A = _operator(params, X, t, mask)
        Ym = Y * mask
        rhs = jnp.concatenate([Ym[None], probes], axis=0)
        N = jnp.sum(mask)
        # Consolidated path: ONE stacked block solve covers the mean solve,
        # the trace-gradient probe solves, AND (via the probes' CG-Lanczos
        # tridiagonals) the SLQ log-det — no separate Lanczos sweep. The
        # fallback (slq_via_cg=False, engines without solve_stacked, or
        # preconditioned solves whose Krylov space is M^{-1}A's) runs the
        # classic stacked solve + reorthogonalised-Lanczos SLQ.
        stacked = getattr(engine, "solve_stacked", None)
        logdet = None
        if stacked is not None and getattr(config, "slq_via_cg", True):
            st = stacked(A, rhs, config, probe_cols=probes.shape[0],
                         subspace_dim=N)
            sol, logdet = st.x, st.logdet
        else:
            sol = engine.solve(A, rhs, config)
        if logdet is None:
            logdet = engine.logdet(A, GPData(X, t, None, mask), config,
                                   probes)
        alpha, W = sol[0], sol[1:]
        value = -0.5 * jnp.sum(Ym * alpha) - 0.5 * logdet - 0.5 * N * _LOG_2PI
        return value, (params, X, t, Y, mask, alpha, W, probes)

    def _bwd(res, gbar):
        params, X, t, Y, mask, alpha, W, probes = res
        p = probes.shape[0]

        def h(pp):
            A = _operator(pp, X, t, mask)
            quad_alpha = jnp.sum(alpha * A(alpha))
            quad_tr = jnp.sum(W * A(probes)) / p
            return 0.5 * quad_alpha - 0.5 * quad_tr

        gparams = jax.grad(h)(params)
        gparams = jax.tree_util.tree_map(lambda g: gbar * g, gparams)
        zeros = lambda a: jnp.zeros_like(a)
        return (gparams, zeros(X), zeros(t), zeros(Y), zeros(mask),
                zeros(probes))

    mll.defvjp(_fwd, _bwd)
    return mll


def make_mll_iterative(cfg: LKGPConfig, mvm_impl=None):
    """Iterative MLL with custom VJP (backward-compatible entry point).

    Returns ``mll(params, X, t, Y, mask, probes)``. With ``mvm_impl`` given
    (signature ``mvm(K1, K2, mask, u, noise=...)``), every MVM — CG, SLQ,
    and the quadratic-form gradients — routes through it; this is how
    ``LKGPConfig.use_pallas`` threads the Pallas kernel into the objective.
    """
    engine = IterativeEngine() if mvm_impl is None else CustomMVMEngine(mvm_impl)
    return make_mll(cfg, engine)
