"""Pluggable inference engines behind one front door.

An :class:`InferenceEngine` realises the projected latent Kronecker operator

    A(u) = mask * (K1 @ (mask * u) @ K2) + sigma^2 * (mask * u)

and the three linear-algebra primitives the model needs: the operator
itself, solves against it, and its (observed-subspace) log-determinant.
Four implementations are registered:

* ``dense``       — exact Cholesky of the masked joint matrix, O(N^3);
                    the paper's naive baseline and the small-N fast path.
* ``iterative``   — batched CG + stochastic Lanczos quadrature (the paper's
                    method), O(n^2 m + n m^2) per MVM.
* ``pallas``      — the iterative engine with every MVM routed through the
                    Pallas TPU kernel (:mod:`repro.kernels.ops`); runs in
                    interpret mode off-TPU so it is testable on CPU.
* ``distributed`` — the iterative engine over the shard_map row-sharded
                    operator (:mod:`repro.distributed.lkgp_dist`), reachable
                    from the top-level API via ``LKGPConfig(backend=...)``.

``make_mll(config, engine)`` assembles the marginal likelihood for any
engine: exact engines differentiate through the Cholesky; iterative-family
engines use the custom-VJP quadratic-form gradient trick (Gardner et al.,
2018) with fixed Rademacher probes.
"""
from __future__ import annotations

import math
from typing import Callable, NamedTuple, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from .cg import CGResult, cg_solve, cg_solve_tridiag, pcg_solve
from .mvm import kron_dense, lk_mvm
from .precond import pivoted_cholesky_grid, woodbury_preconditioner
from .slq import slq_logdet, slq_logdet_from_tridiag, tridiag_from_cg
from .state import GPData, LKGPConfig, LKGPParams, gram_matrices

__all__ = [
    "InferenceEngine", "ENGINES", "register_engine", "get_engine",
    "list_backends", "DenseEngine", "IterativeEngine", "PallasEngine",
    "DistributedEngine", "CustomMVMEngine", "LatentKroneckerOperator",
    "StackedSolveResult", "make_mll", "mll_cholesky", "make_mll_iterative",
    "solve_tally",
]

_LOG_2PI = math.log(2.0 * math.pi)

# Process-wide count of engine solve entries. Eager solves (the posterior
# hot path) bump it once per call; solves inside a jitted objective bump it
# once per TRACE, not per execution — so this is a cache-verification aid
# ("did that posterior() call re-solve?"), not a performance counter. The
# serving benchmark asserts it stays flat across a warm posterior() re-read.
_solve_tally = 0


def solve_tally() -> int:
    """Monotonic count of engine solve entries in this process."""
    return _solve_tally


def _bump_tally() -> None:
    global _solve_tally
    _solve_tally += 1


@runtime_checkable
class InferenceEngine(Protocol):
    """Linear-algebra backend: operator construction, solves, log-dets."""

    name: str
    exact: bool   # True -> logdet/solve are exact, probes unused

    def operator(self, params: LKGPParams, data: GPData,
                 config: LKGPConfig) -> Callable[[jnp.ndarray], jnp.ndarray]:
        """Build A(u) on grid-form vectors from raw parameters."""
        ...

    def operator_from_grams(self, K1, K2, mask, noise):
        """Build A(u) from precomputed Gram matrices (posterior hot path)."""
        ...

    def solve(self, A, b, config: LKGPConfig, x0=None) -> jnp.ndarray:
        """Solve A x = b; b may carry leading batch dimensions.

        ``x0`` optionally warm-starts iterative solves (scheduler refits).
        """
        ...

    def logdet(self, A, data: GPData, config: LKGPConfig,
               probes: jnp.ndarray | None) -> jnp.ndarray:
        """log det of A restricted to the observed subspace."""
        ...


ENGINES: dict[str, type] = {}


def register_engine(name: str):
    def deco(cls):
        cls.name = name
        ENGINES[name] = cls
        return cls
    return deco


_ENGINE_SINGLETONS: dict = {}


def get_engine(name: str, **kwargs) -> "InferenceEngine":
    """Engine by backend name; kwargs-free lookups return a singleton.

    The singleton matters beyond saving an allocation: the jitted fit
    objective is cached keyed on engine *identity* (see
    ``core.state._cached_fit_vg``), so config-resolved engines must be
    the same object across ``fit``/``refit`` rounds or every refit would
    retrace and recompile. Engines are stateless, so sharing is safe.
    Custom-configured engines (``kwargs`` given) are built fresh.
    """
    try:
        cls = ENGINES[name]
    except KeyError:
        raise ValueError(f"unknown backend {name!r}; "
                         f"available: {sorted(ENGINES)}") from None
    if kwargs:
        return cls(**kwargs)
    engine = _ENGINE_SINGLETONS.get(name)
    if engine is None:
        engine = _ENGINE_SINGLETONS[name] = cls()
    return engine


def list_backends() -> list[str]:
    return sorted(ENGINES)


# --------------------------------------------------------------------------
# dense (exact Cholesky)
# --------------------------------------------------------------------------
class _DenseOperator:
    """Callable A(u) that can also materialise / factorise the dense matrix.

    The dynamic-mask construction zeroes unobserved rows/cols and puts a
    unit diagonal on unobserved cells, so the full-grid Cholesky reproduces
    the observed-block solve and log-det exactly while staying jittable.
    The factorisation is cached per instance (one trace/evaluation).
    """

    def __init__(self, K1, K2, mask, noise):
        self.K1, self.K2, self.mask, self.noise = K1, K2, mask, noise
        self._chol: jnp.ndarray | None = None

    def __call__(self, u):
        return lk_mvm(self.K1, self.K2, self.mask, u, self.noise)

    def chol(self):
        if self._chol is None:
            mv = self.mask.reshape(-1)
            K = kron_dense(self.K1, self.K2) * (mv[:, None] * mv[None, :])
            K = K + jnp.diag(self.noise * mv + (1.0 - mv))
            self._chol = jnp.linalg.cholesky(K)
        return self._chol


@register_engine("dense")
class DenseEngine:
    exact = True

    def operator(self, params, data, config):
        K1, K2 = gram_matrices(params, data.X, data.t, config.t_kernel,
                               config.jitter)
        return self.operator_from_grams(K1, K2, data.mask,
                                        jnp.exp(params.raw_noise))

    def operator_from_grams(self, K1, K2, mask, noise):
        return _DenseOperator(K1, K2, mask, noise)

    def solve(self, A, b, config, x0=None):
        # x0 is accepted for interface uniformity; the exact solve ignores it.
        _bump_tally()
        if not isinstance(A, _DenseOperator):
            return cg_solve(A, b, tol=config.cg_tol,
                            max_iters=config.cg_max_iters, x0=x0).x
        L = A.chol()
        N = A.mask.size
        bb = (b * A.mask).reshape(-1, N)          # (batch, N)
        x = jax.scipy.linalg.cho_solve((L, True), bb.T).T
        return (x * A.mask.reshape(-1)).reshape(b.shape)

    def logdet(self, A, data, config, probes=None):
        L = A.chol()
        return 2.0 * jnp.sum(jnp.log(jnp.diag(L)))  # unobserved diag = 1 -> log 0


# --------------------------------------------------------------------------
# iterative (CG + SLQ)
# --------------------------------------------------------------------------
class LatentKroneckerOperator:
    """Callable A(u) that remembers its Kronecker factors.

    The iterative-family engines return this instead of a bare closure so
    that ``solve`` can build the pivoted-Cholesky preconditioner from the
    factors when ``LKGPConfig.precond_rank > 0`` — the factorisation only
    needs K1 / K2 / mask, never the assembled operator.
    """

    def __init__(self, K1, K2, mask, noise, mvm=lk_mvm):
        self.K1, self.K2, self.mask, self.noise = K1, K2, mask, noise
        self._mvm = mvm
        self._precond = None    # (rank, M_inv) cache

    def __call__(self, u):
        return self._mvm(self.K1, self.K2, self.mask, u, noise=self.noise)

    def preconditioner(self, rank: int):
        """Woodbury M^{-1} from the rank-``rank`` pivoted Cholesky, cached.

        The factorisation only depends on (K1, K2, mask, noise), all fixed
        for this operator, so repeated solves (posterior alpha + Matheron
        samples, CG inside one MLL evaluation) share one factor.
        """
        if self._precond is None or self._precond[0] != rank:
            L = pivoted_cholesky_grid(self.K1, self.K2, self.mask, rank)
            self._precond = (rank, woodbury_preconditioner(L, self.noise))
        return self._precond[1]


class StackedSolveResult(NamedTuple):
    """One consolidated multi-RHS solve: solutions + (optional) log-det.

    ``x`` are the stacked solutions; ``logdet`` is the SLQ estimate built
    from the probe columns' CG-Lanczos tridiagonals (None when it could not
    be fused, e.g. preconditioned solves — the preconditioned Krylov space
    is M^{-1}A's, not A's); ``result`` carries the block solver's
    per-column diagnostics (iterations, residuals, breakdown flags,
    active-column MVM count).
    """
    x: jnp.ndarray
    logdet: jnp.ndarray | None
    result: CGResult


def _stash_diagnostics(A, res: CGResult) -> None:
    """Best-effort: hang the solve diagnostics on the operator object.

    Operators are created per evaluation (and per trace), so the attribute
    has the same lifetime as the solve it describes; eager callers
    (:class:`repro.core.posterior.Posterior`) read it back as
    ``A.last_result``. Plain-callable operators that reject attributes are
    skipped silently.
    """
    try:
        A.last_result = res
    except AttributeError:
        pass


@register_engine("iterative")
class IterativeEngine:
    exact = False

    def operator(self, params, data, config):
        K1, K2 = gram_matrices(params, data.X, data.t, config.t_kernel,
                               config.jitter)
        return self.operator_from_grams(K1, K2, data.mask,
                                        jnp.exp(params.raw_noise))

    def operator_from_grams(self, K1, K2, mask, noise):
        return LatentKroneckerOperator(K1, K2, mask, noise)

    def solve(self, A, b, config, x0=None):
        return self.solve_result(A, b, config, x0=x0).x

    def solve_result(self, A, b, config, x0=None) -> CGResult:
        """Like :meth:`solve` but returning the full per-column diagnostics
        (iterations, true residuals, breakdown flags, MVM counts)."""
        _bump_tally()
        rank = getattr(config, "precond_rank", 0)
        if rank and isinstance(A, LatentKroneckerOperator):
            res = _precond_solve(A, b, config, rank, x0=x0)
        else:
            res = cg_solve(A, b, tol=config.cg_tol,
                           max_iters=config.cg_max_iters, x0=x0)
        _stash_diagnostics(A, res)
        return res

    def solve_stacked(self, A, rhs, config, *, probe_cols: int = 0,
                      subspace_dim=None, x0=None) -> StackedSolveResult:
        """ONE batched operator sweep for a whole stack of right-hand sides.

        ``rhs``: (s, n, m) stack (e.g. ``[y | probes | Matheron
        residuals]``); every CG iteration applies the operator to the full
        stack at once, converged columns freeze. When the trailing
        ``probe_cols`` rows are SLQ probes, their CG-Lanczos tridiagonals
        are recorded during the SAME solve and turned into the
        log-determinant estimate — no separate Lanczos sweep.
        """
        _bump_tally()
        rank = getattr(config, "precond_rank", 0)
        if rank and isinstance(A, LatentKroneckerOperator):
            res = _precond_solve(A, rhs, config, rank, x0=x0)
            _stash_diagnostics(A, res)
            return StackedSolveResult(x=res.x, logdet=None, result=res)
        if probe_cols and x0 is not None:
            # A warm start changes the Krylov starting vectors from the
            # probes to rhs - A@x0, breaking the CG-Lanczos correspondence
            # the fused log-det relies on; solve warm but report no logdet
            # (the caller falls back to the separate SLQ pass).
            probe_cols = 0
        if probe_cols:
            res, tri = cg_solve_tridiag(
                A, rhs, max_rank=config.slq_iters, tol=config.cg_tol,
                max_iters=config.cg_max_iters, x0=x0)
            diag, off = tridiag_from_cg(tri.alphas[-probe_cols:],
                                        tri.betas[-probe_cols:],
                                        tri.steps[-probe_cols:])
            logdet = slq_logdet_from_tridiag(diag, off, subspace_dim)
        else:
            res = cg_solve(A, rhs, tol=config.cg_tol,
                           max_iters=config.cg_max_iters, x0=x0)
            logdet = None
        _stash_diagnostics(A, res)
        return StackedSolveResult(x=res.x, logdet=logdet, result=res)

    def logdet(self, A, data, config, probes):
        return slq_logdet(A, probes, config.slq_iters, jnp.sum(data.mask))


def _precond_solve(A: LatentKroneckerOperator, b, config, rank: int,
                   x0=None):
    """Preconditioned CG through the operator's Kronecker factors.

    Flattens grid-form vectors (..., n, m) onto (..., n*m) packed form,
    preconditions with the Woodbury-inverted rank-``rank`` pivoted Cholesky
    of the masked latent covariance, and reshapes the solution back. The
    whole RHS stack shares one Woodbury apply per iteration. All pure jax,
    so it works under jit with a traced mask.
    """
    n, m = A.mask.shape
    M_inv = A.preconditioner(rank)

    def A_flat(u):
        return A(u.reshape(*u.shape[:-1], n, m)).reshape(u.shape)

    x0_flat = None if x0 is None else x0.reshape(*x0.shape[:-2], n * m)
    res = pcg_solve(A_flat, b.reshape(*b.shape[:-2], n * m), M_inv,
                    tol=config.cg_tol, max_iters=config.cg_max_iters,
                    x0=x0_flat)
    return res._replace(x=res.x.reshape(b.shape))


class CustomMVMEngine(IterativeEngine):
    """Iterative engine over a user-supplied ``mvm(K1, K2, mask, u, noise=...)``."""

    name = "custom"

    def __init__(self, mvm: Callable):
        self._mvm = mvm

    def operator_from_grams(self, K1, K2, mask, noise):
        return LatentKroneckerOperator(K1, K2, mask, noise, mvm=self._mvm)


# --------------------------------------------------------------------------
# pallas (iterative, MVMs through the TPU kernel)
# --------------------------------------------------------------------------
def _pallas_mvm_raw(K1, K2, mask, u, noise):
    # Import at call time: repro.kernels imports repro.core.gp_kernels, so a
    # module-level import here would be circular. force_pallas=True runs the
    # kernel even off-TPU (interpret mode) so the backend exercises the same
    # code path everywhere.
    from ..kernels import ops
    return ops.lk_mvm_op(K1, K2, mask, u, noise, force_pallas=True)


@jax.custom_vjp
def _pallas_mvm(K1, K2, mask, u, noise):
    """Differentiable wrapper: Pallas forward, analytic jnp cotangents.

    pallas_call has no autodiff rule, but the MVM is bilinear in (K1, K2, u),
    so the VJPs are closed-form; the ``u`` cotangent is A(g) itself (A is
    symmetric) and is routed back through the Pallas kernel.
    """
    return _pallas_mvm_raw(K1, K2, mask, u, noise)


def _pallas_mvm_fwd(K1, K2, mask, u, noise):
    return _pallas_mvm_raw(K1, K2, mask, u, noise), (K1, K2, mask, u, noise)


def _pallas_mvm_bwd(res, g):
    K1, K2, mask, u, noise = res
    n, m = mask.shape
    gm = (g * mask).reshape(-1, n, m)   # flatten leading batch dims
    um = (u * mask).reshape(-1, n, m)
    umK2 = jnp.einsum("bnm,mk->bnk", um, K2)
    dK1 = jnp.einsum("bik,bjk->ij", gm, umK2)
    K1um = jnp.einsum("ij,bjm->bim", K1, um)
    dK2 = jnp.einsum("bij,bik->jk", K1um, gm)
    du = _pallas_mvm_raw(K1, K2, mask, g, noise)          # A(g), A symmetric
    dnoise = jnp.sum(gm * um).astype(jnp.asarray(noise).dtype)
    return dK1, dK2, jnp.zeros_like(mask), du, dnoise


_pallas_mvm.defvjp(_pallas_mvm_fwd, _pallas_mvm_bwd)


def _pallas_mvm_kw(K1, K2, mask, u, noise=0.0):
    # custom_vjp functions only take positional args; adapt to the
    # ``mvm(K1, K2, mask, u, noise=...)`` calling convention.
    return _pallas_mvm(K1, K2, mask, u, noise)


@register_engine("pallas")
class PallasEngine(IterativeEngine):
    def operator_from_grams(self, K1, K2, mask, noise):
        return LatentKroneckerOperator(K1, K2, mask, noise, mvm=_pallas_mvm_kw)


# --------------------------------------------------------------------------
# distributed (shard_map row sharding)
# --------------------------------------------------------------------------
@register_engine("distributed")
class DistributedEngine(IterativeEngine):
    """Row-shards the grid over a mesh 'data' axis (one all-gather per MVM).

    Pass a mesh for multi-device runs (n must divide the 'data' axis size);
    the default is a 1-axis mesh over all local devices. K1 is built
    replicated here; the fully row-sharded K1 build used at pod scale lives
    in :func:`repro.distributed.lkgp_dist.dist_mll_value`.
    """

    def __init__(self, mesh=None):
        if mesh is None:
            import numpy as np
            from jax.sharding import Mesh
            mesh = Mesh(np.array(jax.devices()), ("data",))
        self.mesh = mesh

    def operator_from_grams(self, K1, K2, mask, noise):
        from ..distributed.lkgp_dist import dist_lk_operator
        base = dist_lk_operator(self.mesh, K1, K2, mask, noise)

        def A(u):
            # The shard_map body is rank-2; map leading batch dims (CG rhs
            # stacks, SLQ probes) sequentially over it.
            if u.ndim == 2:
                return base(u)
            flat = u.reshape((-1, *u.shape[-2:]))
            return jax.lax.map(base, flat).reshape(u.shape)

        return A

    def solve(self, A, b, config, x0=None):
        _bump_tally()
        from ..distributed.lkgp_dist import dist_cg_solve

        def one(bb, x0b=None):
            x, _, _ = dist_cg_solve(A, bb, tol=config.cg_tol,
                                    max_iters=config.cg_max_iters, x0=x0b)
            return x

        if b.ndim == 2:
            return one(b, x0)
        # Per-system solves keep CG trip counts independent across the batch.
        flat = b.reshape((-1, *b.shape[-2:]))
        if x0 is None:
            return jax.lax.map(one, flat).reshape(b.shape)
        x0f = jnp.broadcast_to(x0, b.shape).reshape(flat.shape)
        return jax.lax.map(lambda args: one(*args), (flat, x0f)).reshape(b.shape)


# --------------------------------------------------------------------------
# marginal likelihood
# --------------------------------------------------------------------------
def mll_cholesky(params: LKGPParams, X, t, Y, mask, t_kernel: str = "matern12",
                 jitter: float = 1e-6) -> jnp.ndarray:
    """Exact MLL of the observed block — the paper's NAIVE baseline.

    O(n^3 m^3) time / O(n^2 m^2) space, via the dynamic-mask construction
    (see :class:`_DenseOperator`). Fully differentiable through the
    Cholesky; also the objective of the ``dense`` engine.
    """
    K1, K2 = gram_matrices(params, X, t, t_kernel, jitter)
    noise = jnp.exp(params.raw_noise)
    mv = mask.reshape(-1)
    y = (Y * mask).reshape(-1)
    K = kron_dense(K1, K2) * (mv[:, None] * mv[None, :])
    K = K + jnp.diag(noise * mv + (1.0 - mv))
    L = jnp.linalg.cholesky(K)
    alpha = jax.scipy.linalg.cho_solve((L, True), y)
    N = jnp.sum(mask)
    logdet = 2.0 * jnp.sum(jnp.log(jnp.diag(L)))  # unobserved diag = 1 -> log 0
    return -0.5 * jnp.dot(y, alpha) - 0.5 * logdet - 0.5 * N * _LOG_2PI


def make_mll(config: LKGPConfig, engine: "InferenceEngine") -> Callable:
    """MLL as ``mll(params, X, t, Y, mask, probes)`` for any engine.

    Exact engines ignore ``probes`` and differentiate through the Cholesky.
    Iterative-family engines share fixed Rademacher probes between the SLQ
    log-det estimate and the stochastic trace gradients; fixing them makes
    the objective deterministic, which the L-BFGS line search requires.
    """
    if engine.exact:
        # Exact engines differentiate straight through their solve/logdet
        # (no probes, no custom VJP). For DenseEngine this is exactly
        # mll_cholesky: one cached Cholesky shared by solve and log-det.
        def mll_exact(params, X, t, Y, mask, probes=None):
            data = GPData(X, t, None, mask)
            A = engine.operator(params, data, config)
            Ym = Y * mask
            alpha = engine.solve(A, Ym, config)
            N = jnp.sum(mask)
            logdet = engine.logdet(A, data, config, probes)
            return (-0.5 * jnp.sum(Ym * alpha) - 0.5 * logdet
                    - 0.5 * N * _LOG_2PI)
        return mll_exact

    def _operator(params, X, t, mask):
        return engine.operator(params, GPData(X, t, None, mask), config)

    @jax.custom_vjp
    def mll(params, X, t, Y, mask, probes):
        value, _ = _fwd(params, X, t, Y, mask, probes)
        return value

    def _fwd(params, X, t, Y, mask, probes):
        A = _operator(params, X, t, mask)
        Ym = Y * mask
        rhs = jnp.concatenate([Ym[None], probes], axis=0)
        N = jnp.sum(mask)
        # Consolidated path: ONE stacked block solve covers the mean solve,
        # the trace-gradient probe solves, AND (via the probes' CG-Lanczos
        # tridiagonals) the SLQ log-det — no separate Lanczos sweep. The
        # fallback (slq_via_cg=False, engines without solve_stacked, or
        # preconditioned solves whose Krylov space is M^{-1}A's) runs the
        # classic stacked solve + reorthogonalised-Lanczos SLQ.
        stacked = getattr(engine, "solve_stacked", None)
        logdet = None
        if stacked is not None and getattr(config, "slq_via_cg", True):
            st = stacked(A, rhs, config, probe_cols=probes.shape[0],
                         subspace_dim=N)
            sol, logdet = st.x, st.logdet
        else:
            sol = engine.solve(A, rhs, config)
        if logdet is None:
            logdet = engine.logdet(A, GPData(X, t, None, mask), config,
                                   probes)
        alpha, W = sol[0], sol[1:]
        value = -0.5 * jnp.sum(Ym * alpha) - 0.5 * logdet - 0.5 * N * _LOG_2PI
        return value, (params, X, t, Y, mask, alpha, W, probes)

    def _bwd(res, gbar):
        params, X, t, Y, mask, alpha, W, probes = res
        p = probes.shape[0]

        def h(pp):
            A = _operator(pp, X, t, mask)
            quad_alpha = jnp.sum(alpha * A(alpha))
            quad_tr = jnp.sum(W * A(probes)) / p
            return 0.5 * quad_alpha - 0.5 * quad_tr

        gparams = jax.grad(h)(params)
        gparams = jax.tree_util.tree_map(lambda g: gbar * g, gparams)
        zeros = lambda a: jnp.zeros_like(a)
        return (gparams, zeros(X), zeros(t), zeros(Y), zeros(mask),
                zeros(probes))

    mll.defvjp(_fwd, _bwd)
    return mll


def make_mll_iterative(cfg: LKGPConfig, mvm_impl=None):
    """Iterative MLL with custom VJP (backward-compatible entry point).

    Returns ``mll(params, X, t, Y, mask, probes)``. With ``mvm_impl`` given
    (signature ``mvm(K1, K2, mask, u, noise=...)``), every MVM — CG, SLQ,
    and the quadratic-form gradients — routes through it; this is how
    ``LKGPConfig.use_pallas`` threads the Pallas kernel into the objective.
    """
    engine = IterativeEngine() if mvm_impl is None else CustomMVMEngine(mvm_impl)
    return make_mll(cfg, engine)
