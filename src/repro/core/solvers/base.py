"""Solver protocol + registry: pluggable linear solvers for the engines.

Engines (``repro.core.engines``) realise the projected latent-Kronecker
operator; *solvers* decide how ``A x = b`` is driven against it. This module
defines the :class:`Solver` protocol (``solve`` / ``solve_stacked`` with
CG-compatible diagnostics), a name registry mirroring the engine registry,
and the three built-in implementations:

* ``cg``  — batched block CG (the paper's App. B solver), with the fused
            CG-Lanczos/SLQ log-det path on stacked probe solves.
* ``pcg`` — pivoted-Cholesky preconditioned CG on packed vectors; requires
            an operator exposing ``.mask`` and ``.preconditioner(rank)``
            (``LatentKroneckerOperator`` does) and falls back to plain CG
            otherwise.
* ``sgd`` — heavy-ball stochastic-gradient solves with Polyak averaging
            (arXiv 2506.06895's large-n regime).

``LKGPConfig.solver`` selects by name; ``"auto"`` keeps the historical
behaviour (PCG iff ``precond_rank > 0`` and the operator supports it, else
CG). Register custom solvers with :func:`register_solver`.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Protocol, runtime_checkable

import jax.numpy as jnp

from ..slq import slq_logdet_from_tridiag, tridiag_from_cg
from .cg import CGResult, cg_solve, cg_solve_tridiag
from .pcg import pcg_solve
from .sgd import sgd_solve

__all__ = [
    "Solver", "SOLVERS", "register_solver", "get_solver", "list_solvers",
    "resolve_solver", "StackedSolveResult", "CGSolver", "PCGSolver",
    "SGDSolver",
]

# Rank used when solver="pcg" is requested explicitly but the config left
# precond_rank at 0 (the "auto" route only picks pcg when rank > 0).
_DEFAULT_PCG_RANK = 15


class StackedSolveResult(NamedTuple):
    """One consolidated multi-RHS solve: solutions + (optional) log-det.

    ``x`` are the stacked solutions; ``logdet`` is the SLQ estimate built
    from the probe columns' CG-Lanczos tridiagonals (None when it could not
    be fused: preconditioned solves iterate in M^{-1}A's Krylov space, not
    A's, and SGD solves have no Lanczos correspondence at all — callers
    fall back to a separate SLQ pass); ``result`` carries the block
    solver's per-column diagnostics (iterations, residuals, breakdown
    flags, active-column MVM count). The per-column ``breakdown`` /
    ``col_iters`` diagnostics are also exposed directly on the stacked
    result, so ``solve_info`` consumers can report WHICH right-hand-side
    columns degraded without reaching through ``result``.
    """
    x: jnp.ndarray
    logdet: jnp.ndarray | None
    result: CGResult

    @property
    def breakdown(self) -> jnp.ndarray | None:
        """Per-RHS-column breakdown flags of the underlying block solve."""
        return None if self.result is None else self.result.breakdown

    @property
    def col_iters(self) -> jnp.ndarray | None:
        """Per-RHS-column iteration counts of the underlying block solve."""
        return None if self.result is None else self.result.col_iters

    @property
    def trace(self) -> Any:
        """Escalation trace of the guarded solve that produced this result
        (None for unguarded or in-trace solves)."""
        return None if self.result is None else self.result.trace


@runtime_checkable
class Solver(Protocol):
    """Linear-solver strategy driven against an engine operator."""

    name: str

    def solve(self, A: Callable, b: jnp.ndarray, config: Any,
              x0: jnp.ndarray | None = None) -> CGResult:
        """Solve A x = b for a (stack of) grid-form RHS with diagnostics."""
        ...

    def solve_stacked(self, A: Callable, rhs: jnp.ndarray, config: Any, *,
                      probe_cols: int = 0, subspace_dim: Any = None,
                      x0: jnp.ndarray | None = None) -> StackedSolveResult:
        """One batched sweep over a whole RHS stack, optionally fusing the
        SLQ log-det from the trailing ``probe_cols`` probe systems."""
        ...


SOLVERS: dict[str, type] = {}


def register_solver(name: str) -> Callable[[type], type]:
    def deco(cls: type) -> type:
        cls.name = name
        SOLVERS[name] = cls
        return cls
    return deco


_SOLVER_SINGLETONS: dict[str, "Solver"] = {}


def get_solver(name: str) -> "Solver":
    """Solver by registry name; solvers are stateless singletons."""
    try:
        cls = SOLVERS[name]
    except KeyError:
        raise ValueError(f"unknown solver {name!r}; "
                         f"available: {sorted(SOLVERS)}") from None
    solver = _SOLVER_SINGLETONS.get(name)
    if solver is None:
        solver = _SOLVER_SINGLETONS[name] = cls()
    return solver


def list_solvers() -> list[str]:
    return sorted(SOLVERS)


def _preconditionable(A: Any) -> bool:
    return hasattr(A, "preconditioner") and hasattr(A, "mask")


def resolve_solver(config: Any, A: Any = None) -> "Solver":
    """Map ``config.solver`` (default ``"auto"``) to a registered solver.

    ``"auto"`` preserves the pre-registry routing: preconditioned CG iff
    ``precond_rank > 0`` and the operator carries Kronecker factors to
    factorise (``A is None`` counts as "supports it" for operator-free
    contexts), plain CG otherwise.
    """
    name = getattr(config, "solver", "auto") or "auto"
    if name == "auto":
        rank = getattr(config, "precond_rank", 0)
        ok = A is None or _preconditionable(A)
        name = "pcg" if (rank and ok) else "cg"
    return get_solver(name)


@register_solver("cg")
class CGSolver:
    """Batched block CG; stacked solves fuse the SLQ log-det via CG-Lanczos."""

    def solve(self, A: Callable, b: jnp.ndarray, config: Any,
              x0: jnp.ndarray | None = None) -> CGResult:
        return cg_solve(A, b, tol=config.cg_tol,
                        max_iters=config.cg_max_iters, x0=x0)

    def solve_stacked(self, A: Callable, rhs: jnp.ndarray, config: Any, *,
                      probe_cols: int = 0, subspace_dim: Any = None,
                      x0: jnp.ndarray | None = None) -> StackedSolveResult:
        if probe_cols and x0 is not None:
            # A warm start changes the Krylov starting vectors from the
            # probes to rhs - A@x0, breaking the CG-Lanczos correspondence
            # the fused log-det relies on; solve warm but report no logdet
            # (the caller falls back to the separate SLQ pass).
            probe_cols = 0
        if probe_cols:
            res, tri = cg_solve_tridiag(
                A, rhs, max_rank=config.slq_iters, tol=config.cg_tol,
                max_iters=config.cg_max_iters, x0=x0)
            diag, off = tridiag_from_cg(tri.alphas[-probe_cols:],
                                        tri.betas[-probe_cols:],
                                        tri.steps[-probe_cols:])
            logdet = slq_logdet_from_tridiag(diag, off, subspace_dim)
        else:
            res = cg_solve(A, rhs, tol=config.cg_tol,
                           max_iters=config.cg_max_iters, x0=x0)
            logdet = None
        return StackedSolveResult(x=res.x, logdet=logdet, result=res)


@register_solver("pcg")
class PCGSolver:
    """Pivoted-Cholesky preconditioned CG through the operator's factors.

    Flattens grid-form vectors (..., n, m) onto (..., n*m) packed form,
    preconditions with the Woodbury-inverted rank-r pivoted Cholesky of the
    masked latent covariance (built and cached by the operator), and
    reshapes the solution back. The whole RHS stack shares one Woodbury
    apply per iteration. All pure jax, so it works under jit with a traced
    mask. Operators without ``.preconditioner`` (bare closures, distributed
    bodies) fall back to plain CG.
    """

    def solve(self, A: Callable, b: jnp.ndarray, config: Any,
              x0: jnp.ndarray | None = None) -> CGResult:
        if not _preconditionable(A):
            return get_solver("cg").solve(A, b, config, x0=x0)
        rank = getattr(config, "precond_rank", 0) or _DEFAULT_PCG_RANK
        n, m = A.mask.shape
        M_inv = A.preconditioner(rank)

        def A_flat(u: jnp.ndarray) -> jnp.ndarray:
            return A(u.reshape(*u.shape[:-1], n, m)).reshape(u.shape)

        x0_flat = None if x0 is None else x0.reshape(*x0.shape[:-2], n * m)
        res = pcg_solve(A_flat, b.reshape(*b.shape[:-2], n * m), M_inv,
                        tol=config.cg_tol, max_iters=config.cg_max_iters,
                        x0=x0_flat)
        return res._replace(x=res.x.reshape(b.shape))

    def solve_stacked(self, A: Callable, rhs: jnp.ndarray, config: Any, *,
                      probe_cols: int = 0, subspace_dim: Any = None,
                      x0: jnp.ndarray | None = None) -> StackedSolveResult:
        # The preconditioned Krylov space is M^{-1}A's, not A's, so the
        # CG-Lanczos log-det cannot be fused; callers run SLQ separately.
        res = self.solve(A, rhs, config, x0=x0)
        return StackedSolveResult(x=res.x, logdet=None, result=res)


@register_solver("sgd")
class SGDSolver:
    """Heavy-ball SGD solves with Polyak tail averaging (large-n regime)."""

    def solve(self, A: Callable, b: jnp.ndarray, config: Any,
              x0: jnp.ndarray | None = None) -> CGResult:
        return sgd_solve(
            A, b, tol=config.cg_tol,
            max_iters=getattr(config, "sgd_iters", 500), x0=x0,
            momentum=getattr(config, "sgd_momentum", 0.9),
            lr=getattr(config, "sgd_lr", 0.0))

    def solve_stacked(self, A: Callable, rhs: jnp.ndarray, config: Any, *,
                      probe_cols: int = 0, subspace_dim: Any = None,
                      x0: jnp.ndarray | None = None) -> StackedSolveResult:
        # SGD iterates have no Lanczos correspondence; no fused log-det.
        res = self.solve(A, rhs, config, x0=x0)
        return StackedSolveResult(x=res.x, logdet=None, result=res)
