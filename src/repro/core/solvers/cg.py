"""Batched multi-RHS block conjugate gradients on grid-form vectors.

Matches the paper's App. B settings: relative residual-norm tolerance 0.01,
max 10 000 iterations. The operator is a callable u -> A(u) acting on
(..., n, m) grid vectors; multiple right-hand sides batch over leading dims
and every iteration applies the operator to the WHOLE stack in one batched
sweep (same semantics as GPyTorch's mBCG). On top of the classic batched
loop the solver adds:

* **per-column convergence freezing** — a system that has reached ``tol``
  stops updating (``alpha = 0``, its direction is held fixed) instead of
  riding along to the slowest system's iteration count. Frozen columns no
  longer drift numerically and no longer count as useful operator work:
  ``CGResult.matvecs`` accumulates only the *active* columns per sweep, and
  ``CGResult.col_iters`` records the per-system iteration of convergence.
* **breakdown detection** — on an indefinite or numerically broken operator
  ``p^T A p <= 0`` for a still-active column. Previously the column was
  silently frozen with ``alpha = 0`` and could be reported as a success;
  now it raises the per-system ``CGResult.breakdown`` flag (and is frozen,
  so the remaining healthy columns still converge).
* **warm starts** — :func:`cg_solve` accepts ``x0``; scheduler-style warm
  refits restart from the previous solution instead of zero.
* **CG-Lanczos tridiagonals** — :func:`cg_solve_tridiag` additionally
  returns the Lanczos tridiagonal coefficients of each system's Krylov
  space, recovered from the CG step sizes (Saad; Gardner et al., 2018's
  mBCG). This is what lets one stacked solve of ``K^{-1}[y | probes]``
  also produce the SLQ log-determinant with zero extra operator sweeps
  (see :func:`repro.core.slq.slq_logdet_from_tridiag`).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["cg_solve", "cg_solve_tridiag", "CGResult", "CGTridiag"]


class CGResult(NamedTuple):
    x: jnp.ndarray
    iters: jnp.ndarray          # scalar int32: total operator sweeps
    rel_residual: jnp.ndarray   # (...,) per-system final relative residual
    breakdown: jnp.ndarray | None = None   # (...,) bool: pAp <= 0 observed
    col_iters: jnp.ndarray | None = None   # (...,) int32 per-system iters
    matvecs: jnp.ndarray | None = None     # scalar int32: active-column MVMs
    # Escalation trace attached by repro.core.solvers.guarded on EAGER
    # solves: a tuple of EscalationStep records (None for raw solver calls
    # and for solves inside traced programs, where the guard passes
    # through). Lives on the diagnostics path only — never a traced value.
    trace: Any = None


class CGTridiag(NamedTuple):
    """CG-Lanczos tridiagonal coefficients per system (see cg_solve_tridiag).

    ``alphas``/``betas`` are the raw CG step/update coefficients of the
    first ``max_rank`` iterations; ``steps`` is how many were recorded per
    system (recording stops when a column converges or breaks down).
    """
    alphas: jnp.ndarray   # (..., max_rank)
    betas: jnp.ndarray    # (..., max_rank)
    steps: jnp.ndarray    # (...,) int32


def _dot(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Per-system inner product over the trailing (n, m) grid axes."""
    return jnp.sum(a * b, axis=(-2, -1))


def _cg_loop(A: Callable, b: jnp.ndarray, tol: float, max_iters: int,
             x0: jnp.ndarray | None, record: int):
    """Shared block-CG loop; ``record > 0`` also carries tridiag arrays."""
    if x0 is None:
        x0 = jnp.zeros_like(b)
    b_norm = jnp.sqrt(_dot(b, b))
    # Guard all-zero RHS (can occur for fully-unobserved batches).
    safe_b_norm = jnp.where(b_norm == 0, 1.0, b_norm)
    sys_shape = b.shape[:-2]

    r0 = b - A(x0)
    zero_i = jnp.zeros(sys_shape, jnp.int32)
    state0 = dict(
        x=x0, r=r0, p=r0, rs=_dot(r0, r0), it=jnp.int32(0),
        breakdown=jnp.zeros(sys_shape, bool), col_iters=zero_i,
        matvecs=jnp.int32(0),
    )
    if record:
        state0["ta"] = jnp.zeros((*sys_shape, record), b.dtype)
        state0["tb"] = jnp.zeros((*sys_shape, record), b.dtype)
        state0["tsteps"] = zero_i

    def active_mask(state):
        rel = jnp.sqrt(state["rs"]) / safe_b_norm
        return jnp.logical_and(rel > tol, ~state["breakdown"])

    def cond(state):
        return jnp.logical_and(jnp.any(active_mask(state)),
                               state["it"] < max_iters)

    def body(state):
        x, r, p, rs = state["x"], state["r"], state["p"], state["rs"]
        it = state["it"]
        active = active_mask(state)
        Ap = A(p)
        pAp = _dot(p, Ap)
        # Indefinite / numerically broken column: freeze it and flag it
        # instead of silently reporting success on a stalled system.
        broke = jnp.logical_and(active, pAp <= 0)
        breakdown = jnp.logical_or(state["breakdown"], broke)
        step = jnp.logical_and(active, pAp > 0)
        alpha = jnp.where(step, rs / jnp.where(pAp == 0, 1.0, pAp), 0.0)
        x = x + alpha[..., None, None] * p
        r = r - alpha[..., None, None] * Ap
        rs_new = jnp.where(step, _dot(r, r), rs)
        beta = jnp.where(step, rs_new / jnp.where(rs == 0, 1.0, rs), 0.0)
        # Frozen columns keep their direction fixed (alpha = 0 above makes
        # them no-ops); stepping columns do the standard update.
        p = jnp.where(step[..., None, None], r + beta[..., None, None] * p, p)

        out = dict(state)
        out.update(
            x=x, r=r, p=p, rs=rs_new, it=it + 1,
            breakdown=breakdown,
            col_iters=jnp.where(step, it + 1, state["col_iters"]),
            matvecs=state["matvecs"] + jnp.sum(active, dtype=jnp.int32),
        )
        if record:
            # Record the CG (alpha, beta) pair of this iteration for the
            # first `record` steps of each still-stepping column; the
            # Lanczos T is rebuilt from these in slq_logdet_from_tridiag.
            slot = jnp.minimum(it, record - 1)
            write = jnp.logical_and(step, it < record)
            ta, tb = state["ta"], state["tb"]
            out["ta"] = ta.at[..., slot].set(
                jnp.where(write, alpha, ta[..., slot]))
            out["tb"] = tb.at[..., slot].set(
                jnp.where(write, beta, tb[..., slot]))
            out["tsteps"] = jnp.where(write, it + 1, state["tsteps"])
        return out

    state = jax.lax.while_loop(cond, body, state0)
    # Report the TRUE final residual ||b - Ax|| / ||b||, not the recursively
    # updated one: on ill-conditioned systems the recursion drifts (it can
    # report convergence the solution never reached).
    x = state["x"]
    r_true = b - A(x)
    res = CGResult(
        x=x, iters=state["it"],
        rel_residual=jnp.sqrt(_dot(r_true, r_true)) / safe_b_norm,
        breakdown=state["breakdown"], col_iters=state["col_iters"],
        matvecs=state["matvecs"])
    tri = None
    if record:
        tri = CGTridiag(alphas=state["ta"], betas=state["tb"],
                        steps=state["tsteps"])
    return res, tri


def cg_solve(A: Callable[[jnp.ndarray], jnp.ndarray], b: jnp.ndarray,
             tol: float = 0.01, max_iters: int = 10_000,
             x0: jnp.ndarray | None = None) -> CGResult:
    """Solve A x = b for SPD A with batched block conjugate gradients.

    b: (..., n, m) grid-form right-hand sides (zeros at unobserved cells);
    all systems share each operator sweep. Returns grid-form solutions of
    the same shape, with per-system convergence/breakdown diagnostics.
    """
    res, _ = _cg_loop(A, b, tol, max_iters, x0, record=0)
    return res


def cg_solve_tridiag(A: Callable, b: jnp.ndarray, max_rank: int,
                     tol: float = 0.01, max_iters: int = 10_000,
                     x0: jnp.ndarray | None = None
                     ) -> tuple[CGResult, CGTridiag]:
    """Block CG that also returns per-system CG-Lanczos tridiagonals.

    The Lanczos tridiagonal of the Krylov space started at ``b`` falls out
    of the CG coefficients (T_jj = 1/a_j + b_{j-1}/a_{j-1}, T_{j,j+1} =
    sqrt(b_j)/a_j), so a single stacked solve doubles as the SLQ probe
    sweep — no separate Lanczos recursion, no extra operator applications.
    Only the first ``max_rank`` iterations are recorded (the Gauss
    quadrature converges long before CG does). Warm starts are
    intentionally NOT applied to tridiag solves by callers that need the
    Krylov space of ``b`` itself; ``x0`` is still accepted for the solve.
    """
    if max_rank <= 0:
        raise ValueError("max_rank must be positive for cg_solve_tridiag")
    return _cg_loop(A, b, tol, max_iters, x0, record=int(max_rank))
