"""Guarded solves: a deterministic escalation ladder over the solver stack.

The block solvers (:mod:`repro.core.solvers`) *report* degradation — per
column ``breakdown`` flags on ``p^T A p <= 0`` and TRUE final residuals —
but never act on it. This module consumes those diagnostics and escalates
deterministically when a solve degrades:

1. **retry with jitter escalation** — re-solve against ``A + eps*I`` with
   ``eps`` starting at ``10 * config.jitter`` and growing x10 per retry up
   to ``config.guard_jitter_max`` (at most ``config.guard_retries``
   retries). A jittered operator is strictly better conditioned; for
   near-singular gram factors this is usually enough.
2. **switch solver** — walk the registry ladder ``sgd -> cg -> pcg``
   (solvers strictly after the failing one; unregistered/custom solvers
   escalate to ``cg`` then ``pcg``), each on the ORIGINAL operator.
3. **dense Cholesky fallback** — when the operator exposes its Kronecker
   factors (``K1`` / ``K2`` / ``mask`` / ``noise``) and the grid is small
   (``mask.size <= config.guard_dense_max``), assemble the masked dense
   matrix and solve exactly.

``LKGPConfig.solve_policy`` selects what happens around the ladder:

* ``"strict"``      — no escalation; a degraded solve raises
                      :class:`GuardedSolveError` immediately.
* ``"escalate"``    — walk the ladder, return the first healthy result;
                      raise :class:`GuardedSolveError` if it is exhausted.
* ``"best_effort"`` — walk the ladder, never raise: if nothing is healthy,
                      return the attempt with the smallest worst-column
                      residual (breakdown flags intact).

A solve is *degraded* iff any column flags ``breakdown`` or any final
residual is non-finite. A residual merely above ``tol`` (a max-iters stop)
is NOT degraded — that is ordinary iterative-solver behaviour the callers
already tolerate.

Every guarded result carries its escalation ``trace`` (a tuple of
:class:`EscalationStep`) on ``CGResult.trace``, which flows through
``_stash_diagnostics`` into ``Posterior.solve_info``. Ladder activity is
counted per stage (:func:`escalation_tally`); the engines additionally
bump :func:`repro.core.engines.solve_tally` once per extra attempt.

**Tracing:** the guards are host-side control flow. Inside a traced
program (jit/vmap — e.g. the fit objective) the diagnostics are tracers,
so the guard detects that and passes the base solver's result through
untouched: the traced program is bit-identical to an unguarded one (the
``audit_guarded_solves`` jaxpr auditor pins this — no host callbacks, no
f64). Guards therefore act on the eager paths: posterior solves, serving,
and any direct ``engine.solve*`` call.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .base import StackedSolveResult, Solver, get_solver, resolve_solver
from .cg import CGResult

__all__ = [
    "EscalationStep", "GuardedSolveError", "GuardedSolver", "SOLVE_POLICIES",
    "guarded_solve", "guarded_solve_stacked", "escalation_tally",
    "reset_escalation_tally",
]

SOLVE_POLICIES = ("strict", "escalate", "best_effort")

# Escalation order: stochastic SGD solves are the flakiest, plain CG is the
# workhorse, preconditioned CG is the most robust iterative option. A
# failing solver escalates to the ones AFTER it; unknown (custom) solvers
# escalate to cg then pcg.
_LADDER = ("sgd", "cg", "pcg")
_FACTOR_ATTRS = ("K1", "K2", "mask", "noise")


class EscalationStep(NamedTuple):
    """One rung of the escalation ladder, as executed."""
    stage: str            # "attempt" | "retry_jitter" | "switch_solver"
    #                     # | "dense_fallback"
    solver: str           # solver name the attempt ran with
    jitter: float         # extra diagonal jitter applied (0.0 = none)
    ok: bool              # attempt came back healthy
    worst_residual: float  # max per-column relative residual (nan -> inf)


class GuardedSolveError(RuntimeError):
    """Every rung of the escalation ladder degraded (or policy="strict"
    forbade escalation). Carries the executed ``trace``."""

    def __init__(self, message: str, trace: tuple = ()) -> None:
        super().__init__(message)
        self.trace = trace


# -- ladder activity counters (process-wide, mirrors engines.solve_tally) --
_TALLY_LOCK = threading.Lock()
_TALLY: dict[str, int] = {
    "retry_jitter": 0, "switch_solver": 0, "dense_fallback": 0,
    "degraded_returns": 0, "strict_failures": 0,
}


def escalation_tally() -> dict[str, int]:
    """Counts of escalation-ladder activity in this process, by stage."""
    with _TALLY_LOCK:
        return dict(_TALLY)


def reset_escalation_tally() -> None:
    with _TALLY_LOCK:
        for k in _TALLY:
            _TALLY[k] = 0


def _bump(stage: str) -> None:
    with _TALLY_LOCK:
        _TALLY[stage] = _TALLY.get(stage, 0) + 1


# -- health ---------------------------------------------------------------
def _is_traced(value: Any) -> bool:
    return isinstance(value, jax.core.Tracer)


def _worst_residual(res: CGResult) -> float:
    rel = np.asarray(res.rel_residual)
    if rel.size == 0:
        return 0.0
    return float(np.max(np.nan_to_num(rel, nan=np.inf, posinf=np.inf,
                                      neginf=np.inf)))


def _degraded(res: CGResult) -> bool:
    """Breakdown flagged or non-finite final residual.

    The final residual is the TRUE ``||b - Ax|| / ||b||`` (the solvers
    recompute it), so a non-finite solution always shows up here — no need
    to sync ``x`` separately. Residuals above tolerance do NOT count:
    hitting ``max_iters`` on a hard system is expected behaviour.
    """
    if res.breakdown is not None and bool(np.any(np.asarray(res.breakdown))):
        return True
    return not bool(np.all(np.isfinite(np.asarray(res.rel_residual))))


class _JitteredOperator:
    """``u -> A(u) + eps * u``: the base operator with extra diagonal jitter.

    Attribute access (``mask``, ``preconditioner``, Kronecker factors)
    delegates to the base operator so solver routing (e.g. PCG's
    preconditionable check) is unchanged — the base preconditioner remains
    a valid preconditioner for the jittered system.
    """

    def __init__(self, base: Callable, eps: float) -> None:
        self._base = base
        self.eps = eps

    def __call__(self, u: jnp.ndarray) -> jnp.ndarray:
        return self._base(u) + self.eps * u

    def __getattr__(self, name: str) -> Any:
        return getattr(self._base, name)


def _jitter_ladder(config: Any) -> list[float]:
    eps = 10.0 * max(float(getattr(config, "jitter", 1e-6)), 1e-12)
    cap = float(getattr(config, "guard_jitter_max", 1e-2))
    retries = int(getattr(config, "guard_retries", 3))
    out: list[float] = []
    while eps <= cap * (1.0 + 1e-9) and len(out) < retries:
        out.append(eps)
        eps *= 10.0
    return out


def _switch_candidates(base_name: str) -> list[str]:
    if base_name in _LADDER:
        return list(_LADDER[_LADDER.index(base_name) + 1:])
    return ["cg", "pcg"]


def _dense_eligible(A: Any, config: Any) -> bool:
    if not all(hasattr(A, a) for a in _FACTOR_ATTRS):
        return False
    return int(np.prod(A.mask.shape)) <= int(
        getattr(config, "guard_dense_max", 4096))


def _dense_solve(A: Any, b: jnp.ndarray, config: Any) -> CGResult:
    """Exact masked-grid Cholesky solve from the operator's factors.

    Residuals are measured against the assembled dense matrix (the model's
    intended SPD system): the fallback exists precisely for operators whose
    *realisation* broke (bad kernel MVM, indefinite wrapper), so measuring
    against the broken realisation would mark a correct solve degraded.
    """
    from ..mvm import kron_dense

    mv = A.mask.reshape(-1)
    K = kron_dense(A.K1, A.K2) * (mv[:, None] * mv[None, :])
    K = K + jnp.diag(A.noise * mv + (1.0 - mv))
    L = jnp.linalg.cholesky(K)
    if not bool(np.all(np.isfinite(np.asarray(L)))):
        cap = float(getattr(config, "guard_jitter_max", 1e-2))
        L = jnp.linalg.cholesky(K + cap * jnp.eye(K.shape[0], dtype=K.dtype))
    N = mv.shape[0]
    sys_shape = b.shape[:-2]
    bb = (b * A.mask).reshape(-1, N)
    x = jax.scipy.linalg.cho_solve((L, True), bb.T).T
    x = x * mv
    r = bb - x @ K.T
    norm = jnp.sqrt(jnp.sum(bb * bb, axis=-1))
    rel = (jnp.sqrt(jnp.sum(r * r, axis=-1))
           / jnp.where(norm == 0, 1.0, norm)).reshape(sys_shape)
    return CGResult(
        x=(x.reshape(b.shape)), iters=jnp.int32(0), rel_residual=rel,
        breakdown=jnp.zeros(sys_shape, bool),
        col_iters=jnp.zeros(sys_shape, jnp.int32), matvecs=jnp.int32(0))


def _dense_logdet(A: Any, config: Any) -> jnp.ndarray:
    from ..mvm import kron_dense

    mv = A.mask.reshape(-1)
    K = kron_dense(A.K1, A.K2) * (mv[:, None] * mv[None, :])
    K = K + jnp.diag(A.noise * mv + (1.0 - mv))
    L = jnp.linalg.cholesky(K)
    return 2.0 * jnp.sum(jnp.log(jnp.diag(L)))   # unobserved diag=1 -> log 0


# -- the ladder -----------------------------------------------------------
def _policy(config: Any) -> str:
    policy = getattr(config, "solve_policy", "escalate") or "escalate"
    if policy not in SOLVE_POLICIES:
        raise ValueError(f"unknown solve_policy {policy!r}; "
                         f"expected one of {SOLVE_POLICIES}")
    return policy


def _run_ladder(attempt: Callable[[Solver, Callable], CGResult],
                dense_attempt: Callable[[], CGResult] | None,
                A: Callable, base: Solver, config: Any, what: str,
                first: CGResult | None = None) -> tuple[CGResult, tuple]:
    """Shared ladder driver; returns (result, trace) or raises.

    ``first`` is the base attempt the caller already ran for its health
    pre-check — reused as the ladder's first rung rather than paying the
    base solve twice.
    """
    policy = _policy(config)
    trace: list[EscalationStep] = []
    best: CGResult | None = None
    best_score = np.inf

    def run(stage: str, solver: Solver, op: Callable, eps: float,
            pre: CGResult | None = None) -> tuple[CGResult, bool]:
        nonlocal best, best_score
        res = pre if pre is not None else attempt(solver, op)
        ok = not _degraded(res)
        score = _worst_residual(res)
        trace.append(EscalationStep(stage=stage, solver=solver.name,
                                    jitter=eps, ok=ok, worst_residual=score))
        if best is None or score < best_score:
            best, best_score = res, score
        return res, ok

    res, ok = run("attempt", base, A, 0.0, pre=first)
    if ok:
        return res, tuple(trace)
    if policy == "strict":
        _bump("strict_failures")
        raise GuardedSolveError(
            f"{what}: solver {base.name!r} degraded "
            f"(worst residual {trace[0].worst_residual:.3g}) and "
            "solve_policy='strict' forbids escalation", tuple(trace))

    for eps in _jitter_ladder(config):
        _bump("retry_jitter")
        res, ok = run("retry_jitter", base, _JitteredOperator(A, eps), eps)
        if ok:
            return res, tuple(trace)
    for name in _switch_candidates(base.name):
        _bump("switch_solver")
        res, ok = run("switch_solver", get_solver(name), A, 0.0)
        if ok:
            return res, tuple(trace)
    if dense_attempt is not None and _dense_eligible(A, config):
        _bump("dense_fallback")
        res = dense_attempt()
        ok = not _degraded(res)
        score = _worst_residual(res)
        trace.append(EscalationStep(stage="dense_fallback", solver="dense",
                                    jitter=0.0, ok=ok, worst_residual=score))
        if ok:
            return res, tuple(trace)
        if score < best_score:
            best, best_score = res, score

    if policy == "best_effort":
        _bump("degraded_returns")
        assert best is not None
        return best, tuple(trace)
    raise GuardedSolveError(
        f"{what}: escalation ladder exhausted after {len(trace)} attempts "
        f"(best worst-column residual {best_score:.3g}); trace: "
        + " -> ".join(f"{s.stage}[{s.solver}]" for s in trace), tuple(trace))


def guarded_solve(A: Callable, b: jnp.ndarray, config: Any,
                  x0: jnp.ndarray | None = None,
                  solver: Solver | None = None) -> CGResult:
    """Solve ``A x = b`` under the configured escalation policy.

    Drop-in for ``resolve_solver(config, A).solve(...)`` with health
    checking and the escalation ladder on top; the returned
    :class:`CGResult` carries the executed :class:`EscalationStep` tuple as
    ``trace``. Inside traced programs the base result passes through
    unchanged (``trace=None``).
    """
    base = solver if solver is not None else resolve_solver(config, A)
    res = base.solve(A, b, config, x0=x0)
    if _is_traced(res.rel_residual):
        return res
    if _policy(config) != "strict" and not _degraded(res):
        # Fast path: healthy first attempt, record a one-step trace.
        return res._replace(trace=(EscalationStep(
            "attempt", base.name, 0.0, True, _worst_residual(res)),))

    def attempt(slv: Solver, op: Callable) -> CGResult:
        return slv.solve(op, b, config, x0=x0)

    final, trace = _run_ladder(
        attempt, lambda: _dense_solve(A, b, config), A, base, config,
        what="guarded_solve", first=res)
    return final._replace(trace=trace)


def guarded_solve_stacked(A: Callable, rhs: jnp.ndarray, config: Any, *,
                          probe_cols: int = 0, subspace_dim: Any = None,
                          x0: jnp.ndarray | None = None,
                          solver: Solver | None = None) -> StackedSolveResult:
    """Stacked multi-RHS solve under the escalation policy.

    Escalated attempts keep per-column diagnostics intact. A solver switch
    or dense fallback may change ``logdet`` availability: switched solvers
    report ``logdet=None`` exactly as if selected directly (callers already
    handle the separate-SLQ fallback); the dense fallback reports the exact
    observed-subspace log-determinant, which is strictly better than the
    probe estimate it replaces.
    """
    base = solver if solver is not None else resolve_solver(config, A)
    st = base.solve_stacked(A, rhs, config, probe_cols=probe_cols,
                            subspace_dim=subspace_dim, x0=x0)
    if _is_traced(st.result.rel_residual):
        return st
    if _policy(config) != "strict" and not _degraded(st.result):
        res = st.result._replace(trace=(EscalationStep(
            "attempt", base.name, 0.0, True, _worst_residual(st.result)),))
        return st._replace(result=res)

    results: dict[int, StackedSolveResult] = {id(st.result): st}

    def attempt(slv: Solver, op: Callable) -> CGResult:
        out = slv.solve_stacked(op, rhs, config, probe_cols=probe_cols,
                                subspace_dim=subspace_dim, x0=x0)
        results[id(out.result)] = out
        return out.result

    def dense_attempt() -> CGResult:
        res = _dense_solve(A, rhs, config)
        logdet = _dense_logdet(A, config) if probe_cols else None
        results[id(res)] = StackedSolveResult(x=res.x, logdet=logdet,
                                              result=res)
        return res

    final, trace = _run_ladder(attempt, dense_attempt, A, base, config,
                               what="guarded_solve_stacked", first=st.result)
    st_final = results[id(final)]
    return st_final._replace(result=final._replace(trace=trace))


class GuardedSolver:
    """Solver-protocol wrapper running a base solver under the ladder.

    Useful for driving an explicit solver (rather than the config-resolved
    one) through the guards; the engines call the module-level functions
    directly.
    """

    def __init__(self, base: Solver) -> None:
        self._base = base
        self.name = f"guarded[{base.name}]"

    def solve(self, A: Callable, b: jnp.ndarray, config: Any,
              x0: jnp.ndarray | None = None) -> CGResult:
        return guarded_solve(A, b, config, x0=x0, solver=self._base)

    def solve_stacked(self, A: Callable, rhs: jnp.ndarray, config: Any, *,
                      probe_cols: int = 0, subspace_dim: Any = None,
                      x0: jnp.ndarray | None = None) -> StackedSolveResult:
        return guarded_solve_stacked(
            A, rhs, config, probe_cols=probe_cols,
            subspace_dim=subspace_dim, x0=x0, solver=self._base)
