"""Preconditioned block CG on packed vectors.

The preconditioned variant of :mod:`repro.core.solvers.cg`: same per-column
freezing, breakdown flags, warm starts and TRUE-final-residual reporting,
but iterating on *packed* (..., N) vectors with an ``M_inv`` approximate
inverse applied to the whole RHS stack once per sweep (see
:mod:`repro.core.precond` for the pivoted-Cholesky/Woodbury construction).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from .cg import CGResult

__all__ = ["pcg_solve"]


def pcg_solve(A: Callable, b: jnp.ndarray, M_inv: Callable,
              tol: float = 0.01, max_iters: int = 10_000,
              x0: jnp.ndarray | None = None) -> CGResult:
    """Preconditioned block CG on packed vectors (..., N).

    ``M_inv`` approximates A^{-1} (see core.precond for the pivoted-Cholesky
    preconditioner) and is applied to the whole RHS stack in one batched
    sweep per iteration. The stopping rule monitors the unpreconditioned
    (recursively updated) residual, matching cg_solve; the *reported*
    ``rel_residual`` is the true final residual ``||b - Ax|| / ||b||``.
    Like :func:`repro.core.solvers.cg.cg_solve` it freezes converged
    columns, flags breakdown (``pAp <= 0``) per system, and warm-starts
    from ``x0``.
    """
    if x0 is None:
        x0 = jnp.zeros_like(b)
    b_norm = jnp.sqrt(jnp.sum(b * b, axis=-1))
    safe = jnp.where(b_norm == 0, 1.0, b_norm)
    sys_shape = b.shape[:-1]
    r0 = b - A(x0)
    z0 = M_inv(r0)
    rz0 = jnp.sum(r0 * z0, axis=-1)
    state0 = dict(x=x0, r=r0, z=z0, p=z0, rz=rz0, it=jnp.int32(0),
                  breakdown=jnp.zeros(sys_shape, bool),
                  col_iters=jnp.zeros(sys_shape, jnp.int32),
                  matvecs=jnp.int32(0))

    def active_mask(state):
        rel = jnp.sqrt(jnp.sum(state["r"] * state["r"], axis=-1)) / safe
        return jnp.logical_and(rel > tol, ~state["breakdown"])

    def cond(state):
        return jnp.logical_and(jnp.any(active_mask(state)),
                               state["it"] < max_iters)

    def body(state):
        x, r, z, p, rz = (state["x"], state["r"], state["z"], state["p"],
                          state["rz"])
        it = state["it"]
        active = active_mask(state)
        Ap = A(p)
        pAp = jnp.sum(p * Ap, axis=-1)
        broke = jnp.logical_and(active, pAp <= 0)
        step = jnp.logical_and(active, pAp > 0)
        alpha = jnp.where(step, rz / jnp.where(pAp == 0, 1.0, pAp), 0.0)
        x = x + alpha[..., None] * p
        r = r - alpha[..., None] * Ap
        z = M_inv(r)
        rz_new = jnp.where(step, jnp.sum(r * z, axis=-1), rz)
        beta = jnp.where(step, rz_new / jnp.where(rz == 0, 1.0, rz), 0.0)
        p = jnp.where(step[..., None], z + beta[..., None] * p, p)
        return dict(
            x=x, r=r, z=z, p=p, rz=rz_new, it=it + 1,
            breakdown=jnp.logical_or(state["breakdown"], broke),
            col_iters=jnp.where(step, it + 1, state["col_iters"]),
            matvecs=state["matvecs"] + jnp.sum(active, dtype=jnp.int32))

    state = jax.lax.while_loop(cond, body, state0)
    x = state["x"]
    r_true = b - A(x)
    rel = jnp.sqrt(jnp.sum(r_true * r_true, axis=-1)) / safe
    return CGResult(x=x, iters=state["it"], rel_residual=rel,
                    breakdown=state["breakdown"],
                    col_iters=state["col_iters"], matvecs=state["matvecs"])
