"""Pluggable linear-solver stack for the latent-Kronecker engines.

Public surface: the low-level solver functions (:func:`cg_solve`,
:func:`cg_solve_tridiag`, :func:`pcg_solve`, :func:`sgd_solve`), their
shared diagnostics types (:class:`CGResult`, :class:`CGTridiag`,
:class:`StackedSolveResult`), and the strategy registry
(:class:`Solver` protocol, :func:`get_solver` / :func:`resolve_solver` /
:func:`register_solver` / :func:`list_solvers`).

``repro.core.cg`` remains as a deprecation shim re-exporting the moved
functions; new code should import from this package.
"""
from .base import (CGSolver, PCGSolver, SGDSolver, Solver, SOLVERS,
                   StackedSolveResult, get_solver, list_solvers,
                   register_solver, resolve_solver)
from .cg import CGResult, CGTridiag, cg_solve, cg_solve_tridiag
from .guarded import (SOLVE_POLICIES, EscalationStep, GuardedSolveError,
                      GuardedSolver, escalation_tally, guarded_solve,
                      guarded_solve_stacked, reset_escalation_tally)
from .pcg import pcg_solve
from .sgd import estimate_lmax, sgd_solve

__all__ = [
    "CGResult", "CGTridiag", "cg_solve", "cg_solve_tridiag", "pcg_solve",
    "sgd_solve", "estimate_lmax",
    "Solver", "SOLVERS", "register_solver", "get_solver", "list_solvers",
    "resolve_solver", "StackedSolveResult",
    "CGSolver", "PCGSolver", "SGDSolver",
    "GuardedSolver", "GuardedSolveError", "EscalationStep", "SOLVE_POLICIES",
    "guarded_solve", "guarded_solve_stacked", "escalation_tally",
    "reset_escalation_tally",
]
