"""Stochastic-gradient linear solver with Polyak iterate averaging.

The follow-up paper "Scalable Gaussian Processes with Latent Kronecker
Structure" (arXiv 2506.06895) replaces CG with SGD-style solves of
``A x = b`` when the config axis n grows 10-100x: each sweep is one operator
application (the same cost as a CG sweep) but the iteration is a plain
heavy-ball step, so it tolerates low precision and never breaks down on an
indefinite ``p^T A p``. Solving the quadratic

    f(x) = 0.5 x^T A x - b^T x        (grad f = A x - b = -r)

by gradient descent with momentum gives the update

    v <- momentum * v + r
    x <- x + lr * v

with ``lr ~ 1 / lambda_max(A)`` estimated by power iteration when not given.
Polyak (tail) averaging smooths the last-iterate oscillation: the running
mean of the iterates past a burn-in is tracked alongside the running mean of
their residuals (free, by linearity of ``r = b - A x``), and the averaged
iterate is returned per system whenever its residual beats the last
iterate's.

Diagnostics mirror :class:`repro.core.solvers.cg.CGResult` exactly —
per-column convergence freezing, ``col_iters``, active-column ``matvecs``,
TRUE final residual — so engines and posteriors consume SGD solves
unchanged. ``breakdown`` flags non-finite iterates (divergence), the SGD
analogue of CG's indefinite-operator breakdown.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from .cg import CGResult, _dot

__all__ = ["sgd_solve", "estimate_lmax"]


def estimate_lmax(A: Callable, b: jnp.ndarray, iters: int = 8) -> jnp.ndarray:
    """Largest eigenvalue of SPD ``A`` by power iteration started at ``b``.

    ``b`` may carry leading system dims; every system runs its own power
    iteration (sharing the batched operator sweeps) and the max over
    systems is returned — one scalar, since all systems share the same
    operator. All-zero systems contribute 0 and are ignored by the max.
    """
    nrm = jnp.sqrt(_dot(b, b))
    v0 = b / jnp.where(nrm == 0, 1.0, nrm)[..., None, None]

    def body(_, carry):
        v, lam = carry
        w = A(v)
        lam = jnp.sqrt(_dot(w, w))
        v = w / jnp.where(lam == 0, 1.0, lam)[..., None, None]
        return v, lam

    _, lam = jax.lax.fori_loop(0, iters, body,
                               (v0, jnp.zeros(b.shape[:-2], b.dtype)))
    return jnp.max(lam)


def sgd_solve(A: Callable[[jnp.ndarray], jnp.ndarray], b: jnp.ndarray,
              tol: float = 0.01, max_iters: int = 500,
              x0: jnp.ndarray | None = None, momentum: float = 0.9,
              lr: float = 0.0, lr_iters: int = 8,
              avg_frac: float = 0.5) -> CGResult:
    """Solve SPD ``A x = b`` by heavy-ball gradient descent + Polyak tail
    averaging, on grid-form (..., n, m) right-hand-side stacks.

    ``lr <= 0`` auto-tunes the step size to ``1 / lambda_max(A)`` via
    ``lr_iters`` power-iteration sweeps (stable for any momentum in
    [0, 1)). Averaging starts after ``avg_frac * max_iters`` sweeps; the
    averaged iterate is used per system only where its (exactly tracked)
    residual beats the last iterate's. Semantics otherwise match
    :func:`repro.core.solvers.cg.cg_solve`: converged columns freeze and
    stop counting toward ``matvecs``, and the reported ``rel_residual`` is
    the true final ``||b - A x|| / ||b||``.
    """
    if x0 is None:
        x0 = jnp.zeros_like(b)
    b_norm = jnp.sqrt(_dot(b, b))
    safe_b_norm = jnp.where(b_norm == 0, 1.0, b_norm)
    sys_shape = b.shape[:-2]

    if lr and lr > 0:
        step_size = jnp.asarray(lr, b.dtype)
    else:
        lam = estimate_lmax(A, b, iters=lr_iters)
        step_size = 1.0 / jnp.where(lam == 0, 1.0, lam)

    avg_start = int(max_iters * avg_frac)
    r0 = b - A(x0)
    state0 = dict(
        x=x0, v=jnp.zeros_like(b), r=r0, it=jnp.int32(0),
        breakdown=jnp.zeros(sys_shape, bool),
        col_iters=jnp.zeros(sys_shape, jnp.int32), matvecs=jnp.int32(0),
        x_sum=jnp.zeros_like(b), r_sum=jnp.zeros_like(b),
        avg_cnt=jnp.zeros(sys_shape, jnp.int32),
    )

    def active_mask(state):
        rel = jnp.sqrt(_dot(state["r"], state["r"])) / safe_b_norm
        return jnp.logical_and(rel > tol, ~state["breakdown"])

    def cond(state):
        return jnp.logical_and(jnp.any(active_mask(state)),
                               state["it"] < max_iters)

    def body(state):
        it = state["it"]
        active = active_mask(state)
        am = active[..., None, None]
        v = jnp.where(am, momentum * state["v"] + state["r"], state["v"])
        x = jnp.where(am, state["x"] + step_size * v, state["x"])
        r = jnp.where(am, b - A(x), state["r"])
        # Divergence shows up as inf/nan in the residual: flag it as
        # breakdown (freezing the column) rather than looping to max_iters.
        blew_up = jnp.logical_and(active, ~jnp.all(jnp.isfinite(r),
                                                   axis=(-2, -1)))
        do_avg = jnp.logical_and(active, it + 1 > avg_start)
        davg = do_avg[..., None, None]
        return dict(
            x=x, v=v, r=r, it=it + 1,
            breakdown=jnp.logical_or(state["breakdown"], blew_up),
            col_iters=jnp.where(active, it + 1, state["col_iters"]),
            matvecs=state["matvecs"] + jnp.sum(active, dtype=jnp.int32),
            x_sum=jnp.where(davg, state["x_sum"] + x, state["x_sum"]),
            r_sum=jnp.where(davg, state["r_sum"] + r, state["r_sum"]),
            avg_cnt=state["avg_cnt"] + do_avg.astype(jnp.int32),
        )

    state = jax.lax.while_loop(cond, body, state0)
    # Polyak average: mean of the tail iterates; by linearity of
    # r = b - A(x) its residual is the mean of the tail residuals, so the
    # averaged-vs-last choice costs no extra operator sweep.
    cnt = jnp.maximum(state["avg_cnt"], 1)[..., None, None].astype(b.dtype)
    x_avg = state["x_sum"] / cnt
    r_avg = state["r_sum"] / cnt
    use_avg = jnp.logical_and(
        state["avg_cnt"] > 0,
        _dot(r_avg, r_avg) < _dot(state["r"], state["r"]))
    x = jnp.where(use_avg[..., None, None], x_avg, state["x"])
    r_true = b - A(x)
    return CGResult(
        x=x, iters=state["it"],
        rel_residual=jnp.sqrt(_dot(r_true, r_true)) / safe_b_norm,
        breakdown=state["breakdown"], col_iters=state["col_iters"],
        matvecs=state["matvecs"])
