"""Immutable model state and the functional fitting API.

The model layer is organised around three abstractions:

* :class:`LKGPState` — an immutable pytree holding fitted parameters,
  input/output transforms, and the *raw* training data. Produced by
  :func:`fit`; consumed by every inference engine and by
  :class:`~repro.core.posterior.Posterior`.
* :class:`~repro.core.engines.InferenceEngine` — pluggable linear-algebra
  backends (``dense`` / ``iterative`` / ``pallas`` / ``distributed``)
  selected via ``LKGPConfig.backend``.
* :class:`~repro.core.posterior.Posterior` — a lazy posterior that caches
  the CG solve of ``K^{-1} y`` and shares it between the exact mean and
  Matheron samples.

State transitions are functional: ``fit(...) -> LKGPState``,
``extend(state, ...) -> LKGPState`` (incremental conditioning with
warm-started hyper-parameters), ``refit(state) -> LKGPState``. A batched
``fit_batch`` vmaps the whole objective over independent tasks.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Any, ClassVar, NamedTuple

import jax
import jax.flatten_util
import jax.numpy as jnp
import numpy as np

from . import gp_kernels as gk
from .caching import LRUCache
from .errors import ObservationError, check_grid_columns, check_observed_finite
from .lbfgs import lbfgs_minimize
from .polish import make_polish
from .priors import noise_prior_logpdf, x_lengthscale_prior_logpdf
from .slq import rademacher_probes
from .transforms import TTransform, XTransform, YTransform

__all__ = [
    "LKGPParams", "LKGPConfig", "GPData", "LKGPState", "FitResult",
    "init_params", "gram_matrices", "log_prior", "resolve_backend", "fit",
    "fit_batch", "extend", "refit", "unstack", "stack_states",
    "compiled_cache_stats",
]

_LOG_2PI = math.log(2.0 * math.pi)

BACKENDS = ("dense", "iterative", "pallas", "distributed")


class LKGPParams(NamedTuple):
    """Raw (log-space) parameters; positive values are exp(raw)."""
    raw_x_lengthscale: jnp.ndarray  # (d,)
    raw_t_lengthscale: jnp.ndarray  # ()
    raw_outputscale: jnp.ndarray    # ()
    raw_noise: jnp.ndarray          # ()


@dataclass(frozen=True)
class LKGPConfig:
    """Model + inference configuration.

    ``backend`` selects the inference engine (one front door for all four
    code paths): ``"dense"`` (exact Cholesky), ``"iterative"`` (CG + SLQ),
    ``"pallas"`` (CG + SLQ with every MVM routed through the Pallas TPU
    kernel in :mod:`repro.kernels.ops`), ``"distributed"`` (shard_map row
    sharding over a device mesh). ``"auto"`` resolves from the legacy
    ``mll_method`` / ``use_pallas`` fields and the observation count.
    """
    t_kernel: str = "matern12"
    backend: str = "auto"           # "auto" | dense | iterative | pallas | distributed
    mll_method: str = "auto"        # legacy: "cholesky" | "iterative" | "auto"
    auto_cholesky_max: int = 800    # N_obs threshold for "auto"
    cg_tol: float = 0.01            # paper App. B
    cg_max_iters: int = 10_000      # paper App. B
    precond_rank: int = 0           # >0: rank-r pivoted-Cholesky PCG (iterative/pallas)
    # Linear-solver strategy for the iterative-family engines (see
    # repro.core.solvers): "cg" | "pcg" | "sgd". "auto" keeps the historic
    # routing — PCG iff precond_rank > 0, plain CG otherwise.
    solver: str = "auto"
    sgd_iters: int = 500            # SGD sweep budget (one MVM per sweep)
    sgd_momentum: float = 0.9       # heavy-ball momentum
    sgd_lr: float = 0.0             # 0.0: auto 1/lambda_max via power iteration
    slq_probes: int = 16
    slq_iters: int = 25
    # True: the MLL's log-det comes from the probe columns' CG-Lanczos
    # tridiagonals of the ONE stacked solve K^{-1}[y | probes] (mBCG,
    # Gardner et al. 2018) — no separate Lanczos operator sweeps. False
    # restores the separate reorthogonalised-Lanczos SLQ pass.
    slq_via_cg: bool = True
    jitter: float = 1e-6
    lbfgs_iters: int = 100
    # Hyper-parameter initialisation + optimisation budget policy.
    # ``hyper_init``: "default" starts from the prior-mean init (refits
    # still warm-start from the previous optimum); "amortized" asks the
    # registered :mod:`repro.amortize` encoder for a data-conditioned
    # starting point on every fit AND every refit. ``polish_steps`` picks
    # the optimiser: -1 (default) runs the host-driven L-BFGS for up to
    # ``lbfgs_iters`` iterations; 0 skips optimisation entirely (the init
    # IS the fit — params round-trip bitwise); k > 0 runs the fixed-budget
    # pure-JAX polish (:mod:`repro.core.polish`) for exactly k L-BFGS steps
    # in ONE jitted call. Neither field enters the traced objective, so
    # flipping them never retraces (_objective_cache_key excludes both).
    hyper_init: str = "default"     # "default" | "amortized"
    polish_steps: int = -1          # -1 host L-BFGS | 0 no-op | k device steps
    posterior_samples: int = 64
    # Default cache policy for posterior(state): True lets repeated
    # posterior() calls on an UNCHANGED state share one lazy Posterior (and
    # therefore its cached K^{-1}[y|residuals] solves). Per-call override:
    # posterior(state, cache=...). extend/refit return new state objects,
    # which is what invalidates the cache.
    posterior_cache: bool = True
    seed: int = 0
    use_pallas: bool = False        # legacy alias for backend="pallas"
    # Reliability policy for eager engine solves (repro.core.solvers.guarded):
    # "strict" raises GuardedSolveError on any degraded solve; "escalate"
    # (default) walks the jitter -> solver-switch -> dense-fallback ladder
    # and raises only if it is exhausted; "best_effort" never raises and
    # returns the least-degraded attempt. Solves inside jitted programs
    # (the fit objective) bypass the guard entirely, so none of these
    # fields affect traced computations or the jit cache
    # (_objective_cache_key deliberately excludes them).
    solve_policy: str = "escalate"  # "strict" | "escalate" | "best_effort"
    guard_retries: int = 3          # max jitter-escalation retries
    guard_jitter_max: float = 1e-2  # jitter ladder cap (starts at 10*jitter)
    guard_dense_max: int = 4096     # max mask.size for dense Cholesky fallback


def init_params(d: int, dtype=jnp.float64) -> LKGPParams:
    """Initialise at prior means / paper defaults."""
    return LKGPParams(
        raw_x_lengthscale=jnp.full((d,), math.sqrt(2.0) + 0.5 * math.log(d), dtype),
        raw_t_lengthscale=jnp.asarray(math.log(0.25), dtype),
        raw_outputscale=jnp.asarray(0.0, dtype),
        raw_noise=jnp.asarray(-4.0, dtype),
    )


def gram_matrices(params: LKGPParams, X: jnp.ndarray, t: jnp.ndarray,
                  t_kernel: str = "matern12", jitter: float = 1e-6):
    """K1 (n, n) over configs and K2 (m, m) over progressions (jittered)."""
    k2fn = gk.KERNELS_1D[t_kernel]
    K1 = gk.rbf_ard(X, X, jnp.exp(params.raw_x_lengthscale))
    K2 = k2fn(t, t, jnp.exp(params.raw_t_lengthscale),
              jnp.exp(params.raw_outputscale))
    K1 = K1 + jitter * jnp.eye(X.shape[0], dtype=K1.dtype)
    K2 = K2 + jitter * jnp.eye(t.shape[0], dtype=K2.dtype)
    return K1, K2


def log_prior(params: LKGPParams, d: int) -> jnp.ndarray:
    return (x_lengthscale_prior_logpdf(params.raw_x_lengthscale, d)
            + noise_prior_logpdf(params.raw_noise))


class GPData(NamedTuple):
    """Transformed-space training data handed to an inference engine."""
    X: jnp.ndarray       # (n, d) in the unit hypercube
    t: jnp.ndarray       # (m,) log-scaled to [0, 1]
    Y: jnp.ndarray | None  # (n, m) normalised curves (None when not needed)
    mask: jnp.ndarray    # (n, m) 1.0 where observed


@dataclass(frozen=True)
class LKGPState:
    """Immutable fitted model state (a jax pytree).

    Data fields hold *raw* (untransformed) training data plus the fitted
    transforms and raw GP parameters; ``config`` is static metadata. The
    transformed view engines consume is exposed via :attr:`data`.

    ``fit`` attaches two non-pytree diagnostics with ``object.__setattr__``:
    ``fit_result`` (the L-BFGS result) and ``backend_used``. They describe
    the *fit call that produced this exact state* and never carry over to
    derived states: ``extend`` explicitly clears them (the carried-over
    warm-start parameters are no longer the result of any optimisation of
    the extended data) and ``refit`` re-derives them from its own fit.
    They do not survive ``tree_map`` either — read them with
    ``getattr(state, ..., None)``.

    :func:`repro.core.posterior.posterior` may attach ``_posterior_cache``
    the same way (the state-keyed solve cache): because every state
    transition builds a fresh object, a cached posterior can never outlive
    the state whose solves it holds.
    """
    params: LKGPParams
    X: jnp.ndarray       # (n, d) raw hyper-parameters
    t: jnp.ndarray       # (m,) raw progressions (e.g. epochs, 1-indexed)
    Y: jnp.ndarray       # (n, m) raw metric values
    mask: jnp.ndarray    # (n, m) 1.0 where observed
    x_tf: XTransform
    t_tf: TTransform
    y_tf: YTransform
    config: LKGPConfig = field(default_factory=LKGPConfig)

    # Attached by fit() via object.__setattr__ (see docstring): declared
    # as ClassVar so dataclass/pytree registration ignores them while
    # type checkers still know they exist on instances.
    fit_result: ClassVar[Any]
    backend_used: ClassVar[str]
    engine: ClassVar[Any]

    @property
    def n(self) -> int:
        return self.X.shape[-2]

    @property
    def m(self) -> int:
        return self.t.shape[-1]

    @property
    def d(self) -> int:
        return self.X.shape[-1]

    @property
    def data(self) -> GPData:
        """Transformed-space view of the training data (paper App. B)."""
        return GPData(self.x_tf(self.X), self.t_tf(self.t),
                      self.y_tf(self.Y), self.mask)

    def with_params(self, params: LKGPParams) -> "LKGPState":
        return dataclasses.replace(self, params=params)


jax.tree_util.register_dataclass(
    LKGPState,
    data_fields=["params", "X", "t", "Y", "mask", "x_tf", "t_tf", "y_tf"],
    meta_fields=["config"],
)


def resolve_backend(config: LKGPConfig, n_obs: int) -> str:
    """Map config (including legacy fields) to a concrete backend name."""
    if config.backend != "auto":
        if config.backend not in BACKENDS:
            raise ValueError(f"unknown backend {config.backend!r}; "
                             f"expected one of {BACKENDS}")
        return config.backend
    if config.use_pallas:
        return "pallas"
    if config.mll_method == "cholesky":
        return "dense"
    if config.mll_method == "iterative":
        return "iterative"
    return "dense" if n_obs <= config.auto_cholesky_max else "iterative"


def _fit_transforms(X, t, Y, mask):
    x_tf = XTransform.fit(X)
    t_tf = TTransform.fit(t)
    y_tf = YTransform.fit(Y, mask)
    return x_tf, t_tf, y_tf


class FitResult(NamedTuple):
    """Diagnostics of the optimisation that produced a state's params.

    Superset of the legacy ``LBFGSResult`` fields (``x`` / ``fun`` /
    ``n_iters`` / ``n_evals`` / ``converged``), plus honest budget
    accounting: ``budget`` is the iteration cap the optimiser ran under,
    ``init_source`` records where the starting point came from
    (``"default"`` | ``"amortized"`` | ``"params"``), and ``optimizer``
    names the path taken (``"lbfgs"`` host loop, ``"polish"`` fixed-budget
    device L-BFGS, ``"none"`` for ``polish_steps=0``). A capped run is now
    distinguishable from a converged one: ``converged`` reflects the
    gradient tolerance at the final iterate, while ``n_iters == budget``
    with ``converged=False`` means the budget bound first.
    """
    x: np.ndarray
    fun: float
    n_iters: int
    n_evals: int
    converged: bool
    budget: int
    init_source: str
    optimizer: str


def _flatten_params(p: LKGPParams) -> jnp.ndarray:
    """(d + 3,) flat raw-parameter vector (ravel_pytree field order)."""
    return jnp.concatenate([
        p.raw_x_lengthscale,
        jnp.reshape(p.raw_t_lengthscale, (1,)),
        jnp.reshape(p.raw_outputscale, (1,)),
        jnp.reshape(p.raw_noise, (1,)),
    ])


def _unflatten_params(x: jnp.ndarray, d: int) -> LKGPParams:
    return LKGPParams(raw_x_lengthscale=x[:d], raw_t_lengthscale=x[d],
                      raw_outputscale=x[d + 1], raw_noise=x[d + 2])


# Jitted fit objectives, cached across fit/refit rounds. Key = the
# objective-relevant config fields + engine identity + parameter dim: a
# refit that only bumps lbfgs_iters (or changes seed / posterior_samples,
# which enter through runtime arguments, not the traced program) reuses
# the compiled objective instead of retracing. The engine is part of the
# key *by object* — get_engine returns singletons precisely so this hits.
# Both caches are LRU-bounded with hit/miss/eviction counters (a
# long-lived PredictionService cycling tenant configs must not grow them
# without bound); see :func:`compiled_cache_stats`.
_VG_CACHE: LRUCache = LRUCache(64)
_POLISH_CACHE: LRUCache = LRUCache(64)
# Armijo ladder width. The fixed-budget design evaluates EVERY rung each
# step (deterministic cost), so unused rungs are pure waste: measured on
# prior-sampled tasks, rungs past 1/8 are never accepted from amortized or
# warm inits — width 4 leaves the optimized objective bitwise unchanged
# while cutting the per-step eval count from 7 to 5.
_POLISH_BACKTRACKS = 4
_POLISH_GTOL = 1e-6


def compiled_cache_stats() -> dict:
    """Hit/miss/eviction counters of the compiled-objective caches."""
    return {"fit_vg": _VG_CACHE.stats(), "polish": _POLISH_CACHE.stats()}


def _objective_cache_key(cfg: LKGPConfig) -> tuple:
    return (cfg.t_kernel, cfg.backend, cfg.mll_method, cfg.auto_cholesky_max,
            cfg.cg_tol, cfg.cg_max_iters, cfg.precond_rank, cfg.solver,
            cfg.sgd_iters, cfg.sgd_momentum, cfg.sgd_lr, cfg.slq_probes,
            cfg.slq_iters, cfg.slq_via_cg, cfg.jitter, cfg.use_pallas)


def _cached_fit_vg(cfg: LKGPConfig, engine, d: int):
    """value_and_grad of the fit objective as a pure jitted function.

    The returned function has signature ``vg(params, Xn, tn, Yn, mask,
    probes)`` — all data enters as arguments (``n_obs`` is computed on
    device), so same-shaped refits hit jit's own cache rather than
    re-tracing a fresh closure. The jaxpr auditor's retrace check
    (``repro.analysis.jaxpr_audit``) pins this behaviour.
    """
    from .engines import make_mll

    key = (_objective_cache_key(cfg), engine, d)
    vg = _VG_CACHE.get(key)
    if vg is None:
        mll_fn = make_mll(cfg, engine)

        def objective(p, Xn, tn, Yn, mask, probes):
            n_obs = jnp.sum(mask)
            mll = mll_fn(p, Xn, tn, Yn, mask, probes)
            return -(mll + log_prior(p, d)) / n_obs

        vg = jax.jit(jax.value_and_grad(objective))
        _VG_CACHE[key] = vg
    return vg


def _cached_polish(cfg: LKGPConfig, engine, d: int, steps: int):
    """The fixed-budget polish as ONE cached jitted program.

    Wraps the same compiled objective ``_cached_fit_vg`` hands the host
    L-BFGS (so polish and host paths optimise the identical function) in
    :func:`repro.core.polish.make_polish`. There is deliberately no
    batched variant: :func:`fit_batch` dispatches this exact program once
    per task, which is the only lowering that keeps per-task results
    bitwise identical to a single-task :func:`fit` at every batch size
    (``vmap`` re-associates the Cholesky VJP; ``lax.map`` compiles the
    loop body differently from the straight-line single-task program —
    both measured to drift in the last ulp; see the polish module
    docstring).
    """
    key = (_objective_cache_key(cfg), engine, d, steps)
    fn = _POLISH_CACHE.get(key)
    if fn is None:
        vg = _cached_fit_vg(cfg, engine, d)

        def vg_flat(xf, Xn, tn, Yn, mask, probes):
            f, g = vg(_unflatten_params(xf, d), Xn, tn, Yn, mask, probes)
            return f, _flatten_params(g)

        fn = jax.jit(make_polish(vg_flat, steps=steps,
                                 n_backtracks=_POLISH_BACKTRACKS))
        _POLISH_CACHE[key] = fn
    return fn


def _resolve_init(cfg: LKGPConfig, init, params0, amortizer, d: int, dtype,
                  Xn, tn, Yn, mask, batch: int | None = None):
    """Resolve the starting parameters and their provenance tag.

    Precedence: explicit ``init`` argument > legacy ``params0`` > an
    explicitly passed ``amortizer`` object > ``cfg.hyper_init``. String
    inits are ``"default"`` (prior-mean :func:`init_params`) and
    ``"amortized"`` (the passed or registered :mod:`repro.amortize`
    encoder applied to the transformed data); anything else must be an
    :class:`LKGPParams` (or 4-tuple), returned bitwise-untouched when its
    dtype already matches. With ``batch`` set the data carries a leading
    task axis and the resolved params do too.
    """
    if init is None:
        if params0 is not None:
            init = params0
        elif amortizer is not None:
            init = "amortized"
        else:
            init = cfg.hyper_init
    cast = lambda p: jax.tree_util.tree_map(  # noqa: E731
        lambda a: jnp.asarray(a, dtype), p)
    if isinstance(init, str):
        if init == "default":
            p = init_params(d, dtype)
            if batch is not None:
                p = jax.tree_util.tree_map(
                    lambda a: jnp.broadcast_to(a, (batch, *a.shape)), p)
            return p, "default"
        if init == "amortized":
            if amortizer is None:
                from ..amortize import get_amortizer
                amortizer = get_amortizer(d)
            if batch is not None:
                p = amortizer.init_batch(Xn, tn, Yn, mask)
            else:
                p = amortizer.init_for(Xn, tn, Yn, mask)
            return cast(p), "amortized"
        raise ValueError(f"unknown init {init!r}; expected 'default', "
                         "'amortized', or explicit LKGPParams")
    p = cast(LKGPParams(*init))
    want = 1 if batch is None else 2
    if p.raw_x_lengthscale.ndim != want:
        raise ValueError(
            f"explicit init params have x-lengthscale ndim "
            f"{p.raw_x_lengthscale.ndim}; expected {want} for this "
            f"{'batched ' if batch else ''}fit")
    return p, "params"


def _polish_fit(cfg: LKGPConfig, engine, d: int, dtype, budget: int,
                init_source: str, p0: LKGPParams, Xn, tn, Yn, mask, probes):
    """Fixed-budget polish (or the ``budget == 0`` no-op) for ``fit``."""
    flat0 = _flatten_params(p0).astype(dtype)
    if budget == 0:
        f0, _ = _cached_fit_vg(cfg, engine, d)(p0, Xn, tn, Yn, mask, probes)
        res = FitResult(x=np.asarray(flat0), fun=float(f0), n_iters=0,
                        n_evals=1, converged=False, budget=0,
                        init_source=init_source, optimizer="none")
        return p0, res
    pol = _cached_polish(cfg, engine, d, budget)
    pr = pol(flat0, Xn, tn, Yn, mask, probes)
    params = _unflatten_params(jnp.asarray(pr.x), d)
    res = FitResult(x=np.asarray(pr.x), fun=float(pr.fun), n_iters=budget,
                    n_evals=1 + budget * _POLISH_BACKTRACKS,
                    converged=bool(pr.grad_inf < _POLISH_GTOL),
                    budget=budget, init_source=init_source,
                    optimizer="polish")
    return params, res


def fit(X, t, Y, mask, config: LKGPConfig | None = None,
        params0: LKGPParams | None = None, engine=None, *,
        init=None, polish_steps: int | None = None,
        amortizer=None) -> LKGPState:
    """Fit the LKGP and return an immutable :class:`LKGPState`.

    Maximises (MLL + log prior) / N with L-BFGS on log-space parameters,
    through the engine selected by ``config.backend`` (or an explicitly
    provided ``engine``, e.g. a :class:`DistributedEngine` bound to a mesh).

    ``init`` selects the starting point: ``"default"`` (prior-mean init),
    ``"amortized"`` (the passed/registered :mod:`repro.amortize` encoder),
    or explicit :class:`LKGPParams`; unset, it falls back to ``params0``
    (legacy spelling of explicit params) and then ``config.hyper_init``.
    ``polish_steps`` is a one-call override of ``config.polish_steps``:
    ``-1`` runs the host L-BFGS for up to ``config.lbfgs_iters``
    iterations, ``0`` skips optimisation (the init is the fit, bitwise),
    ``k > 0`` runs exactly ``k`` device-side L-BFGS steps in one jitted
    call. ``state.fit_result`` (a :class:`FitResult`) records the budget,
    iterations used, convergence, and init provenance either way.
    """
    from .engines import get_engine

    cfg = config if config is not None else LKGPConfig()
    X = jnp.asarray(X)
    dtype = X.dtype
    t = jnp.asarray(t, dtype)
    Y = jnp.asarray(Y, dtype)
    mask = jnp.asarray(mask, dtype)
    if Y.shape != mask.shape:
        raise ObservationError(
            f"Y shape {Y.shape} does not match mask shape {mask.shape}")
    check_grid_columns(mask, t.shape[-1])
    check_observed_finite(Y, mask)
    # Zero unobserved cells: every downstream use is masked, so this is a
    # no-op for finite payloads, and it makes the documented contract
    # ("unobserved cells may hold anything") true even for NaN/inf there
    # (IEEE NaN*0 = NaN would otherwise poison Y*mask reductions).
    Y = jnp.where(mask > 0, Y, jnp.zeros_like(Y))

    x_tf, t_tf, y_tf = _fit_transforms(X, t, Y, mask)
    Xn, tn, Yn = x_tf(X), t_tf(t), y_tf(Y)

    d = X.shape[1]
    n_obs = int(np.sum(np.asarray(mask)))
    explicit_engine = engine is not None
    backend = engine.name if explicit_engine else resolve_backend(cfg, n_obs)
    if engine is None:
        engine = get_engine(backend)

    if engine.exact:
        probes = None
    else:
        key = jax.random.PRNGKey(cfg.seed)
        probes = rademacher_probes(key, cfg.slq_probes, mask, dtype)

    p0, init_source = _resolve_init(cfg, init, params0, amortizer, d, dtype,
                                    Xn, tn, Yn, mask)
    budget = cfg.polish_steps if polish_steps is None else polish_steps

    if budget >= 0:
        params, res = _polish_fit(cfg, engine, d, dtype, budget, init_source,
                                  p0, Xn, tn, Yn, mask, probes)
    else:
        vg = _cached_fit_vg(cfg, engine, d)
        flat0, unravel = jax.flatten_util.ravel_pytree(p0)

        def value_and_grad(x):
            f, g = vg(unravel(x.astype(dtype)), Xn, tn, Yn, mask, probes)
            return f, jax.flatten_util.ravel_pytree(g)[0]

        lb = lbfgs_minimize(value_and_grad, np.asarray(flat0, np.float64),
                            max_iters=cfg.lbfgs_iters)
        params = unravel(jnp.asarray(lb.x, dtype))
        res = FitResult(x=lb.x, fun=lb.fun, n_iters=lb.n_iters,
                        n_evals=lb.n_evals, converged=lb.converged,
                        budget=cfg.lbfgs_iters, init_source=init_source,
                        optimizer="lbfgs")
    state = LKGPState(params=params, X=X, t=t, Y=Y, mask=mask,
                      x_tf=x_tf, t_tf=t_tf, y_tf=y_tf, config=cfg)
    object.__setattr__(state, "fit_result", res)
    object.__setattr__(state, "backend_used", backend)
    if explicit_engine:
        # Pin an explicitly injected engine (e.g. a DistributedEngine bound
        # to a specific mesh) so posterior()/refit()/extend() keep using it;
        # config-resolved engines stay dynamic ("auto" re-resolves as data
        # grows).
        object.__setattr__(state, "engine", engine)
    return state


def fit_batch(X, t, Y, mask, config: LKGPConfig | None = None,
              params0: LKGPParams | None = None, *,
              init=None, polish_steps: int | None = None,
              amortizer=None) -> LKGPState:
    """Fit B independent tasks jointly via one batched objective.

    X: (B, n, d); t: (m,) or (B, m); Y, mask: (B, n, m). All tasks must
    share shapes. Returns an :class:`LKGPState` whose data leaves carry a
    leading batch dimension; :func:`unstack` splits it into per-task states.

    The batched objective uses the dense (exact Cholesky) marginal
    likelihood — it is fully vmappable (no data-dependent CG trip counts)
    and the per-task problems this path targets are small. With the
    default ``polish_steps=-1`` the B parameter pytrees are optimised
    jointly with one host L-BFGS on the concatenated vector (gradients are
    block-separable across tasks, so each task's optimum coincides with
    its individual fit). With ``polish_steps=k >= 0`` each task instead
    runs the fixed-budget device polish from its resolved init (see
    :func:`fit`): the polish program compiles once and each task is one
    dispatch of that same executable, so per-task results are bitwise
    identical to a single-task ``fit`` with the same init and budget —
    which is what lets the serving layer coalesce cold fits without
    changing any tenant's numbers.
    """
    from .engines import get_engine, mll_cholesky

    cfg = config if config is not None else LKGPConfig()
    X = jnp.asarray(X)
    dtype = X.dtype
    B, n, d = X.shape
    t = jnp.asarray(t, dtype)
    if t.ndim == 1:
        t = jnp.broadcast_to(t, (B, t.shape[0]))
    Y = jnp.asarray(Y, dtype)
    mask = jnp.asarray(mask, dtype)
    if Y.shape != mask.shape:
        raise ObservationError(
            f"Y shape {Y.shape} does not match mask shape {mask.shape}")
    check_grid_columns(mask, t.shape[-1])
    check_observed_finite(Y, mask)
    Y = jnp.where(mask > 0, Y, jnp.zeros_like(Y))   # see fit()

    # Transforms are fitted and applied PER TASK (not vmapped): the batched
    # lowering of even these small reductions differs from the single-task
    # one in the last ulp on CPU, which would break the bitwise
    # fit == fit_batch polish parity before the optimiser ever ran. B is
    # small on this path (coalesced cold fits), so the host loop is free.
    x_tfs = [XTransform.fit(X[i]) for i in range(B)]
    t_tfs = [TTransform.fit(t[i]) for i in range(B)]
    y_tfs = [YTransform.fit(Y[i], mask[i]) for i in range(B)]

    def _stack_trees(objs):
        return jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *objs)

    x_tf, t_tf, y_tf = (_stack_trees(x_tfs), _stack_trees(t_tfs),
                        _stack_trees(y_tfs))
    Xn = jnp.stack([x_tfs[i](X[i]) for i in range(B)])
    tn = jnp.stack([t_tfs[i](t[i]) for i in range(B)])
    Yn = jnp.stack([y_tfs[i](Y[i]) for i in range(B)])

    p0, init_source = _resolve_init(cfg, init, params0, amortizer, d, dtype,
                                    Xn, tn, Yn, mask, batch=B)
    budget = cfg.polish_steps if polish_steps is None else polish_steps

    if budget >= 0:
        # The polish reuses fit()'s compiled single-task program through
        # the dense engine (fit_batch is exact/dense by construction),
        # dispatched once per task: the program compiles ONCE (shared
        # _POLISH_CACHE entry with fit) and every task steps through the
        # identical executable, so per-task results are bitwise identical
        # to a single-task fit. vmap/lax.map lowerings were both measured
        # to break that parity in the last ulp (see _cached_polish).
        engine = get_engine("dense")
        flat0 = jax.vmap(_flatten_params)(p0).astype(dtype)
        if budget == 0:
            vg = _cached_fit_vg(cfg, engine, d)
            fs = [vg(_unflatten_params(flat0[i], d), Xn[i], tn[i], Yn[i],
                     mask[i], None)[0] for i in range(B)]
            params = p0
            res = FitResult(x=np.asarray(flat0),
                            fun=float(sum(float(f) for f in fs)),
                            n_iters=0, n_evals=B, converged=False, budget=0,
                            init_source=init_source, optimizer="none")
        else:
            pol = _cached_polish(cfg, engine, d, budget)
            prs = [pol(flat0[i], Xn[i], tn[i], Yn[i], mask[i], None)
                   for i in range(B)]
            xs = jnp.stack([pr.x for pr in prs])
            params = jax.vmap(lambda xf: _unflatten_params(xf, d))(xs)
            res = FitResult(
                x=np.asarray(xs),
                fun=float(sum(float(pr.fun) for pr in prs)),
                n_iters=budget,
                n_evals=B * (1 + budget * _POLISH_BACKTRACKS),
                converged=all(float(pr.grad_inf) < _POLISH_GTOL
                              for pr in prs),
                budget=budget, init_source=init_source, optimizer="polish")
    else:
        def obj_one(p, Xi, ti, Yi, mi):
            n_obs = jnp.sum(mi)
            mll = mll_cholesky(p, Xi, ti, Yi, mi, cfg.t_kernel, cfg.jitter)
            return -(mll + log_prior(p, d)) / n_obs

        def objective(pb):
            return jnp.sum(jax.vmap(obj_one)(pb, Xn, tn, Yn, mask))

        flat0, unravel = jax.flatten_util.ravel_pytree(p0)
        vg = jax.jit(jax.value_and_grad(objective))

        def value_and_grad(x):
            f, g = vg(unravel(x.astype(dtype)))
            return f, jax.flatten_util.ravel_pytree(g)[0]

        lb = lbfgs_minimize(value_and_grad, np.asarray(flat0, np.float64),
                            max_iters=cfg.lbfgs_iters)
        params = unravel(jnp.asarray(lb.x, dtype))
        res = FitResult(x=lb.x, fun=lb.fun, n_iters=lb.n_iters,
                        n_evals=lb.n_evals, converged=lb.converged,
                        budget=cfg.lbfgs_iters, init_source=init_source,
                        optimizer="lbfgs")
    state = LKGPState(params=params, X=X, t=t, Y=Y, mask=mask,
                      x_tf=x_tf, t_tf=t_tf, y_tf=y_tf, config=cfg)
    object.__setattr__(state, "fit_result", res)
    object.__setattr__(state, "backend_used", "dense")
    return state


def unstack(state: LKGPState) -> list[LKGPState]:
    """Split a batched state from :func:`fit_batch` into per-task states."""
    B = state.X.shape[0]
    return [jax.tree_util.tree_map(lambda a: a[i], state) for i in range(B)]


def stack_states(states: list[LKGPState]) -> LKGPState:
    """Stack same-shaped per-task states into one batched state.

    The inverse of :func:`unstack`: every data leaf (params, data,
    transforms) gains a leading batch dimension, yielding a state that
    :func:`~repro.core.posterior.posterior_batch` accepts. This is how the
    serving layer coalesces posterior requests from independent tenants
    into ONE vmapped evaluation. All states must share shapes and an
    identical ``config`` (the pytree treedef carries it as metadata).
    """
    if not states:
        raise ValueError("stack_states needs at least one state")
    first = states[0]
    for i, st in enumerate(states):
        if st.config != first.config:
            raise ValueError(f"state {i} has a different config than state 0"
                             " — coalesced states must share one config")
        if st.X.ndim != 2:
            raise ValueError(f"state {i} is already batched "
                             f"(X ndim {st.X.ndim}); stack unbatched states")
        if (st.X.shape != first.X.shape or st.t.shape != first.t.shape
                or st.Y.shape != first.Y.shape):
            raise ValueError(
                f"state {i} shapes (X {st.X.shape}, t {st.t.shape}, "
                f"Y {st.Y.shape}) do not match state 0 "
                f"(X {first.X.shape}, t {first.t.shape}, Y {first.Y.shape})")
    return jax.tree_util.tree_map(lambda *leaves: jnp.stack(leaves), *states)


def extend(state: LKGPState, new_Y, new_mask, new_X=None) -> LKGPState:
    """Incremental conditioning: fold new observations into the state.

    Two modes:

    * ``new_X is None`` — ``new_Y`` / ``new_mask`` are the *full updated*
      (n, m) grids over the existing configs (e.g. a freeze-thaw scheduler
      observed more epochs). ``new_mask`` must be a superset of
      ``state.mask``.
    * ``new_X`` given — k new configs are appended; ``new_Y`` / ``new_mask``
      are their (k, m) rows.

    Output transforms are refit on the union of observed data (the Y shift
    tracks the running max); the fitted hyper-parameters are carried over
    unchanged as a warm start — follow with :func:`refit` to re-optimise
    them from that warm state.
    """
    dtype = state.Y.dtype
    new_Y = jnp.asarray(new_Y, dtype)
    new_mask = jnp.asarray(new_mask, dtype)
    if new_Y.shape != new_mask.shape:
        raise ObservationError(
            f"new_Y shape {new_Y.shape} does not match new_mask shape "
            f"{new_mask.shape}")
    # Reject masks marking cells outside the budget grid t (and budget-axis
    # shape mismatches generally) with a typed error naming the offending
    # columns, instead of an opaque broadcast/concatenate failure below.
    check_grid_columns(new_mask, state.m, what="new_mask")
    check_observed_finite(new_Y, new_mask, what="new_Y")
    new_Y = jnp.where(new_mask > 0, new_Y, jnp.zeros_like(new_Y))  # see fit()

    if new_X is None:
        if new_Y.shape != state.Y.shape:
            raise ValueError(f"full-grid update expects shape {state.Y.shape}, "
                             f"got {new_Y.shape}")
        old_m, upd_m = np.asarray(state.mask), np.asarray(new_mask)
        if np.any(upd_m < old_m):
            raise ValueError("new_mask must be a superset of the current mask")
        X, Y, mask = state.X, new_Y, new_mask
    else:
        new_X = jnp.asarray(new_X, state.X.dtype)
        X = jnp.concatenate([state.X, new_X], axis=0)
        Y = jnp.concatenate([state.Y, new_Y], axis=0)
        mask = jnp.concatenate([state.mask, new_mask], axis=0)

    x_tf, _, y_tf = _fit_transforms(X, state.t, Y, mask)
    out = dataclasses.replace(state, X=X, Y=Y, mask=mask,
                              x_tf=x_tf, y_tf=y_tf)
    # dataclasses.replace drops every attached attribute. The bound engine
    # is deliberately carried forward (posterior()/refit() keep using the
    # same backend); fit_result / backend_used are deliberately NOT — they
    # described the fit of the *pre-extend* data and would be stale against
    # the extended grid (the carried-over params are a warm start, not an
    # optimum). Clearing them explicitly pins that contract even if the
    # construction above ever changes to one that copies attributes.
    eng = getattr(state, "engine", None)
    if eng is not None:
        object.__setattr__(out, "engine", eng)
    object.__setattr__(out, "fit_result", None)
    object.__setattr__(out, "backend_used", None)
    return out


def refit(state: LKGPState, config: LKGPConfig | None = None,
          lbfgs_iters: int | None = None, engine=None, *,
          init=None, polish_steps: int | None = None,
          amortizer=None) -> LKGPState:
    """Re-optimise hyper-parameters warm-started from ``state.params``.

    ``lbfgs_iters`` and ``polish_steps`` are one-call budget overrides:
    they do NOT persist into the returned state's config. An engine bound
    by the original ``fit`` call is reused unless a new one is given.

    The starting point defaults to ``state.params`` (classic warm start)
    — unless the config says ``hyper_init="amortized"`` (or ``init`` /
    ``amortizer`` is given explicitly), in which case every refit
    re-amortizes from the *current* observed data, which tracks the data
    distribution better than dragging yesterday's optimum along. With
    ``init=<params>`` and ``polish_steps=0`` the given params round-trip
    bitwise into the returned state.
    """
    base_cfg = config if config is not None else state.config
    cfg = base_cfg
    if lbfgs_iters is not None:
        cfg = dataclasses.replace(cfg, lbfgs_iters=lbfgs_iters)
    if engine is None:
        engine = getattr(state, "engine", None)
    if init is None and amortizer is None and cfg.hyper_init != "amortized":
        init = state.params
    out = fit(state.X, state.t, state.Y, state.mask, cfg,
              engine=engine, init=init, polish_steps=polish_steps,
              amortizer=amortizer)
    if cfg is not base_cfg:
        diag = {k: getattr(out, k, None)
                for k in ("fit_result", "backend_used", "engine")}
        out = dataclasses.replace(out, config=base_cfg)
        for k, v in diag.items():
            if v is not None:
                object.__setattr__(out, k, v)
    return out
