"""Posterior sampling via Matheron's rule with latent Kronecker structure.

    (f | Y)(.) = f(.) + k(., train) P^T (P (K1 (x) K2) P^T + s^2 I)^{-1}
                                        (vec(Y) - f(X x t) - eps)

* Prior samples on the joint grid use the Kronecker factorisation
  (L1 (x) L2) Z  ==  L1 @ Z @ L2^T  at O((n+n*)^3 + m^3) cost.
* The inverse-matrix-vector product is a batched solve against the masked
  latent-Kronecker operator (grid form, zero-padded residuals) — CG by
  default, or any engine solve via the ``solve`` hook.
* The correction is zero-padding -> Kronecker MVM -> evaluation at test rows:
  K1[joint, train] @ u @ K2.

The pieces are exposed separately (:func:`prior_residual_draws`,
:func:`kronecker_correction`) so that :class:`repro.core.posterior.Posterior`
can stack the Matheron residuals together with ``Y * mask`` into ONE
multi-RHS block solve ``K^{-1}[y | residuals]`` — the cached
``alpha = K^{-1}(Y * mask)`` and all samples then cost a single batched
operator sweep, and by linearity (``K^{-1}(Y - F - eps) = alpha -
K^{-1}(F + eps)``) the sample mean stays exactly consistent with the exact
mean.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from .mvm import lk_operator
from .solvers import get_solver

__all__ = ["sample_posterior_grid", "prior_residual_draws",
           "kronecker_correction"]


def prior_residual_draws(key, K1_joint: jnp.ndarray, K2: jnp.ndarray,
                         n_train: int, noise, n_samples: int,
                         jitter: float = 1e-6):
    """Draw the Matheron prior part: joint-grid prior samples + noise.

    Returns ``(F, eps)`` with ``F`` of shape (s, n+n*, m) — prior samples
    over the full joint grid via the Kronecker factorisation — and ``eps``
    of shape (s, n, m), the observation-noise draws on the training block.
    The solve RHS is then ``mask * (F[:, :n] + eps)``.
    """
    dtype = K1_joint.dtype
    na = K1_joint.shape[0]
    m = K2.shape[0]
    L1 = jnp.linalg.cholesky(K1_joint + jitter * jnp.eye(na, dtype=dtype))
    L2 = jnp.linalg.cholesky(K2 + jitter * jnp.eye(m, dtype=dtype))

    kz, ke = jax.random.split(key)
    Z = jax.random.normal(kz, (n_samples, na, m), dtype)
    # Prior samples on the joint grid: vec(F) ~ N(0, K1_joint (x) K2).
    F = jnp.einsum("ij,sjm,km->sik", L1, Z, L2)
    eps = jnp.sqrt(noise) * jax.random.normal(ke, (n_samples, n_train, m),
                                              dtype)
    return F, eps


def kronecker_correction(K1_joint: jnp.ndarray, u: jnp.ndarray,
                         K2: jnp.ndarray, n_train: int) -> jnp.ndarray:
    """Matheron correction (k1(., X) (x) k2(., t)) P^T u == K1[:, :n] @ u @ K2."""
    return jnp.einsum("aj,sjm,mk->sak", K1_joint[:, :n_train], u, K2)


def sample_posterior_grid(key, K1_joint: jnp.ndarray, K2: jnp.ndarray,
                          n_train: int, Y: jnp.ndarray, mask: jnp.ndarray,
                          noise, n_samples: int, cg_tol: float = 0.01,
                          cg_max_iters: int = 10_000, jitter: float = 1e-6,
                          mvm: Callable | None = None,
                          solve: Callable | None = None,
                          alpha: jnp.ndarray | None = None,
                          solver: str | None = None,
                          config=None) -> jnp.ndarray:
    """Draw posterior samples over the full (train + test configs) x t grid.

    K1_joint: ((n+n*), (n+n*)) config kernel over [X_train; X_test].
    K2: (m, m) progression kernel on the shared t grid.
    Y, mask: (n, m) observed learning curves (grid form).
    mvm: optional raw MVM ``mvm(K1, K2, mask, u, noise=...)`` for the CG
      operator; solve: optional batched solver ``solve(rhs) -> K^{-1} rhs``
      overriding the solver entirely; alpha: optional cached
      ``K^{-1}(Y * mask)``; solver: registry name (``"cg"``/``"sgd"``/...)
      for the pathwise residual solves — SGD is the arXiv 2506.06895
      pathwise-conditioning regime, where every sample draw is an SGD solve
      against the same operator; config: optional LKGPConfig supplying the
      solver hyper-parameters (tolerances default to ``cg_tol`` /
      ``cg_max_iters`` otherwise).
    Returns samples of shape (n_samples, n+n*, m); rows [:n] are posterior
    curves for the training configs (continuations), rows [n:] for test.
    """
    F, eps = prior_residual_draws(key, K1_joint, K2, n_train, noise,
                                  n_samples, jitter)

    if solve is None:
        K1_tt = K1_joint[:n_train, :n_train]
        if mvm is None:
            A = lk_operator(K1_tt, K2, mask, noise)
        else:
            A = lambda u: mvm(K1_tt, K2, mask, u, noise=noise)
        if config is None:
            # Duck-config carrying just what the solver strategies read.
            from .state import LKGPConfig
            config = LKGPConfig(cg_tol=cg_tol, cg_max_iters=cg_max_iters,
                                solver=solver or "auto")
        elif solver is not None and getattr(config, "solver", None) != solver:
            import dataclasses
            config = dataclasses.replace(config, solver=solver)
        strategy = get_solver(config.solver if config.solver != "auto"
                              else "cg")
        solve = lambda rhs: strategy.solve(A, rhs, config).x

    if alpha is None:
        u = solve(mask * (Y[None] - F[:, :n_train, :] - eps))  # (s, n, m)
    else:
        # Reuse the cached K^{-1}(Y*mask): solve only for the (F + eps) part.
        u = alpha[None] - solve(mask * (F[:, :n_train, :] + eps))

    return F + kronecker_correction(K1_joint, u, K2, n_train)
