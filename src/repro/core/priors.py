"""Hyper-parameter priors (paper App. B).

Parameters are optimised in log space (raw = log value). A LogNormal(mu, s)
prior on the positive parameter is a Normal(mu, s) density on its log, which
is what we evaluate on the raw parameter (MAP in the log parameterisation,
matching the paper's "marginal likelihood plus priors" objective).

* x lengthscales: LogNormal(sqrt(2) + 0.5 log d, sqrt(3))   [Hvarfner et al.]
* noise variance: LogNormal(-4, 1)
* t lengthscale / outputscale: no prior.
"""
from __future__ import annotations

import math

import jax.numpy as jnp

__all__ = ["normal_logpdf", "x_lengthscale_prior_logpdf", "noise_prior_logpdf"]

_LOG_2PI = math.log(2.0 * math.pi)


def normal_logpdf(x: jnp.ndarray, mu: float, sigma: float) -> jnp.ndarray:
    z = (x - mu) / sigma
    return -0.5 * (z * z + _LOG_2PI) - math.log(sigma)


def x_lengthscale_prior_logpdf(raw_lengthscale: jnp.ndarray, d: int) -> jnp.ndarray:
    mu = math.sqrt(2.0) + 0.5 * math.log(d)
    return jnp.sum(normal_logpdf(raw_lengthscale, mu, math.sqrt(3.0)))


def noise_prior_logpdf(raw_noise: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum(normal_logpdf(raw_noise, -4.0, 1.0))
