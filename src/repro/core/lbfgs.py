"""L-BFGS with two-loop recursion and strong-Wolfe line search.

The paper fits the 10 GP hyper-parameters with (PyTorch) L-BFGS; neither
optax nor scipy-in-jit is available offline, so this is a small, dependency
free implementation. The driver is a Python loop (the objective is cheap and
called O(100) times); the objective itself should be jitted by the caller.

Operates on flat vectors; use ``jax.flatten_util.ravel_pytree`` to adapt.
"""
# lint: disable-file=RA103 -- the Python driver loop is the design here:
# the jitted objective is called O(100) times and each Wolfe/curvature
# decision genuinely needs the scalar on host. See module docstring.
from __future__ import annotations

from typing import Callable, NamedTuple

import jax.numpy as jnp
import numpy as np

__all__ = ["lbfgs_minimize", "LBFGSResult"]


class LBFGSResult(NamedTuple):
    x: np.ndarray
    fun: float
    n_iters: int
    n_evals: int
    converged: bool


def _two_loop(g, s_list, y_list):
    """H * g via the standard two-loop recursion.

    Pairs with non-positive curvature ``y.s <= 0`` (or non-finite products)
    are skipped — the standard skip rule. Clamping them instead would turn a
    curvature violation into ``rho ~ 1/eps`` and an exploding direction.
    """
    pairs = []
    for s, y in zip(s_list, y_list):
        ys = float(np.dot(y, s))
        if np.isfinite(ys) and ys > 0:
            pairs.append((s, y, 1.0 / ys))
    q = g.copy()
    alphas = []
    for s, y, rho in reversed(pairs):
        a = rho * float(np.dot(s, q))
        alphas.append(a)
        q -= a * y
    if pairs:
        s, y, _ = pairs[-1]
        gamma = float(np.dot(s, y)) / max(float(np.dot(y, y)), 1e-300)
        q *= gamma
    for (s, y, rho), a in zip(pairs, reversed(alphas)):
        b = rho * float(np.dot(y, q))
        q += (a - b) * s
    return q


def _wolfe_line_search(fg, x, f0, g0, d, c1=1e-4, c2=0.9, max_evals=25):
    """Strong-Wolfe line search (bracket + zoom, Nocedal & Wright alg. 3.5/3.6)."""
    dg0 = float(np.dot(g0, d))
    if dg0 >= 0:  # not a descent direction; caller resets
        return None, 0

    def phi(a):
        f, g = fg(x + a * d)
        return float(f), g, float(np.dot(g, d))

    evals = 0
    a_prev, f_prev, dg_prev = 0.0, f0, dg0
    a = 1.0
    a_max = 1e10
    for _ in range(max_evals):
        f, g, dg = phi(a)
        evals += 1
        if not np.isfinite(f):
            a_max = a
            a = 0.5 * (a_prev + a)
            continue
        if f > f0 + c1 * a * dg0 or (evals > 1 and f >= f_prev):
            lo, f_lo, dg_lo, hi = a_prev, f_prev, dg_prev, a
            break
        if abs(dg) <= -c2 * dg0:
            return (a, f, g), evals
        if dg >= 0:
            lo, f_lo, dg_lo, hi = a, f, dg, a_prev
            break
        a_prev, f_prev, dg_prev = a, f, dg
        a = min(2.0 * a, a_max)
    else:
        # Best effort: only hand back a finite decrease; a non-finite f here
        # would poison the (s, y) pair and the next iterate. (f, g) belong to
        # a_prev — the loop body doubles `a` past the last evaluated point.
        if np.isfinite(f) and f < f0 and a_prev > 0:
            return (a_prev, f, g), evals
        return None, evals

    # zoom
    best = None
    for _ in range(max_evals):
        a = 0.5 * (lo + hi)
        f, g, dg = phi(a)
        evals += 1
        if np.isfinite(f) and f < f0 and (best is None or f < best[1]):
            best = (a, f, g)
        if not np.isfinite(f) or f > f0 + c1 * a * dg0 or f >= f_lo:
            hi = a
        else:
            if abs(dg) <= -c2 * dg0:
                return (a, f, g), evals
            if dg * (hi - lo) >= 0:
                hi = lo
            lo, f_lo, dg_lo = a, f, dg
        if abs(hi - lo) < 1e-14:
            break
    return best, evals  # best finite decrease seen, or None (caller resets)


def lbfgs_minimize(value_and_grad: Callable, x0, max_iters: int = 100,
                   history: int = 10, gtol: float = 1e-6,
                   ftol: float = 1e-10) -> LBFGSResult:
    """Minimise a smooth objective. ``value_and_grad(x) -> (f, g)``."""

    def fg(x):
        f, g = value_and_grad(jnp.asarray(x))
        return float(f), np.asarray(g, dtype=np.float64)

    x = np.asarray(x0, dtype=np.float64).copy()
    f, g = fg(x)
    n_evals = 1
    s_list: list[np.ndarray] = []
    y_list: list[np.ndarray] = []
    converged = False
    it = 0
    for it in range(1, max_iters + 1):
        if np.max(np.abs(g)) < gtol:
            converged = True
            break
        d = -_two_loop(g, s_list, y_list)
        res, ev = _wolfe_line_search(fg, x, f, g, d)
        n_evals += ev
        if res is None:  # bad direction: reset memory, steepest descent
            s_list.clear()
            y_list.clear()
            d = -g
            res, ev = _wolfe_line_search(fg, x, f, g, d)
            n_evals += ev
            if res is None:
                break
        a, f_new, g_new = res
        x_new = x + a * d
        s = x_new - x
        y = g_new - g
        if float(np.dot(s, y)) > 1e-10 * float(np.linalg.norm(s)) * float(np.linalg.norm(y)):
            s_list.append(s)
            y_list.append(y)
            if len(s_list) > history:
                s_list.pop(0)
                y_list.pop(0)
        if abs(f - f_new) < ftol * max(1.0, abs(f)):
            x, f, g = x_new, f_new, g_new
            converged = True
            break
        x, f, g = x_new, f_new, g_new
    # A run that reaches the gradient tolerance exactly on its final iterate
    # used to report converged=False (the gtol check only ran at the TOP of
    # each iteration), making a capped-but-converged run indistinguishable
    # from a genuinely budget-limited one. Check the final iterate too.
    if not converged and np.max(np.abs(g)) < gtol:
        converged = True
    return LBFGSResult(x=x, fun=f, n_iters=it, n_evals=n_evals, converged=converged)
