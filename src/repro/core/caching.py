"""A small instrumented LRU cache for compiled-program singletons.

Both compiled-objective caches (``state._VG_CACHE`` / ``state._POLISH_CACHE``)
and the engine singleton map (``engines._ENGINE_SINGLETONS``) hold objects
that are expensive to rebuild (jitted programs, or the identity keys jitted
programs are cached on). A long-lived :class:`~repro.serving.service
.PredictionService` cycling tenant configs used to grow the objective cache
without bound (FIFO-popped only at a fixed cap, with no visibility into churn);
this class bounds them with true LRU eviction and exposes hit/miss/eviction
counters so cache health is observable from service metrics.

The interface is deliberately dict-like (``get`` / ``[]`` / ``len`` /
``items`` / ``clear``) so existing call sites — including the jaxpr
auditor's retrace check, which introspects ``_VG_CACHE`` directly — keep
working unchanged.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Iterator

__all__ = ["LRUCache"]


class LRUCache:
    """Bounded mapping with least-recently-used eviction and counters.

    ``get`` / ``__getitem__`` count hits and misses and refresh recency on
    hit (``in`` probes neither); inserting past ``maxsize`` evicts the least
    recently used entry and counts an eviction. ``clear`` drops entries but
    keeps the counters (they describe the cache's lifetime, not its
    contents).
    """

    def __init__(self, maxsize: int) -> None:
        if maxsize < 1:
            raise ValueError(f"maxsize must be >= 1, got {maxsize}")
        self.maxsize = maxsize
        self._data: OrderedDict[Any, Any] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key: Any, default: Any = None) -> Any:
        try:
            value = self._data[key]
        except KeyError:
            self.misses += 1
            return default
        self.hits += 1
        self._data.move_to_end(key)
        return value

    def __getitem__(self, key: Any) -> Any:
        if key not in self._data:
            self.misses += 1
            raise KeyError(key)
        return self.get(key)

    def __setitem__(self, key: Any, value: Any) -> None:
        if key in self._data:
            self._data.move_to_end(key)
        self._data[key] = value
        while len(self._data) > self.maxsize:
            self._data.popitem(last=False)
            self.evictions += 1

    def __contains__(self, key: Any) -> bool:
        return key in self._data

    def __len__(self) -> int:
        return len(self._data)

    def __iter__(self) -> Iterator[Any]:
        return iter(self._data)

    def items(self):
        return self._data.items()

    def pop(self, key: Any, *default: Any) -> Any:
        return self._data.pop(key, *default)

    def clear(self) -> None:
        self._data.clear()

    def stats(self) -> dict:
        """Counters + occupancy as a plain dict (JSON-friendly)."""
        return {"size": len(self._data), "maxsize": self.maxsize,
                "hits": self.hits, "misses": self.misses,
                "evictions": self.evictions}
