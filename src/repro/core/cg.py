"""Deprecated location of the solver functions (moved to core.solvers).

``repro.core.cg`` grew preconditioned and stochastic-gradient siblings and
became the :mod:`repro.core.solvers` package; this shim re-exports the old
public names so external imports keep working. Import from
``repro.core.solvers`` (or ``repro.core``) instead.
"""
from __future__ import annotations

import warnings

from .solvers.cg import (CGResult, CGTridiag, _cg_loop, _dot, cg_solve,
                         cg_solve_tridiag)
from .solvers.pcg import pcg_solve

__all__ = ["cg_solve", "cg_solve_tridiag", "pcg_solve", "CGResult",
           "CGTridiag"]

warnings.warn(
    "repro.core.cg is deprecated; import from repro.core.solvers "
    "(cg_solve/cg_solve_tridiag/pcg_solve and the Solver registry) instead.",
    DeprecationWarning, stacklevel=2)
