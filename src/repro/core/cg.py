"""Batched conjugate gradients on grid-form vectors.

Matches the paper's App. B settings: relative residual-norm tolerance 0.01,
max 10 000 iterations. The operator is a callable u -> A(u) acting on
(..., n, m) grid vectors; multiple right-hand sides batch over leading dims
and the while_loop stops when *every* system has converged (same semantics as
GPyTorch's batched CG).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["cg_solve", "CGResult"]


class CGResult(NamedTuple):
    x: jnp.ndarray
    iters: jnp.ndarray          # scalar int32
    rel_residual: jnp.ndarray   # (...,) per-system final relative residual


def _dot(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Per-system inner product over the trailing (n, m) grid axes."""
    return jnp.sum(a * b, axis=(-2, -1))


def cg_solve(A: Callable[[jnp.ndarray], jnp.ndarray], b: jnp.ndarray,
             tol: float = 0.01, max_iters: int = 10_000,
             x0: jnp.ndarray | None = None) -> CGResult:
    """Solve A x = b for SPD A with batched conjugate gradients.

    b: (..., n, m) grid-form right-hand sides (zeros at unobserved cells).
    Returns grid-form solutions of the same shape.
    """
    if x0 is None:
        x0 = jnp.zeros_like(b)
    b_norm = jnp.sqrt(_dot(b, b))
    # Guard all-zero RHS (can occur for fully-unobserved batches).
    safe_b_norm = jnp.where(b_norm == 0, 1.0, b_norm)

    r0 = b - A(x0)
    state0 = (x0, r0, r0, _dot(r0, r0), jnp.int32(0))

    def cond(state):
        _, r, _, rs, it = state
        rel = jnp.sqrt(rs) / safe_b_norm
        return jnp.logical_and(jnp.max(rel) > tol, it < max_iters)

    def body(state):
        x, r, p, rs, it = state
        Ap = A(p)
        pAp = _dot(p, Ap)
        # Converged systems have tiny p; guard the division.
        alpha = jnp.where(pAp > 0, rs / jnp.where(pAp == 0, 1.0, pAp), 0.0)
        x = x + alpha[..., None, None] * p
        r = r - alpha[..., None, None] * Ap
        rs_new = _dot(r, r)
        beta = rs_new / jnp.where(rs == 0, 1.0, rs)
        p = r + beta[..., None, None] * p
        return (x, r, p, rs_new, it + 1)

    x, r, _, rs, it = jax.lax.while_loop(cond, body, state0)
    # Report the TRUE final residual ||b - Ax|| / ||b||, not the recursively
    # updated one: on ill-conditioned systems the recursion drifts (it can
    # report convergence the solution never reached).
    r_true = b - A(x)
    return CGResult(x=x, iters=it,
                    rel_residual=jnp.sqrt(_dot(r_true, r_true)) / safe_b_norm)


def pcg_solve(A: Callable, b: jnp.ndarray, M_inv: Callable,
              tol: float = 0.01, max_iters: int = 10_000) -> CGResult:
    """Preconditioned CG on packed vectors (..., N).

    ``M_inv`` approximates A^{-1} (see core.precond for the pivoted-Cholesky
    preconditioner). The stopping rule monitors the unpreconditioned
    (recursively updated) residual, matching cg_solve; the *reported*
    ``rel_residual`` is the true final residual ``||b - Ax|| / ||b||``.
    """
    x0 = jnp.zeros_like(b)
    b_norm = jnp.sqrt(jnp.sum(b * b, axis=-1))
    safe = jnp.where(b_norm == 0, 1.0, b_norm)
    r0 = b - A(x0)
    z0 = M_inv(r0)
    rz0 = jnp.sum(r0 * z0, axis=-1)

    def cond(state):
        _, r, _, _, _, it = state
        rel = jnp.sqrt(jnp.sum(r * r, axis=-1)) / safe
        return jnp.logical_and(jnp.max(rel) > tol, it < max_iters)

    def body(state):
        x, r, z, p, rz, it = state
        Ap = A(p)
        pAp = jnp.sum(p * Ap, axis=-1)
        alpha = jnp.where(pAp > 0, rz / jnp.where(pAp == 0, 1.0, pAp), 0.0)
        x = x + alpha[..., None] * p
        r = r - alpha[..., None] * Ap
        z = M_inv(r)
        rz_new = jnp.sum(r * z, axis=-1)
        beta = rz_new / jnp.where(rz == 0, 1.0, rz)
        p = z + beta[..., None] * p
        return (x, r, z, p, rz_new, it + 1)

    x, r, _, _, _, it = jax.lax.while_loop(cond, body,
                                           (x0, r0, z0, z0, rz0, jnp.int32(0)))
    r_true = b - A(x)
    rel = jnp.sqrt(jnp.sum(r_true * r_true, axis=-1)) / safe
    return CGResult(x=x, iters=it, rel_residual=rel)
