"""Stationary GP kernel functions (pure jnp, dtype-polymorphic).

The paper's model (App. B) uses an RBF-ARD kernel over hyper-parameters x
(one lengthscale per dimension, unit variance) and a Matern-1/2 kernel over
the learning-curve progression t (scalar lengthscale, scalar outputscale).
We additionally provide Matern-3/2 and Matern-5/2 for ablations.

All functions take raw (unconstrained, log-space) parameters already
transformed to their positive values by the caller.
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "sq_dist",
    "abs_dist",
    "rbf_ard",
    "matern12",
    "matern32",
    "matern52",
    "KERNELS_1D",
]


def sq_dist(x1: jnp.ndarray, x2: jnp.ndarray) -> jnp.ndarray:
    """Pairwise squared Euclidean distance.

    x1: (n, d), x2: (p, d) -> (n, p). Uses the matmul expansion so the
    contraction runs on the MXU; clamps tiny negatives from cancellation.
    """
    n1 = jnp.sum(x1 * x1, axis=-1)[:, None]
    n2 = jnp.sum(x2 * x2, axis=-1)[None, :]
    d2 = n1 + n2 - 2.0 * (x1 @ x2.T)
    return jnp.maximum(d2, 0.0)


def abs_dist(t1: jnp.ndarray, t2: jnp.ndarray) -> jnp.ndarray:
    """Pairwise absolute distance for 1-D inputs. t1: (n,), t2: (p,) -> (n, p)."""
    return jnp.abs(t1[:, None] - t2[None, :])


def rbf_ard(x1: jnp.ndarray, x2: jnp.ndarray, lengthscale: jnp.ndarray,
            outputscale=1.0) -> jnp.ndarray:
    """RBF kernel with per-dimension lengthscales.

    k(x, x') = outputscale * exp(-0.5 * sum_d ((x_d - x'_d) / l_d)^2)
    """
    z1 = x1 / lengthscale
    z2 = x2 / lengthscale
    return outputscale * jnp.exp(-0.5 * sq_dist(z1, z2))


def matern12(t1: jnp.ndarray, t2: jnp.ndarray, lengthscale, outputscale=1.0) -> jnp.ndarray:
    """Matern-1/2 (exponential / Ornstein-Uhlenbeck) kernel on 1-D inputs."""
    r = abs_dist(t1, t2) / lengthscale
    return outputscale * jnp.exp(-r)


def matern32(t1: jnp.ndarray, t2: jnp.ndarray, lengthscale, outputscale=1.0) -> jnp.ndarray:
    r = abs_dist(t1, t2) * (jnp.sqrt(3.0) / lengthscale)
    return outputscale * (1.0 + r) * jnp.exp(-r)


def matern52(t1: jnp.ndarray, t2: jnp.ndarray, lengthscale, outputscale=1.0) -> jnp.ndarray:
    r = abs_dist(t1, t2) * (jnp.sqrt(5.0) / lengthscale)
    return outputscale * (1.0 + r + r * r / 3.0) * jnp.exp(-r)


KERNELS_1D = {
    "matern12": matern12,
    "matern32": matern32,
    "matern52": matern52,
}
