"""Core library: the paper's contribution (Latent Kronecker GP)."""
from .cg import CGResult, cg_solve
from .gp_kernels import KERNELS_1D, matern12, matern32, matern52, rbf_ard
from .lbfgs import LBFGSResult, lbfgs_minimize
from .lkgp import (LKGP, LKGPConfig, LKGPParams, gram_matrices, init_params,
                   log_prior, make_mll_iterative, mll_cholesky)
from .matheron import sample_posterior_grid
from .mvm import (grid_to_packed, joint_cov_packed, kron_dense, lk_mvm,
                  lk_operator, packed_to_grid)
from .priors import noise_prior_logpdf, x_lengthscale_prior_logpdf
from .slq import lanczos, rademacher_probes, slq_logdet
from .transforms import TTransform, XTransform, YTransform

__all__ = [
    "CGResult", "cg_solve", "KERNELS_1D", "matern12", "matern32", "matern52",
    "rbf_ard", "LBFGSResult", "lbfgs_minimize", "LKGP", "LKGPConfig",
    "LKGPParams", "gram_matrices", "init_params", "log_prior",
    "make_mll_iterative", "mll_cholesky", "sample_posterior_grid",
    "grid_to_packed", "joint_cov_packed", "kron_dense", "lk_mvm",
    "lk_operator", "packed_to_grid", "noise_prior_logpdf",
    "x_lengthscale_prior_logpdf", "lanczos", "rademacher_probes",
    "slq_logdet", "TTransform", "XTransform", "YTransform",
]
