"""Core library: the paper's contribution (Latent Kronecker GP).

Layered as state -> engines -> posterior:

* :mod:`~repro.core.state` — immutable :class:`LKGPState` pytree and the
  functional API (``fit``, ``fit_batch``, ``extend``, ``refit``);
* :mod:`~repro.core.engines` — the :class:`InferenceEngine` protocol and
  registry of backends (``dense`` / ``iterative`` / ``pallas`` /
  ``distributed``) selected by ``LKGPConfig.backend``;
* :mod:`~repro.core.posterior` — lazy :class:`Posterior` with a cached
  ``K^{-1} y`` shared between the exact mean and Matheron samples;
* :mod:`~repro.core.lkgp` — the legacy :class:`LKGP` facade.

Supporting numerics: the pluggable solver stack — grid-form CG/PCG/SGD
(:mod:`~repro.core.solvers`, with ``LKGPConfig.solver`` selecting the
strategy; :mod:`~repro.core.cg` remains as a deprecation shim), stochastic
Lanczos quadrature (:mod:`~repro.core.slq`), the latent-Kronecker MVM
(:mod:`~repro.core.mvm`), Matheron sampling, transforms, and priors.
"""
from .caching import LRUCache
from .engines import (ENGINES, CustomMVMEngine, DenseEngine,
                      DistributedEngine, InferenceEngine, IterativeEngine,
                      LatentKroneckerOperator, PallasEngine,
                      StackedSolveResult, engine_cache_stats, get_engine,
                      list_backends, make_mll, make_mll_iterative,
                      mll_cholesky, register_engine)
from .gp_kernels import KERNELS_1D, matern12, matern32, matern52, rbf_ard
from .lbfgs import LBFGSResult, lbfgs_minimize
from .lkgp import LKGP
from .matheron import (kronecker_correction, prior_residual_draws,
                       sample_posterior_grid)
from .mvm import (grid_to_packed, joint_cov_packed, kron_dense, lk_mvm,
                  lk_operator, packed_to_grid)
from .posterior import (BatchedPosterior, Posterior, PosteriorLike,
                        joint_grams, posterior, posterior_batch)
from .precond import (pivoted_cholesky_grid, pivoted_cholesky_latent,
                      woodbury_preconditioner)
from .priors import noise_prior_logpdf, x_lengthscale_prior_logpdf
from .slq import (lanczos, rademacher_probes, slq_logdet,
                  slq_logdet_from_tridiag, tridiag_from_cg)
from .errors import ObservationError, check_grid_columns, check_observed_finite
from .solvers import (SOLVE_POLICIES, SOLVERS, CGResult, CGTridiag,
                      EscalationStep, GuardedSolveError, GuardedSolver,
                      Solver, cg_solve, cg_solve_tridiag, escalation_tally,
                      get_solver, guarded_solve, guarded_solve_stacked,
                      list_solvers, pcg_solve, register_solver,
                      resolve_solver, sgd_solve)
from .polish import PolishResult, make_polish
from .state import (FitResult, GPData, LKGPConfig, LKGPParams, LKGPState,
                    compiled_cache_stats, extend, fit, fit_batch,
                    gram_matrices, init_params, log_prior, refit,
                    resolve_backend, stack_states, unstack)
from .transforms import TTransform, XTransform, YTransform

__all__ = [
    # solvers / numerics
    "CGResult", "CGTridiag", "cg_solve", "cg_solve_tridiag", "pcg_solve",
    "sgd_solve", "Solver", "SOLVERS", "get_solver", "register_solver",
    "list_solvers", "resolve_solver",
    # reliability: guarded solves + typed input errors
    "GuardedSolver", "GuardedSolveError", "EscalationStep", "SOLVE_POLICIES",
    "guarded_solve", "guarded_solve_stacked", "escalation_tally",
    "ObservationError", "check_observed_finite", "check_grid_columns",
    "KERNELS_1D", "matern12", "matern32",
    "matern52", "rbf_ard", "LBFGSResult", "lbfgs_minimize",
    "sample_posterior_grid", "prior_residual_draws", "kronecker_correction",
    "grid_to_packed", "joint_cov_packed",
    "kron_dense", "lk_mvm", "lk_operator", "packed_to_grid",
    "noise_prior_logpdf", "x_lengthscale_prior_logpdf", "lanczos",
    "rademacher_probes", "slq_logdet", "slq_logdet_from_tridiag",
    "tridiag_from_cg", "TTransform", "XTransform",
    "YTransform", "pivoted_cholesky_grid", "pivoted_cholesky_latent",
    "woodbury_preconditioner",
    # state + functional API
    "LKGPState", "GPData", "LKGPConfig", "LKGPParams", "FitResult", "fit",
    "fit_batch", "extend", "refit", "unstack", "stack_states",
    "resolve_backend", "gram_matrices", "init_params", "log_prior",
    # fixed-budget polish + cache instrumentation
    "PolishResult", "make_polish", "LRUCache", "compiled_cache_stats",
    "engine_cache_stats",
    # engines
    "InferenceEngine", "ENGINES", "get_engine", "register_engine",
    "list_backends", "DenseEngine", "IterativeEngine", "PallasEngine",
    "DistributedEngine", "CustomMVMEngine", "LatentKroneckerOperator",
    "StackedSolveResult", "make_mll", "make_mll_iterative", "mll_cholesky",
    # posterior + facade
    "PosteriorLike", "Posterior", "posterior", "joint_grams", "LKGP",
    "BatchedPosterior", "posterior_batch",
]
