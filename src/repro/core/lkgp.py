"""Latent Kronecker Gaussian Process (LKGP) — backward-compatible facade.

Model (paper App. B):
  f ~ GP(0, k1(x, x') * k2(t, t')),
  k1 = RBF-ARD over hyper-parameters (unit variance, per-dim lengthscale),
  k2 = Matern-1/2 over progression (scalar lengthscale + outputscale),
  homoskedastic Gaussian noise sigma^2; 10 raw parameters for d = 7.

The model layer proper lives in three sibling modules:

* :mod:`repro.core.state`     — immutable :class:`LKGPState` + functional
  ``fit`` / ``fit_batch`` / ``extend`` / ``refit``;
* :mod:`repro.core.engines`   — pluggable inference backends
  (dense / iterative / pallas / distributed) behind ``LKGPConfig.backend``;
* :mod:`repro.core.posterior` — lazy :class:`Posterior` with a cached
  ``K^{-1} y`` solve shared between the mean and Matheron samples.

This module re-exports all of that and keeps the original mutable
:class:`LKGP` class as a thin wrapper for existing call sites. The wrapper
is DEPRECATED (constructing one warns): it predates the immutable-state
design, so it cannot participate in the state-keyed posterior cache or the
serving layer's coalescing, both of which key on :class:`LKGPState`
identity. Use the functional API::

    state = fit(X, t, Y, mask, LKGPConfig(backend="iterative"))
    post = posterior(state)
    mean, var = post.final()

Migration is mechanical — see the README's "Migrating off the LKGP
facade" section: ``LKGP(cfg).fit(...)`` -> ``fit(..., cfg)``;
``model.posterior(Xs)`` -> ``posterior(state, Xs)``;
``model.predict_final()`` -> ``posterior(state).final()``;
``model.params`` / transforms live on the state.
"""
from __future__ import annotations

import warnings
from typing import Any

# Re-exports: the historical public surface of this module.
from .engines import (CustomMVMEngine, DenseEngine, DistributedEngine,
                      InferenceEngine, IterativeEngine, PallasEngine,
                      get_engine, list_backends, make_mll, make_mll_iterative,
                      mll_cholesky, register_engine)
from .posterior import Posterior, joint_grams, posterior
from .state import (GPData, LKGPConfig, LKGPParams, LKGPState, extend, fit,
                    fit_batch, gram_matrices, init_params, log_prior, refit,
                    resolve_backend, unstack)

__all__ = ["LKGPConfig", "LKGPParams", "LKGP", "LKGPState", "GPData",
           "init_params", "gram_matrices", "mll_cholesky",
           "make_mll_iterative", "make_mll", "log_prior", "fit", "fit_batch",
           "extend", "refit", "unstack", "resolve_backend", "Posterior",
           "posterior", "joint_grams", "InferenceEngine", "get_engine",
           "register_engine", "list_backends", "DenseEngine",
           "IterativeEngine", "PallasEngine", "DistributedEngine",
           "CustomMVMEngine"]

# Legacy names for the backends as reported by ``mll_method_used``.
_LEGACY_METHOD = {"dense": "cholesky"}


class LKGP:
    """User-facing model: fit on partial curves, predict continuations.

    X: (n, d) raw hyper-parameters; t: (m,) raw progressions (e.g. epochs,
    1-indexed); Y: (n, m) metric values; mask: (n, m) 1.0 where observed.
    All data is transformed per App. B before entering the GP.

    Thin facade over the functional API: ``fit`` stores an immutable
    :class:`LKGPState` in ``self.state``; inference delegates to
    :class:`Posterior`.
    """

    def __init__(self, config: LKGPConfig | None = None):
        warnings.warn(
            "LKGP is deprecated; use the functional API (fit / posterior "
            "from repro.core) — see the README migration notes. The facade "
            "bypasses the state-keyed posterior cache and the serving "
            "layer's request coalescing.",
            DeprecationWarning, stacklevel=2)
        self.config = config if config is not None else LKGPConfig()
        self.state: LKGPState | None = None
        self.fit_result: Any = None
        self.mll_method_used: str | None = None

    # -- fitting ----------------------------------------------------------
    def fit(self, X, t, Y, mask, params0: LKGPParams | None = None) -> "LKGP":
        self.state = fit(X, t, Y, mask, self.config, params0=params0)
        self.fit_result = getattr(self.state, "fit_result", None)
        backend = getattr(self.state, "backend_used", None)
        self.mll_method_used = _LEGACY_METHOD.get(backend, backend)
        return self

    # -- fitted-state accessors (legacy attribute surface) ----------------
    @property
    def params(self):
        return self.state.params if self.state is not None else None

    @property
    def x_tf(self):
        return self.state.x_tf if self.state is not None else None

    @property
    def t_tf(self):
        return self.state.t_tf if self.state is not None else None

    @property
    def y_tf(self):
        return self.state.y_tf if self.state is not None else None

    @property
    def _X(self):
        return None if self.state is None else self.state.x_tf(self.state.X)

    @property
    def _t(self):
        return None if self.state is None else self.state.t_tf(self.state.t)

    @property
    def _Y(self):
        return None if self.state is None else self.state.y_tf(self.state.Y)

    @property
    def _mask(self):
        return None if self.state is None else self.state.mask

    def _grams(self, Xs=None):
        return joint_grams(self.state, Xs)

    # -- inference --------------------------------------------------------
    def posterior(self, Xs=None) -> Posterior:
        """Lazy posterior (optionally over additional test configs Xs)."""
        return Posterior(self.state, Xs=Xs)

    def posterior_mean(self, Xs=None):
        """Exact posterior mean over the full grid, original y units."""
        return self.posterior(Xs).mean

    def posterior_samples(self, key, Xs=None, n_samples: int | None = None):
        """Matheron-rule posterior samples, original y units: (s, n(+n*), m)."""
        return self.posterior(Xs).samples(key, n_samples)

    def predict_final(self, key=None, n_samples: int | None = None):
        """(mean, var) of the final-progression value per training config."""
        return self.posterior().final(key=key, n_samples=n_samples)
