"""Latent Kronecker Gaussian Process (LKGP) — the paper's model.

Model (paper App. B):
  f ~ GP(0, k1(x, x') * k2(t, t')),
  k1 = RBF-ARD over hyper-parameters (unit variance, per-dim lengthscale),
  k2 = Matern-1/2 over progression (scalar lengthscale + outputscale),
  homoskedastic Gaussian noise sigma^2; 10 raw parameters for d = 7.

Two marginal-likelihood paths:
  * "cholesky"  — exact, O(N^3): the paper's naive baseline. Implemented
    with a dynamic-mask trick (unobserved rows/cols zeroed, unit diagonal)
    so it stays jittable; equals the packed-submatrix MLL exactly.
  * "iterative" — the paper's method: batched CG solves + stochastic Lanczos
    quadrature for the log-det, with gradients via the quadratic-form trick
    (Gardner et al., 2018), O(n^2 m + n m^2) per MVM.

Fitting maximises (MLL + log prior) / N with L-BFGS on log-space parameters.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from functools import partial
from typing import NamedTuple

import jax
import jax.flatten_util
import jax.numpy as jnp
import numpy as np

from . import gp_kernels as gk
from .cg import cg_solve
from .lbfgs import lbfgs_minimize
from .matheron import sample_posterior_grid
from .mvm import kron_dense, lk_operator
from .priors import noise_prior_logpdf, x_lengthscale_prior_logpdf
from .slq import rademacher_probes, slq_logdet
from .transforms import TTransform, XTransform, YTransform

__all__ = ["LKGPConfig", "LKGPParams", "LKGP", "init_params", "gram_matrices",
           "mll_cholesky", "make_mll_iterative", "log_prior"]

_LOG_2PI = math.log(2.0 * math.pi)


class LKGPParams(NamedTuple):
    """Raw (log-space) parameters; positive values are exp(raw)."""
    raw_x_lengthscale: jnp.ndarray  # (d,)
    raw_t_lengthscale: jnp.ndarray  # ()
    raw_outputscale: jnp.ndarray    # ()
    raw_noise: jnp.ndarray          # ()


@dataclass(frozen=True)
class LKGPConfig:
    t_kernel: str = "matern12"
    mll_method: str = "auto"        # "cholesky" | "iterative" | "auto"
    auto_cholesky_max: int = 800    # N_obs threshold for "auto"
    cg_tol: float = 0.01            # paper App. B
    cg_max_iters: int = 10_000      # paper App. B
    slq_probes: int = 16
    slq_iters: int = 25
    jitter: float = 1e-6
    lbfgs_iters: int = 100
    posterior_samples: int = 64
    seed: int = 0
    use_pallas: bool = False        # route MVMs through the Pallas TPU kernel


def init_params(d: int, dtype=jnp.float64) -> LKGPParams:
    """Initialise at prior means / paper defaults."""
    return LKGPParams(
        raw_x_lengthscale=jnp.full((d,), math.sqrt(2.0) + 0.5 * math.log(d), dtype),
        raw_t_lengthscale=jnp.asarray(math.log(0.25), dtype),
        raw_outputscale=jnp.asarray(0.0, dtype),
        raw_noise=jnp.asarray(-4.0, dtype),
    )


def gram_matrices(params: LKGPParams, X: jnp.ndarray, t: jnp.ndarray,
                  t_kernel: str = "matern12", jitter: float = 1e-6):
    """K1 (n, n) over configs and K2 (m, m) over progressions (jittered)."""
    k2fn = gk.KERNELS_1D[t_kernel]
    K1 = gk.rbf_ard(X, X, jnp.exp(params.raw_x_lengthscale))
    K2 = k2fn(t, t, jnp.exp(params.raw_t_lengthscale),
              jnp.exp(params.raw_outputscale))
    K1 = K1 + jitter * jnp.eye(X.shape[0], dtype=K1.dtype)
    K2 = K2 + jitter * jnp.eye(t.shape[0], dtype=K2.dtype)
    return K1, K2


def log_prior(params: LKGPParams, d: int) -> jnp.ndarray:
    return (x_lengthscale_prior_logpdf(params.raw_x_lengthscale, d)
            + noise_prior_logpdf(params.raw_noise))


def mll_cholesky(params: LKGPParams, X, t, Y, mask, t_kernel: str = "matern12",
                 jitter: float = 1e-6) -> jnp.ndarray:
    """Exact MLL of the observed block — the paper's NAIVE baseline.

    O(n^3 m^3) time / O(n^2 m^2) space. Dynamic-mask construction: the full
    (nm x nm) joint covariance has unobserved rows/cols zeroed and a unit
    diagonal placed on unobserved cells, so its Cholesky factorisation
    reproduces the observed-block log-det and solve exactly while remaining
    jittable (no data-dependent shapes).
    """
    K1, K2 = gram_matrices(params, X, t, t_kernel, jitter)
    noise = jnp.exp(params.raw_noise)
    mv = mask.reshape(-1)
    y = (Y * mask).reshape(-1)
    K = kron_dense(K1, K2) * (mv[:, None] * mv[None, :])
    K = K + jnp.diag(noise * mv + (1.0 - mv))
    L = jnp.linalg.cholesky(K)
    alpha = jax.scipy.linalg.cho_solve((L, True), y)
    N = jnp.sum(mask)
    logdet = 2.0 * jnp.sum(jnp.log(jnp.diag(L)) * 1.0)  # unobserved diag = 1 -> log 0
    return -0.5 * jnp.dot(y, alpha) - 0.5 * logdet - 0.5 * N * _LOG_2PI


def make_mll_iterative(cfg: LKGPConfig, mvm_impl=None):
    """Iterative MLL with custom VJP (quadratic-form gradient trick).

    Returns ``mll(params, X, t, Y, mask, probes)``. ``probes`` are fixed
    Rademacher vectors in the observed subspace, shared between the SLQ
    log-det estimate and the stochastic trace gradients; fixing them makes
    the objective deterministic, which the L-BFGS line search requires.
    """

    def _operator(params, X, t, mask):
        K1, K2 = gram_matrices(params, X, t, cfg.t_kernel, cfg.jitter)
        noise = jnp.exp(params.raw_noise)
        if mvm_impl is not None:
            return partial(mvm_impl, K1, K2, mask, noise=noise)
        return lk_operator(K1, K2, mask, noise)

    @jax.custom_vjp
    def mll(params, X, t, Y, mask, probes):
        value, _ = _fwd(params, X, t, Y, mask, probes)
        return value

    def _fwd(params, X, t, Y, mask, probes):
        A = _operator(params, X, t, mask)
        Ym = Y * mask
        rhs = jnp.concatenate([Ym[None], probes], axis=0)
        sol = cg_solve(A, rhs, tol=cfg.cg_tol, max_iters=cfg.cg_max_iters).x
        alpha, W = sol[0], sol[1:]
        N = jnp.sum(mask)
        logdet = slq_logdet(A, probes, cfg.slq_iters, N)
        value = -0.5 * jnp.sum(Ym * alpha) - 0.5 * logdet - 0.5 * N * _LOG_2PI
        return value, (params, X, t, mask, alpha, W, probes)

    def _bwd(res, gbar):
        params, X, t, mask, alpha, W, probes = res
        p = probes.shape[0]

        def h(pp):
            A = _operator(pp, X, t, mask)
            quad_alpha = jnp.sum(alpha * A(alpha))
            quad_tr = jnp.sum(W * A(probes)) / p
            return 0.5 * quad_alpha - 0.5 * quad_tr

        gparams = jax.grad(h)(params)
        gparams = jax.tree_util.tree_map(lambda g: gbar * g, gparams)
        zeros = lambda a: jnp.zeros_like(a)
        return (gparams, zeros(X), zeros(t), jnp.zeros(mask.shape, X.dtype),
                zeros(mask), zeros(probes))

    mll.defvjp(_fwd, _bwd)
    return mll


@dataclass
class LKGP:
    """User-facing model: fit on partial curves, predict continuations.

    X: (n, d) raw hyper-parameters; t: (m,) raw progressions (e.g. epochs,
    1-indexed); Y: (n, m) metric values; mask: (n, m) 1.0 where observed.
    All data is transformed per App. B before entering the GP.
    """
    config: LKGPConfig = field(default_factory=LKGPConfig)

    # fitted state
    params: LKGPParams | None = None
    x_tf: XTransform | None = None
    t_tf: TTransform | None = None
    y_tf: YTransform | None = None
    _X: jnp.ndarray | None = None
    _t: jnp.ndarray | None = None
    _Y: jnp.ndarray | None = None
    _mask: jnp.ndarray | None = None
    fit_result: object | None = None

    # -- fitting ----------------------------------------------------------
    def fit(self, X, t, Y, mask, params0: LKGPParams | None = None) -> "LKGP":
        cfg = self.config
        X = jnp.asarray(X)
        dtype = X.dtype
        t = jnp.asarray(t, dtype)
        Y = jnp.asarray(Y, dtype)
        mask = jnp.asarray(mask, dtype)

        self.x_tf = XTransform.fit(X)
        self.t_tf = TTransform.fit(t)
        self.y_tf = YTransform.fit(Y, mask)
        Xn, tn, Yn = self.x_tf(X), self.t_tf(t), self.y_tf(Y)
        self._X, self._t, self._Y, self._mask = Xn, tn, Yn, mask

        d = X.shape[1]
        n_obs = int(np.sum(np.asarray(mask)))
        method = cfg.mll_method
        if method == "auto":
            method = "cholesky" if n_obs <= cfg.auto_cholesky_max else "iterative"
        self.mll_method_used = method

        if method == "cholesky":
            def objective(p):
                mll = mll_cholesky(p, Xn, tn, Yn, mask, cfg.t_kernel, cfg.jitter)
                return -(mll + log_prior(p, d)) / n_obs
        else:
            key = jax.random.PRNGKey(cfg.seed)
            probes = rademacher_probes(key, cfg.slq_probes, mask, dtype)
            mll_fn = make_mll_iterative(cfg)

            def objective(p):
                mll = mll_fn(p, Xn, tn, Yn, mask, probes)
                return -(mll + log_prior(p, d)) / n_obs

        vg = jax.jit(jax.value_and_grad(objective))
        p0 = params0 if params0 is not None else init_params(d, dtype)
        flat0, unravel = jax.flatten_util.ravel_pytree(p0)

        def value_and_grad(x):
            f, g = vg(unravel(x.astype(dtype)))
            return f, jax.flatten_util.ravel_pytree(g)[0]

        res = lbfgs_minimize(value_and_grad, np.asarray(flat0, np.float64),
                             max_iters=cfg.lbfgs_iters)
        self.params = unravel(jnp.asarray(res.x, dtype))
        self.fit_result = res
        return self

    # -- inference --------------------------------------------------------
    def _grams(self, Xs=None):
        cfg = self.config
        K2 = gk.KERNELS_1D[cfg.t_kernel](
            self._t, self._t, jnp.exp(self.params.raw_t_lengthscale),
            jnp.exp(self.params.raw_outputscale))
        K2 = K2 + cfg.jitter * jnp.eye(self._t.shape[0], dtype=K2.dtype)
        ls = jnp.exp(self.params.raw_x_lengthscale)
        if Xs is None:
            Xa = self._X
        else:
            Xa = jnp.concatenate([self._X, self.x_tf(jnp.asarray(Xs, self._X.dtype))], 0)
        K1a = gk.rbf_ard(Xa, Xa, ls)
        return K1a, K2

    def posterior_mean(self, Xs=None) -> jnp.ndarray:
        """Exact posterior mean over the full grid, original y units.

        Rows [:n] are curve continuations for training configs; if Xs is
        given, rows [n:] are predictions for new configs.
        """
        cfg = self.config
        K1a, K2 = self._grams(Xs)
        n = self._X.shape[0]
        noise = jnp.exp(self.params.raw_noise)
        A = lk_operator(K1a[:n, :n], K2, self._mask, noise)
        alpha = cg_solve(A, self._Y * self._mask, tol=cfg.cg_tol,
                         max_iters=cfg.cg_max_iters).x
        mean = jnp.einsum("aj,jm,mk->ak", K1a[:, :n], alpha, K2)
        return self.y_tf.inverse(mean)

    def posterior_samples(self, key, Xs=None, n_samples: int | None = None) -> jnp.ndarray:
        """Matheron-rule posterior samples, original y units: (s, n(+n*), m)."""
        cfg = self.config
        n_samples = n_samples or cfg.posterior_samples
        K1a, K2 = self._grams(Xs)
        n = self._X.shape[0]
        noise = jnp.exp(self.params.raw_noise)
        samples = sample_posterior_grid(
            key, K1a, K2, n, self._Y, self._mask, noise, n_samples,
            cg_tol=cfg.cg_tol, cg_max_iters=cfg.cg_max_iters, jitter=cfg.jitter)
        return self.y_tf.inverse(samples)

    def predict_final(self, key=None, n_samples: int | None = None):
        """(mean, var) of the final-progression value per training config.

        Mean is exact (CG); variance is estimated from Matheron samples plus
        observation noise — the Fig. 4 protocol (predict final validation
        accuracy from partial curves, scored by MSE and log-likelihood).
        """
        if key is None:
            key = jax.random.PRNGKey(self.config.seed + 1)
        mean = self.posterior_mean()[:, -1]
        s = self.posterior_samples(key, n_samples=n_samples)[:, :, -1]
        var_f = jnp.var(s, axis=0)
        var_y = var_f + self.y_tf.inverse_var(jnp.exp(self.params.raw_noise))
        return mean, var_y
