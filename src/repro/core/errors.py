"""Typed error surface for the reliability layer.

The streaming boundary (``fit`` / ``extend`` /
``PredictionService.observe``) validates payloads eagerly on the host and
rejects bad ones with :class:`ObservationError` — a ``ValueError`` subclass
so legacy ``except ValueError`` callers keep working — carrying the
offending indices so the serving layer can log *which* cells were bad
without re-deriving them. Solver-side failures escalate through
:mod:`repro.core.solvers.guarded` and surface as
:class:`~repro.core.solvers.guarded.GuardedSolveError`.
"""
from __future__ import annotations

import numpy as np

__all__ = ["ObservationError", "check_observed_finite", "check_grid_columns"]

_MAX_NAMED = 8   # cap on indices spelled out in an error message


class ObservationError(ValueError):
    """A streamed observation payload is invalid.

    ``indices`` names the offending cells/columns (possibly truncated in
    the message, never in the attribute).
    """

    def __init__(self, message: str, indices=()):
        super().__init__(message)
        self.indices = tuple(map(tuple, indices)) if np.ndim(indices) > 1 \
            else tuple(indices)


def _named(indices) -> str:
    shown = list(indices[:_MAX_NAMED])
    more = len(indices) - len(shown)
    return f"{shown}" + (f" (+{more} more)" if more > 0 else "")


def check_observed_finite(Y, mask, what: str = "Y") -> None:
    """Raise :class:`ObservationError` on non-finite values at observed cells.

    Unobserved cells may hold anything (they are masked out of every
    product); observed cells must be finite or the solve/transform chain
    silently propagates NaNs into every tenant product derived from them.
    """
    Y = np.asarray(Y)
    mask = np.asarray(mask)
    bad = np.logical_and(mask > 0, ~np.isfinite(Y))
    if np.any(bad):
        cells = np.argwhere(bad)
        raise ObservationError(
            f"non-finite {what} at {int(cells.shape[0])} observed "
            f"cell(s): {_named([tuple(map(int, c)) for c in cells])}",
            indices=[tuple(map(int, c)) for c in cells])


def check_grid_columns(mask, m: int, what: str = "mask") -> None:
    """Reject masks marking cells outside the budget grid ``t``.

    A mask wider than the session's ``m`` budgets that marks any column
    ``>= m`` refers to progression values the grid does not contain; name
    the offending column indices instead of failing later with an opaque
    broadcast/concatenate error (or, worse, silently truncating).
    """
    mask = np.asarray(mask)
    m_got = mask.shape[-1]
    if m_got == m:
        return
    if m_got > m:
        extra = mask[..., m:]
        marked = np.argwhere(np.any(extra > 0, axis=tuple(
            range(extra.ndim - 1)))) + m
        cols = [int(c) for c in marked.reshape(-1)]
        if cols:
            raise ObservationError(
                f"{what} marks observed cells outside the budget grid "
                f"(m={m}): columns {_named(cols)}", indices=cols)
    raise ObservationError(
        f"{what} has {m_got} budget columns but the session grid has "
        f"m={m}", indices=[])
