"""Latent-Kronecker matrix-vector multiplication (the paper's core primitive).

Representation
--------------
The latent grid is (n configs) x (m progressions). A vector v in the observed
subspace is stored in *grid* form: an (n, m) array that is zero at unobserved
cells (``mask`` is 1.0 where observed). The projection P of the paper is then
slice indexing (grid -> packed) and P^T is zero padding (packed -> grid);
neither is ever materialised.

With vec-row-major convention and U = unvec(v) of shape (n, m):

    (K1 (x) K2) vec(U) = vec(K1 @ U @ K2^T)

so the masked joint operator (K_joint + sigma^2 I) applied to a subspace
vector u is

    A(u) = mask * (K1 @ u @ K2) + sigma^2 * u          (K2 symmetric)

which maps the observed subspace to itself; CG run on grid-form vectors with
a masked RHS therefore never leaves the subspace.

Complexities: the MVM is O(n^2 m + n m^2) time and O(nm) space, matching
Section 2 of the paper.
"""
from __future__ import annotations

from functools import partial

import jax.numpy as jnp

__all__ = [
    "lk_mvm",
    "lk_operator",
    "packed_to_grid",
    "grid_to_packed",
    "kron_dense",
    "joint_cov_packed",
]


def lk_mvm(K1: jnp.ndarray, K2: jnp.ndarray, mask: jnp.ndarray,
           u: jnp.ndarray, noise: jnp.ndarray | float = 0.0) -> jnp.ndarray:
    """Apply A(u) = mask * (K1 @ (mask*u) @ K2) + noise * (mask*u).

    u may have leading batch dimensions: (..., n, m). The inner ``mask*u`` is
    a no-op for vectors already in the subspace but keeps the operator
    symmetric-PSD on the full grid space, which the iterative solvers rely on.
    """
    um = u * mask
    t = jnp.einsum("...nm,mk->...nk", um, K2)
    s = jnp.einsum("ij,...jm->...im", K1, t)
    return mask * s + noise * um


def lk_operator(K1, K2, mask, noise):
    """Partial application returning ``A(u)`` for the CG solver."""
    return partial(lk_mvm, K1, K2, mask, noise=noise)


def grid_to_packed(grid: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """P: select observed entries (static mask -> concrete indexing).

    Only used by the O(N^3) reference/naive paths; requires a concrete mask.
    """
    import numpy as np

    idx = np.flatnonzero(np.asarray(mask).ravel())
    return grid.reshape(*grid.shape[:-2], -1)[..., idx]


def packed_to_grid(packed: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """P^T: zero padding back onto the latent grid."""
    import numpy as np

    mask_np = np.asarray(mask)
    idx = np.flatnonzero(mask_np.ravel())
    flat = jnp.zeros((*packed.shape[:-1], mask_np.size), packed.dtype)
    flat = flat.at[..., idx].set(packed)
    return flat.reshape(*packed.shape[:-1], *mask_np.shape)


def kron_dense(K1: jnp.ndarray, K2: jnp.ndarray) -> jnp.ndarray:
    """Dense Kronecker product (naive baseline only; O(n^2 m^2) memory)."""
    n, m = K1.shape[0], K2.shape[0]
    return (K1[:, None, :, None] * K2[None, :, None, :]).reshape(n * m, n * m)


def joint_cov_packed(K1: jnp.ndarray, K2: jnp.ndarray, mask) -> jnp.ndarray:
    """K_joint = P (K1 (x) K2) P^T for the naive Cholesky baseline."""
    import numpy as np

    idx = np.flatnonzero(np.asarray(mask).ravel())
    full = kron_dense(K1, K2)
    return full[jnp.ix_(idx, idx)]
