"""Partial pivoted-Cholesky preconditioner for the latent-Kronecker CG.

Beyond-paper extension (the paper's App. B notes CG convergence depends on
conditioning; Lin et al. 2024b — cited therein — study solver improvements).
We build a rank-r pivoted Cholesky approximation L_r of the *latent* joint
covariance using the separable structure: entries of K1 (x) K2 are computed
lazily as K1[i1,j1]*K2[i2,j2] on observed cells only, so the factorisation
costs O(N r^2) time and O(N r) memory for N observed values, never
materialising the joint matrix. The preconditioner is the standard
woodbury-inverted (L_r L_r^T + sigma^2 I)^{-1} applied in O(N r) per CG
iteration — provably reducing the condition number to that of the residual
spectrum (Gardner et al. 2018).

Operates on packed (observed-only) vectors; `lkgp` wires it into CG via the
grid<->packed helpers when ``LKGPConfig.precond_rank > 0``.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

__all__ = ["pivoted_cholesky_latent", "woodbury_preconditioner"]


def pivoted_cholesky_latent(K1, K2, mask, rank: int, jitter: float = 1e-12):
    """Rank-``rank`` pivoted Cholesky of (P (K1xK2) P^T) via lazy entries.

    Returns L (N, rank) over the packed observed entries (numpy, float64 —
    this is a host-side setup cost, not a jitted inner loop).
    """
    K1 = np.asarray(K1, np.float64)
    K2 = np.asarray(K2, np.float64)
    mask_np = np.asarray(mask)
    rows, cols = np.nonzero(mask_np)
    N = len(rows)
    rank = min(rank, N)

    diag = K1[rows, rows] * K2[cols, cols]
    L = np.zeros((N, rank))
    perm = np.arange(N)
    d = diag.copy()

    for k in range(rank):
        # pivot: largest remaining diagonal
        j = k + int(np.argmax(d[perm[k:]]))
        perm[[k, j]] = perm[[j, k]]
        p = perm[k]
        pivot = d[p]
        if pivot <= jitter:
            L = L[:, :k]
            break
        lkk = np.sqrt(pivot)
        L[p, k] = lkk
        rest = perm[k + 1:]
        # lazy row of the joint covariance at the pivot
        row = K1[rows[rest], rows[p]] * K2[cols[rest], cols[p]]
        if k > 0:
            row = row - L[rest, :k] @ L[p, :k]
        L[rest, k] = row / lkk
        d[rest] = d[rest] - L[rest, k] ** 2
    return jnp.asarray(L)


def woodbury_preconditioner(L, noise):
    """M^{-1} v for M = L L^T + noise I, via Woodbury in O(N r).

    Returns a function on packed vectors (..., N):
    M^{-1} = I/s - L (s I_r + L^T L)^{-1} L^T / s^2,  s = noise.
    """
    import jax

    N, r = L.shape
    eye = jnp.eye(r, dtype=L.dtype)
    inner = noise * eye + L.T @ L            # (r, r), SPD
    chol = jnp.linalg.cholesky(inner)

    def apply(v):
        w = jnp.einsum("nr,...n->...r", L, v)
        z = jax.scipy.linalg.cho_solve((chol, True), w[..., None])[..., 0]
        return v / noise - jnp.einsum("nr,...r->...n", L, z) / noise

    return apply
