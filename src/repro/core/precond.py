"""Partial pivoted-Cholesky preconditioner for the latent-Kronecker CG.

Beyond-paper extension (the paper's App. B notes CG convergence depends on
conditioning; Lin et al. 2024b — cited therein — study solver improvements).
We build a rank-r pivoted Cholesky approximation L_r of the *latent* joint
covariance using the separable structure: entries of K1 (x) K2 are computed
lazily as K1[i1,j1]*K2[i2,j2] on observed cells only, so the factorisation
costs O(N r^2) time and O(N r) memory for N observed values, never
materialising the joint matrix. The preconditioner is the standard
woodbury-inverted (L_r L_r^T + sigma^2 I)^{-1} applied in O(N r) per CG
iteration — provably reducing the condition number to that of the residual
spectrum (Gardner et al. 2018).

Two factorisation entry points:

* :func:`pivoted_cholesky_latent` — host-side numpy over *packed* observed
  entries (needs a concrete mask; reference / offline use).
* :func:`pivoted_cholesky_grid` — pure-jax over flattened *grid* cells
  (unobserved cells carry a zero diagonal and are never pivoted), jittable
  with a traced mask; this is what the iterative/pallas engines use when
  ``LKGPConfig.precond_rank > 0``.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

__all__ = ["pivoted_cholesky_latent", "pivoted_cholesky_grid",
           "woodbury_preconditioner"]


def pivoted_cholesky_latent(K1, K2, mask, rank: int, jitter: float = 1e-12):
    """Rank-``rank`` pivoted Cholesky of (P (K1xK2) P^T) via lazy entries.

    Returns L (N, rank) over the packed observed entries (numpy, float64 —
    this is a host-side setup cost, not a jitted inner loop).
    """
    K1 = np.asarray(K1, np.float64)
    K2 = np.asarray(K2, np.float64)
    mask_np = np.asarray(mask)
    rows, cols = np.nonzero(mask_np)
    N = len(rows)
    rank = min(rank, N)

    diag = K1[rows, rows] * K2[cols, cols]
    L = np.zeros((N, rank))
    perm = np.arange(N)
    d = diag.copy()

    for k in range(rank):
        # pivot: largest remaining diagonal
        # Pivoted-Cholesky setup runs once on host numpy inputs; the
        # pivot index must be a Python int to permute in place.
        j = k + int(np.argmax(d[perm[k:]]))  # lint: disable=RA103
        perm[[k, j]] = perm[[j, k]]
        p = perm[k]
        pivot = d[p]
        if pivot <= jitter:
            L = L[:, :k]
            break
        lkk = np.sqrt(pivot)
        L[p, k] = lkk
        rest = perm[k + 1:]
        # lazy row of the joint covariance at the pivot
        row = K1[rows[rest], rows[p]] * K2[cols[rest], cols[p]]
        if k > 0:
            row = row - L[rest, :k] @ L[p, :k]
        L[rest, k] = row / lkk
        d[rest] = d[rest] - L[rest, k] ** 2
    return jnp.asarray(L)


def pivoted_cholesky_grid(K1, K2, mask, rank: int, jitter: float = 1e-12):
    """Rank-``rank`` pivoted Cholesky of the masked latent covariance, jittable.

    Works on the flattened (n*m,) grid: the diagonal of the masked joint
    covariance is ``mask * diag(K1) ⊗ diag(K2)``, so unobserved cells carry a
    zero diagonal, are never selected as pivots, and end up with all-zero rows
    in L — exactly the projected operator the CG solve sees. Each pivot's
    covariance row is formed lazily from the Kronecker factors
    (``mask ⊙ K1[:, j1] K2[:, j2]^T``), O(nm) per step, O(nm r^2) total.

    Returns L of shape (n*m, rank). Pure jax (lax.fori_loop + dynamic
    argmax pivoting), so it can live inside the jitted MLL objective where
    the mask is a tracer. If the residual diagonal is exhausted before
    ``rank`` steps the remaining columns are zero (harmless in Woodbury).
    """
    K1 = jnp.asarray(K1)
    K2 = jnp.asarray(K2)
    mask = jnp.asarray(mask, K1.dtype)
    n, m = mask.shape
    N = n * m
    diag = (mask * (jnp.diag(K1)[:, None] * jnp.diag(K2)[None, :])).reshape(N)
    mask_flat = mask.reshape(N)

    def body(k, carry):
        L, d, done = carry
        dm = jnp.where(done, -jnp.inf, d)
        j = jnp.argmax(dm)
        pivot = dm[j]
        valid = pivot > jitter
        lkk = jnp.sqrt(jnp.maximum(pivot, jitter))
        j1, j2 = j // m, j % m
        row = (mask * (K1[:, j1][:, None] * K2[:, j2][None, :])).reshape(N)
        row = row - L @ L[j]
        col = jnp.where(done, 0.0, row / lkk).at[j].set(lkk)
        col = jnp.where(valid, col * mask_flat, jnp.zeros_like(col))
        L = L.at[:, k].set(col)
        d = jnp.maximum(d - col * col, 0.0)
        done = done.at[j].set(True)
        return L, d, done

    L0 = jnp.zeros((N, rank), K1.dtype)
    done0 = jnp.zeros((N,), bool)
    L, _, _ = jax.lax.fori_loop(0, rank, body, (L0, diag, done0))
    return L


def woodbury_preconditioner(L, noise):
    """M^{-1} v for M = L L^T + noise I, via Woodbury in O(N r).

    Returns a function on packed vectors (..., N):
    M^{-1} = I/s - L (s I_r + L^T L)^{-1} L^T / s^2,  s = noise.
    """
    import jax

    N, r = L.shape
    eye = jnp.eye(r, dtype=L.dtype)
    inner = noise * eye + L.T @ L            # (r, r), SPD
    chol = jnp.linalg.cholesky(inner)

    def apply(v):
        w = jnp.einsum("nr,...n->...r", L, v)
        # cho_solve wants matching batch dims; fold leading dims into the
        # column axis instead so one (r, r) factor serves every RHS.
        wf = w.reshape(-1, r)
        z = jax.scipy.linalg.cho_solve((chol, True), wf.T).T.reshape(w.shape)
        return v / noise - jnp.einsum("nr,...r->...n", L, z) / noise

    return apply
