"""Fixed-budget pure-JAX L-BFGS "polish" for warm-started hyper-parameters.

:func:`repro.core.lbfgs.lbfgs_minimize` is a host-driven loop: every
objective evaluation is a blocking device call, and for the small
per-task MLL problems the schedulers and the serving layer refit each
round, dispatch latency — not linear algebra — dominates refit
wall-clock. When the starting point is already good (an amortized
prediction from :mod:`repro.amortize`, or the previous round's optimum),
a handful of L-BFGS steps suffice, and those steps can run entirely on
device: :func:`make_polish` builds the whole optimizer — two-loop
recursion over fixed-size history buffers, Armijo backtracking over a
fixed geometric step ladder — as ONE traced program, so a polish is a
single jitted call instead of ~2 * steps host round-trips.

Everything is fixed-shape and data-independent in control flow, which
buys two properties the host loop cannot offer:

* **deterministic cost** — exactly ``steps * n_backtracks`` objective
  evaluations, no line-search adaptivity, honest wall-clock accounting;
* **bitwise batch-invariance** — batching is done by dispatching the ONE
  compiled single-task program once per task, so ``fit`` (one task) and
  ``fit_batch`` (a coalesced batch) polish to bit-identical parameters
  at every batch size. Neither batched lowering gives this: ``vmap``
  re-associates the batched Cholesky VJP's reductions on CPU (per-element
  gradients drift across batch sizes in the last ulp — measured; same
  class of divergence PR 7 banned from the serving path), and ``lax.map``
  compiles its scan body differently from the straight-line single-task
  program (B >= 2 elements agree with each other but not with B = 1 /
  single — also measured), because XLA unrolls trip-count-1 loops and
  fuses loop bodies differently from inlined code.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["PolishResult", "make_polish"]


class PolishResult(NamedTuple):
    """Traced polish outcome (all leaves are arrays; ``lax.map``-friendly)."""
    x: jnp.ndarray         # (P,) final iterate
    fun: jnp.ndarray       # () final objective value
    grad_inf: jnp.ndarray  # () inf-norm of the final gradient
    n_accepted: jnp.ndarray  # () number of steps whose line search accepted


def _two_loop(g, S, Yb, rho, valid):
    """H @ g via the two-loop recursion over fixed-size masked history.

    ``S`` / ``Yb`` are (h, P) with the most recent pair at index ``h - 1``;
    ``valid`` masks skipped pairs (curvature condition failed) out of both
    loops, reproducing the standard skip rule without dynamic shapes.
    """
    h = S.shape[0]
    idx_new_to_old = jnp.arange(h - 1, -1, -1)

    def bwd(q, i):
        a = jnp.where(valid[i], rho[i] * jnp.dot(S[i], q), 0.0)
        return q - a * Yb[i], a

    q, alphas = jax.lax.scan(bwd, g, idx_new_to_old)
    sy = jnp.sum(S * Yb, axis=1)
    yy = jnp.sum(Yb * Yb, axis=1)
    i_last = h - 1 - jnp.argmax(valid[::-1])     # most recent valid pair
    tiny = jnp.asarray(1e-30, g.dtype)
    gamma = jnp.where(jnp.any(valid),
                      sy[i_last] / jnp.maximum(yy[i_last], tiny), 1.0)
    q = gamma * q

    def fwd(q, ia):
        i, a = ia
        b = jnp.where(valid[i], rho[i] * jnp.dot(Yb[i], q), 0.0)
        return q + (a - b) * S[i], None

    q, _ = jax.lax.scan(fwd, q, (idx_new_to_old[::-1], alphas[::-1]))
    return q


def make_polish(vg: Callable, steps: int, history: int = 5,
                c1: float = 1e-4, n_backtracks: int = 4) -> Callable:
    """Build ``polish(x0, *args) -> PolishResult`` running ``steps`` L-BFGS
    steps of the objective whose value-and-gradient is ``vg(x, *args)``.

    Each step evaluates the ``n_backtracks`` Armijo candidates
    ``x + 0.5**j * d`` with ``lax.map`` (sequentially — NOT ``vmap``,
    which would change the gradients' reduction order) and takes the
    first sufficient-decrease point; if none qualifies the iterate stays
    put (that step is spent, keeping cost fixed). The returned function
    is pure and fixed-shape: jit it once and dispatch it per task (see
    module docstring for why batched lowerings are avoided).
    """
    if steps < 1:
        raise ValueError(f"make_polish needs steps >= 1, got {steps}")
    ladder = [0.5 ** j for j in range(n_backtracks)]   # host-side: dtype-free

    def polish(x0, *args):
        dtype = x0.dtype
        P = x0.shape[0]
        alphas = jnp.asarray(ladder, dtype)
        f0, g0 = vg(x0, *args)
        S0 = jnp.zeros((history, P), dtype)
        Y0 = jnp.zeros((history, P), dtype)
        rho0 = jnp.zeros((history,), dtype)
        valid0 = jnp.zeros((history,), bool)

        def step(carry, _):
            x, f, g, S, Yb, rho, valid, n_acc = carry
            d = -_two_loop(g, S, Yb, rho, valid)
            dg = jnp.dot(d, g)
            descent = dg < 0
            d = jnp.where(descent, d, -g)
            dg = jnp.where(descent, dg, -jnp.dot(g, g))

            cand = jax.lax.map(lambda a: vg(x + a * d, *args), alphas)
            fs, gs = cand
            ok = jnp.isfinite(fs) & (fs <= f + c1 * alphas * dg)
            any_ok = jnp.any(ok)
            j = jnp.argmax(ok)                   # first passing candidate
            x_new = jnp.where(any_ok, x + alphas[j] * d, x)
            f_new = jnp.where(any_ok, fs[j], f)
            g_new = jnp.where(any_ok, gs[j], g)

            s = x_new - x
            y = g_new - g
            sy = jnp.dot(s, y)
            good = any_ok & (sy > 1e-10 * jnp.linalg.norm(s)
                             * jnp.linalg.norm(y))
            rho_new = jnp.where(good, 1.0 / jnp.where(good, sy, 1.0), 0.0)
            S = jnp.where(good, jnp.concatenate([S[1:], s[None]]), S)
            Yb = jnp.where(good, jnp.concatenate([Yb[1:], y[None]]), Yb)
            rho = jnp.where(good, jnp.concatenate([rho[1:], rho_new[None]]),
                            rho)
            valid = jnp.where(good, jnp.concatenate([valid[1:], good[None]]),
                              valid)
            n_acc = n_acc + any_ok.astype(jnp.int32)
            return (x_new, f_new, g_new, S, Yb, rho, valid, n_acc), None

        init = (x0, f0, g0, S0, Y0, rho0, valid0, jnp.asarray(0, jnp.int32))
        (x, f, g, *_, n_acc), _ = jax.lax.scan(step, init, None, length=steps)
        return PolishResult(x=x, fun=f, grad_inf=jnp.max(jnp.abs(g)),
                            n_accepted=n_acc)

    return polish
