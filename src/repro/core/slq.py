"""Stochastic Lanczos quadrature (SLQ) for log-determinants.

Estimates log det(A|_S) of the masked joint operator restricted to the
observed subspace S, using Rademacher probes drawn inside S (probes stay in S
because the operator maps S to itself). This is the standard machinery behind
GPyTorch's iterative marginal likelihood [Gardner et al., 2018], adapted to
the grid-form representation of the latent Kronecker operator.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["lanczos", "slq_logdet", "slq_logdet_from_tridiag",
           "tridiag_from_cg", "rademacher_probes"]


def rademacher_probes(key, n_probes: int, mask: jnp.ndarray, dtype) -> jnp.ndarray:
    """(p, n, m) +-1 probes restricted to the observed subspace."""
    z = jax.random.rademacher(key, (n_probes, *mask.shape), dtype=dtype)
    return z * mask


def lanczos(A: Callable, v0: jnp.ndarray, num_iters: int):
    """Batched Lanczos tridiagonalisation with full reorthogonalisation.

    v0: (p, n, m) initial probes (not necessarily normalised).
    Returns (alphas (p,k), betas (p,k-1)) of the tridiagonal T per probe.
    """
    p = v0.shape[0]
    norm0 = jnp.sqrt(jnp.sum(v0 * v0, axis=(-2, -1), keepdims=True))
    v = v0 / jnp.maximum(norm0, 1e-30)

    k = num_iters
    V = jnp.zeros((k, *v.shape), v.dtype)  # Lanczos basis for reorthogonalisation
    alphas = jnp.zeros((p, k), v.dtype)
    betas = jnp.zeros((p, k), v.dtype)

    def dot(a, b):
        return jnp.sum(a * b, axis=(-2, -1))

    def body(j, carry):
        V, alphas, betas, v, v_prev, beta_prev = carry
        V = V.at[j].set(v)
        w = A(v) - beta_prev[..., None, None] * v_prev
        alpha = dot(w, v)
        w = w - alpha[..., None, None] * v
        # Full reorthogonalisation: w -= V V^T w (masked basis, so stays in S).
        coeffs = jnp.einsum("kpnm,pnm->kp", V, w)
        w = w - jnp.einsum("kp,kpnm->pnm", coeffs, V)
        beta = jnp.sqrt(jnp.maximum(dot(w, w), 0.0))
        v_next = jnp.where(beta[..., None, None] > 1e-12,
                           w / jnp.maximum(beta[..., None, None], 1e-30), 0.0)
        alphas = alphas.at[:, j].set(alpha)
        betas = betas.at[:, j].set(beta)
        return (V, alphas, betas, v_next, v, beta)

    init = (V, alphas, betas, v, jnp.zeros_like(v), jnp.zeros((p,), v.dtype))
    V, alphas, betas, _, _, _ = jax.lax.fori_loop(0, k, body, init)
    return alphas, betas[:, : k - 1]


def slq_logdet(A: Callable, probes: jnp.ndarray, num_iters: int,
               subspace_dim) -> jnp.ndarray:
    """log det estimate of A restricted to the probe subspace.

    probes: (p, n, m) Rademacher probes already masked; every probe has
    squared norm == subspace_dim.
    """
    alphas, betas = lanczos(A, probes, num_iters)

    def per_probe(alpha, beta):
        T = jnp.diag(alpha) + jnp.diag(beta, 1) + jnp.diag(beta, -1)
        lam, U = jnp.linalg.eigh(T)
        lam = jnp.maximum(lam, 1e-30)  # guard Lanczos breakdown zeros
        w0 = U[0, :] ** 2
        return jnp.sum(w0 * jnp.log(lam))

    quad = jax.vmap(per_probe)(alphas, betas)  # (p,)
    return subspace_dim * jnp.mean(quad)


def tridiag_from_cg(cg_alphas: jnp.ndarray, cg_betas: jnp.ndarray,
                    steps: jnp.ndarray):
    """Lanczos tridiagonal (diag, offdiag) from CG step coefficients.

    The Krylov space CG explores from ``b`` is the Lanczos space of
    ``v0 = b/||b||``, and the tridiagonal falls out of the CG (alpha, beta)
    sequences (Saad 2003 §6.7; the mBCG trick of Gardner et al., 2018):

        T[j, j]   = 1/alpha_j + beta_{j-1}/alpha_{j-1}        (beta_{-1}=0)
        T[j, j+1] = sqrt(beta_j) / alpha_j

    ``cg_alphas``/``cg_betas``: (..., k) per-system coefficient arrays;
    ``steps``: (...,) number of valid entries per system. Entries at or
    beyond ``steps`` are padded to an identity block (diag 1, offdiag 0):
    the padding decouples from e1, so it contributes exactly log(1) = 0 to
    the quadrature below.
    """
    k = cg_alphas.shape[-1]
    idx = jnp.arange(k)
    valid = idx < steps[..., None]
    safe_a = jnp.where(valid & (cg_alphas > 0), cg_alphas, 1.0)
    inv_a = 1.0 / safe_a
    prev_ratio = jnp.zeros_like(cg_alphas).at[..., 1:].set(
        cg_betas[..., :-1] / safe_a[..., :-1])
    diag = jnp.where(valid, inv_a + prev_ratio, 1.0)
    # offdiag j couples steps j and j+1; valid only when step j+1 exists.
    off_valid = idx[:-1] < (steps[..., None] - 1)
    off = jnp.where(off_valid,
                    jnp.sqrt(jnp.maximum(cg_betas[..., :-1], 0.0))
                    * inv_a[..., :-1], 0.0)
    return diag, off


def slq_logdet_from_tridiag(diag: jnp.ndarray, off: jnp.ndarray,
                            subspace_dim) -> jnp.ndarray:
    """log det estimate from per-probe Lanczos tridiagonals (p, k)/(p, k-1).

    Same Gauss quadrature as :func:`slq_logdet`, but starting from
    tridiagonal coefficients recovered from a (stacked) CG solve — the
    probes' solves and the log-det then share ONE set of operator sweeps.
    Assumes probes with squared norm == subspace_dim (masked Rademacher).
    """
    def per_probe(d, e):
        T = jnp.diag(d) + jnp.diag(e, 1) + jnp.diag(e, -1)
        lam, U = jnp.linalg.eigh(T)
        lam = jnp.maximum(lam, 1e-30)  # guard breakdown zeros
        w0 = U[0, :] ** 2
        return jnp.sum(w0 * jnp.log(lam))

    quad = jax.vmap(per_probe)(diag, off)  # (p,)
    return subspace_dim * jnp.mean(quad)
