"""Block-size autotuner for the fused latent-Kronecker MVM kernel.

The fused kernel's best (block_n, block_m) depends on the grid shape: a
block_n that covers n keeps the kernel in its single-K1-sweep regime (no
stage-R recompute, every operand read once), while larger-than-needed
blocks waste VMEM and padding FLOPs. The autotuner picks per-shape blocks
from a small sweep over ``CANDIDATE_BLOCKS`` ({64, 128, 256}):

* **timed mode** (default on TPU, or ``timed=True``): each candidate is
  compiled and timed on a synthetic problem of the bucketed shape,
  validated against the :mod:`repro.kernels.ref` oracle, and the fastest
  valid candidate wins.
* **heuristic mode** (default off-TPU, and always under ``jit`` tracing —
  timing inside a trace is meaningless): the smallest candidate covering
  each axis, i.e. the analytic single-sweep optimum.

Results are cached per (n, m, B) power-of-two bucket (+ precision +
backend), so the sweep runs once per shape family per process. The
benchmark suite (``benchmarks/bench_mvm.py``) pre-fills the cache with
timed results; later jitted traces reuse them via :func:`autotune_blocks`.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from ..analysis.vmem import best_fitting_blocks, fused_vmem_breakdown

__all__ = ["CANDIDATE_BLOCKS", "autotune_blocks", "clear_cache",
           "cache_contents"]

CANDIDATE_BLOCKS = (64, 128, 256)

_CACHE: dict[tuple, "tuple[int, int] | None"] = {}
_MISS = object()   # cached None is a real answer ("no candidate fits")


def _bucket(x: int) -> int:
    """Next power of two >= x (min 8): shapes in one bucket share blocks."""
    b = 8
    while b < x:
        b *= 2
    return b


def clear_cache() -> None:
    _CACHE.clear()


def cache_contents() -> dict:
    return dict(_CACHE)


def _heuristic(n: int, m: int,
               precision: str = "f32") -> tuple[int, int] | None:
    """Smallest candidate covering each axis (single-sweep regime).

    VMEM-guarded since PR 6: if the covering pair does not fit the 16 MiB
    budget (``repro.analysis.vmem``), fall back to the best *fitting*
    candidate; None when no candidate fits at all — the fused kernel
    cannot run this shape and callers must take the two-stage path.
    """
    bn = next((c for c in CANDIDATE_BLOCKS if c >= n), CANDIDATE_BLOCKS[-1])
    bm = next((c for c in CANDIDATE_BLOCKS if c >= m), CANDIDATE_BLOCKS[-1])
    if fused_vmem_breakdown(n, m, bn, bm, precision).fits():
        return bn, bm
    return best_fitting_blocks(n, m, precision,
                               candidates=CANDIDATE_BLOCKS)


def _candidate_pairs(n: int, m: int, precision: str = "f32"):
    """Deduplicated, VMEM-fitting candidate pairs for the timed sweep.

    Oversized pairs are excluded *statically*: on TPU they would fail at
    Mosaic compile time (wasting a sweep slot), and in interpret mode
    they would time fine and poison the cache with a config that OOMs on
    hardware.
    """
    seen, pairs = set(), []
    for bn in CANDIDATE_BLOCKS:
        for bm in CANDIDATE_BLOCKS:
            eff = (min(bn, _bucket(max(8, n))), min(bm, _bucket(max(8, m))))
            if eff in seen:
                continue
            seen.add(eff)
            if fused_vmem_breakdown(n, m, bn, bm, precision).fits():
                pairs.append((bn, bm))
    return pairs


def _time_candidate(fn, args, reps: int = 3) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        # Timing harness: the per-iteration sync IS the measurement.
        jax.block_until_ready(fn(*args))  # lint: disable=RA103
        best = min(best, time.perf_counter() - t0)
    return best


def autotune_blocks(n: int, m: int, B: int = 1, *, precision: str = "f32",
                    timed: bool | None = None,
                    interpret: bool | None = None,
                    atol: float = 1e-4) -> tuple[int, int] | None:
    """Pick (block_n, block_m) for the fused kernel at shape (B, n, m).

    ``timed=None`` resolves to True on TPU and False elsewhere. Timed
    sweeps validate every candidate against the jnp oracle and skip any
    that fail; a fully-failing sweep falls back to the heuristic. Safe to
    call at ``jit`` trace time with ``timed=False`` (pure-python cache
    lookup / heuristic — no compilation, no timing).

    Every candidate considered (timed or heuristic) is pre-filtered
    against the exact VMEM budget model (:mod:`repro.analysis.vmem`).
    Returns ``None`` when *no* candidate fits — e.g. m >= 8192, where a
    single row strip exceeds 16 MiB — meaning the fused kernel cannot run
    this shape and the caller must use the two-stage kernel.
    """
    key = (_bucket(n), _bucket(m), _bucket(max(B, 1)), precision,
           jax.default_backend())
    hit = _CACHE.get(key, _MISS)
    if hit is not _MISS:
        return hit
    if timed is None:
        timed = jax.default_backend() == "tpu"
    if not timed:
        blocks = _heuristic(n, m, precision)
        _CACHE[key] = blocks
        return blocks

    # Import here: repro.kernels.lk_mvm has no dependency on this module,
    # but keeping the top level import-light avoids cycles via ref.py.
    from .lk_mvm import lk_mvm_fused
    from .ref import lk_mvm_ref

    nb, mb, Bb = key[0], key[1], key[2]
    rng = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(rng, 3)
    A = jax.random.normal(k1, (nb, nb), jnp.float32)
    K1 = A @ A.T / nb + 0.5 * jnp.eye(nb, dtype=jnp.float32)
    C = jax.random.normal(k2, (mb, mb), jnp.float32)
    K2 = C @ C.T / mb + 0.5 * jnp.eye(mb, dtype=jnp.float32)
    mask = jnp.ones((nb, mb), jnp.float32)
    u = jax.random.normal(k3, (Bb, nb, mb), jnp.float32)
    ref = np.asarray(lk_mvm_ref(K1, K2, mask, u, 0.1))
    scale = max(1.0, float(np.max(np.abs(ref))))

    best, best_t = None, float("inf")
    for bn, bm in _candidate_pairs(nb, mb, precision):
        def run(K1, K2, mask, u, _bn=bn, _bm=bm):
            return lk_mvm_fused(K1, K2, mask, u, 0.1, block_n=_bn,
                                block_m=_bm, precision=precision,
                                interpret=interpret)
        try:
            # Correctness screen of each candidate against the dense
            # reference needs the values on host.
            out = np.asarray(run(K1, K2, mask, u))  # lint: disable=RA103
        except Exception:
            continue
        tol = atol * scale if precision == "f32" else 0.1 * scale
        if not np.allclose(out, ref, atol=tol):
            continue
        t = _time_candidate(run, (K1, K2, mask, u))
        if t < best_t:
            best, best_t = (bn, bm), t
    if best is None:
        best = _heuristic(n, m, precision)
    _CACHE[key] = best
    return best
