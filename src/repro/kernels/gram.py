"""Pallas TPU kernel: fused RBF-ARD gram matrix.

K[i, j] = outputscale * exp(-0.5 * || (x_i - x_j) / l ||^2)

A naive jnp implementation either materialises the (n, n, d) broadcast
difference tensor or does three separate HBM passes (row norms, matmul,
exp). This kernel pre-scales is done by the wrapper (z = x / l); the kernel
computes per (bi, bj) tile

    sq[i, j] = |z_i|^2 + |z_j|^2 - 2 z_i . z_j

accumulating the dot product over d-chunks on the MXU, and applies the
exp epilogue in VMEM — one HBM write total.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["rbf_gram_pallas"]


def _gram_kernel(zi_ref, zj_ref, scale_ref, o_ref, acc_ref, ni_ref, nj_ref,
                 *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        ni_ref[...] = jnp.zeros_like(ni_ref)
        nj_ref[...] = jnp.zeros_like(nj_ref)

    zi = zi_ref[...].astype(jnp.float32)
    zj = zj_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot_general(zi, zj, (((1,), (1,)), ((), ())),
                                        preferred_element_type=jnp.float32)
    ni_ref[...] += jnp.sum(zi * zi, axis=1, keepdims=True)
    nj_ref[...] += jnp.sum(zj * zj, axis=1, keepdims=True)

    @pl.when(k == nk - 1)
    def _done():
        sq = ni_ref[...] + nj_ref[...].T - 2.0 * acc_ref[...]
        sq = jnp.maximum(sq, 0.0)
        o_ref[...] = (scale_ref[0, 0] * jnp.exp(-0.5 * sq)).astype(o_ref.dtype)


def _pad_to(x, mults):
    pads = [(0, (-s) % mult) for s, mult in zip(x.shape, mults)]
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads)


@functools.partial(jax.jit,
                   static_argnames=("block_n", "block_d", "interpret"))
def rbf_gram_pallas(x1: jnp.ndarray, x2: jnp.ndarray, lengthscale: jnp.ndarray,
                    outputscale=1.0, *, block_n: int = 128, block_d: int = 128,
                    interpret: bool | None = None) -> jnp.ndarray:
    """RBF-ARD gram matrix between x1 (n, d) and x2 (p, d)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n, d = x1.shape
    p = x2.shape[0]
    z1 = x1 / lengthscale
    z2 = x2 / lengthscale

    bn = min(block_n, max(8, n))
    bp = min(block_n, max(8, p))
    bd = min(block_d, max(1, d))
    z1p = _pad_to(z1, (bn, bd))  # zero-padded d contributes 0 to sq-dist
    z2p = _pad_to(z2, (bp, bd))
    npad, dpad = z1p.shape
    ppad = z2p.shape[0]
    scale = jnp.asarray(outputscale, jnp.float32).reshape(1, 1)

    gk = dpad // bd
    out = pl.pallas_call(
        functools.partial(_gram_kernel, nk=gk),
        grid=(npad // bn, ppad // bp, gk),
        in_specs=[
            pl.BlockSpec((bn, bd), lambda i, j, k: (i, k)),
            pl.BlockSpec((bp, bd), lambda i, j, k: (j, k)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((bn, bp), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((npad, ppad), x1.dtype),
        scratch_shapes=[pltpu.VMEM((bn, bp), jnp.float32),
                        pltpu.VMEM((bn, 1), jnp.float32),
                        pltpu.VMEM((bp, 1), jnp.float32)],
        interpret=interpret,
    )(z1p, z2p, scale)
    return out[:n, :p]
