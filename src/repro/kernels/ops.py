"""Jitted public wrappers that dispatch Pallas kernels or jnp oracles.

On TPU the Pallas path is used; elsewhere (this container is CPU-only) the
default is the jnp oracle, with ``force_pallas=True`` running the kernels in
interpret mode for validation. The MVM routes through the single-pass fused
kernel by default (``fused=False`` selects the two-stage baseline); block
sizes come from the :mod:`repro.kernels.autotune` cache when not given.
"""
from __future__ import annotations

import jax

from .autotune import autotune_blocks
from .gram import rbf_gram_pallas
from .lk_mvm import lk_mvm_pallas
from .ref import lk_mvm_ref, rbf_gram_ref

__all__ = ["lk_mvm_op", "rbf_gram_op"]


def _use_pallas(force_pallas: bool) -> bool:
    return force_pallas or jax.default_backend() == "tpu"


def lk_mvm_op(K1, K2, mask, u, noise=0.0, *, force_pallas: bool = False,
              block_n: int | None = None, block_m: int | None = None,
              fused: bool = True, precision: str = "f32"):
    if _use_pallas(force_pallas):
        if block_n is None or block_m is None:
            n, m = mask.shape
            B = 1
            for s in u.shape[:-2]:
                B *= s
            # timed=False: safe at jit trace time (cache lookup/heuristic
            # only); benchmarks pre-fill the cache with timed results.
            blocks = autotune_blocks(n, m, B, precision=precision,
                                     timed=False)
            if blocks is None:
                # No candidate fits the VMEM budget at this shape (e.g.
                # m >= 8192: one fused row strip alone exceeds 16 MiB).
                # The two-stage kernel keeps its intermediate in HBM.
                fused = False
                blocks = (128, 128)
            bn, bm = blocks
            block_n = block_n if block_n is not None else bn
            block_m = block_m if block_m is not None else bm
        return lk_mvm_pallas(K1, K2, mask, u, noise,
                             block_n=block_n, block_m=block_m,
                             fused=fused, precision=precision)
    return lk_mvm_ref(K1, K2, mask, u, noise)


def rbf_gram_op(x1, x2, lengthscale, outputscale=1.0, *,
                force_pallas: bool = False, block_n: int = 128,
                block_d: int = 128):
    if _use_pallas(force_pallas):
        return rbf_gram_pallas(x1, x2, lengthscale, outputscale,
                               block_n=block_n, block_d=block_d)
    return rbf_gram_ref(x1, x2, lengthscale, outputscale)
