"""Jitted public wrappers that dispatch Pallas kernels or jnp oracles.

On TPU the Pallas path is used; elsewhere (this container is CPU-only) the
default is the jnp oracle, with ``force_pallas=True`` running the kernels in
interpret mode for validation.
"""
from __future__ import annotations

import jax

from .gram import rbf_gram_pallas
from .lk_mvm import lk_mvm_pallas
from .ref import lk_mvm_ref, rbf_gram_ref

__all__ = ["lk_mvm_op", "rbf_gram_op"]


def _use_pallas(force_pallas: bool) -> bool:
    return force_pallas or jax.default_backend() == "tpu"


def lk_mvm_op(K1, K2, mask, u, noise=0.0, *, force_pallas: bool = False,
              block_n: int = 128, block_m: int = 128):
    if _use_pallas(force_pallas):
        return lk_mvm_pallas(K1, K2, mask, u, noise,
                             block_n=block_n, block_m=block_m)
    return lk_mvm_ref(K1, K2, mask, u, noise)


def rbf_gram_op(x1, x2, lengthscale, outputscale=1.0, *,
                force_pallas: bool = False, block_n: int = 128,
                block_d: int = 128):
    if _use_pallas(force_pallas):
        return rbf_gram_pallas(x1, x2, lengthscale, outputscale,
                               block_n=block_n, block_d=block_d)
    return rbf_gram_ref(x1, x2, lengthscale, outputscale)
