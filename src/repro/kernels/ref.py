"""Pure-jnp oracles for the Pallas kernels (used by tests and CPU fallback)."""
from __future__ import annotations

import jax.numpy as jnp

from ..core.gp_kernels import rbf_ard
from ..core.mvm import lk_mvm

__all__ = ["lk_mvm_ref", "rbf_gram_ref"]


def lk_mvm_ref(K1, K2, mask, u, noise=0.0):
    """out = mask * (K1 @ (mask*u) @ K2) + noise * (mask*u)."""
    return lk_mvm(K1, K2, mask, u, noise)


def rbf_gram_ref(x1, x2, lengthscale, outputscale=1.0):
    return rbf_ard(x1, x2, lengthscale, outputscale)
