"""Pallas TPU kernels: masked latent-Kronecker MVM.

Computes   out = mask * (K1 @ (mask * U) @ K2) + noise * (mask * U)

This is the inner loop of every CG iteration in the paper (Section 2): on
GPU/GPyTorch it is two cuBLAS calls plus separate elementwise masking
kernels, i.e. four full HBM round-trips of the (B, n, m) intermediate.

Two implementations live here:

:func:`lk_mvm_fused` (the default behind :func:`lk_mvm_pallas`)
    ONE ``pallas_call``. Grid (B, n-rows, m-cols) with an inner K1-row
    sweep; each step recomputes the per-block-row tile
    ``T = (mask * U)[k, :] @ K2[:, j]`` straight into VMEM scratch and
    accumulates ``K1[i, k] @ T`` — the (B, n, m) f32 intermediate NEVER
    touches HBM. The noise/mask epilogue tiles are sliced out of the
    already-resident row strips when the sweep passes k == i, so the fused
    kernel reads each operand exactly once per grid step. The recompute
    factor on the cheap first product is n/block_n on its O(n m^2) term —
    for learning-curve grids (m << n, m <~ block) this is bounded by the
    O(n^2 m) second product, while HBM traffic drops by the full
    intermediate round-trip. Supports a bf16-inputs / f32-accumulate mode
    (``precision="bf16"``); block sizes come from
    :mod:`repro.kernels.autotune` when not given explicitly.
    VMEM per step is O(block_n * m + m * block_m), so the fused kernel
    targets the paper's regime m <~ 4096.

:func:`lk_mvm_two_stage` (the committed baseline the benchmarks gate
    against) — two ``pallas_call``s with the masked intermediate
    materialised in HBM between them:

    Stage R (right):  T   = (mask * U) @ K2        grid (B, n/bn, m/bj, m/bk)
    Stage L (left):   out = mask * (K1 @ T) + noise * (mask * U)
                                                   grid (B, n/bi, m/bj, n/bk)

Accumulation always runs over the innermost grid axis into an f32 VMEM
scratch; epilogues apply the mask and noise term on the final step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..analysis.vmem import check_fused_blocks

__all__ = ["lk_mvm_pallas", "lk_mvm_fused", "lk_mvm_fused_rows",
           "lk_mvm_two_stage"]


def _stage_right_kernel(u_ref, mask_ref, k2_ref, o_ref, acc_ref, *, nk: int):
    """T[b, i, j] += (mask*U)[b, i, k] @ K2[k, j]."""
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    um = (u_ref[0] * mask_ref[...]).astype(jnp.float32)
    acc_ref[...] += jax.lax.dot(um, k2_ref[...].astype(jnp.float32),
                                preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _done():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def _stage_left_kernel(k1_ref, t_ref, mask_ref, u_ref, noise_ref, o_ref,
                       acc_ref, *, nk: int):
    """out[b, i, j] = mask * (K1[i, k] @ T[b, k, j]) + noise * mask * U."""
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot(k1_ref[...].astype(jnp.float32),
                                t_ref[0].astype(jnp.float32),
                                preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _done():
        mask = mask_ref[...]
        noise = noise_ref[0, 0]
        out = mask * acc_ref[...] + noise * (mask * u_ref[0].astype(jnp.float32))
        o_ref[0] = out.astype(o_ref.dtype)


def _fused_kernel(k1_ref, u_ref, mask_ref, k2_ref, noise_ref, o_ref,
                  acc_ref, epi_mask_ref, epi_u_ref, *, nk: int, bm: int,
                  compute_dtype):
    """Single-pass out[b, i, j] = mask*(sum_k K1[i,k] @ ((mask*U)[k,:]@K2[:,j]))
    + noise * mask * U, with T tiles living only in VMEM."""
    i = pl.program_id(1)
    j = pl.program_id(2)
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Stage-R tile for block-row k, computed straight into registers/VMEM:
    # (bn, m) x (m, bm) — the full m sweep in one MXU pass.
    um = (u_ref[0] * mask_ref[...]).astype(compute_dtype)
    t = jax.lax.dot(um, k2_ref[...].astype(compute_dtype),
                    preferred_element_type=jnp.float32)
    acc_ref[...] += jax.lax.dot(k1_ref[...].astype(compute_dtype),
                                t.astype(compute_dtype),
                                preferred_element_type=jnp.float32)

    # The epilogue needs mask/U at block (i, j); the k-sweep's row strips
    # contain exactly those tiles when k == i — slice them out of VMEM
    # instead of fetching them from HBM again.
    @pl.when(k == i)
    def _capture():
        off = pl.multiple_of(j * bm, bm)
        epi_mask_ref[...] = mask_ref[:, pl.ds(off, bm)].astype(jnp.float32)
        epi_u_ref[...] = u_ref[0, :, pl.ds(off, bm)].astype(jnp.float32)

    @pl.when(k == nk - 1)
    def _done():
        msk = epi_mask_ref[...]
        noise = noise_ref[0, 0]
        out = msk * acc_ref[...] + noise * (msk * epi_u_ref[...])
        o_ref[0] = out.astype(o_ref.dtype)


def _pad_to(x, mults):
    pads = [(0, (-s) % mult) for s, mult in zip(x.shape, mults)]
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads)


@functools.partial(jax.jit, static_argnames=("block_n", "block_m", "interpret"))
def lk_mvm_two_stage(K1: jnp.ndarray, K2: jnp.ndarray, mask: jnp.ndarray,
                     u: jnp.ndarray, noise=0.0, *, block_n: int = 128,
                     block_m: int = 128,
                     interpret: bool | None = None) -> jnp.ndarray:
    """Two-stage masked Kronecker MVM (HBM-materialised intermediate).

    Kept as the benchmark baseline for the fused kernel; u: (..., n, m) ->
    same shape. Zero-padding to block multiples is harmless: padded
    rows/cols of mask are zero, K2/K1 padding contributes zero partial
    products.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n, m = mask.shape
    batch_shape = u.shape[:-2]
    u3 = u.reshape((-1, n, m))
    B = u3.shape[0]
    dtype = u.dtype

    bn = min(block_n, max(8, n))
    bm = min(block_m, max(8, m))
    K1p = _pad_to(K1, (bn, bn))
    K2p = _pad_to(K2, (bm, bm))
    maskp = _pad_to(mask, (bn, bm))
    up = _pad_to(u3, (1, bn, bm))
    npad, mpad = maskp.shape
    noise_arr = jnp.asarray(noise, jnp.float32).reshape(1, 1)

    gn, gm, gkm, gkn = npad // bn, mpad // bm, mpad // bm, npad // bn

    # Stage R: T = (mask * U) @ K2
    t = pl.pallas_call(
        functools.partial(_stage_right_kernel, nk=gkm),
        grid=(B, gn, gm, gkm),
        in_specs=[
            pl.BlockSpec((1, bn, bm), lambda b, i, j, k: (b, i, k)),   # U
            pl.BlockSpec((bn, bm), lambda b, i, j, k: (i, k)),         # mask
            pl.BlockSpec((bm, bm), lambda b, i, j, k: (k, j)),         # K2
        ],
        out_specs=pl.BlockSpec((1, bn, bm), lambda b, i, j, k: (b, i, j)),
        out_shape=jax.ShapeDtypeStruct((B, npad, mpad), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bn, bm), jnp.float32)],
        interpret=interpret,
    )(up, maskp, K2p)

    # Stage L: out = mask * (K1 @ T) + noise * mask * U
    out = pl.pallas_call(
        functools.partial(_stage_left_kernel, nk=gkn),
        grid=(B, gn, gm, gkn),
        in_specs=[
            pl.BlockSpec((bn, bn), lambda b, i, j, k: (i, k)),         # K1
            pl.BlockSpec((1, bn, bm), lambda b, i, j, k: (b, k, j)),   # T
            pl.BlockSpec((bn, bm), lambda b, i, j, k: (i, j)),         # mask
            pl.BlockSpec((1, bn, bm), lambda b, i, j, k: (b, i, j)),   # U
            pl.BlockSpec(memory_space=pltpu.SMEM),                     # noise
        ],
        out_specs=pl.BlockSpec((1, bn, bm), lambda b, i, j, k: (b, i, j)),
        out_shape=jax.ShapeDtypeStruct((B, npad, mpad), dtype),
        scratch_shapes=[pltpu.VMEM((bn, bm), jnp.float32)],
        interpret=interpret,
    )(K1p, t, maskp, up, noise_arr)

    return out[:, :n, :m].reshape(*batch_shape, n, m)


@functools.partial(jax.jit, static_argnames=("block_n", "block_m",
                                             "precision", "interpret"))
def lk_mvm_fused(K1: jnp.ndarray, K2: jnp.ndarray, mask: jnp.ndarray,
                 u: jnp.ndarray, noise=0.0, *, block_n: int = 128,
                 block_m: int = 128, precision: str = "f32",
                 interpret: bool | None = None) -> jnp.ndarray:
    """Single-pass masked Kronecker MVM. u: (..., n, m) -> same shape.

    One ``pallas_call``; the stage-R tile stays in VMEM scratch (see module
    docstring). ``precision="bf16"`` casts the matmul inputs to bfloat16
    and accumulates in f32 (the mask/noise epilogue stays f32); the output
    keeps u's dtype. Zero-padding to block multiples is harmless: padded
    rows/cols of mask are zero, K2/K1 padding contributes zero partial
    products.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if precision not in ("f32", "bf16"):
        raise ValueError(f"precision must be 'f32' or 'bf16', got {precision!r}")
    compute_dtype = jnp.bfloat16 if precision == "bf16" else jnp.float32
    n, m = mask.shape
    batch_shape = u.shape[:-2]
    u3 = u.reshape((-1, n, m))
    B = u3.shape[0]
    dtype = u.dtype

    min_edge = 16 if precision == "bf16" else 8
    bn = min(block_n, max(min_edge, n))
    bm = min(block_m, max(min_edge, m))
    # Static VMEM guard (trace time, shapes only): an oversized block
    # choice fails here with an actionable message instead of at Mosaic
    # compile time on TPU — or worse, "working" in interpret mode on CPU
    # and OOMing the first time the same trace reaches hardware.
    check_fused_blocks(n, m, block_n, block_m, precision,
                       out_itemsize=jnp.dtype(dtype).itemsize)
    if precision == "bf16":
        K1 = K1.astype(jnp.bfloat16)
        K2 = K2.astype(jnp.bfloat16)
        u3 = u3.astype(jnp.bfloat16)
        mask = mask.astype(jnp.bfloat16)   # exact: mask is 0/1
    K1p = _pad_to(K1, (bn, bn))
    K2p = _pad_to(K2, (bm, bm))
    maskp = _pad_to(mask, (bn, bm))
    up = _pad_to(u3, (1, bn, bm))
    npad, mpad = maskp.shape
    noise_arr = jnp.asarray(noise, jnp.float32).reshape(1, 1)

    gn, gm, gkn = npad // bn, mpad // bm, npad // bn

    out = pl.pallas_call(
        functools.partial(_fused_kernel, nk=gkn, bm=bm,
                          compute_dtype=compute_dtype),
        grid=(B, gn, gm, gkn),
        in_specs=[
            pl.BlockSpec((bn, bn), lambda b, i, j, k: (i, k)),       # K1
            pl.BlockSpec((1, bn, mpad), lambda b, i, j, k: (b, k, 0)),  # U row strip
            pl.BlockSpec((bn, mpad), lambda b, i, j, k: (k, 0)),     # mask row strip
            pl.BlockSpec((mpad, bm), lambda b, i, j, k: (0, j)),     # K2 col strip
            pl.BlockSpec(memory_space=pltpu.SMEM),                   # noise
        ],
        out_specs=pl.BlockSpec((1, bn, bm), lambda b, i, j, k: (b, i, j)),
        out_shape=jax.ShapeDtypeStruct((B, npad, mpad), dtype),
        scratch_shapes=[
            pltpu.VMEM((bn, bm), jnp.float32),   # accumulator
            pltpu.VMEM((bn, bm), jnp.float32),   # epilogue mask tile
            pltpu.VMEM((bn, bm), jnp.float32),   # epilogue U tile
        ],
        interpret=interpret,
    )(K1p, up, maskp, K2p, noise_arr)

    return out[:, :n, :m].reshape(*batch_shape, n, m)


def _fused_rows_kernel(k1_ref, um_ref, k2_ref, mask_ref, u_ref, noise_ref,
                       o_ref, acc_ref, *, nk: int, compute_dtype):
    """Rectangular fused pass for one row shard:
    out[i, j] = mask_rows * (sum_k K1_rows[i, k] @ (um_full[k, :] @ K2[:, j]))
    + noise * mask_rows * u_rows.

    Unlike :func:`_fused_kernel`, the epilogue mask/u tiles are dedicated
    inputs indexed at the *local* output block (i, j): under row sharding
    the square kernel's ``k == i`` capture trick is invalid, because the
    k sweep runs over GLOBAL block rows while i indexes the shard's local
    rows — the strips never align except on shard 0.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Stage-R tile for global block-row k (um_full is pre-masked by the
    # caller: mask*u gathered across shards), straight into VMEM.
    t = jax.lax.dot(um_ref[...].astype(compute_dtype),
                    k2_ref[...].astype(compute_dtype),
                    preferred_element_type=jnp.float32)
    acc_ref[...] += jax.lax.dot(k1_ref[...].astype(compute_dtype),
                                t.astype(compute_dtype),
                                preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _done():
        msk = mask_ref[...].astype(jnp.float32)
        noise = noise_ref[0, 0]
        out = msk * acc_ref[...] + noise * (msk * u_ref[...].astype(jnp.float32))
        o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "block_m",
                                             "precision", "interpret"))
def lk_mvm_fused_rows(K1_rows: jnp.ndarray, K2: jnp.ndarray,
                      mask_rows: jnp.ndarray, u_rows: jnp.ndarray,
                      um_full: jnp.ndarray, noise=0.0, *, block_n: int = 128,
                      block_m: int = 128, precision: str = "f32",
                      interpret: bool | None = None) -> jnp.ndarray:
    """Fused masked Kronecker MVM for ONE row shard of the latent grid.

    This is the per-shard body of the distributed fused path (see
    :func:`repro.distributed.lkgp_dist.dist_lk_mvm_fused`): the caller
    all-gathers ``um_full = mask * u`` (n, m) once per MVM and every shard
    runs this kernel on its local row block.

    K1_rows: (n_local, n) local row block of K1; mask_rows / u_rows:
    (n_local, m) local rows of mask / u; um_full: (n, m) gathered masked
    input. Returns (n_local, m) =
    ``mask_rows * (K1_rows @ (um_full @ K2)) + noise * (mask_rows * u_rows)``.

    Rank-2 only (the shard_map body is rank-2; engines lax.map the batch).
    Zero-padding to block multiples is harmless for the same reason as in
    :func:`lk_mvm_fused`.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if precision not in ("f32", "bf16"):
        raise ValueError(f"precision must be 'f32' or 'bf16', got {precision!r}")
    compute_dtype = jnp.bfloat16 if precision == "bf16" else jnp.float32
    n_local, m = mask_rows.shape
    n = um_full.shape[0]
    dtype = u_rows.dtype

    min_edge = 16 if precision == "bf16" else 8
    bn = min(block_n, max(min_edge, n_local))
    bm = min(block_m, max(min_edge, m))
    # Per-shard VMEM guard. The square kernel's byte model upper-bounds this
    # variant: it charges two (bn, mpad) row strips + 3 scratch tiles where
    # this kernel holds one (bn, mpad) strip, two (bn, bm) epilogue tiles
    # and 1 scratch tile.
    check_fused_blocks(n_local, m, block_n, block_m, precision,
                       out_itemsize=jnp.dtype(dtype).itemsize)
    if precision == "bf16":
        K1_rows = K1_rows.astype(jnp.bfloat16)
        K2 = K2.astype(jnp.bfloat16)
        um_full = um_full.astype(jnp.bfloat16)
        mask_rows = mask_rows.astype(jnp.bfloat16)   # exact: mask is 0/1
        u_rows = u_rows.astype(jnp.bfloat16)
    K1p = _pad_to(K1_rows, (bn, bn))
    K2p = _pad_to(K2, (bm, bm))
    maskp = _pad_to(mask_rows, (bn, bm))
    urp = _pad_to(u_rows, (bn, bm))
    ump = _pad_to(um_full, (bn, bm))
    nlpad, mpad = maskp.shape
    # K1 cols and um_full rows are both n padded to the same bn multiple.
    npad = ump.shape[0]
    noise_arr = jnp.asarray(noise, jnp.float32).reshape(1, 1)

    gi, gj, gk = nlpad // bn, mpad // bm, npad // bn

    out = pl.pallas_call(
        functools.partial(_fused_rows_kernel, nk=gk,
                          compute_dtype=compute_dtype),
        grid=(gi, gj, gk),
        in_specs=[
            pl.BlockSpec((bn, bn), lambda i, j, k: (i, k)),      # K1 rows
            pl.BlockSpec((bn, mpad), lambda i, j, k: (k, 0)),    # um row strip
            pl.BlockSpec((mpad, bm), lambda i, j, k: (0, j)),    # K2 col strip
            pl.BlockSpec((bn, bm), lambda i, j, k: (i, j)),      # local mask
            pl.BlockSpec((bn, bm), lambda i, j, k: (i, j)),      # local u
            pl.BlockSpec(memory_space=pltpu.SMEM),               # noise
        ],
        out_specs=pl.BlockSpec((bn, bm), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nlpad, mpad), dtype),
        scratch_shapes=[pltpu.VMEM((bn, bm), jnp.float32)],
        interpret=interpret,
    )(K1p, ump, K2p, maskp, urp, noise_arr)

    return out[:n_local, :m]


def lk_mvm_pallas(K1, K2, mask, u, noise=0.0, *, block_n: int = 128,
                  block_m: int = 128, interpret: bool | None = None,
                  fused: bool = True,
                  precision: str = "f32") -> jnp.ndarray:
    """Masked Kronecker MVM (back-compatible entry point).

    Dispatches to the single-pass :func:`lk_mvm_fused` kernel by default;
    ``fused=False`` runs the committed two-stage baseline.
    """
    if fused:
        return lk_mvm_fused(K1, K2, mask, u, noise, block_n=block_n,
                            block_m=block_m, precision=precision,
                            interpret=interpret)
    return lk_mvm_two_stage(K1, K2, mask, u, noise, block_n=block_n,
                            block_m=block_m, interpret=interpret)
