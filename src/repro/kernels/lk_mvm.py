"""Pallas TPU kernel: masked latent-Kronecker MVM.

Computes   out = mask * (K1 @ (mask * U) @ K2) + noise * (mask * U)

as two fused masked matmuls. This is the inner loop of every CG iteration in
the paper (Section 2): on GPU/GPyTorch it is two cuBLAS calls plus separate
elementwise masking kernels, i.e. four full HBM round-trips of the (B, n, m)
intermediate. Here each stage applies the mask on load/store inside VMEM, so
the intermediate touches HBM exactly once, and blocks are 128-aligned for the
MXU.

Stage R (right):  T   = (mask * U) @ K2          grid (B, n/bn, m/bj, m/bk)
Stage L (left):   out = mask * (K1 @ T) + noise * (mask * U)
                                                 grid (B, n/bi, m/bj, n/bk)

Accumulation runs over the innermost grid axis into an f32 VMEM scratch;
the epilogue applies mask and the noise term on the final k step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["lk_mvm_pallas"]


def _stage_right_kernel(u_ref, mask_ref, k2_ref, o_ref, acc_ref, *, nk: int):
    """T[b, i, j] += (mask*U)[b, i, k] @ K2[k, j]."""
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    um = (u_ref[0] * mask_ref[...]).astype(jnp.float32)
    acc_ref[...] += jax.lax.dot(um, k2_ref[...].astype(jnp.float32),
                                preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _done():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def _stage_left_kernel(k1_ref, t_ref, mask_ref, u_ref, noise_ref, o_ref,
                       acc_ref, *, nk: int):
    """out[b, i, j] = mask * (K1[i, k] @ T[b, k, j]) + noise * mask * U."""
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot(k1_ref[...].astype(jnp.float32),
                                t_ref[0].astype(jnp.float32),
                                preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _done():
        mask = mask_ref[...]
        noise = noise_ref[0, 0]
        out = mask * acc_ref[...] + noise * (mask * u_ref[0].astype(jnp.float32))
        o_ref[0] = out.astype(o_ref.dtype)


def _pad_to(x, mults):
    pads = [(0, (-s) % mult) for s, mult in zip(x.shape, mults)]
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads)


@functools.partial(jax.jit, static_argnames=("block_n", "block_m", "interpret"))
def lk_mvm_pallas(K1: jnp.ndarray, K2: jnp.ndarray, mask: jnp.ndarray,
                  u: jnp.ndarray, noise=0.0, *, block_n: int = 128,
                  block_m: int = 128, interpret: bool | None = None) -> jnp.ndarray:
    """Masked Kronecker MVM. u: (..., n, m) -> same shape.

    Zero-padding to block multiples is harmless: padded rows/cols of mask are
    zero, K2/K1 padding contributes zero partial products.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n, m = mask.shape
    batch_shape = u.shape[:-2]
    u3 = u.reshape((-1, n, m))
    B = u3.shape[0]
    dtype = u.dtype

    bn = min(block_n, max(8, n))
    bm = min(block_m, max(8, m))
    K1p = _pad_to(K1, (bn, bn))
    K2p = _pad_to(K2, (bm, bm))
    maskp = _pad_to(mask, (bn, bm))
    up = _pad_to(u3, (1, bn, bm))
    npad, mpad = maskp.shape
    noise_arr = jnp.asarray(noise, jnp.float32).reshape(1, 1)

    gn, gm, gkm, gkn = npad // bn, mpad // bm, mpad // bm, npad // bn

    # Stage R: T = (mask * U) @ K2
    t = pl.pallas_call(
        functools.partial(_stage_right_kernel, nk=gkm),
        grid=(B, gn, gm, gkm),
        in_specs=[
            pl.BlockSpec((1, bn, bm), lambda b, i, j, k: (b, i, k)),   # U
            pl.BlockSpec((bn, bm), lambda b, i, j, k: (i, k)),         # mask
            pl.BlockSpec((bm, bm), lambda b, i, j, k: (k, j)),         # K2
        ],
        out_specs=pl.BlockSpec((1, bn, bm), lambda b, i, j, k: (b, i, j)),
        out_shape=jax.ShapeDtypeStruct((B, npad, mpad), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bn, bm), jnp.float32)],
        interpret=interpret,
    )(up, maskp, K2p)

    # Stage L: out = mask * (K1 @ T) + noise * mask * U
    out = pl.pallas_call(
        functools.partial(_stage_left_kernel, nk=gkn),
        grid=(B, gn, gm, gkn),
        in_specs=[
            pl.BlockSpec((bn, bn), lambda b, i, j, k: (i, k)),         # K1
            pl.BlockSpec((1, bn, bm), lambda b, i, j, k: (b, k, j)),   # T
            pl.BlockSpec((bn, bm), lambda b, i, j, k: (i, j)),         # mask
            pl.BlockSpec((1, bn, bm), lambda b, i, j, k: (b, i, j)),   # U
            pl.BlockSpec(memory_space=pltpu.SMEM),                     # noise
        ],
        out_specs=pl.BlockSpec((1, bn, bm), lambda b, i, j, k: (b, i, j)),
        out_shape=jax.ShapeDtypeStruct((B, npad, mpad), dtype),
        scratch_shapes=[pltpu.VMEM((bn, bm), jnp.float32)],
        interpret=interpret,
    )(K1p, t, maskp, up, noise_arr)

    return out[:, :n, :m].reshape(*batch_shape, n, m)
