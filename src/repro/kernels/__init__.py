"""Pallas TPU kernels for the paper's compute hot-spots."""
from .autotune import CANDIDATE_BLOCKS, autotune_blocks
from .gram import rbf_gram_pallas
from .lk_mvm import lk_mvm_fused, lk_mvm_pallas, lk_mvm_two_stage
from .ops import lk_mvm_op, rbf_gram_op
from .ref import lk_mvm_ref, rbf_gram_ref

__all__ = ["rbf_gram_pallas", "lk_mvm_pallas", "lk_mvm_fused",
           "lk_mvm_two_stage", "lk_mvm_op", "rbf_gram_op",
           "lk_mvm_ref", "rbf_gram_ref", "autotune_blocks",
           "CANDIDATE_BLOCKS"]
