"""Pallas TPU kernels for the paper's compute hot-spots."""
from .gram import rbf_gram_pallas
from .lk_mvm import lk_mvm_pallas
from .ops import lk_mvm_op, rbf_gram_op
from .ref import lk_mvm_ref, rbf_gram_ref

__all__ = ["rbf_gram_pallas", "lk_mvm_pallas", "lk_mvm_op", "rbf_gram_op",
           "lk_mvm_ref", "rbf_gram_ref"]
