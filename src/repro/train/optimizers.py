"""Optimizers in pure JAX (no optax offline): AdamW and Adafactor.

State lives in pytrees mirroring the parameters, so it inherits parameter
shardings (ZeRO: with FSDP rules the moments are fully sharded). Moments
dtype is configurable — the 400B-class MoE configs use bf16 moments to fit
the v5e HBM budget (documented in EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["OptConfig", "cosine_lr", "init_opt_state", "apply_update",
           "global_norm", "clip_by_global_norm"]


class OptConfig(NamedTuple):
    name: str = "adamw"            # adamw | adafactor
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moments_dtype: Any = jnp.float32
    # adafactor
    factored_min_dim: int = 128


def cosine_lr(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(math.pi * prog))
    decayed = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.peak_lr * jnp.where(step < cfg.warmup_steps, warm, decayed)


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree, max_norm):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), norm


def _is_factored(shape, cfg):
    return len(shape) >= 2 and shape[-1] >= cfg.factored_min_dim \
        and shape[-2] >= cfg.factored_min_dim


def init_opt_state(params, cfg: OptConfig):
    if cfg.name == "adamw":
        zeros = lambda p: jnp.zeros(p.shape, cfg.moments_dtype)
        return {"m": jax.tree_util.tree_map(zeros, params),
                "v": jax.tree_util.tree_map(zeros, params)}
    if cfg.name == "adafactor":
        def vrow(p):
            if _is_factored(p.shape, cfg):
                return jnp.zeros(p.shape[:-1], cfg.moments_dtype)
            return jnp.zeros(p.shape, cfg.moments_dtype)

        def vcol(p):
            if _is_factored(p.shape, cfg):
                return jnp.zeros((*p.shape[:-2], p.shape[-1]),
                                 cfg.moments_dtype)
            return jnp.zeros((0,), cfg.moments_dtype)

        return {"vr": jax.tree_util.tree_map(vrow, params),
                "vc": jax.tree_util.tree_map(vcol, params)}
    raise ValueError(cfg.name)


def _adamw_leaf(p, g, m, v, lr, step, cfg: OptConfig):
    g32 = g.astype(jnp.float32)
    m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
    v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g32 * g32
    mhat = m32 / (1 - cfg.b1 ** step)
    vhat = v32 / (1 - cfg.b2 ** step)
    upd = mhat / (jnp.sqrt(vhat) + cfg.eps)
    if p.ndim >= 2:  # no weight decay on norms/biases
        upd = upd + cfg.weight_decay * p.astype(jnp.float32)
    newp = p.astype(jnp.float32) - lr * upd
    return newp.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)


def _adafactor_leaf(p, g, vr, vc, lr, step, cfg: OptConfig):
    g32 = g.astype(jnp.float32)
    decay = 1.0 - (step ** -0.8)
    if _is_factored(p.shape, cfg):
        r = decay * vr.astype(jnp.float32) + (1 - decay) * jnp.mean(
            g32 * g32, axis=-1)
        c = decay * vc.astype(jnp.float32) + (1 - decay) * jnp.mean(
            g32 * g32, axis=-2)
        rc = r[..., None] * c[..., None, :]
        denom = jnp.sqrt(rc / jnp.maximum(
            jnp.mean(r, axis=-1)[..., None, None], 1e-30)) + cfg.eps
        upd = g32 / denom
        new_vr, new_vc = r.astype(vr.dtype), c.astype(vc.dtype)
    else:
        v = decay * vr.astype(jnp.float32) + (1 - decay) * g32 * g32
        upd = g32 / (jnp.sqrt(v) + cfg.eps)
        new_vr, new_vc = v.astype(vr.dtype), vc
    # update clipping (Adafactor RMS-1 rule)
    rms = jnp.sqrt(jnp.mean(upd * upd) + 1e-30)
    upd = upd / jnp.maximum(1.0, rms)
    if p.ndim >= 2:
        upd = upd + cfg.weight_decay * p.astype(jnp.float32)
    newp = p.astype(jnp.float32) - lr * upd
    return newp.astype(p.dtype), new_vr, new_vc


def apply_update(params, grads, opt_state, step, cfg: OptConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    lr = cosine_lr(cfg, step)
    stepf = step.astype(jnp.float32) + 1.0
    if cfg.name == "adamw":
        out = jax.tree_util.tree_map(
            lambda p, g, m, v: _adamw_leaf(p, g, m, v, lr, stepf, cfg),
            params, grads, opt_state["m"], opt_state["v"])
        newp = jax.tree_util.tree_map(lambda t: t[0], out,
                                      is_leaf=lambda x: isinstance(x, tuple))
        newm = jax.tree_util.tree_map(lambda t: t[1], out,
                                      is_leaf=lambda x: isinstance(x, tuple))
        newv = jax.tree_util.tree_map(lambda t: t[2], out,
                                      is_leaf=lambda x: isinstance(x, tuple))
        return newp, {"m": newm, "v": newv}, {"lr": lr, "grad_norm": gnorm}
    if cfg.name == "adafactor":
        out = jax.tree_util.tree_map(
            lambda p, g, vr, vc: _adafactor_leaf(p, g, vr, vc, lr, stepf, cfg),
            params, grads, opt_state["vr"], opt_state["vc"])
        newp = jax.tree_util.tree_map(lambda t: t[0], out,
                                      is_leaf=lambda x: isinstance(x, tuple))
        newvr = jax.tree_util.tree_map(lambda t: t[1], out,
                                       is_leaf=lambda x: isinstance(x, tuple))
        newvc = jax.tree_util.tree_map(lambda t: t[2], out,
                                       is_leaf=lambda x: isinstance(x, tuple))
        return newp, {"vr": newvr, "vc": newvc}, {"lr": lr, "grad_norm": gnorm}
    raise ValueError(cfg.name)
