"""Distributed train/serve step builders (pjit + logical sharding rules).

``make_train_step`` returns a jit-compiled step plus the sharding pytrees the
launcher / dry-run needs: state shardings (params, optimizer moments, step)
and per-input batch shardings. Features:

  * microbatched gradient accumulation (scan) — also the compute/comm overlap
    mechanism: XLA overlaps the reduce of microbatch i with compute of i+1;
  * remat at layer granularity (inside the models);
  * optional int8 gradient compression across the 'pod' axis (shard_map);
  * donated state for in-place updates.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..distributed.sharding import (batch_shardings,
                                    logical_to_pspec, make_constrain,
                                    param_shardings, rules_for,
                                    set_active_mesh)
from .optimizers import OptConfig, apply_update, init_opt_state

__all__ = ["TrainState", "make_train_step", "make_serve_steps", "TrainSetup"]


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jnp.ndarray


class TrainSetup(NamedTuple):
    step_fn: Any                 # jitted (state, batch) -> (state, metrics)
    state_shardings: Any
    batch_shardings: Any
    init_state: Any              # (key) -> TrainState (abstract-safe)
    lowered: Any = None


def _state_logical(model, opt_cfg: OptConfig):
    logical = model.logical
    if opt_cfg.name == "adamw":
        opt_logical = {"m": logical, "v": logical}
    else:
        # factored moments: row moment drops the last axis, col the 2nd-last
        def row(l):
            return l[:-1] if isinstance(l, tuple) else l

        def col(l):
            return (*l[:-2], l[-1]) if isinstance(l, tuple) and len(l) >= 2 else l

        tmap = functools.partial(
            jax.tree_util.tree_map,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, tuple, type(None))) for e in x))
        opt_logical = {"vr": tmap(row, logical), "vc": tmap(col, logical)}
    return logical, opt_logical


def make_train_step(model, mesh, opt_cfg: OptConfig | None = None,
                    grad_accum: int = 1, rules=None, act_rules=None,
                    donate: bool = True):
    """Build the jitted SPMD train step for a model on a mesh."""
    cfg = model.cfg
    opt_cfg = opt_cfg or OptConfig()
    rules = rules if rules is not None else rules_for(cfg)
    constrain = make_constrain(mesh, act_rules)
    set_active_mesh(mesh)  # enables shard_map layer paths (MoE EP)

    # ---- shardings --------------------------------------------------------
    def _abstract_params():
        return jax.eval_shape(lambda k: model.init(k), jax.random.key(0))

    p_shapes = _abstract_params()
    p_sh = param_shardings(model.logical, mesh, rules, p_shapes)
    logical, opt_logical = _state_logical(model, opt_cfg)
    o_shapes = jax.eval_shape(
        lambda: init_opt_state(p_shapes, opt_cfg))
    o_sh = jax.tree_util.tree_map(
        lambda l, s: NamedSharding(mesh, logical_to_pspec(l, rules, mesh,
                                                          s.shape)),
        opt_logical, o_shapes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, tuple, type(None))) for e in x))
    state_sh = TrainState(params=p_sh, opt_state=o_sh,
                          step=NamedSharding(mesh, P()))

    # ---- step function ----------------------------------------------------
    def loss_fn(params, batch):
        return model.loss(params, batch, constrain=constrain)

    def train_step(state: TrainState, batch):
        if grad_accum > 1:
            def micro(carry, mb):
                gsum, lsum = carry
                l, g = jax.value_and_grad(loss_fn)(state.params, mb)
                gsum = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + l), None

            gzero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            # (B, ...) -> (accum, B/accum, ...) with microbatch rows STRIDED
            # across the batch so each microbatch stays evenly sharded over
            # the data axes (a plain leading reshape would concentrate each
            # microbatch on 1/accum of the data shards).
            mbs = jax.tree_util.tree_map(
                lambda x: x.reshape(x.shape[0] // grad_accum, grad_accum,
                                    *x.shape[1:]).swapaxes(0, 1), batch)
            (grads, loss), _ = jax.lax.scan(micro, (gzero, 0.0), mbs)
            grads = jax.tree_util.tree_map(lambda g: g / grad_accum, grads)
            loss = loss / grad_accum
        else:
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
        new_params, new_opt, metrics = apply_update(
            state.params, grads, state.opt_state, state.step, opt_cfg)
        metrics["loss"] = loss
        new_state = TrainState(params=new_params, opt_state=new_opt,
                               step=state.step + 1)
        return new_state, metrics

    def init_state(key):
        params = model.init(key)
        return TrainState(params=params,
                          opt_state=init_opt_state(params, opt_cfg),
                          step=jnp.zeros((), jnp.int32))

    step_fn = jax.jit(
        train_step,
        in_shardings=(state_sh, None),
        out_shardings=(state_sh, None),
        donate_argnums=(0,) if donate else (),
    )
    return TrainSetup(step_fn=step_fn, state_shardings=state_sh,
                      batch_shardings=None, init_state=init_state)


def make_serve_steps(model, mesh, rules=None, max_len: int = 2048):
    """Jitted prefill and decode steps with sharded params and KV caches.

    Serving defaults to SERVE_RULES: weights resident (no per-token FSDP
    gathers), MoE/MLP inner dims spread over both axes so the 480B-class
    experts fit HBM without optimizer state (§Perf hillclimb 2).
    """
    from ..distributed.sharding import SERVE_RULES

    cfg = model.cfg
    rules = rules if rules is not None else SERVE_RULES
    constrain = make_constrain(mesh)
    set_active_mesh(mesh)

    p_shapes = jax.eval_shape(lambda k: model.init(k), jax.random.key(0))
    p_sh = param_shardings(model.logical, mesh, rules, p_shapes)

    def cache_shardings(batch, prefer: str = "time"):
        """prefer="time": T-axis over 'model' (decode steady state — softmax
        stats psums instead of score partials). prefer="width": natural
        prefill output layout (heads/width over 'model'); the handoff
        reshards once, amortised over the whole decode."""
        shapes = jax.eval_shape(lambda: model.init_cache(batch, max_len))
        dp = tuple(a for a in ("pod", "data") if a in mesh.shape)

        def one(sds):
            # cache leaves: (L, B, ...) -> batch over dp; scalars replicated
            if sds.ndim < 2:
                return NamedSharding(mesh, P())
            prod = 1
            kept = []
            for a in dp:
                if sds.shape[1] % (prod * mesh.shape[a]) == 0:
                    kept.append(a)
                    prod *= mesh.shape[a]
            # Shard the model dimension of the cache over 'model': prefer the
            # kv-heads axis of (L, B, T, H, Dh); fall back to head_dim (GQA
            # archs where kv_heads < model-axis size), then to any trailing
            # divisible dim (rnn width, wkv heads, ...). Without this a 32k
            # KV cache replicates 16x over the model axis (~50 GiB/device).
            tp = mesh.shape.get("model", 1)
            rest = [None] * (sds.ndim - 2)
            if not jnp.issubdtype(sds.dtype, jnp.integer):
                # Preference (§Perf hillclimb 2, iter 3): shard the TIME axis
                # of (L,B,T,H,Dh) caches over 'model' — decode attention then
                # psums tiny softmax stats instead of (B,H,1,T) partials or
                # replicating the cache; fall back kv-heads, then head_dim.
                order = []
                if sds.ndim >= 5:
                    if prefer == "time":
                        order.append(0)               # T axis
                    order.append(sds.ndim - 4)        # kv-heads axis
                order.append(sds.ndim - 3)            # head_dim / width axis
                order += [i for i in range(sds.ndim - 2)
                          if i not in order and i != 0]
                for i in order:
                    if 0 <= i < sds.ndim - 2 and sds.shape[i + 2] % tp == 0 \
                            and sds.shape[i + 2] >= tp:
                        rest[i] = "model"
                        break
            return NamedSharding(
                mesh, P(None, tuple(kept) if kept else None, *rest))

        return jax.tree_util.tree_map(one, shapes)

    def prefill(params, batch):
        return model.prefill(params, batch, max_len, constrain=constrain)

    def decode_step(params, cache, tokens):
        return model.decode_step(params, cache, tokens, constrain=constrain)

    return {
        "param_shardings": p_sh,
        "cache_shardings": cache_shardings,
        "prefill": prefill,
        "decode_step": decode_step,
        "constrain": constrain,
    }
