"""GPipe-style pipeline parallelism over a mesh axis (default: 'pod').

The layer stack is split into ``num_stages`` contiguous stages; microbatches
stream through stages with jax.lax.ppermute boundary transfers inside
shard_map. Schedule: standard GPipe fill-drain over T = M + S - 1 ticks
(M microbatches, S stages); bubble fraction (S-1)/T.

This is the forward pipeline (inference / microbatched forward); the trainer
uses it with ``jax.grad`` through the shard_map for small stage counts
(S = 2 pods), where the fill-drain bubble at M >= 8 costs < 12%.

Each stage holds ``layers/S`` of the stacked layer params (leading-dim
shard), which is exactly a P('pod', ...) sharding of the scanned parameter
stack — so switching DP <-> PP over the pod axis is a resharding, not a
repartitioning of the program.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from ..compat import shard_map

__all__ = ["pipelined_forward"]


def pipelined_forward(mesh: Mesh, layer_fn, num_microbatches: int,
                      axis: str = "pod"):
    """Build fn(stage_params, x) running layer_fn stacks as a pipeline.

    layer_fn(stage_params, x_micro) -> y_micro applies ONE stage (its share
    of layers, itself a lax.scan) to one microbatch.

    stage_params: pytree with leading dim = num_stages (sharded over
    ``axis``); x: (M * mb, ...) batch split into M microbatches.
    """
    S = mesh.shape[axis]
    M = num_microbatches

    def body(stage_params, x):
        # stage_params: this stage's params (leading dim 1) — squeeze
        sp = jax.tree_util.tree_map(lambda a: a[0], stage_params)
        stage = jax.lax.axis_index(axis)
        mb = x.shape[0] // M
        xs = x.reshape(M, mb, *x.shape[1:])
        T = M + S - 1

        def tick(carry, t):
            buf, outs = carry
            # stage s works on microbatch (t - s) when 0 <= t - s < M
            mb_idx = t - stage
            active = (mb_idx >= 0) & (mb_idx < M)
            # first stage reads fresh input; others read the permuted buffer
            x_in = jnp.where(stage == 0,
                             xs[jnp.clip(mb_idx, 0, M - 1)], buf)
            y = layer_fn(sp, x_in)
            y = jnp.where(active, y, jnp.zeros_like(y))
            # pass activation to the next stage
            buf_next = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % S) for i in range(S)])
            # last stage records its finished microbatch
            out_idx = jnp.clip(t - (S - 1), 0, M - 1)
            is_last = stage == S - 1
            take = active & is_last
            outs = jax.lax.dynamic_update_index_in_dim(
                outs, jnp.where(take, y, outs[out_idx]), out_idx, 0)
            return (buf_next, outs), None

        buf0 = jnp.zeros_like(xs[0])
        outs0 = jnp.zeros_like(xs)
        (_, outs), _ = jax.lax.scan(tick, (buf0, outs0), jnp.arange(T))
        # broadcast results from the last stage to all stages (psum of a
        # one-hot masked buffer keeps outs replicated over the axis)
        outs = jax.lax.psum(
            jnp.where(stage == S - 1, outs, jnp.zeros_like(outs)), axis)
        return outs.reshape(x.shape)

    def wrapped(stage_params, x):
        pspecs = jax.tree_util.tree_map(lambda _: P(axis), stage_params)
        return shard_map(body, mesh=mesh,
                         in_specs=(pspecs, P()), out_specs=P(),
                         check_vma=False)(stage_params, x)

    return wrapped
