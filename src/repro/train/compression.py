"""Gradient compression for cross-pod all-reduce: int8 + error feedback.

At 2+ pods the gradient all-reduce crosses the pod boundary (DCN or optical
ICI), which is the scarcest bandwidth in the system. We quantise each leaf to
int8 with a per-leaf scale before the psum over 'pod' and keep the
quantisation residual locally ("error feedback", Seide et al. 2014), adding
it to the next step's gradient — preserving convergence while cutting
cross-pod bytes 4x vs fp32 / 2x vs bf16.

Implemented over shard_map on the 'pod' axis; inside a pod the gradient is
already reduced by the normal SPMD partitioning over 'data'.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from ..compat import shard_map

__all__ = ["quantize_leaf", "dequantize_leaf", "compressed_psum_tree",
           "make_compressed_allreduce"]


def quantize_leaf(g, error):
    """int8 symmetric quantisation with carried error feedback."""
    g32 = g.astype(jnp.float32) + error
    scale = jnp.max(jnp.abs(g32)) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    new_error = g32 - q.astype(jnp.float32) * scale
    return q, scale, new_error


def dequantize_leaf(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum_tree(grads, errors, axis_name: str):
    """Quantise -> psum(int32) -> dequantise, leaf-wise, with error feedback.

    Returns (mean-reduced grads fp32, new error pytree).
    """
    n = jax.lax.psum(1, axis_name)

    def leaf(g, e):
        q, scale, new_e = quantize_leaf(g, e)
        # sum int8 payloads in int32 to avoid overflow across <=128 pods
        q_sum = jax.lax.psum(q.astype(jnp.int32), axis_name)
        # scales differ per pod: reduce with max for a conservative shared
        # scale; rescale local contribution accordingly before summing would
        # need a second pass, so we psum (q * scale) at fp accuracy instead
        # when scales diverge. Single-scale fast path:
        s_max = jax.lax.pmax(scale, axis_name)
        g_hat = q_sum.astype(jnp.float32) * s_max / n
        return g_hat, new_e

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(errors)
    out = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_e = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    return new_g, new_e


def make_compressed_allreduce(mesh: Mesh):
    """shard_map-wrapped compressed all-reduce over the 'pod' axis.

    grads/errors leaves must be replicated over 'pod' inputs representing
    per-pod partial gradients (fully sharded over remaining axes is fine).
    """
    if "pod" not in mesh.shape:
        raise ValueError("compressed all-reduce needs a 'pod' mesh axis")

    def fn(grads, errors):
        return compressed_psum_tree(grads, errors, "pod")

    def wrapped(grads, errors):
        specs = jax.tree_util.tree_map(lambda _: P(), grads)
        espec = jax.tree_util.tree_map(lambda _: P(), errors)
        return shard_map(fn, mesh=mesh, in_specs=(specs, espec),
                         out_specs=(specs, espec), check_vma=False)(
                             grads, errors)

    return wrapped
