"""Optimizers and distributed train/serve step builders."""
from .optimizers import OptConfig, apply_update, cosine_lr, init_opt_state
from .trainer import TrainSetup, TrainState, make_serve_steps, make_train_step
