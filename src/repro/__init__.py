"""repro: Latent Kronecker GPs for learning-curve prediction, production JAX.

Layout:
  repro.core        — the paper's model (LKGP) and its linear algebra
  repro.kernels     — Pallas TPU kernels (lk_mvm, gram) + jnp oracles
  repro.models      — the 10 assigned LM architectures (pure JAX)
  repro.configs     — published configs + reduced smoke variants
  repro.data        — learning-curve prior + token pipeline
  repro.train       — optimizers, train/serve step builders
  repro.distributed — sharding rules, collectives, distributed LKGP
  repro.checkpoint  — fault-tolerant checkpoint manager
  repro.autotune    — LKGP-driven early-stopping scheduler
  repro.baselines   — amortized transformer baseline + head-to-head eval
  repro.amortize    — hyper-parameter amortizer (warm starts for fit/refit)
  repro.launch      — production meshes, multi-pod dry-run, roofline
"""
__version__ = "1.0.0"
