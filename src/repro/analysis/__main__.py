"""``python -m repro.analysis`` — the repo's static-analysis gate.

Default run = AST lint rules over the given paths + the Pallas VMEM
candidate-space audit (both pure stdlib, no jax import). ``--jaxpr`` adds
the traced-program audits (f64 / host callbacks / retrace) which import
jax and take a few seconds. Exit status is 0 iff no *new* findings — i.e.
nothing unsuppressed and unbaselined, and every auditor invariant holds.

Typical invocations::

    python -m repro.analysis src/ --baseline analysis_baseline.json
    python -m repro.analysis src/ --jaxpr --baseline analysis_baseline.json
    python -m repro.analysis src/ --write-baseline analysis_baseline.json
    python -m repro.analysis path/to/file.py --format json
"""
from __future__ import annotations

import argparse
import json
import sys

from .runner import (analyze_paths, filter_baseline, format_report,
                     load_baseline, write_baseline)
from .vmem import audit_candidate_space, best_fitting_blocks


def _run_vmem_audit(out) -> int:
    """Audit the autotuner's candidate space against the VMEM budget.

    The raw {64, 128, 256} sweep is *expected* to contain oversized
    combinations at large (n, m) — the invariant we enforce is that the
    VMEM-filtered chooser never returns one of them: every shape bucket
    either has a fitting best pair or is explicitly marked as requiring
    the two-stage fallback. A violation here means vmem.py and
    kernels/autotune.py have drifted apart.
    """
    from .vmem import fused_vmem_breakdown

    rows = audit_candidate_space()
    buckets = [2 ** k for k in range(3, 14)]
    failures = 0
    no_fit = 0
    for n in buckets:
        for m in buckets:
            for prec in ("f32", "bf16"):
                pair = best_fitting_blocks(n, m, precision=prec)
                if pair is None:
                    no_fit += 1   # fine: two-stage fallback handles it
                elif not fused_vmem_breakdown(n, m, *pair, prec).fits():
                    failures += 1
                    print(f"vmem: FILTER BUG — chooser returned oversized "
                          f"{pair} for (n={n}, m={m}, {prec})", file=out)
    print(f"vmem: {len(rows)} oversized (shape, candidate) combinations in "
          f"the raw {{64,128,256}} sweep; {no_fit} (shape, precision) "
          "bucket(s) require the two-stage fallback; filtered chooser "
          f"emitted {'no' if not failures else failures} oversized pair(s).",
          file=out)
    return failures


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="JAX/Pallas-aware static analysis (AST lints, VMEM "
                    "budget audit, optional jaxpr audits).")
    parser.add_argument("paths", nargs="*", default=["src"],
                        help="files or directories to lint (default: src)")
    parser.add_argument("--baseline", metavar="FILE",
                        help="JSON baseline of grandfathered fingerprints")
    parser.add_argument("--write-baseline", metavar="FILE",
                        help="write current findings as the new baseline "
                             "and exit 0")
    parser.add_argument("--format", choices=("text", "json"), default="text")
    parser.add_argument("--jaxpr", action="store_true",
                        help="also run the jaxpr auditors (imports jax)")
    parser.add_argument("--no-vmem", action="store_true",
                        help="skip the Pallas VMEM candidate-space audit")
    args = parser.parse_args(argv)

    paths = args.paths or ["src"]
    findings = analyze_paths(paths)

    if args.write_baseline:
        write_baseline(findings, args.write_baseline)
        print(f"wrote {len(findings)} finding(s) to {args.write_baseline}")
        return 0

    baseline: set[str] = set()
    if args.baseline:
        try:
            baseline = load_baseline(args.baseline)
        except FileNotFoundError:
            print(f"warning: baseline {args.baseline} not found; "
                  "treating all findings as new", file=sys.stderr)
    new, baselined = filter_baseline(findings, baseline)

    failed = bool(new)
    if args.format == "json":
        print(json.dumps({"findings": [f.to_json() for f in new],
                          "baselined": baselined}, indent=2))
    else:
        print(format_report(new, baselined))

    if not args.no_vmem:
        failed |= bool(_run_vmem_audit(sys.stdout))

    if args.jaxpr:
        # Imported lazily: jax is heavy and the lint layer must work
        # without it (e.g. in a minimal CI container).
        from .jaxpr_audit import run_all_audits
        failures = run_all_audits(verbose=True)
        failed |= bool(failures)

    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
