"""Apply the lint rules to files, honouring suppressions and a baseline.

Suppression syntax (checked per finding):

* ``# lint: disable=RA103`` at the end of the offending line suppresses
  the listed rule IDs (comma-separated; ``all`` suppresses everything) on
  that line only.
* ``# lint: disable-file=RA103`` anywhere in the file suppresses the
  listed rules for the whole module (used when a file is *designed* around
  a pattern, e.g. the Python-driver L-BFGS loop).

Baseline: a committed JSON file of fingerprints for grandfathered
findings. Fingerprints are line-number independent — ``rule : path :
stripped source line : occurrence-index`` hashed — so unrelated edits
above a finding do not invalidate the baseline, while any edit to the
offending line surfaces it again.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
from collections import Counter

from .rules import ALL_RULES, Finding, ModuleContext, Rule

__all__ = ["analyze_source", "analyze_file", "analyze_paths",
           "load_baseline", "write_baseline", "filter_baseline",
           "format_report"]

_DISABLE_RE = re.compile(r"#\s*lint:\s*disable=([A-Za-z0-9_,\s]+)")
_DISABLE_FILE_RE = re.compile(r"#\s*lint:\s*disable-file=([A-Za-z0-9_,\s]+)")


def _parse_ids(match: re.Match) -> set[str]:
    return {p.strip() for p in match.group(1).split(",") if p.strip()}


def _suppressions(lines: list[str]) -> tuple[dict[int, set[str]], set[str]]:
    """(per-line rule-ID sets keyed by 1-based line, file-level set)."""
    per_line: dict[int, set[str]] = {}
    file_level: set[str] = set()
    for i, line in enumerate(lines, start=1):
        m = _DISABLE_FILE_RE.search(line)
        if m:
            file_level |= _parse_ids(m)
            continue
        m = _DISABLE_RE.search(line)
        if m:
            per_line[i] = _parse_ids(m)
    return per_line, file_level


def _fingerprint(finding: Finding, lines: list[str],
                 occurrence: int) -> str:
    text = ""
    if 1 <= finding.line <= len(lines):
        text = lines[finding.line - 1].strip()
    raw = f"{finding.rule}:{finding.path}:{text}:{occurrence}"
    return hashlib.sha1(raw.encode()).hexdigest()[:16]


def analyze_source(source: str, path: str,
                   rules: tuple[Rule, ...] = ALL_RULES) -> list[Finding]:
    """Run the rules over one module's source; returns surviving findings.

    Suppressed findings are dropped; fingerprints are attached. Syntax
    errors come back as a single RA000 error finding rather than raising —
    the analyzer must be able to report on a broken tree.
    """
    try:
        ctx = ModuleContext.from_source(source, path)
    except SyntaxError as e:
        return [Finding(rule="RA000", severity="error", path=path,
                        line=e.lineno or 1, col=e.offset or 0,
                        message=f"syntax error: {e.msg}",
                        fingerprint=hashlib.sha1(
                            f"RA000:{path}".encode()).hexdigest()[:16])]
    per_line, file_level = _suppressions(ctx.lines)
    findings: list[Finding] = []
    for rule in rules:
        for f in rule.check(ctx):
            if rule.id in file_level or "all" in file_level:
                continue
            line_ids = per_line.get(f.line, set())
            if rule.id in line_ids or "all" in line_ids:
                continue
            findings.append(f)
    findings.sort(key=lambda f: (f.line, f.col, f.rule))
    # Occurrence index disambiguates identical lines (e.g. repeated
    # `float(x)` in one file) so baseline entries stay one-to-one.
    seen: Counter = Counter()
    for f in findings:
        text = ctx.lines[f.line - 1].strip() if f.line <= len(ctx.lines) else ""
        key = (f.rule, text)
        f.fingerprint = _fingerprint(f, ctx.lines, seen[key])
        seen[key] += 1
    return findings


def analyze_file(path: str, rules: tuple[Rule, ...] = ALL_RULES,
                 root: str | None = None) -> list[Finding]:
    with open(path, encoding="utf-8") as fh:
        source = fh.read()
    rel = os.path.relpath(path, root) if root else path
    return analyze_source(source, rel.replace(os.sep, "/"), rules)


def _iter_py_files(paths: list[str]):
    for p in paths:
        if os.path.isfile(p):
            yield p
        else:
            for dirpath, dirnames, filenames in os.walk(p):
                dirnames[:] = sorted(d for d in dirnames
                                     if d != "__pycache__"
                                     and not d.startswith("."))
                for name in sorted(filenames):
                    if name.endswith(".py"):
                        yield os.path.join(dirpath, name)


def analyze_paths(paths: list[str], rules: tuple[Rule, ...] = ALL_RULES,
                  root: str | None = None) -> list[Finding]:
    """Lint every ``*.py`` under the given files/directories."""
    findings: list[Finding] = []
    for path in _iter_py_files(paths):
        findings.extend(analyze_file(path, rules, root=root))
    return findings


# --------------------------------------------------------------------------
# baseline
# --------------------------------------------------------------------------
def load_baseline(path: str) -> set[str]:
    """Fingerprint set from a baseline JSON file ({} -> empty set)."""
    with open(path, encoding="utf-8") as fh:
        data = json.load(fh)
    return {entry["fingerprint"] for entry in data.get("findings", [])}


def write_baseline(findings: list[Finding], path: str) -> None:
    data = {
        "version": 1,
        "comment": ("Grandfathered repro.analysis findings. Regenerate with "
                    "`python -m repro.analysis src/ --write-baseline "
                    "analysis_baseline.json` after reviewing that every "
                    "entry is justified."),
        "findings": [
            {"fingerprint": f.fingerprint, "rule": f.rule, "path": f.path,
             "line": f.line, "message": f.message}
            for f in findings
        ],
    }
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(data, fh, indent=2)
        fh.write("\n")


def filter_baseline(findings: list[Finding],
                    baseline: set[str]) -> tuple[list[Finding], int]:
    """(new findings not in the baseline, count of baselined ones)."""
    new = [f for f in findings if f.fingerprint not in baseline]
    return new, len(findings) - len(new)


def format_report(findings: list[Finding], baselined: int = 0) -> str:
    lines = [f.format() for f in findings]
    errors = sum(1 for f in findings if f.severity == "error")
    warnings = len(findings) - errors
    lines.append(f"{len(findings)} finding(s): {errors} error(s), "
                 f"{warnings} warning(s)"
                 + (f"; {baselined} baselined" if baselined else ""))
    return "\n".join(lines)
