"""Jaxpr auditors: structural invariants of the traced programs.

The AST layer (:mod:`repro.analysis.rules`) sees source; this layer sees
what JAX actually traces, which is where the paper's complexity story
lives or dies. Three invariants:

* **f64-free** — with f32 inputs, no equation converts to float64 and no
  output is float64. A stray `np.float64` constant or Python-scalar
  promotion under ``jax_enable_x64`` doubles memory traffic and halves
  MXU throughput; the O(n²+m²) space claim assumes f32. Audited over
  ``make_mll`` (dense + iterative), the fit objective, ``Posterior.final``,
  and the fused Pallas MVM wrapper.
* **host-callback-free** — no ``pure_callback`` / ``io_callback`` /
  ``debug_callback`` equations: a callback inside the solver forces a
  device→host round trip per CG iteration.
* **retrace-free refits** — two ``refit`` rounds on same-shaped data must
  reuse ONE compiled objective (``core.state._VG_CACHE`` entry with jit
  cache size 1). Before PR 6 every refit rebuilt a fresh closure and
  recompiled — O(seconds) per round of pure tracing overhead.

Requires jax; the CLI keeps it behind ``--jaxpr`` so the lint layer can
run in minimal environments.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["iter_eqns", "find_f64", "find_host_callbacks", "audit_mll",
           "audit_fit_objective", "audit_posterior_final",
           "audit_fused_mvm", "audit_solvers", "audit_guarded_solves",
           "audit_dist_fused_mvm", "audit_refit_retrace",
           "audit_amortizer", "run_all_audits"]

_CALLBACK_PRIMS = ("pure_callback", "io_callback", "debug_callback",
                   "callback")


def iter_eqns(jaxpr):
    """All equations of a (closed) jaxpr, recursing into sub-jaxprs."""
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for sub in _sub_jaxprs(v):
                yield from iter_eqns(sub)


def _sub_jaxprs(value):
    import jax.core as jcore
    closed = getattr(jcore, "ClosedJaxpr", ())
    raw = getattr(jcore, "Jaxpr", ())
    if isinstance(value, (closed, raw)):
        yield value
    elif isinstance(value, (list, tuple)):
        for v in value:
            yield from _sub_jaxprs(v)


def _is_f64(aval) -> bool:
    dt = getattr(aval, "dtype", None)
    return dt is not None and dt == np.float64


def find_f64(jaxpr) -> list[str]:
    """Equations that introduce float64 (conversions or f64 outputs)."""
    bad = []
    for eqn in iter_eqns(jaxpr):
        if (eqn.primitive.name == "convert_element_type"
                and eqn.params.get("new_dtype") == np.float64):
            bad.append(f"convert_element_type -> f64: {eqn}")
            continue
        for var in eqn.outvars:
            if _is_f64(getattr(var, "aval", None)):
                bad.append(f"f64 output from {eqn.primitive.name}: {eqn}")
                break
    inner = getattr(jaxpr, "jaxpr", jaxpr)
    for var in inner.outvars:
        if _is_f64(getattr(var, "aval", None)):
            bad.append("jaxpr output is f64")
    return bad


def find_host_callbacks(jaxpr) -> list[str]:
    return [f"host callback: {eqn.primitive.name}"
            for eqn in iter_eqns(jaxpr)
            if eqn.primitive.name in _CALLBACK_PRIMS]


# --------------------------------------------------------------------------
# synthetic problem shared by the audits (small: tracing only, no solves)
# --------------------------------------------------------------------------
def _problem(n=8, m=6, d=3, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, d)).astype(np.float32)
    t = np.linspace(0.1, 1.0, m).astype(np.float32)
    Y = rng.normal(size=(n, m)).astype(np.float32)
    mask = (rng.random((n, m)) < 0.8).astype(np.float32)
    mask[:, 0] = 1.0
    return X, t, Y, mask


def _audit_jaxpr(name: str, jaxpr) -> list[str]:
    return ([f"{name}: {msg}" for msg in find_f64(jaxpr)]
            + [f"{name}: {msg}" for msg in find_host_callbacks(jaxpr)])


def audit_mll() -> list[str]:
    """Dense and iterative MLLs are f64- and callback-free on f32 input."""
    from repro.core.engines import get_engine, make_mll
    from repro.core.state import LKGPConfig, init_params
    from repro.core.slq import rademacher_probes

    X, t, Y, mask = _problem()
    failures = []
    for backend, method in (("dense", "cholesky"), ("iterative", "iterative")):
        cfg = LKGPConfig(mll_method=method)
        engine = get_engine(backend)
        mll = make_mll(cfg, engine)
        params = init_params(X.shape[1], jnp.float32)
        probes = (None if engine.exact else rademacher_probes(
            # Trace-only fixtures in separate audits; streams never mix.
            jax.random.PRNGKey(0),  # lint: disable=RA101
            cfg.slq_probes, jnp.asarray(mask), jnp.float32))
        jaxpr = jax.make_jaxpr(
            lambda p, x, tt, y, mk: mll(p, x, tt, y, mk, probes))(
                params, X, t, Y, mask)
        failures += _audit_jaxpr(f"make_mll[{backend}]", jaxpr)
    return failures


def audit_fit_objective() -> list[str]:
    """The cached fit objective (value+grad) is f64/callback-free."""
    from repro.core.engines import get_engine
    from repro.core.state import LKGPConfig, _cached_fit_vg, init_params
    from repro.core.slq import rademacher_probes

    X, t, Y, mask = _problem()
    failures = []
    for backend, method in (("dense", "cholesky"), ("iterative", "iterative")):
        cfg = LKGPConfig(mll_method=method)
        engine = get_engine(backend)
        vg = _cached_fit_vg(cfg, engine, X.shape[1])
        params = init_params(X.shape[1], jnp.float32)
        probes = (None if engine.exact else rademacher_probes(
            # Trace-only fixtures in separate audits; streams never mix.
            jax.random.PRNGKey(0),  # lint: disable=RA101
            cfg.slq_probes, jnp.asarray(mask), jnp.float32))
        jaxpr = jax.make_jaxpr(
            lambda p, x, tt, y, mk: vg(p, x, tt, y, mk, probes))(
                params, X, t, Y, mask)
        failures += _audit_jaxpr(f"fit_objective[{backend}]", jaxpr)
    return failures


def audit_posterior_final() -> list[str]:
    """Posterior.final's traced computation is f64/callback-free.

    The engine is passed explicitly: Posterior.__init__ otherwise counts
    observations with host numpy, which cannot be traced.
    """
    from repro.core.engines import get_engine
    from repro.core.posterior import Posterior
    from repro.core.state import LKGPConfig, fit

    X, t, Y, mask = _problem()
    state = fit(X, t, Y, mask, LKGPConfig(lbfgs_iters=2))
    engine = get_engine("dense")

    def final_of(Y_):
        import dataclasses
        st = dataclasses.replace(state, Y=Y_)
        mean, var = Posterior(st, engine=engine).final()
        return mean, var

    jaxpr = jax.make_jaxpr(final_of)(jnp.asarray(Y, jnp.float32))
    return _audit_jaxpr("Posterior.final", jaxpr)


def audit_fused_mvm() -> list[str]:
    """The fused Pallas MVM wrapper is f64/callback-free at f32."""
    from repro.kernels.lk_mvm import lk_mvm_fused

    rng = np.random.default_rng(0)
    n, m, B = 16, 8, 2
    K1 = rng.normal(size=(n, n)).astype(np.float32)
    K2 = rng.normal(size=(m, m)).astype(np.float32)
    mask = (rng.random((n, m)) < 0.8).astype(np.float32)
    u = rng.normal(size=(B, n, m)).astype(np.float32)
    jaxpr = jax.make_jaxpr(
        lambda a, b, c, d: lk_mvm_fused(a, b, c, d, 0.1, block_n=16,
                                        block_m=16, interpret=True))(
                                            K1, K2, mask, u)
    return _audit_jaxpr("lk_mvm_fused", jaxpr)


def audit_solvers() -> list[str]:
    """Every registered solver strategy is f64/callback-free at f32.

    Covers the raw ``sgd_solve`` loop (new in the solver stack — a stray
    f64 constant in the Polyak averaging or the power-iteration lr estimate
    would silently double the per-iteration memory traffic) plus each
    registry strategy's ``solve`` entry point over the latent-Kronecker
    operator.
    """
    from repro.core.mvm import lk_operator
    from repro.core.solvers import get_solver, list_solvers, sgd_solve
    from repro.core.state import LKGPConfig

    rng = np.random.default_rng(0)
    n, m = 8, 6
    K1 = rng.normal(size=(n, n)).astype(np.float32)
    K1 = K1 @ K1.T + n * np.eye(n, dtype=np.float32)
    K2 = rng.normal(size=(m, m)).astype(np.float32)
    K2 = K2 @ K2.T + m * np.eye(m, dtype=np.float32)
    mask = (rng.random((n, m)) < 0.8).astype(np.float32)
    mask[:, 0] = 1.0
    b = (rng.normal(size=(n, m)) * mask).astype(np.float32)

    A = lk_operator(jnp.asarray(K1), jnp.asarray(K2), jnp.asarray(mask), 0.1)
    failures = []
    jaxpr = jax.make_jaxpr(
        lambda rhs: sgd_solve(A, rhs, tol=1e-4, max_iters=32).x)(b)
    failures += _audit_jaxpr("sgd_solve", jaxpr)
    cfg = LKGPConfig(cg_max_iters=32, sgd_iters=32, precond_rank=3)
    for name in list_solvers():
        solver = get_solver(name)
        jaxpr = jax.make_jaxpr(
            lambda rhs: solver.solve(A, rhs, cfg).x)(b)
        failures += _audit_jaxpr(f"solver[{name}].solve", jaxpr)
    return failures


def audit_guarded_solves() -> list[str]:
    """Guarded solves add NOTHING to traced programs.

    The escalation ladder is host-side control flow that must bypass
    itself under tracing. Three structural claims, each per engine entry
    point (``solve_result`` / ``solve_stacked``): with f32 inputs the
    traced program (a) introduces no f64, (b) introduces no host
    callbacks, and (c) is equation-for-equation IDENTICAL to the raw
    unguarded solver's jaxpr — the guard may not even add a no-op
    equation, or the jit caches of guarded and historical programs would
    diverge.
    """
    from repro.core.engines import get_engine
    from repro.core.solvers import resolve_solver
    from repro.core.state import LKGPConfig

    rng = np.random.default_rng(0)
    n, m = 8, 6
    K1 = rng.normal(size=(n, n)).astype(np.float32)
    K1 = K1 @ K1.T + n * np.eye(n, dtype=np.float32)
    K2 = rng.normal(size=(m, m)).astype(np.float32)
    K2 = K2 @ K2.T + m * np.eye(m, dtype=np.float32)
    mask = (rng.random((n, m)) < 0.8).astype(np.float32)
    mask[:, 0] = 1.0
    b = (rng.normal(size=(n, m)) * mask).astype(np.float32)

    engine = get_engine("iterative")
    failures = []
    for policy in ("strict", "escalate", "best_effort"):
        cfg = LKGPConfig(cg_max_iters=32, solve_policy=policy)
        A = engine.operator_from_grams(jnp.asarray(K1), jnp.asarray(K2),
                                       jnp.asarray(mask), 0.1)
        guarded = jax.make_jaxpr(
            lambda rhs: engine.solve_result(A, rhs, cfg).x)(b)
        failures += _audit_jaxpr(f"guarded_solve[{policy}]", guarded)
        raw = jax.make_jaxpr(
            lambda rhs: resolve_solver(cfg, A).solve(A, rhs, cfg).x)(b)
        if str(guarded) != str(raw):
            failures.append(
                f"guarded_solve[{policy}]: traced program differs from the "
                "raw solver's — the guard leaks into traced computations")
        stacked = jax.make_jaxpr(
            lambda rhs: engine.solve_stacked(A, rhs, cfg).x)(
                np.stack([b, b]))
        failures += _audit_jaxpr(f"guarded_solve_stacked[{policy}]", stacked)
    return failures


def _find_pallas_in_shard_map(jaxpr) -> int:
    """Count pallas_call equations nested inside shard_map equations."""
    count = 0
    for eqn in iter_eqns(jaxpr):
        if eqn.primitive.name != "shard_map":
            continue
        for v in eqn.params.values():
            for sub in _sub_jaxprs(v):
                count += sum(1 for e in iter_eqns(sub)
                             if e.primitive.name == "pallas_call")
    return count


def audit_dist_fused_mvm() -> list[str]:
    """DistributedEngine's fused operator: f64-free AND fused per shard.

    Asserts the structural claim behind the n-sharded fused path — the
    traced program contains a ``pallas_call`` *inside* the ``shard_map``
    equation (each shard runs the fused kernel on its row block), and with
    f32 grams nothing promotes to f64.
    """
    from repro.core.engines import DistributedEngine

    rng = np.random.default_rng(0)
    n, m = 32, 8
    K1 = rng.normal(size=(n, n)).astype(np.float32)
    K1 = (K1 @ K1.T / n + np.eye(n)).astype(np.float32)
    K2 = rng.normal(size=(m, m)).astype(np.float32)
    K2 = (K2 @ K2.T / m + np.eye(m)).astype(np.float32)
    mask = (rng.random((n, m)) < 0.8).astype(np.float32)
    u = (rng.normal(size=(n, m)) * mask).astype(np.float32)

    engine = DistributedEngine(fused=True)
    A = engine.operator_from_grams(jnp.asarray(K1), jnp.asarray(K2),
                                   jnp.asarray(mask), 0.1)
    jaxpr = jax.make_jaxpr(A)(jnp.asarray(u))
    failures = _audit_jaxpr("dist_fused_mvm", jaxpr)
    n_fused = _find_pallas_in_shard_map(jaxpr)
    if n_fused < 1:
        failures.append(
            "dist_fused_mvm: no pallas_call traced inside shard_map — the "
            "distributed engine is not running the fused kernel per shard")
    return failures


def audit_refit_retrace() -> list[str]:
    """Two same-shape refits reuse one compiled objective (no retrace)."""
    from repro.core import state as state_mod
    from repro.core.state import LKGPConfig, fit, refit

    X, t, Y, mask = _problem(n=10, m=6)
    state_mod._VG_CACHE.clear()
    cfg = LKGPConfig(mll_method="iterative", lbfgs_iters=3)
    st = fit(X, t, Y, mask, cfg)
    st = refit(st, lbfgs_iters=2)
    st = refit(st, lbfgs_iters=2)
    failures = []
    if len(state_mod._VG_CACHE) != 1:
        failures.append(
            f"refit retrace: expected 1 cached objective, found "
            f"{len(state_mod._VG_CACHE)} — the objective cache key is "
            "unstable across refits")
    for key, vg in state_mod._VG_CACHE.items():
        n_traces = vg._cache_size()
        if n_traces != 1:
            failures.append(
                f"refit retrace: objective for key {key[0]!r} traced "
                f"{n_traces} times across same-shaped refits")
    return failures


def audit_amortizer() -> list[str]:
    """Amortizer forward is f64/callback-free; polish compiles ONCE.

    Two structural claims behind the amortized warm-start path:

    * the amortizer's forward pass (curve encoder -> set encoder -> head)
      stays f32 and callback-free — it runs inside cold-fit hot paths, so
      a stray f64 constant in the Fourier features or the bounded-delta
      head would double its cost silently;
    * ``fit(init="amortized", polish_steps=k)`` and a same-shape
      ``fit_batch`` share ONE ``_POLISH_CACHE`` entry traced exactly once
      — the batched path dispatches the same compiled single-task program
      per task (the bitwise-parity design), so a second trace means the
      cache key is unstable and every batch recompiles.
    """
    from repro.amortize import Amortizer, AmortizerConfig, init_amortizer
    from repro.core import state as state_mod
    from repro.core.state import LKGPConfig, fit, fit_batch

    acfg = AmortizerConfig(d=3, d_model=16, curve_layers=1, set_layers=1,
                           num_heads=2, d_ff=32, fourier_feats=2)
    # Trace-only fixture; never mixes with a training stream.
    am = Amortizer(acfg, init_amortizer(
        jax.random.PRNGKey(0), acfg))  # lint: disable=RA101
    X, t, Y, mask = _problem(n=6, m=5, d=3)
    jaxpr = jax.make_jaxpr(
        lambda x, tt, y, mk: am.init_flat(x, tt, y, mk))(X, t, Y, mask)
    failures = _audit_jaxpr("amortizer.forward", jaxpr)

    state_mod._POLISH_CACHE.clear()
    cfg = LKGPConfig(polish_steps=2)
    fit(X, t, Y, mask, cfg, init="amortized", amortizer=am)
    fit_batch(np.stack([X, X]), t, np.stack([Y, Y]), np.stack([mask, mask]),
              cfg, init="amortized", amortizer=am)
    if len(state_mod._POLISH_CACHE) != 1:
        failures.append(
            f"amortizer polish: expected 1 cached polish program shared by "
            f"fit and fit_batch, found {len(state_mod._POLISH_CACHE)} — the "
            "polish cache key is unstable across entry points")
    for key, pol in state_mod._POLISH_CACHE.items():
        n_traces = pol._cache_size()
        if n_traces != 1:
            failures.append(
                f"amortizer polish: program for key {key[0]!r} traced "
                f"{n_traces} times across fit/fit_batch — the batched path "
                "is not reusing the single-task executable")
    return failures


def run_all_audits(verbose: bool = False) -> list[str]:
    """Run every auditor; returns the list of failure messages."""
    audits = [("mll f64/callback", audit_mll),
              ("fit objective f64/callback", audit_fit_objective),
              ("Posterior.final f64/callback", audit_posterior_final),
              ("fused MVM f64/callback", audit_fused_mvm),
              ("solver stack f64/callback", audit_solvers),
              ("guarded solves f64/callback", audit_guarded_solves),
              ("distributed fused MVM", audit_dist_fused_mvm),
              ("refit retrace", audit_refit_retrace),
              ("amortizer forward + polish reuse", audit_amortizer)]
    failures: list[str] = []
    for name, fn in audits:
        try:
            fails = fn()
        except Exception as e:   # audit infrastructure failure is a failure
            fails = [f"{name}: auditor raised {type(e).__name__}: {e}"]
        failures += fails
        if verbose:
            status = "ok" if not fails else f"FAIL ({len(fails)})"
            print(f"jaxpr audit: {name}: {status}")
    for msg in failures:
        print(f"jaxpr audit failure: {msg}")
    return failures
