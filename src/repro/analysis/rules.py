"""AST lint rules for JAX/Pallas-specific footguns.

Every rule has a stable ID, a severity, and a one-line rationale; the
runner (:mod:`repro.analysis.runner`) applies them to parsed modules,
honours ``# lint: disable=ID`` suppressions, and subtracts a committed
baseline. The rules are deliberately narrow: each one encodes a failure
mode that silently destroys the paper's complexity story (host syncs in
solver loops, f64 promotion, PRNG key reuse) without ever failing a
functional test.

Rule catalogue
--------------
RA101  prng-key-reuse        error    two PRNG keys built from the same
                                      seed expression share randomness
RA102  traced-python-branch  error    Python ``if``/``while`` on a traced
                                      value inside a jitted function
RA103  host-sync-in-loop     warning  ``float()`` / ``.item()`` /
                                      ``np.asarray`` / ``block_until_ready``
                                      inside a Python loop (device sync per
                                      iteration)
RA104  implicit-promotion    warning  identity arithmetic with bare Python
                                      scalars (``* 1.0``, ``+ 0.0``) or the
                                      builtin ``float`` used as a dtype —
                                      silent f64 promotion under x64
RA105  mutable-default       error    mutable default argument (shared
                                      across calls; breaks pytree configs)
RA106  banned-import         error    ``scipy`` / ``torch`` imports under
                                      ``src/repro`` (``jax.scipy`` is fine)
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Iterator

__all__ = ["Finding", "ModuleContext", "Rule", "ALL_RULES", "RULES_BY_ID",
           "BANNED_IMPORT_ROOTS"]

BANNED_IMPORT_ROOTS = ("scipy", "torch")


@dataclass
class Finding:
    """One analyzer finding; ``fingerprint`` is filled in by the runner."""
    rule: str
    severity: str            # "error" | "warning"
    path: str
    line: int
    col: int
    message: str
    fingerprint: str = ""

    def format(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: "
                f"{self.rule} [{self.severity}] {self.message}")

    def to_json(self) -> dict:
        return {"rule": self.rule, "severity": self.severity,
                "path": self.path, "line": self.line, "col": self.col,
                "message": self.message, "fingerprint": self.fingerprint}


@dataclass
class ModuleContext:
    """Parsed module handed to every rule."""
    path: str
    source: str
    tree: ast.Module
    lines: list[str] = field(default_factory=list)

    @classmethod
    def from_source(cls, source: str, path: str) -> "ModuleContext":
        return cls(path=path, source=source, tree=ast.parse(source),
                   lines=source.splitlines())


class Rule:
    """Base class: subclasses set ``id``/``severity`` and implement check."""

    id: str = ""
    name: str = ""
    severity: str = "error"
    rationale: str = ""

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        raise NotImplementedError

    def finding(self, ctx: ModuleContext, node: ast.AST,
                message: str) -> Finding:
        return Finding(rule=self.id, severity=self.severity, path=ctx.path,
                       line=getattr(node, "lineno", 1),
                       col=getattr(node, "col_offset", 0), message=message)


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------
def _attr_tail(node: ast.AST) -> str:
    """Final attribute / name of a dotted expression (``a.b.c`` -> ``c``)."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return ""


def _dotted(node: ast.AST) -> str:
    """Full dotted name of an expression, or "" if not a plain chain."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _seed_signature(node: ast.AST):
    """Structural signature of a seed expression, base names erased.

    ``cfg.seed + 1`` and ``state.config.seed + 1`` normalise to the same
    signature (both read a ``.seed`` attribute and add 1), which is exactly
    the aliasing that makes key reuse hard to spot in review.
    """
    if isinstance(node, ast.Constant):
        return ("const", repr(node.value))
    if isinstance(node, ast.Name):
        return ("name",)
    if isinstance(node, ast.Attribute):
        return ("attr", node.attr)
    if isinstance(node, ast.BinOp):
        return ("binop", type(node.op).__name__,
                _seed_signature(node.left), _seed_signature(node.right))
    if isinstance(node, ast.UnaryOp):
        return ("unary", type(node.op).__name__,
                _seed_signature(node.operand))
    if isinstance(node, ast.Call):
        return ("call", _dotted(node.func) or _attr_tail(node.func),
                tuple(_seed_signature(a) for a in node.args))
    return ("other", ast.dump(node))


# --------------------------------------------------------------------------
# RA101: PRNG key reuse
# --------------------------------------------------------------------------
class PrngKeyReuseRule(Rule):
    id = "RA101"
    name = "prng-key-reuse"
    severity = "error"
    rationale = ("Two jax.random.PRNGKey calls built from the same seed "
                 "expression produce identical keys: the code paths silently "
                 "share randomness. Derive sub-keys with jax.random.fold_in "
                 "or jax.random.split instead.")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        # PRNGKey(expr) fed straight into fold_in(key, tag) is the
        # sanctioned way to derive distinct streams from one base seed —
        # the tag differentiates them, so same-seed matches are fine.
        folded: set[int] = set()
        for node in ast.walk(ctx.tree):
            if (isinstance(node, ast.Call)
                    and _attr_tail(node.func) in ("fold_in", "split")
                    and node.args
                    and isinstance(node.args[0], ast.Call)):
                folded.add(id(node.args[0]))
        seen: dict = {}
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            if _attr_tail(node.func) != "PRNGKey" or len(node.args) != 1:
                continue
            if id(node) in folded:
                continue
            sig = _seed_signature(node.args[0])
            first = seen.get(sig)
            if first is None:
                seen[sig] = node
                continue
            expr = ast.unparse(node.args[0])
            yield self.finding(
                ctx, node,
                f"PRNGKey seed expression {expr!r} matches the key built at "
                f"line {first.lineno}: the two keys are identical and the "
                "paths share randomness; derive distinct streams with "
                "jax.random.fold_in")


# --------------------------------------------------------------------------
# RA102: Python branch on traced values inside jit
# --------------------------------------------------------------------------
def _jit_static_names(dec: ast.AST, func: ast.FunctionDef) -> list[str] | None:
    """Param names made static by a jit decorator, or None if not a jit.

    Recognises ``@jax.jit``, ``@jit``, and
    ``@functools.partial(jax.jit, static_argnames=..., static_argnums=...)``.
    """
    def is_jit(expr: ast.AST) -> bool:
        return _dotted(expr) in ("jit", "jax.jit")

    if is_jit(dec):
        return []
    if (isinstance(dec, ast.Call)
            and _attr_tail(dec.func) == "partial"
            and dec.args and is_jit(dec.args[0])):
        static: list[str] = []
        args = ([a.arg for a in func.args.posonlyargs]
                + [a.arg for a in func.args.args]
                + [a.arg for a in func.args.kwonlyargs])
        for kw in dec.keywords:
            if kw.arg == "static_argnames":
                for el in ast.walk(kw.value):
                    if isinstance(el, ast.Constant) and isinstance(el.value, str):
                        static.append(el.value)
            if kw.arg == "static_argnums":
                for el in ast.walk(kw.value):
                    if isinstance(el, ast.Constant) and isinstance(el.value, int):
                        if 0 <= el.value < len(args):
                            static.append(args[el.value])
        return static
    return None


def _is_static_test(test: ast.AST) -> bool:
    """Tests that are legal on tracers: ``x is None``, isinstance checks."""
    if isinstance(test, ast.Compare) and all(
            isinstance(op, (ast.Is, ast.IsNot)) for op in test.ops):
        return True
    if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
        return _is_static_test(test.operand)
    if isinstance(test, ast.Call) and _attr_tail(test.func) == "isinstance":
        return True
    return False


class TracedBranchRule(Rule):
    id = "RA102"
    name = "traced-python-branch"
    severity = "error"
    rationale = ("A Python if/while on a traced value inside a jitted "
                 "function either raises a ConcretizationTypeError or — "
                 "when the value is silently concretised — forces a host "
                 "sync and a retrace per distinct value. Use jnp.where / "
                 "lax.cond / lax.while_loop.")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        for func in ast.walk(ctx.tree):
            if not isinstance(func, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            static: list[str] | None = None
            for dec in func.decorator_list:
                names = _jit_static_names(dec, func)
                if names is not None:
                    static = names
                    break
            if static is None:
                continue
            params = {a.arg for a in (func.args.posonlyargs + func.args.args
                                      + func.args.kwonlyargs)}
            traced = params - set(static) - {"self", "cls"}
            for node in ast.walk(func):
                if not isinstance(node, (ast.If, ast.While)):
                    continue
                if _is_static_test(node.test):
                    continue
                used = {n.id for n in ast.walk(node.test)
                        if isinstance(n, ast.Name)}
                hits = sorted(used & traced)
                if hits:
                    kind = "if" if isinstance(node, ast.If) else "while"
                    yield self.finding(
                        ctx, node,
                        f"Python `{kind}` on potentially traced value(s) "
                        f"{', '.join(hits)} inside jitted function "
                        f"{func.name!r}; use jnp.where / lax.cond / "
                        "lax.while_loop (or mark the argument static)")


# --------------------------------------------------------------------------
# RA103: host syncs inside Python loops
# --------------------------------------------------------------------------
_SYNC_BUILTINS = {"float", "int", "bool"}
_SYNC_ATTRS = {"item", "block_until_ready", "device_get", "asarray", "array"}


class HostSyncInLoopRule(Rule):
    id = "RA103"
    name = "host-sync-in-loop"
    severity = "warning"
    rationale = ("float()/.item()/np.asarray/jax.device_get on a device "
                 "value blocks on the accelerator; inside a Python loop "
                 "(e.g. a solver driver) that is one sync per iteration and "
                 "the async dispatch pipeline is dead.")

    def _sync_call(self, node: ast.Call) -> str | None:
        if isinstance(node.func, ast.Name):
            if (node.func.id in _SYNC_BUILTINS and node.args
                    and not isinstance(node.args[0], ast.Constant)):
                return f"{node.func.id}()"
            return None
        if isinstance(node.func, ast.Attribute):
            tail = node.func.attr
            if tail == "item":
                return ".item()"
            if tail in ("asarray", "array"):
                root = _dotted(node.func).split(".")[0]
                if root in ("np", "numpy"):
                    return f"{root}.{tail}()"
                return None
            if tail in ("block_until_ready", "device_get"):
                return f"{_dotted(node.func) or tail}()"
        return None

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        # Only meaningful in modules that can hold device values.
        if not _imports_jax(ctx.tree):
            return
        # A call nested in several loops is reached once per enclosing
        # loop by this walk — report each call node exactly once.
        seen: set[int] = set()
        for loop in ast.walk(ctx.tree):
            if not isinstance(loop, (ast.For, ast.While)):
                continue
            for node in ast.walk(loop):
                if not isinstance(node, ast.Call) or id(node) in seen:
                    continue
                seen.add(id(node))
                what = self._sync_call(node)
                if what is not None:
                    yield self.finding(
                        ctx, node,
                        f"{what} inside a Python loop forces a host sync "
                        "per iteration if the value lives on device; hoist "
                        "it out of the loop or keep the loop on-device "
                        "(lax.while_loop / lax.fori_loop)")


def _imports_jax(tree: ast.Module) -> bool:
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            if any(a.name.split(".")[0] == "jax" for a in node.names):
                return True
        if isinstance(node, ast.ImportFrom):
            if (node.module or "").split(".")[0] == "jax":
                return True
    return False


# --------------------------------------------------------------------------
# RA104: implicit dtype promotion via bare Python scalars
# --------------------------------------------------------------------------
class ImplicitPromotionRule(Rule):
    id = "RA104"
    name = "implicit-promotion"
    severity = "warning"
    rationale = ("Identity arithmetic with a bare Python float (* 1.0, "
                 "+ 0.0) is a no-op that can still promote weak dtypes, and "
                 "the builtin `float` used as a dtype means float64 under "
                 "x64 — both silently double memory traffic.")

    def _identity_op(self, node: ast.BinOp) -> str | None:
        left, right = node.left, node.right
        def is_const(n, v):
            return (isinstance(n, ast.Constant)
                    and isinstance(n.value, float) and n.value == v)
        if isinstance(node.op, ast.Mult):
            if is_const(left, 1.0) or is_const(right, 1.0):
                return "* 1.0"
        if isinstance(node.op, ast.Div) and is_const(right, 1.0):
            return "/ 1.0"
        if isinstance(node.op, ast.Add):
            if is_const(left, 0.0) or is_const(right, 0.0):
                return "+ 0.0"
        if isinstance(node.op, ast.Sub) and is_const(right, 0.0):
            return "- 0.0"
        return None

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.BinOp):
                op = self._identity_op(node)
                if op is not None:
                    yield self.finding(
                        ctx, node,
                        f"identity arithmetic `{op}` with a bare Python "
                        "scalar: a no-op that can promote weak dtypes — "
                        "drop it or make the dtype explicit")
            if isinstance(node, ast.Call):
                # x.astype(float) / jnp.asarray(x, float) / dtype=float
                is_float = lambda a: isinstance(a, ast.Name) and a.id in (
                    "float", "int")
                if (isinstance(node.func, ast.Attribute)
                        and node.func.attr == "astype" and node.args
                        and is_float(node.args[0])):
                    yield self.finding(
                        ctx, node,
                        "astype(float) is float64 under x64; name the dtype "
                        "explicitly (e.g. jnp.float32)")
                for kw in node.keywords:
                    if kw.arg == "dtype" and is_float(kw.value):
                        yield self.finding(
                            ctx, node,
                            "dtype=float is float64 under x64; name the "
                            "dtype explicitly (e.g. jnp.float32)")


# --------------------------------------------------------------------------
# RA105: mutable default arguments
# --------------------------------------------------------------------------
class MutableDefaultRule(Rule):
    id = "RA105"
    name = "mutable-default"
    severity = "error"
    rationale = ("A mutable default ([], {}, set()) is created once and "
                 "shared across every call — state leaks between calls, and "
                 "on pytree dataclasses it aliases leaves between "
                 "instances.")

    def _is_mutable(self, node: ast.AST) -> bool:
        if isinstance(node, (ast.List, ast.Dict, ast.Set)):
            return True
        if (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
                and node.func.id in ("list", "dict", "set") and not node.args
                and not node.keywords):
            return True
        return False

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        for func in ast.walk(ctx.tree):
            if not isinstance(func, (ast.FunctionDef, ast.AsyncFunctionDef,
                                     ast.Lambda)):
                continue
            defaults = list(func.args.defaults) + [
                d for d in func.args.kw_defaults if d is not None]
            for d in defaults:
                if self._is_mutable(d):
                    name = getattr(func, "name", "<lambda>")
                    yield self.finding(
                        ctx, d,
                        f"mutable default argument in {name!r} is shared "
                        "across calls; default to None and create inside "
                        "the body (or use dataclasses.field(default_factory))")


# --------------------------------------------------------------------------
# RA106: banned imports (scipy / torch under src/repro)
# --------------------------------------------------------------------------
class BannedImportRule(Rule):
    id = "RA106"
    name = "banned-import"
    severity = "error"
    rationale = ("The library must stay importable from jax + numpy alone: "
                 "scipy (the real package — jax.scipy is fine) and torch "
                 "must not be imported anywhere under src/repro, at module "
                 "or function level.")

    def __init__(self, banned: tuple[str, ...] = BANNED_IMPORT_ROOTS):
        self.banned = banned

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    root = a.name.split(".")[0]
                    if root in self.banned:
                        yield self.finding(
                            ctx, node,
                            f"import of banned dependency {a.name!r}: "
                            f"{root} must not be used under src/repro")
            elif isinstance(node, ast.ImportFrom):
                if node.level:    # relative import, never a banned root
                    continue
                root = (node.module or "").split(".")[0]
                if root in self.banned:
                    yield self.finding(
                        ctx, node,
                        f"import from banned dependency {node.module!r}: "
                        f"{root} must not be used under src/repro")


ALL_RULES: tuple[Rule, ...] = (
    PrngKeyReuseRule(),
    TracedBranchRule(),
    HostSyncInLoopRule(),
    ImplicitPromotionRule(),
    MutableDefaultRule(),
    BannedImportRule(),
)

RULES_BY_ID = {r.id: r for r in ALL_RULES}
