"""repro.analysis — JAX/Pallas-aware static analysis for this repo.

Three layers (see each module's docstring):

* :mod:`repro.analysis.rules` + :mod:`repro.analysis.runner` — stdlib-AST
  lint rules (RA101..RA106) for JAX footguns: PRNG key reuse, Python
  control flow on traced values, host syncs in solver loops, implicit
  dtype promotion, mutable defaults, banned imports (scipy/torch).
* :mod:`repro.analysis.jaxpr_audit` — structural audits of the traced
  programs: f64-free, host-callback-free, retrace-free across refits.
* :mod:`repro.analysis.vmem` — exact VMEM budget model for the fused
  Pallas MVM; rejects oversized block choices before ``pallas_call``.

CLI: ``python -m repro.analysis src/ --baseline analysis_baseline.json``
(the CI ``lint`` job). ``rules``/``runner``/``vmem`` are pure stdlib and
never import jax; ``jaxpr_audit`` does and is opt-in via ``--jaxpr``.
"""
from .rules import ALL_RULES, RULES_BY_ID, Finding
from .runner import (analyze_file, analyze_paths, analyze_source,
                     filter_baseline, format_report, load_baseline,
                     write_baseline)
from .vmem import (VMEM_BUDGET_BYTES, VmemBudgetError, audit_candidate_space,
                   best_fitting_blocks, check_fused_blocks,
                   fused_vmem_breakdown, fused_vmem_bytes)

__all__ = [
    "ALL_RULES", "RULES_BY_ID", "Finding",
    "analyze_source", "analyze_file", "analyze_paths",
    "load_baseline", "write_baseline", "filter_baseline", "format_report",
    "VMEM_BUDGET_BYTES", "VmemBudgetError", "fused_vmem_breakdown",
    "fused_vmem_bytes", "check_fused_blocks", "best_fitting_blocks",
    "audit_candidate_space",
]
