"""Static VMEM budget checker for the fused latent-Kronecker MVM kernel.

``lk_mvm_fused`` (:mod:`repro.kernels.lk_mvm`) keeps, per grid step, a
K1 block, a full U row strip, a full mask row strip, a full K2 column
strip, the output block, and three f32 scratch tiles resident in VMEM.
TPU VMEM is ~16 MiB per core; a (block_n, block_m) choice whose resident
set exceeds it fails at ``pallas_call`` compile time on hardware — long
after the autotuner committed to it, and invisibly on CPU where the
kernel runs in interpret mode. This module computes the **exact** bytes
implied by a block choice (including (sublane, lane) tile rounding and
the pipeline's double buffering) so oversized configurations are rejected
*before* ``pallas_call`` ever runs:

* :func:`fused_vmem_breakdown` / :func:`fused_vmem_bytes` — the byte
  model, mirroring the kernel's BlockSpecs one-to-one;
* :func:`check_fused_blocks` — raise :class:`VmemBudgetError` when a
  choice exceeds the budget (called by ``lk_mvm_fused`` itself);
* :func:`best_fitting_blocks` — the largest-throughput candidate pair
  that fits (used by the autotuner to filter its sweep);
* :func:`audit_candidate_space` — sweep representative shape buckets and
  report every (shape, candidate) combination the autotuner could emit
  that does not fit; after PR 6 the *filtered* sweep is provably clean
  while the raw {64, 128, 256} grid is not (see tests/test_analysis.py).

Pure stdlib — importable (and CI-checkable) without jax.
"""
from __future__ import annotations

from dataclasses import dataclass

__all__ = ["VMEM_BUDGET_BYTES", "VmemBudgetError", "VmemBreakdown",
           "fused_vmem_breakdown", "fused_vmem_bytes", "check_fused_blocks",
           "best_fitting_blocks", "audit_candidate_space"]

VMEM_BUDGET_BYTES = 16 * 1024 * 1024   # 16 MiB per TPU core

# Matches repro.kernels.lk_mvm: candidate sweep and minimum block edges.
_CANDIDATES = (64, 128, 256)
_MIN_EDGE = {"f32": 8, "bf16": 16}
_ITEMSIZE = {"f32": 4, "bf16": 2}
# itemsize -> sublane multiple; lane is always 128. The 8-byte entry
# covers f64 outputs in interpret-mode tests (x64 enabled on CPU; real
# TPUs never see f64 tiles).
_SUBLANE = {4: 8, 2: 16, 8: 8}
_LANE = 128


class VmemBudgetError(ValueError):
    """A (block_n, block_m) choice does not fit the per-core VMEM budget."""


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def _tile_bytes(rows: int, cols: int, itemsize: int) -> int:
    """Bytes of a 2-D VMEM buffer after (sublane, lane) tile rounding."""
    r = _round_up(max(rows, 1), _SUBLANE[itemsize])
    c = _round_up(max(cols, 1), _LANE)
    return r * c * itemsize


def effective_blocks(n: int, m: int, block_n: int, block_m: int,
                     precision: str = "f32") -> tuple[int, int, int]:
    """(bn, bm, mpad) exactly as ``lk_mvm_fused`` derives them."""
    min_edge = _MIN_EDGE[precision]
    bn = min(block_n, max(min_edge, n))
    bm = min(block_m, max(min_edge, m))
    mpad = _round_up(m, bm)
    return bn, bm, mpad


@dataclass(frozen=True)
class VmemBreakdown:
    """Exact per-grid-step VMEM bytes of ``lk_mvm_fused``."""
    k1_block: int        # (bn, bn) K1 tile
    u_strip: int         # (bn, mpad) U row strip
    mask_strip: int      # (bn, mpad) mask row strip
    k2_strip: int        # (mpad, bm) K2 column strip
    out_block: int       # (bn, bm) output tile
    scratch: int         # 3 x (bn, bm) f32 (accumulator + epilogue tiles)
    double_buffered: int # pipelined copies of inputs + output
    total: int

    def fits(self, budget: int = VMEM_BUDGET_BYTES) -> bool:
        return self.total <= budget


def fused_vmem_breakdown(n: int, m: int, block_n: int, block_m: int,
                         precision: str = "f32",
                         out_itemsize: int = 4) -> VmemBreakdown:
    """Byte-exact VMEM model of one ``lk_mvm_fused`` grid step.

    Mirrors the kernel's BlockSpecs: inputs and the output are double
    buffered by the Pallas pipeline (two resident copies each); the three
    scratch tiles are single f32 buffers. ``B`` does not appear: the batch
    axis is the outermost grid dimension, one b per step.
    """
    if precision not in _ITEMSIZE:
        raise ValueError(f"precision must be 'f32' or 'bf16', "
                         f"got {precision!r}")
    ib = _ITEMSIZE[precision]
    bn, bm, mpad = effective_blocks(n, m, block_n, block_m, precision)
    k1 = _tile_bytes(bn, bn, ib)
    u = _tile_bytes(bn, mpad, ib)
    mask = _tile_bytes(bn, mpad, ib)
    k2 = _tile_bytes(mpad, bm, ib)
    out = _tile_bytes(bn, bm, out_itemsize)
    scratch = 3 * _tile_bytes(bn, bm, 4)
    inputs_once = k1 + u + mask + k2
    double = inputs_once + out     # the second pipelined copy of each
    total = 2 * inputs_once + 2 * out + scratch
    return VmemBreakdown(k1_block=k1, u_strip=u, mask_strip=mask,
                         k2_strip=k2, out_block=out, scratch=scratch,
                         double_buffered=double, total=total)


def fused_vmem_bytes(n: int, m: int, block_n: int, block_m: int,
                     precision: str = "f32", out_itemsize: int = 4) -> int:
    return fused_vmem_breakdown(n, m, block_n, block_m, precision,
                                out_itemsize).total


def check_fused_blocks(n: int, m: int, block_n: int, block_m: int,
                       precision: str = "f32", out_itemsize: int = 4,
                       budget: int = VMEM_BUDGET_BYTES) -> VmemBreakdown:
    """Raise :class:`VmemBudgetError` if the choice exceeds the budget."""
    bd = fused_vmem_breakdown(n, m, block_n, block_m, precision,
                              out_itemsize)
    if not bd.fits(budget):
        bn, bm, mpad = effective_blocks(n, m, block_n, block_m, precision)
        raise VmemBudgetError(
            f"lk_mvm_fused blocks (block_n={block_n}, block_m={block_m}) "
            f"at shape (n={n}, m={m}, {precision}) need {bd.total} bytes "
            f"of VMEM (> budget {budget}): the (bn={bn}, mpad={mpad}) row "
            f"strips alone are {bd.u_strip + bd.mask_strip} bytes. Use "
            "smaller blocks, or the two-stage kernel (fused=False) whose "
            "intermediate lives in HBM.")
    return bd


def _grid_steps(n: int, m: int, bn: int, bm: int) -> int:
    """Grid work per batch item: (n/bn rows) x (m/bm cols) x (n/bn k-sweep)."""
    gn = -(-n // bn)
    gm = -(-m // bm)
    return gn * gm * gn


def best_fitting_blocks(n: int, m: int, precision: str = "f32",
                        out_itemsize: int = 4,
                        candidates: tuple[int, ...] = _CANDIDATES,
                        budget: int = VMEM_BUDGET_BYTES
                        ) -> tuple[int, int] | None:
    """The fitting candidate pair with the fewest grid steps, or None.

    Fewest grid steps == fewest stage-R recomputes (the analytic optimum
    the autotuner's heuristic mode targets); ties break toward larger
    blocks. Returns None when no candidate pair fits — the fused kernel
    cannot run this shape within budget and callers must fall back to the
    two-stage kernel.
    """
    best: tuple[int, int] | None = None
    best_key: tuple | None = None
    for bn in candidates:
        for bm in candidates:
            if not fused_vmem_breakdown(n, m, bn, bm, precision,
                                        out_itemsize).fits(budget):
                continue
            key = (_grid_steps(n, m, *effective_blocks(
                n, m, bn, bm, precision)[:2]), -bn, -bm)
            if best_key is None or key < best_key:
                best, best_key = (bn, bm), key
    return best


def audit_candidate_space(shapes=None,
                          candidates: tuple[int, ...] = _CANDIDATES,
                          budget: int = VMEM_BUDGET_BYTES) -> list[dict]:
    """Every (shape, precision, candidate) combination over budget.

    ``shapes`` defaults to the power-of-two (n, m) buckets the autotuner
    caches on, up to (8192, 8192) — the paper's target regime. The
    returned rows are what the raw {64, 128, 256} sweep *could* pick
    without the VMEM filter; an empty result for the filtered chooser
    (:func:`best_fitting_blocks` composed over the same shapes) is the
    invariant the CI gate enforces.
    """
    if shapes is None:
        buckets = [2 ** k for k in range(3, 14)]        # 8 .. 8192
        shapes = [(n, m) for n in buckets for m in buckets]
    rows = []
    for n, m in shapes:
        for precision in ("f32", "bf16"):
            for bn in candidates:
                for bm in candidates:
                    bd = fused_vmem_breakdown(n, m, bn, bm, precision)
                    if not bd.fits(budget):
                        rows.append({
                            "n": n, "m": m, "precision": precision,
                            "block_n": bn, "block_m": bm,
                            "bytes": bd.total, "budget": budget,
                        })
    return rows
