"""Pluggable curve-dataset sources behind one registry.

A :class:`CurveSource` yields :class:`~repro.data.curves.CurveTask` suites
from *somewhere* — the synthetic prior, an LCBench/ifBO-format artifact on
disk — behind one spec string, so benchmarks and schedulers are agnostic
to where curves come from:

    get_source("synthetic:crossing")                 # prior, crossing regime
    get_source("lcbench:tests/fixtures/lcbench_mini.npz")
    get_source("ifbo:path/to/artifact.npz")          # same loader

The part before the first ``:`` selects the registered source kind; the
remainder is the kind-specific argument (a synthetic variant name, an
artifact path). ``source.dataset_id`` is the stable tag benchmark rows
carry so the regression gate never compares synthetic and real rows
against each other.
"""
from __future__ import annotations

import os
from typing import Protocol, runtime_checkable

from .curves import CurveTask, sample_suite
from .lcbench import LCBenchArtifact, load_artifact

__all__ = ["CurveSource", "SOURCES", "register_source", "get_source",
           "list_source_kinds", "SyntheticSource", "LCBenchSource"]


@runtime_checkable
class CurveSource(Protocol):
    """A provider of curve-prediction tasks."""

    spec: str           # the full spec this source was built from
    dataset_id: str     # stable tag for benchmark rows / regression gating
    maximize: bool      # metric convention of the yielded tasks

    def tasks(self, num_tasks: int | None = None, seed: int = 0,
              **kwargs) -> list[CurveTask]:
        """Yield up to ``num_tasks`` tasks (all available when None)."""
        ...


SOURCES: dict[str, type] = {}


def register_source(kind: str):
    """Class decorator: register ``cls(arg, spec=...)`` under ``kind``."""
    def deco(cls):
        SOURCES[kind] = cls
        return cls
    return deco


def get_source(spec: str) -> "CurveSource":
    """Resolve ``"<kind>:<arg>"`` (or bare ``"<kind>"``) to a source."""
    kind, _, arg = str(spec).partition(":")
    try:
        cls = SOURCES[kind]
    except KeyError:
        raise ValueError(f"unknown dataset source kind {kind!r} in "
                         f"{spec!r}; available: {sorted(SOURCES)}") from None
    return cls(arg, spec=spec)


def list_source_kinds() -> list[str]:
    return sorted(SOURCES)


# --------------------------------------------------------------------------
# synthetic (the LCBench-like prior in repro.data.curves)
# --------------------------------------------------------------------------
@register_source("synthetic")
class SyntheticSource:
    """Samples suites from the synthetic prior; the arg picks the regime.

    Variants mirror the benchmark suites: ``mixed`` (default), ``crossing``
    (rate anti-correlated with asymptote; rank-based promotion misled), and
    ``noisy-divergent``.
    """

    VARIANTS = {
        "": {},
        "mixed": {},
        "crossing": dict(crossing=True, diverge_prob=0.0),
        "noisy-divergent": dict(noise=0.03, diverge_prob=0.08),
    }

    def __init__(self, variant: str = "", spec: str | None = None):
        if variant not in self.VARIANTS:
            raise ValueError(f"unknown synthetic variant {variant!r}; "
                             f"expected one of {sorted(self.VARIANTS)}")
        self.variant = variant
        self.spec = spec if spec is not None else f"synthetic:{variant}"
        self.dataset_id = f"synthetic:{variant or 'mixed'}"
        self.maximize = True

    def tasks(self, num_tasks: int | None = None, seed: int = 0,
              **kwargs) -> list[CurveTask]:
        kw = dict(self.VARIANTS[self.variant])
        kw.update(kwargs)
        return sample_suite(seed, num_tasks if num_tasks is not None else 4,
                            **kw)


# --------------------------------------------------------------------------
# lcbench / ifbo artifacts on disk
# --------------------------------------------------------------------------
@register_source("lcbench")
@register_source("ifbo")
class LCBenchSource:
    """Tasks from an LCBench/ifBO-format npz artifact (see data.lcbench)."""

    def __init__(self, path: str, spec: str | None = None):
        if not path:
            raise ValueError("lcbench source needs a path: 'lcbench:<path>'")
        self.path = path
        self.spec = spec if spec is not None else f"lcbench:{path}"
        stem = os.path.splitext(os.path.basename(path))[0]
        self.dataset_id = f"lcbench:{stem}"
        self._artifact: LCBenchArtifact | None = None

    @property
    def artifact(self) -> LCBenchArtifact:
        if self._artifact is None:
            self._artifact = load_artifact(self.path)
        return self._artifact

    @property
    def maximize(self) -> bool:
        return self.artifact.maximize

    @property
    def names(self) -> list:
        return self.artifact.names

    @property
    def has_full(self) -> list:
        return self.artifact.has_full

    def tasks(self, num_tasks: int | None = None, seed: int = 0,
              **kwargs) -> list[CurveTask]:
        tasks = self.artifact.tasks
        return list(tasks if num_tasks is None else tasks[:num_tasks])
