"""Composable, invertible per-task standardization for curve datasets.

Real learning-curve artifacts mix metric conventions — validation accuracy
(maximize), loss or error rate (minimize), arbitrary units — and arbitrary
budget grids (epochs, steps, log-spaced fidelities). The model stack wants
one convention: score space, where larger is always better, plus a
progression axis the Matern kernel sees as roughly uniform. These
transforms standardize *before* the GP's own fitted input/output
transforms (:mod:`repro.core.transforms`) and carry their inverse, so
predictions can be reported back in the artifact's raw metric units.

Everything here is plain elementwise arithmetic, so the transforms work on
numpy and jax arrays alike, and :class:`Compose` chains them (inverse runs
in reverse order). :class:`AffineTransform` replaces the ad-hoc
``maximize`` sign flips that used to live in
:class:`repro.autotune.predictor.CurvePredictor`.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

__all__ = ["AffineTransform", "LogWarp", "Compose", "metric_transform"]


class AffineTransform(NamedTuple):
    """``z = scale * y + shift`` with stored exact inverse.

    Covers the two metric standardizations the datasets need: the
    sign flip into score space (``scale=-1`` for minimized metrics) and
    per-task affine normalization fitted on observed cells.
    """

    scale: float = 1.0
    shift: float = 0.0

    def __call__(self, y):
        return y * self.scale + self.shift

    def inverse(self, z):
        return (z - self.shift) / self.scale

    def inverse_var(self, v):
        """Map a variance from transformed space back to raw units."""
        return v / (self.scale * self.scale)

    @classmethod
    def identity(cls) -> "AffineTransform":
        return cls(1.0, 0.0)

    @classmethod
    def sign(cls, maximize: bool) -> "AffineTransform":
        """Score-space convention: larger is always better."""
        return cls(1.0 if maximize else -1.0, 0.0)

    @classmethod
    def fit_normalize(cls, Y, mask) -> "AffineTransform":
        """Zero-mean / unit-std over the *observed* cells of one task."""
        Y = np.asarray(Y, np.float64)
        mask = np.asarray(mask, np.float64)
        cnt = max(float(mask.sum()), 1.0)
        mean = float((Y * mask).sum() / cnt)
        var = float((mask * (Y - mean) ** 2).sum() / cnt)
        std = float(np.sqrt(max(var, 1e-12)))
        return cls(1.0 / std, -mean / std)


class LogWarp(NamedTuple):
    """Progression warp ``u = log(t + offset)`` with exact inverse.

    Maps a multiplicative budget grid (epochs 1..m, log-spaced fidelities)
    onto an additively-spaced axis. ``offset`` keeps zero-based step counts
    in the kernel's domain.
    """

    offset: float = 0.0

    def __call__(self, t):
        return np.log(np.asarray(t, np.float64) + self.offset)

    def inverse(self, u):
        return np.exp(np.asarray(u, np.float64)) - self.offset


class Compose(NamedTuple):
    """Apply ``transforms`` left to right; invert right to left."""

    transforms: tuple

    def __call__(self, y):
        for tf in self.transforms:
            y = tf(y)
        return y

    def inverse(self, z):
        for tf in reversed(self.transforms):
            z = tf.inverse(z)
        return z

    def inverse_var(self, v):
        for tf in reversed(self.transforms):
            v = tf.inverse_var(v)
        return v


def metric_transform(maximize: bool = True, normalize: bool = False,
                     Y=None, mask=None):
    """Standard metric pipeline: sign flip, optionally per-task affine.

    With ``normalize=True`` the affine part is fitted on the observed cells
    of ``(Y, mask)`` *after* the sign flip, so score space is zero-mean /
    unit-std regardless of the artifact's metric units.
    """
    sign = AffineTransform.sign(maximize)
    if not normalize:
        return sign
    if Y is None or mask is None:
        raise ValueError("normalize=True needs Y and mask to fit on")
    norm = AffineTransform.fit_normalize(sign(np.asarray(Y, np.float64)),
                                         mask)
    return Compose((sign, norm))
