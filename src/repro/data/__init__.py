"""Learning-curve prior and token pipeline."""
from .curves import CurveTask, benchmark_cutoffs, sample_task
from .tokens import TokenPipeline
