"""Curve datasets: pluggable sources, artifacts, transforms, token pipeline.

* :mod:`repro.data.curves`     — the synthetic LCBench-like prior +
  :class:`CurveTask`, suite stacking, scheduler observation models.
* :mod:`repro.data.sources`    — :class:`CurveSource` protocol + registry
  (``get_source("synthetic:crossing")``, ``get_source("lcbench:<path>")``).
* :mod:`repro.data.lcbench`    — LCBench/ifBO-format npz artifact IO.
* :mod:`repro.data.transforms` — composable, invertible per-task metric /
  progression standardization.
"""
from .curves import (CurveTask, benchmark_cutoffs, noisy_step_fns,
                     replay_step_fns, sample_suite, sample_task, stack_suite)
from .lcbench import LCBenchArtifact, load_artifact, write_artifact
from .sources import (CurveSource, LCBenchSource, SyntheticSource,
                      get_source, list_source_kinds, register_source)
from .tokens import TokenPipeline
from .transforms import AffineTransform, Compose, LogWarp, metric_transform
