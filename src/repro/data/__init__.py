"""Learning-curve prior and token pipeline."""
from .curves import (CurveTask, benchmark_cutoffs, noisy_step_fns,
                     sample_suite, sample_task, stack_suite)
from .tokens import TokenPipeline
