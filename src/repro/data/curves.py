"""Synthetic learning-curve generator (LCBench-like prior).

The LCBench/ifBO artifacts are not available offline, so the prediction
benchmark samples tasks from the same parametric families the DPL / ifBO
priors use (pow3, log-power, exponential-saturation, Janoschek), with
hyper-parameter-driven coefficients, heteroskedastic noise, occasional spikes
and divergent curves — matching the qualitative regimes of Fig. 1.

A "task" = n configs x of dim d, curves over m epochs, plus an
early-stopping mask (each curve observed up to a random cutoff).
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

__all__ = ["CurveTask", "sample_task", "sample_suite", "stack_suite",
           "noisy_step_fns", "benchmark_cutoffs"]


class CurveTask(NamedTuple):
    X: np.ndarray       # (n, d) hyper-parameters in [0, 1]
    t: np.ndarray       # (m,) epochs 1..m
    Y: np.ndarray       # (n, m) validation-accuracy-like curves
    mask: np.ndarray    # (n, m) 1.0 where observed
    Y_full: np.ndarray  # ground truth (n, m)


def _curve_family(rng, x, t_norm, crossing: bool = False):
    """One curve as a function of its hyper-parameters x (d >= 4 used).

    ``crossing`` anti-correlates convergence rate with the asymptote
    (high-asymptote configs are slow starters — the small-learning-rate
    regime), so curves cross and early rankings mislead rank-based
    promotion. In crossing mode the family is also a deterministic
    function of x (real HPO response surfaces are; a per-curve coin flip
    is irreducible noise no surrogate could transfer across configs).
    """
    kind = min(3, int(4.0 * x[2])) if crossing else rng.integers(0, 4)
    # config-dependent asymptote / rate / delay
    asym = 0.55 + 0.4 * (0.6 * x[0] + 0.4 * x[1]) - 0.1 * (x[2] - 0.5) ** 2
    if crossing:
        rate = 0.5 + 6.0 * (1.0 - x[0]) + 2.0 * (1.0 - x[1])
    else:
        rate = 0.5 + 6.0 * x[2] + 2.0 * x[0]
    delay = 0.05 + 0.3 * x[3]
    lo = 0.08 + 0.15 * x[1]
    tt = np.maximum(t_norm - 0.02 * delay, 1e-4)
    if kind == 0:      # pow3: asym - a * t^-alpha
        a = (asym - lo)
        pow_p = 0.3 + 1.5 * ((1.0 - x[0]) if crossing else x[2])
        y = asym - a * np.power(tt * 50 + 1, -pow_p)
    elif kind == 1:    # log-power
        y = asym / (1 + np.power(tt * 30 / np.exp(delay), -(0.8 + rate / 4)))
        y = lo + (asym - lo) * (y / max(asym, 1e-3))
    elif kind == 2:    # exponential saturation
        y = asym - (asym - lo) * np.exp(-rate * tt * 3)
    else:              # Janoschek
        y = asym - (asym - lo) * np.exp(-rate * np.power(tt, 1.2) * 2.5)
    return np.clip(y, 0.0, 1.0)


def sample_task(seed: int, n: int = 32, m: int = 20, d: int = 7,
                observed_fraction: tuple[float, float] = (0.1, 0.9),
                noise: float = 0.01, spike_prob: float = 0.05,
                diverge_prob: float = 0.03,
                crossing: bool = False) -> CurveTask:
    rng = np.random.default_rng(seed)
    X = rng.uniform(0, 1, (n, d))
    t = np.arange(1.0, m + 1.0)
    t_norm = (t - 1) / (m - 1) if m > 1 else t * 0 + 1.0
    Y = np.stack([_curve_family(rng, X[i], t_norm, crossing=crossing)
                  for i in range(n)])

    # noise, spikes, divergence (Fig 1 right panel regimes)
    Y = Y + rng.normal(0, noise * (0.5 + X[:, :1]), Y.shape)
    spikes = rng.random(Y.shape) < spike_prob
    Y = np.where(spikes, Y - rng.uniform(0.05, 0.3, Y.shape), Y)
    diverges = rng.random(n) < diverge_prob
    for i in np.where(diverges)[0]:
        start = rng.integers(m // 2, m)
        Y[i, start:] -= np.linspace(0, 0.3, m - start)
    Y = np.clip(Y, 0.0, 1.0)

    Y_full = Y.copy()
    lens = rng.integers(max(1, int(observed_fraction[0] * m)),
                        max(2, int(observed_fraction[1] * m)) + 1, n)
    lens[rng.integers(0, n)] = m  # keep one fully observed curve
    mask = (np.arange(m)[None, :] < lens[:, None]).astype(np.float64)
    return CurveTask(X=X, t=t, Y=Y * mask, mask=mask, Y_full=Y_full)


def sample_suite(seed: int, num_tasks: int, n: int = 16, m: int = 12,
                 d: int = 7, **task_kwargs) -> list[CurveTask]:
    """A suite of independent tasks with shared shapes (one noise regime).

    All tasks share (n, m, d) so the suite can be stacked for the batched
    ``fit_batch`` / ``posterior_batch`` path; ``task_kwargs`` forward to
    :func:`sample_task` (noise, spike_prob, diverge_prob, ...).
    """
    return [sample_task(seed * 1000 + b, n=n, m=m, d=d, **task_kwargs)
            for b in range(num_tasks)]


def stack_suite(tasks: list[CurveTask]):
    """Stack a shape-aligned suite into (X, t, Y, mask, Y_full) batch arrays."""
    if len({(tk.X.shape, tk.Y.shape) for tk in tasks}) != 1:
        raise ValueError("stack_suite needs shape-aligned tasks "
                         "(use sample_suite)")
    return (np.stack([tk.X for tk in tasks]),
            tasks[0].t,
            np.stack([tk.Y for tk in tasks]),
            np.stack([tk.mask for tk in tasks]),
            np.stack([tk.Y_full for tk in tasks]))


def noisy_step_fns(task: CurveTask, seed: int, obs_noise: float = 0.02,
                   spike_prob: float = 0.03):
    """Per-config ``step() -> observed metric`` callables over a task.

    The scheduler-facing observation model: the clean curve ``Y_full`` plus
    Gaussian eval noise and occasional downward spikes — noise lives in the
    *observation stream* (as in real eval pipelines), so ``Y_full`` remains
    the ground truth that regret is measured against. Shared by
    ``benchmarks/bench_automl.py``, ``examples/successive_halving.py`` and
    the scheduler tests so the three stay on one observation model.
    """
    rng = np.random.default_rng(seed)
    counters = [0] * len(task.X)

    def mk(i):
        def step():
            e = counters[i]
            counters[i] += 1
            v = task.Y_full[i, e] + rng.normal(0, obs_noise)
            if rng.random() < spike_prob:
                v -= rng.uniform(0.05, 0.3)
            return float(v)
        return step

    return [mk(i) for i in range(len(task.X))]


def benchmark_cutoffs(n_train_examples: int, n: int, m: int,
                      seed: int) -> np.ndarray:
    """ifBO-style protocol: a budget of observed values spread over configs."""
    rng = np.random.default_rng(seed)
    lens = np.zeros(n, np.int64)
    order = rng.permutation(n)
    budget = n_train_examples
    i = 0
    while budget > 0:
        c = order[i % n]
        if lens[c] < m:
            lens[c] += 1
            budget -= 1
        i += 1
    return lens
