"""Synthetic learning-curve generator (LCBench-like prior).

The LCBench/ifBO artifacts are not available offline, so the prediction
benchmark samples tasks from the same parametric families the DPL / ifBO
priors use (pow3, log-power, exponential-saturation, Janoschek), with
hyper-parameter-driven coefficients, heteroskedastic noise, occasional spikes
and divergent curves — matching the qualitative regimes of Fig. 1.

A "task" = n configs x of dim d, curves over m epochs, plus an
early-stopping mask (each curve observed up to a random cutoff).
"""
from __future__ import annotations

import warnings
from typing import NamedTuple

import numpy as np

__all__ = ["CurveTask", "sample_task", "sample_suite", "stack_suite",
           "noisy_step_fns", "replay_step_fns", "benchmark_cutoffs"]


class CurveTask(NamedTuple):
    X: np.ndarray       # (n, d) hyper-parameters in [0, 1]
    t: np.ndarray       # (m,) progression grid: epochs 1..m, or any
                        # positive strictly-increasing budgets (log-spaced
                        # fidelities, step counts, ...)
    Y: np.ndarray       # (n, m) validation-accuracy-like curves
    mask: np.ndarray    # (n, m) 1.0 where observed
    Y_full: np.ndarray  # ground truth (n, m)


def _curve_family(rng, x, t_norm, crossing: bool = False):
    """One curve as a function of its hyper-parameters x (d >= 4 used).

    ``crossing`` anti-correlates convergence rate with the asymptote
    (high-asymptote configs are slow starters — the small-learning-rate
    regime), so curves cross and early rankings mislead rank-based
    promotion. In crossing mode the family is also a deterministic
    function of x (real HPO response surfaces are; a per-curve coin flip
    is irreducible noise no surrogate could transfer across configs).
    """
    kind = min(3, int(4.0 * x[2])) if crossing else rng.integers(0, 4)
    # config-dependent asymptote / rate / delay
    asym = 0.55 + 0.4 * (0.6 * x[0] + 0.4 * x[1]) - 0.1 * (x[2] - 0.5) ** 2
    if crossing:
        rate = 0.5 + 6.0 * (1.0 - x[0]) + 2.0 * (1.0 - x[1])
    else:
        rate = 0.5 + 6.0 * x[2] + 2.0 * x[0]
    delay = 0.05 + 0.3 * x[3]
    lo = 0.08 + 0.15 * x[1]
    tt = np.maximum(t_norm - 0.02 * delay, 1e-4)
    if kind == 0:      # pow3: asym - a * t^-alpha
        a = (asym - lo)
        pow_p = 0.3 + 1.5 * ((1.0 - x[0]) if crossing else x[2])
        y = asym - a * np.power(tt * 50 + 1, -pow_p)
    elif kind == 1:    # log-power
        y = asym / (1 + np.power(tt * 30 / np.exp(delay), -(0.8 + rate / 4)))
        y = lo + (asym - lo) * (y / max(asym, 1e-3))
    elif kind == 2:    # exponential saturation
        y = asym - (asym - lo) * np.exp(-rate * tt * 3)
    else:              # Janoschek
        y = asym - (asym - lo) * np.exp(-rate * np.power(tt, 1.2) * 2.5)
    return np.clip(y, 0.0, 1.0)


def sample_task(seed: int, n: int = 32, m: int = 20, d: int = 7,
                observed_fraction: tuple[float, float] = (0.1, 0.9),
                noise: float = 0.01, spike_prob: float = 0.05,
                diverge_prob: float = 0.03,
                crossing: bool = False, t: np.ndarray | None = None) -> CurveTask:
    """Sample one task from the prior; ``t`` overrides the epoch grid.

    With ``t`` given (positive, strictly increasing — e.g. log-spaced
    budget fidelities), curves are evaluated at those progressions and
    ``m = len(t)``; the default remains epochs ``1..m``.
    """
    rng = np.random.default_rng(seed)
    X = rng.uniform(0, 1, (n, d))
    if t is None:
        t = np.arange(1.0, m + 1.0)
    else:
        t = np.asarray(t, np.float64)
        if t.ndim != 1 or t.shape[0] < 1 or np.any(np.diff(t) <= 0) \
                or t[0] <= 0:
            raise ValueError("t must be a positive strictly-increasing 1-D "
                             f"grid, got {t}")
        m = t.shape[0]
    t_norm = ((t - t[0]) / (t[-1] - t[0]) if m > 1 and t[-1] > t[0]
              else t * 0 + 1.0)
    Y = np.stack([_curve_family(rng, X[i], t_norm, crossing=crossing)
                  for i in range(n)])

    # noise, spikes, divergence (Fig 1 right panel regimes)
    Y = Y + rng.normal(0, noise * (0.5 + X[:, :1]), Y.shape)
    spikes = rng.random(Y.shape) < spike_prob
    Y = np.where(spikes, Y - rng.uniform(0.05, 0.3, Y.shape), Y)
    diverges = rng.random(n) < diverge_prob
    for i in np.where(diverges)[0]:
        start = rng.integers(m // 2, m)
        Y[i, start:] -= np.linspace(0, 0.3, m - start)
    Y = np.clip(Y, 0.0, 1.0)

    Y_full = Y.copy()
    lens = rng.integers(max(1, int(observed_fraction[0] * m)),
                        max(2, int(observed_fraction[1] * m)) + 1, n)
    lens[rng.integers(0, n)] = m  # keep one fully observed curve
    mask = (np.arange(m)[None, :] < lens[:, None]).astype(np.float64)
    return CurveTask(X=X, t=t, Y=Y * mask, mask=mask, Y_full=Y_full)


def sample_suite(seed: int, num_tasks: int, n: int = 16, m: int = 12,
                 d: int = 7, **task_kwargs) -> list[CurveTask]:
    """A suite of independent tasks with shared shapes (one noise regime).

    All tasks share (n, m, d) so the suite can be stacked for the batched
    ``fit_batch`` / ``posterior_batch`` path; ``task_kwargs`` forward to
    :func:`sample_task` (noise, spike_prob, diverge_prob, ...).
    """
    return [sample_task(seed * 1000 + b, n=n, m=m, d=d, **task_kwargs)
            for b in range(num_tasks)]


def _pad_grid(t: np.ndarray, m_pad: int) -> np.ndarray:
    """Extend a strictly-increasing grid by repeating its last step."""
    m = t.shape[0]
    if m_pad <= m:
        return t
    step = float(t[-1] - t[-2]) if m >= 2 else 1.0
    extra = t[-1] + step * np.arange(1, m_pad - m + 1)
    return np.concatenate([t, extra])


def stack_suite(tasks: list[CurveTask], pad: bool = False):
    """Stack a suite into (X, t, Y, mask, Y_full) batch arrays.

    Shape-aligned suites (e.g. from :func:`sample_suite`) stack directly
    and return a shared 1-D ``t``. Real artifact suites are usually ragged
    (each task its own (n, m)); with ``pad=True`` they are zero-padded to
    the max shape instead of raising: padded curve cells carry ``mask=0``
    (so they never enter a masked likelihood), padded config rows repeat
    the task's last config (keeping input-transform statistics in range)
    with an all-zero mask, and each grid is extended by its own last step.
    Padded/ragged suites return ``t`` of shape (B, m_max). Hyper-parameter
    dimension ``d`` must match — it cannot be padded meaningfully.
    """
    if not tasks:
        raise ValueError("stack_suite needs at least one task")
    shapes = [(tk.X.shape, tk.Y.shape) for tk in tasks]
    ds = {tk.X.shape[1] for tk in tasks}
    if len(ds) != 1:
        detail = ", ".join(f"task {i}: d={tk.X.shape[1]}"
                           for i, tk in enumerate(tasks))
        raise ValueError("stack_suite cannot align tasks with different "
                         f"hyper-parameter dimensions ({detail})")
    if len(set(shapes)) != 1:
        if not pad:
            ref = max(set(shapes), key=shapes.count)
            offending = [f"task {i}: X{sh[0]} Y{sh[1]}"
                         for i, sh in enumerate(shapes) if sh != ref]
            raise ValueError(
                "stack_suite needs shape-aligned tasks; majority shape is "
                f"X{ref[0]} Y{ref[1]} but {'; '.join(offending)}. Pass "
                "pad=True to zero-pad ragged tasks, or use sample_suite "
                "for aligned synthetic suites.")
        n_max = max(tk.X.shape[0] for tk in tasks)
        m_max = max(tk.t.shape[0] for tk in tasks)
        Xs, ts, Ys, masks, fulls = [], [], [], [], []
        for tk in tasks:
            n, m = tk.Y.shape
            Xs.append(np.concatenate(
                [tk.X, np.repeat(tk.X[-1:], n_max - n, axis=0)], axis=0))
            ts.append(_pad_grid(np.asarray(tk.t, np.float64), m_max))
            grid_pad = ((0, n_max - n), (0, m_max - m))
            Ys.append(np.pad(tk.Y, grid_pad))
            masks.append(np.pad(tk.mask, grid_pad))
            fulls.append(np.pad(tk.Y_full, grid_pad))
        return (np.stack(Xs), np.stack(ts), np.stack(Ys), np.stack(masks),
                np.stack(fulls))
    t0 = np.asarray(tasks[0].t)
    ragged_t = any(not np.array_equal(np.asarray(tk.t), t0) for tk in tasks)
    t = np.stack([tk.t for tk in tasks]) if ragged_t else tasks[0].t
    return (np.stack([tk.X for tk in tasks]),
            t,
            np.stack([tk.Y for tk in tasks]),
            np.stack([tk.mask for tk in tasks]),
            np.stack([tk.Y_full for tk in tasks]))


def noisy_step_fns(task: CurveTask, seed: int, obs_noise: float = 0.02,
                   spike_prob: float = 0.03):
    """Per-config ``step() -> observed metric`` callables over a task.

    The scheduler-facing observation model: the clean curve ``Y_full`` plus
    Gaussian eval noise and occasional downward spikes — noise lives in the
    *observation stream* (as in real eval pipelines), so ``Y_full`` remains
    the ground truth that regret is measured against. Shared by
    ``benchmarks/bench_automl.py``, ``examples/successive_halving.py`` and
    the scheduler tests so the three stay on one observation model.
    """
    rng = np.random.default_rng(seed)
    counters = [0] * len(task.X)

    def mk(i):
        def step():
            e = counters[i]
            counters[i] += 1
            v = task.Y_full[i, e] + rng.normal(0, obs_noise)
            if rng.random() < spike_prob:
                v -= rng.uniform(0.05, 0.3)
            return float(v)
        return step

    return [mk(i) for i in range(len(task.X))]


def replay_step_fns(task: CurveTask, seed: int = 0, obs_noise: float = 0.0,
                    spike_prob: float = 0.0, censored: bool | None = None):
    """``noisy_step_fns``-compatible callables replaying a *loaded* task.

    Drives schedulers through a real (artifact) task's recorded curves:
    ``step()`` for config i returns the next value of ``Y_full[i]``. For a
    censored config (artifact without post-cutoff ground truth — the
    loader stores ``Y_full = Y`` zeroed past the early-stop mask), steps
    beyond the observed prefix hold the last observed value rather than
    replaying the padding zeros. ``obs_noise`` / ``spike_prob`` optionally
    re-add an observation-stream noise model on top of the recorded
    values (default: exact replay).

    ``censored`` is the authoritative flag (pass ``not has_full[i]`` from
    :class:`~repro.data.lcbench.LCBenchArtifact`): ``False`` means
    ``Y_full`` is trusted everywhere (a genuinely recorded all-zero tail
    replays as zeros), ``True`` holds the last observed value past every
    early-stop point. ``None`` falls back to a per-config heuristic —
    an exact-zero tail past the mask is treated as loader padding.
    """
    rng = np.random.default_rng(seed)
    Y_full = np.asarray(task.Y_full, np.float64)
    mask = np.asarray(task.mask, np.float64)
    m = Y_full.shape[1]
    lens = mask.sum(axis=1).astype(np.int64)
    if censored is None:
        # Heuristic: no information past the early-stop mask (exact zeros
        # are the loader's fallback padding).
        cens = [int(lens[i]) < m and not np.any(Y_full[i, int(lens[i]):])
                for i in range(Y_full.shape[0])]
    else:
        cens = [bool(censored) and int(lens[i]) < m
                for i in range(Y_full.shape[0])]
    counters = [0] * Y_full.shape[0]

    def mk(i):
        def step():
            e = counters[i]
            counters[i] += 1
            if cens[i]:
                if lens[i] == 0:
                    # Nothing was ever recorded; replaying the loader's
                    # padding zeros would hand schedulers fabricated (and,
                    # for minimized metrics, unbeatable) observations.
                    raise RuntimeError(
                        f"replay_step_fns: config {i} is censored with no "
                        "observed values — nothing to replay")
                e = min(e, int(lens[i]) - 1)
            else:
                e = min(e, m - 1)
            v = Y_full[i, e]
            if obs_noise:
                v = v + rng.normal(0, obs_noise)
            if spike_prob and rng.random() < spike_prob:
                v -= rng.uniform(0.05, 0.3)
            return float(v)
        return step

    return [mk(i) for i in range(Y_full.shape[0])]


def benchmark_cutoffs(n_train_examples: int, n: int, m: int,
                      seed: int) -> np.ndarray:
    """ifBO-style protocol: a budget of observed values spread over configs."""
    rng = np.random.default_rng(seed)
    lens = np.zeros(n, np.int64)
    order = rng.permutation(n)
    budget = n_train_examples
    if budget > n * m:
        # Without the clamp the while loop below never terminates once
        # every lens[c] == m (no step can decrement the budget).
        warnings.warn(f"benchmark_cutoffs: budget {n_train_examples} exceeds "
                      f"the grid size n*m = {n * m}; clamping",
                      stacklevel=2)
        budget = n * m
    i = 0
    while budget > 0:
        c = order[i % n]
        if lens[c] < m:
            lens[c] += 1
            budget -= 1
        i += 1
    return lens
