"""LCBench/ifBO-format curve artifacts: npz + embedded JSON meta on disk.

One artifact holds a set of learning-curve *tasks* (LCBench calls them
datasets): each task is a config table ``X`` (n, d), a progression grid
``t`` (m,) — epochs, steps, or arbitrary non-uniform budget fidelities —
per-config curves ``Y`` (n, m), and an early-stop mask (1.0 where the
curve was actually observed). The on-disk layout is a single ``.npz``:

* ``format``              — the schema tag ``"lcbench-v1"``;
* ``num_tasks``           — T;
* ``X_<i>, t_<i>, Y_<i>, mask_<i>`` for ``i in range(T)``; ``Y`` is stored
  zeroed where unobserved (the :class:`~repro.data.curves.CurveTask` mask
  convention, enforced on load);
* ``Y_full_<i>``          — optional ground-truth curves (present when the
  artifact was exported from a source with post-cutoff values, e.g. the
  synthetic prior or LCBench's complete tables; absent for genuinely
  censored logs, in which case the loader falls back to ``Y`` and records
  ``has_full=False``);
* ``meta_json``           — a JSON string: task names, metric name,
  ``maximize`` convention, free-form extras.

Everything loads with ``allow_pickle=False``; the artifact is hermetic.
"""
from __future__ import annotations

import json
from typing import NamedTuple

import numpy as np

from .curves import CurveTask

__all__ = ["FORMAT", "LCBenchArtifact", "write_artifact", "load_artifact"]

FORMAT = "lcbench-v1"


class LCBenchArtifact(NamedTuple):
    """A loaded artifact: tasks plus their metadata."""

    tasks: list          # list[CurveTask]
    names: list          # list[str], one per task
    metric: str          # e.g. "val_accuracy", "val_loss"
    maximize: bool       # metric convention (True: larger is better)
    has_full: list       # list[bool]: task i carries ground-truth Y_full
    meta: dict           # the full decoded meta_json


def write_artifact(path, tasks, *, names=None, metric: str = "val_accuracy",
                   maximize: bool = True, extra_meta: dict | None = None):
    """Write ``tasks`` (list of :class:`CurveTask`) as one npz artifact.

    ``Y`` is stored masked (zeroed where unobserved). ``Y_full`` is stored
    only when it genuinely differs from the masked observations — an
    artifact round-trips the distinction between "full curves + early-stop
    protocol mask" and "censored logs".
    """
    tasks = list(tasks)
    if not tasks:
        raise ValueError("write_artifact needs at least one task")
    names = ([f"task{i}" for i in range(len(tasks))]
             if names is None else list(names))
    if len(names) != len(tasks):
        raise ValueError(f"{len(names)} names for {len(tasks)} tasks")

    arrays: dict = {"format": np.asarray(FORMAT),
                    "num_tasks": np.asarray(len(tasks), np.int64)}
    has_full = []
    for i, tk in enumerate(tasks):
        X = np.asarray(tk.X, np.float64)
        t = np.asarray(tk.t, np.float64)
        Y = np.asarray(tk.Y, np.float64)
        mask = np.asarray(tk.mask, np.float64)
        if t.ndim != 1 or np.any(np.diff(t) <= 0) or t[0] <= 0:
            raise ValueError(f"task {i}: t must be positive and strictly "
                             f"increasing, got {t}")
        if Y.shape != mask.shape or Y.shape != (X.shape[0], t.shape[0]):
            raise ValueError(f"task {i}: inconsistent shapes X{X.shape} "
                             f"t{t.shape} Y{Y.shape} mask{mask.shape}")
        arrays[f"X_{i}"] = X
        arrays[f"t_{i}"] = t
        arrays[f"Y_{i}"] = Y * mask
        arrays[f"mask_{i}"] = mask
        stored = (tk.Y_full is not None
                  and not np.array_equal(np.asarray(tk.Y_full) * mask,
                                         np.asarray(tk.Y_full)))
        # Y_full differs from its masked view somewhere -> real post-cutoff
        # ground truth worth storing. (A fully-observed task needs no copy:
        # its masked Y already IS complete ground truth, so it still counts
        # as has_full.)
        if stored:
            arrays[f"Y_full_{i}"] = np.asarray(tk.Y_full, np.float64)
        has_full.append(bool(stored or np.all(mask > 0)))

    meta = {"names": names, "metric": metric, "maximize": bool(maximize),
            "has_full": has_full}
    meta.update(extra_meta or {})
    arrays["meta_json"] = np.asarray(json.dumps(meta))
    with open(path, "wb") as f:
        np.savez(f, **arrays)
    return path


def load_artifact(path) -> LCBenchArtifact:
    """Load an npz artifact into tasks + metadata.

    Mask semantics are enforced on load: ``Y`` comes back zeroed where
    unobserved even if the file stored raw values there. Tasks without a
    stored ``Y_full`` get ``Y_full = Y`` (masked) and ``has_full=False`` —
    callers scoring against ground truth must restrict to observed cells
    for those tasks.
    """
    with np.load(path, allow_pickle=False) as z:
        fmt = str(z["format"])
        if fmt != FORMAT:
            raise ValueError(f"unknown artifact format {fmt!r} in {path}; "
                             f"expected {FORMAT!r}")
        meta = json.loads(str(z["meta_json"]))
        T = int(z["num_tasks"])
        tasks, has_full = [], []
        for i in range(T):
            X = z[f"X_{i}"]
            t = z[f"t_{i}"]
            mask = z[f"mask_{i}"]
            Y = z[f"Y_{i}"] * mask
            key = f"Y_full_{i}"
            if key in z.files:
                Y_full = z[key]
                has_full.append(True)
            else:
                Y_full = Y.copy()
                # A fully-observed task needs no stored copy: the masked Y
                # already covers every cell, so it still has ground truth.
                has_full.append(bool(np.all(mask > 0)))
            tasks.append(CurveTask(X=X, t=t, Y=Y, mask=mask, Y_full=Y_full))
    return LCBenchArtifact(tasks=tasks,
                           names=list(meta.get("names",
                                               [f"task{i}" for i in range(T)])),
                           metric=str(meta.get("metric", "metric")),
                           maximize=bool(meta.get("maximize", True)),
                           has_full=has_full, meta=meta)
