"""Deterministic synthetic token pipeline for LM training.

Tokens are generated from a counter-based PRNG keyed by (stream_seed, step,
shard), so the stream is (a) reproducible across restarts — a trainer resumed
from step k sees exactly the tokens it would have seen — and (b) shardable
across hosts without communication. A Zipf-ish marginal plus a short Markov
blend gives non-trivial, learnable structure for the end-to-end examples.
"""
from __future__ import annotations

import numpy as np

__all__ = ["TokenPipeline"]


class TokenPipeline:
    def __init__(self, vocab_size: int, batch: int, seq_len: int,
                 seed: int = 0, markov_order: int = 1):
        self.vocab_size = vocab_size
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed
        rng = np.random.default_rng(seed)
        # fixed task structure: Zipf unigram + a sparse bigram table
        ranks = np.arange(1, vocab_size + 1)
        self.unigram = (1.0 / ranks ** 1.1)
        self.unigram /= self.unigram.sum()
        self.shift = rng.integers(1, vocab_size)

    def batch_at(self, step: int, shard: int = 0, num_shards: int = 1):
        """(tokens, labels) for a global step; deterministic in (step, shard)."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, shard]))
        b = self.batch // num_shards
        base = rng.choice(self.vocab_size, size=(b, self.seq_len + 1),
                          p=self.unigram)
        # half the positions follow a deterministic bigram (learnable signal)
        follow = rng.random((b, self.seq_len)) < 0.5
        nxt = (base[:, :-1] + self.shift) % self.vocab_size
        seq = base.copy()
        seq[:, 1:] = np.where(follow, nxt, base[:, 1:])
        tokens = seq[:, :-1].astype(np.int32)
        labels = seq[:, 1:].astype(np.int32)
        return tokens, labels
