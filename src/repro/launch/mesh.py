"""Production meshes.

Single pod = 16 x 16 = 256 chips (TPU v5e pod), axes (data, model).
Multi-pod = 2 x 16 x 16 = 512 chips, axes (pod, data, model); 'pod' is an
extra data-parallel (or pipeline) axis whose collectives cross the DCN/ICI
pod boundary.

Defined as functions so importing this module never touches jax device
state (jax locks the device count on first backend init).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_debug_mesh"]


def _make_mesh(shape, axes):
    if hasattr(jax.sharding, "AxisType"):  # axis_types landed after 0.4.x
        return jax.make_mesh(shape, axes,
                             axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 2, pod: int | None = None):
    """Small host-device mesh for tests (requires the XLA host-device flag)."""
    if pod is None:
        return _make_mesh((data, model), ("data", "model"))
    return _make_mesh((pod, data, model), ("pod", "data", "model"))
