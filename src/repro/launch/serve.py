"""Serving drivers: LM decode on a mesh, or the LKGP curve service.

LM mode (default; batched prefill + greedy decode)::

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6_1b6 --smoke \
        --batch 8 --prompt-len 32 --gen 32

uses the serve-optimized sharding rules (weights resident; see
DESIGN.md §6.5): prefill emits the natural cache layout and the decode
loop runs with donated caches.

Curve-prediction mode drives :class:`repro.serving.PredictionService` —
multi-tenant streaming observes with warm refits, coalesced predictions::

    PYTHONPATH=src python -m repro.launch.serve --service curves \
        --tenants 8 --rounds 4
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, get_smoke_config
from ..models import build_model
from ..train.trainer import make_serve_steps
from .train import make_mesh_from_args


def main_curves(args):
    """Streaming LKGP curve-service driver (synthetic tenants)."""
    from ..core import LKGPConfig
    from ..data.curves import sample_task
    from ..serving import PredictionService, ServiceConfig

    svc = PredictionService(ServiceConfig(
        gp=LKGPConfig(lbfgs_iters=args.lbfgs_iters, backend="dense"),
        capacity=max(args.tenants, 1),
        refit_every=args.refit_every))
    tasks = {f"tenant-{i}": sample_task(args.seed + i, n=args.n, m=args.m,
                                        d=4)
             for i in range(args.tenants)}

    # Cold fits, coalesced across tenants into one vmapped L-BFGS.
    svc.observe_batch([
        dict(tenant=name, task="run", X=task.X, t=task.t,
             Y=task.Y, mask=task.mask)
        for name, task in tasks.items()])

    masks = {name: np.asarray(task.mask).copy()
             for name, task in tasks.items()}
    for rnd in range(args.rounds):
        for name, task in tasks.items():   # reveal one more epoch per curve
            mask = masks[name]
            for i in range(mask.shape[0]):
                k = int(mask[i].sum())      # lint: disable=RA103
                if k < mask.shape[1]:
                    mask[i, k] = 1.0
            Y = np.where(mask > 0,
                         np.asarray(task.Y_full),    # lint: disable=RA103
                         0.0)
            svc.observe(name, "run", Y, mask)
        preds = svc.predict_many([(name, "run") for name in tasks])
        # Prediction.mean is host numpy already — no device sync here.
        best = {p.tenant: float(np.max(p.mean))      # lint: disable=RA103
                for p in preds}
        print(f"round {rnd}: coalesced batch={preds[0].batch_size} "
              f"best-final={max(best.values()):.4f}")

    # Per-request repeats ride the warm state-keyed posterior cache.
    t0 = time.time()
    for name in tasks:
        svc.predict(name, "run")
    print(f"warm per-request sweep: "
          f"{(time.time() - t0) / max(len(tasks), 1) * 1e3:.2f} ms/req")
    m = svc.metrics()
    print(f"store={m['store']} counters={m['counters']}")
    print(f"predict p50={m['predict_latency']['p50_ms']:.2f} ms "
          f"p99={m['predict_latency']['p99_ms']:.2f} ms")
    return m


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--service", default="lm", choices=["lm", "curves"],
                    help="lm: decode loop (default); curves: LKGP service")
    ap.add_argument("--arch")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--mesh", default="debug",
                    choices=["debug", "single", "multi"])
    ap.add_argument("--seed", type=int, default=0)
    # curve-service knobs
    ap.add_argument("--tenants", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--n", type=int, default=8)
    ap.add_argument("--m", type=int, default=10)
    ap.add_argument("--refit-every", type=int, default=4)
    ap.add_argument("--lbfgs-iters", type=int, default=10)
    args = ap.parse_args(argv)

    if args.service == "curves":
        return main_curves(args)
    if args.arch is None:
        ap.error("--arch is required for --service lm")

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    mesh = make_mesh_from_args(args)
    # Only VLM configs carry patch tokens; anything else (including ad-hoc
    # config objects) contributes 0 to the cache length.
    num_patch = getattr(cfg, "num_patch_tokens", 0) or 0
    serve = make_serve_steps(model, mesh,
                             max_len=args.prompt_len + args.gen + num_patch)
    with mesh:
        params = jax.jit(model.init,
                         out_shardings=serve["param_shardings"])(
                             jax.random.key(args.seed))
        batch = {"tokens": jax.random.randint(
            jax.random.PRNGKey(args.seed + 1),
            (args.batch, args.prompt_len), 0, cfg.vocab_size)}
        if cfg.family in ("audio", "encdec"):
            batch["frames"] = jnp.zeros(
                (args.batch, cfg.enc_frames, cfg.d_model), jnp.float32)
        if cfg.family == "vlm":
            batch["prefix_embeds"] = jnp.zeros(
                (args.batch, cfg.num_patch_tokens, cfg.d_model), jnp.float32)

        t0 = time.time()
        logits, cache = jax.jit(serve["prefill"])(params, batch)
        jax.block_until_ready(logits)
        t_prefill = time.time() - t0

        step = jax.jit(serve["decode_step"], donate_argnums=(1,))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out = [tok]
        t0 = time.time()
        for _ in range(args.gen - 1):
            logits, cache = step(params, cache, tok)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            out.append(tok)
        jax.block_until_ready(logits)
        t_decode = time.time() - t0

    gen = np.asarray(jnp.concatenate(out, axis=1))
    print(f"arch={args.arch} batch={args.batch} prompt={args.prompt_len} "
          f"generated={gen.shape[1]}")
    print(f"prefill: {t_prefill*1e3:.1f} ms; decode: "
          f"{t_decode/max(args.gen-1,1)*1e3:.1f} ms/token "
          f"({args.batch*(args.gen-1)/max(t_decode,1e-9):.0f} tok/s)")
    for i in range(min(2, args.batch)):
        print(f"  req {i}: {gen[i, :10].tolist()} ...")
    return gen


if __name__ == "__main__":
    main()
