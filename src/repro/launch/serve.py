"""Serving driver: batched prefill + greedy decode on a mesh.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6_1b6 --smoke \
        --batch 8 --prompt-len 32 --gen 32

Uses the serve-optimized sharding rules (weights resident; see
DESIGN.md §6.5): prefill emits the natural cache layout and the decode
loop runs with donated caches.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, get_smoke_config
from ..models import build_model
from ..train.trainer import make_serve_steps
from .train import make_mesh_from_args


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--mesh", default="debug",
                    choices=["debug", "single", "multi"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    mesh = make_mesh_from_args(args)
    # Only VLM configs carry patch tokens; anything else (including ad-hoc
    # config objects) contributes 0 to the cache length.
    num_patch = getattr(cfg, "num_patch_tokens", 0) or 0
    serve = make_serve_steps(model, mesh,
                             max_len=args.prompt_len + args.gen + num_patch)
    with mesh:
        params = jax.jit(model.init,
                         out_shardings=serve["param_shardings"])(
                             jax.random.key(args.seed))
        batch = {"tokens": jax.random.randint(
            jax.random.PRNGKey(args.seed + 1),
            (args.batch, args.prompt_len), 0, cfg.vocab_size)}
        if cfg.family in ("audio", "encdec"):
            batch["frames"] = jnp.zeros(
                (args.batch, cfg.enc_frames, cfg.d_model), jnp.float32)
        if cfg.family == "vlm":
            batch["prefix_embeds"] = jnp.zeros(
                (args.batch, cfg.num_patch_tokens, cfg.d_model), jnp.float32)

        t0 = time.time()
        logits, cache = jax.jit(serve["prefill"])(params, batch)
        jax.block_until_ready(logits)
        t_prefill = time.time() - t0

        step = jax.jit(serve["decode_step"], donate_argnums=(1,))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out = [tok]
        t0 = time.time()
        for _ in range(args.gen - 1):
            logits, cache = step(params, cache, tok)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            out.append(tok)
        jax.block_until_ready(logits)
        t_decode = time.time() - t0

    gen = np.asarray(jnp.concatenate(out, axis=1))
    print(f"arch={args.arch} batch={args.batch} prompt={args.prompt_len} "
          f"generated={gen.shape[1]}")
    print(f"prefill: {t_prefill*1e3:.1f} ms; decode: "
          f"{t_decode/max(args.gen-1,1)*1e3:.1f} ms/token "
          f"({args.batch*(args.gen-1)/max(t_decode,1e-9):.0f} tok/s)")
    for i in range(min(2, args.batch)):
        print(f"  req {i}: {gen[i, :10].tolist()} ...")
    return gen


if __name__ == "__main__":
    main()
