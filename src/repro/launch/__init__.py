"""Production meshes, multi-pod dry-run, roofline analysis."""
