"""Training driver: config -> mesh -> restore-or-init -> step loop.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm_12b \
        --smoke --steps 20 --ckpt-dir /tmp/ckpt --ckpt-every 10

Fault tolerance: atomic keep-K checkpoints (async), deterministic data
keyed by step (restart replays the exact stream), `--simulate-preempt N`
kills the process at step N to exercise restart in tests, and elastic
restore works across device counts (mesh-independent checkpoint layout).
"""
import argparse
import os
import time

import jax
import jax.numpy as jnp

from ..checkpoint import CheckpointManager
from ..configs import get_config, get_smoke_config
from ..data import TokenPipeline
from ..distributed.sharding import batch_shardings, rules_for
from ..models import build_model
from ..train.optimizers import OptConfig
from ..train.trainer import make_train_step


def make_mesh_from_args(args):
    from .mesh import make_debug_mesh, make_production_mesh

    if args.mesh == "debug":
        n = len(jax.devices())
        model_ax = 2 if n % 2 == 0 else 1
        return make_debug_mesh(data=n // model_ax, model=model_ax)
    return make_production_mesh(multi_pod=(args.mesh == "multi"))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--mesh", default="debug",
                    choices=["debug", "single", "multi"])
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--keep", type=int, default=3)
    ap.add_argument("--simulate-preempt", type=int, default=-1)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    mesh = make_mesh_from_args(args)
    opt = OptConfig(name=args.optimizer, peak_lr=args.lr,
                    warmup_steps=max(2, args.steps // 20),
                    decay_steps=args.steps)
    setup = make_train_step(model, mesh, opt_cfg=opt,
                            grad_accum=args.grad_accum)

    ckpt = CheckpointManager(args.ckpt_dir, keep=args.keep) \
        if args.ckpt_dir else None
    start_step = 0
    with mesh:
        state_shapes = jax.eval_shape(setup.init_state, jax.random.key(0))
        if ckpt and ckpt.latest_step() is not None:
            state = ckpt.restore(state_shapes,
                                 shardings=setup.state_shardings)
            start_step = int(state.step)
            print(f"restored checkpoint at step {start_step}", flush=True)
        else:
            state = jax.jit(setup.init_state,
                            out_shardings=setup.state_shardings)(
                                jax.random.key(0))

        pipe = TokenPipeline(cfg.vocab_size, args.batch, args.seq)
        losses = []
        t0 = time.time()
        for step in range(start_step, args.steps):
            tokens, labels = pipe.batch_at(step)
            batch = {"tokens": jnp.asarray(tokens),
                     "labels": jnp.asarray(labels)}
            if cfg.family in ("audio", "encdec"):
                batch["frames"] = jnp.zeros(
                    (args.batch, cfg.enc_frames, cfg.d_model), jnp.float32)
            if cfg.family == "vlm":
                batch["prefix_embeds"] = jnp.zeros(
                    (args.batch, cfg.num_patch_tokens, cfg.d_model),
                    jnp.float32)
            sh = batch_shardings(
                {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                 for k, v in batch.items()}, mesh)
            batch = {k: jax.device_put(v, sh[k]) for k, v in batch.items()}
            state, metrics = setup.step_fn(state, batch)
            # Device scalar stays on device: a float() here is one host
            # sync per step and stalls async dispatch (RA103). Converted
            # in bulk after the loop.
            losses.append(metrics["loss"])
            if (step + 1) % args.log_every == 0:
                dt = (time.time() - t0) / args.log_every
                print(f"step {step+1:5d} loss {losses[-1]:.4f} "
                      f"({dt*1e3:.0f} ms/step)", flush=True)
                t0 = time.time()
            if ckpt and (step + 1) % args.ckpt_every == 0:
                ckpt.save(step + 1, state)
            if args.simulate_preempt == step + 1:
                print(f"SIMULATED PREEMPTION at step {step+1}", flush=True)
                if ckpt:
                    ckpt.wait()
                os._exit(42)
        if ckpt:
            ckpt.save(args.steps, state)
            ckpt.wait()
    losses = [float(x) for x in losses]
    print(f"final loss: {losses[-1]:.4f} (first: {losses[0]:.4f})")
    return losses


if __name__ == "__main__":
    main()
