import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# The two lines above MUST run before any jax import: jax locks the device
# count at first backend initialisation. 512 host devices stand in for the
# 2-pod production fleet; nothing below allocates real buffers (lower/compile
# on ShapeDtypeStructs only).
"""Multi-pod dry-run: prove every (arch x shape x mesh) cell lowers,
SPMD-partitions, and compiles on the production meshes.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2_72b \
        --shape train_4k --mesh single,multi --out artifacts/dryrun

Per cell it writes a JSON artifact with compiled.memory_analysis(),
cost_analysis(), and the collective-bytes breakdown parsed from the
optimized HLO (see hlo_analysis.py). EXPERIMENTS.md §Dry-run and §Roofline
are generated from these artifacts.
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCH_IDS, SHAPES, get_config, shape_applicable
from ..distributed.sharding import batch_shardings, rules_for
from ..models import active_params, build_model, count_params, make_input_specs
from ..train.optimizers import OptConfig
from ..train.trainer import make_serve_steps, make_train_step
from .hlo_analysis import analyze_collectives
from .mesh import make_production_mesh

MESHES = {"single": False, "multi": True}


def _with_shardings(specs: dict, shardings: dict):
    return {k: jax.ShapeDtypeStruct(v.shape, v.dtype, sharding=shardings[k])
            for k, v in specs.items()}


def _opt_for(cfg):
    # 400B-class MoE: bf16 moments + adafactor to fit v5e HBM (DESIGN.md §5).
    n = count_params(cfg)
    if n >= 1e11:
        return OptConfig(name="adafactor", moments_dtype=jnp.bfloat16)
    return OptConfig(name="adamw")


def _accum_for(cfg, shape):
    """Gradient-accumulation factor for train shapes.

    Targets <= ~8k tokens per device per microbatch (v5e HBM budget for the
    saved layer-boundary activations of the remat'd scan).
    """
    if shape.kind != "train":
        return 1
    tokens = shape.global_batch * shape.seq_len
    per_dev = tokens / 16  # batch shards over the 16-wide 'data' axis
    target = 4096 if (cfg.moe and cfg.d_model >= 7000) else 8192
    accum = max(1, int(per_dev // target))
    while shape.global_batch % accum:
        accum -= 1
    return accum


def lower_cell(arch: str, shape_name: str, mesh, mesh_name: str,
               profile: str = "optimized"):
    """Lower + compile one cell; returns the artifact dict.

    profile "baseline": the paper-faithful first implementation (einsum MoE
    dispatch, FSDP rules for serving). "optimized": shard_map expert-parallel
    MoE, resident serve weights, ZeRO-DP for the dense trains (§Perf).
    """
    from ..distributed.sharding import (SERVE_RULES, SP_ACT_RULES,
                                        ZERO_ACT_RULES, ZERO_RULES,
                                        set_active_mesh)

    from ..distributed.sharding import SERVE_DECODE_RULES

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    model = build_model(cfg)
    rules = rules_for(cfg)
    act_rules = None
    if profile == "baseline":
        serve_rules = rules
    elif shape.kind == "decode":
        serve_rules = SERVE_DECODE_RULES
    else:
        # prefill: token-heavy, so FSDP weight gathers amortise for dense
        # archs (iter-5: resident-TP regressed qwen2 prefill 13->27 s);
        # MoE keeps SERVE_RULES (expert residency is the 28x win there).
        serve_rules = SERVE_RULES if cfg.moe else rules
    if profile == "baseline":
        set_active_mesh(None)  # einsum MoE dispatch path
    if profile == "optimized" and shape.kind == "train" \
            and not cfg.moe and count_params(cfg) >= 1e10:
        # ZeRO-DP hillclimb: both axes data-parallel, weights 256-way sharded
        rules, act_rules = ZERO_RULES, ZERO_ACT_RULES
    if profile == "optimized" and shape.kind == "train" and cfg.moe:
        act_rules = SP_ACT_RULES  # sequence-parallel layer boundaries
    specs = make_input_specs(cfg, shape)
    t0 = time.time()

    grad_accum = _accum_for(cfg, shape)
    if profile == "optimized" and act_rules is ZERO_ACT_RULES:
        grad_accum = 1  # 256-way DP: 4k tokens/chip fit without accumulation
    if shape.kind == "train":
        setup = make_train_step(model, mesh, opt_cfg=_opt_for(cfg),
                                rules=rules, act_rules=act_rules,
                                grad_accum=grad_accum)
        state_shapes = jax.eval_shape(setup.init_state, jax.random.key(0))
        state_in = jax.tree_util.tree_map(
            lambda sds, sh: jax.ShapeDtypeStruct(sds.shape, sds.dtype,
                                                 sharding=sh),
            state_shapes, setup.state_shardings)
        batch_in = _with_shardings(specs, batch_shardings(specs, mesh))
        with mesh:
            lowered = setup.step_fn.lower(state_in, batch_in)
    else:
        serve = make_serve_steps(model, mesh, rules=serve_rules,
                                 max_len=shape.seq_len)
        p_shapes = jax.eval_shape(lambda k: model.init(k), jax.random.key(0))
        p_in = jax.tree_util.tree_map(
            lambda sds, sh: jax.ShapeDtypeStruct(sds.shape, sds.dtype,
                                                 sharding=sh),
            p_shapes, serve["param_shardings"])
        from jax.sharding import NamedSharding, PartitionSpec as P

        cache_sh = serve["cache_shardings"](
            shape.global_batch,
            prefer="time" if shape.kind == "decode" else "width")
        vocab_ok = cfg.vocab_size % mesh.shape.get("model", 1) == 0
        logits_sh = NamedSharding(mesh, P(None, "model" if vocab_ok else None))
        if shape.kind == "prefill":
            batch_in = _with_shardings(specs, batch_shardings(specs, mesh))
            fn = jax.jit(serve["prefill"],
                         in_shardings=(serve["param_shardings"],
                                       {k: v.sharding for k, v in
                                        batch_in.items()}),
                         out_shardings=(logits_sh, cache_sh))
            with mesh:
                lowered = fn.lower(p_in, batch_in)
        else:
            cache_shapes = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len))
            cache_in = jax.tree_util.tree_map(
                lambda sds, sh: jax.ShapeDtypeStruct(sds.shape, sds.dtype,
                                                     sharding=sh),
                cache_shapes, cache_sh)
            batch_in = _with_shardings(specs, batch_shardings(specs, mesh))
            fn = jax.jit(serve["decode_step"], donate_argnums=(1,),
                         out_shardings=(logits_sh, cache_sh))
            with mesh:
                lowered = fn.lower(p_in, cache_in, batch_in["tokens"])

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # older jax: one dict per device
        cost = cost[0] if cost else {}
    n_dev = mesh.devices.size
    stats = analyze_collectives(compiled.as_text(), n_dev)
    # layer-scan trip count x grad-accum loop (see hlo_analysis caveats)
    body_mult = cfg.num_layers * max(1, grad_accum)

    artifact = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "num_devices": int(n_dev),
        "params": count_params(cfg),
        "active_params": active_params(cfg),
        "grad_accum": grad_accum,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "cost_analysis": {
            "flops_per_device": float(cost.get("flops", -1.0)),
            "bytes_accessed_per_device": float(cost.get("bytes accessed",
                                                        -1.0)),
        },
        "memory_analysis": {
            "argument_bytes_per_device": mem.argument_size_in_bytes,
            "output_bytes_per_device": mem.output_size_in_bytes,
            "temp_bytes_per_device": mem.temp_size_in_bytes,
            "alias_bytes_per_device": mem.alias_size_in_bytes,
            "generated_code_bytes": mem.generated_code_size_in_bytes,
        },
        "collectives": {
            "raw": {k: dict(count=v[0], result_bytes=v[1], wire_bytes=v[2])
                    for k, v in {**stats.entry}.items()},
            "in_loop_bodies": {k: dict(count=v[0], result_bytes=v[1],
                                       wire_bytes=v[2])
                               for k, v in {**stats.body}.items()},
            "body_multiplier": body_mult,
            "totals": stats.totals(body_mult),
            "total_wire_bytes_per_device": stats.total_wire_bytes(body_mult),
        },
    }
    return artifact


def lower_lkgp_cell(mesh, mesh_name: str, n: int = 8192, m: int = 100,
                    d: int = 16, dtype=None):
    """The paper's own technique on the production mesh: one distributed
    latent-Kronecker CG fit step (row-sharded configs, see DESIGN.md §3).

    Roofline unit = one CG iteration (the while-loop body, which matches
    XLA's loop-body-once cost accounting).
    """
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from ..distributed.lkgp_dist import dist_mll_value

    dtype = dtype or jnp.float32  # TPU adaptation: fp32 (see DESIGN.md §3)
    row = NamedSharding(mesh, P("data", None))
    rep = NamedSharding(mesh, P())
    X = jax.ShapeDtypeStruct((n, d), dtype, sharding=row)
    Y = jax.ShapeDtypeStruct((n, m), dtype, sharding=row)
    mask = jax.ShapeDtypeStruct((n, m), dtype, sharding=row)
    t = jax.ShapeDtypeStruct((m,), dtype, sharding=rep)
    ls = jax.ShapeDtypeStruct((d,), dtype, sharding=rep)
    sc = jax.ShapeDtypeStruct((), dtype, sharding=rep)

    def fit_quad(ls_, tls, os_, noise, X_, t_, Y_, mask_):
        return dist_mll_value(mesh, ls_, tls, os_, noise, X_, t_, Y_, mask_)

    t0 = time.time()
    with mesh:
        lowered = jax.jit(fit_quad).lower(ls, sc, sc, sc, X, t, Y, mask)
        compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # older jax: one dict per device
        cost = cost[0] if cost else {}
    stats = analyze_collectives(compiled.as_text(), mesh.devices.size)
    chips = int(mesh.devices.size)
    # analytic per-CG-iteration costs (the MVM dominates)
    mvm_flops = (2 * n * n * m + 2 * n * m * m) / chips
    ag_bytes = n * m * dtype(0).dtype.itemsize * (chips - 1) / chips
    return {
        "arch": "lkgp", "shape": f"fit_n{n}_m{m}", "mesh": mesh_name,
        "num_devices": chips, "params": 0, "active_params": 0,
        "grad_accum": 1,
        "analytic_per_cg_iter": {
            "flops_per_chip": mvm_flops,
            "allgather_bytes_per_chip": ag_bytes,
        },
        "cost_analysis": {
            "flops_per_device": float(cost.get("flops", -1.0)),
            "bytes_accessed_per_device": float(cost.get("bytes accessed",
                                                        -1.0)),
        },
        "memory_analysis": {
            "argument_bytes_per_device": mem.argument_size_in_bytes,
            "output_bytes_per_device": mem.output_size_in_bytes,
            "temp_bytes_per_device": mem.temp_size_in_bytes,
            "alias_bytes_per_device": mem.alias_size_in_bytes,
            "generated_code_bytes": mem.generated_code_size_in_bytes,
        },
        "collectives": {
            "raw": {k: dict(count=v[0], result_bytes=v[1], wire_bytes=v[2])
                    for k, v in {**stats.entry}.items()},
            "in_loop_bodies": {k: dict(count=v[0], result_bytes=v[1],
                                       wire_bytes=v[2])
                               for k, v in {**stats.body}.items()},
            "body_multiplier": 1,
            "totals": stats.totals(1.0),
            "total_wire_bytes_per_device": stats.total_wire_bytes(1.0),
        },
        "compile_s": round(time.time() - t0, 2),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="comma list or 'all'")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single,multi")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--profile", default="optimized",
                    choices=["baseline", "optimized"])
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--fail-fast", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = args.mesh.split(",")
    os.makedirs(args.out, exist_ok=True)

    results = []
    for mesh_name in meshes:
        mesh = make_production_mesh(multi_pod=MESHES[mesh_name])
        if "lkgp" in archs:
            art = lower_lkgp_cell(mesh, mesh_name)
            path = os.path.join(args.out, f"lkgp__fit__{mesh_name}.json")
            with open(path, "w") as f:
                json.dump(art, f, indent=1)
            print(f"OK    lkgp                     fit_8k       {mesh_name:6s} "
                  f"compile={art['compile_s']:7.1f}s "
                  f"temp/dev={art['memory_analysis']['temp_bytes_per_device']/2**30:6.2f}GiB",
                  flush=True)
        for arch in archs:
            if arch == "lkgp":
                continue
            for shape_name in shapes:
                if not shape_applicable(arch, shape_name):
                    print(f"SKIP  {arch:24s} {shape_name:12s} {mesh_name}"
                          " (inapplicable: full attention at 500k)")
                    continue
                path = os.path.join(args.out,
                                    f"{arch}__{shape_name}__{mesh_name}.json")
                if args.skip_existing and os.path.exists(path):
                    print(f"HAVE  {arch:24s} {shape_name:12s} {mesh_name}")
                    continue
                try:
                    art = lower_cell(arch, shape_name, mesh, mesh_name,
                                     profile=args.profile)
                    with open(path, "w") as f:
                        json.dump(art, f, indent=1)
                    ma = art["memory_analysis"]
                    print(f"OK    {arch:24s} {shape_name:12s} {mesh_name:6s} "
                          f"compile={art['compile_s']:7.1f}s "
                          f"args/dev={ma['argument_bytes_per_device']/2**30:6.2f}GiB "
                          f"temp/dev={ma['temp_bytes_per_device']/2**30:6.2f}GiB "
                          f"flops/dev={art['cost_analysis']['flops_per_device']:.3e}",
                          flush=True)
                    results.append((arch, shape_name, mesh_name, "OK"))
                except Exception as e:  # noqa: BLE001 - report and continue
                    print(f"FAIL  {arch:24s} {shape_name:12s} {mesh_name}: "
                          f"{type(e).__name__}: {e}", flush=True)
                    traceback.print_exc()
                    results.append((arch, shape_name, mesh_name, "FAIL"))
                    if args.fail_fast:
                        raise
    ok = sum(1 for r in results if r[-1] == "OK")
    print(f"\ndry-run: {ok}/{len(results)} cells compiled")
    if any(r[-1] == "FAIL" for r in results):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
