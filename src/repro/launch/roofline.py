"""Roofline analysis from dry-run artifacts (deliverable g).

Hardware model (TPU v5e, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI. Three terms per (arch x shape x mesh) cell, in seconds:

    compute    = FLOPs_per_chip / 197e12
    memory     = HBM_bytes_per_chip / 819e9
    collective = collective_wire_bytes_per_chip / 50e9

FLOPs/bytes sources. XLA's cost analysis counts loop bodies ONCE (verified
empirically — see EXPERIMENTS.md §Roofline), so compiled.cost_analysis() on
a scan-over-layers model underreports by ~num_layers. We therefore report
BOTH the raw cost_analysis numbers (artifact fidelity) and an analytic
per-arch cost model (validated against cost_analysis on single-layer configs
by tests/test_roofline.py) that the roofline terms use. Collective bytes come
from the HLO parse with the loop-body multiplier (hlo_analysis.py).

MODEL_FLOPS convention: 6*N*T for training (N = params, N_active for MoE,
T = tokens), 2*N*T for forward-only serving; attention FLOPs are excluded
from MODEL_FLOPS but included in the analytic compute term, so the ratio
MODEL_FLOPS / HLO_FLOPs surfaces remat recompute, attention overhead, and
MoE dispatch overhead.
"""
from __future__ import annotations

import glob
import json
import os

from ..configs import SHAPES, get_config
from ..models import active_params, count_params

__all__ = ["PEAK_FLOPS", "HBM_BW", "LINK_BW", "analytic_costs",
           "roofline_terms", "summarize_artifacts", "format_table"]

PEAK_FLOPS = 197e12     # bf16 / chip
HBM_BW = 819e9          # bytes/s / chip
LINK_BW = 50e9          # bytes/s / link
CHIPS_PER_POD = 256
DATA_AXIS = 16          # batch shards on the assigned meshes

_BF16 = 2
_F32 = 4


def _attn_flops_per_token(cfg, ctx_len, causal=True):
    """Score + weighted-value FLOPs per query token (per layer that has
    attention), GQA-aware; causal halves the average context."""
    eff = ctx_len / 2 if causal else ctx_len
    if cfg.window:
        eff = min(eff, cfg.window)
    return 4.0 * cfg.num_heads * cfg.head_dim * eff


def _layer_matmul_flops_per_token(cfg):
    """Projection/MLP matmul FLOPs per token per layer (forward)."""
    D, Hq, Hkv, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    f = 0.0
    if cfg.family == "ssm":  # rwkv6: 5 square proj + out + channel mix
        f += 2 * D * D * 6                      # r,k,v,g,o,w-ish projections
        f += 2 * D * cfg.rwkv_head_size * 2     # wkv state update + readout
        f += 2 * (2 * D * cfg.d_ff + D * D)     # channel mix (wk, wv) + wr
        return f
    if cfg.family == "hybrid":
        R = cfg.rnn_width
        pat = cfg.block_pattern
        n_attn = sum(1 for b in pat if b == "attn") / len(pat)
        n_rec = 1 - n_attn
        attn_f = 2 * D * (Hq + 2 * Hkv) * Dh + 2 * Hq * Dh * D
        rec_f = 2 * D * R * 3 + 2 * R * R * 2 + 10 * R
        f += n_attn * attn_f + n_rec * rec_f
        f += 2 * 3 * D * cfg.d_ff               # GeGLU
        return f
    # attention projections
    f += 2 * D * (Hq + 2 * Hkv) * Dh + 2 * Hq * Dh * D
    if cfg.family in ("encdec", "audio"):
        f += 2 * D * (Hq + 2 * Hkv) * Dh + 2 * Hq * Dh * D  # cross-attn
        f += 2 * 2 * D * cfg.d_ff               # GELU MLP
        return f
    # FFN
    if cfg.moe:
        f += 2 * D * cfg.num_experts            # router
        f += 2 * 3 * D * cfg.moe_d_ff * cfg.moe_top_k * cfg.capacity_factor
        if cfg.moe_dense_residual:
            f += 2 * 3 * D * cfg.d_ff
    else:
        n_mat = 3 if cfg.mlp_act in ("swiglu", "geglu") else 2
        f += 2 * n_mat * D * cfg.d_ff
    return f


def analytic_costs(cfg, shape, chips: int, grad_accum: int = 1):
    """Per-chip FLOPs and HBM bytes for one step of this cell (analytic)."""
    B, S = shape.global_batch, shape.seq_len
    n_params = count_params(cfg)
    n_active = active_params(cfg)
    p_bytes = n_params * _BF16
    L = cfg.num_layers

    if shape.kind == "train":
        tokens = B * S
        fwd = tokens * (L * (_layer_matmul_flops_per_token(cfg)
                             + _attn_flops_per_token(cfg, S))
                        + 2 * cfg.d_model * cfg.vocab_size)
        # remat: fwd + recompute + 2x bwd = 4x matmul flops
        flops = 4.0 * fwd
        model_flops = 6.0 * n_active * tokens
        # HBM: params read fwd+bwd per microbatch + optimizer r/w (fp32-ish)
        opt_mult = 6 * _F32 / _BF16 if n_params < 1e11 else 3
        p_traffic = p_bytes * (2 * grad_accum + opt_mult)
        act = tokens * L * (6 * cfg.d_model + 2 * _ffn_width(cfg)) * _BF16 * 2
        logits = tokens * cfg.vocab_size * _F32 / (S / min(S, 512))  # chunked
        hbm = p_traffic + act + logits
    elif shape.kind == "prefill":
        tokens = B * S
        fwd = tokens * (L * (_layer_matmul_flops_per_token(cfg)
                             + _attn_flops_per_token(cfg, S)))
        fwd += B * 2 * cfg.d_model * cfg.vocab_size  # last-token logits
        flops = fwd
        model_flops = 2.0 * n_active * tokens
        act = tokens * L * (4 * cfg.d_model + _ffn_width(cfg)) * _BF16
        hbm = p_bytes + act
    else:  # decode: one token per sequence
        tokens = B
        ctx = S
        flops = tokens * (L * _layer_matmul_flops_per_token(cfg)
                          + 2 * cfg.d_model * cfg.vocab_size)
        if cfg.family not in ("ssm",):
            flops += tokens * L * _attn_flops_per_token(cfg, ctx,
                                                        causal=False)
        model_flops = 2.0 * n_active * tokens
        hbm = p_bytes + _cache_bytes(cfg, B, S)  # read cache once per step
    return {
        "flops_per_chip": flops / chips,
        "hbm_bytes_per_chip": hbm / chips,
        "model_flops_per_chip": model_flops / chips,
        "tokens": tokens,
    }


def _ffn_width(cfg):
    if cfg.moe:
        return cfg.moe_d_ff * cfg.moe_top_k + (cfg.d_ff if
                                               cfg.moe_dense_residual else 0)
    return cfg.d_ff


def _cache_bytes(cfg, B, S):
    L = cfg.num_layers
    if cfg.family == "ssm":
        H = cfg.d_model // cfg.rwkv_head_size
        return L * B * (H * cfg.rwkv_head_size ** 2 * _F32
                        + 2 * cfg.d_model * _BF16)
    if cfg.family == "hybrid":
        pat = cfg.block_pattern
        n_attn = sum(1 for b in pat if b == "attn") / len(pat)
        kv = n_attn * L * B * min(S, cfg.window) * 2 \
            * cfg.num_kv_heads * cfg.head_dim * _BF16
        rec = (1 - n_attn) * L * B * cfg.rnn_width * _F32
        return kv + rec
    kv = L * B * S * 2 * cfg.num_kv_heads * cfg.head_dim * _BF16
    if cfg.family in ("encdec", "audio"):
        kv += L * B * cfg.enc_frames * 2 * cfg.num_heads * cfg.head_dim * _BF16
    return kv


def roofline_terms(art: dict) -> dict:
    """Compute the three terms + diagnosis for one artifact."""
    cfg = get_config(art["arch"])
    shape = SHAPES[art["shape"]]
    chips = art["num_devices"]
    ana = analytic_costs(cfg, shape, chips, art.get("grad_accum", 1))

    compute_s = ana["flops_per_chip"] / PEAK_FLOPS
    memory_s = ana["hbm_bytes_per_chip"] / HBM_BW
    coll_bytes = art["collectives"]["total_wire_bytes_per_device"]
    collective_s = coll_bytes / LINK_BW

    bound = max(compute_s, memory_s, collective_s)
    dominant = ("compute" if bound == compute_s else
                "memory" if bound == memory_s else "collective")
    ideal_s = ana["model_flops_per_chip"] / PEAK_FLOPS
    fraction = ideal_s / bound if bound > 0 else 0.0

    raw_flops = art["cost_analysis"]["flops_per_device"]
    return {
        "arch": art["arch"], "shape": art["shape"], "mesh": art["mesh"],
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s, "dominant": dominant,
        "roofline_fraction": fraction,
        "model_flops_per_chip": ana["model_flops_per_chip"],
        "analytic_flops_per_chip": ana["flops_per_chip"],
        "hlo_flops_per_chip_raw": raw_flops,
        "useful_ratio": (ana["model_flops_per_chip"]
                         / max(ana["flops_per_chip"], 1.0)),
        "temp_gib": art["memory_analysis"]["temp_bytes_per_device"] / 2**30,
        "args_gib": art["memory_analysis"]["argument_bytes_per_device"] / 2**30,
    }


def summarize_artifacts(paths=None, directory="artifacts/dryrun"):
    if paths is None:
        paths = sorted(glob.glob(os.path.join(directory, "*.json")))
    rows = []
    for p in paths:
        with open(p) as f:
            art = json.load(f)
        if art.get("arch") == "lkgp":  # special-cased in EXPERIMENTS §Roofline
            continue
        rows.append(roofline_terms(art))
    return rows


def format_table(rows, mesh="single") -> str:
    rows = [r for r in rows if r["mesh"] == mesh]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    lines = ["| arch | shape | compute s | memory s | coll s | bound | "
             "fraction | useful | mem/dev GiB |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.2e} | "
            f"{r['memory_s']:.2e} | {r['collective_s']:.2e} | "
            f"{r['dominant']} | {r['roofline_fraction']:.3f} | "
            f"{r['useful_ratio']:.2f} | "
            f"{r['args_gib'] + r['temp_gib']:.1f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    import sys

    rows = summarize_artifacts(
        directory=sys.argv[1] if len(sys.argv) > 1 else "artifacts/dryrun")
    for mesh in ("single", "multi"):
        print(f"\n== mesh: {mesh} ==")
        print(format_table(rows, mesh))
