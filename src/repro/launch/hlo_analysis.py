"""Post-SPMD HLO text analysis: collective bytes and schedules.

``compiled.cost_analysis()`` does not report communication, so we parse the
optimized (per-device) HLO module for all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute ops and sum their sizes.

Two caveats handled explicitly:
  * XLA counts loop bodies ONCE. Collectives are reported per computation;
    callers multiply non-entry-computation collectives by the loop trip
    count (for these models: the layer scan).
  * Sizes: we record RESULT shape bytes per op; ``wire_bytes`` converts to
    bytes actually crossing links with standard ring-algorithm factors.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

import numpy as np

__all__ = ["CollectiveStats", "analyze_collectives", "DTYPE_BYTES"]

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+)\[([\d,]*)\][^ ]*)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", )
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY )?%?([\w.\-]+)[^{]*\{", re.M)
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_OLD_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


@dataclass
class CollectiveStats:
    # kind -> [count, result_bytes, wire_bytes] aggregated
    entry: dict = field(default_factory=lambda: defaultdict(lambda: [0, 0, 0]))
    body: dict = field(default_factory=lambda: defaultdict(lambda: [0, 0, 0]))

    def totals(self, body_multiplier: float = 1.0):
        out = {}
        for kind in set(self.entry) | set(self.body):
            e = self.entry.get(kind, [0, 0, 0])
            b = self.body.get(kind, [0, 0, 0])
            out[kind] = {
                "count": e[0] + b[0] * body_multiplier,
                "result_bytes": e[1] + b[1] * body_multiplier,
                "wire_bytes": e[2] + b[2] * body_multiplier,
            }
        return out

    def total_wire_bytes(self, body_multiplier: float = 1.0) -> float:
        return sum(v["wire_bytes"]
                   for v in self.totals(body_multiplier).values())


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in DTYPE_BYTES:
        return 0
    if not dims:
        return DTYPE_BYTES[dtype]
    return DTYPE_BYTES[dtype] * int(np.prod([int(d) for d in dims.split(",")]))


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))  # [num_groups, group_size]<=[N]
    m = _GROUPS_OLD_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def _wire_bytes(kind: str, result_bytes: int, p: int) -> float:
    """Ring-algorithm bytes per participating device."""
    if p <= 1:
        return 0.0
    r = (p - 1) / p
    if kind == "all-gather":
        return result_bytes * r              # each device receives (p-1)/p
    if kind == "all-reduce":
        return 2.0 * result_bytes * r        # reduce-scatter + all-gather
    if kind == "reduce-scatter":
        return result_bytes * r * p          # operand = result * p
    if kind == "all-to-all":
        return result_bytes * r
    if kind == "collective-permute":
        return float(result_bytes)
    return float(result_bytes)


def analyze_collectives(hlo_text: str, num_devices: int) -> CollectiveStats:
    stats = CollectiveStats()
    current_comp = ""
    is_entry = False
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if stripped.startswith("ENTRY"):
            is_entry = True
            continue
        if stripped.startswith("}"):
            if line.startswith("}"):
                is_entry = False
            continue
        if not is_entry and line and not line.startswith(" "):
            # a new (non-entry) computation header
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(4)
        if "-done(" in line:   # size counted at -start
            continue
        # result shape: tuple (async pairs) or single
        if m.group(1) is not None:
            shapes = _SHAPE_RE.findall(m.group(1))
            rbytes = max((_shape_bytes(d, s) for d, s in shapes), default=0)
        else:
            rbytes = _shape_bytes(m.group(2), m.group(3))
        p = _group_size(line, num_devices)
        wire = _wire_bytes(kind, rbytes, p)
        bucket = stats.entry if is_entry else stats.body
        bucket[kind][0] += 1
        bucket[kind][1] += rbytes
        bucket[kind][2] += wire
    return stats
