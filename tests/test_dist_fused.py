"""Distributed fused Pallas MVM: per-shard kernel execution, numerics
against the einsum reference, and the f64 / VMEM gating of
``DistributedEngine(fused=...)``."""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import LKGPConfig
from repro.core.engines import DistributedEngine, IterativeEngine
from repro.core.mvm import lk_mvm


def _f32_problem(n=32, m=8, seed=0):
    rng = np.random.default_rng(seed)
    K1 = rng.normal(size=(n, n)).astype(np.float32)
    K1 = (K1 @ K1.T / n + np.eye(n)).astype(np.float32)
    K2 = rng.normal(size=(m, m)).astype(np.float32)
    K2 = (K2 @ K2.T / m + np.eye(m)).astype(np.float32)
    mask = (rng.random((n, m)) < 0.8).astype(np.float32)
    mask[:, 0] = 1.0
    Y = (rng.normal(size=(n, m)) * mask).astype(np.float32)
    return (jnp.asarray(K1), jnp.asarray(K2), jnp.asarray(mask),
            jnp.asarray(Y))


def _iter_eqns(jaxpr):
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for sub in _sub_jaxprs(v):
                yield from _iter_eqns(sub)


def _sub_jaxprs(value):
    import jax.core as jcore
    closed = getattr(jcore, "ClosedJaxpr", ())
    raw = getattr(jcore, "Jaxpr", ())
    if isinstance(value, (closed, raw)):
        yield value
    elif isinstance(value, (list, tuple)):
        for v in value:
            yield from _sub_jaxprs(v)


def _pallas_calls_inside_shard_map(jaxpr) -> int:
    count = 0
    for eqn in _iter_eqns(jaxpr):
        if eqn.primitive.name != "shard_map":
            continue
        for v in eqn.params.values():
            for sub in _sub_jaxprs(v):
                count += sum(1 for e in _iter_eqns(sub)
                             if e.primitive.name == "pallas_call")
    return count


def test_fused_distributed_mvm_matches_reference():
    """f32 grams take the fused path ('auto') and the operator matches the
    einsum reference, for rank-2 and stacked inputs."""
    K1, K2, mask, Y = _f32_problem()
    eng = DistributedEngine()
    A = eng.operator_from_grams(K1, K2, mask, 0.1)
    assert getattr(A, "fused", False)

    ref = lk_mvm(K1, K2, mask, Y, noise=0.1)
    out = A(Y)
    assert out.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)

    U = jnp.stack([Y, 2.0 * Y, Y * mask])
    ref_b = lk_mvm(K1, K2, mask, U, noise=0.1)
    np.testing.assert_allclose(np.asarray(A(U)), np.asarray(ref_b),
                               atol=1e-4, rtol=1e-4)


def test_fused_kernel_is_traced_per_shard():
    """The acceptance claim: the traced program must contain a pallas_call
    INSIDE the shard_map equation — each shard runs the fused kernel on
    its row block, not a global kernel outside the mesh."""
    K1, K2, mask, Y = _f32_problem()
    A = DistributedEngine(fused=True).operator_from_grams(K1, K2, mask, 0.1)
    jaxpr = jax.make_jaxpr(A)(Y)
    assert _pallas_calls_inside_shard_map(jaxpr) >= 1
    # and the reference (unfused) body has none
    A_ref = DistributedEngine(fused=False).operator_from_grams(
        K1, K2, mask, 0.1)
    assert _pallas_calls_inside_shard_map(jax.make_jaxpr(A_ref)(Y)) == 0


def test_f64_grams_auto_gate_to_reference_body():
    """f32-accumulating Pallas is wrong for x64 parity paths: 'auto' must
    fall back to the exact einsum body on f64 grams, and fused=True must
    refuse them loudly."""
    K1, K2, mask, Y = _f32_problem()
    K1d, K2d, md, Yd = (x.astype(jnp.float64) for x in (K1, K2, mask, Y))
    eng = DistributedEngine()
    A = eng.operator_from_grams(K1d, K2d, md, 0.1)
    assert not getattr(A, "fused", True)
    out = A(Yd)
    assert out.dtype == jnp.float64
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(lk_mvm(K1d, K2d, md, Yd, noise=0.1)),
        atol=1e-10)

    with pytest.raises(ValueError, match="f32"):
        DistributedEngine(fused=True).operator_from_grams(K1d, K2d, md, 0.1)


def test_fused_false_disables_kernel():
    K1, K2, mask, Y = _f32_problem()
    A = DistributedEngine(fused=False).operator_from_grams(K1, K2, mask, 0.1)
    assert not getattr(A, "fused", True)
    np.testing.assert_allclose(
        np.asarray(A(Y)), np.asarray(lk_mvm(K1, K2, mask, Y, noise=0.1)),
        atol=1e-5, rtol=1e-5)


def test_distributed_fused_solve_matches_iterative():
    """End-to-end: a CG solve driven against the fused distributed operator
    matches the plain iterative engine's solution in f32."""
    K1, K2, mask, Y = _f32_problem()
    cfg = LKGPConfig(cg_tol=1e-5, cg_max_iters=2000)
    x_ref = IterativeEngine().solve(
        IterativeEngine().operator_from_grams(K1, K2, mask, 0.1), Y, cfg)
    eng = DistributedEngine(fused=True)
    A = eng.operator_from_grams(K1, K2, mask, 0.1)
    x = eng.solve(A, Y, cfg)
    assert A.last_result is not None
    assert not bool(jnp.any(A.last_result.breakdown))
    np.testing.assert_allclose(np.asarray(x), np.asarray(x_ref),
                               atol=1e-3, rtol=1e-3)
