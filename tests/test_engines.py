"""Unified inference-engine API: backend parity, lazy Posterior, state ops."""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (LKGP, GPData, LKGPConfig, Posterior, cg_solve, extend,
                        fit, fit_batch, get_engine, gram_matrices, init_params,
                        joint_grams, list_backends, lk_operator, make_mll,
                        posterior, rademacher_probes, refit, resolve_backend,
                        unstack)
from repro.core import mll_cholesky
from repro.data import sample_task


def _small_task(seed=3, n=6, m=6, d=4):
    return sample_task(seed=seed, n=n, m=m, d=d)


def _tight_cfg(**kw):
    base = dict(cg_tol=1e-8, cg_max_iters=2000, slq_probes=64, slq_iters=25,
                lbfgs_iters=0)
    base.update(kw)
    return LKGPConfig(**base)


# --------------------------------------------------------------------------
# registry / resolution
# --------------------------------------------------------------------------
def test_registry_has_all_four_backends():
    assert set(list_backends()) >= {"dense", "iterative", "pallas",
                                    "distributed"}
    with pytest.raises(ValueError, match="unknown backend"):
        get_engine("nope")
    with pytest.raises(ValueError, match="unknown backend"):
        resolve_backend(LKGPConfig(backend="nope"), 10)


def test_resolve_backend_legacy_fields():
    assert resolve_backend(LKGPConfig(), 10) == "dense"
    assert resolve_backend(LKGPConfig(), 10_000) == "iterative"
    assert resolve_backend(LKGPConfig(mll_method="cholesky"), 10_000) == "dense"
    assert resolve_backend(LKGPConfig(mll_method="iterative"), 10) == "iterative"
    assert resolve_backend(LKGPConfig(use_pallas=True), 10) == "pallas"
    assert resolve_backend(LKGPConfig(backend="distributed"), 10) == "distributed"


# --------------------------------------------------------------------------
# engine parity: posterior mean and MLL value/grad
# --------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["dense", "iterative", "pallas",
                                     "distributed"])
def test_backend_parity_posterior_mean(backend):
    """All backends agree on the posterior mean for shared fitted params."""
    task = _small_task()
    cfg = _tight_cfg()
    state = fit(task.X, task.t, task.Y, task.mask, cfg)  # dense (auto, small)
    ref = np.asarray(posterior(state, engine=get_engine("dense")).mean)
    got = np.asarray(posterior(state, engine=get_engine(backend)).mean)
    np.testing.assert_allclose(got, ref, atol=1e-3)


@pytest.mark.parametrize("backend", ["iterative", "pallas", "distributed"])
def test_backend_parity_mll_value_and_grad(backend):
    task = _small_task()
    cfg = _tight_cfg(slq_probes=256, slq_iters=30)
    X = jnp.asarray(task.X)
    t = jnp.asarray(task.t, X.dtype)
    Y = jnp.asarray(task.Y, X.dtype)
    mask = jnp.asarray(task.mask, X.dtype)
    params = init_params(X.shape[1], X.dtype)
    probes = rademacher_probes(jax.random.PRNGKey(0), cfg.slq_probes, mask,
                               X.dtype)

    mll = make_mll(cfg, get_engine(backend))
    v, g = jax.value_and_grad(
        lambda p: mll(p, X, t, Y, mask, probes))(params)
    v_ref, g_ref = jax.value_and_grad(
        lambda p: mll_cholesky(p, X, t, Y, mask, jitter=cfg.jitter))(params)

    assert abs(float(v) - float(v_ref)) / abs(float(v_ref)) < 0.05
    for a, b in zip(jax.tree_util.tree_leaves(g),
                    jax.tree_util.tree_leaves(g_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=0.25, atol=0.25)


@pytest.mark.parametrize("backend", ["dense", "iterative", "pallas",
                                     "distributed"])
def test_backend_selectable_through_fit(backend):
    """Every backend is reachable through the one public entry point."""
    task = _small_task(n=4, m=5)
    cfg = LKGPConfig(backend=backend, lbfgs_iters=1, cg_tol=1e-6,
                     cg_max_iters=500, slq_probes=8, slq_iters=10)
    state = fit(task.X, task.t, task.Y, task.mask, cfg)
    assert state.backend_used == backend
    mean = posterior(state).mean
    assert mean.shape == task.Y.shape
    assert np.all(np.isfinite(np.asarray(mean)))


@pytest.mark.parametrize("backend", ["dense", "iterative", "pallas",
                                     "distributed"])
def test_backend_parity_nonuniform_progression_grid(backend):
    """All engines consume the state's explicit t: posterior means agree on
    a NON-UNIFORM budget grid, and the K2 Gram they build is genuinely
    non-uniform (off-diagonal decay varies across the grid). Note a purely
    log-spaced (geomspace) grid would be *uniform* after the TTransform's
    log warp — the grid here stays irregular even in log space."""
    t = np.array([1.0, 2.0, 3.0, 8.0, 30.0, 150.0, 256.0])
    task = sample_task(seed=17, n=6, d=4, t=t)
    cfg = _tight_cfg(lbfgs_iters=2)
    state = fit(task.X, task.t, task.Y, task.mask, cfg)
    np.testing.assert_array_equal(np.asarray(state.t), t)
    ref = np.asarray(posterior(state, engine=get_engine("dense")).mean)
    got = np.asarray(posterior(state, engine=get_engine(backend)).mean)
    np.testing.assert_allclose(got, ref, atol=1e-3)

    _, K2 = gram_matrices(state.params, state.data.X, state.data.t,
                          cfg.t_kernel, cfg.jitter)
    off = np.asarray(jnp.diag(K2, k=1))
    assert np.std(off) > 1e-6, "K2 looks uniform; t was not consumed"


@pytest.mark.parametrize("backend", ["iterative", "pallas", "distributed"])
def test_backend_parity_mll_nonuniform_grid(backend):
    """MLL value parity vs the exact Cholesky on a non-uniform grid.

    ``t`` goes through the fitted TTransform first — engines receive the
    transformed grid in real use (`fit` / `Posterior`), and the irregular
    raw grid stays irregular after the log warp.
    """
    from repro.core.transforms import TTransform

    t_log = np.array([1.0, 2.0, 3.0, 8.0, 30.0, 150.0, 256.0])
    task = sample_task(seed=19, n=6, d=4, t=t_log)
    cfg = _tight_cfg(slq_probes=256, slq_iters=30)
    X = jnp.asarray(task.X)
    t = jnp.asarray(task.t, X.dtype)
    t = TTransform.fit(t)(t)
    assert np.std(np.diff(np.asarray(t))) > 1e-3   # still non-uniform
    Y = jnp.asarray(task.Y, X.dtype)
    mask = jnp.asarray(task.mask, X.dtype)
    params = init_params(X.shape[1], X.dtype)
    probes = rademacher_probes(jax.random.PRNGKey(2), cfg.slq_probes, mask,
                               X.dtype)
    mll = make_mll(cfg, get_engine(backend))
    v = float(mll(params, X, t, Y, mask, probes))
    v_ref = float(mll_cholesky(params, X, t, Y, mask, jitter=cfg.jitter))
    assert abs(v - v_ref) / abs(v_ref) < 0.05


def test_dense_vs_iterative_agree_on_quickstart_task():
    """Acceptance: dense vs iterative posterior means within 1e-3."""
    task = sample_task(seed=7, n=16, m=20, d=7)
    state = fit(task.X, task.t, task.Y, task.mask, _tight_cfg(lbfgs_iters=5))
    m_dense = np.asarray(posterior(state, engine=get_engine("dense")).mean)
    m_iter = np.asarray(posterior(state, engine=get_engine("iterative")).mean)
    np.testing.assert_allclose(m_iter, m_dense, atol=1e-3)


# --------------------------------------------------------------------------
# use_pallas flag regression: the flag must change the executed path
# --------------------------------------------------------------------------
def test_use_pallas_flag_changes_executed_path(monkeypatch):
    from repro.kernels import ops as kernel_ops

    calls = {"n": 0}
    real = kernel_ops.lk_mvm_op

    def counting(*args, **kwargs):
        calls["n"] += 1
        return real(*args, **kwargs)

    monkeypatch.setattr(kernel_ops, "lk_mvm_op", counting)
    task = _small_task(n=4, m=4)
    base = dict(lbfgs_iters=1, cg_tol=1e-4, cg_max_iters=200, slq_probes=4,
                slq_iters=8)

    fit(task.X, task.t, task.Y, task.mask,
        LKGPConfig(mll_method="iterative", **base))
    assert calls["n"] == 0, "plain iterative backend must not touch Pallas"

    fit(task.X, task.t, task.Y, task.mask,
        LKGPConfig(use_pallas=True, **base))
    assert calls["n"] > 0, "use_pallas=True must route MVMs through kernels.ops"


def test_exact_engine_methods_are_honoured_by_make_mll():
    """make_mll must route exact engines through their own solve/logdet."""
    from repro.core import DenseEngine

    calls = {"solve": 0, "logdet": 0}

    class SpyDense(DenseEngine):
        name = "spy-dense"

        def solve(self, A, b, config):
            calls["solve"] += 1
            return super().solve(A, b, config)

        def logdet(self, A, data, config, probes=None):
            calls["logdet"] += 1
            return super().logdet(A, data, config, probes)

    task = _small_task(n=4, m=4)
    X = jnp.asarray(task.X)
    t = jnp.asarray(task.t, X.dtype)
    Y = jnp.asarray(task.Y, X.dtype)
    mask = jnp.asarray(task.mask, X.dtype)
    params = init_params(X.shape[1], X.dtype)
    cfg = LKGPConfig()

    mll = make_mll(cfg, SpyDense())
    v = float(mll(params, X, t, Y, mask, None))
    assert calls["solve"] == 1 and calls["logdet"] == 1
    v_ref = float(mll_cholesky(params, X, t, Y, mask, jitter=cfg.jitter))
    np.testing.assert_allclose(v, v_ref, rtol=1e-10)


def test_make_mll_iterative_threads_mvm_impl():
    """Back-compat entry point: a custom mvm_impl is used for every MVM."""
    task = _small_task(n=4, m=4)
    X = jnp.asarray(task.X)
    t = jnp.asarray(task.t, X.dtype)
    Y = jnp.asarray(task.Y, X.dtype)
    mask = jnp.asarray(task.mask, X.dtype)
    params = init_params(X.shape[1], X.dtype)
    probes = rademacher_probes(jax.random.PRNGKey(1), 8, mask, X.dtype)
    cfg = LKGPConfig(cg_tol=1e-6, cg_max_iters=500, slq_iters=10)

    calls = {"n": 0}

    def spy_mvm(K1, K2, mask, u, noise=0.0):
        calls["n"] += 1
        from repro.core import lk_mvm
        return lk_mvm(K1, K2, mask, u, noise)

    from repro.core import make_mll_iterative
    mll_spy = make_mll_iterative(cfg, mvm_impl=spy_mvm)
    mll_ref = make_mll_iterative(cfg)
    v1 = float(mll_spy(params, X, t, Y, mask, probes))
    assert calls["n"] > 0
    v2 = float(mll_ref(params, X, t, Y, mask, probes))
    np.testing.assert_allclose(v1, v2, rtol=1e-8)


def test_mll_bwd_cotangent_dtypes_match_primals():
    """Regression: the Y cotangent must track Y's dtype/shape (zeros_like)."""
    task = _small_task(n=4, m=4)
    X = jnp.asarray(task.X)
    t = jnp.asarray(task.t, X.dtype)
    Y = jnp.asarray(task.Y, jnp.float64)
    mask = jnp.asarray(task.mask, X.dtype)
    params = init_params(X.shape[1], X.dtype)
    probes = rademacher_probes(jax.random.PRNGKey(1), 4, mask, X.dtype)
    cfg = LKGPConfig(cg_tol=1e-4, cg_max_iters=200, slq_iters=8)

    from repro.core import make_mll_iterative
    mll = make_mll_iterative(cfg)
    grads = jax.grad(mll, argnums=(1, 2, 3, 4, 5))(
        params, X, t, Y, mask, probes)
    for g, primal in zip(grads, (X, t, Y, mask, probes)):
        assert g.shape == primal.shape
        assert g.dtype == primal.dtype


# --------------------------------------------------------------------------
# lazy Posterior
# --------------------------------------------------------------------------
def test_posterior_mean_matches_legacy_inline_computation():
    """Acceptance: Posterior.mean == the seed repo's inline posterior mean."""
    task = sample_task(seed=7, n=16, m=20, d=7)
    cfg = LKGPConfig(lbfgs_iters=3)
    state = fit(task.X, task.t, task.Y, task.mask, cfg)

    # Legacy inline computation (the seed implementation, verbatim).
    K1a, K2 = joint_grams(state, None)
    n = state.n
    noise = jnp.exp(state.params.raw_noise)
    A = lk_operator(K1a[:n, :n], K2, state.mask, noise)
    alpha = cg_solve(A, state.y_tf(state.Y) * state.mask, tol=cfg.cg_tol,
                     max_iters=cfg.cg_max_iters).x
    legacy = state.y_tf.inverse(
        jnp.einsum("aj,jm,mk->ak", K1a[:, :n], alpha, K2))

    # Same CG solver, same operator -> bit-identical to the seed path.
    got = posterior(state, engine=get_engine("iterative")).mean
    np.testing.assert_allclose(np.asarray(got), np.asarray(legacy),
                               rtol=1e-10, atol=1e-10)
    # The default call auto-resolves the engine (dense-exact here); it must
    # agree with the CG-based legacy value to CG tolerance.
    np.testing.assert_allclose(np.asarray(posterior(state).mean),
                               np.asarray(legacy), atol=1e-2)


def test_posterior_alpha_cached_and_shared(monkeypatch):
    """The K^{-1}y solve runs once and is reused by mean and samples."""
    task = _small_task()
    state = fit(task.X, task.t, task.Y, task.mask, _tight_cfg())
    post = posterior(state, engine=get_engine("iterative"))

    solves = {"n": 0}
    real_solve = type(post._engine).solve

    def counting_solve(self, A, b, config):
        solves["n"] += 1
        return real_solve(self, A, b, config)

    monkeypatch.setattr(type(post._engine), "solve", counting_solve)
    _ = post.mean
    assert solves["n"] == 1
    _ = post.mean                      # cached: no new solve
    assert solves["n"] == 1
    _ = post.samples(jax.random.PRNGKey(0), 4)   # one solve for (F + eps)
    assert solves["n"] == 2
    _ = post.mean                      # alpha still cached
    assert solves["n"] == 2


def test_posterior_samples_consistent_with_mean():
    """Sharing alpha keeps the sample mean consistent with the exact mean."""
    task = _small_task()
    state = fit(task.X, task.t, task.Y, task.mask, _tight_cfg())
    post = posterior(state)
    s = post.samples(jax.random.PRNGKey(2), 3000)
    emp = np.asarray(jnp.mean(s, axis=0))
    np.testing.assert_allclose(emp, np.asarray(post.mean), atol=0.12)


def test_posterior_final_matches_facade_predict_final():
    """The deprecated facade still works (and warns) while delegating to
    the functional posterior — the one deliberate LKGP call site left."""
    task = _small_task()
    cfg = LKGPConfig(lbfgs_iters=2)
    with pytest.warns(DeprecationWarning, match="LKGP is deprecated"):
        model = LKGP(cfg)
    model.fit(task.X, task.t, task.Y, task.mask)
    m1, v1 = model.predict_final(jax.random.PRNGKey(5))
    m2, v2 = posterior(model.state).final(jax.random.PRNGKey(5))
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), rtol=1e-12)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-12)


def test_posterior_new_configs_rows():
    task = _small_task(n=5, m=6)
    state = fit(task.X, task.t, task.Y, task.mask, _tight_cfg())
    Xs = np.random.default_rng(0).uniform(0, 1, (3, task.X.shape[1]))
    post = posterior(state, Xs=Xs)
    assert post.mean.shape == (5 + 3, 6)
    s = post.samples(jax.random.PRNGKey(0), 4)
    assert s.shape == (4, 8, 6)


# --------------------------------------------------------------------------
# extend / refit (incremental conditioning)
# --------------------------------------------------------------------------
def test_extend_more_epochs_warm_start():
    task = _small_task(n=6, m=8)
    state = fit(task.X, task.t, task.Y, task.mask,
                LKGPConfig(lbfgs_iters=10))
    mask2 = np.asarray(task.mask).copy()
    mask2[:, : task.Y.shape[1] // 2 + 2] = 1.0
    mask2 = np.maximum(mask2, np.asarray(task.mask))
    Y2 = task.Y_full * mask2

    st2 = extend(state, Y2, mask2)
    # params carried over unchanged (warm start)
    for a, b in zip(jax.tree_util.tree_leaves(st2.params),
                    jax.tree_util.tree_leaves(state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(np.sum(np.asarray(st2.mask))) > int(np.sum(np.asarray(state.mask)))

    st3 = refit(st2, lbfgs_iters=5)
    assert st3.fit_result.n_iters <= 5
    mean = posterior(st3).mean
    assert np.all(np.isfinite(np.asarray(mean)))


def test_extend_rejects_mask_shrink():
    task = _small_task(n=4, m=5)
    state = fit(task.X, task.t, task.Y, task.mask, LKGPConfig(lbfgs_iters=0))
    bad = np.zeros_like(np.asarray(task.mask))
    with pytest.raises(ValueError, match="superset"):
        extend(state, task.Y, bad)


def test_extend_new_configs():
    task = _small_task(n=5, m=6)
    state = fit(task.X, task.t, task.Y, task.mask, LKGPConfig(lbfgs_iters=2))
    rng = np.random.default_rng(1)
    k = 2
    new_X = rng.uniform(0, 1, (k, task.X.shape[1]))
    new_Y = rng.uniform(0.2, 0.8, (k, 6)) * 0 + 0.5
    new_mask = np.zeros((k, 6))
    new_mask[:, :2] = 1.0
    st2 = extend(state, new_Y * new_mask, new_mask, new_X=new_X)
    assert st2.n == 7 and st2.X.shape == (7, task.X.shape[1])
    mean = posterior(st2).mean
    assert mean.shape == (7, 6)
    assert np.all(np.isfinite(np.asarray(mean)))


# --------------------------------------------------------------------------
# fit_batch (vmap over independent tasks)
# --------------------------------------------------------------------------
def test_fit_batch_matches_individual_fits():
    B, n, m, d = 3, 5, 6, 4
    tasks = [_small_task(seed=10 + i, n=n, m=m, d=d) for i in range(B)]
    X = np.stack([tk.X for tk in tasks])
    Y = np.stack([tk.Y for tk in tasks])
    mask = np.stack([tk.mask for tk in tasks])
    t = tasks[0].t
    cfg = LKGPConfig(lbfgs_iters=25, mll_method="cholesky")

    batched = fit_batch(X, t, Y, mask, cfg)
    states = unstack(batched)
    assert len(states) == B

    for i, tk in enumerate(tasks):
        solo = fit(tk.X, tk.t, tk.Y, tk.mask, cfg)
        mean_b = np.asarray(posterior(states[i]).mean)
        mean_s = np.asarray(posterior(solo).mean)
        # Joint vs per-task L-BFGS trajectories differ; optima coincide.
        np.testing.assert_allclose(mean_b, mean_s, atol=0.05)


def test_fit_batch_broadcasts_t_and_stacks_transforms():
    B, n, m, d = 2, 4, 5, 4
    tasks = [_small_task(seed=20 + i, n=n, m=m, d=d) for i in range(B)]
    X = np.stack([tk.X for tk in tasks])
    Y = np.stack([tk.Y for tk in tasks])
    mask = np.stack([tk.mask for tk in tasks])
    batched = fit_batch(X, tasks[0].t, Y, mask, LKGPConfig(lbfgs_iters=2))
    assert batched.t.shape == (B, m)
    assert batched.params.raw_x_lengthscale.shape == (B, d)
    s0 = unstack(batched)[0]
    assert s0.X.shape == (n, d)


# --------------------------------------------------------------------------
# Matheron consistency (alpha-reuse path; dense vs iterative engines)
# --------------------------------------------------------------------------
def test_matheron_sample_mean_converges_to_exact_mean_alpha_reuse():
    """The empirical mean of Posterior.samples must converge to the exact
    Posterior.mean: both share the cached alpha = K^{-1}(Y*mask), so the
    Monte-Carlo error is the only gap and shrinks with the sample count."""
    task = _small_task(seed=11)
    state = fit(task.X, task.t, task.Y, task.mask, _tight_cfg())
    post = posterior(state, engine=get_engine("iterative"))
    mean = np.asarray(post.mean)

    errs = []
    for n_samples in (250, 4000):
        s = post.samples(jax.random.PRNGKey(3), n_samples)
        errs.append(float(np.max(np.abs(np.asarray(jnp.mean(s, 0)) - mean))))
    assert errs[-1] < 0.12, errs
    assert errs[-1] < errs[0], errs      # more samples -> closer to exact


def test_matheron_samples_consistent_across_dense_and_iterative():
    """With a tight CG tolerance, the same PRNG key must produce (near-)
    identical Matheron samples through the dense and iterative engines —
    on the observed cells in particular, where the conditioning acts."""
    task = _small_task(seed=13)
    state = fit(task.X, task.t, task.Y, task.mask, _tight_cfg(cg_tol=1e-10))
    key = jax.random.PRNGKey(7)
    s_dense = np.asarray(
        posterior(state, engine=get_engine("dense")).samples(key, 16))
    s_iter = np.asarray(
        posterior(state, engine=get_engine("iterative")).samples(key, 16))

    obs = np.asarray(task.mask) > 0
    np.testing.assert_allclose(s_dense[:, obs], s_iter[:, obs],
                               rtol=1e-6, atol=1e-6)
    # full grid (incl. extrapolated cells) agrees to solver tolerance too
    np.testing.assert_allclose(s_dense, s_iter, atol=1e-5)
