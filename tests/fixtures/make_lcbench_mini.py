"""Regenerate tests/fixtures/lcbench_mini.npz (committed CI fixture).

A small LCBench-format artifact sampled from the synthetic prior so CI
stays hermetic while exercising the full real-dataset code path:

* three tasks (two ``crossing``, one mixed regime) of a few dozen configs,
* a NON-UNIFORM, log-spaced budget grid (geomspace 1..200, 12 fidelities)
  so every consumer — K2 Gram construction across all backends, the
  transformer's progression encoding, scheduler replay — runs off the
  uniform ``1..m`` epoch assumption,
* early-stop masks from the prior's random cutoffs, full ground-truth
  curves stored (``Y_full``), plus one deliberately *censored* task
  (``Y_full`` withheld) covering the no-ground-truth loader fallback.

    PYTHONPATH=src python tests/fixtures/make_lcbench_mini.py
"""
import os

import numpy as np

from repro.data import CurveTask, sample_task, write_artifact

OUT = os.path.join(os.path.dirname(__file__), "lcbench_mini.npz")


def main(path: str = OUT) -> str:
    t = np.geomspace(1.0, 200.0, 12)
    tasks = [
        sample_task(9001, n=24, d=7, t=t, noise=0.01, spike_prob=0.02,
                    diverge_prob=0.0, crossing=True),
        sample_task(9002, n=24, d=7, t=t, noise=0.02, spike_prob=0.04,
                    diverge_prob=0.05, crossing=True),
        sample_task(9003, n=20, d=7, t=t, noise=0.01, spike_prob=0.03,
                    diverge_prob=0.03, crossing=False),
    ]
    # Censor the last task: real logs often have nothing past the
    # early-stop cutoff. Y_full collapses to the masked observations.
    c = tasks[-1]
    tasks[-1] = CurveTask(X=c.X, t=c.t, Y=c.Y, mask=c.mask,
                          Y_full=c.Y.copy())
    write_artifact(path, tasks,
                   names=["mini-crossing-a", "mini-crossing-b",
                          "mini-mixed-censored"],
                   metric="val_accuracy", maximize=True,
                   extra_meta={"generator": "tests/fixtures/"
                                            "make_lcbench_mini.py"})
    return path


if __name__ == "__main__":
    print(main())
