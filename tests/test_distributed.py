"""Distributed substrate tests on a multi-device host mesh (subprocess).

The XLA host-device-count flag must be set before jax initialises, and the
main pytest process must keep seeing 1 device (per the assignment), so every
test here runs its payload in a fresh subprocess with the flag set.
"""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_payload(code: str, devices: int = 8, timeout: int = 520):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-4000:]}"
    return r.stdout


def test_distributed_lkgp_mvm_matches_single_device():
    out = run_payload("""
        import jax, jax.numpy as jnp, numpy as np
        jax.config.update("jax_enable_x64", True)
        from repro.core import gram_matrices, init_params, lk_operator, cg_solve
        from repro.distributed.lkgp_dist import dist_lk_operator, dist_cg_solve
        from repro.launch.mesh import make_debug_mesh
        from jax.sharding import NamedSharding, PartitionSpec as P

        mesh = make_debug_mesh(data=4, model=2)
        n, m, d = 32, 12, 5
        key = jax.random.PRNGKey(0)
        X = jax.random.uniform(key, (n, d), jnp.float64)
        t = jnp.linspace(0, 1, m, dtype=jnp.float64)
        params = init_params(d, jnp.float64)
        K1, K2 = gram_matrices(params, X, t)
        lens = jax.random.randint(jax.random.PRNGKey(1), (n,), 1, m + 1)
        mask = (jnp.arange(m)[None] < lens[:, None]).astype(jnp.float64)
        Y = jax.random.normal(jax.random.PRNGKey(2), (n, m), jnp.float64) * mask

        noise = 0.05
        with mesh:
            sh = NamedSharding(mesh, P("data", None))
            K1s = jax.device_put(K1, sh)
            Ys = jax.device_put(Y, sh)
            ms = jax.device_put(mask, sh)
            A = dist_lk_operator(mesh, K1s, K2, ms, noise)
            out = jax.jit(A)(Ys)
            x_dist, iters, rel = jax.jit(
                lambda b: dist_cg_solve(A, b, tol=1e-8, max_iters=500))(Ys)

        A_ref = lk_operator(K1, K2, mask, noise)
        np.testing.assert_allclose(np.asarray(out), np.asarray(A_ref(Y)),
                                   rtol=1e-9, atol=1e-9)
        x_ref = cg_solve(A_ref, Y, tol=1e-8, max_iters=500).x
        np.testing.assert_allclose(np.asarray(x_dist), np.asarray(x_ref),
                                   rtol=1e-5, atol=1e-7)
        print("DIST-LKGP-OK", int(iters))
    """)
    assert "DIST-LKGP-OK" in out


def test_distributed_backend_via_top_level_api():
    """backend="distributed" is reachable through fit()/posterior() and
    agrees with the iterative backend on a multi-device mesh."""
    out = run_payload("""
        import jax, jax.numpy as jnp, numpy as np
        jax.config.update("jax_enable_x64", True)
        from jax.sharding import Mesh
        from repro.core import (LKGPConfig, DistributedEngine, fit, get_engine,
                                posterior)
        from repro.data import sample_task

        task = sample_task(seed=5, n=32, m=10, d=5)
        base = dict(lbfgs_iters=2, cg_tol=1e-8, cg_max_iters=1000,
                    slq_probes=8, slq_iters=15, seed=0)

        # default engine: 1-axis mesh over all 8 host devices
        cfg = LKGPConfig(backend="distributed", **base)
        st_d = fit(task.X, task.t, task.Y, task.mask, cfg)
        assert st_d.backend_used == "distributed"
        m_dist = np.asarray(posterior(st_d).mean)

        cfg_i = LKGPConfig(backend="iterative", **base)
        st_i = fit(task.X, task.t, task.Y, task.mask, cfg_i)
        m_iter = np.asarray(posterior(st_i).mean)
        np.testing.assert_allclose(m_dist, m_iter, rtol=1e-6, atol=1e-8)

        # explicit mesh injection
        mesh = Mesh(np.array(jax.devices()[:4]), ("data",))
        eng = DistributedEngine(mesh=mesh)
        st_m = fit(task.X, task.t, task.Y, task.mask, cfg, engine=eng)
        m_mesh = np.asarray(posterior(st_m, engine=eng).mean)
        np.testing.assert_allclose(m_mesh, m_iter, rtol=1e-6, atol=1e-8)
        print("DIST-API-OK")
    """)
    assert "DIST-API-OK" in out


def test_gradient_compression_error_feedback():
    out = run_payload("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_debug_mesh
        from repro.train.compression import make_compressed_allreduce

        mesh = make_debug_mesh(data=2, model=2, pod=2)
        tree = {"a": jnp.linspace(-1, 1, 64).reshape(8, 8),
                "b": jnp.array([1e-3, 5.0, -2.0])}
        err0 = jax.tree_util.tree_map(lambda a: jnp.zeros_like(a), tree)
        ar = make_compressed_allreduce(mesh)
        with mesh:
            g1, e1 = jax.jit(ar)(tree, err0)
        # identical inputs on both pods -> mean == input (to int8 precision)
        for k in tree:
            np.testing.assert_allclose(np.asarray(g1[k]), np.asarray(tree[k]),
                                       atol=float(jnp.max(jnp.abs(tree[k]))) / 100)
        # error feedback: residual carried, bounded by one quantisation step
        for k in tree:
            scale = float(jnp.max(jnp.abs(tree[k]))) / 127
            assert float(jnp.max(jnp.abs(e1[k]))) <= scale + 1e-6
        # over many steps the averaged estimate converges to the true mean
        acc = jax.tree_util.tree_map(lambda a: jnp.zeros_like(a), tree)
        err = err0
        steps = 20
        with mesh:
            for _ in range(steps):
                g, err = jax.jit(ar)(tree, err)
                acc = jax.tree_util.tree_map(lambda s, x: s + x, acc, g)
        for k in tree:
            np.testing.assert_allclose(np.asarray(acc[k]) / steps,
                                       np.asarray(tree[k]),
                                       atol=2e-3 * max(1.0, float(jnp.max(jnp.abs(tree[k])))))
        print("COMPRESS-OK")
    """)
    assert "COMPRESS-OK" in out


def test_pipeline_parallel_matches_sequential():
    out = run_payload("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_debug_mesh
        from repro.train.pipeline import pipelined_forward

        mesh = make_debug_mesh(data=2, model=2, pod=2)  # 2 pipeline stages
        S, L_per, D = 2, 3, 16
        key = jax.random.PRNGKey(0)
        Ws = jax.random.normal(key, (S, L_per, D, D), jnp.float32) * 0.1

        def stage_fn(sp, x):  # sp["w"]: (L_per, D, D)
            def body(h, w):
                return jnp.tanh(h @ w), None
            h, _ = jax.lax.scan(body, x, sp["w"])
            return h

        x = jax.random.normal(jax.random.PRNGKey(1), (8, D), jnp.float32)
        pipe = pipelined_forward(mesh, stage_fn, num_microbatches=4)
        with mesh:
            y_pipe = jax.jit(pipe)({"w": Ws}, x) if False else pipe({"w": Ws}, x)

        # sequential reference
        h = x
        for s in range(S):
            h = stage_fn({"w": Ws[s]}, h)
        np.testing.assert_allclose(np.asarray(y_pipe), np.asarray(h),
                                   rtol=1e-5, atol=1e-5)
        print("PIPE-OK")
    """)
    assert "PIPE-OK" in out


def test_checkpoint_restart_and_elastic_restore():
    out = run_payload("""
        import os, subprocess, sys, tempfile, numpy as np
        d = tempfile.mkdtemp()
        base = [sys.executable, "-m", "repro.launch.train", "--arch",
                "stablelm_12b", "--smoke", "--steps", "8", "--batch", "4",
                "--seq", "16", "--ckpt-dir", d, "--ckpt-every", "2",
                "--log-every", "100"]
        env = dict(os.environ)
        # run 1: preempted at step 4
        r = subprocess.run(base + ["--simulate-preempt", "4"],
                           capture_output=True, text=True, env=env)
        assert r.returncode == 42, r.stderr[-2000:]
        assert "SIMULATED PREEMPTION" in r.stdout
        # run 2: resumes from step 4 on a DIFFERENT device count (elastic)
        env2 = dict(env)
        env2["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        r2 = subprocess.run(base, capture_output=True, text=True, env=env2)
        assert r2.returncode == 0, r2.stderr[-2000:]
        assert "restored checkpoint at step 4" in r2.stdout, r2.stdout
        assert "final loss" in r2.stdout
        print("CKPT-OK")
    """, devices=8)
    assert "CKPT-OK" in out


def test_train_loss_decreases_on_mesh():
    out = run_payload("""
        import jax, numpy as np
        from repro.launch.train import main
        losses = main(["--arch", "rwkv6_1b6", "--smoke", "--steps", "30",
                       "--batch", "8", "--seq", "32", "--lr", "5e-3",
                       "--log-every", "10"])
        assert losses[-1] < losses[0] - 0.2, (losses[0], losses[-1])
        print("TRAIN-DECREASE-OK")
    """)
    assert "TRAIN-DECREASE-OK" in out


def test_moe_sharded_matches_einsum_path():
    out = run_payload("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.models import build_model
        from repro.models.moe import moe_ffn, moe_ffn_sharded
        from repro.launch.mesh import make_debug_mesh

        mesh = make_debug_mesh(data=2, model=2)
        cfg = get_smoke_config("qwen3_moe_235b")  # 8 experts, top-4
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        lp = jax.tree_util.tree_map(lambda a: a[0], params["layers"]["moe"])
        B, S = 4, 8
        x = jax.random.normal(jax.random.PRNGKey(3), (B, S, cfg.d_model),
                              jnp.float32)
        # einsum reference with groups == data shards
        ref = moe_ffn(x, lp, cfg, num_groups=2)
        with mesh:
            out = jax.jit(lambda x, p: moe_ffn_sharded(x, p, cfg, mesh))(x, lp)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)
        # gradients flow through the shard_map path
        g = jax.grad(lambda x: jnp.sum(
            moe_ffn_sharded(x, lp, cfg, mesh) ** 2))(x)
        assert bool(jnp.all(jnp.isfinite(g)))
        print("MOE-SHARDED-OK")
    """)
    assert "MOE-SHARDED-OK" in out
