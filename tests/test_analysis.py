"""Tests for the repro.analysis subsystem (PR 6).

Covers: every AST lint rule against must-trigger / must-not-trigger
fixtures, suppression + baseline mechanics, the generalized banned-import
guard over the real src/ tree (migrated from the PR-5 one-off no-scipy
test), the Pallas VMEM budget model (including a block configuration the
autotuner's raw {64, 128, 256} sweep could previously have selected), the
jaxpr auditors (f64-free, callback-free, retrace-free refits), and the
posterior PRNG stream-separation regression.
"""
import os

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

from repro.analysis import (analyze_file, analyze_paths, analyze_source,
                            filter_baseline, load_baseline, write_baseline)
from repro.analysis.rules import RULES_BY_ID
from repro.analysis.vmem import (VMEM_BUDGET_BYTES, VmemBudgetError,
                                 audit_candidate_space, best_fitting_blocks,
                                 check_fused_blocks, fused_vmem_breakdown)

HERE = os.path.dirname(__file__)
FIXTURES = os.path.join(HERE, "analysis_fixtures")
SRC = os.path.join(HERE, os.pardir, "src")

ALL_RULE_IDS = ("RA101", "RA102", "RA103", "RA104", "RA105", "RA106")


# --------------------------------------------------------------------------
# AST rules against fixtures
# --------------------------------------------------------------------------
@pytest.mark.parametrize("rule_id", ALL_RULE_IDS)
def test_rule_triggers_on_fixture(rule_id):
    path = os.path.join(FIXTURES, f"{rule_id.lower()}_trigger.py")
    findings = analyze_file(path)
    assert findings, f"{rule_id} trigger fixture produced no findings"
    assert {f.rule for f in findings} == {rule_id}, findings


@pytest.mark.parametrize("rule_id", ALL_RULE_IDS)
def test_rule_silent_on_clean_fixture(rule_id):
    path = os.path.join(FIXTURES, f"{rule_id.lower()}_clean.py")
    findings = analyze_file(path)
    assert findings == [], [f.format() for f in findings]


def test_every_rule_has_fixture_coverage():
    for rule_id in RULES_BY_ID:
        for kind in ("trigger", "clean"):
            path = os.path.join(FIXTURES, f"{rule_id.lower()}_{kind}.py")
            assert os.path.exists(path), f"missing fixture {path}"


def test_finding_fields_and_severities():
    findings = analyze_file(os.path.join(FIXTURES, "ra101_trigger.py"))
    f = findings[0]
    assert f.rule == "RA101" and f.severity == "error"
    assert f.line > 0 and f.fingerprint and "PRNGKey" in f.message
    findings = analyze_file(os.path.join(FIXTURES, "ra103_trigger.py"))
    assert all(f.severity == "warning" for f in findings)


# --------------------------------------------------------------------------
# suppression syntax
# --------------------------------------------------------------------------
def test_line_suppression():
    src = ("import scipy\n"
           "import scipy.stats  # lint: disable=RA106\n")
    findings = analyze_source(src, "x.py")
    assert [f.line for f in findings] == [1]


def test_line_suppression_all_keyword():
    src = "import torch  # lint: disable=all\n"
    assert analyze_source(src, "x.py") == []


def test_file_level_suppression():
    src = ("# lint: disable-file=RA106\n"
           "import scipy\n"
           "import torch\n"
           "def f(x=[]):\n"
           "    return x\n")
    findings = analyze_source(src, "x.py")
    # RA106 silenced file-wide; RA105 still fires
    assert [f.rule for f in findings] == ["RA105"]


def test_syntax_error_reported_not_raised():
    findings = analyze_source("def broken(:\n", "x.py")
    assert len(findings) == 1 and findings[0].rule == "RA000"


# --------------------------------------------------------------------------
# baseline mechanics
# --------------------------------------------------------------------------
def test_baseline_roundtrip_and_fingerprint_stability(tmp_path):
    src = "import scipy\n"
    findings = analyze_source(src, "pkg/mod.py")
    assert len(findings) == 1
    bl_path = str(tmp_path / "baseline.json")
    write_baseline(findings, bl_path)
    baseline = load_baseline(bl_path)
    new, n_base = filter_baseline(findings, baseline)
    assert new == [] and n_base == 1

    # Inserting lines above must NOT invalidate the baseline entry…
    shifted = analyze_source("# a comment\n\nimport scipy\n", "pkg/mod.py")
    new, n_base = filter_baseline(shifted, baseline)
    assert new == [] and n_base == 1

    # …but editing the offending line itself must surface it again.
    edited = analyze_source("import scipy.stats\n", "pkg/mod.py")
    new, _ = filter_baseline(edited, baseline)
    assert len(new) == 1


def test_identical_lines_get_distinct_fingerprints():
    src = ("import jax\n"
           "def f(xs, g):\n"
           "    out = []\n"
           "    for x in xs:\n"
           "        out.append(float(g(x)))\n"
           "        out.append(float(g(x)))\n"
           "    return out\n")
    findings = analyze_source(src, "x.py")
    assert len(findings) == 2
    assert findings[0].fingerprint != findings[1].fingerprint


# --------------------------------------------------------------------------
# the generalized import guard over the real tree (migrated PR-5 test)
# --------------------------------------------------------------------------
def test_src_tree_has_no_banned_imports():
    """No scipy/torch anywhere under src/repro (single source of truth).

    Replaces the PR-5 one-off AST check that covered only
    repro.autotune.predictor and only scipy.
    """
    rule = (RULES_BY_ID["RA106"],)
    findings = analyze_paths([os.path.join(SRC, "repro")], rules=rule)
    assert findings == [], [f.format() for f in findings]


def test_src_tree_is_lint_clean():
    """`python -m repro.analysis src/` must exit 0 with an empty baseline."""
    findings = analyze_paths([os.path.join(SRC, "repro")])
    assert findings == [], [f.format() for f in findings]


# --------------------------------------------------------------------------
# VMEM budget checker
# --------------------------------------------------------------------------
def test_vmem_small_blocks_fit():
    bd = fused_vmem_breakdown(128, 128, 64, 64)
    assert bd.fits() and bd.total < VMEM_BUDGET_BYTES // 4
    check_fused_blocks(128, 128, 64, 64)   # must not raise


def test_vmem_rejects_block_the_old_sweep_could_pick():
    """(256, 256) at (n=512, m=8192) was selectable pre-PR6 and overflows.

    The old heuristic picked the largest candidate for any axis >= 256,
    and the timed sweep would happily time it in interpret mode; the row
    strips alone exceed the 16 MiB budget.
    """
    bd = fused_vmem_breakdown(512, 8192, 256, 256)
    assert not bd.fits()
    assert bd.u_strip + bd.mask_strip + bd.k2_strip > VMEM_BUDGET_BYTES
    with pytest.raises(VmemBudgetError, match="VMEM"):
        check_fused_blocks(512, 8192, 256, 256)


def test_vmem_guard_fires_at_kernel_trace_time():
    import jax.numpy as jnp

    from repro.kernels.lk_mvm import lk_mvm_fused

    with pytest.raises(VmemBudgetError):
        jax.eval_shape(
            lambda: lk_mvm_fused(
                jnp.zeros((512, 512), jnp.float32),
                jnp.zeros((8192, 8192), jnp.float32),
                jnp.zeros((512, 8192), jnp.float32),
                jnp.zeros((1, 512, 8192), jnp.float32),
                0.1, block_n=256, block_m=256, interpret=True))


def test_autotuner_candidates_all_fit_or_none():
    """The filtered chooser never returns an oversized pair; the raw
    sweep provably contains oversized ones it must exclude."""
    oversized = audit_candidate_space()
    assert oversized, "expected oversized combos in the raw sweep"
    buckets = [2 ** k for k in range(3, 14)]
    for n in buckets:
        for m in buckets:
            pair = best_fitting_blocks(n, m)
            if pair is not None:
                assert fused_vmem_breakdown(n, m, *pair).fits(), (n, m, pair)


def test_autotune_blocks_vmem_filtered():
    from repro.kernels.autotune import autotune_blocks, clear_cache

    clear_cache()
    try:
        blocks = autotune_blocks(512, 8192, timed=False)
        assert blocks is None      # nothing fits: two-stage fallback
        blocks = autotune_blocks(512, 512, timed=False)
        assert blocks is not None
        assert fused_vmem_breakdown(512, 512, *blocks).fits()
    finally:
        clear_cache()


def test_lk_mvm_op_falls_back_to_two_stage():
    """lk_mvm_op on an unfittable shape must route to the two-stage
    kernel rather than raise (checked via trace only — no execution)."""
    import jax.numpy as jnp

    from repro.kernels.autotune import clear_cache
    from repro.kernels.ops import lk_mvm_op

    clear_cache()
    try:
        out = jax.eval_shape(
            lambda: lk_mvm_op(
                jnp.zeros((64, 64), jnp.float32),
                jnp.zeros((8192, 8192), jnp.float32),
                jnp.zeros((64, 8192), jnp.float32),
                jnp.zeros((64, 8192), jnp.float32),
                0.1, force_pallas=True))
        assert out.shape == (64, 8192)
    finally:
        clear_cache()


# --------------------------------------------------------------------------
# jaxpr auditors
# --------------------------------------------------------------------------
def test_jaxpr_mll_f64_and_callback_free():
    from repro.analysis.jaxpr_audit import audit_fit_objective, audit_mll

    assert audit_mll() == []
    assert audit_fit_objective() == []


def test_jaxpr_fused_mvm_clean():
    from repro.analysis.jaxpr_audit import audit_fused_mvm

    assert audit_fused_mvm() == []


def test_refit_is_retrace_free():
    """Two same-shape refit rounds reuse ONE compiled objective."""
    from repro.analysis.jaxpr_audit import audit_refit_retrace

    assert audit_refit_retrace() == []


def test_find_f64_detects_promotion():
    import jax.numpy as jnp

    from repro.analysis.jaxpr_audit import find_f64

    jaxpr = jax.make_jaxpr(lambda x: x.astype(jnp.float64))(
        np.zeros(3, np.float32))
    assert find_f64(jaxpr)


def test_find_host_callbacks_detects_callback():
    from repro.analysis.jaxpr_audit import find_host_callbacks

    def f(x):
        return jax.pure_callback(
            lambda v: np.asarray(v) * 2, jax.ShapeDtypeStruct((3,), np.float32), x)

    jaxpr = jax.make_jaxpr(f)(np.zeros(3, np.float32))
    assert find_host_callbacks(jaxpr)


# --------------------------------------------------------------------------
# posterior PRNG stream separation (the RA101 true positive, fixed)
# --------------------------------------------------------------------------
def test_posterior_default_and_explicit_final_use_distinct_streams():
    from repro.core.posterior import posterior
    from repro.core.state import LKGPConfig, fit

    rng = np.random.default_rng(0)
    n, m, d = 10, 6, 2
    X = rng.normal(size=(n, d))
    t = np.linspace(1, m, m)
    Y = rng.normal(size=(n, m))
    mask = np.ones((n, m))
    cfg = LKGPConfig(lbfgs_iters=2, posterior_samples=8, seed=3)
    state = fit(X, t, Y, mask, cfg)

    # Cached default path vs the explicit-key fallback inside final():
    post = posterior(state)
    mean_default, var_default = post.final()            # tag-1 stream
    post2 = posterior(state)
    mean_expl, var_expl = post2.final(n_samples=cfg.posterior_samples)
    # Means are exact (identical); variances come from Matheron draws
    # under different fold_in tags and must differ.
    np.testing.assert_allclose(np.asarray(mean_default),
                               np.asarray(mean_expl), rtol=1e-6)
    assert not np.allclose(np.asarray(var_default), np.asarray(var_expl)), \
        "default and explicit final() paths drew identical samples"

    # Same tag twice -> identical draws (determinism of each stream).
    post3 = posterior(state)
    _, var_expl2 = post3.final(n_samples=cfg.posterior_samples)
    np.testing.assert_allclose(np.asarray(var_expl), np.asarray(var_expl2),
                               rtol=1e-6)
