"""Unified PosteriorLike API: protocol conformance, parity, cache semantics.

Covers the posterior API surface the serving layer builds on:

* :class:`Posterior` and :class:`BatchedPosterior` both satisfy
  :class:`PosteriorLike`, with numeric parity on the exact final mean;
* the state-keyed solve cache — identity, zero extra solves on a repeat,
  explicit ``cache=`` control, config default, extend/refit invalidation;
* ``extend`` clearing stale fit metadata (``fit_result`` /
  ``backend_used``) and ``refit`` re-deriving it;
* the deprecated :class:`LKGP` facade warning;
* :func:`stack_states` validation.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import (LKGPConfig, PosteriorLike, extend, fit, posterior,
                        refit, stack_states, unstack)
from repro.core import engines as engines_mod
from repro.core.posterior import BatchedPosterior, Posterior, posterior_batch
from repro.data import sample_task

CFG = LKGPConfig(lbfgs_iters=5, backend="dense")


@pytest.fixture(scope="module")
def task():
    return sample_task(seed=0, n=6, m=8, d=4)


@pytest.fixture(scope="module")
def state(task):
    return fit(task.X, task.t, task.Y, task.mask, CFG)


@pytest.fixture(scope="module")
def batched_state(task):
    other = sample_task(seed=1, n=6, m=8, d=4)
    states = [fit(tk.X, tk.t, tk.Y, tk.mask, CFG) for tk in (task, other)]
    return stack_states(states)


def _exercise(p, n: int, m: int, batch: int | None = None):
    """Drive one object through the full PosteriorLike surface."""
    lead = () if batch is None else (batch,)
    mean = np.asarray(p.mean)
    var = np.asarray(p.variance)
    assert mean.shape == lead + (n, m) and np.all(np.isfinite(mean))
    assert var.shape == lead + (n, m) and np.all(np.isfinite(var))
    assert np.all(var > 0)
    s = np.asarray(p.samples(jax.random.PRNGKey(0), 5))
    assert s.shape == (5,) + lead + (n, m) and np.all(np.isfinite(s))
    fm, fv = p.final()
    fm, fv = np.asarray(fm), np.asarray(fv)
    assert fm.shape == lead + (n,) and fv.shape == lead + (n,)
    assert np.all(np.isfinite(fm)) and np.all(fv > 0)
    fm2, fv2 = p.final(key=jax.random.PRNGKey(1), n_samples=16)
    assert np.asarray(fm2).shape == lead + (n,)
    assert np.all(np.asarray(fv2) > 0)
    p.solve_info  # readable on every implementation (may be None)
    return mean, fm, fv


def test_both_posteriors_conform_to_protocol(state, batched_state):
    lazy = posterior(state, cache=False)
    batched = BatchedPosterior(batched_state)
    assert isinstance(lazy, PosteriorLike)
    assert isinstance(batched, PosteriorLike)
    # Protocol is structural: an arbitrary object is rejected.
    assert not isinstance(object(), PosteriorLike)


def test_parity_through_shared_interface(state, batched_state, task):
    n, m = task.Y.shape
    lazy_mean, lazy_fm, lazy_fv = _exercise(
        posterior(state, cache=False), n, m)
    b_mean, b_fm, b_fv = _exercise(
        BatchedPosterior(batched_state), n, m, batch=2)
    # Row 0 of the batched state IS `state`: exact means must agree across
    # implementations (engine solve vs vmapped dense Cholesky).
    np.testing.assert_allclose(b_mean[0], lazy_mean, rtol=1e-6, atol=1e-8)
    np.testing.assert_allclose(b_fm[0], lazy_fm, rtol=1e-6, atol=1e-8)
    # Variances: exact (batched) vs Matheron MC (lazy) — statistical
    # agreement only.
    assert np.all(b_fv[0] > 0) and np.all(lazy_fv > 0)
    ratio = lazy_fv / b_fv[0]
    assert np.all(ratio > 0.2) and np.all(ratio < 5.0)


def test_cache_returns_same_object_with_zero_extra_solves(task):
    st = fit(task.X, task.t, task.Y, task.mask, CFG)
    p1 = posterior(st)
    m1, v1 = p1.final()
    jax.block_until_ready(m1)
    count, tally = p1.solve_count, engines_mod.solve_tally()
    assert count >= 1 and p1.solve_info is p1.solve_info

    p2 = posterior(st)
    m2, v2 = p2.final()
    _ = p2.mean
    assert p2 is p1
    assert p2.solve_count == count
    assert engines_mod.solve_tally() == tally
    assert np.array_equal(np.asarray(m1), np.asarray(m2))
    assert np.array_equal(np.asarray(v1), np.asarray(v2))


def test_cache_control_flags(state, task):
    # cache=False always builds a fresh posterior.
    assert posterior(state, cache=False) is not posterior(state)
    assert posterior(state, cache=False) is not posterior(state, cache=False)
    # Explicit Xs / engine bypass the cache (not state-determined).
    Xs = np.asarray(task.X)[:2] + 0.1
    p_xs = posterior(state, Xs=Xs)
    assert p_xs is not posterior(state)
    assert np.asarray(p_xs.mean).shape[0] == task.Y.shape[0] + 2
    # ... and demanding the cache for them is an error.
    with pytest.raises(ValueError, match="cache=True"):
        posterior(state, Xs=Xs, cache=True)


def test_config_posterior_cache_default_off(task):
    cfg = dataclasses.replace(CFG, posterior_cache=False)
    st = fit(task.X, task.t, task.Y, task.mask, cfg)
    assert posterior(st) is not posterior(st)
    # Per-call opt-in still shares one posterior.
    assert posterior(st, cache=True) is posterior(st, cache=True)


def test_batched_cache_semantics(batched_state):
    bp1 = posterior_batch(batched_state)
    assert posterior_batch(batched_state) is bp1
    assert posterior_batch(batched_state, cache=False) is not bp1
    m1, v1 = bp1.final()
    m2, v2 = posterior_batch(batched_state).final()
    assert m2 is m1 and v2 is v1       # resident default final


def test_extend_invalidates_cache_and_changes_prediction(task):
    st = fit(task.X, task.t, task.Y, task.mask, CFG)
    p1 = posterior(st)
    before, _ = p1.final()
    mask2 = np.asarray(task.mask).copy()
    i = int(np.argmin(mask2.sum(axis=1)))
    k = int(mask2[i].sum())
    assert k < mask2.shape[1], "fixture task needs an unobserved cell"
    mask2[i, k] = 1.0
    Y2 = np.where(mask2 > 0, np.asarray(task.Y_full), 0.0)
    st2 = extend(st, Y2, mask2)
    assert st2 is not st
    p2 = posterior(st2)
    assert p2 is not p1
    after, _ = p2.final()
    assert not np.array_equal(np.asarray(before), np.asarray(after))
    # The old state's cache is untouched: re-reading it is still resident.
    assert posterior(st) is p1


def test_extend_clears_fit_metadata_and_refit_rederives(task):
    st = fit(task.X, task.t, task.Y, task.mask, CFG)
    assert st.fit_result is not None
    assert st.backend_used is not None
    st2 = extend(st, task.Y, task.mask)
    # Stale diagnostics from the cold fit must not masquerade as current.
    assert st2.fit_result is None
    assert st2.backend_used is None
    st3 = refit(st2, lbfgs_iters=2)
    assert st3.fit_result is not None
    assert st3.backend_used is not None


def test_lkgp_facade_is_deprecated():
    from repro.core.lkgp import LKGP
    with pytest.warns(DeprecationWarning, match="LKGP is deprecated"):
        LKGP(CFG)


def test_stack_states_roundtrip_and_validation(state, task):
    stacked = stack_states([state, state])
    rows = unstack(stacked)
    assert len(rows) == 2
    np.testing.assert_array_equal(np.asarray(rows[0].Y),
                                  np.asarray(state.Y))
    with pytest.raises(ValueError):
        stack_states([])
    other = sample_task(seed=2, n=5, m=8, d=4)
    small = fit(other.X, other.t, other.Y, other.mask, CFG)
    with pytest.raises(ValueError, match="do not match"):
        stack_states([state, small])
