"""Amortized hyper-parameter inits: identity start, persistence, bitwise
fit == fit_batch polish parity, explicit-init round-trips, FitResult
budget/provenance reporting, and the LRU-bounded compiled caches."""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from repro.amortize import (Amortizer, AmortizerConfig, AmortizeTrainConfig,
                            clear_amortizer_registry, get_amortizer,
                            init_amortizer, register_amortizer,
                            train_amortizer)
from repro.core import LKGPConfig, fit, fit_batch, refit, unstack
from repro.core.caching import LRUCache
from repro.core.state import (_POLISH_BACKTRACKS, _POLISH_CACHE,
                              _flatten_params, compiled_cache_stats,
                              init_params)


def _tiny_amortizer(d=3, seed=0) -> Amortizer:
    acfg = AmortizerConfig(d=d, d_model=16, curve_layers=1, set_layers=1,
                           num_heads=2, d_ff=32, fourier_feats=2)
    return Amortizer(acfg, init_amortizer(jax.random.PRNGKey(seed), acfg))


def _tasks(seed, B=3, n=6, m=5, d=3):
    """B same-shape prefix-revealed tasks."""
    rng = np.random.default_rng(seed)
    X = rng.uniform(size=(B, n, d))
    t = np.linspace(0.05, 1.0, m)
    Y = rng.normal(size=(B, n, m))
    lens = rng.integers(2, m + 1, size=(B, n))
    mask = (np.arange(m)[None, None, :] < lens[:, :, None]).astype(float)
    return X, t, Y * mask, mask


# -- amortizer mechanics -----------------------------------------------------
def test_untrained_amortizer_predicts_default_init():
    """Zero-initialised head => the forward pass IS the prior-mean init."""
    am = _tiny_amortizer()
    X, t, Y, mask = _tasks(0, B=1)
    flat = am.init_flat(X[0], t, Y[0], mask[0])
    base = _flatten_params(init_params(3, jnp.float32))
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(base))


def test_save_load_roundtrip_bitwise(tmp_path):
    am = _tiny_amortizer(seed=3)
    path = tmp_path / "am.npz"
    am.save(path)
    am2 = Amortizer.load(path)
    assert am2.cfg == am.cfg
    la, lb = (jax.tree_util.tree_leaves(am.params),
              jax.tree_util.tree_leaves(am2.params))
    for a, b in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    X, t, Y, mask = _tasks(1, B=1)
    np.testing.assert_array_equal(
        np.asarray(am.init_flat(X[0], t, Y[0], mask[0])),
        np.asarray(am2.init_flat(X[0], t, Y[0], mask[0])))


def test_init_batch_matches_init_for_bitwise():
    """The batched entry dispatches the single-task program per task."""
    am = _tiny_amortizer(seed=5)
    X, t, Y, mask = _tasks(2, B=4)
    tb = np.broadcast_to(t, (4, t.shape[0]))
    batch = am.init_batch(X, tb, Y, mask)
    for i in range(4):
        single = am.init_for(X[i], t, Y[i], mask[i])
        for a, b in zip(jax.tree_util.tree_leaves(single),
                        jax.tree_util.tree_leaves(batch)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b)[i])


def test_registry_and_fixture():
    clear_amortizer_registry()
    am = _tiny_amortizer()
    register_amortizer(am)
    assert get_amortizer(3) is am
    with pytest.raises(ValueError, match="amortizer"):
        get_amortizer(99)   # no registration, no fixture for d=99
    clear_amortizer_registry()
    # the committed d=5 fixture loads lazily
    assert get_amortizer(5).cfg.d == 5


# -- fit/fit_batch/refit integration ----------------------------------------
def test_fit_matches_fit_batch_polish_bitwise():
    """Same task + same amortized init + same budget => identical params
    whether fit individually or through the coalesced batch path."""
    am = _tiny_amortizer(seed=7)
    X, t, Y, mask = _tasks(3, B=3)
    cfg = LKGPConfig()
    stb = fit_batch(X, t, Y, mask, cfg, init="amortized", polish_steps=2,
                    amortizer=am)
    singles = [fit(X[i], t, Y[i], mask[i], cfg, init="amortized",
                   polish_steps=2, amortizer=am) for i in range(3)]
    for i, (sb, ss) in enumerate(zip(unstack(stb), singles)):
        for a, b in zip(jax.tree_util.tree_leaves(ss.params),
                        jax.tree_util.tree_leaves(sb.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f"task {i}")
    # diagnostics: budget, provenance, and the fixed eval count
    res = stb.fit_result
    assert res.optimizer == "polish" and res.init_source == "amortized"
    assert res.budget == 2 and res.n_iters == 2
    assert res.n_evals == 3 * (1 + 2 * _POLISH_BACKTRACKS)


def test_polish_program_shared_between_fit_and_fit_batch():
    am = _tiny_amortizer(seed=9)
    X, t, Y, mask = _tasks(4, B=2)
    cfg = LKGPConfig(jitter=1.1e-6)   # unique cache key for this test
    _POLISH_CACHE.clear()
    fit(X[0], t, Y[0], mask[0], cfg, init="amortized", polish_steps=2,
        amortizer=am)
    fit_batch(X, t, Y, mask, cfg, init="amortized", polish_steps=2,
              amortizer=am)
    assert len(_POLISH_CACHE) == 1
    stats = compiled_cache_stats()["polish"]
    assert stats["misses"] >= 1 and stats["hits"] >= 2


def test_oneshot_fit_is_the_amortized_init_bitwise():
    am = _tiny_amortizer(seed=11)
    X, t, Y, mask = _tasks(5, B=1)
    st = fit(X[0], t, Y[0], mask[0], LKGPConfig(), init="amortized",
             polish_steps=0, amortizer=am)
    assert st.fit_result.optimizer == "none"
    assert st.fit_result.init_source == "amortized"
    # polish improves on the one-shot init (same objective surface)
    stp = fit(X[0], t, Y[0], mask[0], LKGPConfig(), init="amortized",
              polish_steps=3, amortizer=am)
    assert stp.fit_result.fun <= st.fit_result.fun + 1e-12


def test_explicit_params_roundtrip_refit_untouched():
    """init=<params> + polish_steps=0 => params pass through refit bitwise."""
    X, t, Y, mask = _tasks(6, B=1)
    st = fit(X[0], t, Y[0], mask[0], LKGPConfig(lbfgs_iters=3))
    p = st.params
    st2 = refit(st, init=p, polish_steps=0)
    assert st2.fit_result.init_source == "params"
    assert st2.fit_result.optimizer == "none"
    for a, b in zip(jax.tree_util.tree_leaves(p),
                    jax.tree_util.tree_leaves(st2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # default warm start resolves to state.params and round-trips too
    st3 = refit(st, polish_steps=0)
    assert st3.fit_result.init_source == "params"
    for a, b in zip(jax.tree_util.tree_leaves(p),
                    jax.tree_util.tree_leaves(st3.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_hyper_init_config_drives_registry():
    """cfg.hyper_init='amortized' pulls the registered encoder; refit
    re-amortizes instead of warm-starting from state.params."""
    clear_amortizer_registry()
    register_amortizer(_tiny_amortizer(seed=13))
    try:
        X, t, Y, mask = _tasks(7, B=1)
        cfg = LKGPConfig(hyper_init="amortized", polish_steps=2)
        st = fit(X[0], t, Y[0], mask[0], cfg)
        assert st.fit_result.init_source == "amortized"
        st2 = refit(st)
        assert st2.fit_result.init_source == "amortized"
    finally:
        clear_amortizer_registry()


def test_fit_result_reports_lbfgs_budget():
    X, t, Y, mask = _tasks(8, B=1)
    st = fit(X[0], t, Y[0], mask[0], LKGPConfig(lbfgs_iters=5))
    res = st.fit_result
    assert res.optimizer == "lbfgs" and res.init_source == "default"
    assert res.budget == 5 and 1 <= res.n_iters <= 5
    assert isinstance(res.converged, (bool, np.bool_))


# -- LRU-bounded compiled caches --------------------------------------------
def test_lru_cache_counters_and_eviction():
    c = LRUCache(2)
    c["a"], c["b"] = 1, 2
    assert c.get("a") == 1          # hit; "a" becomes most-recent
    assert c.get("zz") is None      # miss
    c["c"] = 3                      # evicts "b" (least recent)
    assert "b" not in c and "a" in c and "c" in c
    s = c.stats()
    assert s["hits"] == 1 and s["misses"] == 1 and s["evictions"] == 1
    assert s["size"] == 2 and s["maxsize"] == 2


def test_compiled_cache_stats_shape():
    stats = compiled_cache_stats()
    for key in ("fit_vg", "polish"):
        for field in ("hits", "misses", "evictions", "size", "maxsize"):
            assert isinstance(stats[key][field], int)


# -- training smoke ----------------------------------------------------------
def test_train_amortizer_smoke():
    """Two tiny self-supervised steps run and keep the loss finite."""
    acfg = AmortizerConfig(d=4, d_model=16, curve_layers=1, set_layers=1,
                           num_heads=2, d_ff=32, fourier_feats=2)
    tcfg = AmortizeTrainConfig(steps=2, tasks_per_step=2, n=4, m=5,
                               log_every=1)
    am, info = train_amortizer(acfg, tcfg, out=lambda *_: None)
    assert isinstance(am, Amortizer)
    assert np.isfinite(info["first_loss"]) and np.isfinite(info["final_loss"])
    X, t, Y, mask = _tasks(9, B=1, n=4, m=5, d=4)
    flat = am.init_flat(X[0], t, Y[0], mask[0])
    assert np.isfinite(np.asarray(flat)).all()
