"""Transformer curve-prediction baseline: model, pretrain, eval harness."""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from repro.baselines import (CurveTransformerConfig, PretrainConfig,
                             build_curve_model, curve_loss, cutoff_masks,
                             eval_transformer, forward, gaussian_nll,
                             head_to_head, normalize_t, pretrain,
                             sample_stream_batch, score_predictions)
from repro.core import LKGPConfig
from repro.data import sample_suite, sample_task

TINY = CurveTransformerConfig(d_model=16, num_layers=1, num_heads=2, d_ff=32)


def _params(cfg=TINY, seed=0):
    return build_curve_model(cfg).init(jax.random.PRNGKey(seed))


def _arrays(n=5, m=8, d=7, seed=0):
    task = sample_task(seed, n=n, m=m, d=d)
    return (jnp.asarray(task.X), jnp.asarray(task.Y),
            jnp.asarray(task.mask), normalize_t(jnp.asarray(task.t)), task)


def test_forward_shapes_and_finiteness():
    X, Y, mask, t_norm, _ = _arrays()
    mu, sigma = forward(_params(), X, Y, mask, t_norm, TINY)
    assert mu.shape == Y.shape and sigma.shape == Y.shape
    assert np.all(np.isfinite(np.asarray(mu)))
    assert np.all(np.asarray(sigma) > TINY.min_sigma * 0.99)


def test_predictions_ignore_masked_out_values():
    """The explicit missing-value mask must gate the inputs: values at
    unobserved cells cannot influence any prediction."""
    X, Y, mask, t_norm, _ = _arrays(seed=1)
    params = _params()
    mu1, sig1 = forward(params, X, Y, mask, t_norm, TINY)
    Y_garbage = jnp.where(mask > 0, Y, 1e6)   # rewrite hidden cells only
    mu2, sig2 = forward(params, X, Y_garbage, mask, t_norm, TINY)
    np.testing.assert_array_equal(np.asarray(mu1), np.asarray(mu2))
    np.testing.assert_array_equal(np.asarray(sig1), np.asarray(sig2))


def test_gaussian_nll_is_correct():
    mu, sigma, y = jnp.asarray(0.3), jnp.asarray(0.5), jnp.asarray(0.8)
    got = float(gaussian_nll(mu, sigma, y))
    ref = -float(jax.scipy.stats.norm.logpdf(y, mu, sigma))
    np.testing.assert_allclose(got, ref, rtol=1e-6)


def test_curve_loss_weights_observed_vs_continuation():
    X, Y, mask, t_norm, task = _arrays(seed=2)
    batch = {"hp": X, "y": Y, "mask": mask, "t_norm": t_norm,
             "target": jnp.asarray(task.Y_full)}
    loss = float(curve_loss(_params(), batch, TINY))
    assert np.isfinite(loss)
    grads = jax.grad(lambda p: curve_loss(p, batch, TINY))(_params())
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(l))) for l in leaves)


def test_stream_batch_curriculum_anneals_prefix_floor():
    cfg = PretrainConfig(steps=100, tasks_per_step=2, n=6, m=8)
    early = sample_stream_batch(cfg, 0)
    late = sample_stream_batch(cfg, 99)
    assert early["y"].shape == (12, 8) and early["hp"].shape == (12, 7)
    # early curriculum shows longer observed prefixes on average
    assert early["mask"].mean() > late["mask"].mean()


def test_pretrain_reduces_nll():
    cfg = PretrainConfig(steps=40, tasks_per_step=2, n=6, m=8, log_every=0)
    params, info = pretrain(TINY, cfg, out=lambda *a, **k: None)
    assert info["final_loss"] < info["first_loss"], info
    leaves = jax.tree_util.tree_leaves(params)
    assert all(np.all(np.isfinite(np.asarray(l, np.float32))) for l in leaves)


def test_cutoff_masks_identical_and_anchored():
    task = sample_task(5, n=8, m=10)
    masks = cutoff_masks(task, (0.2, 0.5), seed=3)
    again = cutoff_masks(task, (0.2, 0.5), seed=3)
    for f in (0.2, 0.5):
        np.testing.assert_array_equal(masks[f], again[f])  # deterministic
        lens = masks[f].sum(axis=1)
        assert lens.max() == 10                 # one fully-observed anchor
        assert (lens == max(1, round(f * 10))).sum() >= 7


def test_score_predictions_oracle():
    """A perfect oracle scores ~zero MAE and perfect rank correlation."""
    task = sample_task(7, n=10, m=9)
    mask = cutoff_masks(task, (0.3,), seed=0)[0.3]
    s = score_predictions(task.Y_full, np.full_like(task.Y_full, 1e-4),
                          task, mask)
    assert s["mae"] < 1e-12
    assert s["rank_corr"] > 0.999
    worse = score_predictions(task.Y_full * 0 + task.Y_full.mean(),
                              np.full_like(task.Y_full, 1e-4), task, mask)
    assert worse["mae"] > s["mae"]
    assert worse["nll"] > s["nll"]


def test_head_to_head_rows_structure():
    params = _params()
    tasks = sample_suite(31, 1, n=6, m=8, d=7)
    rows = head_to_head(params, TINY, tasks, cutoffs=(0.25, 0.5),
                        gp_cfg=LKGPConfig(lbfgs_iters=2), seed=0)
    assert len(rows) == 2 * 2                  # 2 cutoffs x 2 models
    models = {r["model"] for r in rows}
    assert models == {"lkgp", "transformer"}
    for r in rows:
        for k in ("nll", "mae", "rank_corr", "fit_s", "predict_s"):
            assert np.isfinite(r[k]), r
    # amortized model: no per-task fit cost
    assert all(r["fit_s"] == 0.0 for r in rows if r["model"] == "transformer")


def test_eval_transformer_uses_only_masked_inputs():
    """The harness must not leak hidden cells into the transformer input."""
    params = _params()
    task = sample_task(41, n=6, m=8)
    mask = cutoff_masks(task, (0.4,), seed=1)[0.4]
    p1 = eval_transformer(params, TINY, task, mask)
    leaked = task._replace(Y_full=np.where(mask > 0, task.Y_full, -7.0))
    p2 = eval_transformer(params, TINY, leaked, mask)
    np.testing.assert_array_equal(p1["mean"], p2["mean"])
