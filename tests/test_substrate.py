"""Substrate units: chunked attention, optimizers, checkpoints, data,
autotune scheduler."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # container has no hypothesis wheel; see tests/_hypcompat.py
    from _hypcompat import given, settings, st

from repro.models.layers import (_chunked_attention, _plain_attention,
                                 chunked_ce_loss)
from repro.train.optimizers import (OptConfig, apply_update, cosine_lr,
                                    init_opt_state)


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------
@pytest.mark.parametrize("window", [None, 256])
@pytest.mark.parametrize("gqa", [1, 2])
def test_chunked_attention_matches_plain(window, gqa):
    B, S, Hkv, Dh = 2, 2048, 2, 32
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, S, Hkv * gqa, Dh), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, Hkv, Dh), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, Hkv, Dh), jnp.float32)
    out_c = _chunked_attention(q, k, v, True, window, 256, 512)
    out_p = _plain_attention(q, k, v, True, window, 0)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_p),
                               atol=2e-5)


def test_chunked_attention_grads_finite():
    B, S, H, Dh = 1, 1024, 2, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, Dh), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, H, Dh), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, H, Dh), jnp.float32)
    g = jax.grad(lambda q: jnp.sum(
        _chunked_attention(q, k, v, True, None, 256, 256) ** 2))(q)
    assert bool(jnp.all(jnp.isfinite(g)))


def test_chunked_ce_loss_matches_dense():
    B, S, D, V = 2, 64, 16, 97
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (B, S, D), jnp.float32)
    emb = jax.random.normal(jax.random.PRNGKey(4), (V, D), jnp.float32)
    labels = jax.random.randint(jax.random.PRNGKey(5), (B, S), 0, V)
    loss_c = chunked_ce_loss(x, emb, labels, chunk=16)
    logits = x @ emb.T
    lse = jax.nn.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    loss_d = jnp.mean(lse - gold)
    np.testing.assert_allclose(float(loss_c), float(loss_d), rtol=1e-5)


# --------------------------------------------------------------------------
# optimizers
# --------------------------------------------------------------------------
def _quad_problem():
    params = {"w": jnp.array([3.0, -2.0, 1.5]), "b": jnp.array(5.0)}

    def loss(p):
        return jnp.sum(p["w"] ** 2) + p["b"] ** 2

    return params, loss


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizer_converges_on_quadratic(name):
    params, loss = _quad_problem()
    cfg = OptConfig(name=name, peak_lr=0.3, warmup_steps=1, decay_steps=200,
                    weight_decay=0.0, clip_norm=100.0)
    state = init_opt_state(params, cfg)
    step = jnp.zeros((), jnp.int32)
    for _ in range(150):
        grads = jax.grad(loss)(params)
        params, state, _ = apply_update(params, grads, state, step, cfg)
        step = step + 1
    assert float(loss(params)) < 0.05, float(loss(params))


def test_adafactor_factored_state_is_small():
    p = {"w": jnp.zeros((256, 512))}
    cfg = OptConfig(name="adafactor")
    st_ = init_opt_state(p, cfg)
    n_state = sum(x.size for x in jax.tree_util.tree_leaves(st_))
    assert n_state == 256 + 512  # vr + vc, not 256*512


def test_cosine_schedule_shape():
    cfg = OptConfig(peak_lr=1.0, warmup_steps=10, decay_steps=100,
                    min_lr_ratio=0.1)
    lrs = [float(cosine_lr(cfg, jnp.asarray(s))) for s in range(0, 101, 10)]
    assert lrs[0] == 0.0 and abs(lrs[1] - 1.0) < 1e-6
    assert lrs[-1] == pytest.approx(0.1, rel=1e-3)
    assert all(a >= b - 1e-9 for a, b in zip(lrs[1:], lrs[2:]))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_property_clip_by_global_norm(seed):
    from repro.train.optimizers import clip_by_global_norm, global_norm

    key = jax.random.PRNGKey(seed)
    tree = {"a": jax.random.normal(key, (7, 3)) * 10,
            "b": jax.random.normal(jax.random.PRNGKey(seed + 1), (5,))}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(global_norm(clipped)) <= 1.0 + 1e-5
    # direction preserved
    ratio = np.asarray(clipped["a"]) / np.asarray(tree["a"])
    np.testing.assert_allclose(ratio, ratio.flat[0], rtol=1e-5)


# --------------------------------------------------------------------------
# checkpoint manager
# --------------------------------------------------------------------------
def test_checkpoint_roundtrip_keep_k():
    from repro.checkpoint import CheckpointManager

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2, async_save=False)
        state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
                 "step": jnp.int32(7)}
        for s in (1, 2, 3):
            mgr.save(s, state)
        assert mgr.all_steps() == [2, 3]  # keep-2 GC
        restored = mgr.restore(state)
        np.testing.assert_allclose(np.asarray(restored["params"]["w"]),
                                   np.asarray(state["params"]["w"]))
        assert int(restored["step"]) == 7


def test_checkpoint_atomicity_tmpdirs_cleaned():
    from repro.checkpoint import CheckpointManager

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=3, async_save=True)
        mgr.save(1, {"x": jnp.ones(4)})
        mgr.wait()
        names = os.listdir(d)
        assert names == ["step_0000000001"], names


# --------------------------------------------------------------------------
# data
# --------------------------------------------------------------------------
def test_token_pipeline_deterministic_and_sharded():
    from repro.data import TokenPipeline

    pipe = TokenPipeline(vocab_size=101, batch=8, seq_len=16, seed=3)
    t1, l1 = pipe.batch_at(5)
    t2, l2 = pipe.batch_at(5)
    np.testing.assert_array_equal(t1, t2)  # restart-deterministic
    assert l1.shape == (8, 16) and t1.max() < 101
    s0, _ = pipe.batch_at(5, shard=0, num_shards=2)
    s1, _ = pipe.batch_at(5, shard=1, num_shards=2)
    assert s0.shape == (4, 16)
    assert not np.array_equal(s0, s1)


def test_curve_task_properties():
    from repro.data import sample_task

    task = sample_task(0, n=16, m=20)
    assert task.Y_full.shape == (16, 20)
    assert np.all((task.Y_full >= 0) & (task.Y_full <= 1))
    assert np.all(task.Y[task.mask == 0] == 0)
    # masks are early-stopping prefixes
    for i in range(16):
        obs = np.where(task.mask[i] > 0)[0]
        assert len(obs) >= 1 and np.array_equal(obs, np.arange(len(obs)))


# --------------------------------------------------------------------------
# autotune
# --------------------------------------------------------------------------
def test_freeze_thaw_scheduler_stops_bad_runs():
    jax.config.update("jax_enable_x64", True)
    from repro.autotune import AutotuneConfig, FreezeThawScheduler
    from repro.core import LKGPConfig

    rng = np.random.default_rng(0)
    n, m = 8, 12
    X = rng.uniform(0, 1, (n, 3))
    finals = 0.3 + 0.6 * X[:, 0]  # config 1-d quality

    def make_step(i):
        state = {"e": 0}

        def step():
            state["e"] += 1
            t = state["e"] / m
            return float(finals[i] * (1 - np.exp(-4 * t))
                         + rng.normal(0, 0.004))

        return step

    sched = FreezeThawScheduler(
        X, [make_step(i) for i in range(n)],
        AutotuneConfig(max_epochs=m, refit_every=2, min_epochs_before_stop=4,
                       ucb_beta=1.5, gp=LKGPConfig(lbfgs_iters=20)))
    summary = sched.run()
    best = int(np.argmax(finals))
    assert best in summary["survivors"]
    assert summary["epochs_spent"] < n * m  # budget actually saved
    assert any(ev["stopped"] for ev in summary["stop_events"])


def test_freeze_thaw_scheduler_minimize_reports_raw_units():
    """maximize=False: summary must report the raw (un-negated) metric."""
    jax.config.update("jax_enable_x64", True)
    from repro.autotune import AutotuneConfig, FreezeThawScheduler
    from repro.core import LKGPConfig

    rng = np.random.default_rng(1)
    n, m = 6, 8
    X = rng.uniform(0, 1, (n, 3))
    finals = 0.2 + 0.6 * X[:, 0]  # losses: smaller is better

    def make_step(i):
        state = {"e": 0}

        def step():
            state["e"] += 1
            t = state["e"] / m
            return float(finals[i] + (1 - finals[i]) * np.exp(-4 * t)
                         + rng.normal(0, 0.003))

        return step

    sched = FreezeThawScheduler(
        X, [make_step(i) for i in range(n)],
        AutotuneConfig(max_epochs=m, refit_every=2, min_epochs_before_stop=4,
                       ucb_beta=2.0, maximize=False,
                       gp=LKGPConfig(lbfgs_iters=10)))
    summary = sched.run()
    # observed_best is the smallest observed loss, in raw units
    obs = sched.Y[sched.mask > 0]
    assert summary["observed_best"] == float(np.min(obs))
    # predicted finals come back in raw loss units (positive, near `finals`)
    pred = np.asarray(summary["predicted_final"])
    assert np.all(pred > 0), pred
    surviving_best = int(np.argmin(finals))
    assert surviving_best in summary["survivors"]


# --------------------------------------------------------------------------
# chunked-parallel RWKV6 wkv (§Perf hillclimb for the ssm arch)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("decay_scale", [0.5, 8.0])  # mild and strong decay
def test_wkv_chunked_matches_sequential(decay_scale):
    from repro.models.rwkv import _wkv_chunked, _wkv_scan

    B, S, H, N = 2, 64, 2, 8
    D = H * N
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (B, S, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, D), jnp.float32)
    # w in (0,1) with data-dependent strong decays (the hard case)
    w = jnp.exp(-jnp.exp(
        decay_scale * jax.random.normal(ks[3], (B, S, D), jnp.float32) - 2))
    u = jax.random.normal(ks[4], (D,), jnp.float32) * 0.3
    state0 = jax.random.normal(jax.random.PRNGKey(9), (B, H, N, N),
                               jnp.float32)

    y_seq, s_seq = _wkv_scan(r, k, v, w, u, H, N, state0)
    y_chk, s_chk = _wkv_chunked(r, k, v, w, u, H, N, chunk=16, state0=state0)
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_seq),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_chk), np.asarray(s_seq),
                               rtol=2e-4, atol=2e-4)


def test_wkv_chunked_grads_finite():
    from repro.models.rwkv import _wkv_chunked

    B, S, H, N = 1, 32, 2, 8
    D = H * N
    key = jax.random.PRNGKey(1)
    r = jax.random.normal(key, (B, S, D), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(2), (B, S, D), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(3), (B, S, D), jnp.float32)
    w = jax.nn.sigmoid(jax.random.normal(jax.random.PRNGKey(4), (B, S, D)))
    u = jnp.zeros((D,), jnp.float32)

    def f(r):
        y, _ = _wkv_chunked(r, k, v, w, u, H, N, chunk=8)
        return jnp.sum(y ** 2)

    g = jax.grad(f)(r)
    assert bool(jnp.all(jnp.isfinite(g)))
