"""Must NOT trigger RA106: allowed deps and relative imports only."""
import jax.numpy as jnp
import numpy as np

from . import ra105_clean


def norm(x):
    return float(np.linalg.norm(np.asarray(jnp.asarray(x)))), ra105_clean
