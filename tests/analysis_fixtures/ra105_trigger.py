"""Must trigger RA105: mutable default arguments."""


def collect(item, acc=[]):
    acc.append(item)
    return acc


def configure(overrides={}):
    return dict(base=1, **overrides)
