"""Must trigger RA103: host syncs inside Python loops in a jax module."""
import jax
import numpy as np


def solver_driver(step, x0, iters):
    x = x0
    history = []
    for _ in range(iters):
        x = step(x)
        history.append(float(x.mean()))     # sync per iteration
        arr = np.asarray(x)                 # sync per iteration
        jax.block_until_ready(x)            # sync per iteration
    return history, arr
