"""Must trigger RA106: banned scipy / torch imports (module + function)."""
import scipy.linalg
import torch


def fallback(x):
    from scipy.stats import spearmanr

    return spearmanr(x, x), scipy.linalg.norm(x), torch.tensor(x)
