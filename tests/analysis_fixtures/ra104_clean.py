"""Must NOT trigger RA104: explicit dtypes and meaningful scalar ops."""
import jax.numpy as jnp


def no_promote(x):
    a = x * 2.0                       # meaningful scalar: fine
    b = x + 1.5                       # meaningful scalar: fine
    c = x.astype(jnp.float32)         # explicit dtype: fine
    d = jnp.zeros(3, dtype=x.dtype)   # inherited dtype: fine
    return a, b, c, d
