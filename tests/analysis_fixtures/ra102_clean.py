"""Must NOT trigger RA102: branches on static args / None checks only."""
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("mode", "block"))
def dispatch(x, mode, block=64):
    if mode == "double":     # static argument: fine
        return x * 2.0
    if block > 128:          # static argument: fine
        return x + 1.0
    return x


@jax.jit
def with_default(x, y=None):
    if y is None:            # None-check on an optional arg: fine
        return x
    if not isinstance(x, jnp.ndarray):   # isinstance guard: fine
        x = jnp.asarray(x)
    return x + y
