"""Must NOT trigger RA103: syncs outside loops, on-device loops, non-jax."""
import jax
import jax.numpy as jnp
import numpy as np


def solve(step, x0, iters):
    def body(_, x):
        return step(x)

    x = jax.lax.fori_loop(0, iters, body, x0)
    return float(jnp.mean(x))      # one sync, outside any loop


def host_only(values):
    # float() on a suppressed line inside a loop is also fine:
    total = 0.0
    for v in values:
        total += float(np.abs(v))  # lint: disable=RA103
    return total
