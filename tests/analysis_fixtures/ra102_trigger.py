"""Must trigger RA102: Python control flow on a traced argument."""
import functools

import jax
import jax.numpy as jnp


@jax.jit
def relu_bad(x):
    if x > 0:          # traced value in Python `if`
        return x
    return jnp.zeros_like(x)


@functools.partial(jax.jit, static_argnames=("iters",))
def loop_bad(x, iters):
    while x < 1.0:     # traced value in Python `while`
        x = x * 2.0
    return x
