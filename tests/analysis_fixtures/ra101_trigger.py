"""Must trigger RA101: same seed expression builds two identical keys."""
import jax


def sample_a(cfg):
    return jax.random.normal(jax.random.PRNGKey(cfg.seed + 1), (3,))


def sample_b(cfg):
    # identical key to sample_a -> shared randomness
    return jax.random.uniform(jax.random.PRNGKey(cfg.seed + 1), (3,))
