"""Must NOT trigger RA101: distinct streams via fold_in / distinct seeds."""
import jax


def sample_a(cfg):
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), 1)
    return jax.random.normal(key, (3,))


def sample_b(cfg):
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), 2)
    return jax.random.uniform(key, (3,))


def sample_c(cfg):
    return jax.random.normal(jax.random.PRNGKey(cfg.seed + 999), (3,))
