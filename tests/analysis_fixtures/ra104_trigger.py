"""Must trigger RA104: implicit promotion via identity scalar ops."""
import jax.numpy as jnp


def promote(x):
    a = x * 1.0            # identity multiply: promotes under x64
    b = x + 0.0            # identity add
    c = x.astype(float)    # Python float -> platform default dtype
    d = jnp.zeros(3, dtype=float)   # dtype=float is platform-dependent
    return a, b, c, d
