"""Must NOT trigger RA105: immutable defaults / None sentinels."""


def collect(item, acc=None):
    acc = [] if acc is None else acc
    acc.append(item)
    return acc


def configure(overrides=(), name="default", count=0):
    return dict(base=1, name=name, count=count, **dict(overrides))
