"""Chaos suite: guarded solves, input quarantine, checkpoint/restore.

The reliability contract under test (ISSUE 9):

* a degraded solve (breakdown flags, non-finite residuals) escalates
  deterministically — jitter retries -> solver switch -> dense fallback —
  under ``solve_policy``, and the executed ladder is visible on
  ``solve_info``/``trace``;
* invalid payloads are rejected at the streaming boundary with typed
  errors naming the offending cells, and ``PredictionService`` quarantines
  them — zero unhandled exceptions, healthy tenants bitwise-unaffected;
* checkpoint/restore rebuilds warm sessions after a simulated crash.
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from _hypcompat import given, settings, st  # noqa: E402
from repro.core import (GuardedSolveError, LKGPConfig,  # noqa: E402
                        ObservationError, extend, fit, get_engine,
                        gram_matrices, guarded_solve, guarded_solve_stacked,
                        init_params)
from repro.core.solvers import get_solver  # noqa: E402
from repro.core.solvers.guarded import _jitter_ladder  # noqa: E402
from repro.data import sample_task  # noqa: E402
from repro.serving import (PredictionService, ServiceConfig,  # noqa: E402
                           SessionKey)
from repro.testing import (FaultSchedule, NegatedOperator,  # noqa: E402
                           arm_flaky_solver, crash_and_restore,
                           evict_session, near_singular_problem, poison_nan)

GP = LKGPConfig(lbfgs_iters=5, backend="dense")


def _lk_problem(n=12, m=10, d=3, seed=0, noise=0.05):
    key = jax.random.PRNGKey(seed)
    kx, ky, kl = jax.random.split(key, 3)
    X = jax.random.uniform(kx, (n, d), jnp.float64)
    t = jnp.linspace(0.05, 1.0, m).astype(jnp.float64)
    K1, K2 = gram_matrices(init_params(d, jnp.float64), X, t)
    lens = jax.random.randint(kl, (n,), m // 2, m + 1)
    mask = (jnp.arange(m)[None, :] < lens[:, None]).astype(jnp.float64)
    Y = jax.random.normal(ky, (n, m), jnp.float64) * mask
    return K1, K2, mask, Y, jnp.float64(noise)


def _operator(K1, K2, mask, noise):
    return get_engine("iterative").operator_from_grams(K1, K2, mask, noise)


# --------------------------------------------------------------------------
# guarded solves: the escalation ladder
# --------------------------------------------------------------------------
def test_healthy_solve_is_bitwise_unchanged_by_the_guard():
    """The guard must be a pure observer on healthy solves: same bits as
    the raw solver, plus a one-step trace."""
    K1, K2, mask, Y, noise = _lk_problem()
    A = _operator(K1, K2, mask, noise)
    cfg = LKGPConfig()
    raw = get_solver("cg").solve(A, Y, cfg)
    res = guarded_solve(A, Y, cfg, solver=get_solver("cg"))
    np.testing.assert_array_equal(np.asarray(raw.x), np.asarray(res.x))
    assert len(res.trace) == 1
    assert res.trace[0].stage == "attempt" and res.trace[0].ok


def test_escalation_reaches_dense_fallback_on_broken_operator():
    """A negated (indefinite) operator defeats every iterative rung; the
    dense fallback solves the INTENDED system from the Kronecker factors."""
    K1, K2, mask, Y, noise = _lk_problem()
    A = NegatedOperator(_operator(K1, K2, mask, noise))
    res = guarded_solve(A, Y, LKGPConfig())
    stages = [s.stage for s in res.trace]
    assert stages[0] == "attempt" and not res.trace[0].ok
    assert "retry_jitter" in stages and stages[-1] == "dense_fallback"
    assert res.trace[-1].ok
    assert not bool(np.any(np.asarray(res.breakdown)))
    assert float(np.max(np.asarray(res.rel_residual))) < 1e-8


def test_strict_policy_raises_without_escalating():
    K1, K2, mask, Y, noise = _lk_problem()
    A = NegatedOperator(_operator(K1, K2, mask, noise))
    with pytest.raises(GuardedSolveError) as exc_info:
        guarded_solve(A, Y, LKGPConfig(solve_policy="strict"))
    assert len(exc_info.value.trace) == 1   # no escalation attempts


def test_escalate_raises_when_ladder_exhausted():
    """A broken bare closure (no Kronecker factors -> no dense fallback)
    exhausts the ladder; escalate raises with the full trace attached."""
    K1, K2, mask, Y, noise = _lk_problem()
    A = _operator(K1, K2, mask, noise)
    broken = lambda u: -A(u)   # noqa: E731 — plain closure, no attributes
    with pytest.raises(GuardedSolveError) as exc_info:
        guarded_solve(broken, Y, LKGPConfig(guard_retries=1))
    stages = [s.stage for s in exc_info.value.trace]
    assert "dense_fallback" not in stages
    assert stages.count("retry_jitter") == 1


def test_best_effort_never_raises_and_keeps_diagnostics():
    K1, K2, mask, Y, noise = _lk_problem()
    A = _operator(K1, K2, mask, noise)
    broken = lambda u: -A(u)   # noqa: E731
    res = guarded_solve(broken, Y,
                        LKGPConfig(solve_policy="best_effort",
                                   guard_retries=1))
    assert res.trace and not res.trace[-1].ok
    assert bool(np.any(np.asarray(res.breakdown)))   # flags intact


def test_near_singular_system_ends_healthy():
    """Near-singular factors (duplicated configs, ~zero noise): whatever
    rung the ladder ends on must report a healthy, finite solution."""
    K1, K2, mask, Y, noise = near_singular_problem()
    A = _operator(K1, K2, mask, noise)
    res = guarded_solve(A, Y, LKGPConfig())
    assert res.trace[-1].ok
    assert bool(np.all(np.isfinite(np.asarray(res.x))))
    assert not bool(np.any(np.asarray(res.breakdown)))


def test_flaky_solver_escalates_at_one_extra_attempt():
    """The armed flaky solver fails instantly once; escalation recovers on
    the first jitter retry (which delegates to CG) — the cheap-escalation
    scenario the latency benchmark measures."""
    K1, K2, mask, Y, noise = _lk_problem()
    A = _operator(K1, K2, mask, noise)
    cfg = LKGPConfig(solver="flaky")
    arm_flaky_solver(1)
    res = guarded_solve(A, Y, cfg)
    assert [s.stage for s in res.trace] == ["attempt", "retry_jitter"]
    assert res.trace[-1].ok


def test_jitter_ladder_is_deterministic_and_capped():
    cfg = LKGPConfig(jitter=1e-6, guard_retries=6, guard_jitter_max=1e-2)
    ladder = _jitter_ladder(cfg)
    np.testing.assert_allclose(ladder, [1e-5, 1e-4, 1e-3, 1e-2], rtol=1e-9)
    assert _jitter_ladder(LKGPConfig(guard_retries=0)) == []
    assert len(_jitter_ladder(LKGPConfig(guard_retries=2))) == 2


def test_engine_exposes_escalation_trace_and_counts_attempts():
    from repro.core import engines as engines_mod

    K1, K2, mask, Y, noise = _lk_problem()
    A = NegatedOperator(_operator(K1, K2, mask, noise))
    eng = get_engine("iterative")
    before = engines_mod.solve_tally()
    res = eng.solve_result(A, Y, LKGPConfig())
    assert A.last_result is res
    assert res.trace is not None and len(res.trace) > 1
    # one tally entry for the solve + one per extra ladder attempt
    assert engines_mod.solve_tally() - before == len(res.trace)
    assert engines_mod.escalation_tally()["dense_fallback"] >= 1


# --------------------------------------------------------------------------
# satellite: stacked solves report WHICH RHS systems degraded
# --------------------------------------------------------------------------
def test_stacked_solve_reports_degraded_columns():
    """An operator broken for system 0 of the stack only: the stacked
    result's ``breakdown``/``col_iters`` (delegated straight off
    StackedSolveResult) name the degraded system, healthy ones converge."""
    K1, K2, mask, Y, noise = _lk_problem()
    A = _operator(K1, K2, mask, noise)

    def partly_broken(u):   # negate system 0 of the stack, keep the rest
        out = A(u)
        return out.at[0].set(-out[0])

    rhs = jnp.stack([Y, Y, Y])
    cfg = LKGPConfig(solve_policy="best_effort", guard_retries=0)
    st_res = guarded_solve_stacked(partly_broken, rhs, cfg)
    breakdown = np.asarray(st_res.breakdown)
    assert breakdown.shape == (3,)
    assert bool(breakdown[0]) and not breakdown[1:].any()
    col_iters = np.asarray(st_res.col_iters)
    assert (col_iters[1:] > 0).all()
    assert st_res.trace is not None    # ladder ran and was recorded


def test_stacked_solve_healthy_keeps_logdet_and_diagnostics():
    K1, K2, mask, Y, noise = _lk_problem()
    A = _operator(K1, K2, mask, noise)
    rhs = jnp.stack([Y, Y])
    st_res = guarded_solve_stacked(A, rhs, LKGPConfig(), probe_cols=1,
                                   subspace_dim=int(mask.sum()),
                                   solver=get_solver("cg"))
    assert st_res.logdet is not None
    assert not bool(np.any(np.asarray(st_res.breakdown)))
    assert st_res.trace[0].stage == "attempt" and st_res.trace[0].ok


# --------------------------------------------------------------------------
# property: the escalation ladder is deterministic
# --------------------------------------------------------------------------
@settings(max_examples=6)
@given(policy=st.sampled_from(["escalate", "best_effort"]),
       retries=st.integers(0, 3), seed=st.integers(0, 4))
def test_escalation_is_deterministic(policy, retries, seed):
    """Same faulty operator + same policy => identical escalation trace and
    bitwise-identical final solution across independent runs."""
    K1, K2, mask, Y, noise = _lk_problem(seed=seed)
    cfg = LKGPConfig(solve_policy=policy, guard_retries=retries)

    def run():
        A = NegatedOperator(_operator(K1, K2, mask, noise))
        return guarded_solve(A, Y, cfg)

    r1, r2 = run(), run()
    assert r1.trace == r2.trace
    np.testing.assert_array_equal(np.asarray(r1.x), np.asarray(r2.x))
    np.testing.assert_array_equal(np.asarray(r1.rel_residual),
                                  np.asarray(r2.rel_residual))


# --------------------------------------------------------------------------
# satellite: typed input guards at the streaming boundary
# --------------------------------------------------------------------------
def _fitted_state(n=6, m=8, d=4, seed=0):
    task = sample_task(seed=seed, n=n, m=m, d=d)
    return fit(task.X, task.t, task.Y, task.mask, GP)


def test_extend_rejects_out_of_grid_mask_columns():
    state = _fitted_state()
    n, m = state.n, state.m
    wide_mask = np.zeros((n, m + 2))
    wide_mask[:, :m] = np.asarray(state.mask)
    wide_mask[0, m + 1] = 1.0                      # outside the budget grid
    with pytest.raises(ObservationError) as exc_info:
        extend(state, np.zeros((n, m + 2)), wide_mask)
    assert exc_info.value.indices == (m + 1,)      # names the offending col
    assert str(m + 1) in str(exc_info.value)


def test_extend_rejects_nonfinite_observed_cells():
    state = _fitted_state()
    Y, mask = poison_nan(state.Y, state.mask, cells=2)
    with pytest.raises(ObservationError) as exc_info:
        extend(state, Y, mask)
    assert len(exc_info.value.indices) == 2


def test_extend_allows_nonfinite_at_unobserved_cells():
    """NaN under the mask is legal — the boundary zeroes unobserved cells,
    so they never reach a ``Y*mask`` reduction (where IEEE NaN*0 = NaN
    would otherwise poison the transforms)."""
    state = _fitted_state()
    Y = np.array(state.Y)
    mask = np.asarray(state.mask)
    unobs = np.argwhere(mask == 0)
    Y[tuple(unobs[0])] = np.nan
    out = extend(state, Y, mask)
    assert bool(np.all(np.isfinite(np.asarray(out.Y))))
    assert bool(np.isfinite(np.asarray(out.y_tf.scale)))


def test_fit_rejects_nan_and_shape_mismatch():
    task = sample_task(seed=0, n=6, m=8, d=4)
    Y = np.array(task.Y)
    mask = np.array(task.mask)
    mask[0, 0] = 1.0
    Y[0, 0] = np.inf
    with pytest.raises(ObservationError):
        fit(task.X, task.t, Y, mask, GP)
    with pytest.raises(ObservationError):
        fit(task.X, task.t, np.asarray(task.Y)[:, :-1], task.mask, GP)


# --------------------------------------------------------------------------
# service chaos: quarantine, eviction, crash/restore
# --------------------------------------------------------------------------
def _grow(Y, mask, value=0.5):
    """One more observed epoch per row (a healthy extend payload)."""
    Y, mask = np.array(Y), np.array(mask)
    for row in range(mask.shape[0]):
        k = int(mask[row].sum())
        if k < mask.shape[1]:
            mask[row, k] = 1.0
            Y[row, k] = value
    return Y, mask


def test_service_chaos_schedule_no_unhandled_exceptions(tmp_path):
    """The standard injected-fault schedule: NaN payload, mid-workload
    eviction, crash/restore from a checkpoint. Zero unhandled exceptions;
    every healthy tenant's predictions bitwise-match a fault-free control
    service that saw the identical healthy traffic."""
    tasks = [sample_task(seed=i, n=6, m=8, d=4) for i in range(4)]
    make_cfg = lambda d: ServiceConfig(       # noqa: E731
        gp=GP, refit_every=0, checkpoint_dir=str(d), checkpoint_every=0)

    control = PredictionService(make_cfg(tmp_path / "control"))
    chaos = PredictionService(make_cfg(tmp_path / "chaos"))
    for svc in (control, chaos):
        for i, task in enumerate(tasks):
            out = svc.observe(f"tenant{i}", "job", Y=task.Y, mask=task.mask,
                              X=task.X, t=task.t)
            assert out["action"] == "fit"

    schedule = FaultSchedule()
    schedule.add(0, lambda service: service.observe(
        "tenant0", "job", *poison_nan(tasks[0].Y, tasks[0].mask)))
    schedule.add(1, lambda service: evict_session(service, "tenant3", "job"))
    schedule.add(2, lambda service: service.checkpoint())

    grids = {i: (tasks[i].Y, tasks[i].mask) for i in (1, 2)}
    for rnd in range(3):
        # healthy tenants stream one more epoch on BOTH services...
        for i in (1, 2):
            grids[i] = _grow(*grids[i], value=0.1 * (rnd + 1))
            for svc in (control, chaos):
                out = svc.observe(f"tenant{i}", "job",
                                  Y=grids[i][0], mask=grids[i][1])
                assert out["action"] == "extend"
        # ...then this round's fault fires on the chaos service only
        results = schedule.fire(rnd, service=chaos)
        if rnd == 0:
            assert results[0]["action"] == "quarantined"

    # crash after the last round; restore from the round-2 checkpoint
    chaos, restored = crash_and_restore(chaos)
    assert restored == 3        # tenant3 was evicted before the snapshot
    with pytest.raises(KeyError):
        chaos.predict("tenant3", "job")

    for i in (1, 2):
        want = control.predict(f"tenant{i}", "job")
        got = chaos.predict(f"tenant{i}", "job")
        np.testing.assert_array_equal(want.mean, got.mean)
        np.testing.assert_array_equal(want.var, got.var)
        assert want.generation == got.generation
    # the quarantined tenant still serves from its last good (cold) state
    assert chaos.predict("tenant0", "job").generation == 0
    assert chaos.metrics()["counters"]["restores"] == 1


def test_service_quarantines_guarded_solve_error(monkeypatch):
    """An exhausted escalation ladder inside the observe path (refit) is
    quarantined like any bad payload: no exception escapes, the session
    keeps serving its last good state."""
    import repro.serving.service as service_mod

    svc = PredictionService(ServiceConfig(gp=GP, refit_every=1))
    task = sample_task(seed=0, n=6, m=8, d=4)
    svc.observe("t", "job", Y=task.Y, mask=task.mask, X=task.X, t=task.t)
    before = svc.predict("t", "job")

    def exploding_refit(state, **kwargs):
        raise GuardedSolveError("ladder exhausted (injected)")

    monkeypatch.setattr(service_mod, "refit", exploding_refit)
    Y, mask = _grow(task.Y, task.mask)
    out = svc.observe("t", "job", Y=Y, mask=mask)
    assert out["action"] == "quarantined"
    after = svc.predict("t", "job")
    np.testing.assert_array_equal(before.mean, after.mean)
    assert svc.metrics()["events"]["counts"]["quarantine"] == 1


def test_service_cold_fit_quarantines_bad_payload():
    svc = PredictionService(ServiceConfig(gp=GP))
    task = sample_task(seed=0, n=6, m=8, d=4)
    Y = np.array(task.Y)
    mask = np.array(task.mask)
    mask[0, 0] = 1.0
    Y[0, 0] = np.nan
    out = svc.observe("t", "job", Y=Y, mask=mask, X=task.X, t=task.t)
    assert out["action"] == "quarantined" and out["generation"] == -1
    assert SessionKey("t", "job") not in svc.store
    # the same tenant can onboard with a clean payload afterwards
    out = svc.observe("t", "job", Y=task.Y, mask=task.mask,
                      X=task.X, t=task.t)
    assert out["action"] == "fit"


def test_checkpoint_restore_preserves_session_bookkeeping(tmp_path):
    svc = PredictionService(ServiceConfig(
        gp=GP, refit_every=2, checkpoint_dir=str(tmp_path)))
    task = sample_task(seed=0, n=6, m=8, d=4)
    svc.observe("t", "job", Y=task.Y, mask=task.mask, X=task.X, t=task.t)
    Y, mask = _grow(task.Y, task.mask)
    svc.observe("t", "job", Y=Y, mask=mask)
    Y, mask = _grow(Y, mask, value=0.7)
    svc.observe("t", "job", Y=Y, mask=mask)      # 2nd extend -> warm refit
    svc.checkpoint()
    seq_before = svc.obs_log.next_seq

    svc2, restored = crash_and_restore(svc)
    assert restored == 1
    session = svc2.store.get(SessionKey("t", "job"))
    assert session.observes == 2
    assert session.generation == 2
    assert svc2.obs_log.next_seq == seq_before
    # the restored session accepts further observes and keeps counting
    Y, mask = _grow(Y, mask, value=0.9)
    out = svc2.observe("t", "job", Y=Y, mask=mask)
    assert out["action"] in ("extend", "extend+refit")
    assert svc2.obs_log.next_seq == seq_before + 1


def test_periodic_checkpointing_fires_from_observe(tmp_path):
    svc = PredictionService(ServiceConfig(
        gp=GP, refit_every=0, checkpoint_dir=str(tmp_path),
        checkpoint_every=2))
    task = sample_task(seed=0, n=6, m=8, d=4)
    svc.observe("t", "job", Y=task.Y, mask=task.mask, X=task.X, t=task.t)
    Y, mask = _grow(task.Y, task.mask)
    svc.observe("t", "job", Y=Y, mask=mask)      # 2nd observe -> snapshot
    assert svc.counters["checkpoints"].value == 1
    assert svc.checkpointer.latest_step() is not None


def test_restore_without_checkpoint_dir_is_a_typed_error():
    svc = PredictionService(ServiceConfig(gp=GP))
    with pytest.raises(RuntimeError, match="checkpoint_dir"):
        svc.restore()


# --------------------------------------------------------------------------
# auditors + metrics surface
# --------------------------------------------------------------------------
def test_guarded_solves_jaxpr_audit_is_clean():
    from repro.analysis.jaxpr_audit import audit_guarded_solves

    assert audit_guarded_solves() == []


def test_event_log_counts_survive_window_rolloff():
    from repro.serving import EventLog

    log = EventLog(window=4)
    for i in range(10):
        log.record("tick", i=i)
    snap = log.snapshot()
    assert snap["counts"]["tick"] == 10
    assert len(snap["recent"]) == 4
    assert log.count("tick") == 10
