"""Serving subsystem: sessions, coalescing, cache invalidation, async.

Exercises the guarantees the service is built on:

* concurrent tenants stream observations and predictions without
  cross-talk (per-session locks, one store lock);
* coalesced ``predict_many`` is *bitwise* identical to per-request
  ``predict`` — both run the same vmapped posterior function;
* any ``observe`` (extend / refit) swaps the session state, invalidating
  the warm posterior cache — a later prediction can never serve
  pre-extend solves;
* the LRU store evicts least-recently-used sessions past capacity;
* the Future-based async surface resolves queued requests in one flush.
"""
import threading

import numpy as np
import pytest

from repro.core import LKGPConfig
from repro.data import sample_task
from repro.serving import (CoalescingBatcher, PredictionService,
                           ServiceConfig, SessionKey, SessionStore,
                           coalesce_sessions)

GP = LKGPConfig(lbfgs_iters=5, backend="dense")


def make_service(tenants, n=6, m=8, capacity=None, refit_every=2,
                 coalesce=True):
    svc = PredictionService(ServiceConfig(
        gp=GP, capacity=capacity or max(len(tenants), 1),
        refit_every=refit_every, refit_lbfgs_iters=2, coalesce=coalesce))
    tasks = {name: sample_task(seed=i, n=n, m=m, d=4)
             for i, name in enumerate(tenants)}
    svc.observe_batch([
        dict(tenant=name, task="run", X=tk.X, t=tk.t, Y=tk.Y, mask=tk.mask)
        for name, tk in tasks.items()])
    return svc, tasks


def grow_mask(mask):
    mask = np.asarray(mask).copy()
    for i in range(mask.shape[0]):
        k = int(mask[i].sum())
        if k < mask.shape[1]:
            mask[i, k] = 1.0
    return mask


def test_cold_fit_requires_x_and_t():
    svc = PredictionService(ServiceConfig(gp=GP))
    tk = sample_task(seed=0, n=6, m=8, d=4)
    with pytest.raises(KeyError, match="first observe"):
        svc.observe("t0", "run", tk.Y, tk.mask)
    with pytest.raises(KeyError, match="observe first"):
        svc.predict("t0", "run")
    info = svc.observe("t0", "run", tk.Y, tk.mask, X=tk.X, t=tk.t)
    assert info["action"] == "fit"
    pred = svc.predict("t0", "run")
    assert pred.mean.shape == (6,) and np.all(np.isfinite(pred.mean))
    assert np.all(pred.var > 0)


def test_observe_batch_coalesces_cold_fits():
    svc, _ = make_service([f"t{i}" for i in range(4)])
    assert svc.counters["cold_fits"].value == 4
    assert svc.counters["coalesced_groups"].value == 1
    assert svc.counters["coalesced_requests"].value == 4
    assert len(svc.store) == 4


def test_coalesced_predictions_match_per_request_bitwise():
    names = [f"t{i}" for i in range(4)]
    svc, _ = make_service(names)
    singles = {name: svc.predict(name, "run") for name in names}
    coalesced = svc.predict_many([(name, "run") for name in names])
    assert coalesced[0].batch_size == 4
    for p in coalesced:
        assert np.array_equal(singles[p.tenant].mean, p.mean)
        assert np.array_equal(singles[p.tenant].var, p.var)


def test_mixed_shapes_coalesce_into_separate_groups():
    svc = PredictionService(ServiceConfig(gp=GP, capacity=8))
    small = sample_task(seed=0, n=5, m=8, d=4)
    big = sample_task(seed=1, n=6, m=8, d=4)
    svc.observe("a", "run", small.Y, small.mask, X=small.X, t=small.t)
    svc.observe("b", "run", big.Y, big.mask, X=big.X, t=big.t)
    svc.observe("c", "run", small.Y, small.mask, X=small.X, t=small.t)
    preds = svc.predict_many([(t, "run") for t in ("a", "b", "c")])
    by_tenant = {p.tenant: p for p in preds}
    assert by_tenant["a"].batch_size == 2       # a + c stack together
    assert by_tenant["c"].batch_size == 2
    assert by_tenant["b"].batch_size == 1
    assert by_tenant["a"].mean.shape == (5,)
    assert by_tenant["b"].mean.shape == (6,)
    # ... and each row still matches its per-request prediction bitwise.
    assert np.array_equal(svc.predict("a", "run").mean, by_tenant["a"].mean)


def test_observe_invalidates_warm_predictions():
    svc, tasks = make_service(["t0"], refit_every=0)
    tk = tasks["t0"]
    before = svc.predict("t0", "run")
    old_state = svc.store.get(SessionKey("t0", "run")).state

    mask2 = grow_mask(tk.mask)
    Y2 = np.where(mask2 > 0, np.asarray(tk.Y_full), 0.0)
    info = svc.observe("t0", "run", Y2, mask2)
    assert info["action"] == "extend"

    session = svc.store.get(SessionKey("t0", "run"))
    assert session.state is not old_state
    after = svc.predict("t0", "run")
    assert after.generation == before.generation + 1
    # New observations actually entered the served posterior.
    assert not np.array_equal(before.mean, after.mean)
    # Repeats on the unchanged new state are stable (cache, not staleness).
    again = svc.predict("t0", "run")
    assert np.array_equal(after.mean, again.mean)
    assert np.array_equal(after.var, again.var)


def test_refit_every_triggers_warm_refit():
    svc, tasks = make_service(["t0"], refit_every=2)
    tk = tasks["t0"]
    mask = tk.mask
    actions = []
    for _ in range(4):
        mask = grow_mask(mask)
        Y = np.where(mask > 0, np.asarray(tk.Y_full), 0.0)
        actions.append(svc.observe("t0", "run", Y, mask)["action"])
    assert actions == ["extend", "extend+refit", "extend", "extend+refit"]
    assert svc.counters["refits"].value == 2
    # refit re-derives fit metadata on the session's state.
    st = svc.store.get(SessionKey("t0", "run")).state
    assert st.fit_result is not None and st.backend_used is not None


def test_lru_eviction():
    names = [f"t{i}" for i in range(3)]
    svc, tasks = make_service(names, capacity=2, coalesce=False)
    stats = svc.store.stats()
    assert stats["size"] == 2 and stats["evictions"] == 1
    assert SessionKey("t0", "run") not in svc.store   # LRU went first
    with pytest.raises(KeyError):
        svc.predict("t0", "run")
    # Touching t1 makes t2 the LRU victim for the next insert.
    svc.predict("t1", "run")
    tk = tasks["t0"]
    svc.observe("t0", "run", tk.Y, tk.mask, X=tk.X, t=tk.t)
    assert SessionKey("t1", "run") in svc.store
    assert SessionKey("t2", "run") not in svc.store


def test_session_store_validation_and_stats():
    with pytest.raises(ValueError):
        SessionStore(capacity=0)
    store = SessionStore(capacity=2)
    assert store.get(SessionKey("a", "b")) is None
    assert store.stats()["misses"] == 1
    assert len(store) == 0


def test_concurrent_tenants_are_isolated():
    names = [f"t{i}" for i in range(4)]
    svc, tasks = make_service(names, refit_every=0)
    reference = {name: svc.predict(name, "run") for name in names}
    rounds = 4
    errors = []
    results = {name: [] for name in names}

    def worker(name):
        try:
            tk = tasks[name]
            mask = tk.mask
            for _ in range(rounds):
                mask = grow_mask(mask)
                Y = np.where(mask > 0, np.asarray(tk.Y_full), 0.0)
                svc.observe(name, "run", Y, mask)
                results[name].append(svc.predict(name, "run"))
        except Exception as e:  # noqa: BLE001 - surface to the main thread
            errors.append((name, e))

    threads = [threading.Thread(target=worker, args=(n,)) for n in names]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors

    for name in names:
        preds = results[name]
        assert [p.generation for p in preds] == list(
            range(reference[name].generation + 1,
                  reference[name].generation + rounds + 1))
        assert all(p.tenant == name for p in preds)
        # Concurrency must not leak another tenant's solves into this
        # session: replaying the same final state serially reproduces the
        # last concurrent prediction bitwise.
        assert np.array_equal(svc.predict(name, "run").mean, preds[-1].mean)


def test_async_submit_flush():
    names = [f"t{i}" for i in range(3)]
    svc, _ = make_service(names)
    futures = [svc.submit_predict(name, "run") for name in names]
    assert svc.batcher.pending() == 3
    assert not futures[0].done()
    assert svc.flush() == 3
    assert svc.batcher.pending() == 0
    results = [f.result(timeout=1) for f in futures]
    assert all(r.batch_size == 3 for r in results)
    singles = {name: svc.predict(name, "run") for name in names}
    for r in results:
        assert np.array_equal(singles[r.tenant].mean, r.mean)
    assert svc.flush() == 0                      # idempotent when drained


def test_batcher_isolates_group_failures():
    calls = []

    def execute(group):
        calls.append(len(group))
        if len(group) == 1:
            raise RuntimeError("boom")
        return [f"ok-{s}" for s in group]

    store = SessionStore(capacity=4)
    batcher = CoalescingBatcher(execute)

    class FakeSession:
        def __init__(self, sig):
            self._sig = sig

    import repro.serving.batcher as batcher_mod
    orig = batcher_mod.stack_signature
    batcher_mod.stack_signature = lambda s: s._sig
    try:
        good = [FakeSession("a"), FakeSession("a")]
        bad = FakeSession("b")
        futs = [batcher.submit(s) for s in [good[0], bad, good[1]]]
        assert batcher.flush() == 3
    finally:
        batcher_mod.stack_signature = orig
    assert sorted(calls) == [1, 2]
    assert futs[0].result(timeout=1) == f"ok-{good[0]}"
    assert futs[2].result(timeout=1) == f"ok-{good[1]}"
    with pytest.raises(RuntimeError, match="boom"):
        futs[1].result(timeout=1)
    assert coalesce_sessions([]) == []


def test_metrics_shape():
    svc, _ = make_service(["t0", "t1"])
    svc.predict("t0", "run")
    m = svc.metrics()
    assert set(m) == {"store", "predict_latency", "observe_latency",
                      "counters", "events", "compiled_caches"}
    assert m["counters"]["predicts"] == 1
    assert m["counters"]["observes"] == 2
    assert m["predict_latency"]["count"] == 1
    assert m["store"]["size"] == 2
    # compiled-program cache health (LRU counters) is service-observable
    for cache in ("fit_vg", "polish", "engines"):
        stats = m["compiled_caches"][cache]
        assert {"size", "maxsize", "hits", "misses",
                "evictions"} <= set(stats)


def test_solve_tally_is_thread_safe():
    """The engine solve tally is bumped from every tenant thread of a
    PredictionService; an unguarded read-modify-write drops counts across
    interpreter switches. Hammer _bump_tally from many threads with an
    aggressive switch interval and require an EXACT count."""
    import sys

    from repro.core import engines

    n_threads, n_bumps = 8, 2000
    before = engines.solve_tally()
    old_interval = sys.getswitchinterval()
    sys.setswitchinterval(1e-5)
    try:
        def hammer():
            for _ in range(n_bumps):
                engines._bump_tally()

        threads = [threading.Thread(target=hammer)
                   for _ in range(n_threads)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
    finally:
        sys.setswitchinterval(old_interval)
    assert engines.solve_tally() - before == n_threads * n_bumps
